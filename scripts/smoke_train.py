"""Dev smoke: distributed GS train step on whatever devices exist.

Run plain (1 device) or with XLA_FLAGS=--xla_force_host_platform_device_count=8.
Prints loss trajectory; with DUMP=1 writes loss curve to /tmp/losses.txt for
cross-device-count equality checks.
"""
import os
import sys

if "--devices" in sys.argv:
    i = sys.argv.index("--devices")
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={sys.argv[i+1]}"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as PS

from repro.core import gaussians as G
from repro.core import projection as P
from repro.core.config import GSConfig
from repro.core.train import init_state, make_train_step, state_shardings, make_eval_render
from repro.volume import kingsnake_like, extract_isosurface_points, orbit_cameras, render_isosurface
from repro.volume.cameras import camera_slice
from repro.core.losses import psnr

devs = jax.devices()
nd = len(devs)
dshape = {1: (1, 1), 2: (2, 1), 4: (2, 2), 8: (4, 2)}[nd]
mesh = jax.make_mesh(dshape, ("data", "model"))
print("mesh", mesh.shape)

H = W = 64
cfg = GSConfig(img_h=H, img_w=W, tile_h=16, tile_w=16, k_per_tile=256, batch_size=4, backend="ref")

vol = kingsnake_like(res=48)
pts, nrm, cols = extract_isosurface_points(vol, max_points=2000, seed=0)
print("extracted", pts.shape[0], "points")
cams = orbit_cameras(8, img_h=H, img_w=W, radius=3.0)
gts = jnp.stack([
    render_isosurface(jnp.asarray(vol.field), vol.isovalue, camera_slice(cams, i), img_h=H, img_w=W, n_steps=96)
    for i in range(8)
])
print("gt range", float(gts.min()), float(gts.max()))

# pad N to multiple of model axis * quantum
m = mesh.shape["model"]
n0 = pts.shape[0]
pad = (-n0) % (m * 128)
pts = np.concatenate([pts, np.full((pad, 3), 1e6, np.float32)])
cols = np.concatenate([cols, np.zeros((pad, 3), np.float32)])
g = G.init_from_points(jnp.asarray(pts), jnp.asarray(cols), init_scale=0.04)
g = g._replace(opacity_logit=g.opacity_logit.at[n0:].set(-20.0))

state = init_state(g)
sh = state_shardings(mesh)
state = jax.device_put(state, sh)
step_fn = make_train_step(mesh, cfg)

rng = np.random.default_rng(0)
losses = []
for it in range(20):
    sel = rng.choice(8, cfg.batch_size, replace=False)
    cb = camera_slice(cams, jnp.asarray(sel))
    gb = gts[jnp.asarray(sel)]
    state, metrics = step_fn(state, cb, gb)
    losses.append(float(metrics["loss"]))
    if it % 5 == 0:
        print(f"step {it} loss {losses[-1]:.5f}")

eval_fn = make_eval_render(mesh, cfg)
img, _ = eval_fn(state.params, camera_slice(cams, 0))
print("final loss", losses[-1], "eval psnr vs gt0", float(psnr(img, gts[0])))
if os.environ.get("DUMP"):
    np.savetxt(f"/tmp/losses_{nd}.txt", np.asarray(losses))
