"""Dev smoke: one train-loss eval + one decode step for every arch family."""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_arch
from repro.models import api, lm

B, S = 2, 32
for aid in ARCH_IDS:
    mod = get_arch(aid)
    cfg = mod.smoke_config()
    key = jax.random.key(0)
    params = lm.init_params(cfg, key)
    if cfg.arch_type == "whisper":
        batch = {
            "audio_embeds": jnp.zeros((B, cfg.n_audio_ctx, cfg.d_model), jnp.float32),
            "tokens": jnp.zeros((B, S), jnp.int32),
            "labels": jnp.ones((B, S), jnp.int32),
        }
    elif cfg.arch_type == "vlm":
        batch = {
            "embeds": jax.random.normal(key, (B, S, cfg.d_model), jnp.float32),
            "positions3": jnp.broadcast_to(jnp.arange(S)[None, :, None], (B, S, 3)).astype(jnp.int32),
            "labels": jnp.ones((B, S), jnp.int32),
        }
    else:
        batch = {
            "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
            "labels": jax.random.randint(key, (B, S), 0, cfg.vocab),
        }
    try:
        loss = jax.jit(lambda p, b: api.compute_loss(cfg, p, b))(params, batch)
        ok_train = bool(jnp.isfinite(loss))
        # decode
        cache = api.init_cache(cfg, B, 64)
        serve = api.make_serve_step(cfg)
        logits, cache2 = jax.jit(serve)(params, cache, jnp.zeros((B, 1), jnp.int32), jnp.asarray(5, jnp.int32))
        ok_dec = bool(jnp.all(jnp.isfinite(logits)))
        print(f"{aid:26s} loss={float(loss):8.4f} train_ok={ok_train} decode_ok={ok_dec} logits={logits.shape}")
    except Exception as e:
        print(f"{aid:26s} FAIL: {type(e).__name__}: {str(e)[:300]}")
