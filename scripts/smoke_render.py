"""Dev smoke: render path + pallas-vs-ref allclose (fwd + grad)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gaussians as G
from repro.core import projection as P
from repro.core import render as R
from repro.core.losses import gs_loss, psnr

rng = np.random.default_rng(0)
n = 500
pts = rng.normal(0, 0.3, (n, 3)).astype(np.float32)
cols = rng.uniform(0.2, 0.9, (n, 3)).astype(np.float32)
g = G.init_from_points(jnp.asarray(pts), jnp.asarray(cols), init_scale=0.03)

H = W = 64
cam = P.look_at_camera(eye=[0, 0, -3.0], target=[0, 0, 0], up=[0, 1, 0], fx=80.0, fy=80.0, cx=W / 2, cy=H / 2)

img_ref, t_ref = R.render(g, cam, img_h=H, img_w=W, tile_h=16, tile_w=16, k_per_tile=512, backend="ref")
img_pal, t_pal = R.render(g, cam, img_h=H, img_w=W, tile_h=16, tile_w=16, k_per_tile=512, backend="pallas")
print("img range", float(img_ref.min()), float(img_ref.max()), "mean T", float(t_ref.mean()))
print("fwd maxdiff img", float(jnp.abs(img_ref - img_pal).max()), "t", float(jnp.abs(t_ref - t_pal).max()))

# naive oracle check
packed = P.project(g, cam)
packed_s, _ = P.sort_by_depth(packed)
img_naive, _ = jax.jit(lambda p: R.raster_naive_check(p, H, W))(packed_s) if hasattr(R, "raster_naive_check") else (None, None)

from repro.kernels.tile_raster.ref import rasterize_naive
img_nv, t_nv = rasterize_naive(packed_s, H, W, jnp.zeros(3))
print("tiled-vs-naive maxdiff", float(jnp.abs(img_ref - img_nv).max()))

# grads
target = jnp.clip(img_ref + 0.01, 0, 1)


def loss_fn(gm, backend):
    img, _ = R.render(gm, cam, img_h=H, img_w=W, tile_h=16, tile_w=16, k_per_tile=512, backend=backend)
    return gs_loss(img, target)


gr = jax.grad(lambda gm: loss_fn(gm, "ref"))(g)
gp = jax.grad(lambda gm: loss_fn(gm, "pallas"))(g)
for name, a, b in zip(g._fields, gr, gp):
    d = float(jnp.abs(a - b).max())
    m = float(jnp.abs(a).max())
    print(f"grad {name}: maxdiff={d:.3e} scale={m:.3e}")
print("psnr vs target", float(psnr(img_ref, target)))
