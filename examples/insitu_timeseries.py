"""In-situ-style streaming reconstruction (the paper's future-work item).

A simulation produces a time-evolving volume; instead of writing full
volume dumps (the I/O burden the paper wants to avoid), each timestep is
reconstructed as a compact Gaussian model, WARM-STARTED from the previous
step's model — few optimization steps per timestep, since the isosurface
moves smoothly.

  PYTHONPATH=src python examples/insitu_timeseries.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gaussians as G
from repro.core.config import GSConfig
from repro.core.losses import psnr
from repro.core.train import init_state, make_eval_render, make_train_step, state_shardings
from repro.data.views import ViewDataset
from repro.volume.datasets import VolumeSpec, miranda_like
from repro.volume.isosurface import extract_isosurface_points


def evolving_volume(t: float, res: int = 40) -> VolumeSpec:
    """Mixing-layer field whose interface advances with simulation time."""
    base = miranda_like(res=res)
    x = np.linspace(-1, 1, res, dtype=np.float32)
    z = x[None, None, :]
    drift = 0.25 * np.sin(2.0 * np.pi * t) * np.cos(3.0 * z)
    return VolumeSpec(base.field + drift.astype(np.float32) * 0.3, base.isovalue, base.extent, f"insitu_t{t:.2f}")


def main():
    H = 48
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = GSConfig(img_h=H, img_w=H, batch_size=2, k_per_tile=128)
    step_fn = make_train_step(mesh, cfg)
    eval_fn = make_eval_render(mesh, cfg)

    state = None
    for ti, t in enumerate(np.linspace(0, 0.5, 4)):
        vol = evolving_volume(float(t))
        pts, _, cols = extract_isosurface_points(vol, max_points=1200, seed=0)
        data = ViewDataset(vol, n_views=6, img_h=H, img_w=H, cache_dir=None, n_steps_raymarch=48)

        if state is None:
            # cold start at t=0: full init from the extracted points
            pad = (-pts.shape[0]) % 256
            pts_p = np.concatenate([pts, np.full((pad, 3), 1e6, np.float32)])
            cols_p = np.concatenate([cols, np.zeros((pad, 3), np.float32)])
            g = G.init_from_points(jnp.asarray(pts_p), jnp.asarray(cols_p), init_scale=0.06)
            state = jax.device_put(init_state(g), state_shardings(mesh))
            n_steps = 40
        else:
            # warm start: keep the previous model, just continue optimizing
            n_steps = 12

        t0 = time.time()
        for cams, gt in data.batches(cfg.batch_size, steps=n_steps):
            state, m = step_fn(state, cams, gt)
        cam0, gt0 = data.view(0)
        img, _ = eval_fn(state.params, cam0)
        print(
            f"t={t:.2f}  {'cold' if ti == 0 else 'warm'}-start {n_steps:2d} steps "
            f"({time.time()-t0:5.1f}s)  loss {float(m['loss']):.4f}  PSNR {float(psnr(img, gt0)):5.2f} dB"
        )


if __name__ == "__main__":
    main()
