"""In-situ-style streaming reconstruction (the paper's future-work item).

A simulation produces a time-evolving volume; instead of writing full volume
dumps (the I/O burden the paper wants to avoid), each timestep is absorbed
into one fixed-capacity Gaussian model WARM-STARTED from the previous step —
few optimization steps per timestep, one jit trace for the whole sequence.
This is the ``repro.insitu`` subsystem end-to-end: an in-situ callback stream,
the incremental trainer, temporal (keyframe + quantized delta) checkpoints,
and a time-scrubbing render across the stored sequence.

  PYTHONPATH=src python examples/insitu_timeseries.py
"""
import os
import tempfile

import jax

from repro.core.config import GSConfig
from repro.insitu import InsituTrainer, TemporalCheckpointStore, build_timeline_server, scrub
from repro.serve_gs import front_camera
from repro.volume.timevary import synthetic_stream


def main():
    H = 48
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = GSConfig(
        img_h=H, img_w=H, batch_size=2, k_per_tile=128, max_steps=200,
        densify_from=10**9, opacity_reset_interval=10**9,
    )

    # the "simulation": a Miranda-like mixing layer growing over 4 timesteps
    stream = synthetic_stream("miranda", 4, res=32, t1=0.2)
    store = TemporalCheckpointStore(
        os.path.join(tempfile.mkdtemp(prefix="insitu_example_"), "seq"), keyframe_interval=4
    )
    trainer = InsituTrainer(
        cfg, mesh, cold_steps=60, warm_steps=15, n_views=6,
        max_points=800, n_steps_raymarch=48, init_scale=0.06, verbose=True,
    )
    trainer.run(stream, store=store)
    print(f"train-step traces across the sequence: {trainer.n_traces} (fixed capacity -> 1)")
    print(f"temporal store: {store.stats()}")

    # post hoc time-scrub: one camera, every stored timestep
    server = build_timeline_server(store, cfg, n_levels=2, max_batch=2)
    cam = front_camera(server.pyramid, img_h=H, img_w=H)
    frames = scrub(server, cam, store.timesteps())
    for t, frame in frames.items():
        print(f"  t={t}: frame {frame.shape}, surface pixels {(frame.sum(-1) > 0.01).mean():.1%}")


if __name__ == "__main__":
    main()
