"""Post-hoc visualization: restore a trained checkpoint and render a novel
orbit (the 'real-time post hoc visualization' use case from the paper).
Writes PPM images (no imaging deps needed).

  PYTHONPATH=src python examples/render_novel_views.py --ckpt experiments/ckpts/miranda_demo
"""
import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint
from repro.core import gaussians as G
from repro.core.config import GSConfig
from repro.core.train import init_state, make_eval_render, state_shardings
from repro.utils.image import write_ppm
from repro.volume.cameras import camera_slice, orbit_cameras


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt", required=True)
    ap.add_argument("--res", type=int, default=64)
    ap.add_argument("--views", type=int, default=8)
    ap.add_argument("--out", default="experiments/renders")
    args = ap.parse_args()

    step = latest_step(args.ckpt)
    if step is None:
        raise SystemExit(f"no checkpoint under {args.ckpt} — run the training example first")
    # peek manifest for the Gaussian count
    import json
    man = json.load(open(os.path.join(args.ckpt, f"step_{step:08d}", "manifest.json")))
    n = man["leaves"]["params.means"]["shape"][0]
    like = init_state(G.init_from_points(jnp.zeros((n, 3)), jnp.zeros((n, 3))))
    state = restore_checkpoint(args.ckpt, step, jax.tree_util.tree_map(np.asarray, like))

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = GSConfig(img_h=args.res, img_w=args.res, k_per_tile=256)
    render = make_eval_render(mesh, cfg)
    params = G.GaussianModel(*[jnp.asarray(x) for x in state.params])
    cams = orbit_cameras(args.views, img_h=args.res, img_w=args.res, radius=2.5, elev_cycles=1.0)
    os.makedirs(args.out, exist_ok=True)
    for i in range(args.views):
        img, _ = render(params, camera_slice(cams, i))
        path = os.path.join(args.out, f"novel_{i:03d}.ppm")
        write_ppm(path, img)
        print("wrote", path)


if __name__ == "__main__":
    main()
