"""Quickstart: fit 3D Gaussians to a synthetic isosurface in ~2 minutes on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gaussians as G
from repro.core.config import GSConfig
from repro.core.losses import psnr
from repro.core.train import init_state, make_eval_render, make_train_step, state_shardings
from repro.data.views import ViewDataset
from repro.volume import extract_isosurface_points, kingsnake_like

# 1. scientific volume -> isosurface point cloud (the ParaView step, in-repo)
vol = kingsnake_like(res=40)
points, normals, colors = extract_isosurface_points(vol, max_points=2500)
print(f"extracted {points.shape[0]} isosurface points from '{vol.name}'")

# 2. ground-truth views: ray-marched isosurface renders on a structured orbit
data = ViewDataset(vol, n_views=12, img_h=64, img_w=64, cache_dir=None, n_steps_raymarch=96)

# 3. Gaussians seeded from the point cloud
pad = (-points.shape[0]) % 256
points = np.concatenate([points, np.full((pad, 3), 1e6, np.float32)])
colors = np.concatenate([colors, np.zeros((pad, 3), np.float32)])
g = G.init_from_points(jnp.asarray(points), jnp.asarray(colors), init_scale=0.05)

# 4. distributed-ready train step (here on a trivial 1x1 mesh — the same code
#    runs Gaussian-sharded + pixel-sharded on a real TPU mesh)
mesh = jax.make_mesh((1, 1), ("data", "model"))
cfg = GSConfig(img_h=64, img_w=64, batch_size=4, k_per_tile=192)
state = jax.device_put(init_state(g), state_shardings(mesh))
step = make_train_step(mesh, cfg)

for i, (cams, gt) in enumerate(data.batches(cfg.batch_size, steps=60)):
    state, metrics = step(state, cams, gt)
    if i % 10 == 0:
        print(f"step {i:3d}  loss {float(metrics['loss']):.5f}")

# 5. evaluate
eval_render = make_eval_render(mesh, cfg)
cam, gt = data.view(0)
img, _ = eval_render(state.params, cam)
print(f"PSNR vs ground truth: {float(psnr(img, gt)):.2f} dB")
