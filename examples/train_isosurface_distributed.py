"""End-to-end driver: distributed training of a ~100k-Gaussian isosurface
model for a few hundred steps, with densification, checkpointing and final
metrics. This is the paper's pipeline at CPU-friendly scale; pass
--data-par/--model-par on a real mesh (or force host devices) to shard.

  PYTHONPATH=src python examples/train_isosurface_distributed.py \
      --dataset miranda --steps 300

(Equivalent to `python -m repro.launch.train`, kept here as the runnable
example entry point.)
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    if len(sys.argv) == 1:
        sys.argv += [
            "--dataset", "miranda", "--volume-res", "48", "--max-points", "8000",
            "--res", "64", "--steps", "300", "--views", "24", "--ckpt", "experiments/ckpts/miranda_demo",
        ]
    main()
