"""Quickstart: turn a Gaussian model into a render service and save frames.

Builds a tiny synthetic isosurface scene (or restores a checkpoint trained
with repro.launch.train), stands up the LOD-aware batched RenderServer, and
serves one orbit worth of frames to PPM files plus a serving report.

  PYTHONPATH=src python examples/serve_gs_quickstart.py --out experiments/served
  PYTHONPATH=src python examples/serve_gs_quickstart.py --ckpt experiments/ckpts/run0
"""
import argparse
import json
import os

from repro.core.config import GSConfig
from repro.launch.serve_gs import init_params_from_volume, load_params_from_ckpt
from repro.serve_gs import RenderServer
from repro.utils.image import write_ppm
from repro.volume.cameras import camera_slice, orbit_cameras


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--res", type=int, default=48)
    ap.add_argument("--views", type=int, default=8)
    ap.add_argument("--out", default="experiments/served")
    args = ap.parse_args()

    if args.ckpt:
        params = load_params_from_ckpt(args.ckpt)
    else:
        params = init_params_from_volume("kingsnake", volume_res=32, max_points=800)

    cfg = GSConfig(img_h=args.res, img_w=args.res, k_per_tile=128)
    # store_frames off: frames arrive through each request's FrameFuture, so
    # nothing needs to sit in the server's retirement buffer
    server = RenderServer(params, cfg, n_levels=2, max_batch=4, store_frames=False)

    # one orbit: near views hit LOD 0, a far ring hits the coarser level
    near = orbit_cameras(args.views, img_h=args.res, img_w=args.res, radius=3.0)
    far = orbit_cameras(args.views, img_h=args.res, img_w=args.res, radius=7.0)
    futures = []
    for cams in (near, far):
        for i in range(args.views):
            futures.append(server.submit(camera_slice(cams, i)))
    server.run()  # drains the pipelined dispatch ring; futures resolve

    os.makedirs(args.out, exist_ok=True)
    for k, fut in enumerate(futures):
        write_ppm(os.path.join(args.out, f"frame_{k:03d}.ppm"), fut.result())
    print(f"wrote {len(futures)} frames to {args.out}")
    print(json.dumps(server.report(), indent=1))


if __name__ == "__main__":
    main()
