"""Serving example for the transformer substrate: batched greedy decode with
a KV/state cache — the serve_step that the decode_32k / long_500k dry-run
shapes lower at production scale.

  PYTHONPATH=src python examples/serve_lm.py --arch qwen3-0.6b --tokens 16
(uses the reduced smoke variant so it runs in seconds on CPU)
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import api, lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=64)
    args = ap.parse_args()

    cfg = get_arch(args.arch).smoke_config()
    print(f"{cfg.name} (reduced): {cfg.n_layers}L d={cfg.d_model} arch={cfg.arch_type}")
    params = lm.init_params(cfg, jax.random.key(0))
    serve = jax.jit(api.make_serve_step(cfg))
    cache = api.init_cache(cfg, args.batch, args.cache_len)

    toks = jnp.full((args.batch, 1), 1, jnp.int32)
    out = []
    for t in range(args.tokens):
        logits, cache = serve(params, cache, toks, jnp.asarray(t, jnp.int32))
        toks = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(np.asarray(toks[:, 0]))
    gen = np.stack(out, 1)
    print("greedy-decoded token ids (batch x steps):")
    print(gen)
    assert np.isfinite(np.asarray(logits)).all()
    print("ok: cache-backed batched decode ran", args.tokens, "steps")


if __name__ == "__main__":
    main()
