"""Table I analog: distributed-GS training-time scaling vs worker count.

Paper Table I measures wall-clock training minutes on 1/2/4 A100s at
512/1024/2048 px for Kingsnake (4M) and Miranda (18M). This container has one
CPU core, so wall-clock across *fake* devices is meaningless; instead we
reproduce the table with the roofline-modeled step time extracted from the
compiled distributed step at the paper's exact scales (see gs_dryrun.py),
plus the memory-infeasibility check for Miranda on a single worker.

The paper's qualitative claims we validate:
  C1  speedup grows with resolution (pixel-dominated work shards over workers)
  C2  Miranda (18M) exceeds a single worker's memory but fits on 2/4
  C3  4-worker speedup at 2048px is large (paper: 5.6x on Kingsnake)
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from bench_schema import write_bench

CASES = [
    # (name, points, res, workers)
    ("kingsnake", 4_000_000, r, w) for r in (512, 1024, 2048) for w in (1, 2, 4)
] + [
    ("miranda", 18_180_000, r, w) for r in (512, 1024, 2048) for w in (1, 2, 4)
]

OUT = "experiments/gs_dryrun"
# paper-hardware memory budget per worker (A100-40GB on Polaris)
WORKER_HBM = 40e9


def run_all(fast: bool = False):
    cases = [c for c in CASES if c[2] <= (1024 if fast else 2048)]
    for name, pts, res, w in cases:
        path = os.path.join(OUT, f"{name}_{pts}_{res}_{w}w.json")
        if os.path.exists(path):
            continue
        cmd = [sys.executable, "benchmarks/gs_dryrun.py", "--points", str(pts), "--res", str(res),
               "--workers", str(w), "--name", name, "--out", OUT]
        r = subprocess.run(cmd, capture_output=True, text=True, timeout=3600,
                           env=dict(os.environ, PYTHONPATH="src"))
        status = "ok" if r.returncode == 0 else "FAIL"
        print(f"{status} {name} {res}px {w}w", flush=True)
        if r.returncode != 0:
            print(r.stderr[-1500:])


def table(out=print):
    """Two step-time models per row: `ref` uses the CPU-oracle lowering's
    memory term (alpha matrices spilled to HBM); `kernel` substitutes the
    Pallas rasterizer's VMEM-resident memory model (EXPERIMENTS.md §Perf G2).
    """
    rows = []
    for name, pts, res, w in CASES:
        path = os.path.join(OUT, f"{name}_{pts}_{res}_{w}w.json")
        if not os.path.exists(path):
            continue
        d = json.load(open(path))
        rf = d["roofline_s"]
        step_ref = max(rf["compute"], rf["memory"], rf["collective"])
        mem_k = rf.get("memory_kernel_adjusted", rf["memory"])
        step_kernel = max(rf["compute"], mem_k, rf["collective"])
        peak = d["per_worker"]["peak_bytes"]
        rows.append((name, res, w, step_ref, step_kernel, peak, rf, mem_k))
    out("dataset,res,workers,step_ref_s,step_kernel_s,peak_gb_per_worker,fits_A100_40GB,dominant_kernel")
    base = {}
    for name, res, w, s_ref, s_k, peak, rf, mem_k in rows:
        if w == 1:
            base[(name, res)] = s_k
        dom = max([("compute", rf["compute"]), ("memory", mem_k), ("collective", rf["collective"])],
                  key=lambda kv: kv[1])[0]
        out(f"{name},{res},{w},{s_ref:.4f},{s_k:.5f},{peak/1e9:.2f},{peak < WORKER_HBM},{dom}")
    out("")
    out("dataset,res,workers,modeled_speedup_vs_1w(kernel)")
    for name, res, w, s_ref, s_k, peak, rf, mem_k in rows:
        b = base.get((name, res))
        if b and w > 1:
            out(f"{name},{res},{w},{b/s_k:.2f}")
    return rows


def emit_bench(rows, path: str) -> dict:
    """Flatten the scaling table into a schema-2 BENCH record: per-case
    modeled step seconds + per-worker peak bytes (the dry-run analog of the
    live ``train.shard_*`` / devmem gauges), so the perf trajectory diff
    covers the paper-scale cases too."""
    metrics = {}
    base = {}
    for name, res, w, s_ref, s_k, peak, rf, mem_k in rows:
        key = f"{name}_{res}_{w}w"
        metrics[f"step_kernel_s.{key}"] = round(s_k, 6)
        metrics[f"peak_bytes.{key}"] = int(peak)
        if w == 1:
            base[(name, res)] = s_k
    for name, res, w, s_ref, s_k, peak, rf, mem_k in rows:
        b = base.get((name, res))
        if b and w > 1:
            metrics[f"speedup.{name}_{res}_{w}w"] = round(b / s_k, 3)
    metrics["cases"] = len(rows)
    metrics["fits_40gb"] = sum(1 for r in rows if r[5] < WORKER_HBM)
    return write_bench(
        path, "table1_scaling",
        config={"worker_hbm_bytes": WORKER_HBM, "source": OUT},
        metrics=metrics,
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="skip the 2048px cases")
    ap.add_argument("--bench-out", default=None,
                    help="also write a flat BENCH_*.json record (bench_schema)")
    args = ap.parse_args(argv)
    run_all(fast=args.fast)
    rows = table()
    if args.bench_out and rows:
        emit_bench(rows, args.bench_out)


if __name__ == "__main__":
    main()
