"""GS train-step dry-run at PAPER scale (Table I analog machinery).

Lowers the distributed Grendel-style GS train step with ShapeDtypeStructs at
the paper's true scales (Kingsnake 4M / Miranda 18.18M Gaussians; 512-2048px)
for 1/2/4 workers, and extracts per-worker FLOPs / HBM bytes / collective
bytes with the trip-aware HLO cost model. Wall-clock on this CPU container is
meaningless for a 4-A100 claim, so the Table I analog reports *modeled* step
time on the paper's hardware class and the derived speedups — method
documented in EXPERIMENTS.md §Paper-repro.

Run one point:  PYTHONPATH=src python benchmarks/gs_dryrun.py --points 4000000 --res 512 --workers 4
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--points", type=int, required=True)
    ap.add_argument("--res", type=int, required=True)
    ap.add_argument("--workers", type=int, required=True)       # model-axis workers
    ap.add_argument("--data-par", type=int, default=1)          # data-axis (views)
    ap.add_argument("--pods", type=int, default=1)              # pod axis (the paper's multi-node future work)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--k-per-tile", type=int, default=1024)
    ap.add_argument("--name", default="gs")
    ap.add_argument("--out", default="experiments/gs_dryrun")
    ap.add_argument("--gather-mode", default="projected", choices=["projected", "params3d"])
    args = ap.parse_args()

    n_dev = max(args.workers * args.data_par * args.pods, 1)
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import gaussians as G
    from repro.core import projection as P
    from repro.core.config import GSConfig
    from repro.core.train import init_state, make_train_step
    from repro.launch import hlo_cost
    from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

    if args.pods > 1:
        mesh = jax.make_mesh((args.pods, args.data_par, args.workers), ("pod", "data", "model"))
        data_axes = ("pod", "data")
    else:
        mesh = jax.make_mesh((args.data_par, args.workers), ("data", "model"))
        data_axes = ("data",)
    quantum = args.workers * 256
    n = int(np.ceil(args.points / quantum) * quantum)
    cfg = GSConfig(
        img_h=args.res, img_w=args.res, batch_size=args.batch,
        k_per_tile=args.k_per_tile, backend="ref", gather_mode=args.gather_mode,
    )
    if args.gather_mode != "projected":
        args.name = f"{args.name}-{args.gather_mode}"

    def sds(shape, dt=jnp.float32):
        return jax.ShapeDtypeStruct(shape, dt)

    params = G.GaussianModel(
        means=sds((n, 3)), log_scales=sds((n, 3)), quats=sds((n, 4)),
        opacity_logit=sds((n,)), sh=sds((n, 1, 3)),
    )
    state = jax.eval_shape(init_state, params)
    cams = P.Camera(
        viewmat=sds((args.batch, 4, 4)), fx=sds((args.batch,)), fy=sds((args.batch,)),
        cx=sds((args.batch,)), cy=sds((args.batch,)),
    )
    gt = sds((args.batch, args.res, args.res, 3))

    step = make_train_step(mesh, cfg, data_axes=data_axes)
    lowered = step.lower(state, cams, gt)
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    txt = compiled.as_text()
    cost = hlo_cost.analyze(txt)

    # kernel-adjusted memory: the (K, tile_pixels) alpha-matrix intermediates
    # live in VMEM inside the Pallas rasterizer on TPU; the ref lowering
    # spills them to HBM. Subtract that class, add the kernel's true slab I/O.
    hc = hlo_cost.HloCost(txt)
    tile_px = cfg.tile_h * cfg.tile_w
    alpha_class = hlo_cost.sum_sig_suffix_bytes(hc, (args.k_per_tile, tile_px))
    tiles_local = (args.res // cfg.tile_h) * (args.res // cfg.tile_w) // max(args.workers, 1)
    slab_io = args.batch * tiles_local * args.k_per_tile * 11 * 4.0 * 3  # fwd read + bwd read/write
    kernel_mem_bytes = max(cost["bytes"] - alpha_class, 0.0) + slab_io

    result = {
        "name": args.name, "points": args.points, "res": args.res, "workers": args.workers,
        "pods": args.pods, "data_par": args.data_par,
        "batch": args.batch,
        "per_worker": {
            "flops": cost["flops"],
            "hbm_bytes": cost["bytes"],
            "collective_bytes": cost["coll_total_moved_bytes"],
            "collectives": cost["coll"],
            "arg_bytes": mem.argument_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": mem.argument_size_in_bytes + mem.temp_size_in_bytes,
        },
        "roofline_s": {
            "compute": cost["flops"] / PEAK_FLOPS_BF16,
            "memory": cost["bytes"] / HBM_BW,
            "memory_kernel_adjusted": kernel_mem_bytes / HBM_BW,
            "collective": cost["coll_total_moved_bytes"] / ICI_BW,
        },
        "alpha_class_bytes": alpha_class,
        "top_bytes": cost.get("top_bytes", []),
        "top_collectives": cost.get("top_collectives", []),
    }
    os.makedirs(args.out, exist_ok=True)
    tag = f"{args.workers}w" + (f"_{args.pods}pod{args.data_par}dp" if args.pods > 1 or args.data_par > 1 else "")
    path = os.path.join(args.out, f"{args.name}_{args.points}_{args.res}_{tag}.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    print(json.dumps(result["roofline_s"]), "peak_gb=%.2f" % (result["per_worker"]["peak_bytes"] / 1e9))


if __name__ == "__main__":
    main()
