"""Shared machine-readable benchmark record (BENCH_*.json).

Every serving benchmark in CI emits one flat record with the same shape, so
the per-PR perf trajectory can be diffed/plotted without per-benchmark
parsers:

  {
    "bench":   "<benchmark name>",
    "schema":  2,
    "config":  {...knobs that define the run...},
    "metrics": {...flat floats/ints: frames_per_s, p50_ms, p99_ms, ...},
    "stages":  {...optional per-stage latency breakdown...}
  }

Schema 2 adds the optional ``stages`` block: per-stage latency histograms
(count/sum/mean/min/max/p50/p95/p99 + bucket counts) straight from the
``repro.obs`` registry snapshot, so a BENCH record carries distributions
instead of only aggregate fps. Schema-1 consumers that ignore unknown keys
keep working; ``stages`` is omitted when a benchmark has nothing to report.
"""
from __future__ import annotations

import json
import os

SCHEMA_VERSION = 2


def stage_breakdown(snapshot: dict, prefix: str | None = None) -> dict:
    """Extract the histogram entries of a ``MetricsRegistry.snapshot()`` as a
    BENCH ``stages`` block ({dotted name: histogram dict}). ``prefix``
    filters to one tier (e.g. ``"server."``)."""
    out = {}
    for name, v in snapshot.items():
        if prefix is not None and not name.startswith(prefix):
            continue
        if isinstance(v, dict) and "p99" in v and "buckets" in v:
            out[name] = v
    return out


def bench_record(name: str, config: dict, metrics: dict, stages: dict | None = None) -> dict:
    rec = {"bench": name, "schema": SCHEMA_VERSION, "config": config, "metrics": metrics}
    if stages:
        rec["stages"] = stages
    return rec


def write_bench(
    path: str, name: str, config: dict, metrics: dict, stages: dict | None = None
) -> dict:
    rec = bench_record(name, config, metrics, stages)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec
