"""Shared machine-readable benchmark record (BENCH_*.json).

Every serving benchmark in CI emits one flat record with the same shape, so
the per-PR perf trajectory can be diffed/plotted without per-benchmark
parsers:

  {
    "bench":   "<benchmark name>",
    "schema":  1,
    "config":  {...knobs that define the run...},
    "metrics": {...flat floats/ints: frames_per_s, p50_ms, p99_ms, ...}
  }
"""
from __future__ import annotations

import json
import os

SCHEMA_VERSION = 1


def bench_record(name: str, config: dict, metrics: dict) -> dict:
    return {"bench": name, "schema": SCHEMA_VERSION, "config": config, "metrics": metrics}


def write_bench(path: str, name: str, config: dict, metrics: dict) -> dict:
    rec = bench_record(name, config, metrics)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec
