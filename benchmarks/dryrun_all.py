"""Drive the full (arch x shape x mesh) dry-run sweep as subprocesses.

Each combo runs in a fresh process (XLA device-count flags are per-process).
Results cached as JSON under experiments/dryrun/; reruns skip existing files.

Usage: PYTHONPATH=src python benchmarks/dryrun_all.py [--multi-pod-only] [--single-pod-only]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

ARCHS = [
    # roughly smallest-compile-first so failures surface early
    "qwen3-0.6b",
    "whisper-tiny",
    "xlstm-350m",
    "granite-moe-3b-a800m",
    "granite-3-8b",
    "moonshot-v1-16b-a3b",
    "zamba2-7b",
    "gemma3-27b",
    "kimi-k2-1t-a32b",
    "qwen2-vl-72b",
]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
OUT = "experiments/dryrun"


def result_path(arch_name: str, shape: str, mesh: str) -> str:
    return os.path.join(OUT, f"{arch_name}_{shape}_{mesh}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--timeout", type=int, default=3600)
    args = ap.parse_args()

    meshes = ["pod1", "pod2"]
    if args.multi_pod_only:
        meshes = ["pod2"]
    if args.single_pod_only:
        meshes = ["pod1"]

    os.makedirs(OUT, exist_ok=True)
    fail_log = os.path.join(OUT, "failures.log")
    for mesh in meshes:
        for arch in ARCHS:
            for shape in SHAPES:
                # arch name inside the json uses the config's display name
                from importlib import import_module  # local to avoid jax import here
                disp = arch.replace("_", "-")
                path = result_path(disp, shape, mesh)
                if os.path.exists(path):
                    print(f"cached  {disp} {shape} {mesh}")
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch, "--shape", shape, "--out", OUT]
                if mesh == "pod2":
                    cmd.append("--multi-pod")
                t0 = time.time()
                print(f"RUN     {disp} {shape} {mesh} ...", flush=True)
                try:
                    r = subprocess.run(
                        cmd, capture_output=True, text=True, timeout=args.timeout,
                        env=dict(os.environ, PYTHONPATH="src"), cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    )
                    if r.returncode != 0:
                        with open(fail_log, "a") as f:
                            f.write(f"=== {disp} {shape} {mesh} rc={r.returncode}\n{r.stdout[-2000:]}\n{r.stderr[-4000:]}\n")
                        print(f"FAIL    {disp} {shape} {mesh} ({time.time()-t0:.0f}s) rc={r.returncode}")
                    else:
                        print(f"ok      {disp} {shape} {mesh} ({time.time()-t0:.0f}s)")
                except subprocess.TimeoutExpired:
                    with open(fail_log, "a") as f:
                        f.write(f"=== {disp} {shape} {mesh} TIMEOUT\n")
                    print(f"TIMEOUT {disp} {shape} {mesh}")


if __name__ == "__main__":
    main()
