"""Network frontend load: N asyncio clients over localhost TCP vs the
in-process pipelined baseline.

Methodology: one shared serving pool with TWO registered streams — a static
synthetic isosurface scene and a real ``TemporalCheckpointStore``-backed
insitu timeline (recorded into a temp dir at startup). The same request
trace (every client walks an orbit; odd clients scrub the timeline, even
clients orbit the static scene) is driven twice over warmed jit traces:

  in-process — submit straight into the RenderServer, pipelined drain
               (the ``serve_throughput.py`` serving discipline)
  network    — N concurrent asyncio clients connect to the gateway over
               localhost TCP, each awaiting its frames end-to-end (protocol
               encode/decode + RGB8/zlib-delta frame encoding included)

Between laps the frame cache and metrics reset, so both laps render cold.
Reports aggregate fps, client-observed p50/p99 latency, shed/drop/protocol
error counts, bytes on the wire, and the network/in-process fps ratio;
writes a BENCH_frontend.json perf-trajectory record. Exits nonzero if any
request was dropped without a shed notice, anything was shed at all (the
trace is sized within admission capacity), any protocol error occurred, or
the fps ratio falls below ``--min-ratio``.

  PYTHONPATH=src python benchmarks/frontend_load.py --smoke --out BENCH_frontend.json
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os
import sys
import tempfile
import time

# Batched serving shards views over the mesh's data axis; on a CPU host we
# split the platform into a few "devices" (the dryrun methodology) so a
# micro-batch genuinely renders views in parallel. Must run before jax init.
_flags = os.environ.get("XLA_FLAGS", "")
if "host_platform_device_count" not in _flags:
    n_dev = min(4, os.cpu_count() or 1)
    os.environ["XLA_FLAGS"] = f"{_flags} --xla_force_host_platform_device_count={n_dev}".strip()

import jax

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from bench_schema import stage_breakdown, write_bench
from repro.core.config import GSConfig
from repro.frontend import (
    AsyncFrontendClient,
    Gateway,
    GatewayThread,
    SessionManager,
)
from repro.insitu import TemporalCheckpointStore, timeline_stream
from repro.launch.frontend import synthetic_timeline
from repro.launch.serve_gs import init_params_from_volume
from repro.launch.tune import load_recommended_knobs
from repro.obs import Histogram, trace_meta, validate_trace_jsonl, write_trace
from repro.serve_gs import make_clients
from repro.serve_gs.server import _percentile


def record_timeline(params, n_steps: int, directory: str) -> TemporalCheckpointStore:
    """Record a small drifting sequence into a real temporal store (the
    'timeline' stream is then served exactly like a recorded insitu run)."""
    with TemporalCheckpointStore(directory, keyframe_interval=2) as store:
        for t, p in sorted(synthetic_timeline(params, n_steps).items()):
            store.append(t, p)
    return TemporalCheckpointStore(directory)


def build_trace(args):
    """Per-client (stream, timestep, camera) request sequences — identical
    for the in-process and network laps."""
    orbits = make_clients(
        args.clients, n_views=12, img_h=args.res, img_w=args.res, shared_orbit=False
    )
    trace = []
    for c, orbit in enumerate(orbits):
        reqs = []
        for r in range(args.requests):
            cam = orbit.next_camera()
            if c % 2 == 0:
                reqs.append(("static", 0, cam))
            else:
                reqs.append(("timeline", r % args.timeline_steps, cam))
        trace.append(reqs)
    return trace


def run_inprocess(manager: SessionManager, trace, *, laps=2) -> dict:
    """The pipelined in-process baseline: wavefront submits, ring drain.
    Best of ``laps`` cold-cache runs (scheduler-noise hygiene, matching
    ``serve_throughput.py``)."""
    server = manager.server
    best = None
    for _ in range(laps):
        server.cache.drop(lambda k: True)  # every lap renders cold
        t0 = time.perf_counter()
        lat = []
        for r in range(len(trace[0])):
            wave = []
            for c, reqs in enumerate(trace):
                stream, t, cam = reqs[r]
                ts = time.perf_counter()
                wave.append(
                    (server.submit(cam, timestep=manager.resolve(stream, t), client_id=c), ts)
                )
            server.run()
            for fut, ts in wave:
                fut.result()
                lat.append(time.perf_counter() - ts)
        wall = time.perf_counter() - t0
        n = sum(len(r) for r in trace)
        rep = {
            "submitted": n,
            "frames_per_s": round(n / wall, 2),
            "p50_ms": round(_percentile([x * 1e3 for x in lat], 50), 3),
            "p99_ms": round(_percentile([x * 1e3 for x in lat], 99), 3),
        }
        if best is None or rep["frames_per_s"] > best["frames_per_s"]:
            best = rep
    return best


async def one_client(cl: AsyncFrontendClient, reqs, lat, errors, window: int):
    """Drive one viewer: up to ``window`` requests in flight (a streaming
    client requests ahead of display, mirroring the engine's pipelined
    dispatch; window=1 is strict request-response lockstep)."""
    frames = 0
    inflight = []
    async def drain_one():
        nonlocal frames
        fut, t0 = inflight.pop(0)
        try:
            frame = await fut
            assert frame.ndim == 3
            frames += 1
            lat.append(time.perf_counter() - t0)
        except Exception as e:  # shed / remote error: counted, not fatal here
            errors.append(repr(e))

    for stream, t, cam in reqs:
        if len(inflight) >= window:
            await drain_one()
        inflight.append((await cl.submit_render(stream, cam, timestep=t), time.perf_counter()))
    while inflight:
        await drain_one()
    return frames


def aggregate_encoders(stats: dict) -> dict:
    """Fold per-session encoder stats into one wire-cost record (sessions
    vanish on disconnect, so this must run while the clients are live)."""
    keys = ("tiles_total", "tiles_shipped", "tiles_reffed", "tile_frames",
            "delta_frames", "raw_frames", "raw_fallbacks", "bytes_sent",
            "bytes_raw_equiv")
    tot = dict.fromkeys(keys, 0)
    for s in stats.get("sessions", {}).values():
        enc = s.get("encoder") or {}
        for k in keys:
            tot[k] += enc.get(k) or 0
    tot["tiles_shipped_frac"] = (
        round(tot["tiles_shipped"] / tot["tiles_total"], 4)
        if tot["tiles_total"] else None
    )
    tot["compression"] = (
        round(tot["bytes_raw_equiv"] / tot["bytes_sent"], 3)
        if tot["bytes_sent"] else None
    )
    return tot


async def drive_clients(host, port, trace, window) -> dict:
    """One measured lap: connect N clients, run the trace, disconnect."""
    clients = []
    for _ in trace:
        cl = AsyncFrontendClient(host, port)
        await cl.connect()
        clients.append(cl)
    try:
        lat, errors = [], []
        t0 = time.perf_counter()
        frames = await asyncio.gather(*[
            one_client(cl, reqs, lat, errors, window)
            for cl, reqs in zip(clients, trace)
        ])
        wall = time.perf_counter() - t0
        # wire-encoder stats live on the sessions: snapshot before disconnect
        wire = aggregate_encoders(await clients[0].stats())
        n = sum(len(r) for r in trace)
        return {
            "completed": int(sum(frames)),
            "submitted": n,
            "frames_per_s": round(sum(frames) / wall, 2),
            "p50_ms": round(_percentile([x * 1e3 for x in lat], 50), 3),
            "p99_ms": round(_percentile([x * 1e3 for x in lat], 99), 3),
            "client_errors": errors,
            "wire": wire,
        }
    finally:
        for cl in clients:
            await cl.close()




def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="reduced CPU config")
    ap.add_argument("--dataset", default="kingsnake")
    ap.add_argument("--res", type=int, default=64)
    ap.add_argument("--volume-res", type=int, default=48)
    ap.add_argument("--max-points", type=int, default=3000)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--requests", type=int, default=8, help="requests per client")
    ap.add_argument("--timeline-steps", type=int, default=3)
    ap.add_argument("--levels", type=int, default=2)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--pipeline-depth", type=int, default=2)
    ap.add_argument("--queue-limit", type=int, default=8)
    ap.add_argument("--wave-per-session", type=int, default=4)
    ap.add_argument("--coalesce-ms", type=float, default=2.0)
    ap.add_argument("--config-from", default=None, metavar="RECOMMEND.json",
                    help="apply the knobs recommended by repro.launch.tune "
                         "(coalesce/batch/depth/queue/wave) before serving")
    ap.add_argument("--client-window", type=int, default=2,
                    help="in-flight requests per client (1 = strict lockstep)")
    ap.add_argument("--no-delta", action="store_true")
    ap.add_argument("--min-ratio", type=float, default=0.75,
                    help="fail if network fps < ratio x in-process fps")
    ap.add_argument("--trace-out", default=None, metavar="PATH.jsonl",
                    help="run one extra traced lap, export its span trees as "
                         "JSONL + Chrome trace JSON, and gate the overhead")
    ap.add_argument("--trace-capacity", type=int, default=65536,
                    help="span ring size for the traced lap")
    ap.add_argument("--max-trace-overhead", type=float, default=0.5,
                    help="fail if the traced lap loses more than this "
                         "fraction of fps vs the slower untraced lap "
                         "(lenient: shared CI hosts are noisy)")
    ap.add_argument("--out", default="BENCH_frontend.json")
    args = ap.parse_args(argv)

    if args.config_from:
        # knobs recommended by repro.launch.tune (replay-driven autotuning);
        # unknown-to-this-driver knobs (cache_scale) are ignored
        knobs = load_recommended_knobs(args.config_from)
        for knob, attr in (
            ("coalesce_ms", "coalesce_ms"), ("max_batch", "max_batch"),
            ("pipeline_depth", "pipeline_depth"), ("queue_limit", "queue_limit"),
            ("wave_per_session", "wave_per_session"),
        ):
            if knob in knobs:
                setattr(args, attr, type(getattr(args, attr))(knobs[knob]))
        print(f"config-from {args.config_from}: "
              f"coalesce_ms={args.coalesce_ms} max_batch={args.max_batch} "
              f"pipeline_depth={args.pipeline_depth} "
              f"queue_limit={args.queue_limit} "
              f"wave_per_session={args.wave_per_session}")

    if args.smoke:
        args.res, args.volume_res, args.max_points = 32, 32, 800
        args.requests = min(args.requests, 6)
        # 32px toy frames render in ~3 ms, so the fixed per-message network
        # cost (~1.5 ms: two asyncio stacks + TCP on a shared 2-core host)
        # is comparable to the render itself; the fps-ratio criterion is
        # about production frame sizes (see --res 64 default), the smoke
        # gate is functional: zero shed, zero drops, zero protocol errors
        args.min_ratio = min(args.min_ratio, 0.3)

    params = init_params_from_volume(
        args.dataset, volume_res=args.volume_res, max_points=args.max_points
    )
    cfg = GSConfig(img_h=args.res, img_w=args.res, k_per_tile=128 if args.smoke else 256)
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev, 1), ("data", "model"))

    manager = SessionManager(
        cfg, mesh=mesh, n_levels=args.levels, max_batch=args.max_batch,
        cache_capacity=512, store_frames=False, pipeline_depth=args.pipeline_depth,
    )
    manager.register_static("static", params)
    store = record_timeline(
        params, args.timeline_steps,
        os.path.join(tempfile.mkdtemp(prefix="frontend_bench_"), "seq"),
    )
    with store:
        timeline_stream(manager, "timeline", store)
    warm_s = manager.warmup()
    trace = build_trace(args)
    submitted = args.clients * args.requests

    # ---- in-process pipelined baseline (best of 2 cold-cache laps)
    rep_local = run_inprocess(manager, trace)

    # ---- identical trace over localhost TCP: clients in their OWN process
    # (like real remote viewers), best of 2 cold-cache laps. One unified
    # reset() windows every tier (server + cache + gateway + sessions) per
    # lap; the acceptance gates then sum the per-lap gateway snapshots, so
    # nothing shed or misframed in an early lap can hide behind a reset.
    manager.obs.metrics.reset()

    def _gw_counters(snapshot: dict) -> dict:
        return {
            k.split(".", 1)[1]: v for k, v in snapshot.items()
            if k.startswith("gateway.") and not isinstance(v, dict)
        }

    # per-lap histogram accumulation: bucket counts ADD across laps
    # (Histogram.merge), so the BENCH stages block describes every lap's
    # samples at full percentile fidelity — not just the best-timed lap
    hist_acc: dict[str, Histogram] = {}

    def _accumulate_hists(snapshot: dict) -> None:
        for k, v in snapshot.items():
            if isinstance(v, dict) and "counts" in v:
                if k in hist_acc:
                    hist_acc[k].merge(v)
                else:
                    hist_acc[k] = Histogram.from_dict(v, k)

    gateway = Gateway(
        manager, port=0, queue_limit=args.queue_limit,
        wave_per_session=args.wave_per_session,
        coalesce_ms=args.coalesce_ms,
        delta_encoding=not args.no_delta,
    )
    gt = GatewayThread(gateway).start()
    try:
        rep_net, laps, gw_laps = None, [], []
        for _ in range(2):
            # cold cache per lap, routed through the engine's single thread
            gateway.run_on_engine(manager.server.cache.drop, lambda k: True).result()
            rep = asyncio.run(
                drive_clients("127.0.0.1", gt.port, trace, args.client_window)
            )
            laps.append(rep)
            snap = manager.obs.metrics.snapshot()
            gw_laps.append(_gw_counters(snap))
            _accumulate_hists(snap)
            if rep_net is None or rep["frames_per_s"] > rep_net["frames_per_s"]:
                rep_net = rep
            gateway.run_on_engine(manager.obs.metrics.reset).result()

        # ---- optional third lap with span tracing live: same trace, fps
        # compared against the SLOWER untraced lap (overhead budget), span
        # trees exported as JSONL + Chrome trace JSON and re-validated
        trace_info = None
        if args.trace_out:
            manager.obs.enable_trace(args.trace_capacity)
            gateway.run_on_engine(manager.server.cache.drop, lambda k: True).result()
            rep_traced = asyncio.run(
                drive_clients("127.0.0.1", gt.port, trace, args.client_window)
            )
            laps.append(rep_traced)
            snap = manager.obs.metrics.snapshot()
            gw_laps.append(_gw_counters(snap))
            _accumulate_hists(snap)
            spans = manager.obs.trace.drain()
            dropped = manager.obs.trace.dropped
            # the knobs that produced this trace travel in the export header
            # so launch.tune replays against the real baseline configuration
            meta = trace_meta(manager.obs.trace, knobs={
                "coalesce_ms": args.coalesce_ms,
                "max_batch": args.max_batch,
                "pipeline_depth": args.pipeline_depth,
                "queue_limit": args.queue_limit,
                "wave_per_session": args.wave_per_session,
            })
            manager.obs.disable_trace()
            jsonl_path, chrome_path = write_trace(args.trace_out, spans, meta=meta)
            with open(jsonl_path) as f:
                n_spans = validate_trace_jsonl(f.read())
            floor_fps = min(lap["frames_per_s"] for lap in laps[:2])
            overhead = round(1.0 - rep_traced["frames_per_s"] / max(floor_fps, 1e-9), 3)
            trace_info = {
                "spans": int(n_spans), "dropped": dropped,
                "traced_frames_per_s": rep_traced["frames_per_s"],
                "traced_p50_ms": rep_traced["p50_ms"],
                "traced_p99_ms": rep_traced["p99_ms"],
                "overhead": overhead,
                "jsonl": jsonl_path, "chrome": chrome_path,
            }

        async def fetch_stats():
            cl = AsyncFrontendClient("127.0.0.1", gt.port)
            await cl.connect()
            try:
                return await cl.stats()
            finally:
                await cl.close()

        stats = asyncio.run(fetch_stats())
    finally:
        gt.stop()

    # acceptance-gate counters: sum of the per-lap windows
    gw = {}
    for lap_gw in gw_laps:
        for k, v in lap_gw.items():
            gw[k] = gw.get(k, 0) + v
    ratio = round(rep_net["frames_per_s"] / max(rep_local["frames_per_s"], 1e-9), 3)
    report = {
        "scene": {"dataset": args.dataset, "gaussians": params.n, "res": args.res},
        "devices": n_dev,
        "streams": stats["streams"],
        "request_set": {
            "clients": args.clients, "requests_per_client": args.requests,
            "submitted": submitted,
        },
        "warmup_s": round(warm_s, 2),
        "inprocess": rep_local,
        "network": rep_net,
        "network_vs_inprocess": ratio,
        "gateway": gw,
        "wire": rep_net["wire"],
    }
    if trace_info:
        report["trace"] = trace_info
    print(json.dumps(report, indent=1))
    if args.out:
        write_bench(
            args.out, "frontend_load",
            config={
                "clients": args.clients, "requests_per_client": args.requests,
                "res": args.res, "gaussians": params.n, "devices": n_dev,
                "streams": len(stats["streams"]), "pipeline_depth": args.pipeline_depth,
                "queue_limit": args.queue_limit, "delta": not args.no_delta,
                "wave_per_session": args.wave_per_session,
                "coalesce_ms": args.coalesce_ms, "max_batch": args.max_batch,
                "config_from": args.config_from, "smoke": args.smoke,
            },
            metrics={
                "frames_per_s": rep_net["frames_per_s"],
                "p50_ms": rep_net["p50_ms"],
                "p99_ms": rep_net["p99_ms"],
                "inprocess_frames_per_s": rep_local["frames_per_s"],
                "network_vs_inprocess": ratio,
                "shed": gw["shed"],
                "protocol_errors": gw["protocol_errors"],
                "request_errors": gw["request_errors"],
                "dropped_writes": gw["dropped_writes"],
                "bytes_out": gw["bytes_out"],
                "wire_compression": rep_net["wire"]["compression"] or 0.0,
                "tiles_shipped_frac": rep_net["wire"]["tiles_shipped_frac"] or 0.0,
                "tile_frames": rep_net["wire"]["tile_frames"],
                "raw_fallbacks": rep_net["wire"]["raw_fallbacks"],
                **({"trace_spans": trace_info["spans"],
                    "trace_overhead": trace_info["overhead"],
                    # the traced lap's own measured numbers: the ones the
                    # replay harness (launch.tune --measured) calibrates
                    # against, since the exported spans describe THAT lap
                    "trace_frames_per_s": trace_info["traced_frames_per_s"],
                    "trace_p50_ms": trace_info["traced_p50_ms"],
                    "trace_p99_ms": trace_info["traced_p99_ms"]} if trace_info else {}),
            },
            # stages merged across every lap (histogram bucket counts add),
            # filtered through the same schema shape check as before
            stages=stage_breakdown(
                {k: h.snapshot() for k, h in sorted(hist_acc.items())}
            ),
        )

    # ---- hard acceptance over EVERY lap (not just the best-timed one):
    # nothing lost, nothing shed, nothing misframed
    for i, lap in enumerate(laps):
        if lap["completed"] != submitted:
            raise SystemExit(
                f"unshed drop in lap {i}: {lap['completed']} frames "
                f"of {submitted} submitted (shed={gw['shed']})"
            )
        if lap["client_errors"]:
            raise SystemExit(
                f"client errors in lap {i}: {lap['client_errors'][:3]}"
            )
    if gw["shed"]:
        raise SystemExit(f"load shed on an in-capacity trace: {gw['shed']}")
    if gw["protocol_errors"] or gw["request_errors"]:
        raise SystemExit(
            f"protocol/request errors: {gw['protocol_errors']}/{gw['request_errors']}"
        )
    if ratio < args.min_ratio:
        raise SystemExit(
            f"network fps {rep_net['frames_per_s']} < {args.min_ratio} x "
            f"in-process {rep_local['frames_per_s']}"
        )
    if trace_info:
        if trace_info["dropped"]:
            raise SystemExit(
                f"span ring overflowed: {trace_info['dropped']} spans dropped "
                f"(raise the recorder capacity)"
            )
        if trace_info["overhead"] > args.max_trace_overhead:
            raise SystemExit(
                f"tracing overhead {trace_info['overhead']} exceeds budget "
                f"{args.max_trace_overhead} (traced "
                f"{trace_info['traced_frames_per_s']} fps vs untraced floor)"
            )
        print(
            f"trace: {trace_info['spans']} spans -> {trace_info['jsonl']} + "
            f"{trace_info['chrome']} (overhead {trace_info['overhead']})"
        )
    print(
        f"frontend ok: {args.clients} clients x {args.requests} over 2 streams, "
        f"{rep_net['frames_per_s']} frames/s over TCP "
        f"({ratio}x in-process), p99 {rep_net['p99_ms']} ms, 0 shed/dropped"
    )


if __name__ == "__main__":
    main()
