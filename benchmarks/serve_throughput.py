"""Render-serving throughput: batched vs serial, pipelined vs sync, LOD
speed, cache effect, in-flight dedup.

Methodology: one synthetic isosurface scene, one fixed request set (a
multi-client orbit wavefront). Measured scenarios after jit warmup:

  serial    — max_batch=1, cache off: one render dispatch per request
  batched   — max_batch=B, cache off: micro-batched vmap dispatches
  cached    — max_batch=B, cache on, shared-orbit clients: revisited poses
  sync      — duplicate-heavy trace (client pairs submit identical poses in
              the same wavefront), pipeline depth 1: dispatch-then-block
  pipelined — the same trace at --pipeline-depth (default 2): up to depth
              micro-batches in flight while the host postprocesses/assembles

plus a per-LOD-level timing of one fixed batch (coarser level => fewer
composited Gaussians => faster frame). Emits a single JSON report. Exits
nonzero if any scenario completes fewer requests than were submitted.

  PYTHONPATH=src python benchmarks/serve_throughput.py --smoke --out report.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# Batched serving shards views over the mesh's data axis; on a CPU host we
# split the platform into a few "devices" (the dryrun methodology) so the
# micro-batch genuinely renders views in parallel. Must run before jax init.
_flags = os.environ.get("XLA_FLAGS", "")
if "host_platform_device_count" not in _flags:
    n_dev = min(4, os.cpu_count() or 1)
    os.environ["XLA_FLAGS"] = f"{_flags} --xla_force_host_platform_device_count={n_dev}".strip()

import jax
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from bench_schema import stage_breakdown, write_bench
from repro.core.config import GSConfig
from repro.launch.serve_gs import init_params_from_volume
from repro.serve_gs import RenderServer, make_clients, run_load
from repro.serve_gs.batcher import stack_cameras


def build_server(params, cfg, *, mesh, max_batch, cache_capacity, n_levels, keep_ratio,
                 pipeline_depth=1):
    return RenderServer(
        params,
        cfg,
        mesh=mesh,
        n_levels=n_levels,
        keep_ratio=keep_ratio,
        max_batch=max_batch,
        cache_capacity=cache_capacity,
        store_frames=False,
        pipeline_depth=pipeline_depth,
    )


def drive(server, *, n_clients, requests, n_views, res, radius_spread, dup_pairs=False,
          flush_every_round=True):
    clients = make_clients(
        n_clients, n_views=n_views, img_h=res, img_w=res, radius_spread=radius_spread,
        dup_pairs=dup_pairs,
    )
    rep = run_load(
        server, clients, requests_per_client=requests, flush_every_round=flush_every_round
    )
    submitted = n_clients * requests
    if rep["completed"] != submitted:
        raise SystemExit(
            f"serving path dropped requests: completed {rep['completed']} of {submitted}"
        )
    return rep


def time_level(server, level, *, batch, repeats=3):
    """Median seconds for one batched render call at a pyramid level."""
    cam = make_clients(1, n_views=8, img_h=server.cfg.img_h, img_w=server.cfg.img_w)[0].next_camera()
    cams = stack_cameras([cam] * batch)
    lp = server._level_params[level]
    render = server._level_render[level]
    jax.block_until_ready(render(lp, cams))  # compile outside the timing
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(render(lp, cams))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="reduced CPU config")
    ap.add_argument("--res", type=int, default=48)
    ap.add_argument("--volume-res", type=int, default=48)
    ap.add_argument("--max-points", type=int, default=3000)
    ap.add_argument("--dataset", default="kingsnake")
    ap.add_argument("--levels", type=int, default=3)
    ap.add_argument("--keep-ratio", type=float, default=0.5)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument(
        "--pipeline-depth", type=int, default=2,
        help="in-flight depth for the pipelined scenario (sync baseline is 1)",
    )
    ap.add_argument(
        "--config-from", default=None, metavar="RECOMMEND.json",
        help="apply engine knobs (max_batch, pipeline_depth) recommended by "
        "repro.launch.tune; gateway-tier knobs in the file are ignored here",
    )
    ap.add_argument(
        "--max-trace-overhead", type=float, default=0.25,
        help="fail if the span-traced lap loses more than this fraction of "
        "fps vs the slower untraced lap (the recorder itself costs well "
        "under 2%%; the lenient default absorbs shared-host scheduler noise)",
    )
    ap.add_argument("--out", default=None)
    ap.add_argument(
        "--bench-out", default=None,
        help="also write a flat BENCH_*.json record (bench_schema) for the "
        "cross-PR perf trajectory",
    )
    args = ap.parse_args(argv)

    if args.config_from:
        from repro.launch.tune import load_recommended_knobs
        knobs = load_recommended_knobs(args.config_from)
        if "max_batch" in knobs:
            args.max_batch = int(knobs["max_batch"])
        if "pipeline_depth" in knobs:
            args.pipeline_depth = int(knobs["pipeline_depth"])
        print(f"config-from {args.config_from}: max_batch={args.max_batch} "
              f"pipeline_depth={args.pipeline_depth}")

    if args.smoke:
        args.res, args.volume_res, args.max_points = 32, 32, 800
        args.requests = min(args.requests, 6)

    params = init_params_from_volume(
        args.dataset, volume_res=args.volume_res, max_points=args.max_points
    )
    cfg = GSConfig(img_h=args.res, img_w=args.res, k_per_tile=128 if args.smoke else 256)
    common = dict(n_levels=args.levels, keep_ratio=args.keep_ratio)
    load = dict(
        n_clients=args.clients, requests=args.requests, n_views=12,
        res=args.res, radius_spread=0.0,  # same level for all: isolates batching
    )

    n_dev = len(jax.devices())
    mesh_serial = jax.make_mesh((1, 1), ("data", "model"))
    mesh_batched = jax.make_mesh((n_dev, 1), ("data", "model"))

    # ---- serial baseline: one request per dispatch, single device, no cache
    serial = build_server(params, cfg, mesh=mesh_serial, max_batch=1, cache_capacity=0, **common)
    serial.warmup(buckets=(1,))
    rep_serial = drive(serial, **load)

    # ---- micro-batched: same request set, no cache. Each round's wavefront
    # (one request per client, all same level) coalesces into one dispatch,
    # sharded one-view-per-device over the data axis.
    batched = build_server(
        params, cfg, mesh=mesh_batched, max_batch=args.max_batch, cache_capacity=0, **common
    )
    wave = batched.batcher.bucket_for(min(args.clients, args.max_batch))
    batched.warmup(buckets=(wave,))
    rep_batched = drive(batched, **load)

    # ---- cached: shared-orbit clients revisit poses across LOD rings.
    # Runs the production tile-granular cache path (revisited poses are
    # assembled from content-deduplicated tiles).
    cached = build_server(
        params, cfg, mesh=mesh_batched, max_batch=args.max_batch, cache_capacity=512, **common
    )
    cached.warmup(buckets=tuple(sorted({cached.batcher.bucket_for(n) for n in (1, 2, args.clients)})))
    rep_cached = drive(cached, **dict(load, radius_spread=1.0))

    # ---- pipelined vs sync on a duplicate-heavy trace: client pairs submit
    # identical poses in the same wavefront (in-flight dedup territory — the
    # cache can't catch these, the first render hasn't landed), cache off so
    # every unique pose really renders. Sync = depth 1 (dispatch-then-block);
    # pipelined = depth D (device renders batch N while the host copies out
    # batch N-1 and stacks batch N+1). One-view-per-device micro-batches and
    # a deep queue (no per-round flush) keep the in-flight ring populated;
    # each depth gets a warm lap, then best-of-2 measured windows over a
    # fresh metrics slate (scheduler-noise hygiene on small shared hosts).
    dup_load = dict(load, radius_spread=0.0, dup_pairs=True, flush_every_round=False)

    def drive_depth(depth, *, traced_lap=False):
        srv = build_server(
            params, cfg, mesh=mesh_batched, max_batch=n_dev, cache_capacity=0,
            pipeline_depth=depth, **common
        )
        srv.warmup(buckets=srv.batcher.buckets)
        drive(srv, **dup_load)  # warm lap: allocator + dispatch paths hot
        best, best_snap, lap_fps = None, {}, []
        for _ in range(2):
            srv.reset_metrics()
            rep = drive(srv, **dup_load)
            lap_fps.append(rep["frames_per_s"])
            snap = srv.obs.metrics.snapshot()
            if best is None or rep["frames_per_s"] > best["frames_per_s"]:
                best, best_snap = rep, snap
        tracing = None
        if traced_lap:
            # same trace with the span recorder live; overhead is judged
            # against the SLOWER untraced lap so scheduler noise doesn't
            # masquerade as tracing cost
            srv.obs.enable_trace()
            srv.reset_metrics()
            rep_t = drive(srv, **dup_load)
            spans = srv.obs.trace.drain()
            tracing = {
                "traced_frames_per_s": rep_t["frames_per_s"],
                "spans": len(spans),
                "dropped": srv.obs.trace.dropped,
                "overhead": round(
                    1.0 - rep_t["frames_per_s"] / max(min(lap_fps), 1e-9), 3
                ),
            }
            srv.obs.disable_trace()
        return best, best_snap, tracing

    rep_sync, _, _ = drive_depth(1)
    rep_pipe, pipe_snap, tracing = drive_depth(args.pipeline_depth, traced_lap=True)

    # ---- per-LOD render speed for one fixed batch
    lod_ms = [
        round(time_level(batched, lvl, batch=wave) * 1e3, 3)
        for lvl in range(batched.pyramid.n_levels)
    ]

    report = {
        "scene": {"dataset": args.dataset, "gaussians": params.n, "res": args.res},
        "devices": n_dev,
        "request_set": {"clients": args.clients, "requests_per_client": args.requests},
        "serial": {"frames_per_s": rep_serial["frames_per_s"], "latency_ms": rep_serial["latency_ms"]},
        "batched": {
            "max_batch": args.max_batch,
            "frames_per_s": rep_batched["frames_per_s"],
            "latency_ms": rep_batched["latency_ms"],
            "mean_batch": rep_batched["render"]["mean_batch"],
        },
        "batched_speedup": round(
            rep_batched["frames_per_s"] / max(rep_serial["frames_per_s"], 1e-9), 3
        ),
        "cached": {
            "frames_per_s": rep_cached["frames_per_s"],
            "cache": rep_cached["cache"],
            "tiles": rep_cached["tiles"],
            "requests_per_level": rep_cached["lod"]["requests_per_level"],
        },
        "sync": {
            "frames_per_s": rep_sync["frames_per_s"],
            "latency_ms": rep_sync["latency_ms"],
            "pipeline": rep_sync["pipeline"],
        },
        "pipelined": {
            "frames_per_s": rep_pipe["frames_per_s"],
            "latency_ms": rep_pipe["latency_ms"],
            "pipeline": rep_pipe["pipeline"],
        },
        "pipeline_speedup": round(
            rep_pipe["frames_per_s"] / max(rep_sync["frames_per_s"], 1e-9), 3
        ),
        "deduped": rep_pipe["pipeline"]["deduped"],
        "tracing": tracing,
        "lod": {
            "live_counts": list(batched.pyramid.live_counts),
            "batch_render_ms": lod_ms,
            "coarsest_vs_full_speedup": round(lod_ms[0] / max(lod_ms[-1], 1e-9), 3),
        },
    }
    out = json.dumps(report, indent=1)
    print(out)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            f.write(out)
    if args.bench_out:
        write_bench(
            args.bench_out, "serve_throughput",
            config={
                "clients": args.clients, "requests_per_client": args.requests,
                "res": args.res, "gaussians": params.n, "devices": n_dev,
                "max_batch": args.max_batch, "pipeline_depth": args.pipeline_depth,
                "smoke": args.smoke,
            },
            metrics={
                "frames_per_s": rep_pipe["frames_per_s"],
                "p50_ms": rep_pipe["latency_ms"]["p50"],
                "p99_ms": rep_pipe["latency_ms"]["p99"],
                "sync_frames_per_s": rep_sync["frames_per_s"],
                "pipeline_speedup": report["pipeline_speedup"],
                "batched_speedup": report["batched_speedup"],
                "serial_frames_per_s": rep_serial["frames_per_s"],
                "cached_frames_per_s": rep_cached["frames_per_s"],
                "deduped": report["deduped"],
                "cached_renders_per_frame": rep_cached["tiles"]["renders_per_frame"],
                "tile_cache_hit_rate": rep_cached["cache"]["hit_rate"],
                "tile_dedup_bytes_saved": rep_cached["cache"]["tiles"][
                    "dedup_bytes_saved"
                ],
                "trace_spans": tracing["spans"],
                "trace_overhead": tracing["overhead"],
            },
            stages=stage_breakdown(pipe_snap, prefix="server."),
        )

    if tracing["dropped"]:
        raise SystemExit(
            f"span ring overflowed during the traced lap: "
            f"{tracing['dropped']} spans dropped"
        )
    if tracing["overhead"] > args.max_trace_overhead:
        raise SystemExit(
            f"tracing overhead {tracing['overhead']} exceeds budget "
            f"{args.max_trace_overhead} (traced {tracing['traced_frames_per_s']} "
            f"fps vs untraced floor)"
        )


if __name__ == "__main__":
    main()
