"""Streaming reconstruction benchmark: warm-start vs cold-start, per-timestep
wall-clock, recompile count, temporal-store compression.

Methodology: one time-varying synthetic stream (T timesteps). The *warm*
pipeline cold-starts at t=0 and warm-starts every later timestep (params +
Adam moments carried over, dead slots reseeded), with a PSNR-vs-steps curve
recorded per timestep. For every t >= 1 a *cold baseline* trains the same
timestep from scratch at the same fixed capacity and step budget. The target
PSNR for timestep t is the cold baseline's final PSNR (minus a small
tolerance); steps-to-target are read off both curves. Emits one JSON report:

  warm_steps_to_target[t] < cold_steps_to_target[t]  on >= 2 consecutive t
  recompile_count == 1 (one jitted train-step trace for the whole sequence)

Temporal checkpoints are written by the store's background writer (delta
quantization + compression overlap the next timestep's training); the report
carries the overlap accounting (append_wall_s vs write_s). A final phase
reloads the sequence into a pipelined timeline server and time-scrubs every
stored timestep; the script exits nonzero if that pipelined serving path
completes fewer requests than were submitted (or if either training
acceptance criterion fails).

  PYTHONPATH=src python benchmarks/insitu_throughput.py --smoke --out report.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile

import jax

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from bench_schema import stage_breakdown, write_bench
from repro.core.config import GSConfig
from repro.insitu import InsituTrainer, TemporalCheckpointStore, build_timeline_server, scrub
from repro.serve_gs import front_camera
from repro.volume.timevary import GENERATORS, synthetic_stream


def steps_to_target(curve: list, target: float) -> int | None:
    """First recorded step whose PSNR reaches ``target`` (None if never)."""
    for step, p in curve:
        if p >= target:
            return int(step)
    return None


def make_trainer(cfg, mesh, args, *, capacity=None, eval_every):
    return InsituTrainer(
        cfg, mesh,
        capacity=capacity,
        capacity_factor=args.capacity_factor,
        cold_steps=args.cold_steps,
        warm_steps=args.cold_steps,  # same budget as cold: fairness of steps-to-target
        n_views=args.views, max_points=args.max_points,
        n_steps_raymarch=args.raymarch_steps, init_scale=0.06,
        eval_every=eval_every, seed=args.seed,
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="reduced CPU config")
    ap.add_argument("--dataset", choices=list(GENERATORS), default="miranda")
    ap.add_argument("--timesteps", type=int, default=4)
    ap.add_argument("--t1", type=float, default=0.25)
    ap.add_argument("--volume-res", type=int, default=40)
    ap.add_argument("--res", type=int, default=56)
    ap.add_argument("--views", type=int, default=6)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--max-points", type=int, default=1200)
    ap.add_argument("--cold-steps", type=int, default=120)
    ap.add_argument("--eval-every", type=int, default=10)
    ap.add_argument("--raymarch-steps", type=int, default=48)
    ap.add_argument("--capacity-factor", type=float, default=1.5)
    ap.add_argument("--target-tol-db", type=float, default=0.1)
    ap.add_argument("--keyframe-interval", type=int, default=4)
    ap.add_argument(
        "--pipeline-depth", type=int, default=2,
        help="in-flight depth for the time-scrub serving phase (1 = sync)",
    )
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    ap.add_argument("--bench-out", default=None,
                    help="also write a flat BENCH_*.json record (bench_schema) with "
                         "per-stage train histograms + shard-balance gauges")
    args = ap.parse_args(argv)

    if args.smoke:
        args.timesteps = min(args.timesteps, 3)
        args.volume_res, args.res = 32, 48
        args.max_points = min(args.max_points, 800)
        args.cold_steps = min(args.cold_steps, 80)
        args.t1 = min(args.t1, 0.15)

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = GSConfig(
        img_h=args.res, img_w=args.res, batch_size=args.batch,
        k_per_tile=128 if args.smoke else 256,
        max_steps=args.cold_steps * args.timesteps,
        densify_from=10**9, opacity_reset_interval=10**9,
    )
    vols = list(synthetic_stream(args.dataset, args.timesteps, res=args.volume_res, t1=args.t1))

    # ---- warm pipeline over the whole stream, with temporal checkpoints
    # (context manager: queued background writes are flushed + the writer
    # joined even if a later benchmark phase raises)
    with TemporalCheckpointStore(
        os.path.join(tempfile.mkdtemp(prefix="insitu_bench_"), "seq"),
        keyframe_interval=args.keyframe_interval,
    ) as store:
        warm = make_trainer(cfg, mesh, args, eval_every=args.eval_every)
        warm_reports = warm.run(iter(vols), store=store)

        # ---- cold baselines: from-scratch at each later timestep, same capacity
        rows = [{
            "t": 0,
            "mode": "cold_start",
            "steps": warm_reports[0].steps,
            "psnr_after": round(warm_reports[0].psnr_after, 3),
            "train_s": round(warm_reports[0].train_s, 3),
            "wall_s": round(warm_reports[0].wall_s, 3),
        }]
        fewer = []
        cold = make_trainer(cfg, mesh, args, capacity=warm.capacity, eval_every=args.eval_every)
        for t in range(1, args.timesteps):
            if cold.state is not None:
                cold.reset()  # keep the jitted fns: no retrace per baseline
            cold_rep = cold.start(vols[t])
            target = cold_rep.psnr_after - args.target_tol_db
            w_rep = warm_reports[t]
            w_steps = steps_to_target(w_rep.psnr_curve, target)
            c_steps = steps_to_target(cold_rep.psnr_curve, target)
            fewer.append(w_steps is not None and c_steps is not None and w_steps < c_steps)
            rows.append({
                "t": t,
                "target_psnr": round(target, 3),
                "warm": {
                    "steps_to_target": w_steps,
                    "psnr_before": round(w_rep.psnr_before, 3),
                    "psnr_after": round(w_rep.psnr_after, 3),
                    "n_reseeded": w_rep.n_reseeded,
                    "train_s": round(w_rep.train_s, 3),
                    "wall_s": round(w_rep.wall_s, 3),
                    "curve": [(s, round(p, 3)) for s, p in w_rep.psnr_curve],
                },
                "cold": {
                    "steps_to_target": c_steps,
                    "psnr_after": round(cold_rep.psnr_after, 3),
                    "train_s": round(cold_rep.train_s, 3),
                    "curve": [(s, round(p, 3)) for s, p in cold_rep.psnr_curve],
                },
                "warm_fewer_steps": fewer[-1],
            })

        # ---- pipelined time-scrub serving over the stored sequence: every
        # timestep requested at one camera through the FrameFuture path
        # (store_frames off, depth-D dispatch); all submits must complete.
        with build_timeline_server(
            store, cfg, n_levels=2, max_batch=2, store_frames=False,
            pipeline_depth=args.pipeline_depth,
        ) as server:
            cam = front_camera(server.pyramid, img_h=cfg.img_h, img_w=cfg.img_w)
            scrub_ts = store.timesteps()
            frames = scrub(server, cam, scrub_ts)
            serve_rep = server.report()
        if serve_rep["completed"] != len(scrub_ts):
            raise SystemExit(
                f"pipelined scrub dropped requests: completed {serve_rep['completed']} "
                f"of {len(scrub_ts)}"
            )

        consec = 0
        best_consec = 0
        for f in fewer:
            consec = consec + 1 if f else 0
            best_consec = max(best_consec, consec)
        report = {
            "config": {
                "dataset": args.dataset, "timesteps": args.timesteps,
                "volume_res": args.volume_res, "res": args.res,
                "capacity": warm.capacity, "cold_steps": args.cold_steps,
                "eval_every": args.eval_every, "target_tol_db": args.target_tol_db,
            },
            "timesteps": rows,
            "recompile_count": warm.n_traces,
            "per_timestep_wall_s": [round(r.wall_s, 3) for r in warm_reports],
            "warm_fewer_steps_consecutive": best_consec,
            "store": store.stats(),
            "scrub_serving": {
                "timesteps": len(scrub_ts),
                "completed": serve_rep["completed"],
                "frames_per_s": serve_rep["frames_per_s"],
                "pipeline": serve_rep["pipeline"],
                "frame_shape": list(frames[scrub_ts[0]].shape),
            },
            "acceptance": {
                "warm_fewer_on_2_consecutive": best_consec >= 2,
                "single_train_step_trace": warm.n_traces == 1,
                "scrub_served_all": serve_rep["completed"] == len(scrub_ts),
            },
        }
        out = json.dumps(report, indent=1)
        print(out)
        if args.out:
            os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
            with open(args.out, "w") as f:
                f.write(out)
        if args.bench_out:
            # the warm trainer's registry holds the whole run's train.*
            # telemetry: step/timestep histograms become the stages block,
            # shard-balance gauges ride along as flat metrics
            snap = warm.obs.metrics.snapshot()
            total_steps = sum(r.steps for r in warm_reports)
            total_train_s = sum(r.train_s for r in warm_reports)
            bench_metrics = {
                "steps_per_s": round(total_steps / max(total_train_s, 1e-9), 3),
                "frames_per_s": serve_rep["frames_per_s"],
                "recompile_count": warm.n_traces,
                "warm_fewer_steps_consecutive": best_consec,
                "gather_bytes": snap.get("train.gather_bytes", 0),
            }
            for k, v in snap.items():
                if k.startswith("train.shard_") or k in ("train.alive_total", "train.psnr"):
                    bench_metrics[k] = v
            write_bench(
                args.bench_out, "insitu_throughput",
                config={
                    "dataset": args.dataset, "timesteps": args.timesteps,
                    "volume_res": args.volume_res, "res": args.res,
                    "capacity": warm.capacity, "cold_steps": args.cold_steps,
                    "smoke": args.smoke,
                },
                metrics=bench_metrics,
                stages=stage_breakdown(snap, "train."),
            )
        assert report["acceptance"]["single_train_step_trace"], report["recompile_count"]
        assert report["acceptance"]["warm_fewer_on_2_consecutive"], fewer


if __name__ == "__main__":
    main()
