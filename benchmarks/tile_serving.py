"""Tile-granular serving vs the whole-frame baseline: renders and bytes.

Methodology: one synthetic isosurface scene served twice — by a
tile-granular server (tile cache + dirty-row invalidation + partial strip
renders) and by a whole-frame baseline with the SAME cache byte budget —
over two viewer traces drawn from the paper's workloads:

  orbit   a viewer orbits the scene (lap 1, cold), an in situ update then
          perturbs the Gaussians in one world slab (changes confined to a
          few screen tile rows for every orbit pose, verified by
          projection), and the viewer replays the orbit (lap 2). The
          baseline must re-render every frame; the tile server re-renders
          only the dirty rows.
  scrub   a fixed camera drags the time slider back and forth over a
          recorded timeline (lap 1, cold on the way out, revisits on the
          way back), every timestep then receives a localized refinement
          update, and the viewer scrubs again (lap 2).

Wire cost is measured by feeding the served frame sequences to the v2
``tiles8`` changed-tile encoder and to the v1 ``zdelta8`` whole-frame-delta
encoder (full message bytes, headers included).

Every lap-2 tile-server frame is checked BITWISE against the baseline's
full re-render — the benchmark exits nonzero if the tile path diverges by
one ulp, if tiles-on-wire is not strictly below the frame-delta baseline,
or if the tile server's render work is not strictly below the baseline's.
Writes a BENCH_tiles.json perf-trajectory record (bench_schema).

  PYTHONPATH=src python benchmarks/tile_serving.py --smoke --out BENCH_tiles.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from bench_schema import stage_breakdown, write_bench
from repro.core import projection as P
from repro.core.config import GSConfig
from repro.frontend import protocol as proto
from repro.frontend.encode import FrameEncoder
from repro.launch.serve_gs import init_params_from_volume
from repro.serve_gs import RenderServer
from repro.volume.cameras import camera_slice, orbit_cameras


# --------------------------------------------------------------- scene edits
def top_slab_indices(params, frac: float) -> np.ndarray:
    """Gaussians in the scene's top world-z slab (the 'update region')."""
    z = np.asarray(params.means)[:, 2]
    return np.nonzero(z >= np.quantile(z, 1.0 - frac))[0]


def perturb(params, idx: np.ndarray, step: int, scale: float = 0.01):
    """Deterministically nudge the slab's Gaussians (one update tick)."""
    rng = np.random.default_rng(1000 + step)
    means = np.asarray(params.means).copy()
    means[idx] += rng.normal(0, scale, (idx.size, 3)).astype(np.float32)
    return params._replace(means=means)


def projected_rows(params_list, idx, cams, *, img_h, tile_h) -> set[int]:
    """Union of tile rows covered by ``idx`` Gaussians' screen footprints
    across every listed model and pose — the exact dirty-row bound the
    in situ updater would compute from its changed set."""
    rows: set[int] = set()
    tiles_y = img_h // tile_h
    for params in params_list:
        for cam in cams:
            packed = np.asarray(P.project(params, cam))
            my, rad = packed[idx, P.MY], packed[idx, P.RAD]
            live = rad > 0
            for y, r in zip(my[live], rad[live]):
                lo = max(int(np.floor((y - r) / tile_h)), 0)
                hi = min(int(np.floor((y + r) / tile_h)), tiles_y - 1)
                rows.update(range(lo, hi + 1))
    return rows


# ------------------------------------------------------------------- serving
def build_server(params, cfg, *, tile_cache, cache_bytes, max_batch=4):
    return RenderServer(
        params, cfg, n_levels=1, max_batch=max_batch, cache_bytes=cache_bytes,
        tile_cache=tile_cache, store_frames=False,
    )


def lap(server, reqs) -> tuple[list, dict]:
    """Serve one trace lap; returns (frames, per-lap tile/render report)."""
    server.reset_metrics()
    frames = []
    for ts, cam in reqs:
        frames.append(server.submit(cam, timestep=ts).result())
    rep = server.report()
    return frames, {
        "renders_per_frame": rep["tiles"]["renders_per_frame"],
        "render_calls": rep["render"]["calls"],
        "cache": rep["cache"],
        "frames_per_s": rep["frames_per_s"],
    }


def wire_bytes(frames, *, tiles: bool, tile) -> tuple[int, dict]:
    """Full on-wire bytes (headers included) for a frame sequence."""
    enc = FrameEncoder(tiles=tiles, tile=tile)
    total = 0
    for i, f in enumerate(frames):
        meta, payload = enc.encode("s", f)
        header = {"type": proto.FRAME, "seq": i, "stream": "s", **meta}
        total += len(proto.pack_message(header, payload))
    return total, enc.stats()


def run_trace(name, params_by_ts, update_by_ts, dirty_rows, reqs, cfg, cache_bytes):
    """Drive one trace through the tile server and the whole-frame baseline:
    cold lap -> localized update -> replay lap. Returns the trace report;
    raises SystemExit if the tile path is not bitwise the baseline."""
    servers = {}
    laps = {}
    stages = {}
    for kind, tiled in (("tile", True), ("frame", False)):
        ts0 = sorted(params_by_ts)[0]
        srv = build_server(
            params_by_ts[ts0], cfg, tile_cache=tiled, cache_bytes=cache_bytes
        )
        for t in sorted(params_by_ts)[1:]:
            srv.add_timestep(t, params_by_ts[t])
        srv.warmup(buckets=(1,))
        if tiled:
            srv.warmup_tiles(levels=[0], rows=sorted(dirty_rows))
        servers[kind] = srv
        cold = lap(srv, reqs)
        # the in situ update: same new models, but only the tile server can
        # exploit the bounded dirty region — the baseline drops whole frames
        for t, new_params in update_by_ts.items():
            srv.add_timestep(t, new_params, dirty_rows=dirty_rows if tiled else None)
        warm = lap(srv, reqs)
        laps[kind] = {"cold": cold, "update_replay": warm}
        if tiled:
            # stage breakdown of the replay window (lap() resets the unified
            # registry on entry, so this snapshot covers exactly that lap)
            stages = stage_breakdown(srv.obs.metrics.snapshot(), prefix="server.")

    # ---- bitwise equivalence: tile-path frames == baseline full re-renders
    for phase in ("cold", "update_replay"):
        for i, (a, b) in enumerate(zip(laps["tile"][phase][0], laps["frame"][phase][0])):
            if not np.array_equal(a, b):
                raise SystemExit(
                    f"{name} trace, {phase} frame {i}: tile path diverged "
                    f"from the whole-frame baseline (max abs diff "
                    f"{float(np.abs(a - b).max()):.3e})"
                )

    # ---- wire cost over the full served sequence (cold + replay)
    seq = laps["tile"]["cold"][0] + laps["tile"]["update_replay"][0]
    tile_shape = (cfg.tile_h, cfg.tile_w)
    bytes_tiles, enc_tiles = wire_bytes(seq, tiles=True, tile=tile_shape)
    bytes_delta, enc_delta = wire_bytes(seq, tiles=False, tile=tile_shape)
    bytes_raw = enc_delta["bytes_raw_equiv"]

    for srv in servers.values():
        srv.close()
    return {
        "stages": stages,  # popped (not printed) by main; BENCH-record only
        "requests_per_lap": len(reqs),
        "dirty_rows": sorted(dirty_rows),
        "tiles_y": cfg.img_h // cfg.tile_h,
        "renders_per_frame": {
            "tile_cold": laps["tile"]["cold"][1]["renders_per_frame"],
            "tile_replay": laps["tile"]["update_replay"][1]["renders_per_frame"],
            "frame_cold": laps["frame"]["cold"][1]["renders_per_frame"],
            "frame_replay": laps["frame"]["update_replay"][1]["renders_per_frame"],
        },
        "tile_cache": laps["tile"]["update_replay"][1]["cache"],
        "wire": {
            "raw_bytes": bytes_raw,
            "tiles8_bytes": bytes_tiles,
            "zdelta8_bytes": bytes_delta,
            "tiles_vs_delta": round(bytes_tiles / max(bytes_delta, 1), 4),
            "tiles_shipped_frac": enc_tiles["tiles_shipped_frac"],
            "raw_fallbacks": enc_tiles["raw_fallbacks"] + enc_delta["raw_fallbacks"],
        },
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="reduced CPU config")
    ap.add_argument("--dataset", default="kingsnake")
    ap.add_argument("--res", type=int, default=64)
    ap.add_argument("--volume-res", type=int, default=48)
    ap.add_argument("--max-points", type=int, default=2000)
    ap.add_argument("--orbit-views", type=int, default=12)
    ap.add_argument("--timeline-steps", type=int, default=6)
    ap.add_argument("--update-frac", type=float, default=0.12,
                    help="fraction of Gaussians (top world-z slab) the in "
                    "situ update touches")
    ap.add_argument("--cache-mb", type=float, default=64.0)
    ap.add_argument("--out", default=None,
                    help="write the BENCH_tiles.json record here")
    args = ap.parse_args(argv)

    if args.smoke:
        args.res, args.volume_res, args.max_points = 48, 32, 600
        args.orbit_views, args.timeline_steps = 8, 4

    params = init_params_from_volume(
        args.dataset, volume_res=args.volume_res, max_points=args.max_points
    )
    cfg = GSConfig(img_h=args.res, img_w=args.res, k_per_tile=64 if args.smoke else 128)
    cache_bytes = int(args.cache_mb * (1 << 20))
    idx = top_slab_indices(params, args.update_frac)

    # ---- orbit trace: flat circular orbit (elev 0) so the top-z slab stays
    # in the top screen rows for every pose; far enough that background
    # tiles exist (the changed-tile wire win) — poses chosen, rows PROVEN
    # below by projecting the changed set through every pose
    cams = orbit_cameras(
        args.orbit_views, img_h=args.res, img_w=args.res, radius=5.0,
        elev_cycles=0.0, elev_max_deg=0.0,
    )
    orbit_cams = [
        P.Camera(*[np.asarray(x) for x in camera_slice(cams, i)])
        for i in range(args.orbit_views)
    ]
    orbit_update = {0: perturb(params, idx, step=0)}
    orbit_rows = projected_rows(
        [params, orbit_update[0]], idx, orbit_cams, img_h=args.res, tile_h=cfg.tile_h
    )
    tiles_y = args.res // cfg.tile_h
    orbit = run_trace(
        "orbit", {0: params}, orbit_update, orbit_rows,
        [(0, c) for c in orbit_cams], cfg, cache_bytes,
    )

    # ---- time-scrub trace: fixed camera, timeline whose steps drift the
    # slab; the update then refines every timestep's slab in place
    scrub_cam = orbit_cams[0]
    timeline = {
        t: perturb(params, idx, step=t, scale=0.004 * t)
        for t in range(args.timeline_steps)
    }
    scrub_update = {
        t: perturb(timeline[t], idx, step=100 + t, scale=0.004)
        for t in range(args.timeline_steps)
    }
    scrub_rows = projected_rows(
        list(timeline.values()) + list(scrub_update.values()), idx, [scrub_cam],
        img_h=args.res, tile_h=cfg.tile_h,
    )
    # the slider drags out and back: revisited timesteps are tile-store refs
    scrub_order = list(range(args.timeline_steps)) + list(
        range(args.timeline_steps - 2, -1, -1)
    )
    scrub = run_trace(
        "scrub", timeline, scrub_update, scrub_rows,
        [(t, scrub_cam) for t in scrub_order], cfg, cache_bytes,
    )

    stages = {
        **{f"orbit.{k}": v for k, v in orbit.pop("stages").items()},
        **{f"scrub.{k}": v for k, v in scrub.pop("stages").items()},
    }
    report = {
        "scene": {"dataset": args.dataset, "gaussians": params.n, "res": args.res,
                  "changed_gaussians": int(idx.size)},
        "tile": [cfg.tile_h, cfg.tile_w],
        "cache_bytes": cache_bytes,
        "orbit": orbit,
        "scrub": scrub,
    }
    print(json.dumps(report, indent=1))

    if args.out:
        write_bench(
            args.out, "tile_serving",
            config={
                "res": args.res, "gaussians": params.n,
                "orbit_views": args.orbit_views,
                "timeline_steps": args.timeline_steps,
                "update_frac": args.update_frac, "smoke": args.smoke,
            },
            metrics={
                "orbit_tiles8_bytes": orbit["wire"]["tiles8_bytes"],
                "orbit_zdelta8_bytes": orbit["wire"]["zdelta8_bytes"],
                "orbit_tiles_vs_delta": orbit["wire"]["tiles_vs_delta"],
                "orbit_tiles_shipped_frac": orbit["wire"]["tiles_shipped_frac"],
                "orbit_renders_per_frame_tile": orbit["renders_per_frame"]["tile_replay"],
                "orbit_renders_per_frame_base": orbit["renders_per_frame"]["frame_replay"],
                "scrub_tiles8_bytes": scrub["wire"]["tiles8_bytes"],
                "scrub_zdelta8_bytes": scrub["wire"]["zdelta8_bytes"],
                "scrub_tiles_vs_delta": scrub["wire"]["tiles_vs_delta"],
                "scrub_renders_per_frame_tile": scrub["renders_per_frame"]["tile_replay"],
                "scrub_renders_per_frame_base": scrub["renders_per_frame"]["frame_replay"],
                "tile_cache_hit_rate": orbit["tile_cache"]["hit_rate"],
            },
            stages=stages,
        )

    # ---- hard acceptance: the tile economy must actually materialize
    failures = []
    for name, tr in (("orbit", orbit), ("scrub", scrub)):
        if tr["wire"]["tiles8_bytes"] >= tr["wire"]["zdelta8_bytes"]:
            failures.append(
                f"{name}: tiles8 wire bytes {tr['wire']['tiles8_bytes']} not "
                f"below frame-delta {tr['wire']['zdelta8_bytes']}"
            )
        r = tr["renders_per_frame"]
        if not r["tile_replay"] < r["frame_replay"]:
            failures.append(
                f"{name}: tile replay render work {r['tile_replay']} not "
                f"below whole-frame baseline {r['frame_replay']}"
            )
    if failures:
        raise SystemExit("; ".join(failures))
    print(
        f"tile serving ok: orbit replay renders/frame "
        f"{orbit['renders_per_frame']['tile_replay']} vs baseline "
        f"{orbit['renders_per_frame']['frame_replay']} "
        f"(dirty rows {orbit['dirty_rows']} of {tiles_y}); "
        f"tiles8 wire {orbit['wire']['tiles8_bytes']}B vs zdelta8 "
        f"{orbit['wire']['zdelta8_bytes']}B "
        f"({orbit['wire']['tiles_vs_delta']}x); scrub "
        f"{scrub['renders_per_frame']['tile_replay']} vs "
        f"{scrub['renders_per_frame']['frame_replay']}, wire "
        f"{scrub['wire']['tiles_vs_delta']}x"
    )


if __name__ == "__main__":
    main()
