"""Pallas tile-raster kernel micro-benchmark (interpret mode on CPU).

On CPU this measures the *reference semantics* path; the derived column
reports modeled TPU time from the kernel's FLOP/byte footprint (the number
that matters for the §Perf log). CSV: name,us_per_call,derived.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import projection as P
from repro.core import render as R
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16

from typing import Callable


def _timeit(f: Callable, *args, n=5) -> float:
    f(*args)[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(n):
        out = f(*args)
    jax.tree_util.tree_leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / n * 1e6


def rows():
    out = []
    rng = np.random.default_rng(0)
    for n, h, w, k in [(500, 64, 64, 256), (2000, 128, 128, 256)]:
        pts = rng.normal(0, 0.4, (n, 3)).astype(np.float32)
        from repro.core import gaussians as G

        g = G.init_from_points(jnp.asarray(pts), init_scale=0.05)
        cam = P.look_at_camera([0, 0, -3], [0, 0, 0], [0, 1, 0], w * 1.2, w * 1.2, w / 2, h / 2)
        packed, _ = P.sort_by_depth(P.project(g, cam))

        for backend in ("ref", "pallas"):
            f = jax.jit(
                lambda p: R.render_packed(p, img_h=h, img_w=w, tile_h=16, tile_w=16,
                                          k_per_tile=k, backend=backend)
            )
            us = _timeit(f, packed)
            tiles = (h // 16) * (w // 16)
            flops = tiles * k * 16 * 16 * 40  # ~40 flop per splat-pixel
            bytes_ = tiles * k * 11 * 4 + h * w * 4 * 4
            derived = max(flops / PEAK_FLOPS_BF16, bytes_ / HBM_BW) * 1e6
            out.append((f"raster_{backend}_{n}g_{h}px", us, f"tpu_model_us={derived:.1f}"))
    return out


def flash_rows():
    out = []
    import jax.random as jr

    for b, s, h, hd in [(1, 512, 4, 64), (1, 1024, 8, 128)]:
        ks = jr.split(jr.key(0), 3)
        q = jr.normal(ks[0], (b, s, h, hd), jnp.float32)
        k = jr.normal(ks[1], (b, s, h, hd), jnp.float32)
        v = jr.normal(ks[2], (b, s, h, hd), jnp.float32)
        from repro.kernels.flash_attention.ops import flash_attention

        for backend in ("ref", "pallas"):
            f = jax.jit(lambda q, k, v: (flash_attention(q, k, v, backend=backend),))
            us = _timeit(f, q, k, v)
            flops = 4 * b * h * s * s * hd
            derived = max(flops / PEAK_FLOPS_BF16, (3 * b * s * h * hd * 2) / HBM_BW) * 1e6
            out.append((f"flashattn_{backend}_{s}s_{h}h_{hd}d", us, f"tpu_model_us={derived:.1f}"))
    return out


if __name__ == "__main__":
    for r in rows() + flash_rows():
        print(f"{r[0]},{r[1]:.1f},{r[2]}")
