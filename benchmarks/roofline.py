"""§Roofline table: read the dry-run JSONs, print the three-term roofline per
(arch x shape) on the single-pod mesh, with dominant term, MODEL_FLOPS ratio
and the one-line improvement note."""
from __future__ import annotations

import glob
import json
import os

NOTES = {
    "compute": "raise arithmetic intensity (bf16 matmul paths, larger per-chip tiles)",
    "memory": "fuse/shorten elementwise chains, bf16 intermediates, fewer remat recomputes",
    "collective": "re-shard to cut gathered bytes (seq-shard caches, 2D weight sharding), overlap with compute",
}


def load(dirname="experiments/dryrun", mesh="pod1"):
    rows = []
    for path in sorted(glob.glob(os.path.join(dirname, f"*_{mesh}.json"))):
        d = json.load(open(path))
        rows.append(d)
    return rows


def table(out=print, dirname="experiments/dryrun", mesh="pod1"):
    rows = load(dirname, mesh)
    out("arch,shape,compute_ms,memory_ms,collective_ms,dominant,useful_flop_ratio,fits_16gb,note")
    for d in rows:
        if d.get("skipped"):
            out(f"{d['arch']},{d['shape']},SKIP({d['skipped'][:40]}),,,,,,")
            continue
        r = d["roofline"]
        ratio = d.get("useful_flop_ratio")
        out(
            f"{d['arch']},{d['shape']},{r['compute_s']*1e3:.2f},{r['memory_s']*1e3:.2f},"
            f"{r['collective_s']*1e3:.2f},{r['dominant']},"
            + (f"{ratio:.3f}" if ratio else "n/a")
            + f",{d['memory_analysis']['fits_16gb']},{NOTES[r['dominant']]}"
        )
    return rows


if __name__ == "__main__":
    import sys
    table(mesh=sys.argv[1] if len(sys.argv) > 1 else "pod1")
