"""Tables II/III analog: reconstruction quality vs worker count.

Paper claim: distribution does not compromise quality. We verify the stronger
statement our implementation makes true BY CONSTRUCTION and by measurement:
the sharded step computes the *same* optimization trajectory, so PSNR/SSIM/
LPIPS-proxy after N steps match across 1 vs 8 workers (reduced scale, real
execution on forced host devices).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent(
    """
    import os, sys, json
    nd = int(sys.argv[1])
    if nd > 1:
        os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={nd}"
    import jax, numpy as np, jax.numpy as jnp
    from repro.core.config import GSConfig
    from repro.core.train import init_state, make_train_step, make_eval_render, state_shardings
    from repro.core import gaussians as G
    from repro.core.losses import psnr, ssim, lpips_proxy
    from repro.volume import kingsnake_like, extract_isosurface_points
    from repro.data.views import ViewDataset

    shape = {1: (1,1), 2: (2,1), 4: (2,2), 8: (4,2)}[nd]
    mesh = jax.make_mesh(shape, ("data", "model"))
    H = 64
    cfg = GSConfig(img_h=H, img_w=H, k_per_tile=192, batch_size=4, backend="ref")
    vol = kingsnake_like(res=40)
    pts, _, cols = extract_isosurface_points(vol, max_points=2500, seed=0)
    pad = (-pts.shape[0]) % (mesh.shape["model"] * 256)
    pts = np.concatenate([pts, np.full((pad,3), 1e6, np.float32)])
    cols = np.concatenate([cols, np.zeros((pad,3), np.float32)])
    g = G.init_from_points(jnp.asarray(pts), jnp.asarray(cols), init_scale=0.05)
    g = g._replace(opacity_logit=g.opacity_logit.at[pts.shape[0]-pad:].set(-20.))
    data = ViewDataset(vol, n_views=12, img_h=H, img_w=H, cache_dir="experiments/gt_cache", n_steps_raymarch=96)
    state = jax.device_put(init_state(g), state_shardings(mesh))
    step = make_train_step(mesh, cfg)
    for cams, gt in data.batches(cfg.batch_size, steps=60):
        state, m = step(state, cams, gt)
    ev = make_eval_render(mesh, cfg)
    ps, ss, lp = [], [], []
    for i in range(0, 12, 3):
        cam, gt = data.view(i)
        img, _ = ev(state.params, cam)
        ps.append(float(psnr(img, gt))); ss.append(float(ssim(img, gt))); lp.append(float(lpips_proxy(img, gt)))
    print(json.dumps({"workers": nd, "psnr": float(np.mean(ps)), "ssim": float(np.mean(ss)),
                      "lpips_proxy": float(np.mean(lp)), "loss": float(m["loss"])}))
    """
)

OUT = "experiments/quality"


def run(nd: int) -> dict:
    os.makedirs(OUT, exist_ok=True)
    path = os.path.join(OUT, f"quality_{nd}w.json")
    if os.path.exists(path):
        return json.load(open(path))
    r = subprocess.run([sys.executable, "-c", SCRIPT, str(nd)], capture_output=True, text=True,
                       timeout=3600, env=dict(os.environ, PYTHONPATH="src"))
    assert r.returncode == 0, r.stderr[-3000:]
    d = json.loads(r.stdout.strip().splitlines()[-1])
    json.dump(d, open(path, "w"))
    return d


def table(out=print):
    out("workers,psnr,ssim,lpips_proxy,final_loss")
    rows = []
    for nd in (1, 4, 8):
        d = run(nd)
        rows.append(d)
        out(f"{d['workers']},{d['psnr']:.2f},{d['ssim']:.4f},{d['lpips_proxy']:.4f},{d['loss']:.5f}")
    return rows


if __name__ == "__main__":
    table()
