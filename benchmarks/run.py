"""Benchmark harness entry point — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows per the harness contract, then
the paper-table analogs (Table I scaling, Tables II/III quality) and the
§Roofline summary when dry-run artifacts exist.

  PYTHONPATH=src python -m benchmarks.run            # quick sections only
  PYTHONPATH=src python -m benchmarks.run --full     # + heavy subprocess tables
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="also run subprocess-heavy tables")
    args = ap.parse_args()

    print("# --- kernel micro-benchmarks (name,us_per_call,derived) ---")
    from benchmarks import raster_kernel

    for name, us, derived in raster_kernel.rows() + raster_kernel.flash_rows():
        print(f"{name},{us:.1f},{derived}")

    print("\n# --- GS train step (single device, reduced scale) ---")
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.config import GSConfig
    from repro.core.train import init_state, make_train_step, state_shardings
    from repro.core import gaussians as G
    from repro.volume import kingsnake_like, extract_isosurface_points, orbit_cameras, render_isosurface
    from repro.volume.cameras import camera_slice

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = GSConfig(img_h=64, img_w=64, k_per_tile=192, batch_size=2, backend="ref")
    vol = kingsnake_like(res=32)
    pts, _, cols = extract_isosurface_points(vol, max_points=1500, seed=0)
    pad = (-pts.shape[0]) % 256
    pts = np.concatenate([pts, np.full((pad, 3), 1e6, np.float32)])
    cols = np.concatenate([cols, np.zeros((pad, 3), np.float32)])
    g = G.init_from_points(jnp.asarray(pts), jnp.asarray(cols), init_scale=0.05)
    state = jax.device_put(init_state(g), state_shardings(mesh))
    step = make_train_step(mesh, cfg)
    cams = orbit_cameras(2, img_h=64, img_w=64)
    gt = jnp.stack([
        render_isosurface(jnp.asarray(vol.field), vol.isovalue, camera_slice(cams, i), img_h=64, img_w=64, n_steps=64)
        for i in range(2)
    ])
    state, m = step(state, cams, gt)  # compile
    t0 = time.perf_counter()
    for _ in range(3):
        state, m = step(state, cams, gt)
    jax.block_until_ready(state.params.means)
    us = (time.perf_counter() - t0) / 3 * 1e6
    print(f"gs_train_step_1536g_64px,{us:.0f},loss={float(m['loss']):.5f}")

    print("\n# --- Table I analog: scaling (modeled step time at paper scale) ---")
    from benchmarks import table1_scaling

    if args.full:
        table1_scaling.run_all()
    table1_scaling.table()

    print("\n# --- Tables II/III analog: quality vs workers ---")
    if args.full:
        from benchmarks import table23_quality

        table23_quality.table()
    else:
        import os, json
        rows = []
        for nd in (1, 4, 8):
            p = f"experiments/quality/quality_{nd}w.json"
            if os.path.exists(p):
                rows.append(json.load(open(p)))
        if rows:
            print("workers,psnr,ssim,lpips_proxy,final_loss")
            for d in rows:
                print(f"{d['workers']},{d['psnr']:.2f},{d['ssim']:.4f},{d['lpips_proxy']:.4f},{d['loss']:.5f}")
        else:
            print("(cached quality results not found; run with --full)")

    print("\n# --- Roofline summary (single-pod dry-run) ---")
    from benchmarks import roofline

    try:
        roofline.table()
    except Exception as e:  # dry-run artifacts may not exist yet
        print(f"(roofline artifacts missing: {e})")


if __name__ == "__main__":
    main()
