"""Foveated per-tile LOD + world-space invalidation: render cost vs quality.

Methodology: one synthetic isosurface scene served over an orbit trace,
measured in three phases:

  dirty    world-space dirty-row precision. Two identical tile servers take
           the same in situ update; one is handed the classic caller-computed
           dirty-row union (``dirty_rows=``), the other only the changed
           Gaussian *indices* (``changed=``) and must bound the damage itself
           by projecting the changed set through its registered viewer poses.
           The auto server must replay the orbit bitwise identically to the
           hand server with no more render work (its per-pose bounds can
           only be tighter than the all-pose union).
  foveate  per-tile foveated LOD. A uniform lap at the coverage level fills
           the tile cache; a foveated replay (gaze at frame center) reuses
           the sharp rows from cache and coarsens the periphery one pyramid
           level per row of distance. Gaze rows must stay BITWISE equal to
           the uniform frames; the assigned render cost (tile rows weighted
           by keep_ratio**level — the fraction of Gaussians each level
           keeps) must land strictly below uniform-finest.
  budget   budget-aware degradation. With the per-row cost estimate warmed
           by the foveated lap, requests carry a ``budget_ms`` of ~half the
           uniform-sharp frame cost; the server must shrink the sharp zone
           (coarse rows > 0) rather than blow the budget, and never coarsen
           the gaze row itself.

Exits nonzero if the auto-dirty replay diverges from the hand-dirty replay
by one ulp, if the auto server renders more than the hand server, if
foveated gaze rows differ from uniform, if the foveated cost is not below
uniform, or if the budget never degrades the periphery. Writes a
BENCH_lod.json perf-trajectory record (bench_schema).

  PYTHONPATH=src python benchmarks/lod_serving.py --smoke --out BENCH_lod.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from bench_schema import stage_breakdown, write_bench
from tile_serving import build_server, perturb, projected_rows, top_slab_indices
from repro.core import projection as P
from repro.core.config import GSConfig
from repro.launch.serve_gs import init_params_from_volume
from repro.serve_gs import RenderServer, select_level_map
from repro.volume.cameras import camera_slice, orbit_cameras


def lap(server, cams, **submit_kw) -> list:
    """Serve every pose once (fixed t=0); returns the frames in order."""
    return [server.submit(cam, **submit_kw).result() for cam in cams]


# --------------------------------------------------------- phase A: dirty rows
def run_dirty(params, idx, cams, cfg, cache_bytes) -> dict:
    """Hand-computed dirty-row union vs server-computed world-space bounds."""
    new_params = perturb(params, idx, step=0)
    hand_rows = projected_rows(
        [params, new_params], idx, cams, img_h=cfg.img_h, tile_h=cfg.tile_h
    )
    reports = {}
    frames = {}
    for kind in ("hand", "auto"):
        srv = build_server(params, cfg, tile_cache=True, cache_bytes=cache_bytes)
        srv.warmup(buckets=(1,))
        srv.warmup_tiles(levels=[0])
        lap(srv, cams)  # cold lap: fills tiles AND registers every pose
        if kind == "hand":
            srv.add_timestep(0, new_params, dirty_rows=hand_rows)
        else:
            srv.add_timestep(0, new_params, changed=idx)
        srv.reset_metrics()
        frames[kind] = lap(srv, cams)
        rep = srv.report()
        reports[kind] = {
            "renders_per_frame": rep["tiles"]["renders_per_frame"],
            "rows_rendered": rep["tiles"]["rows_rendered_partial"],
            "partial_hits": rep["tiles"]["partial_hits"],
            "frame_misses": rep["tiles"]["frame_misses"],
        }
        srv.close()

    for i, (a, b) in enumerate(zip(frames["auto"], frames["hand"])):
        if not np.array_equal(a, b):
            raise SystemExit(
                f"dirty phase, replay frame {i}: changed= server diverged from "
                f"dirty_rows= server (max abs diff {float(np.abs(a - b).max()):.3e})"
            )
    return {
        "hand_rows": sorted(hand_rows),
        "tiles_y": cfg.img_h // cfg.tile_h,
        "hand": reports["hand"],
        "auto": reports["auto"],
    }


# ----------------------------------------------------------- phase B: foveated
def run_foveated(params, cams, cfg, cache_bytes, *, n_levels, keep_ratio) -> tuple:
    """Uniform-finest lap, then a gaze-centered foveated replay of the same
    orbit on the same server; returns the phase report plus the live server
    for the budget phase (caller closes)."""
    srv = RenderServer(
        params, cfg, n_levels=n_levels, keep_ratio=keep_ratio, max_batch=4,
        cache_bytes=cache_bytes, tile_cache=True, store_frames=False,
    )
    tiles_y = cfg.img_h // cfg.tile_h
    n_built = srv.pyramid.n_levels
    srv.warmup(buckets=(1,))
    srv.warmup_tiles()  # every (level, row) strip: latency below excludes traces

    # the level maps the server will assign (identical code path): sharp rows
    # sit at the coverage level, so they can reuse the uniform lap's tiles.
    # Gaze at the TOP edge: with only a handful of tile rows a centered gaze
    # keeps every row inside the sharp zone (nothing to coarsen)
    gaze = (0.5, 0.0)
    gaze_row = min(int(gaze[1] * tiles_y), tiles_y - 1)
    maps = [
        select_level_map(
            srv.pyramid, cam, img_w=cfg.img_w, tiles_y=tiles_y,
            gaze_row=gaze_row, n_levels=n_built, keep_ratio=keep_ratio,
        )
        for cam in cams
    ]
    if any(len(set(m)) == 1 for m in maps):
        raise SystemExit(
            f"foveate phase degenerate: uniform level map {maps} — the orbit "
            f"poses sit too deep in the {n_built}-level pyramid to coarsen"
        )

    srv.reset_metrics()
    uniform = lap(srv, cams)
    rep_u = srv.report()
    units_uniform = sum(
        keep_ratio ** lvl * n for lvl, n in enumerate(rep_u["lod"]["rows_per_level"])
    )
    p99_uniform = rep_u["latency_ms"]["p99"]

    srv.reset_metrics()
    fov = lap(srv, cams, gaze=gaze)
    rep_f = srv.report()
    units_fov = sum(
        keep_ratio ** lvl * n for lvl, n in enumerate(rep_f["lod"]["rows_per_level"])
    )
    th = cfg.tile_h
    for i, (uf, ff, m) in enumerate(zip(uniform, fov, maps)):
        base = min(m)
        for r in range(tiles_y):
            if m[r] == base and not np.array_equal(
                uf[r * th:(r + 1) * th], ff[r * th:(r + 1) * th]
            ):
                raise SystemExit(
                    f"foveate phase, pose {i} row {r}: gaze row (level {base}) "
                    f"diverged from the uniform-finest frame"
                )
    return {
        "levels_built": n_built,
        "level_maps": sorted(set(maps)),
        "uniform": {
            "cost_units": round(units_uniform, 3),
            "rows_per_level": rep_u["lod"]["rows_per_level"],
            "p99_ms": p99_uniform,
        },
        "foveated": {
            "cost_units": round(units_fov, 3),
            "rows_per_level": rep_f["lod"]["rows_per_level"],
            "p99_ms": rep_f["latency_ms"]["p99"],
            "requests": rep_f["lod"]["foveated_requests"],
            "full_hits": rep_f["tiles"]["full_hits"],
            "partial_hits": rep_f["tiles"]["partial_hits"],
        },
        "row_cost_ms": rep_f["lod"]["row_cost_ms"],
    }, srv


# ------------------------------------------------------------- phase C: budget
def run_budget(srv, cams, cfg, *, keep_ratio, frac=0.5) -> dict:
    """Requests carrying ``budget_ms`` ~= ``frac`` of the uniform-sharp frame
    cost must degrade the periphery (coarse rows) but never the gaze row."""
    tiles_y = cfg.img_h // cfg.tile_h
    row_cost = srv.report()["lod"]["row_cost_ms"]
    if not row_cost:
        raise SystemExit("budget phase: row cost estimate never warmed up")
    gaze = (0.5, 0.0)
    gaze_row = min(int(gaze[1] * tiles_y), tiles_y - 1)
    base = min(
        select_level_map(
            srv.pyramid, cams[0], img_w=cfg.img_w, tiles_y=tiles_y,
            gaze_row=gaze_row, n_levels=srv.pyramid.n_levels, keep_ratio=keep_ratio,
        )
    )
    budget_ms = frac * row_cost * tiles_y * keep_ratio ** base
    srv.reset_metrics()
    frames = lap(srv, cams, gaze=gaze, budget_ms=budget_ms)
    rep = srv.report()
    rows = rep["lod"]["rows_per_level"]
    coarse = sum(n for lvl, n in enumerate(rows) if lvl > base)
    assert all(f.shape == (cfg.img_h, cfg.img_w, 3) for f in frames)
    return {
        "budget_ms": round(budget_ms, 4),
        "row_cost_ms": row_cost,
        "base_level": base,
        "rows_per_level": rows,
        "coarse_rows": coarse,
        "sharp_rows": rows[base] if base < len(rows) else 0,
        "p99_ms": rep["latency_ms"]["p99"],
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="reduced CPU config")
    ap.add_argument("--dataset", default="kingsnake")
    ap.add_argument("--res", type=int, default=64)
    ap.add_argument("--volume-res", type=int, default=48)
    ap.add_argument("--max-points", type=int, default=2000)
    ap.add_argument("--orbit-views", type=int, default=12)
    ap.add_argument("--levels", type=int, default=3)
    ap.add_argument("--keep-ratio", type=float, default=0.5)
    ap.add_argument("--update-frac", type=float, default=0.12)
    ap.add_argument("--cache-mb", type=float, default=64.0)
    ap.add_argument("--out", default=None, help="write the BENCH_lod.json record here")
    args = ap.parse_args(argv)

    if args.smoke:
        args.res, args.volume_res, args.max_points = 48, 32, 600
        args.orbit_views = 6

    params = init_params_from_volume(
        args.dataset, volume_res=args.volume_res, max_points=args.max_points
    )
    cfg = GSConfig(img_h=args.res, img_w=args.res, k_per_tile=64 if args.smoke else 128)
    cache_bytes = int(args.cache_mb * (1 << 20))
    cams = orbit_cameras(
        args.orbit_views, img_h=args.res, img_w=args.res, radius=5.0,
        elev_cycles=0.0, elev_max_deg=0.0,
    )
    orbit = [
        P.Camera(*[np.asarray(x) for x in camera_slice(cams, i)])
        for i in range(args.orbit_views)
    ]
    idx = top_slab_indices(params, args.update_frac)

    dirty = run_dirty(params, idx, orbit, cfg, cache_bytes)
    fov, srv = run_foveated(
        params, orbit, cfg, cache_bytes,
        n_levels=args.levels, keep_ratio=args.keep_ratio,
    )
    try:
        budget = run_budget(srv, orbit, cfg, keep_ratio=args.keep_ratio)
        stages = stage_breakdown(srv.obs.metrics.snapshot(), prefix="server.")
    finally:
        srv.close()

    report = {
        "scene": {"dataset": args.dataset, "gaussians": params.n, "res": args.res,
                  "changed_gaussians": int(idx.size)},
        "orbit_views": args.orbit_views,
        "dirty": dirty,
        "foveate": fov,
        "budget": budget,
    }
    print(json.dumps(report, indent=1))

    if args.out:
        write_bench(
            args.out, "lod_serving",
            config={
                "res": args.res, "gaussians": params.n,
                "orbit_views": args.orbit_views, "levels": args.levels,
                "keep_ratio": args.keep_ratio, "update_frac": args.update_frac,
                "smoke": args.smoke,
            },
            metrics={
                "dirty_renders_per_frame_auto": dirty["auto"]["renders_per_frame"],
                "dirty_renders_per_frame_hand": dirty["hand"]["renders_per_frame"],
                "dirty_rows_hand": len(dirty["hand_rows"]),
                "fov_cost_units": fov["foveated"]["cost_units"],
                "uniform_cost_units": fov["uniform"]["cost_units"],
                "fov_vs_uniform": round(
                    fov["foveated"]["cost_units"] / max(fov["uniform"]["cost_units"], 1e-9), 4
                ),
                "fov_p99_ms": fov["foveated"]["p99_ms"],
                "uniform_p99_ms": fov["uniform"]["p99_ms"],
                "budget_p99_ms": budget["p99_ms"],
                "budget_coarse_rows": budget["coarse_rows"],
                "row_cost_ms": budget["row_cost_ms"],
            },
            stages=stages,
        )

    # ---- hard acceptance: precision and the foveated economy must hold
    failures = []
    if dirty["auto"]["renders_per_frame"] > dirty["hand"]["renders_per_frame"]:
        failures.append(
            f"dirty: auto bounds render MORE than the hand union "
            f"({dirty['auto']['renders_per_frame']} vs "
            f"{dirty['hand']['renders_per_frame']} renders/frame)"
        )
    if not fov["foveated"]["cost_units"] < fov["uniform"]["cost_units"]:
        failures.append(
            f"foveate: assigned cost {fov['foveated']['cost_units']} units not "
            f"below uniform-finest {fov['uniform']['cost_units']}"
        )
    if budget["coarse_rows"] <= 0:
        failures.append("budget: periphery never degraded under a half-cost budget")
    if failures:
        raise SystemExit("; ".join(failures))
    print(
        f"lod serving ok: auto dirty bounds {dirty['auto']['renders_per_frame']} "
        f"renders/frame vs hand {dirty['hand']['renders_per_frame']} "
        f"(rows {dirty['hand_rows']} of {dirty['tiles_y']}); foveated "
        f"{fov['foveated']['cost_units']} cost units vs uniform "
        f"{fov['uniform']['cost_units']} with gaze rows bitwise equal; "
        f"budget {budget['budget_ms']}ms -> {budget['coarse_rows']} coarse rows"
    )


if __name__ == "__main__":
    main()
