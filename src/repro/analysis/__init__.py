"""``repro.analysis``: invariant lints + lockset race sanitizer.

Static passes (AST only — importing this package never imports jax):

* :mod:`repro.analysis.retrace` — one-trace-per-sequence invariant
  (jit/shard_map construction in loops / per-call functions, unhashable
  static args).
* :mod:`repro.analysis.names` — metric/span name vocabulary coherence
  across code, benchmarks, and docs.
* :mod:`repro.analysis.locks` — per-class lock discipline across the
  gateway / render-executor / checkpoint-writer thread boundaries.
* :mod:`repro.analysis.hygiene` — broad exception-handler lint.

Runtime sanitizer (opt-in, ``REPRO_TSAN=1``): :mod:`repro.analysis.tsan`.
CLI: ``python -m repro.launch.analyze`` (report + baseline ratchet).
"""
from repro.analysis.common import (
    Finding,
    SourceFile,
    baseline_key,
    diff_against_baseline,
    iter_python_files,
    load_baseline,
    load_tree,
    save_baseline,
)

__all__ = [
    "Finding",
    "SourceFile",
    "baseline_key",
    "diff_against_baseline",
    "iter_python_files",
    "load_baseline",
    "load_tree",
    "save_baseline",
]
