"""Lockset race sanitizer (static half): per-class lock discipline.

The serving stack shares mutable objects across three thread boundaries —
the gateway event loop, the single render-executor thread, and the temporal
store's checkpoint-writer thread. PR 4/6 established the discipline (either
a ``threading.Lock`` guards the state, or a single thread owns it); this
pass enforces it structurally instead of by review:

``locks.inconsistent_guard``
    Eraser-style intra-class lockset check: an instance attribute that is
    accessed under ``with self.<lock>`` somewhere in the class but *written*
    with no lock held somewhere else (``__init__`` excluded — construction
    happens-before sharing). Mixed discipline is the tell-tale of a
    forgotten guard: either every post-init access takes the lock, or the
    attribute is single-threaded and none should.

``locks.thread_shared_write``
    For classes that *create* their own concurrency — ``threading.Thread(
    target=self.m)``, ``executor.submit(self.m)``, ``loop.run_in_executor(
    ex, self.m)`` — attributes written on one side of the boundary (methods
    reachable from a thread entry point) and touched on the other, with no
    lock common to both sides. Designs whose ordering is real but invisible
    to a lockset (e.g. ``queue.Queue.join`` happens-before) waive the
    finding with a reasoned pragma on the method header.

The runtime half (``repro.analysis.tsan``) checks the same property
dynamically under ``REPRO_TSAN=1``.
"""
from __future__ import annotations

import ast

from repro.analysis.common import Finding, SourceFile

__all__ = ["run", "analyze_class"]

_LOCK_CTORS = {"Lock", "RLock", "Condition", "Semaphore", "BoundedSemaphore"}
_MUTATORS = {
    "append", "appendleft", "extend", "insert", "add", "update", "pop",
    "popitem", "popleft", "remove", "discard", "clear", "setdefault",
    "sort", "reverse",
}


def _self_attr(node) -> str | None:
    """'X' when node is ``self.X``."""
    if (isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


class _Access:
    __slots__ = ("attr", "write", "method", "line", "locks")

    def __init__(self, attr, write, method, line, locks):
        self.attr = attr
        self.write = write
        self.method = method
        self.line = line
        self.locks = frozenset(locks)


class _MethodScanner(ast.NodeVisitor):
    """Collect self-attribute accesses (with held-lock sets), self-method
    calls, and thread entry points within one method body."""

    def __init__(self, method: str, lock_attrs: set[str]):
        self.method = method
        self.lock_attrs = lock_attrs
        self.accesses: list[_Access] = []
        self.calls: set[str] = set()         # self.m() targets
        self.thread_roots: set[str] = set()  # self.m handed to a thread
        self._held: list[str] = []

    # ---- lock scope
    def visit_With(self, node: ast.With):
        entered = []
        for item in node.items:
            attr = _self_attr(item.context_expr)
            if attr in self.lock_attrs:
                entered.append(attr)
        self._held.extend(entered)
        self.generic_visit(node)
        if entered:
            del self._held[-len(entered):]

    visit_AsyncWith = visit_With

    # ---- writes
    def _record(self, attr: str, write: bool, line: int):
        self.accesses.append(
            _Access(attr, write, self.method, line, self._held)
        )

    def _target_attrs(self, target):
        """self-attrs written by an assignment target (incl. tuple unpack
        and subscript stores like ``self.d[k] = v``)."""
        out = []
        if isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                out.extend(self._target_attrs(el))
            return out
        attr = _self_attr(target)
        if attr is not None:
            out.append((attr, target.lineno))
        elif isinstance(target, ast.Subscript):
            attr = _self_attr(target.value)
            if attr is not None:
                out.append((attr, target.lineno))
        return out

    def visit_Assign(self, node: ast.Assign):
        for t in node.targets:
            for attr, line in self._target_attrs(t):
                self._record(attr, True, line)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign):
        for attr, line in self._target_attrs(node.target):
            self._record(attr, True, line)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign):
        if node.value is not None:
            for attr, line in self._target_attrs(node.target):
                self._record(attr, True, line)
        self.generic_visit(node)

    def visit_Delete(self, node: ast.Delete):
        for t in node.targets:
            for attr, line in self._target_attrs(t):
                self._record(attr, True, line)
        self.generic_visit(node)

    # ---- reads, mutating method calls, self-calls, thread entries
    def visit_Attribute(self, node: ast.Attribute):
        attr = _self_attr(node)
        if attr is not None and isinstance(node.ctx, ast.Load):
            self._record(attr, False, node.lineno)
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call):
        f = node.func
        # self.attr.append(...) and friends mutate self.attr
        if isinstance(f, ast.Attribute) and f.attr in _MUTATORS:
            attr = _self_attr(f.value)
            if attr is not None:
                self._record(attr, True, node.lineno)
        # thread entry points: Thread(target=self.m), submit(self.m, ...),
        # run_in_executor(ex, self.m, ...)
        callee = f.attr if isinstance(f, ast.Attribute) else (
            f.id if isinstance(f, ast.Name) else None
        )
        if callee == "Thread":
            for kw in node.keywords:
                if kw.arg == "target":
                    m = _self_attr(kw.value)
                    if m is not None:
                        self.thread_roots.add(m)
        elif callee == "submit" and node.args:
            m = _self_attr(node.args[0])
            if m is not None:
                self.thread_roots.add(m)
        elif callee == "run_in_executor" and len(node.args) >= 2:
            m = _self_attr(node.args[1])
            if m is not None:
                self.thread_roots.add(m)
        # intra-class call graph edge
        if isinstance(f, ast.Attribute):
            m = _self_attr(f)
            if m is not None:
                self.calls.add(m)
        self.generic_visit(node)


def analyze_class(sf: SourceFile, cls: ast.ClassDef) -> list[Finding]:
    methods = [n for n in cls.body
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    if not methods:
        return []
    # pass 1: lock attributes (assigned a threading lock ctor anywhere)
    lock_attrs: set[str] = set()
    for m in methods:
        for node in ast.walk(m):
            if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
                callee = node.value.func
                name = (callee.attr if isinstance(callee, ast.Attribute)
                        else callee.id if isinstance(callee, ast.Name) else None)
                if name in _LOCK_CTORS:
                    for t in node.targets:
                        attr = _self_attr(t)
                        if attr is not None:
                            lock_attrs.add(attr)
    # pass 2: per-method accesses, calls, thread roots
    scans: dict[str, _MethodScanner] = {}
    roots: set[str] = set()
    for m in methods:
        sc = _MethodScanner(m.name, lock_attrs)
        sc.visit(m)
        scans[m.name] = sc
        roots |= sc.thread_roots
    # pass 3: methods reachable from thread entry points
    thread_side: set[str] = set()
    frontier = [r for r in roots if r in scans]
    while frontier:
        m = frontier.pop()
        if m in thread_side:
            continue
        thread_side.add(m)
        frontier.extend(c for c in scans[m].calls if c in scans)

    accesses = [a for sc in scans.values() for a in sc.accesses
                if a.method not in ("__init__", "__post_init__")
                and a.attr not in lock_attrs]
    by_attr: dict[str, list[_Access]] = {}
    for a in accesses:
        by_attr.setdefault(a.attr, []).append(a)

    findings: list[Finding] = []
    for attr, accs in sorted(by_attr.items()):
        guarded = [a for a in accs if a.locks]
        bare_writes = [a for a in accs if a.write and not a.locks]
        if guarded and bare_writes:
            w = bare_writes[0]
            locks = sorted({l for a in guarded for l in a.locks})
            findings.append(Finding(
                "locks.inconsistent_guard", sf.relpath, w.line,
                f"{cls.name}.{attr}",
                f"{cls.name}.{attr} is guarded by {'/'.join(locks)} in "
                f"{guarded[0].method}() but written without it in "
                f"{w.method}() — hold the lock at every post-init access, "
                "or drop it everywhere if the attribute is single-threaded",
            ))
            continue  # one finding per attr: the stronger rule wins
        if not thread_side:
            continue
        t_acc = [a for a in accs if a.method in thread_side]
        c_acc = [a for a in accs if a.method not in thread_side]
        cross = ((any(a.write for a in t_acc) and c_acc)
                 or (any(a.write for a in c_acc) and t_acc))
        if not cross:
            continue
        common = None
        for a in t_acc + c_acc:
            common = a.locks if common is None else common & a.locks
        if common:
            continue
        w = next(a for a in t_acc + c_acc if a.write)
        t_m = sorted({a.method for a in t_acc})
        c_m = sorted({a.method for a in c_acc})
        findings.append(Finding(
            "locks.thread_shared_write", sf.relpath, w.line,
            f"{cls.name}.{attr}",
            f"{cls.name}.{attr} crosses the thread boundary (thread side: "
            f"{', '.join(t_m)}; caller side: {', '.join(c_m)}) with no "
            "common lock — guard both sides, or waive with a pragma naming "
            "the ordering that makes it safe",
        ))
    return findings


def run(files: list[SourceFile]) -> list[Finding]:
    out: list[Finding] = []
    for sf in files:
        findings: list[Finding] = []
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.ClassDef):
                findings.extend(analyze_class(sf, node))
        out.extend(sf.apply_pragmas(findings))
    return out
