"""Retrace lint: enforce the one-trace-per-sequence invariant statically.

The stack's throughput story (PR 2/3: exactly ONE train-step trace per
sequence, a fixed per-(shape, level, bucket) serving trace budget) is only
guarded by recompile-count tests on the specific paths they exercise. This
pass flags the *construction patterns* that create hidden retraces anywhere
in the tree:

``retrace.jit_in_loop``
    ``jax.jit`` / ``pjit`` / ``shard_map`` / ``pallas_call`` constructed
    inside a ``for``/``while`` body or comprehension. Every iteration builds
    a fresh callable with a fresh trace cache — the canonical
    recompile-per-step bug (and the closure-capture bug: a function defined
    in the loop and jitted there captures loop state into the trace).

``retrace.factory_in_loop``
    A call, inside a loop, to a *jit factory* — any function in the scanned
    tree whose body constructs a jit (``make_train_step``,
    ``make_batched_eval_render``, ...). Same failure mode one call deeper.

``retrace.jit_outside_factory``
    A jit constructed inside a function that is not module scope, not an
    ``__init__``, and not factory-named (``make_*``/``build_*``/``create_*``
    /``resolve_*``/``get_*``, underscore-prefixed variants included). Such a
    function re-traces on every call unless every caller caches the result —
    a per-call cost invisible at the call site. One-shot CLI mains and
    build-once helpers waive this with a reasoned pragma.

``retrace.unhashable_static``
    ``static_argnums``/``static_argnames`` given a list/dict/set literal.
    jax hashes static arguments into the trace-cache key; unhashable
    containers either fail at call time or (as dict values) defeat caching.
"""
from __future__ import annotations

import ast
import re

from repro.analysis.common import Finding, SourceFile

__all__ = ["run", "JIT_CTORS"]

# names whose *call* constructs a traced/compiled callable
JIT_CTORS = {"jit", "pjit", "shard_map", "pallas_call"}

_FACTORY_NAME = re.compile(r"^_?(make|build|create|resolve|get)_")
_CTOR_OK_FUNCS = {"__init__", "__post_init__", "__call__"}


def _call_name(node: ast.Call) -> str | None:
    """Simple name of the called function: jax.jit -> "jit", jit -> "jit"."""
    f = node.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return None


def _is_jit_ctor(node: ast.Call) -> bool:
    name = _call_name(node)
    if name in JIT_CTORS:
        return True
    # functools.partial(jax.jit, ...) builds a jit ctor; calling IT later is
    # caught as a plain ctor call only if spelled directly — treat the
    # partial itself as the construction site
    if name == "partial" and node.args:
        first = node.args[0]
        if isinstance(first, (ast.Attribute, ast.Name)):
            inner = first.attr if isinstance(first, ast.Attribute) else first.id
            return inner in JIT_CTORS
    return False


def collect_jit_factories(files: list[SourceFile]) -> set[str]:
    """Names of functions (anywhere in the tree) whose body constructs a jit
    directly — the set ``factory_in_loop`` checks call sites against."""
    factories: set[str] = set()
    for sf in files:
        for node in ast.walk(sf.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            # only factory-NAMED functions join the set: call sites resolve
            # by bare name, and a generic name ("run") that happens to build
            # a kernel somewhere would flag every unrelated obj.run() call
            if not _FACTORY_NAME.match(node.name):
                continue
            for sub in ast.walk(node):
                if isinstance(sub, ast.Call) and _is_jit_ctor(sub):
                    factories.add(node.name)
                    break
    return factories


class _Visitor(ast.NodeVisitor):
    def __init__(self, sf: SourceFile, factories: set[str]):
        self.sf = sf
        self.factories = factories
        self.findings: list[Finding] = []
        self._funcs: list[str] = []   # enclosing function-name stack
        self._loops = 0               # enclosing for/while/comprehension depth

    # ---- scope bookkeeping
    def _visit_func(self, node):
        # decorators evaluate at def time in the ENCLOSING scope: visit them
        # before entering the function (else @partial(jax.jit, ...) on a
        # module-level function reads as construction inside it)
        for dec in node.decorator_list:
            self.visit(dec)
        self._funcs.append(node.name)
        outer_loops, self._loops = self._loops, 0  # a nested def resets loop
        for arg_default in node.args.defaults + node.args.kw_defaults:
            if arg_default is not None:
                self.visit(arg_default)
        for stmt in node.body:                     # context: its body runs
            self.visit(stmt)                       # when called, not per-iter
        self._loops = outer_loops
        self._funcs.pop()

    visit_FunctionDef = visit_AsyncFunctionDef = _visit_func

    def _visit_loop(self, node):
        self._loops += 1
        self.generic_visit(node)
        self._loops -= 1

    visit_For = visit_AsyncFor = visit_While = _visit_loop
    visit_ListComp = visit_SetComp = visit_DictComp = visit_GeneratorExp = _visit_loop

    # ---- the rules
    def visit_Call(self, node: ast.Call):
        name = _call_name(node)
        ctx = ".".join(self._funcs) or "<module>"
        if _is_jit_ctor(node):
            detail = f"{ctx}:{name}"
            if self._loops:
                self.findings.append(Finding(
                    "retrace.jit_in_loop", self.sf.relpath, node.lineno, detail,
                    f"{name}(...) constructed inside a loop in {ctx}: every "
                    "iteration builds a fresh traced callable (fresh trace "
                    "cache) — hoist the construction out of the loop",
                ))
            elif self._funcs and not self._factory_scope_ok():
                self.findings.append(Finding(
                    "retrace.jit_outside_factory", self.sf.relpath, node.lineno,
                    detail,
                    f"{name}(...) constructed inside {ctx}(): re-traces on "
                    "every call unless callers cache the result — move into a "
                    "make_*/build_* factory called once, or waive with a "
                    "pragma if this path runs once per process",
                ))
            self._check_static_args(node, ctx, name)
        elif self._loops and name in self.factories and name != (
            self._funcs[-1] if self._funcs else None
        ):
            self.findings.append(Finding(
                "retrace.factory_in_loop", self.sf.relpath, node.lineno,
                f"{ctx}:{name}",
                f"jit factory {name}() called inside a loop in {ctx}: each "
                "call builds a fresh jitted callable — build once before the "
                "loop and reuse it",
            ))
        self.generic_visit(node)

    @staticmethod
    def _factory_ok(fname: str) -> bool:
        return bool(_FACTORY_NAME.match(fname)) or fname in _CTOR_OK_FUNCS

    def _factory_scope_ok(self) -> bool:
        """OK when ANY enclosing function is factory-named: a closure built
        inside ``make_*`` (the kernel pattern — ``make_composite``'s inner
        ``run`` wrapping a ``pallas_call``) is constructed per *trace* of its
        jitted caller, not per call."""
        return any(self._factory_ok(f) for f in self._funcs)

    def _check_static_args(self, node: ast.Call, ctx: str, name: str):
        for kw in node.keywords:
            if kw.arg not in ("static_argnums", "static_argnames"):
                continue
            for sub in ast.walk(kw.value):
                if isinstance(sub, (ast.List, ast.Dict, ast.Set)):
                    self.findings.append(Finding(
                        "retrace.unhashable_static", self.sf.relpath,
                        node.lineno, f"{ctx}:{name}:{kw.arg}",
                        f"{kw.arg} passed a {type(sub).__name__.lower()} "
                        f"literal in {ctx}: jax hashes static arguments into "
                        "the trace-cache key — use a tuple",
                    ))
                    break


def run(files: list[SourceFile]) -> list[Finding]:
    factories = collect_jit_factories(files)
    out: list[Finding] = []
    for sf in files:
        v = _Visitor(sf, factories)
        v.visit(sf.tree)
        out.extend(sf.apply_pragmas(v.findings))
    return out
