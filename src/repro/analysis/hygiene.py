"""Error-handling hygiene: no silent broad exception swallowing.

``hygiene.broad_except``
    A ``except Exception:`` / bare ``except:`` / ``except BaseException:``
    handler. Broad handlers on the serving hot path turn real failures
    (encoder bugs, engine state corruption) into silently-wrong frames.
    Legitimate catch-alls — last-ditch dispatcher survival, reader-death
    fan-out — must (a) record an ``obs`` error counter or re-raise/surface
    the error, and (b) carry a reasoned pragma::

        except Exception:  # analysis: allow(hygiene.broad_except, last-ditch: counted on gateway.engine_errors)
"""
from __future__ import annotations

import ast

from repro.analysis.common import Finding, SourceFile

__all__ = ["run"]

_BROAD = {"Exception", "BaseException"}


def _is_broad(node: ast.ExceptHandler) -> bool:
    t = node.type
    if t is None:
        return True  # bare except:
    if isinstance(t, ast.Name):
        return t.id in _BROAD
    if isinstance(t, ast.Attribute):  # builtins.Exception spelled out
        return t.attr in _BROAD
    if isinstance(t, ast.Tuple):
        return any(isinstance(el, (ast.Name, ast.Attribute)) and
                   (el.id if isinstance(el, ast.Name) else el.attr) in _BROAD
                   for el in t.elts)
    return False


def run(files: list[SourceFile]) -> list[Finding]:
    out: list[Finding] = []
    for sf in files:
        findings: list[Finding] = []
        func_stack: list[tuple[str, int, int]] = []
        for node in ast.walk(sf.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                func_stack.append((node.name, node.lineno, node.end_lineno or node.lineno))
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.ExceptHandler) and _is_broad(node):
                enclosing = [f for f in func_stack if f[1] <= node.lineno <= f[2]]
                # innermost enclosing function = the one starting last
                ctx = max(enclosing, key=lambda f: f[1])[0] if enclosing else "<module>"
                findings.append(Finding(
                    "hygiene.broad_except", sf.relpath, node.lineno, ctx,
                    f"broad exception handler in {ctx}: narrow the caught "
                    "types, or keep it broad with a reasoned pragma (and an "
                    "obs error counter if this swallows on a hot path)",
                ))
        out.extend(sf.apply_pragmas(findings))
    return out
