"""TSan-lite: opt-in runtime lockset race sanitizer (``REPRO_TSAN=1``).

The static pass (:mod:`repro.analysis.locks`) sees spelled-out ``self.x``
writes; it cannot see aliased mutation (``d = self._index; d["k"] = v``) or
prove a happens-before discipline actually holds at runtime. This module is
the dynamic complement: instances at the known thread boundaries (gateway,
session manager, checkpoint-store writer) opt in via :func:`attach`, which

* swaps the instance's class for a generated subclass whose ``__setattr__``
  records every field write with the writing thread + the locks it holds,
* wraps named lock attributes in :class:`TrackedLock` (maintains the
  per-thread held-lock set),
* wraps named dict attributes in :class:`TrackedDict` (mutator methods
  count as writes to the owning field — the aliasing the AST pass misses),

and runs the Eraser lockset state machine per field: a field stays
*exclusive* while one thread writes it; the second writing thread moves it
to *shared* and every shared write intersects the candidate lockset. An
empty intersection is a write/write race, recorded (once per field) on the
module-level :data:`RACES` list that the test fixture drains and fails on.

Fields whose cross-thread order is established by something other than a
lock (``queue.join()``, a ``threading.Event``) are listed in ``ordered=``
and exempted — the waiver mirror of the static pass's pragma.

When ``REPRO_TSAN`` is unset this module is inert: :func:`attach` returns
the instance untouched, no wrapper types are created, and instrumented
code paths are bitwise identical to an uninstrumented run.
"""
from __future__ import annotations

import dataclasses
import os
import threading

__all__ = [
    "enabled",
    "attach",
    "TrackedLock",
    "TrackedDict",
    "Race",
    "RACES",
    "take_races",
    "reset",
]

_TLS = threading.local()
_RACE_LOCK = threading.Lock()
RACES: list["Race"] = []
_SUBCLASS_CACHE: dict[type, type] = {}


def enabled() -> bool:
    return os.environ.get("REPRO_TSAN", "") not in ("", "0")


def _held() -> tuple[int, ...]:
    return tuple(getattr(_TLS, "held", ()))


def _push_held(lock_id: int) -> None:
    _TLS.held = _held() + (lock_id,)


def _pop_held(lock_id: int) -> None:
    held = list(_held())
    if lock_id in held:
        held.reverse()
        held.remove(lock_id)
        held.reverse()
    _TLS.held = tuple(held)


@dataclasses.dataclass
class Race:
    """One detected write/write race (reported once per (object, field))."""

    obj: str      # attach-time name, e.g. "SessionManager"
    field: str
    threads: tuple[str, str]  # (owner thread name, racing thread name)
    message: str

    def __str__(self) -> str:
        return self.message


@dataclasses.dataclass
class _FieldState:
    owner: int | None = None      # first writing thread ident
    owner_name: str = ""
    shared: bool = False
    lockset: frozenset | None = None
    reported: bool = False


class _Cfg:
    __slots__ = ("name", "exempt", "dicts", "fields", "lock")

    def __init__(self, name: str, exempt: set[str], dicts: set[str]):
        self.name = name
        self.exempt = exempt
        self.dicts = dicts
        self.fields: dict[str, _FieldState] = {}
        self.lock = threading.Lock()  # guards .fields itself


def _on_write(cfg: _Cfg, field: str) -> None:
    if field in cfg.exempt:
        return
    tid = threading.get_ident()
    tname = threading.current_thread().name
    with cfg.lock:
        st = cfg.fields.setdefault(field, _FieldState())
        if st.owner is None:
            st.owner, st.owner_name = tid, tname
            return
        if not st.shared:
            if tid == st.owner:
                return
            st.shared = True               # second writer arrives: Eraser
            st.lockset = frozenset(_held())  # candidate set = its locks
        else:
            st.lockset = st.lockset & frozenset(_held())
        if not st.lockset and not st.reported:
            st.reported = True
            race = Race(
                cfg.name, field, (st.owner_name, tname),
                f"write/write race on {cfg.name}.{field}: threads "
                f"{st.owner_name!r} and {tname!r} both write it with no "
                "common lock held — guard it, or attach() it as ordered= "
                "with the happens-before that protects it",
            )
            with _RACE_LOCK:
                RACES.append(race)


class TrackedLock:
    """Wraps a Lock/RLock; acquire/release maintain the held-lock set."""

    def __init__(self, lock, name: str):
        self._lock = lock
        self._name = name

    def acquire(self, *a, **kw) -> bool:
        got = self._lock.acquire(*a, **kw)
        if got:
            _push_held(id(self))
        return got

    def release(self) -> None:
        _pop_held(id(self))
        self._lock.release()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def locked(self) -> bool:
        return self._lock.locked()

    def __repr__(self) -> str:
        return f"TrackedLock({self._name})"


class TrackedDict(dict):
    """dict whose mutators count as writes to the owning object's field —
    catches the ``d = self._index; d[k] = v`` aliasing the AST pass can't."""

    def __init__(self, data, cfg: _Cfg, field: str):
        super().__init__(data)
        self._cfg = cfg
        self._field = field

    def _w(self) -> None:
        _on_write(self._cfg, self._field)

    def __setitem__(self, k, v):
        self._w()
        super().__setitem__(k, v)

    def __delitem__(self, k):
        self._w()
        super().__delitem__(k)

    def pop(self, *a):
        self._w()
        return super().pop(*a)

    def popitem(self):
        self._w()
        return super().popitem()

    def clear(self):
        self._w()
        super().clear()

    def update(self, *a, **kw):
        self._w()
        super().update(*a, **kw)

    def setdefault(self, k, default=None):
        self._w()
        return super().setdefault(k, default)


def _tracked_setattr(self, name, value):
    cfg = self.__dict__.get("_tsan_cfg")
    if cfg is not None and not name.startswith("_tsan"):
        if name in cfg.dicts and type(value) is dict:
            # field re-assigned a plain dict (swap patterns like
            # ``dirty, self._d = self._d, {}``): keep tracking the new one
            value = TrackedDict(value, cfg, name)
        _on_write(cfg, name)  # checks the ordered/exempt set itself
    object.__setattr__(self, name, value)


def attach(obj, *, locks=(), dicts=(), ordered=(), name: str | None = None):
    """Instrument ``obj`` (in place) when the sanitizer is enabled.

    ``locks``: attribute names holding Lock/RLock objects — wrapped so the
    held-lock set is maintained. ``dicts``: dict-valued attributes whose
    mutator calls count as field writes. ``ordered``: fields exempted
    because a non-lock happens-before (queue.join, Event) orders them.
    Returns ``obj`` either way; a no-op (same object, same class, same
    attribute values) when ``REPRO_TSAN`` is off."""
    if not enabled():
        return obj
    cls = obj.__class__
    sub = _SUBCLASS_CACHE.get(cls)
    if sub is None:
        sub = type("Tsan" + cls.__name__, (cls,), {"__setattr__": _tracked_setattr})
        _SUBCLASS_CACHE[cls] = sub
    cfg = _Cfg(name or cls.__name__, set(ordered) | set(locks), set(dicts))
    object.__setattr__(obj, "_tsan_cfg", cfg)
    for ln in locks:
        object.__setattr__(obj, ln, TrackedLock(getattr(obj, ln), f"{cfg.name}.{ln}"))
    for dn in dicts:
        object.__setattr__(obj, dn, TrackedDict(getattr(obj, dn), cfg, dn))
    obj.__class__ = sub
    return obj


def take_races() -> list[Race]:
    """Drain and return the recorded races (the test-fixture hook)."""
    with _RACE_LOCK:
        out, RACES[:] = list(RACES), []
    return out


def reset() -> None:
    take_races()
