"""Vocabulary checker: the stringly-typed metric/span names stay coherent.

The stack's observability contract is a flat dotted namespace
(``server.*`` / ``gateway.*`` / ``cache.*`` / ``sessions.*`` / ``train.*``
/ ``lod.*``) registered via ``counter("...")``/``gauge("...")``/
``histogram("...")`` plus the span vocabularies ``STAGES``/``TRAIN_STAGES``
in ``repro.obs.trace``. Code, benchmarks, and the README all reference these
names as string literals — nothing type-checks them, so a typo'd read or a
renamed metric silently reports zeros. This pass extracts every name and
cross-checks:

``names.unregistered_use``
    A tier-dotted string literal used in code (a read, a doc-string example,
    a test assertion) that no registration site or declared family produces.

``names.unread``
    A registered metric whose dotted name no code outside the registration
    reads — not as an exact literal, not via a prefix read (``"gateway." +
    name``, ``stage_breakdown(snap, prefix="server.")``), and not documented
    in the scanned docs. Either wire it into a report/test/README or drop it.

``names.doc_drift``
    A tier-dotted name in the docs (README, ``bench_schema.py``) that
    matches no registered name or family — documentation that drifted from
    the registry.

``names.dynamic_unresolved``
    A registration whose name is built dynamically with no static dotted
    prefix (``gauge(f"{prefix}.bytes.{dev}")``). Declare the produced family
    at the site: ``# analysis: declare(train.devmem.*)``.

``names.unknown_span`` / ``names.unrecorded_stage``
    A ``record(rid, "<span>")`` literal outside ``STAGES``/``TRAIN_STAGES``,
    and a vocabulary stage never recorded anywhere (exporters lay Perfetto
    lanes from the vocabulary — a dead stage is a dead lane).

Dynamic registrations with a static dotted prefix (``f"server.lod_rows.l
{lvl}"``) register the family ``server.lod_rows.l*``; doc names may use
``*`` or ``<i>``-style placeholders to reference a family.
"""
from __future__ import annotations

import ast
import re

from repro.analysis.common import Finding, SourceFile

__all__ = ["run", "extract_vocab", "TIERS"]

TIERS = ("server", "gateway", "cache", "sessions", "train", "lod")

_REG_METHODS = {"counter", "gauge", "histogram"}
_NAME_RE = re.compile(r"^(?:%s)\.[A-Za-z0-9_.]+$" % "|".join(TIERS))
_DOC_RE = re.compile(r"\b(?:%s)\.[A-Za-z0-9_.<>{}*]*[A-Za-z0-9_*>}]" % "|".join(TIERS))
_SPAN_VOCAB_NAMES = {"STAGES", "TRAIN_STAGES"}
# "sessions.py" / "train.jsonl" are file references, not metric names
_FILE_EXT_RE = re.compile(r"\.(py|pyc|md|json|jsonl|txt|yml|yaml|csv|png|npz|npy)$")


def _static_prefix(node) -> str | None:
    """Leading literal of a dynamically-built string, or None.

    Handles f-strings, ``"a." + x``, ``"a.%d" % x`` and ``"a.{}".format(x)``.
    """
    if isinstance(node, ast.JoinedStr):
        if node.values and isinstance(node.values[0], ast.Constant):
            return str(node.values[0].value)
        return ""
    if isinstance(node, ast.BinOp) and isinstance(node.op, (ast.Add, ast.Mod)):
        if isinstance(node.left, ast.Constant) and isinstance(node.left.value, str):
            return node.left.value.split("%")[0]
        return ""
    if (isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute)
            and node.func.attr == "format"
            and isinstance(node.func.value, ast.Constant)
            and isinstance(node.func.value.value, str)):
        return node.func.value.value.split("{")[0]
    return None


class Vocab:
    """Everything extracted from the scanned tree in one walk."""

    def __init__(self):
        self.registered: dict[str, tuple[str, int]] = {}   # name -> site
        self.families: dict[str, tuple[str, int]] = {}     # prefix -> site
        self.dynamic_unresolved: list[tuple[str, int, str]] = []  # path, line, ctx
        self.uses: list[tuple[str, str, int]] = []         # name, path, line
        self.read_prefixes: set[str] = set()
        self.declared: set[str] = set()        # exact declares
        self.declared_families: set[str] = set()
        self.spans_recorded: list[tuple[str, str, int]] = []
        self.span_vocab: dict[str, tuple[str, int]] = {}   # stage -> def site

    # ---- matching helpers
    def covers(self, name: str) -> bool:
        """Is ``name`` produced by some registration or declaration?"""
        if name in self.registered or name in self.declared:
            return True
        return any(name.startswith(f)
                   for f in (*self.families, *self.declared_families))

    def doc_token_matches(self, token: str) -> bool:
        """Does a doc name (possibly with ``*``/``<i>``/``{i}`` placeholders)
        reference at least one registered name or family?"""
        norm = re.sub(r"(<[^>]*>|\{[^}]*\})", "*", token)
        if "*" not in norm:
            return self.covers(norm)
        prefix = norm.split("*", 1)[0]
        if any(n.startswith(prefix) for n in (*self.registered, *self.declared)):
            return True
        return any(f.startswith(prefix) or prefix.startswith(f)
                   for f in (*self.families, *self.declared_families))

    def read_evidence(self, name: str, reg_site: tuple[str, int]) -> bool:
        for use, path, line in self.uses:
            if use == name and (path, line) != reg_site:
                return True
        return any(name.startswith(p) for p in self.read_prefixes)


class _Extractor(ast.NodeVisitor):
    def __init__(self, sf: SourceFile, vocab: Vocab):
        self.sf = sf
        self.vocab = vocab
        self._funcs: list[str] = []
        self._reg_sites: set[tuple[int, int]] = set()  # (line, col) of reg args

    def _visit_func(self, node):
        self._funcs.append(node.name)
        self.generic_visit(node)
        self._funcs.pop()

    visit_FunctionDef = visit_AsyncFunctionDef = _visit_func

    def visit_Assign(self, node: ast.Assign):
        # STAGES / TRAIN_STAGES tuple definitions (module scope)
        if not self._funcs:
            for t in node.targets:
                if (isinstance(t, ast.Name) and t.id in _SPAN_VOCAB_NAMES
                        and isinstance(node.value, (ast.Tuple, ast.List))):
                    for el in node.value.elts:
                        if isinstance(el, ast.Constant) and isinstance(el.value, str):
                            self.vocab.span_vocab.setdefault(
                                el.value, (self.sf.relpath, el.lineno)
                            )
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call):
        f = node.func
        attr = f.attr if isinstance(f, ast.Attribute) else (
            f.id if isinstance(f, ast.Name) else None
        )
        if attr in _REG_METHODS and node.args:
            arg = node.args[0]
            site = (self.sf.relpath, node.lineno)
            if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
                self._reg_sites.add((arg.lineno, arg.col_offset))
                if _NAME_RE.match(arg.value):
                    self.vocab.registered.setdefault(arg.value, site)
            else:
                prefix = _static_prefix(arg)
                if prefix is not None:
                    if "." in prefix and prefix.split(".", 1)[0] in TIERS:
                        self.vocab.families.setdefault(prefix, site)
                    elif not self.sf.declare_covers(node.lineno):
                        ctx = ".".join(self._funcs) or "<module>"
                        self.vocab.dynamic_unresolved.append(
                            (self.sf.relpath, node.lineno, ctx)
                        )
        elif attr in ("record", "instant") and len(node.args) >= 2:
            arg = node.args[1]
            if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
                self.vocab.spans_recorded.append(
                    (arg.value, self.sf.relpath, arg.lineno)
                )
        # prefix reads built dynamically: "gateway." + name, "%s.x" % tier
        for sub in ast.walk(node):
            p = _static_prefix(sub)
            if p and p.endswith(".") and p.rstrip(".").split(".", 1)[0] in TIERS:
                self.vocab.read_prefixes.add(p)
        self.generic_visit(node)

    def visit_Constant(self, node: ast.Constant):
        if isinstance(node.value, str):
            if (node.lineno, node.col_offset) in self._reg_sites:
                return
            v = node.value
            if _NAME_RE.match(v) and not _FILE_EXT_RE.search(v):
                self.vocab.uses.append((v, self.sf.relpath, node.lineno))
            elif v.endswith(".") and v.rstrip(".").split(".", 1)[0] in TIERS and "." in v:
                self.vocab.read_prefixes.add(v)


def extract_vocab(files: list[SourceFile]) -> Vocab:
    vocab = Vocab()
    for sf in files:
        for name in sf.declared_names():
            if name.endswith("*"):
                vocab.declared_families.add(name[:-1])
            else:
                vocab.declared.add(name)
    for sf in files:
        ex = _Extractor(sf, vocab)
        ex.visit(sf.tree)
        # second walk for bare constants: _reg_sites must be complete first
        # (visit_Call runs before the registration arg's own visit_Constant,
        # so one walk suffices — kept as a single pass)
    return vocab


def _doc_findings(vocab: Vocab, doc_texts: dict[str, str]) -> list[Finding]:
    out = []
    for path, text in sorted(doc_texts.items()):
        for i, line in enumerate(text.splitlines(), start=1):
            for m in _DOC_RE.finditer(line):
                token = m.group(0)
                if "." not in token or _FILE_EXT_RE.search(token):
                    continue
                if not vocab.doc_token_matches(token):
                    out.append(Finding(
                        "names.doc_drift", path, i, token,
                        f"{token!r} is documented but matches no registered "
                        "metric name or family — fix the doc or register "
                        "the name",
                    ))
    return out


def run(files: list[SourceFile], doc_texts: dict[str, str] | None = None) -> list[Finding]:
    vocab = extract_vocab(files)
    by_path = {sf.relpath: sf for sf in files}
    findings: list[Finding] = []

    for path, line, ctx in vocab.dynamic_unresolved:
        findings.append(Finding(
            "names.dynamic_unresolved", path, line, ctx,
            f"metric registered in {ctx} with a dynamically-built name the "
            "checker cannot resolve — add '# analysis: declare(<family>*)' "
            "naming the produced family",
        ))
    for use, path, line in vocab.uses:
        if vocab.covers(use):
            continue
        # a literal that is a strict prefix of registered names/families is a
        # filter read (``name.startswith("train.shard_")``), not a typo — it
        # also counts as read evidence for everything it covers
        if any(n.startswith(use) for n in
               (*vocab.registered, *vocab.declared,
                *vocab.families, *vocab.declared_families)):
            vocab.read_prefixes.add(use)
            continue
        findings.append(Finding(
            "names.unregistered_use", path, line, use,
            f"{use!r} is used here but never registered on any metrics "
            "registry — typo'd read, or a metric that was renamed",
        ))
    for name, site in sorted(vocab.registered.items()):
        if vocab.read_evidence(name, site):
            continue
        findings.append(Finding(
            "names.unread", site[0], site[1], name,
            f"{name!r} is registered but nothing reads it by name (no "
            "literal, no covering prefix read, no doc mention) — wire it "
            "into a report/doc or drop it",
        ))
    if vocab.span_vocab:
        for span, path, line in vocab.spans_recorded:
            if span not in vocab.span_vocab:
                # tests/benchmarks may record off-vocabulary spans on purpose
                # (overflow-lane coverage); only src recordings are held to
                # the vocabulary
                if path.startswith(("tests/", "benchmarks/")):
                    continue
                findings.append(Finding(
                    "names.unknown_span", path, line, span,
                    f"span {span!r} is recorded but absent from STAGES/"
                    "TRAIN_STAGES — exporters lay lanes from the vocabulary, "
                    "so this span lands in the overflow lane",
                ))
        recorded = {s for s, _, _ in vocab.spans_recorded}
        if recorded:  # only meaningful when the scanned tree records spans
            for stage, (path, line) in sorted(vocab.span_vocab.items()):
                if stage not in recorded:
                    findings.append(Finding(
                        "names.unrecorded_stage", path, line, stage,
                        f"stage {stage!r} is in the span vocabulary but never "
                        "recorded anywhere in the scanned tree — dead lane",
                    ))
    # doc evidence also counts as "read": drop unread findings whose name a
    # doc token references, then add the doc-drift findings
    doc_texts = doc_texts or {}
    if doc_texts:
        doc_tokens = set()
        for text in doc_texts.values():
            doc_tokens.update(m.group(0) for m in _DOC_RE.finditer(text)
                              if not _FILE_EXT_RE.search(m.group(0)))
        norm = [re.sub(r"(<[^>]*>|\{[^}]*\})", "*", t) for t in doc_tokens]
        def documented(name: str) -> bool:
            for t in norm:
                if t == name:
                    return True
                if "*" in t and name.startswith(t.split("*", 1)[0]):
                    return True
            return False
        findings = [f for f in findings
                    if not (f.rule == "names.unread" and documented(f.detail))]
        findings.extend(_doc_findings(vocab, doc_texts))

    # apply pragmas for findings that live in parsed python files
    out: list[Finding] = []
    for f in findings:
        sf = by_path.get(f.path)
        if sf is not None:
            sf.apply_pragmas([f])
        out.append(f)
    return out
