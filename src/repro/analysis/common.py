"""Shared infrastructure for the ``repro.analysis`` static passes.

Every pass produces :class:`Finding` records over a parsed source tree; this
module owns the pieces they share:

``SourceFile``
    One parsed python file: raw lines, AST, and its pragma table. Parsed
    once, handed to every pass (the whole-``src/`` sweep stays well under a
    second).

Pragmas
    Findings are suppressed (not hidden — reported as *allowed*) with a
    comment pragma::

        x = risky()  # analysis: allow(locks.thread_shared_write, ordered by queue.join)

    The pragma covers its own line and the line below it; placed on a
    ``def``/``class`` header line it covers the whole block — the shape a
    per-attribute or per-method waiver needs. A second pragma form feeds the
    vocabulary pass at dynamic registration sites::

        metrics.gauge(f"{prefix}.bytes.{dev}")  # analysis: declare(train.devmem.*)

    declaring name families the AST cannot resolve statically.

Baseline ratchet
    ``ANALYSIS_baseline.json`` maps finding keys (rule|path|detail — no line
    numbers, so unrelated edits don't shift the baseline) to counts.
    Pre-existing findings pass; a new key, or a count above baseline, fails.
    Keys no longer found are reported as fixed so the baseline can be
    re-tightened with ``--update-baseline``.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
import re

__all__ = [
    "Finding",
    "SourceFile",
    "Pragma",
    "iter_python_files",
    "load_tree",
    "baseline_key",
    "load_baseline",
    "save_baseline",
    "diff_against_baseline",
]

_PRAGMA_RE = re.compile(r"#\s*analysis:\s*(allow|declare)\(([^)]*)\)")


@dataclasses.dataclass
class Finding:
    """One rule violation at one site."""

    rule: str       # dotted rule id, e.g. "retrace.jit_in_loop"
    path: str       # repo-relative file path
    line: int       # 1-based line of the offending node
    detail: str     # stable symbol-ish context (baseline key part, no line)
    message: str    # human-facing explanation
    allowed_by: str | None = None  # pragma reason when suppressed

    def key(self) -> str:
        return baseline_key(self.rule, self.path, self.detail)

    def to_dict(self) -> dict:
        d = {
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "detail": self.detail,
            "message": self.message,
        }
        if self.allowed_by is not None:
            d["allowed_by"] = self.allowed_by
        return d


@dataclasses.dataclass
class Pragma:
    kind: str            # "allow" | "declare"
    line: int
    args: list[str]      # declare: declared names; allow: [rule]
    reason: str          # allow: waiver reason ("" for declare)
    scope_end: int | None = None  # block end when on a def/class header


class SourceFile:
    """One parsed file: lines + AST + pragmas, shared by every pass."""

    def __init__(self, path: str, relpath: str, text: str):
        self.path = path
        self.relpath = relpath
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=relpath)
        self._block_ends = self._scan_blocks()
        self.pragmas = self._scan_pragmas()

    def _scan_blocks(self) -> dict[int, int]:
        """def/class header line -> end line of its block."""
        ends: dict[int, int] = {}
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
                ends[node.lineno] = node.end_lineno or node.lineno
        return ends

    def _scan_pragmas(self) -> list[Pragma]:
        out = []
        for i, line in enumerate(self.lines, start=1):
            m = _PRAGMA_RE.search(line)
            if not m:
                continue
            kind, body = m.group(1), m.group(2)
            parts = [p.strip() for p in body.split(",")]
            if kind == "allow":
                rule = parts[0] if parts else ""
                reason = ", ".join(parts[1:]).strip()
                args = [rule]
            else:
                args, reason = [p for p in parts if p], ""
            out.append(Pragma(kind, i, args, reason, self._block_ends.get(i)))
        return out

    def declared_names(self) -> list[str]:
        """Every name/family from ``declare(...)`` pragmas in this file."""
        return [n for p in self.pragmas if p.kind == "declare" for n in p.args]

    def allow_reason(self, rule: str, line: int) -> str | None:
        """The waiver reason when an ``allow`` pragma covers (rule, line).

        A pragma matches the exact rule, a dotted prefix ("locks."), or "*".
        Coverage: its own line, the next line, or — on a def/class header —
        the whole block."""
        for p in self.pragmas:
            if p.kind != "allow":
                continue
            want = p.args[0]
            if not (want == "*" or want == rule
                    or (want.endswith(".") and rule.startswith(want))):
                continue
            if line in (p.line, p.line + 1):
                return p.reason or "(no reason given)"
            if p.scope_end is not None and p.line <= line <= p.scope_end:
                return p.reason or "(no reason given)"
        return None

    def declare_covers(self, line: int) -> bool:
        """True when a ``declare`` pragma covers ``line`` (same placement
        rules as ``allow``) — waives ``names.dynamic_unresolved`` there."""
        for p in self.pragmas:
            if p.kind != "declare":
                continue
            if line in (p.line, p.line + 1):
                return True
            if p.scope_end is not None and p.line <= line <= p.scope_end:
                return True
        return False

    def apply_pragmas(self, findings: list[Finding]) -> list[Finding]:
        """Stamp ``allowed_by`` onto findings a pragma waives."""
        for f in findings:
            reason = self.allow_reason(f.rule, f.line)
            if reason is not None:
                f.allowed_by = reason
        return findings


def iter_python_files(root: str, *, skip_dirs=("__pycache__", ".git")) -> list[str]:
    out = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d not in skip_dirs]
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                out.append(os.path.join(dirpath, fn))
    return sorted(out)


def load_tree(paths: list[str], repo_root: str) -> list[SourceFile]:
    """Parse every file once; syntax errors become loud ValueErrors (an
    unparseable file would silently escape every pass)."""
    files = []
    for p in paths:
        with open(p, encoding="utf-8") as f:
            text = f.read()
        rel = os.path.relpath(p, repo_root)
        try:
            files.append(SourceFile(p, rel, text))
        except SyntaxError as e:
            raise ValueError(f"cannot parse {rel}: {e}") from e
    return files


# ------------------------------------------------------------------ baseline
def baseline_key(rule: str, path: str, detail: str) -> str:
    return f"{rule}|{path}|{detail}"


def load_baseline(path: str) -> dict[str, int]:
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        data = json.load(f)
    return {str(k): int(v) for k, v in data.get("findings", {}).items()}


def save_baseline(path: str, findings: list[Finding]) -> dict[str, int]:
    counts: dict[str, int] = {}
    for f in findings:
        if f.allowed_by is None:
            counts[f.key()] = counts.get(f.key(), 0) + 1
    with open(path, "w") as fp:
        json.dump(
            {"version": 1, "findings": dict(sorted(counts.items()))}, fp, indent=1
        )
        fp.write("\n")
    return counts


def diff_against_baseline(
    findings: list[Finding], baseline: dict[str, int]
) -> tuple[list[Finding], list[str], dict[str, int]]:
    """Ratchet: returns (new findings over baseline, fixed keys, live counts).

    Per key, the first ``baseline[key]`` findings pass; extras are new.
    Baseline keys with no live finding are fixed (informational)."""
    counts: dict[str, int] = {}
    new: list[Finding] = []
    for f in findings:
        if f.allowed_by is not None:
            continue
        k = f.key()
        counts[k] = counts.get(k, 0) + 1
        if counts[k] > baseline.get(k, 0):
            new.append(f)
    fixed = sorted(k for k in baseline if counts.get(k, 0) < baseline[k])
    return new, fixed, counts
