"""Configuration for distributed 3D-GS training (the paper's pipeline)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class GSConfig:
    # image / rasterization
    img_h: int = 512
    img_w: int = 512
    tile_h: int = 16
    tile_w: int = 16
    k_per_tile: int = 256
    backend: str = "ref"            # "ref" | "pallas"
    binning: str = "auto"           # "flat" | "hier" | "auto" (hier when tiles>=256)
    bg: tuple[float, float, float] = (0.0, 0.0, 0.0)
    sh_degree: int = 0

    # training
    batch_size: int = 4             # global views per step
    max_steps: int = 30_000
    lambda_dssim: float = 0.2
    lr_means_init: float = 1.6e-4
    lr_means_final: float = 1.6e-6
    lr_scales: float = 5e-3
    lr_quats: float = 1e-3
    lr_opacity: float = 5e-2
    lr_sh: float = 2.5e-3
    grendel_sqrt_lr_scaling: bool = True  # Grendel batched-view LR rule

    # densification (3D-GS schedule, host-side between jitted segments)
    densify_from: int = 500
    densify_until: int = 15_000
    densify_interval: int = 100
    densify_grad_thresh: float = 2e-4  # on view-space mean2d grad norm
    densify_scale_thresh: float = 0.01  # split-vs-clone world-size boundary (x scene extent)
    prune_opacity_thresh: float = 0.005
    opacity_reset_interval: int = 3000

    # distribution
    pixel_parallel: bool = True     # strip-shard pixels over the model axis
    pad_quantum: int = 256          # gaussian count padding unit per shard
    # what crosses the interconnect from Gaussian owners to renderers:
    #   "projected" — Grendel/paper-faithful: 11-float 2D splats, per view
    #   "params3d"  — beyond-paper: the 14-float 3D state ONCE per step,
    #                 projection recomputed locally (wins for batch >= 2:
    #                 B*44 bytes vs 56 bytes per gaussian; §Perf GS log)
    gather_mode: str = "projected"

    def lr_tree_dict(self) -> dict:
        return {
            "means": self.lr_means_init,
            "log_scales": self.lr_scales,
            "quats": self.lr_quats,
            "opacity_logit": self.lr_opacity,
            "sh": self.lr_sh,
        }
