"""Densification / pruning / shard rebalancing (3D-GS adaptive control).

Runs host-side between jitted training segments (the Gaussian count changes,
so each densify round triggers a re-jit — same structure as the CUDA
pipeline, where densification is also an out-of-graph phase).

The rebalance step is the TPU adaptation of Grendel's dynamic Gaussian
redistribution: after clone/split/prune the global set is re-partitioned
into equal shards (padded to a quantum with dead Gaussians) so every
model-axis worker carries the same load.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import numpy as np

from repro.core import gaussians as G
from repro.core.config import GSConfig
from repro.core.train import GSTrainState, init_state

DEAD_LOGIT = -20.0  # sigmoid(-20) ~ 2e-9 < 1/255: never rasterized, zero grads


class DensifyReport(NamedTuple):
    n_before: int
    n_cloned: int
    n_split: int
    n_pruned: int
    n_after: int          # live count
    n_padded: int         # allocated count after padding


def _to_host(state: GSTrainState) -> dict:
    return {
        "params": jax.tree_util.tree_map(np.asarray, state.params),
        "adam_m": jax.tree_util.tree_map(np.asarray, state.adam.m),
        "adam_v": jax.tree_util.tree_map(np.asarray, state.adam.v),
        "grad2d": np.asarray(state.grad2d_accum),
        "vis": np.asarray(state.vis_count),
        "maxr": np.asarray(state.max_radii),
        "count": np.asarray(state.adam.count),
        "step": np.asarray(state.step),
    }


def densify_and_rebalance(
    state: GSTrainState,
    cfg: GSConfig,
    *,
    n_shards: int,
    scene_extent: float = 1.0,
    rng: np.random.Generator | None = None,
) -> tuple[GSTrainState, DensifyReport]:
    """3D-GS adaptive density control + equal re-sharding.

    clone: high view-space grad, small world size (under-reconstruction)
    split: high view-space grad, large world size (over-reconstruction)
    prune: opacity below threshold (or never visible since last round)
    """
    rng = rng or np.random.default_rng(0)
    h = _to_host(state)
    p = h["params"]
    n0 = p.means.shape[0]

    opac = 1.0 / (1.0 + np.exp(-p.opacity_logit))
    live = opac > cfg.prune_opacity_thresh
    avg_grad = h["grad2d"] / np.maximum(h["vis"], 1.0)
    scales = np.exp(p.log_scales).max(axis=1)

    hot = (avg_grad > cfg.densify_grad_thresh) & live & (h["vis"] > 0)
    small = scales <= cfg.densify_scale_thresh * scene_extent
    clone_mask = hot & small
    split_mask = hot & ~small

    # ---- clone: duplicate as-is (both copies receive future gradients)
    clones = jax.tree_util.tree_map(lambda a: a[clone_mask], p)

    # ---- split: two children sampled inside the parent, scales shrunk 1.6x
    parents = jax.tree_util.tree_map(lambda a: a[split_mask], p)
    n_split = parents.means.shape[0]
    children = []
    for _ in range(2):
        noise = rng.normal(0.0, 1.0, (n_split, 3)).astype(np.float32) * np.exp(parents.log_scales)
        R = np.asarray(G.quat_to_rotmat(parents.quats))
        offs = np.einsum("nij,nj->ni", R, noise)
        children.append(
            G.GaussianModel(
                means=parents.means + offs,
                log_scales=parents.log_scales - np.log(1.6),
                quats=parents.quats,
                opacity_logit=parents.opacity_logit,
                sh=parents.sh,
            )
        )

    keep_mask = live & ~split_mask  # split parents are replaced by children
    kept = jax.tree_util.tree_map(lambda a: a[keep_mask], p)
    kept_m = jax.tree_util.tree_map(lambda a: a[keep_mask], h["adam_m"])
    kept_v = jax.tree_util.tree_map(lambda a: a[keep_mask], h["adam_v"])

    def cat(*trees):
        return jax.tree_util.tree_map(lambda *xs: np.concatenate(xs, axis=0), *trees)

    new_params = cat(kept, clones, children[0], children[1])
    # fresh optimizer moments for newly created gaussians (3D-GS convention)
    zeros_like_new = jax.tree_util.tree_map(
        lambda a: np.zeros_like(a), cat(clones, children[0], children[1])
    )
    new_m = cat(kept_m, zeros_like_new)
    new_v = cat(kept_v, zeros_like_new)

    n_live = new_params.means.shape[0]
    n_pruned = int(np.sum(~live))

    # ---- rebalance: pad to shard quantum, shuffle for load uniformity
    quantum = n_shards * cfg.pad_quantum
    n_padded = int(np.ceil(n_live / quantum) * quantum)
    pad = n_padded - n_live
    perm = rng.permutation(n_live)  # uniform load across shard boundaries

    def pad_field(a, fill=0.0):
        out = np.concatenate([a[perm], np.full((pad,) + a.shape[1:], fill, a.dtype)], axis=0)
        return out

    new_params = G.GaussianModel(
        means=pad_field(new_params.means, 1e6),
        log_scales=pad_field(new_params.log_scales, -10.0),
        quats=pad_field(new_params.quats, 0.0),
        opacity_logit=pad_field(new_params.opacity_logit, DEAD_LOGIT),
        sh=pad_field(new_params.sh),
    )
    # quats padding needs a valid rotation
    new_params.quats[n_live:, 0] = 1.0
    new_m = jax.tree_util.tree_map(lambda a: pad_field(a), new_m)
    new_v = jax.tree_util.tree_map(lambda a: pad_field(a), new_v)

    import jax.numpy as jnp

    new_state = init_state(G.GaussianModel(*[jnp.asarray(x) for x in new_params]))
    new_state = new_state._replace(
        adam=new_state.adam._replace(
            m=G.GaussianModel(*[jnp.asarray(x) for x in new_m]),
            v=G.GaussianModel(*[jnp.asarray(x) for x in new_v]),
            count=jnp.asarray(h["count"]),
        ),
        step=jnp.asarray(h["step"]),
    )
    report = DensifyReport(
        n_before=n0,
        n_cloned=int(clone_mask.sum()),
        n_split=n_split,
        n_pruned=n_pruned,
        n_after=n_live,
        n_padded=n_padded,
    )
    return new_state, report


def reset_opacity(state: GSTrainState, *, ceiling: float = 0.01) -> GSTrainState:
    """Periodic opacity reset (3D-GS: clamps opacity low to kill floaters).

    Dead (padding) gaussians stay dead."""
    import jax.numpy as jnp

    logit = state.params.opacity_logit
    ceil_logit = float(np.log(ceiling / (1 - ceiling)))
    new = jnp.where(logit > ceil_logit, ceil_logit, logit)
    new = jnp.where(logit <= DEAD_LOGIT + 1e-3, logit, new)
    return state._replace(params=state.params._replace(opacity_logit=new))
