"""Gaussian primitive parameterization.

The model state is a pytree of per-Gaussian parameters, matching the 3D-GS
formulation (Kerbl et al. 2023) as used by Sewell et al. and the paper:
means, anisotropic scales (log-space), rotations (quaternions), opacity
(logit-space) and color (spherical-harmonic coefficients; degree 0 by default
for isosurface visualization where color is view-independent shading baked
from the transfer function).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

SH_C0 = 0.28209479177387814


class GaussianModel(NamedTuple):
    """Per-Gaussian learnable parameters. Leading dim N is the Gaussian count."""

    means: jax.Array          # (N, 3) world-space centers
    log_scales: jax.Array     # (N, 3) log of per-axis std-dev
    quats: jax.Array          # (N, 4) rotation quaternion (wxyz, unnormalized)
    opacity_logit: jax.Array  # (N,)  sigmoid^-1 of opacity
    sh: jax.Array             # (N, K, 3) SH coefficients, K = (deg+1)^2

    @property
    def n(self) -> int:
        return self.means.shape[0]

    @property
    def sh_degree(self) -> int:
        return int(np.sqrt(self.sh.shape[1])) - 1


def scales(g: GaussianModel) -> jax.Array:
    return jnp.exp(g.log_scales)


def opacities(g: GaussianModel) -> jax.Array:
    return jax.nn.sigmoid(g.opacity_logit)


def num_params(g: GaussianModel) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(g))


def init_from_points(
    points: jax.Array,
    colors: jax.Array | None = None,
    *,
    sh_degree: int = 0,
    init_opacity: float = 0.1,
    init_scale: float | jax.Array | None = None,
    seed: int = 0,
) -> GaussianModel:
    """Seed Gaussians from an isosurface point cloud (the paper's init path).

    ``init_scale`` defaults to a heuristic mean nearest-neighbor distance
    estimated from the bounding-box density (exact kNN is done host-side in
    ``repro.volume.isosurface`` when points come from a real extraction).
    """
    points = jnp.asarray(points, jnp.float32)
    n = points.shape[0]
    if colors is None:
        colors = jnp.full((n, 3), 0.5, jnp.float32)
    k = (sh_degree + 1) ** 2
    sh = jnp.zeros((n, k, 3), jnp.float32)
    # DC term chosen so that degree-0 eval reproduces `colors` exactly.
    sh = sh.at[:, 0, :].set((jnp.asarray(colors, jnp.float32) - 0.5) / SH_C0)

    if init_scale is None:
        lo = jnp.min(points, axis=0)
        hi = jnp.max(points, axis=0)
        vol = jnp.prod(jnp.maximum(hi - lo, 1e-6))
        init_scale = jnp.clip((vol / jnp.maximum(n, 1)) ** (1.0 / 3.0), 1e-4, 1e2)
    log_scales = jnp.broadcast_to(jnp.log(jnp.asarray(init_scale, jnp.float32)), (n, 3)).astype(jnp.float32)

    quats = jnp.zeros((n, 4), jnp.float32).at[:, 0].set(1.0)
    opacity_logit = jnp.full((n,), float(np.log(init_opacity / (1 - init_opacity))), jnp.float32)
    return GaussianModel(points, log_scales, quats, opacity_logit, sh)


def quat_to_rotmat(quats: jax.Array) -> jax.Array:
    """(N,4) wxyz quaternions (unnormalized) -> (N,3,3) rotation matrices."""
    q = quats / (jnp.linalg.norm(quats, axis=-1, keepdims=True) + 1e-12)
    w, x, y, z = q[..., 0], q[..., 1], q[..., 2], q[..., 3]
    r00 = 1 - 2 * (y * y + z * z)
    r01 = 2 * (x * y - w * z)
    r02 = 2 * (x * z + w * y)
    r10 = 2 * (x * y + w * z)
    r11 = 1 - 2 * (x * x + z * z)
    r12 = 2 * (y * z - w * x)
    r20 = 2 * (x * z - w * y)
    r21 = 2 * (y * z + w * x)
    r22 = 1 - 2 * (x * x + y * y)
    return jnp.stack(
        [jnp.stack([r00, r01, r02], -1), jnp.stack([r10, r11, r12], -1), jnp.stack([r20, r21, r22], -1)], -2
    )


def covariance3d(g: GaussianModel) -> jax.Array:
    """(N,3,3) world-space covariance R S S^T R^T."""
    R = quat_to_rotmat(g.quats)
    s = scales(g)
    RS = R * s[:, None, :]
    return RS @ jnp.swapaxes(RS, -1, -2)


def eval_sh(sh: jax.Array, dirs: jax.Array) -> jax.Array:
    """Evaluate SH color for view directions.

    sh: (N, K, 3), dirs: (N, 3) unit vectors (camera->gaussian). Returns (N,3)
    in [0,1]-ish (clipped downstream). Supports degrees 0..3.
    """
    k = sh.shape[1]
    c = SH_C0 * sh[:, 0, :]
    if k > 1:
        x, y, z = dirs[:, 0:1], dirs[:, 1:2], dirs[:, 2:3]
        c = c + 0.4886025119029199 * (-y * sh[:, 1, :] + z * sh[:, 2, :] - x * sh[:, 3, :])
    if k > 4:
        x, y, z = dirs[:, 0:1], dirs[:, 1:2], dirs[:, 2:3]
        xx, yy, zz, xy, yz, xz = x * x, y * y, z * z, x * y, y * z, x * z
        c = c + (
            1.0925484305920792 * xy * sh[:, 4, :]
            + -1.0925484305920792 * yz * sh[:, 5, :]
            + 0.31539156525252005 * (2.0 * zz - xx - yy) * sh[:, 6, :]
            + -1.0925484305920792 * xz * sh[:, 7, :]
            + 0.5462742152960396 * (xx - yy) * sh[:, 8, :]
        )
    if k > 9:
        x, y, z = dirs[:, 0:1], dirs[:, 1:2], dirs[:, 2:3]
        xx, yy, zz = x * x, y * y, z * z
        c = c + (
            -0.5900435899266435 * y * (3 * xx - yy) * sh[:, 9, :]
            + 2.890611442640554 * x * y * z * sh[:, 10, :]
            + -0.4570457994644658 * y * (4 * zz - xx - yy) * sh[:, 11, :]
            + 0.3731763325901154 * z * (2 * zz - 3 * xx - 3 * yy) * sh[:, 12, :]
            + -0.4570457994644658 * x * (4 * zz - xx - yy) * sh[:, 13, :]
            + 1.445305721320277 * z * (xx - yy) * sh[:, 14, :]
            + -0.5900435899266435 * x * (xx - 3 * yy) * sh[:, 15, :]
        )
    return c + 0.5
