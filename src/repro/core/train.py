"""Distributed 3D-GS train step (the paper's contribution, JAX-native).

One jitted step = shard_map over the (data, model) mesh:
  project local Gaussian shard -> all_gather projected splats over "model"
  -> depth sort -> tile-bin -> composite local pixel strip -> distributed
  L1+D-SSIM -> backward (all_gather transposes to psum_scatter) -> fused
  psum of packed grads over "data" -> sharded Adam update.

The "replicated baseline" of the paper (single-GPU semantics, data-parallel
only) is the same code on a mesh with model=1.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

from repro.core import gaussians as G
from repro.core import projection as P
from repro.core import render as R
from repro.core.config import GSConfig
from repro.core.sharding import distributed_gs_loss, shard_map
from repro.optim.adam import AdamState, adam_init, adam_update
from repro.optim.schedules import expon_lr, grendel_lr_scale
from repro.utils.tree import pack_pytree


class GSTrainState(NamedTuple):
    params: G.GaussianModel        # sharded over "model" (axis 0 of each leaf)
    adam: AdamState                # sharded like params
    step: jax.Array                # () int32, replicated
    # densification statistics, sharded like params (per local Gaussian)
    grad2d_accum: jax.Array        # (n,) sum of view-space grad norms
    vis_count: jax.Array           # (n,) number of views seen in
    max_radii: jax.Array           # (n,) max screen-space radius


def init_state(params: G.GaussianModel) -> GSTrainState:
    n = params.n
    return GSTrainState(
        params=params,
        adam=adam_init(params),
        step=jnp.zeros((), jnp.int32),
        grad2d_accum=jnp.zeros((n,), jnp.float32),
        vis_count=jnp.zeros((n,), jnp.float32),
        max_radii=jnp.zeros((n,), jnp.float32),
    )


def state_shardings(mesh: Mesh, model_axis: str = "model"):
    """NamedShardings for a GSTrainState on the given mesh."""
    shard0 = NamedSharding(mesh, PS(model_axis))
    rep = NamedSharding(mesh, PS())
    return GSTrainState(
        params=G.GaussianModel(*([shard0] * 5)),
        adam=AdamState(G.GaussianModel(*([shard0] * 5)), G.GaussianModel(*([shard0] * 5)), rep),
        step=rep,
        grad2d_accum=shard0,
        vis_count=shard0,
        max_radii=shard0,
    )


def resolve_gather_mode(cfg: GSConfig, mesh: Mesh, *, data_axes=("data",), model_axis="model") -> str:
    """The comm schedule ``make_train_step`` will actually use (resolves
    ``"auto"`` exactly like the step builder does)."""
    d = 1
    for a in data_axes:
        d *= mesh.shape[a]
    m = mesh.shape[model_axis]
    mode = cfg.gather_mode
    if mode == "auto":
        mode = "params3d" if (cfg.batch_size // d) >= 2 and m > 1 else "projected"
    return mode


def all_gather_bytes_per_step(
    cfg: GSConfig, mesh: Mesh, n_total: int,
    *, data_axes: tuple[str, ...] = ("data",), model_axis: str = "model",
) -> int:
    """Analytic model-axis all-gather payload one train step materializes per
    device (bytes of the gathered tensor; float32). This is the collective
    the paper's scaling lives or dies on, so it travels with the per-step
    telemetry: ``projected`` gathers 11-float splats per local view, the
    beyond-paper ``params3d`` schedule gathers the 3D state once per step."""
    m = mesh.shape[model_axis]
    if m <= 1:
        return 0
    d = 1
    for a in data_axes:
        d *= mesh.shape[a]
    if resolve_gather_mode(cfg, mesh, data_axes=data_axes, model_axis=model_axis) == "params3d":
        sh_k = (cfg.sh_degree + 1) ** 2
        floats = n_total * (11 + 3 * sh_k)
    else:
        b_local = max(cfg.batch_size // d, 1)
        floats = b_local * n_total * P.PACKED_DIM
    return int(floats) * 4


def shard_balance(state: GSTrainState, *, opacity_thresh: float = 0.005) -> dict:
    """Per-model-shard load statistics, the trigger signal for dynamic
    rebalancing (Grendel's result: static Gaussian splits skew).

    Walks the params' ``addressable_shards`` — the same shard-by-shard pull
    checkpoint save uses, deduped across data-axis replicas — and reduces
    each shard ON ITS DEVICE (a handful of scalars cross to host, never the
    arrays): ``alive`` counts Gaussians whose opacity clears
    ``opacity_thresh`` (dead padding + pruned slots don't load a worker),
    ``visible`` counts slots that have ever projected on screen
    (``max_radii > 0``), and ``projected`` sums the accumulated per-view
    visibility tallies (``vis_count``) — the actual splat workload each
    shard contributed since the densify stats were last zeroed.

    ``imbalance`` is max/mean of the per-shard alive counts (1.0 = perfectly
    balanced; 0.0 only for an all-dead model).
    """
    import numpy as np

    logit_thresh = float(np.log(opacity_thresh / (1.0 - opacity_thresh)))

    def _shards(leaf):
        seen = {}
        for shard in leaf.addressable_shards:
            key = tuple((s.start or 0) for s in shard.index)
            if key not in seen:
                seen[key] = shard.data
        return [seen[k] for k in sorted(seen)]

    opac = _shards(state.params.opacity_logit)
    vis = _shards(state.vis_count)
    radii = _shards(state.max_radii)
    capacity = [int(s.shape[0]) for s in opac]
    alive = [int(jnp.sum(s > logit_thresh)) for s in opac]
    visible = [int(jnp.sum(r > 0.0)) for r in radii]
    projected = [float(jnp.sum(v)) for v in vis]
    mean_alive = sum(alive) / len(alive)
    imbalance = (max(alive) / mean_alive) if mean_alive > 0 else 0.0
    return {
        "n_shards": len(capacity),
        "capacity": capacity,
        "alive": alive,
        "visible": visible,
        "projected": projected,
        "alive_total": sum(alive),
        "imbalance": imbalance,
    }


def record_shard_balance(metrics, bal: dict, *, prefix: str = "train") -> None:  # analysis: declare(train.shard_capacity.s*, train.shard_alive.s*, train.shard_visible.s*, train.shard_projected.s*, train.alive_total, train.shard_imbalance)
    """Land a :func:`shard_balance` result on a registry: per-shard gauges
    ``<prefix>.shard_alive.s<i>`` / ``.shard_visible.s<i>`` /
    ``.shard_projected.s<i>`` / ``.shard_capacity.s<i>`` plus the
    ``<prefix>.shard_imbalance`` gauge a rebalancing pass will trigger on."""
    for i in range(bal["n_shards"]):
        metrics.gauge(f"{prefix}.shard_capacity.s{i}").set(bal["capacity"][i])
        metrics.gauge(f"{prefix}.shard_alive.s{i}").set(bal["alive"][i])
        metrics.gauge(f"{prefix}.shard_visible.s{i}").set(bal["visible"][i])
        metrics.gauge(f"{prefix}.shard_projected.s{i}").set(bal["projected"][i])
    metrics.gauge(f"{prefix}.alive_total").set(bal["alive_total"])
    metrics.gauge(f"{prefix}.shard_imbalance").set(round(float(bal["imbalance"]), 6))


def make_train_step(
    mesh: Mesh,
    cfg: GSConfig,
    *,
    data_axes: tuple[str, ...] = ("data",),
    model_axis: str = "model",
):
    """Build the jitted distributed train step for a fixed Gaussian count.

    Returned fn: (state, cams: Camera batched (B,...), gt: (B,H,W,3)) ->
    (state, metrics). Views are sharded over ``data_axes``; pixels strips over
    ``model_axis`` when cfg.pixel_parallel (each device then holds both a
    Gaussian shard and a pixel block — the Grendel worker model).
    """
    d = 1
    for a in data_axes:
        d *= mesh.shape[a]
    m = mesh.shape[model_axis]
    strip = cfg.pixel_parallel and m > 1
    if strip:
        assert cfg.img_h % (m * cfg.tile_h) == 0, "img_h must split into model-axis strips of whole tiles"
    assert cfg.batch_size % d == 0, "global batch must divide data axes"
    strip_h = cfg.img_h // m if strip else cfg.img_h
    bg = jnp.asarray(cfg.bg, jnp.float32)
    all_axes = tuple(data_axes) + (model_axis,)
    # comm-schedule selection (EXPERIMENTS.md G3 ablation): the 3D-state
    # gather wins whenever a worker renders >= 2 views of the same params
    gather_mode = resolve_gather_mode(cfg, mesh, data_axes=data_axes, model_axis=model_axis)

    def local_step(state: GSTrainState, cams: P.Camera, gt: jax.Array):
        params = state.params
        n_local = params.means.shape[0]
        b_local = gt.shape[0]

        def loss_fn(p, probe):
            if gather_mode == "params3d":
                # ---- beyond-paper comm schedule: all-gather the 3D state
                # ONCE per step (14+3K floats/gaussian) instead of 11-float
                # projected splats PER VIEW; projection recomputed locally.
                # Wins whenever B_local >= 2 (§Perf GS iteration G3).
                flat3d = jnp.concatenate(
                    [p.means, p.log_scales, p.quats, p.opacity_logit[:, None],
                     p.sh.reshape(n_local, -1)], axis=1,
                )
                flat_all = jax.lax.all_gather(flat3d, model_axis, axis=0, tiled=True)
                n_total = flat_all.shape[0]
                sh_k = p.sh.shape[1]
                p_full = G.GaussianModel(
                    means=flat_all[:, 0:3],
                    log_scales=flat_all[:, 3:6],
                    quats=flat_all[:, 6:10],
                    opacity_logit=flat_all[:, 10],
                    sh=flat_all[:, 11:].reshape(n_total, sh_k, 3),
                )
                gathered = jax.vmap(lambda cam: P.project(p_full, cam))(cams)  # (B_l,N,11)
                gathered = gathered + jnp.pad(probe, ((0, 0), (0, 0), (0, P.PACKED_DIM - 2)))
                shard0 = jax.lax.axis_index(model_axis) * n_local
                radii_local = jax.lax.dynamic_slice_in_dim(
                    gathered[..., P.RAD], shard0, n_local, axis=1
                )  # own shard's visibility stats
            else:
                # ---- paper-faithful (Grendel): project own shard, gather 2D
                def proj_one(cam):
                    return P.project(p, cam)

                packed = jax.vmap(proj_one)(cams)                  # (B_l, n_local, 11)
                packed = packed + jnp.pad(probe, ((0, 0), (0, 0), (0, P.PACKED_DIM - 2)))
                radii_local = packed[..., P.RAD]                   # (B_l, n_local)
                gathered = jax.lax.all_gather(packed, model_axis, axis=1, tiled=True)

            if strip:
                off = (jax.lax.axis_index(model_axis) * strip_h).astype(jnp.float32)
                gathered = gathered.at[..., P.MY].add(-off)

            def render_one(pk):
                pk_sorted, _ = P.sort_by_depth(pk)
                img, _ = R.render_packed(
                    pk_sorted,
                    img_h=strip_h,
                    img_w=cfg.img_w,
                    tile_h=cfg.tile_h,
                    tile_w=cfg.tile_w,
                    k_per_tile=cfg.k_per_tile,
                    bg=bg,
                    backend=cfg.backend,
                    binning=cfg.binning,
                )
                return img

            imgs = jax.vmap(render_one)(gathered)                  # (B_l, strip_h, W, 3)
            loss = distributed_gs_loss(
                imgs,
                gt,
                lam=cfg.lambda_dssim,
                strip_axis=model_axis if strip else None,
                reduce_axes=all_axes,
            )
            return loss, radii_local

        probe_n = n_local * m if gather_mode == "params3d" else n_local
        probe = jnp.zeros((b_local, probe_n, 2), jnp.float32)
        (loss, radii), (grads, probe_grad) = jax.value_and_grad(loss_fn, argnums=(0, 1), has_aux=True)(
            params, probe
        )

        # ---- the paper's fused all-reduce: ONE collective over packed grads
        flat, unpack = pack_pytree(grads)
        flat = jax.lax.psum(flat, data_axes)
        grads = unpack(flat)
        # view-space positional gradient stats for densification
        g2d = jnp.sqrt(jnp.sum(probe_grad * probe_grad, axis=-1) + 1e-20)  # (B_l, probe_n)
        if gather_mode == "params3d":
            g2d = jax.lax.dynamic_slice_in_dim(
                g2d, jax.lax.axis_index(model_axis) * n_local, n_local, axis=1
            )
        g2d = jax.lax.psum(jnp.sum(g2d, axis=0), data_axes)
        visible = radii > 0.0
        vis = jax.lax.psum(jnp.sum(visible.astype(jnp.float32), axis=0), data_axes)
        maxr = jax.lax.pmax(jnp.max(radii, axis=0), data_axes)

        # ---- sharded Adam update (per-field LRs; Grendel sqrt-batch scaling)
        scale = grendel_lr_scale(cfg.batch_size) if cfg.grendel_sqrt_lr_scaling else 1.0
        lr_means = expon_lr(
            state.step, lr_init=cfg.lr_means_init, lr_final=cfg.lr_means_final, max_steps=cfg.max_steps
        )
        lrs = G.GaussianModel(
            means=lr_means * scale,
            log_scales=cfg.lr_scales * scale,
            quats=cfg.lr_quats * scale,
            opacity_logit=cfg.lr_opacity * scale,
            sh=cfg.lr_sh * scale,
        )
        new_params, new_adam = adam_update(grads, state.adam, params, lrs)

        new_state = GSTrainState(
            params=new_params,
            adam=new_adam,
            step=state.step + 1,
            grad2d_accum=state.grad2d_accum + g2d,
            vis_count=state.vis_count + vis,
            max_radii=jnp.maximum(state.max_radii, maxr),
        )
        metrics = {"loss": loss}
        return new_state, metrics

    st_specs = GSTrainState(
        params=G.GaussianModel(*([PS(model_axis)] * 5)),
        adam=AdamState(
            G.GaussianModel(*([PS(model_axis)] * 5)),
            G.GaussianModel(*([PS(model_axis)] * 5)),
            PS(),
        ),
        step=PS(),
        grad2d_accum=PS(model_axis),
        vis_count=PS(model_axis),
        max_radii=PS(model_axis),
    )
    cam_spec = P.Camera(*([PS(data_axes)] * 5))
    gt_spec = PS(data_axes, model_axis) if strip else PS(data_axes)

    stepped = shard_map(
        local_step,
        mesh=mesh,
        in_specs=(st_specs, cam_spec, gt_spec),
        out_specs=(st_specs, {"loss": PS()}),
        check_vma=False,
    )
    # Pin output shardings to the exact NamedShardings of state_shardings():
    # on size-1 mesh axes XLA otherwise normalizes some outputs to PS(), so
    # feeding step t's output state back as step t+1's input would retrace.
    # One trace per Gaussian capacity is what the streaming trainer
    # (repro.insitu) relies on across a whole timestep sequence.
    out_shardings = jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec), (st_specs, {"loss": PS()})
    )
    return jax.jit(stepped, out_shardings=out_shardings)


def make_eval_render(mesh: Mesh, cfg: GSConfig, *, model_axis: str = "model"):
    """Distributed eval render of one view: full image, replicated output."""

    def local(params: G.GaussianModel, cam: P.Camera):
        packed = P.project(params, cam)
        gathered = jax.lax.all_gather(packed, model_axis, axis=0, tiled=True)
        pk_sorted, _ = P.sort_by_depth(gathered)
        img, t = R.render_packed(
            pk_sorted,
            img_h=cfg.img_h,
            img_w=cfg.img_w,
            tile_h=cfg.tile_h,
            tile_w=cfg.tile_w,
            k_per_tile=cfg.k_per_tile,
            bg=jnp.asarray(cfg.bg, jnp.float32),
            backend=cfg.backend,
            binning=cfg.binning,
        )
        return img, t

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(G.GaussianModel(*([PS(model_axis)] * 5)), P.Camera(*([PS()] * 5))),
        out_specs=(PS(), PS()),
        check_vma=False,
    )
    return jax.jit(fn)


def make_tile_row_render(mesh: Mesh, cfg: GSConfig, *, row: int, model_axis: str = "model"):
    """Distributed eval render of ONE horizontal tile row of one view.

    Returned fn: (params sharded over ``model_axis``, a single Camera) ->
    (cfg.tile_h, cfg.img_w, 3) image — the pixel rows
    ``[row*tile_h, (row+1)*tile_h)`` of the full-frame render, **bit-identical**
    to the same rows of :func:`make_batched_eval_render`'s output. The
    project -> all_gather -> depth-sort prefix is the full-frame computation
    verbatim; only the rasterize stage narrows, via the tile binner's
    ``row_offset`` (tile rectangles and per-tile pixel coordinates come out
    as the same integers, so binning and compositing see identical inputs
    per tile). This is the serve-side partial-render primitive: a cache that
    already holds most of a frame's tiles re-renders only the missing rows.

    ``row`` is static (the Pallas raster kernel specializes on the offset),
    so each (level-config, row) pair is its own jit trace — a bounded set,
    levels x tiles_y, paid lazily on first partial hit per row.
    """
    bg = jnp.asarray(cfg.bg, jnp.float32)
    row_offset = int(row) * cfg.tile_h

    def local(params: G.GaussianModel, cam: P.Camera):
        packed = P.project(params, cam)
        gathered = jax.lax.all_gather(packed, model_axis, axis=0, tiled=True)
        pk_sorted, _ = P.sort_by_depth(gathered)
        img, _ = R.render_packed(
            pk_sorted,
            img_h=cfg.tile_h,
            img_w=cfg.img_w,
            tile_h=cfg.tile_h,
            tile_w=cfg.tile_w,
            k_per_tile=cfg.k_per_tile,
            bg=bg,
            backend=cfg.backend,
            # always flat: a strip cannot reproduce the full frame's "hier"
            # superblock geometry, and hier is defined (and tested) to equal
            # flat binning — flat is the deterministic common denominator
            binning="flat",
            row_offset=row_offset,
        )
        return img

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(G.GaussianModel(*([PS(model_axis)] * 5)), P.Camera(*([PS()] * 5))),
        out_specs=PS(),
        check_vma=False,
    )
    return jax.jit(fn)


def make_batched_eval_render(
    mesh: Mesh,
    cfg: GSConfig,
    *,
    data_axes: tuple[str, ...] = ("data",),
    model_axis: str = "model",
    batch_mode: str = "auto",
):
    """Distributed eval render of a BATCH of views (the serving hot path).

    Returned fn: (params sharded over ``model_axis``, cams: Camera with a
    leading batch dim B sharded over ``data_axes``) -> (B, H, W, 3) images
    sharded over ``data_axes``. B must divide the data-axes device product.

    ``batch_mode`` picks how the local views fuse into one dispatch:
    "vmap" interleaves all views (maximum parallelism — right on TPU/GPU),
    "map" runs them sequentially inside the one jitted call (one view's
    working set at a time — right on cache-bound CPU hosts, where vmap's
    interleaving goes super-linear in B). "auto" selects by backend.

    Each trace is specialized to the local batch shape — callers (the
    ``repro.serve_gs`` micro-batcher) pad request groups to a fixed set of
    bucket sizes so the number of recompiles stays bounded.
    """
    bg = jnp.asarray(cfg.bg, jnp.float32)
    if batch_mode == "auto":
        batch_mode = "map" if jax.default_backend() == "cpu" else "vmap"
    assert batch_mode in ("vmap", "map"), batch_mode

    def local(params: G.GaussianModel, cams: P.Camera):
        def one(cam):
            packed = P.project(params, cam)
            gathered = jax.lax.all_gather(packed, model_axis, axis=0, tiled=True)
            pk_sorted, _ = P.sort_by_depth(gathered)
            img, _ = R.render_packed(
                pk_sorted,
                img_h=cfg.img_h,
                img_w=cfg.img_w,
                tile_h=cfg.tile_h,
                tile_w=cfg.tile_w,
                k_per_tile=cfg.k_per_tile,
                bg=bg,
                backend=cfg.backend,
                binning=cfg.binning,
            )
            return img

        b_local = cams.fx.shape[0]
        if b_local == 1:  # single local view: no batching wrapper at all
            return one(P.Camera(*[x[0] for x in cams]))[None]
        if batch_mode == "map":
            return jax.lax.map(one, cams)
        return jax.vmap(one)(cams)

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(
            G.GaussianModel(*([PS(model_axis)] * 5)),
            P.Camera(*([PS(data_axes)] * 5)),
        ),
        out_specs=PS(data_axes),
        check_vma=False,
    )
    return jax.jit(fn)
