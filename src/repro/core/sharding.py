"""Distribution primitives for Grendel-style 3D-GS training on a TPU mesh.

Mapping (see DESIGN.md §5):
  - Gaussians sharded over mesh axis ``model``  (Grendel: "each GPU holds a
    shard of the global point cloud and Gaussian parameters").
  - Training views sharded over mesh axis ``data`` (and ``pod`` when present).
  - Within one view, horizontal pixel strips sharded over ``model`` — so every
    device owns both a Gaussian shard and a pixel block, exactly Grendel's
    worker model, expressed on a 2D mesh.

Communication per step (all JAX-native collectives inside shard_map):
  all_gather(projected splats, "model")   owner shard -> renderers (11 floats
                                          per Gaussian, not the full 3D state)
  psum_scatter(splat grads, "model")      renderers -> owner shard (implicit:
                                          this is just the autodiff transpose
                                          of the all_gather)
  psum(packed param grads, "data")        the paper's fused all-reduce
  ppermute(strip halos, "model")          distributed SSIM boundary exchange
"""
from __future__ import annotations

import inspect

import jax
import jax.numpy as jnp


def _resolve_shard_map():
    """jax.shard_map (jax >= 0.6) with fallback to the experimental module."""
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    return sm


_SHARD_MAP = _resolve_shard_map()
_SHARD_MAP_PARAMS = frozenset(inspect.signature(_SHARD_MAP).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None, **kwargs):
    """Version-compat ``shard_map``.

    Newer jax exposes ``jax.shard_map`` with a ``check_vma`` kwarg; jax 0.4.x
    only has ``jax.experimental.shard_map.shard_map`` whose equivalent kwarg
    is ``check_rep``. Unknown kwargs are dropped rather than crashing.
    """
    if check_vma is not None:
        if "check_vma" in _SHARD_MAP_PARAMS:
            kwargs["check_vma"] = check_vma
        elif "check_rep" in _SHARD_MAP_PARAMS:
            kwargs["check_rep"] = check_vma
    kwargs = {k: v for k, v in kwargs.items() if k in _SHARD_MAP_PARAMS}
    return _SHARD_MAP(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)


def axis_size(axis_name: str) -> int:
    """Static mesh-axis size inside shard_map (jax.lax.axis_size is new in
    jax 0.6; psum of a literal 1 constant-folds to the size on 0.4.x)."""
    ax = getattr(jax.lax, "axis_size", None)
    if ax is not None:
        return ax(axis_name)
    return jax.lax.psum(1, axis_name)


def halo_exchange_rows(x: jax.Array, halo: int, axis_name: str) -> jax.Array:
    """Extend a (h, W, C) row-strip with `halo` rows from mesh neighbors.

    Workers at the image boundary receive zeros (ppermute semantics), which
    matches zero-padded SAME convolution on the full image.
    """
    n = axis_size(axis_name)
    if n == 1:
        pad = jnp.zeros((halo,) + x.shape[1:], x.dtype)
        return jnp.concatenate([pad, x, pad], axis=0)
    # worker i's top rows go to worker i-1 (they sit just below i-1's strip)
    below = jax.lax.ppermute(x[:halo], axis_name, [(i, i - 1) for i in range(1, n)])
    # worker i's bottom rows go to worker i+1 (just above i+1's strip)
    above = jax.lax.ppermute(x[-halo:], axis_name, [(i, i + 1) for i in range(n - 1)])
    return jnp.concatenate([above, x, below], axis=0)


def _window(size: int = 11, sigma: float = 1.5) -> jax.Array:
    x = jnp.arange(size, dtype=jnp.float32) - (size - 1) / 2.0
    g = jnp.exp(-(x**2) / (2 * sigma**2))
    g = g / jnp.sum(g)
    return jnp.outer(g, g)


def ssim_l1_sums(
    pred: jax.Array,   # (h, W, 3) local pixel strip
    gt: jax.Array,     # (h, W, 3)
    axis_name: str | None,
    *,
    window_size: int = 11,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Local (ssim_map_sum, l1_sum, pixel_count) for the distributed loss.

    When ``axis_name`` is given, the strip is extended with neighbor halos so
    the result psum'd across workers is *bit-identical in exact arithmetic*
    to single-device SAME-padded SSIM over the full image.
    """
    halo = window_size // 2
    stack = jnp.concatenate(
        [pred, gt, pred * pred, gt * gt, pred * gt], axis=-1
    )  # (h, W, 15)
    if axis_name is not None:
        ext = halo_exchange_rows(stack, halo, axis_name)
    else:
        pad = jnp.zeros((halo,) + stack.shape[1:], stack.dtype)
        ext = jnp.concatenate([pad, stack, pad], axis=0)
    # zero-pad W (SAME behavior), VALID conv over the extended strip
    ext = jnp.pad(ext, ((0, 0), (halo, halo), (0, 0)))
    w = _window(window_size)
    # depthwise: run each of the 15 stat channels independently
    y = jax.lax.conv_general_dilated(
        jnp.moveaxis(ext, -1, 0)[None],  # (1,15,h+2p,W+2p)
        jnp.tile(w[None, None], (15, 1, 1, 1)),  # (15,1,k,k)
        (1, 1),
        "VALID",
        feature_group_count=15,
    )[0]  # (15, h, W)
    mu0, mu1 = y[0:3], y[3:6]
    e00, e11, e01 = y[6:9], y[9:12], y[12:15]
    s00 = e00 - mu0 * mu0
    s11 = e11 - mu1 * mu1
    s01 = e01 - mu0 * mu1
    c1, c2 = 0.01**2, 0.03**2
    ssim_map = ((2 * mu0 * mu1 + c1) * (2 * s01 + c2)) / ((mu0 * mu0 + mu1 * mu1 + c1) * (s00 + s11 + c2))
    l1_sum = jnp.sum(jnp.abs(pred - gt))
    count = jnp.asarray(pred.size, jnp.float32)
    return jnp.sum(ssim_map), l1_sum, count


def distributed_gs_loss(
    pred: jax.Array,
    gt: jax.Array,
    *,
    lam: float = 0.2,
    strip_axis: str | None = None,
    reduce_axes: tuple[str, ...] = (),
) -> jax.Array:
    """(1-lam)*L1 + lam*D-SSIM over globally distributed pixels.

    ``pred``/``gt``: (B_local, h_local, W, 3). Returns the *global* scalar
    loss (replicated) — psum over ``reduce_axes``.
    """
    def per_view(p, g):
        return ssim_l1_sums(p, g, strip_axis)

    ssim_s, l1_s, cnt = jax.vmap(per_view)(pred, gt)
    ssim_s, l1_s, cnt = jnp.sum(ssim_s), jnp.sum(l1_s), jnp.sum(cnt)
    if reduce_axes:
        ssim_s = jax.lax.psum(ssim_s, reduce_axes)
        l1_s = jax.lax.psum(l1_s, reduce_axes)
        cnt = jax.lax.psum(cnt, reduce_axes)
    mean_ssim = ssim_s / cnt
    mean_l1 = l1_s / cnt
    return (1.0 - lam) * mean_l1 + lam * (1.0 - mean_ssim) / 2.0
