"""EWA projection of 3D Gaussians to screen-space splats.

Produces the packed splat representation that the distributed pipeline
communicates between Gaussian-owner shards and pixel-renderer shards.
This is the key data-volume insight adapted from Grendel-GS: the projected
2D state (PACKED_DIM=11 floats) is what crosses the interconnect, not the
full 3D parameter state (11 + 3K·floats with SH).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import gaussians as G

# Packed splat layout (dim PACKED_DIM along last axis)
MX, MY, CA, CB, CC, OP, CR, CG, CB_, DEPTH, RAD = range(11)
PACKED_DIM = 11


class Camera(NamedTuple):
    """Pinhole camera. All leaves are arrays so cameras batch/vmap cleanly."""

    viewmat: jax.Array  # (4,4) world -> camera
    fx: jax.Array       # ()
    fy: jax.Array       # ()
    cx: jax.Array       # ()
    cy: jax.Array       # ()

    @property
    def campos(self) -> jax.Array:
        R = self.viewmat[:3, :3]
        t = self.viewmat[:3, 3]
        return -R.T @ t


def look_at_camera(eye, target, up, fx, fy, cx, cy) -> Camera:
    eye = jnp.asarray(eye, jnp.float32)
    target = jnp.asarray(target, jnp.float32)
    up = jnp.asarray(up, jnp.float32)
    fwd = target - eye
    fwd = fwd / (jnp.linalg.norm(fwd) + 1e-12)
    right = jnp.cross(fwd, up)
    right = right / (jnp.linalg.norm(right) + 1e-12)
    down = jnp.cross(fwd, right)  # camera +y points down (image convention)
    R = jnp.stack([right, down, fwd], axis=0)  # world -> cam rows
    t = -R @ eye
    viewmat = jnp.eye(4, dtype=jnp.float32).at[:3, :3].set(R).at[:3, 3].set(t)
    return Camera(viewmat, jnp.float32(fx), jnp.float32(fy), jnp.float32(cx), jnp.float32(cy))


def project(
    g: G.GaussianModel,
    cam: Camera,
    *,
    near: float = 0.01,
    blur: float = 0.3,
    max_radius: float = 1e4,
) -> jax.Array:
    """Project all Gaussians for one camera. Returns packed splats (N, 11).

    Invalid (behind-camera) Gaussians get opacity 0, radius 0, depth +inf so a
    depth sort pushes them to the back and compositing ignores them.
    """
    R = cam.viewmat[:3, :3]
    tvec = cam.viewmat[:3, 3]
    p_cam = g.means @ R.T + tvec  # (N,3)
    x, y, z = p_cam[:, 0], p_cam[:, 1], p_cam[:, 2]
    valid = z > near
    zc = jnp.where(valid, z, 1.0)  # avoid div-by-0 in dead lanes

    mean_x = cam.fx * x / zc + cam.cx
    mean_y = cam.fy * y / zc + cam.cy

    # EWA: cov2d = J W cov3d W^T J^T (+ low-pass blur)
    cov3d = G.covariance3d(g)  # (N,3,3)
    inv_z = 1.0 / zc
    inv_z2 = inv_z * inv_z
    # J rows: d(u)/d(p_cam), d(v)/d(p_cam)
    J = jnp.zeros((g.n, 2, 3), jnp.float32)
    J = J.at[:, 0, 0].set(cam.fx * inv_z)
    J = J.at[:, 0, 2].set(-cam.fx * x * inv_z2)
    J = J.at[:, 1, 1].set(cam.fy * inv_z)
    J = J.at[:, 1, 2].set(-cam.fy * y * inv_z2)
    JW = J @ R  # (N,2,3)
    cov2d = JW @ cov3d @ jnp.swapaxes(JW, -1, -2)  # (N,2,2)
    a = cov2d[:, 0, 0] + blur
    b = cov2d[:, 0, 1]
    c = cov2d[:, 1, 1] + blur

    det = a * c - b * b
    det = jnp.maximum(det, 1e-12)
    inv_det = 1.0 / det
    conic_a = c * inv_det
    conic_b = -b * inv_det
    conic_c = a * inv_det

    mid = 0.5 * (a + c)
    lam1 = mid + jnp.sqrt(jnp.maximum(mid * mid - det, 0.0))
    radius = jnp.minimum(jnp.ceil(3.0 * jnp.sqrt(jnp.maximum(lam1, 0.0))), max_radius)

    opac = G.opacities(g)
    dirs = g.means - cam.campos
    dirs = dirs / (jnp.linalg.norm(dirs, axis=-1, keepdims=True) + 1e-12)
    rgb = jnp.clip(G.eval_sh(g.sh, dirs), 0.0, 1.0)

    opac = jnp.where(valid, opac, 0.0)
    radius = jnp.where(valid, radius, 0.0)
    depth = jnp.where(valid, z, jnp.inf)

    packed = jnp.stack(
        [mean_x, mean_y, conic_a, conic_b, conic_c, opac, rgb[:, 0], rgb[:, 1], rgb[:, 2], depth, radius],
        axis=-1,
    )
    return packed


def sort_by_depth(packed: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Depth-sort packed splats front-to-back. Returns (sorted_packed, order).

    The ordering is treated as non-differentiable (as in the CUDA 3D-GS
    rasterizer): gradients flow through the gathered values, not the order.
    """
    order = jnp.argsort(jax.lax.stop_gradient(packed[:, DEPTH]))
    return packed[order], order
