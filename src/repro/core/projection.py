"""EWA projection of 3D Gaussians to screen-space splats.

Produces the packed splat representation that the distributed pipeline
communicates between Gaussian-owner shards and pixel-renderer shards.
This is the key data-volume insight adapted from Grendel-GS: the projected
2D state (PACKED_DIM=11 floats) is what crosses the interconnect, not the
full 3D parameter state (11 + 3K·floats with SH).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gaussians as G

# Packed splat layout (dim PACKED_DIM along last axis)
MX, MY, CA, CB, CC, OP, CR, CG, CB_, DEPTH, RAD = range(11)
PACKED_DIM = 11


class Camera(NamedTuple):
    """Pinhole camera. All leaves are arrays so cameras batch/vmap cleanly."""

    viewmat: jax.Array  # (4,4) world -> camera
    fx: jax.Array       # ()
    fy: jax.Array       # ()
    cx: jax.Array       # ()
    cy: jax.Array       # ()

    @property
    def campos(self) -> jax.Array:
        R = self.viewmat[:3, :3]
        t = self.viewmat[:3, 3]
        return -R.T @ t


def look_at_camera(eye, target, up, fx, fy, cx, cy) -> Camera:
    eye = jnp.asarray(eye, jnp.float32)
    target = jnp.asarray(target, jnp.float32)
    up = jnp.asarray(up, jnp.float32)
    fwd = target - eye
    fwd = fwd / (jnp.linalg.norm(fwd) + 1e-12)
    right = jnp.cross(fwd, up)
    right = right / (jnp.linalg.norm(right) + 1e-12)
    down = jnp.cross(fwd, right)  # camera +y points down (image convention)
    R = jnp.stack([right, down, fwd], axis=0)  # world -> cam rows
    t = -R @ eye
    viewmat = jnp.eye(4, dtype=jnp.float32).at[:3, :3].set(R).at[:3, 3].set(t)
    return Camera(viewmat, jnp.float32(fx), jnp.float32(fy), jnp.float32(cx), jnp.float32(cy))


def project(
    g: G.GaussianModel,
    cam: Camera,
    *,
    near: float = 0.01,
    blur: float = 0.3,
    max_radius: float = 1e4,
) -> jax.Array:
    """Project all Gaussians for one camera. Returns packed splats (N, 11).

    Invalid (behind-camera) Gaussians get opacity 0, radius 0, depth +inf so a
    depth sort pushes them to the back and compositing ignores them.
    """
    R = cam.viewmat[:3, :3]
    tvec = cam.viewmat[:3, 3]
    p_cam = g.means @ R.T + tvec  # (N,3)
    x, y, z = p_cam[:, 0], p_cam[:, 1], p_cam[:, 2]
    valid = z > near
    zc = jnp.where(valid, z, 1.0)  # avoid div-by-0 in dead lanes

    mean_x = cam.fx * x / zc + cam.cx
    mean_y = cam.fy * y / zc + cam.cy

    # EWA: cov2d = J W cov3d W^T J^T (+ low-pass blur)
    cov3d = G.covariance3d(g)  # (N,3,3)
    inv_z = 1.0 / zc
    inv_z2 = inv_z * inv_z
    # J rows: d(u)/d(p_cam), d(v)/d(p_cam)
    J = jnp.zeros((g.n, 2, 3), jnp.float32)
    J = J.at[:, 0, 0].set(cam.fx * inv_z)
    J = J.at[:, 0, 2].set(-cam.fx * x * inv_z2)
    J = J.at[:, 1, 1].set(cam.fy * inv_z)
    J = J.at[:, 1, 2].set(-cam.fy * y * inv_z2)
    JW = J @ R  # (N,2,3)
    cov2d = JW @ cov3d @ jnp.swapaxes(JW, -1, -2)  # (N,2,2)
    a = cov2d[:, 0, 0] + blur
    b = cov2d[:, 0, 1]
    c = cov2d[:, 1, 1] + blur

    det = a * c - b * b
    det = jnp.maximum(det, 1e-12)
    inv_det = 1.0 / det
    conic_a = c * inv_det
    conic_b = -b * inv_det
    conic_c = a * inv_det

    mid = 0.5 * (a + c)
    lam1 = mid + jnp.sqrt(jnp.maximum(mid * mid - det, 0.0))
    radius = jnp.minimum(jnp.ceil(3.0 * jnp.sqrt(jnp.maximum(lam1, 0.0))), max_radius)

    opac = G.opacities(g)
    dirs = g.means - cam.campos
    dirs = dirs / (jnp.linalg.norm(dirs, axis=-1, keepdims=True) + 1e-12)
    rgb = jnp.clip(G.eval_sh(g.sh, dirs), 0.0, 1.0)

    opac = jnp.where(valid, opac, 0.0)
    radius = jnp.where(valid, radius, 0.0)
    depth = jnp.where(valid, z, jnp.inf)

    packed = jnp.stack(
        [mean_x, mean_y, conic_a, conic_b, conic_c, opac, rgb[:, 0], rgb[:, 1], rgb[:, 2], depth, radius],
        axis=-1,
    )
    return packed


def project_bounds_np(
    g: G.GaussianModel,
    cam: Camera,
    idx: np.ndarray | None = None,
    *,
    near: float = 0.01,
    blur: float = 0.3,
    max_radius: float = 1e4,
    rel_pad: float = 1e-3,
    pad_px: float = 1.0,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Conservative host-side screen bounds for a subset of Gaussians.

    Float64 numpy mirror of :func:`project`'s (mean_x, mean_y, radius) math
    — the only splat quantities tile binning looks at — for world-space
    invalidation: the serving stack maps changed Gaussians to the screen
    tiles they can touch without a device round-trip. Returns ``(mx, my,
    rad)`` with ``rad == 0`` for Gaussians the rasterizer would cull.

    Conservatism, not bit-equality, is the contract: the jitted f32 path
    rounds differently, so every radius is padded by ``rel_pad``
    (relative) plus ``pad_px`` pixels, and the near-plane cut keeps a
    slack band of splats the f32 test might admit. A Gaussian outside the
    padded bound here is guaranteed outside the rasterizer's bound.
    """
    means = np.asarray(g.means, np.float64)
    log_scales = np.asarray(g.log_scales, np.float64)
    quats = np.asarray(g.quats, np.float64)
    if idx is not None:
        sel = np.asarray(idx).reshape(-1)
        means, log_scales, quats = means[sel], log_scales[sel], quats[sel]
    vm = np.asarray(cam.viewmat, np.float64)
    R, tvec = vm[:3, :3], vm[:3, 3]
    p_cam = means @ R.T + tvec
    x, y, z = p_cam[:, 0], p_cam[:, 1], p_cam[:, 2]
    valid = z > near * (1.0 - 1e-4)  # slack: admit what f32 might admit
    zc = np.where(valid, z, 1.0)

    fx = float(np.asarray(cam.fx))
    fy = float(np.asarray(cam.fy))
    mx = fx * x / zc + float(np.asarray(cam.cx))
    my = fy * y / zc + float(np.asarray(cam.cy))

    # world covariance R S S^T R^T (gaussians.quat_to_rotmat / covariance3d)
    q = quats / (np.linalg.norm(quats, axis=-1, keepdims=True) + 1e-12)
    w, qx, qy, qz = q[:, 0], q[:, 1], q[:, 2], q[:, 3]
    rot = np.empty((q.shape[0], 3, 3), np.float64)
    rot[:, 0, 0] = 1 - 2 * (qy * qy + qz * qz)
    rot[:, 0, 1] = 2 * (qx * qy - w * qz)
    rot[:, 0, 2] = 2 * (qx * qz + w * qy)
    rot[:, 1, 0] = 2 * (qx * qy + w * qz)
    rot[:, 1, 1] = 1 - 2 * (qx * qx + qz * qz)
    rot[:, 1, 2] = 2 * (qy * qz - w * qx)
    rot[:, 2, 0] = 2 * (qx * qz - w * qy)
    rot[:, 2, 1] = 2 * (qy * qz + w * qx)
    rot[:, 2, 2] = 1 - 2 * (qx * qx + qy * qy)
    RS = rot * np.exp(log_scales)[:, None, :]
    cov3d = RS @ np.swapaxes(RS, -1, -2)

    inv_z = 1.0 / zc
    J = np.zeros((means.shape[0], 2, 3), np.float64)
    J[:, 0, 0] = fx * inv_z
    J[:, 0, 2] = -fx * x * inv_z * inv_z
    J[:, 1, 1] = fy * inv_z
    J[:, 1, 2] = -fy * y * inv_z * inv_z
    JW = J @ R
    cov2d = JW @ cov3d @ np.swapaxes(JW, -1, -2)
    a = cov2d[:, 0, 0] + blur
    b = cov2d[:, 0, 1]
    c = cov2d[:, 1, 1] + blur
    det = np.maximum(a * c - b * b, 1e-12)
    mid = 0.5 * (a + c)
    lam1 = mid + np.sqrt(np.maximum(mid * mid - det, 0.0))
    rad = np.minimum(np.ceil(3.0 * np.sqrt(np.maximum(lam1, 0.0))), max_radius)
    rad = rad * (1.0 + rel_pad) + pad_px
    rad = np.where(valid, rad, 0.0)
    return mx, my, rad


def sort_by_depth(packed: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Depth-sort packed splats front-to-back. Returns (sorted_packed, order).

    The ordering is treated as non-differentiable (as in the CUDA 3D-GS
    rasterizer): gradients flow through the gathered values, not the order.
    """
    order = jnp.argsort(jax.lax.stop_gradient(packed[:, DEPTH]))
    return packed[order], order
