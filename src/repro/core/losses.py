"""Training losses and image-quality metrics (the paper's metric stack).

- L1 + D-SSIM training loss with lambda=0.2 (3D-GS defaults, used by both
  Sewell et al. and the paper).
- PSNR / SSIM metrics for Tables II-III analogues.
- LPIPS proxy: we cannot ship pretrained VGG weights offline, so we report a
  multi-scale gradient-magnitude perceptual distance ("gmsd_proxy") clearly
  labeled as a proxy in EXPERIMENTS.md.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def l1_loss(pred: jax.Array, target: jax.Array) -> jax.Array:
    return jnp.mean(jnp.abs(pred - target))


def _gaussian_window(size: int = 11, sigma: float = 1.5) -> jax.Array:
    x = jnp.arange(size, dtype=jnp.float32) - (size - 1) / 2.0
    g = jnp.exp(-(x**2) / (2 * sigma**2))
    g = g / jnp.sum(g)
    return jnp.outer(g, g)


def ssim(img0: jax.Array, img1: jax.Array, *, window_size: int = 11) -> jax.Array:
    """SSIM over (H,W,C) images in [0,1]. Matches the standard formulation."""
    c1, c2 = 0.01**2, 0.03**2
    win = _gaussian_window(window_size)[:, :, None, None]  # (k,k,1,1)

    def filt(x):
        # (H,W,C) -> depthwise conv
        x = jnp.moveaxis(x, -1, 0)[:, None]  # (C,1,H,W)
        k = jnp.broadcast_to(jnp.moveaxis(win, (0, 1), (2, 3)), (1, 1, window_size, window_size))
        y = jax.lax.conv_general_dilated(x, k, (1, 1), "SAME")
        return jnp.moveaxis(y[:, 0], 0, -1)

    mu0, mu1 = filt(img0), filt(img1)
    mu00, mu11, mu01 = mu0 * mu0, mu1 * mu1, mu0 * mu1
    s00 = filt(img0 * img0) - mu00
    s11 = filt(img1 * img1) - mu11
    s01 = filt(img0 * img1) - mu01
    num = (2 * mu01 + c1) * (2 * s01 + c2)
    den = (mu00 + mu11 + c1) * (s00 + s11 + c2)
    return jnp.mean(num / den)


def dssim(img0: jax.Array, img1: jax.Array) -> jax.Array:
    return (1.0 - ssim(img0, img1)) / 2.0


def gs_loss(pred: jax.Array, target: jax.Array, *, lam: float = 0.2) -> jax.Array:
    """(1-lam)*L1 + lam*D-SSIM — the 3D-GS training loss used in the paper."""
    return (1.0 - lam) * l1_loss(pred, target) + lam * dssim(pred, target)


def psnr(pred: jax.Array, target: jax.Array) -> jax.Array:
    mse = jnp.mean((pred - target) ** 2)
    return -10.0 * jnp.log10(jnp.maximum(mse, 1e-12))


def _grad_mag(img: jax.Array) -> jax.Array:
    g = jnp.mean(img, axis=-1)
    gx = g[:, 1:] - g[:, :-1]
    gy = g[1:, :] - g[:-1, :]
    return jnp.sqrt(gx[:-1, :] ** 2 + gy[:, :-1] ** 2 + 1e-12)


def lpips_proxy(img0: jax.Array, img1: jax.Array, *, scales: int = 3) -> jax.Array:
    """Multi-scale gradient-magnitude dissimilarity in [0,~1] (LPIPS stand-in).

    NOT LPIPS — a deterministic perceptual-distance proxy usable offline.
    Lower is better, like LPIPS; reported as `lpips_proxy` everywhere.
    """
    total = 0.0
    a, b = img0, img1
    for _ in range(scales):
        ga, gb = _grad_mag(a), _grad_mag(b)
        c = 0.0026
        sim = (2 * ga * gb + c) / (ga * ga + gb * gb + c)
        total = total + (1.0 - jnp.mean(sim))
        if min(a.shape[0], a.shape[1]) >= 4:
            a = 0.25 * (a[0::2, 0::2] + a[1::2, 0::2] + a[0::2, 1::2] + a[1::2, 1::2])
            b = 0.25 * (b[0::2, 0::2] + b[1::2, 0::2] + b[0::2, 1::2] + b[1::2, 1::2])
    return total / scales
