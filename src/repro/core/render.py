"""Differentiable render pipeline: project -> sort -> tile-bin -> composite.

The tile-binning step is the TPU adaptation of the CUDA duplicate+radix-sort
binning in 3D-GS/Grendel-GS: instead of data-dependent duplication, every
tile keeps the front-most K overlapping splats (fixed capacity), built with a
memory-bounded running top-K scan so it scales to millions of Gaussians.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import gaussians as G
from repro.core import projection as P
from repro.kernels.tile_raster import ops as raster_ops

BIG_IDX = jnp.iinfo(jnp.int32).max


@partial(jax.jit, static_argnames=("img_h", "img_w", "tile_h", "tile_w", "k_per_tile", "chunk"))
def build_tile_lists(
    packed_sorted: jax.Array,  # (N, 11) depth-sorted splats
    *,
    img_h: int,
    img_w: int,
    tile_h: int = 16,
    tile_w: int = 16,
    k_per_tile: int = 256,
    chunk: int = 2048,
    row_offset: int = 0,
) -> tuple[jax.Array, jax.Array]:
    """Per-tile front-most-K overlapping splat lists.

    Overlap test: splat bounding circle (mean, radius) vs tile rectangle.
    Because input is depth-sorted, the K smallest overlapping indices are the
    K front-most splats — exactly what front-to-back compositing needs.

    ``row_offset`` shifts tile origins vertically: pixel-parallel workers
    rendering a horizontal strip pass their strip's first image row.

    Returns (idx (T,K) int32 clamped to valid range, valid (T,K) bool).
    """
    n = packed_sorted.shape[0]
    tiles_y = img_h // tile_h
    tiles_x = img_w // tile_w
    t_count = tiles_y * tiles_x

    tids = jnp.arange(t_count)
    tx0 = (tids % tiles_x) * tile_w
    ty0 = (tids // tiles_x) * tile_h + row_offset
    tx1 = tx0 + tile_w
    ty1 = ty0 + tile_h

    pad = (-n) % chunk
    mx = jnp.pad(packed_sorted[:, P.MX], (0, pad))
    my = jnp.pad(packed_sorted[:, P.MY], (0, pad))
    rad = jnp.pad(packed_sorted[:, P.RAD], (0, pad))  # pad radius 0 -> never overlaps
    n_chunks = mx.shape[0] // chunk

    def step(carry, ci):
        best = carry  # (T, K) ascending candidate indices (BIG_IDX = empty)
        sl = ci * chunk
        cmx = jax.lax.dynamic_slice_in_dim(mx, sl, chunk)
        cmy = jax.lax.dynamic_slice_in_dim(my, sl, chunk)
        crad = jax.lax.dynamic_slice_in_dim(rad, sl, chunk)
        overlap = (
            (cmx[None, :] + crad[None, :] >= tx0[:, None])
            & (cmx[None, :] - crad[None, :] <= tx1[:, None])
            & (cmy[None, :] + crad[None, :] >= ty0[:, None])
            & (cmy[None, :] - crad[None, :] <= ty1[:, None])
            & (crad[None, :] > 0)
        )  # (T, chunk)
        cand = jnp.where(overlap, sl + jnp.arange(chunk)[None, :], BIG_IDX)
        merged = jnp.sort(jnp.concatenate([best, cand], axis=1), axis=1)[:, : best.shape[1]]
        return merged, None

    init = jnp.full((t_count, k_per_tile), BIG_IDX, jnp.int32)
    best, _ = jax.lax.scan(step, init, jnp.arange(n_chunks))
    valid = best != BIG_IDX
    idx = jnp.where(valid, best, 0)
    return idx, valid


@partial(
    jax.jit,
    static_argnames=("img_h", "img_w", "tile_h", "tile_w", "k_per_tile", "block", "k_block_mult", "chunk"),
)
def build_tile_lists_hier(
    packed_sorted: jax.Array,
    *,
    img_h: int,
    img_w: int,
    tile_h: int = 16,
    tile_w: int = 16,
    k_per_tile: int = 256,
    block: int = 8,
    k_block_mult: int = 4,
    chunk: int = 4096,
    row_offset: int = 0,
) -> tuple[jax.Array, jax.Array]:
    """Two-level tile binning (§Perf GS iteration: beyond-paper).

    Flat binning tests every (tile, splat) pair — O(T*N) bytes, the dominant
    memory term at 2048px/4M+ splats. Level 1 bins splats into coarse
    (block x block)-tile superblocks (O(T/block^2 * N)); level 2 tests each
    tile only against its block's K1 = k_block_mult*K front candidates
    (O(T * K1)). A splat overlapping a tile always overlaps its block, so
    with adequate K1 the result is identical to flat binning (tested).
    """
    tiles_y = img_h // tile_h
    tiles_x = img_w // tile_w
    by = max(min(block, tiles_y), 1)
    bx = max(min(block, tiles_x), 1)
    assert tiles_y % by == 0 and tiles_x % bx == 0, (tiles_y, tiles_x, by, bx)
    k1 = k_per_tile * k_block_mult

    idx1, valid1 = build_tile_lists(
        packed_sorted,
        img_h=img_h,
        img_w=img_w,
        tile_h=tile_h * by,
        tile_w=tile_w * bx,
        k_per_tile=k1,
        chunk=chunk,
        row_offset=row_offset,
    )  # (Tb, K1) ascending (= front-to-back) within each block
    blocks_x = tiles_x // bx
    cand = packed_sorted[idx1]  # (Tb, K1, 11)
    cand_mx = jnp.where(valid1, cand[..., P.MX], jnp.inf)
    cand_my = jnp.where(valid1, cand[..., P.MY], jnp.inf)
    cand_rad = jnp.where(valid1, cand[..., P.RAD], 0.0)

    def per_block(bid, mx, my, rad, gidx):
        # tile rectangles of this block
        t_local = jnp.arange(by * bx)
        ty = (bid // blocks_x) * by + t_local // bx
        tx = (bid % blocks_x) * bx + t_local % bx
        x0 = (tx * tile_w).astype(jnp.float32)
        y0 = (ty * tile_h + row_offset).astype(jnp.float32)
        overlap = (
            (mx[None, :] + rad[None, :] >= x0[:, None])
            & (mx[None, :] - rad[None, :] <= (x0 + tile_w)[:, None])
            & (my[None, :] + rad[None, :] >= y0[:, None])
            & (my[None, :] - rad[None, :] <= (y0 + tile_h)[:, None])
            & (rad[None, :] > 0)
        )  # (tiles_in_block, K1)
        score = jnp.where(overlap, jnp.arange(k1)[None, :], k1)
        sel = jnp.sort(score, axis=1)[:, :k_per_tile]        # front-most K
        ok = sel < k1
        sel = jnp.where(ok, sel, 0)
        return gidx[sel], ok

    tile_idx, tile_valid = jax.vmap(per_block)(
        jnp.arange(idx1.shape[0]), cand_mx, cand_my, cand_rad, idx1
    )  # (Tb, tiles_in_block, K)
    # reorder (block-major) -> row-major flat tile order
    blocks_y = tiles_y // by
    tile_idx = (
        tile_idx.reshape(blocks_y, blocks_x, by, bx, k_per_tile)
        .transpose(0, 2, 1, 3, 4)
        .reshape(tiles_y * tiles_x, k_per_tile)
    )
    tile_valid = (
        tile_valid.reshape(blocks_y, blocks_x, by, bx, k_per_tile)
        .transpose(0, 2, 1, 3, 4)
        .reshape(tiles_y * tiles_x, k_per_tile)
    )
    return tile_idx, tile_valid


def render_packed(
    packed_sorted: jax.Array,
    *,
    img_h: int,
    img_w: int,
    tile_h: int = 16,
    tile_w: int = 16,
    k_per_tile: int = 256,
    bg: jax.Array | None = None,
    backend: str = "ref",
    row_offset: int = 0,
    binning: str = "auto",
) -> tuple[jax.Array, jax.Array]:
    """Rasterize depth-sorted packed splats to an (img_h, img_w, 3) image."""
    if bg is None:
        bg = jnp.zeros((3,), jnp.float32)
    tiles = (img_h // tile_h) * (img_w // tile_w)
    if binning == "auto":
        binning = "hier" if tiles >= 256 else "flat"
    if binning == "hier":
        idx, valid = build_tile_lists_hier(
            packed_sorted,
            img_h=img_h,
            img_w=img_w,
            tile_h=tile_h,
            tile_w=tile_w,
            k_per_tile=k_per_tile,
            row_offset=row_offset,
        )
    else:
        idx, valid = build_tile_lists(
            packed_sorted,
            img_h=img_h,
            img_w=img_w,
            tile_h=tile_h,
            tile_w=tile_w,
            k_per_tile=k_per_tile,
            row_offset=row_offset,
        )
    return raster_ops.rasterize_tiles(
        packed_sorted,
        idx,
        valid,
        img_h=img_h,
        img_w=img_w,
        tile_h=tile_h,
        tile_w=tile_w,
        bg=bg,
        backend=backend,
        row_offset=row_offset,
    )


def render(
    g: G.GaussianModel,
    cam: P.Camera,
    *,
    img_h: int,
    img_w: int,
    tile_h: int = 16,
    tile_w: int = 16,
    k_per_tile: int = 256,
    bg: jax.Array | None = None,
    backend: str = "ref",
    binning: str = "auto",
) -> tuple[jax.Array, jax.Array]:
    """End-to-end single-device render of a GaussianModel from one camera."""
    packed = P.project(g, cam)
    packed_sorted, _ = P.sort_by_depth(packed)
    return render_packed(
        packed_sorted,
        img_h=img_h,
        img_w=img_w,
        tile_h=tile_h,
        tile_w=tile_w,
        k_per_tile=k_per_tile,
        bg=bg,
        backend=backend,
        binning=binning,
    )
