"""Device-memory watermarks: per-device bytes-in-use / peak, backend-portable.

Miranda-scale capacity failures (18M Gaussians on one A100) announce
themselves as a slow climb of device bytes across stream timesteps — but only
if someone is sampling. This module gives the training loop one call that
works on every backend:

``sample()`` asks each device for ``memory_stats()`` (GPU/TPU runtimes report
``bytes_in_use`` and ``peak_bytes_in_use``) and, where the backend has no
allocator stats (CPU hosts report ``None``), falls back to **live-array
accounting**: every ``jax.live_arrays()`` buffer is attributed to the devices
its shards live on, so the number still means "bytes this process holds on
that device" — it just can't see allocator fragmentation or peak watermarks,
which is why the sample carries its ``source``.

``record()`` lands the sample on a ``MetricsRegistry`` under
``train.devmem.*`` gauges (per-device ``bytes.<dev>`` / ``peak.<dev>`` plus
cross-device maxima), the shape the per-timestep telemetry and the
``BENCH_insitu.json`` record consume.
"""
from __future__ import annotations

__all__ = ["DeviceMemSample", "sample", "record"]

import dataclasses


@dataclasses.dataclass
class DeviceMemSample:
    """One point-in-time reading across the local devices."""

    bytes_in_use: dict   # {device label: bytes currently held}
    peak_bytes: dict     # {device label: peak bytes} (empty under fallback)
    source: str          # "memory_stats" | "live_arrays"

    @property
    def max_bytes(self) -> int:
        return max(self.bytes_in_use.values(), default=0)

    @property
    def max_peak(self) -> int:
        return max(self.peak_bytes.values(), default=0)

    def to_dict(self) -> dict:
        return {
            "source": self.source,
            "bytes_in_use": dict(self.bytes_in_use),
            "peak_bytes": dict(self.peak_bytes),
            "max_bytes": self.max_bytes,
            "max_peak": self.max_peak,
        }


def _label(dev) -> str:
    return f"{dev.platform}{dev.id}"


def sample(devices=None) -> DeviceMemSample:
    """Read current device-memory occupancy for ``devices`` (default: all
    local devices). Never raises on a stats-less backend — it degrades to
    live-array accounting and says so in ``source``."""
    import jax

    if devices is None:
        devices = jax.local_devices()
    in_use: dict[str, int] = {}
    peak: dict[str, int] = {}
    missing = []
    for dev in devices:
        stats = None
        try:
            stats = dev.memory_stats()
        except Exception:  # analysis: allow(hygiene.broad_except, backend without allocator stats raises backend-specific types; degrades to live-array accounting, reported in sample.source)
            stats = None
        if stats and "bytes_in_use" in stats:
            in_use[_label(dev)] = int(stats["bytes_in_use"])
            if "peak_bytes_in_use" in stats:
                peak[_label(dev)] = int(stats["peak_bytes_in_use"])
        else:
            missing.append(dev)
    if not missing:
        return DeviceMemSample(in_use, peak, "memory_stats")

    # fallback: attribute every live buffer to the devices its shards occupy
    want = {_label(d): 0 for d in missing}
    for arr in jax.live_arrays():
        try:
            shards = arr.addressable_shards
        except Exception:  # analysis: allow(hygiene.broad_except, deleted/donated buffers race the live_arrays walk with backend-specific errors; skipping undercounts one sample)
            continue
        for shard in shards:
            label = _label(shard.device)
            if label in want:
                data = shard.data
                want[label] += int(data.size * data.dtype.itemsize)
    in_use.update(want)
    return DeviceMemSample(in_use, peak, "live_arrays")


def record(metrics, smp: DeviceMemSample | None = None, *, prefix: str = "train.devmem") -> DeviceMemSample:  # analysis: declare(train.devmem.*)
    """Sample (unless one is passed) and land it on ``metrics`` as gauges:
    ``<prefix>.bytes.<dev>``, ``<prefix>.peak.<dev>``, plus the cross-device
    ``<prefix>.max_bytes`` / ``<prefix>.max_peak`` watermarks."""
    if smp is None:
        smp = sample()
    for dev, b in smp.bytes_in_use.items():
        metrics.gauge(f"{prefix}.bytes.{dev}").set(int(b))
    for dev, b in smp.peak_bytes.items():
        metrics.gauge(f"{prefix}.peak.{dev}").set(int(b))
    metrics.gauge(f"{prefix}.max_bytes").set(smp.max_bytes)
    if smp.peak_bytes:
        metrics.gauge(f"{prefix}.max_peak").set(smp.max_peak)
    return smp
