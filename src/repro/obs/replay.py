"""Trace-driven replay, part 1: load span JSONL and fit a stage cost model.

Input is the ``--trace-out`` export format (see ``obs.export``): one span per
line, joined into per-request trees by ``rid``. From one trace this module
extracts the two things a what-if simulation needs:

**The arrival timeline** — every request's admit time (relative to the first
admit), session, stream/timestep, and its *recorded cache outcome* (``miss``
/ ``full_hit`` / ``cache_hit`` / ``partial_hit`` / ``dedup`` / ``shed``).
Replaying the *recorded* arrivals (instead of synthesizing Poisson traffic)
is the point: the timeline embeds the real clients' request-ahead pacing,
scrub bursts, and think time, which is exactly what makes knob predictions
transfer back to the stack that produced the trace.

**Stage cost distributions** — empirical duration samples per pipeline
stage. The one subtle fit is device render cost: under ``pipeline_depth >=
2`` consecutive ``render`` spans *overlap* (batch N+1 dispatches while batch
N is still on device), so raw span durations double-count device time.
Batch events are therefore reduced to **exclusive** service time — sorted by
dispatch, each batch is charged ``t1 - max(t0, busy_until)`` — mirroring how
the server's own ``render_s`` counter accounts pipelined waves. Batch cost
is then fit as ``a + b * batch_size`` (least squares) when the trace covers
more than one batch size, with the empirical per-size scatter kept so the
simulator can replay realistic variance rather than a flat mean.

The fit is pure arithmetic over sorted inputs — no RNG — so the same trace
always yields the same model, and ``fingerprint()`` (sha1 of the canonical
JSON form) is the identity the autotuner stamps on its recommendations.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json

from repro.obs.export import validate_trace_jsonl
from repro.obs.trace import TRAIN_STAGES

__all__ = [
    "load_trace",
    "build_trees",
    "fit",
    "fit_trace",
    "train_stage_breakdown",
    "CostModel",
    "StageDist",
    "OUTCOMES",
]

# recorded submit outcomes; "shed" comes from the shed span, "unknown" marks
# a request whose tree lost its submit span (ring overwrite / truncation)
OUTCOMES = ("miss", "full_hit", "cache_hit", "partial_hit", "dedup", "shed", "unknown")

# outcomes that resolve without a device batch
HIT_OUTCOMES = frozenset({"full_hit", "cache_hit", "dedup"})


def load_trace(source: str) -> tuple[dict, list[dict]]:
    """Load a span JSONL trace from a path (or raw JSONL text — anything
    containing a newline or brace is treated as text). Validates the
    contract first; returns ``(meta, records)`` where ``meta`` is the
    ``trace_meta`` header (possibly empty) and each record is one span
    dict."""
    if "\n" in source or source.lstrip().startswith("{"):
        text = source
    else:
        with open(source) as f:
            text = f.read()
    check = validate_trace_jsonl(text)  # raises on any malformed line
    records = []
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        obj = json.loads(line)
        if "trace_meta" in obj:
            continue
        records.append(obj)
    return dict(check.meta), records


def build_trees(records: list[dict]) -> dict[int, dict[str, list[dict]]]:
    """Group spans into per-request trees: ``{rid: {stage: [span, ...]}}``,
    spans within a stage ordered by t0."""
    trees: dict[int, dict[str, list[dict]]] = {}
    for r in sorted(records, key=lambda r: (r["t0"], r["t1"])):
        trees.setdefault(r["rid"], {}).setdefault(r["span"], []).append(r)
    return trees


@dataclasses.dataclass
class StageDist:
    """Empirical duration distribution for one stage (seconds, sorted)."""

    samples: list

    @property
    def count(self) -> int:
        return len(self.samples)

    @property
    def mean(self) -> float:
        return sum(self.samples) / len(self.samples) if self.samples else 0.0

    def percentile(self, q: float) -> float:
        if not self.samples:
            return 0.0
        idx = min(int(q / 100.0 * len(self.samples)), len(self.samples) - 1)
        return self.samples[idx]

    def sample(self, rng) -> float:
        """One draw from the empirical distribution (deterministic under a
        seeded rng); 0 when the trace never exercised this stage."""
        if not self.samples:
            return 0.0
        return self.samples[rng.randrange(len(self.samples))]

    def to_dict(self) -> dict:
        return {
            "count": self.count,
            "mean_ms": round(self.mean * 1e3, 6),
            "p50_ms": round(self.percentile(50) * 1e3, 6),
            "p99_ms": round(self.percentile(99) * 1e3, 6),
            "samples": [round(s, 9) for s in self.samples],
        }


def _linear_fit(points: list[tuple[float, float]]) -> tuple[float, float]:
    """Least-squares ``y = a + b x`` (b clamped >= 0; falls back to a flat
    mean when x never varies)."""
    n = len(points)
    xs = [p[0] for p in points]
    ys = [p[1] for p in points]
    mx = sum(xs) / n
    my = sum(ys) / n
    sxx = sum((x - mx) ** 2 for x in xs)
    if sxx <= 1e-12:  # one batch size observed: no slope information
        return my, 0.0
    b = max(sum((x - mx) * (y - my) for x, y in points) / sxx, 0.0)
    return my - b * mx, b


@dataclasses.dataclass
class CostModel:
    """Everything a discrete-event replay needs, fit from one trace."""

    meta: dict                      # trace_meta header (knobs, drop counts)
    arrivals: list                  # [{t, rid, session, stream, timestep,
                                    #   outcome, missing_tiles, bulk}] by t
    batch_sizes: dict               # {batch_size: StageDist of exclusive s}
    batch_fit: tuple                # (a, b): device cost ~= a + b * size
    partial: "StageDist"            # exclusive partial-render (row) jobs
    submit: dict                    # {outcome: StageDist} submit overhead
    host: "StageDist"               # per-request retire + assemble
    encode: "StageDist"             # per-frame wire encode
    write: "StageDist"              # per-frame socket write
    span_count: int = 0

    @property
    def knobs(self) -> dict:
        """The serving-stack configuration that produced the trace (empty
        when the exporter wasn't given any)."""
        return dict(self.meta.get("knobs") or {})

    @property
    def duration_s(self) -> float:
        return self.arrivals[-1]["t"] if self.arrivals else 0.0

    def outcome_mix(self) -> dict:
        mix = dict.fromkeys(OUTCOMES, 0)
        for a in self.arrivals:
            mix[a["outcome"]] += 1
        return {k: v for k, v in mix.items() if v}

    def batch_cost(self, size: int, rng) -> float:
        """Predicted exclusive device cost of one batch of ``size``.

        Mean-field on purpose: the least-squares fit integrates to exactly
        the observed total device time over the recorded batch mix, so
        using it directly keeps aggregate predictions calibrated even when
        per-size scatter is wild (a contended host makes a size-4 batch
        occasionally cost more than a size-8 one — resampling that scatter
        onto a different batch decomposition inflated predictions by 30%+).
        ``rng`` stays in the signature for cost models that do carry
        usable variance."""
        size = max(int(size), 1)
        a, b = self.batch_fit
        if b > 0.0:
            return max(a + b * size, 0.0)
        if not self.batch_sizes:
            return max(a, 0.0)
        # one batch size observed: no slope information — assume half the
        # cost is fixed dispatch overhead and half scales with views (the
        # vmap prior) so a max_batch what-if still moves in a sane direction
        nearest = min(self.batch_sizes, key=lambda s: (abs(s - size), s))
        mean = self.batch_sizes[nearest].mean
        return max(mean * (0.5 + 0.5 * size / nearest), 0.0)

    def to_dict(self) -> dict:
        return {
            "schema": 1,
            "meta": self.meta,
            "span_count": self.span_count,
            "requests": len(self.arrivals),
            "duration_s": round(self.duration_s, 6),
            "outcome_mix": self.outcome_mix(),
            "arrivals": [
                {**a, "t": round(a["t"], 9)} for a in self.arrivals
            ],
            "batch_fit": {
                "base_s": round(self.batch_fit[0], 9),
                "per_view_s": round(self.batch_fit[1], 9),
            },
            "batch_sizes": {
                str(k): v.to_dict() for k, v in sorted(self.batch_sizes.items())
            },
            "stages": {
                "partial": self.partial.to_dict(),
                "host": self.host.to_dict(),
                "encode": self.encode.to_dict(),
                "write": self.write.to_dict(),
                **{f"submit:{k}": v.to_dict() for k, v in sorted(self.submit.items())},
            },
        }

    def fingerprint(self) -> str:
        """sha1 of the canonical JSON form — the replay-determinism anchor
        (same trace => same model => same fingerprint)."""
        blob = json.dumps(self.to_dict(), sort_keys=True, separators=(",", ":"))
        return hashlib.sha1(blob.encode()).hexdigest()


def _exclusive(events: list[tuple[float, float]]) -> list[float]:
    """Exclusive service times of possibly-overlapping events, in dispatch
    order: each is charged only the wall it added beyond its predecessors
    (``t1 - max(t0, busy_until)``) — the server's render_s accounting."""
    out = []
    busy = float("-inf")
    for t0, t1 in sorted(events):
        out.append(max(t1 - max(t0, busy), 0.0))
        busy = max(busy, t1)
    return out


def fit(meta: dict, records: list[dict]) -> CostModel:
    """Fit a :class:`CostModel` from validated span records (see module
    docstring for what is extracted and how overlap is handled)."""
    trees = build_trees(records)

    arrivals = []
    submit_events: list[tuple[float, float, str]] = []
    host_samples: list[float] = []
    encode_samples: list[float] = []
    write_samples: list[float] = []
    # batch render events dedupe on (t0, t1): every request in one batch
    # records an identical render span (same dispatch, same retire drain)
    batch_events: dict[tuple, int] = {}
    partial_events: dict[tuple, int] = {}

    for rid in sorted(trees):
        tree = trees[rid]
        admit = tree.get("admit") or tree.get("coalesce") or tree.get("submit")
        if not admit:
            continue  # a tree with no entry point can't be replayed
        submits = tree.get("submit")
        if "shed" in tree:
            outcome = "shed"
        elif submits:
            outcome = submits[0].get("outcome", "unknown")
            if outcome not in OUTCOMES:
                outcome = "unknown"
        else:
            outcome = "unknown"
        arrivals.append({
            "t": admit[0]["t0"],
            "rid": rid,
            "session": admit[0].get("session", 0),
            "stream": admit[0].get("stream", ""),
            "timestep": admit[0].get("timestep", 0),
            "outcome": outcome,
            "missing_tiles": (submits[0].get("missing_tiles", 0) if submits else 0),
            "bulk": bool(admit[0].get("bulk", False)),
        })
        if submits:
            # submit spans start at *admit* time (the gateway passes
            # t_submit=t_admit so the server keeps one latency origin), so
            # the raw duration embeds coalesce/queue wait the simulator
            # already models; floor each span at its wave cut and charge
            # exclusive service below
            coalesce = tree.get("coalesce")
            cut = coalesce[-1]["t1"] if coalesce else submits[0]["t0"]
            submit_events.append(
                (max(submits[0]["t0"], cut), submits[0]["t1"], outcome)
            )
        host = 0.0
        for stage in ("retire", "assemble"):
            for s in tree.get(stage, ()):
                host += max(s["t1"] - s["t0"], 0.0)
        if "render" in tree and outcome in ("miss", "unknown"):
            host_samples.append(host)
        for s in tree.get("encode", ()):
            encode_samples.append(max(s["t1"] - s["t0"], 0.0))
        for s in tree.get("write", ()):
            write_samples.append(max(s["t1"] - s["t0"], 0.0))
        for s in tree.get("render", ()):
            key = (round(s["t0"], 9), round(s["t1"], 9))
            if s.get("partial"):
                partial_events[key] = int(s.get("rows", 1))
            else:
                batch_events[key] = int(s.get("batch", 1))

    arrivals.sort(key=lambda a: (a["t"], a["rid"]))
    t0 = arrivals[0]["t"] if arrivals else 0.0
    for a in arrivals:
        a["t"] -= t0

    # exclusive device cost per batch, bucketed by batch size
    excl = _exclusive(list(batch_events))
    sizes: dict[int, list] = {}
    points = []
    for (key, size), e in zip(sorted(batch_events.items()), excl):
        sizes.setdefault(size, []).append(e)
        points.append((float(size), e))
    batch_fit = _linear_fit(points) if points else (0.0, 0.0)

    partial_excl = _exclusive(list(partial_events))

    # submits within one wave share a start (the admit) and run back to
    # back; exclusive accounting recovers each one's marginal CPU cost
    submit_samples: dict[str, list] = {}
    busy = float("-inf")
    for s0, s1, out in sorted(submit_events):
        submit_samples.setdefault(out, []).append(max(s1 - max(s0, busy), 0.0))
        busy = max(busy, s1)

    def dist(samples) -> StageDist:
        return StageDist(sorted(round(s, 9) for s in samples))

    return CostModel(
        meta=dict(meta),
        arrivals=arrivals,
        batch_sizes={k: dist(v) for k, v in sorted(sizes.items())},
        batch_fit=batch_fit,
        partial=dist(partial_excl),
        submit={k: dist(v) for k, v in sorted(submit_samples.items())},
        host=dist(host_samples),
        encode=dist(encode_samples),
        write=dist(write_samples),
        span_count=len(records),
    )


def fit_trace(source: str) -> CostModel:
    """``load_trace`` + ``fit`` in one call (path or raw JSONL text)."""
    meta, records = load_trace(source)
    return fit(meta, records)


def train_stage_breakdown(records: list[dict]) -> dict:
    """Per-stage duration distributions for the TRAINING span vocabulary.

    A training trace uses one rid per stream timestep (or fit call), so the
    serving ``fit()`` — which wants admit/submit trees — has nothing to say
    about it; this is the training-side analog: ``{stage: StageDist}`` over
    the :data:`~repro.obs.trace.TRAIN_STAGES` names found in ``records``
    (seconds, sorted), plus a ``"timesteps"`` entry counting distinct rids
    that carried training spans. Stage names outside the training vocabulary
    are ignored, so a mixed training+serving trace (one shared Obs) feeds
    this AND ``fit()`` from the same file."""
    train = frozenset(TRAIN_STAGES)
    samples: dict[str, list] = {}
    rids = set()
    for r in records:
        if r["span"] not in train:
            continue
        rids.add(r["rid"])
        samples.setdefault(r["span"], []).append(max(r["t1"] - r["t0"], 0.0))
    out = {
        stage: StageDist(sorted(round(s, 9) for s in got))
        for stage, got in samples.items()
    }
    out["timesteps"] = len(rids)
    return out
