"""Typed metrics registry: counters, gauges, fixed-bucket histograms.

One registry serves a whole serving stack (engine + cache + sessions +
gateway + encoders): every tier registers its metrics under a dotted name
(``server.completed``, ``gateway.frames_sent``, ``cache.hits``) and the
registry provides the two operations the loose per-tier counters never had:

``snapshot()``
    An **atomic** point-in-time read of every metric. All mutators and the
    snapshot share one registry lock, so a reader on the event-loop thread
    can never observe a torn pair (e.g. ``hits`` incremented but ``misses``
    not yet) while the render-executor thread is mid-update.

``reset()``
    Zero every metric across every tier in one call — the benchmark-window
    contract. Components whose window state lives outside the registry
    (plain lists, first/last timestamps) hook in via ``on_reset`` so one
    reset really clears the whole stack.

Counters accept float increments (wall-time sums are counters too).
Histograms use fixed bucket boundaries, so recording is O(log buckets) with
no per-sample allocation, and p50/p95/p99 are estimated by linear
interpolation inside the bucket — the shape a replay harness or a
cross-run diff can consume without shipping raw sample lists.
"""
from __future__ import annotations

import bisect
import threading

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "DEFAULT_MS_BUCKETS",
    "DEFAULT_SIZE_BUCKETS",
]

# latency-style buckets (milliseconds): ~logarithmic from 50us to 60s
DEFAULT_MS_BUCKETS = (
    0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0,
    250.0, 500.0, 1000.0, 2500.0, 5000.0, 10000.0, 30000.0, 60000.0,
)

# small-integer buckets (batch sizes, ring occupancy, queue depths)
DEFAULT_SIZE_BUCKETS = (1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64, 128, 256)


class Counter:
    """Monotonically increasing value (int or float). Registry-locked."""

    __slots__ = ("name", "_lock", "_v")

    def __init__(self, name: str, lock: threading.RLock):
        self.name = name
        self._lock = lock
        self._v = 0

    def inc(self, n=1) -> None:
        with self._lock:
            self._v += n

    add = inc  # timing sums read better as .add(seconds)

    @property
    def value(self):
        return self._v

    def snapshot(self):
        return self._v

    def _reset(self) -> None:  # caller holds the registry lock
        self._v = 0


class Gauge:
    """A value that goes up and down (queue depth, bytes held)."""

    __slots__ = ("name", "_lock", "_v")

    def __init__(self, name: str, lock: threading.RLock):
        self.name = name
        self._lock = lock
        self._v = 0

    def set(self, v) -> None:
        with self._lock:
            self._v = v

    def inc(self, n=1) -> None:
        with self._lock:
            self._v += n

    def dec(self, n=1) -> None:
        with self._lock:
            self._v -= n

    @property
    def value(self):
        return self._v

    def snapshot(self):
        return self._v

    def _reset(self) -> None:
        self._v = 0


class Histogram:
    """Fixed-bucket histogram with exact count/sum/min/max and interpolated
    percentiles. Bucket ``i`` counts samples ``<= bounds[i]``; one overflow
    bucket catches the rest.

    Histograms are *mergeable*: ``merge`` sums two histograms with identical
    bounds without losing percentile fidelity (bucket counts add exactly),
    and ``to_dict``/``from_dict`` round-trip one through JSON — the shape a
    benchmark needs to sum per-lap registry snapshots, and the shape the SLO
    tracker needs to window deltas of a cumulative histogram."""

    __slots__ = ("name", "_lock", "bounds", "counts", "count", "total", "vmin", "vmax")

    def __init__(self, name: str, lock: threading.RLock | None = None,
                 bounds=DEFAULT_MS_BUCKETS):
        assert list(bounds) == sorted(bounds) and len(bounds) >= 1, bounds
        self.name = name
        # standalone use (merge accumulators, windowed deltas) gets a private
        # lock; registry-owned histograms share the registry lock
        self._lock = lock if lock is not None else threading.RLock()
        self.bounds = tuple(float(b) for b in bounds)
        self.counts = [0] * (len(self.bounds) + 1)  # +1 overflow
        self.count = 0
        self.total = 0.0
        self.vmin = None
        self.vmax = None

    def observe(self, v) -> None:
        v = float(v)
        with self._lock:
            self.counts[bisect.bisect_left(self.bounds, v)] += 1
            self.count += 1
            self.total += v
            self.vmin = v if self.vmin is None else min(self.vmin, v)
            self.vmax = v if self.vmax is None else max(self.vmax, v)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Estimated q-th percentile (q in [0, 100]), linear within the
        bucket; exact at the recorded min/max ends. 0 when empty."""
        if not self.count:
            return 0.0
        rank = q / 100.0 * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if seen + c >= rank:
                lo = self.bounds[i - 1] if i > 0 else min(self.vmin, self.bounds[0])
                hi = self.bounds[i] if i < len(self.bounds) else self.vmax
                lo = max(lo, self.vmin)
                hi = min(hi, self.vmax) if hi is not None else self.vmax
                if hi <= lo:
                    return float(hi)
                frac = (rank - seen) / c
                return float(lo + (hi - lo) * min(max(frac, 0.0), 1.0))
            seen += c
        return float(self.vmax)  # pragma: no cover - arithmetic safety net

    def snapshot(self) -> dict:
        return {
            "count": self.count,
            "sum": round(self.total, 6),
            "mean": round(self.mean, 4),
            "min": round(self.vmin, 4) if self.vmin is not None else None,
            "max": round(self.vmax, 4) if self.vmax is not None else None,
            "p50": round(self.percentile(50), 4),
            "p95": round(self.percentile(95), 4),
            "p99": round(self.percentile(99), 4),
            # full serde fields: bounds + dense counts make the snapshot
            # self-describing, so Histogram.from_dict can rebuild (and
            # merge()) a histogram from any registry snapshot or wire copy
            "bounds": list(self.bounds),
            "counts": list(self.counts),
            "buckets": {
                ("le_%g" % b if i < len(self.bounds) else "inf"): c
                for i, (b, c) in enumerate(
                    zip(self.bounds + (float("inf"),), self.counts)
                )
                if c
            },
        }

    to_dict = snapshot

    @classmethod
    def from_dict(cls, d: dict, name: str = "") -> "Histogram":
        """Rebuild a standalone (private-lock) histogram from ``to_dict()`` /
        ``snapshot()`` output. min/max fall back to bucket edges when absent
        (a windowed delta has no exact extrema)."""
        h = cls(name or d.get("name", ""), None, d["bounds"])
        counts = [int(c) for c in d["counts"]]
        if len(counts) != len(h.counts):
            raise ValueError(
                f"counts length {len(counts)} != bounds+1 ({len(h.counts)})"
            )
        h.counts = counts
        h.count = int(d.get("count", sum(counts)))
        h.total = float(d.get("sum", 0.0))
        h.vmin = d.get("min")
        h.vmax = d.get("max")
        h._derive_extrema()
        return h

    def _derive_extrema(self) -> None:
        """Fill missing vmin/vmax from the occupied bucket edges so the
        percentile interpolation stays well-defined."""
        if not self.count:
            return
        occupied = [i for i, c in enumerate(self.counts) if c]
        if self.vmin is None:
            i = occupied[0]
            self.vmin = self.bounds[i - 1] if i > 0 else 0.0
        if self.vmax is None:
            i = occupied[-1]
            self.vmax = self.bounds[min(i, len(self.bounds) - 1)]

    def merge(self, other: "Histogram | dict") -> "Histogram":
        """Fold ``other`` (a Histogram, or a ``to_dict()``/``snapshot()``
        dict) into this histogram in place; returns self. Bucket counts add
        exactly, so percentiles of a merged histogram have the same fidelity
        as if every sample had been observed here — the associativity a
        per-lap benchmark accumulator needs. Bounds must match."""
        if isinstance(other, dict):
            other = Histogram.from_dict(other)
        if tuple(other.bounds) != self.bounds:
            raise ValueError(
                f"cannot merge histograms with different bounds "
                f"({self.name!r} vs {other.name!r})"
            )
        with self._lock:
            for i, c in enumerate(other.counts):
                self.counts[i] += c
            self.count += other.count
            self.total += other.total
            if other.vmin is not None:
                self.vmin = other.vmin if self.vmin is None else min(self.vmin, other.vmin)
            if other.vmax is not None:
                self.vmax = other.vmax if self.vmax is None else max(self.vmax, other.vmax)
        return self

    def state(self) -> tuple:
        """Locked point-in-time read of the mutable fields — the delta
        baseline a windowed consumer (SLO tracker) diffs against."""
        with self._lock:
            return (tuple(self.counts), self.count, self.total, self.vmin, self.vmax)

    def _reset(self) -> None:
        self.counts = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.total = 0.0
        self.vmin = self.vmax = None


class MetricsRegistry:
    """Flat namespace of typed metrics with atomic snapshot and one reset.

    ``counter``/``gauge``/``histogram`` are idempotent: asking for an
    existing name returns the existing metric (type-checked), so components
    can re-attach to a shared registry without double-registration errors.
    The lock is reentrant: a reset hook may read metric values."""

    def __init__(self):
        self._lock = threading.RLock()
        self._metrics: dict[str, object] = {}
        self._reset_hooks: list = []

    def _get(self, name: str, cls, *args):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, self._lock, *args)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as {type(m).__name__}"
                )
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, bounds=DEFAULT_MS_BUCKETS) -> Histogram:
        return self._get(name, Histogram, bounds)

    def get(self, name: str):
        return self._metrics.get(name)

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._metrics)

    def on_reset(self, hook) -> None:
        """Register ``hook()`` to run inside ``reset()`` — for window state
        that lives outside the registry (plain lists, t_first/t_last)."""
        with self._lock:  # reset() iterates the hooks under this lock
            self._reset_hooks.append(hook)

    def snapshot(self) -> dict:
        """Atomic point-in-time read: {dotted name: value | histogram dict}.
        No mutator can run while the snapshot is being assembled."""
        with self._lock:
            return {name: m.snapshot() for name, m in sorted(self._metrics.items())}

    def reset(self) -> None:
        """Zero every metric in every tier, then run the reset hooks — THE
        benchmark-window boundary (replaces per-tier reset conventions)."""
        with self._lock:
            for m in self._metrics.values():
                m._reset()
            for hook in self._reset_hooks:
                hook()
