"""Trace-driven replay, part 2: discrete-event simulation of the stack.

``simulate(model, params)`` replays a fitted :class:`~repro.obs.replay.
CostModel`'s recorded arrival timeline against a parameterized model of the
serving stack and predicts fps / p50 / p99 / shed-rate **without touching a
device**. The simulated control flow mirrors the real gateway loop
(``frontend/gateway.py``) stage for stage:

* arrivals land in per-session bounded queues; overflow sheds the oldest
  entry (the gateway's admission control, ``queue_limit``);
* the dispatcher coalesces — waits up to ``coalesce_ms`` for queued work to
  reach a device micro-batch, admitting arrivals that land inside the
  window — then cuts a *wave*: up to ``wave_per_session`` requests per
  session, round-robin;
* the wave runs on the (single) render executor: cache-resolved requests
  pay only their recorded submit overhead, partial hits pay the fitted
  row-render cost, and misses group into micro-batches by (stream,
  timestep) capped at ``max_batch`` — batches flow through a depth-bounded
  device/host pipeline (device renders batch N+1 while host postprocesses
  batch N when ``pipeline_depth >= 2``), the same overlap the engine's
  in-flight ring provides;
* waves serialize on the render executor (the dispatcher awaits it), while
  delivery (encode + socket write per frame) runs in a chained background
  task overlapping the next wave's render — ``deliver_start = max(wave_end,
  prev_deliver_end)``.

Because arrivals replay at their *recorded* times, predicted throughput is
capped by the recorded offered load — the simulator answers "what would
these same clients have experienced under different knobs", which is the
question autotuning actually needs answered (and what makes self-calibration
meaningful: identical knobs must reproduce the measured numbers).

Determinism: a fresh ``random.Random(seed)`` per call, dict iteration over
sorted keys only. Same model + params + seed => identical prediction.
"""
from __future__ import annotations

import collections
import dataclasses
import random

from repro.obs.replay import HIT_OUTCOMES, CostModel

__all__ = ["StackParams", "simulate"]


@dataclasses.dataclass(frozen=True)
class StackParams:
    """The knob vector a what-if run perturbs (gateway + engine tiers)."""

    coalesce_ms: float = 2.0     # dispatcher wave-coalesce window
    max_batch: int = 8           # engine micro-batch cap
    pipeline_depth: int = 2      # engine in-flight ring depth
    queue_limit: int = 8         # per-session admission queue (shed beyond)
    wave_per_session: int = 4    # dispatcher per-session wave quota
    cache_scale: float = 1.0     # <1 demotes recorded hits to misses
                                 # (a smaller cache); >1 promotes misses

    @classmethod
    def from_knobs(cls, knobs: dict) -> "StackParams":
        """Build from a recorded ``trace_meta.knobs`` dict, ignoring keys
        the simulator doesn't model (res, clients, ...)."""
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in dict(knobs).items() if k in fields})

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def _percentile(sorted_vals: list, q: float) -> float:
    if not sorted_vals:
        return 0.0
    idx = min(int(q / 100.0 * len(sorted_vals)), len(sorted_vals) - 1)
    return sorted_vals[idx]


def simulate(model: CostModel, params: StackParams, *, seed: int = 0) -> dict:
    """Replay ``model``'s arrival timeline under ``params``; returns
    ``{frames_per_s, p50_ms, p99_ms, served, shed, shed_rate, waves,
    mean_batch, wall_s}``."""
    rng = random.Random(seed)
    n = len(model.arrivals)
    if n == 0:
        return {"frames_per_s": 0.0, "p50_ms": 0.0, "p99_ms": 0.0, "served": 0,
                "shed": 0, "shed_rate": 0.0, "waves": 0, "mean_batch": 0.0,
                "wall_s": 0.0}

    # --- outcome reassignment under the cache what-if axis. Recorded sheds
    # replay as misses (whether THIS knob set sheds them is the simulator's
    # decision); a lost submit span ("unknown") is conservatively a miss.
    arrivals = []
    for a in model.arrivals:
        outcome = a["outcome"]
        if outcome in ("shed", "unknown"):
            outcome = "miss"
        if params.cache_scale < 1.0 and outcome in HIT_OUTCOMES | {"partial_hit"}:
            if rng.random() >= params.cache_scale:
                outcome = "miss"
        elif params.cache_scale > 1.0 and outcome == "miss":
            if rng.random() < 1.0 - 1.0 / params.cache_scale:
                outcome = "full_hit"
        arrivals.append({**a, "outcome": outcome})

    coalesce_s = max(params.coalesce_ms, 0.0) / 1e3
    queues: dict = collections.defaultdict(collections.deque)
    i = 0                       # next unadmitted arrival
    shed = 0
    latencies: list[float] = []
    t = arrivals[0]["t"]
    deliver_free = t
    waves = 0
    batch_count = 0
    batch_total = 0
    last_completion = t

    def admit_until(limit_t: float) -> None:
        nonlocal i, shed
        while i < n and arrivals[i]["t"] <= limit_t:
            a = arrivals[i]
            q = queues[a["session"]]
            if len(q) >= params.queue_limit:
                q.popleft()     # oldest-drop shed (gateway admission control)
                shed += 1
            q.append(a)
            i += 1

    def queued() -> int:
        return sum(len(q) for q in queues.values())

    while True:
        admit_until(t)
        if queued() == 0:
            if i >= n:
                break
            t = arrivals[i]["t"]
            continue
        # --- coalesce: hold the wave until a device micro-batch's worth is
        # queued or the window expires, admitting arrivals that land inside
        if coalesce_s > 0 and queued() < params.max_batch:
            deadline = t + coalesce_s
            while (i < n and arrivals[i]["t"] <= deadline
                   and queued() < params.max_batch):
                t = max(t, arrivals[i]["t"])
                admit_until(t)
            if queued() < params.max_batch:
                t = deadline  # window expired without filling a batch
        # --- cut the wave: per-session quota, sessions in sorted order
        wave = []
        for sid in sorted(queues):
            q = queues[sid]
            for _ in range(min(params.wave_per_session, len(q))):
                wave.append(q.popleft())
        waves += 1
        # --- render executor: submit overhead + partial jobs serially,
        # then miss batches through the depth-bounded device/host pipeline
        cursor = t
        batches: dict = collections.defaultdict(list)
        for a in wave:
            sub = model.submit.get(a["outcome"]) or model.submit.get("miss")
            if sub is not None:
                cursor += sub.sample(rng)
            if a["outcome"] in HIT_OUTCOMES:
                continue
            if a["outcome"] == "partial_hit":
                cursor += model.partial.sample(rng)
                continue
            batches[(a["stream"], a["timestep"])].append(a)
        dev_free = host_free = cursor
        host_done: list[float] = []
        k = 0
        for key in sorted(batches):
            group = batches[key]
            for j in range(0, len(group), params.max_batch):
                chunk = group[j:j + params.max_batch]
                batch_count += 1
                batch_total += len(chunk)
                dev_start = dev_free
                if k >= params.pipeline_depth:
                    # the in-flight ring slot frees when the host finishes
                    # the batch ``depth`` places back
                    dev_start = max(dev_start, host_done[k - params.pipeline_depth])
                dev_end = dev_start + model.batch_cost(len(chunk), rng)
                dev_free = dev_end
                host_cost = sum(model.host.sample(rng) for _ in chunk)
                host_end = max(dev_end, host_free) + host_cost
                host_free = host_end
                host_done.append(host_end)
                k += 1
        wave_end = host_free
        # --- delivery chain: overlaps the next wave's render, serialized
        # behind the previous wave's delivery
        deliver = max(wave_end, deliver_free)
        for a in wave:
            deliver += model.encode.sample(rng) + model.write.sample(rng)
            latencies.append(deliver - a["t"])
            last_completion = deliver
        deliver_free = deliver
        # the dispatcher awaits the render executor before the next wave
        t = wave_end

    served = len(latencies)
    wall = max(last_completion - arrivals[0]["t"], 1e-9)
    lat_ms = sorted(x * 1e3 for x in latencies)
    return {
        "frames_per_s": round(served / wall, 2),
        "p50_ms": round(_percentile(lat_ms, 50), 3),
        "p99_ms": round(_percentile(lat_ms, 99), 3),
        "served": served,
        "shed": shed,
        "shed_rate": round(shed / n, 4),
        "waves": waves,
        "mean_batch": round(batch_total / batch_count, 2) if batch_count else 0.0,
        "wall_s": round(wall, 6),
    }
