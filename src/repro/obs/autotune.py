"""Trace-driven replay, part 3: knob-space search over the simulator.

``recommend(model)`` grid-searches :class:`~repro.obs.costmodel.StackParams`
space by replaying the fitted trace under every candidate and returns the
best predicted configuration next to the recorded-knob baseline. The search
is deliberately a small exhaustive grid (a few hundred candidates, each a
sub-millisecond pure-Python replay) rather than anything adaptive: the
simulator is deterministic, so an exhaustive sweep IS the global optimum of
the modeled space, and the result is bit-reproducible — the property the
``launch.tune`` CLI and its tests pin via the model fingerprint.

Ranking: feasibility first (when an SLO target is given, candidates whose
predicted p99 exceeds it sort below every feasible one), then predicted
throughput, then lower p99, then fewer sheds. Ties break toward the
*baseline-most* candidate by sorted knob order — strictly-better comparison
(``>``), so iteration order can never flip a recommendation between runs.
"""
from __future__ import annotations

import itertools

from repro.obs.costmodel import StackParams, simulate
from repro.obs.replay import CostModel

__all__ = ["DEFAULT_GRID", "recommend"]

# Small on purpose: every value here is one the serving stack is known to
# accept, and the --config-from consumers re-run for real under the winner,
# so the grid's job is coverage of the knee points, not fine resolution.
DEFAULT_GRID = {
    "coalesce_ms": (0.0, 1.0, 2.0, 4.0),
    "max_batch": (4, 8),
    "pipeline_depth": (1, 2, 3),
    "queue_limit": (4, 8, 16),
    "wave_per_session": (2, 4, 8),
}


def _score(pred: dict, slo_p99_ms: float | None) -> tuple:
    feasible = slo_p99_ms is None or pred["p99_ms"] <= slo_p99_ms
    return (feasible, pred["frames_per_s"], -pred["p99_ms"], -pred["shed"])


def recommend(
    model: CostModel,
    *,
    seed: int = 0,
    grid: dict | None = None,
    slo_p99_ms: float | None = None,
) -> dict:
    """Search the knob grid via replay; returns a self-describing
    recommendation record (baseline + winner + predicted numbers), stamped
    with the model fingerprint so a consumer can tell which trace and fit
    produced it."""
    grid = dict(DEFAULT_GRID if grid is None else grid)
    baseline_params = StackParams.from_knobs(model.knobs)
    baseline = simulate(model, baseline_params, seed=seed)

    best_params, best_pred = baseline_params, baseline
    best_score = _score(baseline, slo_p99_ms)
    evaluated = 1
    keys = sorted(grid)
    for combo in itertools.product(*(sorted(grid[k]) for k in keys)):
        candidate = StackParams(**{
            **baseline_params.to_dict(), **dict(zip(keys, combo)),
        })
        if candidate == baseline_params:
            continue  # already scored as the baseline
        pred = simulate(model, candidate, seed=seed)
        evaluated += 1
        score = _score(pred, slo_p99_ms)
        if score > best_score:  # strictly better: order-stable determinism
            best_params, best_pred, best_score = candidate, pred, score

    return {
        "schema": 1,
        "seed": seed,
        "model_fingerprint": model.fingerprint(),
        "trace": {
            "requests": len(model.arrivals),
            "spans": model.span_count,
            "dropped": int(model.meta.get("dropped", 0)),
            "outcome_mix": model.outcome_mix(),
        },
        "slo_p99_ms": slo_p99_ms,
        "baseline": {
            "knobs": baseline_params.to_dict(),
            "predicted": baseline,
        },
        "recommended": {
            "knobs": best_params.to_dict(),
            "predicted": best_pred,
        },
        "predicted_speedup": round(
            best_pred["frames_per_s"] / max(baseline["frames_per_s"], 1e-9), 3
        ),
        "evaluated": evaluated,
        "grid": {k: list(grid[k]) for k in keys},
    }
