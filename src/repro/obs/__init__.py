"""repro.obs — observability for the serving stack.

One ``Obs`` bundle travels down the stack (gateway → sessions → engine →
cache → encoders): it owns the shared :class:`MetricsRegistry` (atomic
snapshot, one ``reset()`` for every tier) and the span recorder —
:data:`NULL_RECORDER` (falsy; tracing disabled, zero hot-path cost) unless
tracing was requested. Components that are constructed standalone (a bare
``RenderServer`` in a test) default to their own private ``Obs`` so the
instrumentation never needs a None check.
"""
from __future__ import annotations

from repro.obs.clock import now, since
from repro.obs.export import (
    TraceCheck,
    spans_to_chrome,
    spans_to_jsonl,
    trace_meta,
    validate_trace_jsonl,
    write_trace,
)
from repro.obs.metrics import (
    DEFAULT_MS_BUCKETS,
    DEFAULT_SIZE_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from repro.obs.slo import SLOTracker, parse_slo_spec
from repro.obs.trace import (
    NULL_RECORDER,
    STAGES,
    TRAIN_STAGES,
    NullRecorder,
    Span,
    TraceRecorder,
    new_request_id,
)
from repro.obs import devmem

__all__ = [
    "Obs",
    "now",
    "since",
    "MetricsRegistry",
    "Counter",
    "Gauge",
    "Histogram",
    "DEFAULT_MS_BUCKETS",
    "DEFAULT_SIZE_BUCKETS",
    "TraceRecorder",
    "NullRecorder",
    "NULL_RECORDER",
    "Span",
    "STAGES",
    "TRAIN_STAGES",
    "devmem",
    "new_request_id",
    "spans_to_jsonl",
    "spans_to_chrome",
    "write_trace",
    "validate_trace_jsonl",
    "trace_meta",
    "TraceCheck",
    "SLOTracker",
    "parse_slo_spec",
]


class Obs:
    """The observability bundle one serving stack shares.

    ``obs.metrics`` — the registry every tier registers its counters on.
    ``obs.trace`` — a :class:`TraceRecorder` when tracing is on, else the
    falsy :data:`NULL_RECORDER`; hot paths gate on its truthiness.
    """

    __slots__ = ("metrics", "trace")

    def __init__(self, *, trace: bool = False, trace_capacity: int = 65536,
                 metrics: MetricsRegistry | None = None):
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.trace = TraceRecorder(trace_capacity) if trace else NULL_RECORDER

    @property
    def tracing(self) -> bool:
        return bool(self.trace)

    def enable_trace(self, capacity: int = 65536) -> TraceRecorder:
        """Switch tracing on (idempotent); returns the live recorder."""
        if not self.trace:
            self.trace = TraceRecorder(capacity)
        return self.trace

    def disable_trace(self) -> None:
        self.trace = NULL_RECORDER
