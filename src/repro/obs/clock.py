"""One monotonic clock for every serving tier.

Every timestamp that ends up in a span, a latency histogram, or a wall-time
sum must come from the SAME monotonic clock, or cross-tier arithmetic
(gateway wait minus engine render, span trees stitched across threads) mixes
epochs and produces negative stage times. ``now()`` is the canonical clock:
``time.perf_counter`` — monotonic, process-wide, highest available
resolution. Tiers import *this name* instead of calling ``time`` directly so
the choice is made exactly once.

``perf_counter``'s epoch is arbitrary (process start-ish). Exporters that
need wall-clock alignment subtract a reference taken at trace start; nothing
in the serving stack ever compares these timestamps across processes.
"""
from __future__ import annotations

import time

# the canonical monotonic clock: seconds, float, arbitrary epoch
now = time.perf_counter


def since(t0: float) -> float:
    """Seconds elapsed since ``t0`` (a ``now()`` reading)."""
    return now() - t0
