"""Rolling-window SLO tracking over registry histograms.

The registry's histograms are *cumulative* — perfect for a benchmark window,
useless for "are we breaching **right now**". The tracker turns a cumulative
histogram (normally ``gateway.request_ms``) into a sliding window by diffing
bucket counts against a baseline on every ``tick()`` and keeping the deltas
in a time-stamped deque; the window view is the :meth:`Histogram.merge` of
the surviving deltas, so the windowed p99 has full bucket fidelity, not an
average-of-percentiles.

Burn-rate semantics (the SRE error-budget formulation): the target is
"p99 <= ``p99_ms``", i.e. at most ``budget`` (default 1%) of requests may
exceed the threshold. ``burn = violation_rate / budget`` — burn 1.0 spends
the budget exactly as fast as it accrues; the tracker reports

    ok      burn < warn_burn   (default 1.0)
    warn    warn_burn <= burn < breach_burn (default 2.0)
    breach  burn >= breach_burn

computed over the last ``window_s`` seconds only, so a breach *recovers* on
its own once the slow requests age out of the window. An empty window is
``ok`` (no traffic is not an outage).

The tracker never mutates the histogram it watches and rebaselines itself on
``MetricsRegistry.reset()`` (benchmark lap boundaries) — a reset shrinks the
cumulative counts, and a naive diff would otherwise go negative.
"""
from __future__ import annotations

import bisect
import collections

from repro.obs.clock import now as _now
from repro.obs.metrics import Histogram, MetricsRegistry

__all__ = ["SLOTracker", "parse_slo_spec"]

# --slo flag grammar: comma-separated k=v; p99_ms is the only required key
_SPEC_KEYS = ("p99_ms", "window_s", "budget", "warn_burn", "breach_burn")


def parse_slo_spec(text: str) -> dict:
    """Parse ``"p99_ms=250"`` / ``"p99_ms=250,window_s=10,budget=0.05"``
    into SLOTracker kwargs. Raises ValueError on unknown keys or a missing
    p99_ms — a misspelled SLO must fail at launch, not silently monitor
    nothing."""
    out = {}
    for part in text.split(","):
        part = part.strip()
        if not part:
            continue
        key, sep, val = part.partition("=")
        key = key.strip()
        if not sep or key not in _SPEC_KEYS:
            raise ValueError(
                f"bad --slo entry {part!r} (known keys: {', '.join(_SPEC_KEYS)})"
            )
        out[key] = float(val)
    if "p99_ms" not in out:
        raise ValueError("--slo needs p99_ms=<threshold>")
    return out


class SLOTracker:
    """Windowed p99 + error-budget burn state over one registry histogram.

    ``tick()`` is cheap (one locked histogram read, one deque append when
    there is new traffic) and is called opportunistically from the serving
    path (once per delivered wave) and from every stats/metrics read, so the
    reported state is current whenever anyone looks.
    """

    def __init__(
        self,
        metrics: MetricsRegistry,
        *,
        p99_ms: float,
        hist: str = "gateway.request_ms",
        window_s: float = 30.0,
        budget: float = 0.01,
        warn_burn: float = 1.0,
        breach_burn: float = 2.0,
        clock=_now,
    ):
        assert p99_ms > 0 and window_s > 0 and 0 < budget <= 1
        assert warn_burn <= breach_burn
        self.p99_ms = float(p99_ms)
        self.window_s = float(window_s)
        self.budget = float(budget)
        self.warn_burn = float(warn_burn)
        self.breach_burn = float(breach_burn)
        self._clock = clock
        self._hist = metrics.histogram(hist)
        self._baseline = self._hist.state()
        # (t, delta-Histogram) newest-last; merged on demand for the window
        self._window: collections.deque = collections.deque()
        self._total_seen = 0
        # a registry reset() shrinks the cumulative counts mid-flight; the
        # hook rebaselines so the first post-reset tick doesn't diff against
        # a pre-reset world (the negative-delta check below is the backstop
        # for resets that bypass the registry)
        metrics.on_reset(self.rebaseline)

    def rebaseline(self) -> None:
        """Forget everything: fresh baseline, empty window."""
        self._baseline = self._hist.state()
        self._window.clear()

    def tick(self, t: float | None = None) -> None:
        """Fold new samples (since the last tick) into the window and evict
        entries older than ``window_s``. Callable from any thread."""
        t = self._clock() if t is None else t
        counts, count, total, vmin, vmax = self._hist.state()
        b_counts, b_count, b_total, _, _ = self._baseline
        if count < b_count or any(c < b for c, b in zip(counts, b_counts)):
            # the histogram went backwards: reset outside the hook path
            self._baseline = (counts, count, total, vmin, vmax)
            self._window.clear()
            return
        if count > b_count:
            delta = Histogram(self._hist.name, None, self._hist.bounds)
            delta.counts = [c - b for c, b in zip(counts, b_counts)]
            delta.count = count - b_count
            delta.total = total - b_total
            # extrema of the delta are unknowable from cumulative state;
            # bucket edges stand in (percentiles stay bucket-accurate)
            delta._derive_extrema()
            self._window.append((t, delta))
            self._total_seen += delta.count
            self._baseline = (counts, count, total, vmin, vmax)
        horizon = t - self.window_s
        while self._window and self._window[0][0] < horizon:
            self._window.popleft()

    # ----------------------------------------------------------------- state
    def _merged(self) -> Histogram:
        h = Histogram(self._hist.name, None, self._hist.bounds)
        for _, delta in self._window:
            h.merge(delta)
        return h

    def _violations(self, h: Histogram) -> float:
        """Estimated number of window samples above ``p99_ms`` (fractional:
        linear interpolation inside the straddling bucket)."""
        if not h.count:
            return 0.0
        i = bisect.bisect_left(h.bounds, self.p99_ms)
        above = float(sum(h.counts[i + 1:])) if i < len(h.counts) else 0.0
        if i < len(h.counts) and h.counts[i]:
            lo = h.bounds[i - 1] if i > 0 else 0.0
            hi = h.bounds[i] if i < len(h.bounds) else (h.vmax or self.p99_ms)
            frac_above = (hi - self.p99_ms) / (hi - lo) if hi > lo else 0.0
            above += h.counts[i] * min(max(frac_above, 0.0), 1.0)
        return above

    def report(self, t: float | None = None) -> dict:
        """Current window state (ticks first, so it is never stale)."""
        self.tick(t)
        h = self._merged()
        violation_rate = self._violations(h) / h.count if h.count else 0.0
        burn = violation_rate / self.budget
        if not h.count or burn < self.warn_burn:
            state = "ok"
        elif burn < self.breach_burn:
            state = "warn"
        else:
            state = "breach"
        return {
            "target_p99_ms": self.p99_ms,
            "window_s": self.window_s,
            "budget": self.budget,
            "state": state,
            "burn": round(burn, 4),
            "violation_rate": round(violation_rate, 6),
            "window_count": h.count,
            "window_p99_ms": round(h.percentile(99), 3),
            "window_p50_ms": round(h.percentile(50), 3),
            "samples_total": self._total_seen,
        }

    @property
    def state(self) -> str:
        return self.report()["state"]
