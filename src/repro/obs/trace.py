"""Lock-free ring-buffer span recorder + the canonical request-id mint.

A *span* is one stage of one request's life: ``(seq, rid, name, t0, t1,
meta)``. The recorder is a bounded ring written from whichever thread the
stage runs on (event loop, render executor, encode executor) without any
lock: a slot index is reserved with ``next()`` on an ``itertools.count`` —
atomic under the GIL — and the tuple is stored with a single list item
assignment. Readers (``drain``/``spans``) tolerate slots being overwritten
mid-read because each slot holds its own ``seq``; when the ring laps,
``dropped`` reports exactly how many spans were lost.

Disabled tracing must cost nothing on the hot path. ``NullRecorder`` is
*falsy*, so every instrumentation site is two bytecodes::

    rec = self.obs.trace
    if rec:
        rec.record(...)

No tuple is built, no call is made, no allocation happens when tracing is
off — verified by a tracemalloc test in ``tests/test_obs.py``.

``new_request_id()`` lives here because the request id is the join key of
the whole span tree: the gateway mints one at admit, the engine mints one
for in-process callers, and ``MicroBatcher`` uses the same counter for its
default ids, so an id means the same thing in every tier.
"""
from __future__ import annotations

import itertools

from repro.obs.clock import now

__all__ = [
    "Span",
    "TraceRecorder",
    "NullRecorder",
    "NULL_RECORDER",
    "new_request_id",
    "STAGES",
    "TRAIN_STAGES",
]

# Stage vocabulary, in pipeline order. Exporters use this order to lay out
# Perfetto lanes; the JSONL contract promises names come from this set (plus
# any future additions — consumers must ignore unknown names).
STAGES = (
    "admit",      # gateway accepted the request (instant; roots the tree)
    "coalesce",   # waited in the session queue for a dispatch wave
    "shed",       # dropped by backpressure — terminated span, tree ends here
    "submit",     # engine cache probe + enqueue (cache/dedup outcome in meta)
    "render",     # device render of the micro-batch this request rode in
    "retire",     # device->host fetch + future resolution
    "assemble",   # tile-cache strip patch + frame assembly
    "encode",     # wire encoding (raw/delta/tiles)
    "write",      # socket write
)

# Training-loop stage vocabulary, in train-step order. One request id is
# minted per stream timestep (or per GSTrainer.fit call), so a whole
# timestep's stages join into one span tree and render next to serving
# lanes on the same monotonic clock when training and serving share an Obs.
TRAIN_STAGES = (
    "extract",    # isosurface extraction from the volume timestep
    "reseed",     # dead-slot reseeding (the streaming densify stand-in)
    "batch",      # host-side view-batch assembly
    "dispatch",   # jitted step call (returns under async dispatch)
    "device",     # device compute, bounded by block_until_ready
    "densify",    # densify_and_rebalance round (static pipeline only)
    "eval",       # eval-view render + PSNR
    "ckpt",       # checkpoint / temporal-store handoff
    "serve",      # live RenderServer add_timestep handoff
    "fit",        # the whole optimization loop of one timestep (parent span)
)

_request_ids = itertools.count(1)


def new_request_id() -> int:
    """Mint a process-unique request id (GIL-atomic, any thread)."""
    return next(_request_ids)


class Span:
    """Read-side view of one recorded span (the ring stores bare tuples)."""

    __slots__ = ("seq", "rid", "name", "t0", "t1", "meta")

    def __init__(self, seq, rid, name, t0, t1, meta):
        self.seq = seq
        self.rid = rid
        self.name = name
        self.t0 = t0
        self.t1 = t1
        self.meta = meta

    @property
    def dur(self) -> float:
        return self.t1 - self.t0

    def __repr__(self):  # pragma: no cover - debugging aid
        return (
            f"Span(rid={self.rid}, {self.name!r}, "
            f"{(self.t1 - self.t0) * 1e3:.3f}ms, meta={self.meta})"
        )


class TraceRecorder:
    """Bounded multi-producer span ring; truthy (cf. ``NullRecorder``).

    ``record`` is safe from any thread and never blocks: slot reservation is
    one atomic ``next()``, the write is one list item store. A reader that
    races a lapping writer may see a stale tuple, but never a torn one
    (tuples are immutable; the store is a single pointer swap).
    """

    __slots__ = ("capacity", "_ring", "_seq")

    def __init__(self, capacity: int = 65536):
        assert capacity >= 1
        self.capacity = capacity
        self._ring: list = [None] * capacity
        self._seq = itertools.count()

    def __bool__(self) -> bool:
        return True

    def record(self, rid: int, name: str, t0: float, t1: float | None = None, **meta) -> None:
        """Record one finished span. ``t1=None`` -> instant span at ``t0``."""
        seq = next(self._seq)  # atomic slot reservation
        self._ring[seq % self.capacity] = (
            seq, rid, name, t0, t0 if t1 is None else t1, meta,
        )

    def instant(self, rid: int, name: str, **meta) -> None:
        """Record a zero-duration marker stamped with the current time."""
        self.record(rid, name, now(), None, **meta)

    @property
    def recorded(self) -> int:
        """Total spans ever recorded (including overwritten ones)."""
        return self._recorded()

    def _recorded(self) -> int:
        # itertools.count exposes its next value via __reduce__ without
        # advancing: ("count", (next_value,)).
        return self._seq.__reduce__()[1][0]

    @property
    def dropped(self) -> int:
        """Spans lost to ring overwrite so far."""
        return max(0, self._recorded() - self.capacity)

    def spans(self) -> list[Span]:
        """Snapshot the ring's surviving spans in record order (non-destructive)."""
        got = [s for s in list(self._ring) if s is not None]
        got.sort(key=lambda s: s[0])
        return [Span(*s) for s in got]

    def drain(self) -> list[Span]:
        """Snapshot then clear the ring (drop accounting keeps running)."""
        out = self.spans()
        self._ring = [None] * self.capacity
        return out


class NullRecorder:
    """The disabled recorder: falsy, so hot paths skip their whole
    instrumentation block — no meta dict, no time reads, no call."""

    __slots__ = ()
    capacity = 0

    def __bool__(self) -> bool:
        return False

    def record(self, *a, **kw) -> None:  # pragma: no cover - never on hot path
        pass

    def instant(self, *a, **kw) -> None:  # pragma: no cover
        pass

    @property
    def recorded(self) -> int:
        return 0

    @property
    def dropped(self) -> int:
        return 0

    def spans(self) -> list:
        return []

    def drain(self) -> list:
        return []


NULL_RECORDER = NullRecorder()
