"""Trace exporters: JSONL (replay-harness contract) + Chrome trace events.

JSONL — one span per line, the input format for the trace-driven replay
harness (``repro.obs.replay``). The contract, which ``validate_trace_jsonl``
enforces and tests pin:

    {"rid": int >= 0, "span": str, "t0": float, "t1": float >= t0, ...meta}

``rid`` joins a request's spans into one tree; ``span`` is the stage name
(normally from ``trace.STAGES`` — consumers must ignore unknown names);
``t0``/``t1`` are seconds on the shared monotonic clock (``obs.clock.now``),
same epoch across every line of one file. Remaining keys are stage metadata
(batch id/size, cache outcome, encoding, byte counts) and are optional.

An optional FIRST line ``{"trace_meta": {...}}`` carries export metadata:
``dropped`` (spans lost to ring overwrite — a replay fit on a lossy trace is
fit on a lie, so the drop count must travel WITH the data), ``capacity``
(the ring size that caused it), ``recorded``, ``clock`` (the time domain of
``t0``/``t1``), and ``knobs`` (the serving-stack configuration that produced
the trace — the baseline a what-if replay perturbs).

Chrome trace-event JSON — the same spans as complete ("ph": "X") events,
viewable in Perfetto / chrome://tracing. Each stage gets its own lane group,
ordered by pipeline position; spans that overlap in time within one stage
(concurrent requests, or spans recorded from different threads — the render
executor and the event loop write into the same ring) spill into numbered
sub-lanes instead of interleaving into one bar row, so a pipelined wave
reads as parallel bars rather than one garbled lane.
"""
from __future__ import annotations

import json
from typing import Iterable, Sequence

from repro.obs.trace import STAGES, TRAIN_STAGES, Span

__all__ = [
    "spans_to_jsonl",
    "spans_to_chrome",
    "write_trace",
    "validate_trace_jsonl",
    "trace_meta",
    "TraceCheck",
    "CLOCK_DOMAIN",
]

_RESERVED = ("rid", "span", "t0", "t1")
META_KEY = "trace_meta"

# the time domain every span's t0/t1 lives in (obs.clock.now = one shared
# monotonic clock per process; cross-process traces must not be merged
# without re-basing, which is why the domain travels in the export header)
CLOCK_DOMAIN = "monotonic"

# lane layout: each stage owns a block of STRIDE tids so overlap sub-lanes
# sort directly under their stage in the Perfetto thread list
LANE_STRIDE = 16


def trace_meta(recorder, knobs: dict | None = None) -> dict:
    """Export metadata for a recorder (``TraceRecorder`` or the null one):
    drop accounting + ring capacity + clock domain, plus the serving-stack
    ``knobs`` that produced the trace when the caller provides them."""
    meta = {
        "recorded": recorder.recorded,
        "dropped": recorder.dropped,
        "capacity": recorder.capacity,
        "clock": CLOCK_DOMAIN,
    }
    if knobs:
        meta["knobs"] = dict(knobs)
    return meta


def spans_to_jsonl(spans: Iterable[Span], meta: dict | None = None) -> str:
    """Render spans as JSONL (one compact object per line, trailing newline;
    empty string for no spans and no meta). ``meta`` becomes a leading
    ``{"trace_meta": {...}}`` line."""
    lines = []
    if meta is not None:
        lines.append(json.dumps({META_KEY: meta}, separators=(",", ":"), default=str))
    for s in spans:
        obj = {"rid": s.rid, "span": s.name, "t0": s.t0, "t1": s.t1}
        for k, v in s.meta.items():
            if k not in _RESERVED:
                obj[k] = v
        lines.append(json.dumps(obj, separators=(",", ":"), default=str))
    return "\n".join(lines) + ("\n" if lines else "")


def spans_to_chrome(spans: Sequence[Span], meta: dict | None = None) -> dict:
    """Render spans as a Chrome trace-event JSON object (Perfetto-viewable).

    One pid; each stage owns a block of lanes (tids) in pipeline order, and
    spans that overlap in time within a stage are assigned to successive
    sub-lanes (greedy interval partitioning), never stacked into one lane —
    spans sharing a rid but recorded from different threads (render executor
    vs event loop) used to interleave into one unreadable bar row.
    Timestamps are microseconds relative to the earliest span so the
    viewport opens on the data instead of hours into an arbitrary epoch.
    ``meta`` (clock domain, drop accounting, knobs) rides in ``otherData``."""
    spans = sorted(spans, key=lambda s: (s.t0, s.seq))
    base = min((s.t0 for s in spans), default=0.0)
    # serving stages first, then training stages: a trace that carries both
    # (insitu run(server=...)) shows training and serving lanes on one clock
    known = STAGES + TRAIN_STAGES
    stage_base = {name: (i + 1) * LANE_STRIDE for i, name in enumerate(known)}
    overflow_base = (len(known) + 1) * LANE_STRIDE  # unknown stage names
    # per-stage sub-lane occupancy: lane i is free for a span iff the last
    # span placed there ended at or before this span starts
    lane_busy_until: dict[str, list] = {}
    events = []
    lanes_named: set[int] = set()

    def _name_lane(name: str, tid: int, sub: int) -> None:
        if tid in lanes_named:
            return
        lanes_named.add(tid)
        label = f"{tid // LANE_STRIDE:02d}.{name}" + (f"#{sub}" if sub else "")
        events.append({
            "name": "thread_name", "ph": "M", "pid": 1, "tid": tid,
            "args": {"name": label},
        })

    # always name sub-lane 0 of every known stage, so an empty stage still
    # shows its labelled lane in pipeline order
    for name in STAGES:
        _name_lane(name, stage_base[name], 0)
    for s in spans:
        tbase = stage_base.get(s.name, overflow_base)
        busy = lane_busy_until.setdefault(s.name, [])
        for sub, t_free in enumerate(busy):
            if t_free <= s.t0:
                busy[sub] = max(s.t1, s.t0)
                break
        else:
            sub = len(busy)
            busy.append(max(s.t1, s.t0))
        tid = tbase + sub
        _name_lane(s.name, tid, sub)
        events.append({
            "name": s.name,
            "ph": "X",
            "pid": 1,
            "tid": tid,
            "ts": round((s.t0 - base) * 1e6, 3),
            "dur": round(max(s.t1 - s.t0, 0.0) * 1e6, 3),
            "args": {"rid": s.rid, **s.meta},
        })
    other = {"clock_domain": CLOCK_DOMAIN}
    if meta is not None:
        other.update(meta)
    return {"traceEvents": events, "displayTimeUnit": "ms", "otherData": other}


def write_trace(path: str, spans: Sequence[Span], meta: dict | None = None) -> tuple[str, str]:
    """Write ``path`` (JSONL) and ``path`` with a ``.json`` suffix swapped in
    (Chrome trace events). Returns ``(jsonl_path, chrome_path)``. ``meta``
    (see ``trace_meta``) is embedded in both exports, so drop accounting and
    the producing knob configuration travel with the spans."""
    spans = list(spans)
    jsonl_path = str(path)
    with open(jsonl_path, "w") as f:
        f.write(spans_to_jsonl(spans, meta=meta))
    stem = jsonl_path[: -len(".jsonl")] if jsonl_path.endswith(".jsonl") else jsonl_path
    chrome_path = stem + ".chrome.json"
    with open(chrome_path, "w") as f:
        json.dump(spans_to_chrome(spans, meta=meta), f)
    return jsonl_path, chrome_path


class TraceCheck(int):
    """``validate_trace_jsonl``'s result: the span count (it IS an int, so
    every existing caller keeps working) plus the parsed export metadata —
    ``.meta``, ``.dropped``, ``.capacity`` — so consumers can surface ring
    overflow instead of silently fitting a model to a lossy trace."""

    meta: dict

    def __new__(cls, n: int, meta: dict | None = None):
        self = super().__new__(cls, n)
        self.meta = meta or {}
        return self

    @property
    def dropped(self) -> int:
        return int(self.meta.get("dropped", 0))

    @property
    def capacity(self) -> int | None:
        return self.meta.get("capacity")

    @property
    def knobs(self) -> dict:
        return self.meta.get("knobs") or {}


def validate_trace_jsonl(text: str) -> TraceCheck:
    """Validate JSONL trace text against the schema contract; returns the
    number of span lines (as a :class:`TraceCheck`, an ``int`` carrying the
    export metadata). Raises ``ValueError`` naming the first bad line."""
    n = 0
    meta = None
    first_content_line = True
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        try:
            obj = json.loads(line)
        except json.JSONDecodeError as e:
            raise ValueError(f"trace line {lineno}: not JSON ({e})") from None
        if not isinstance(obj, dict):
            raise ValueError(f"trace line {lineno}: not an object")
        if META_KEY in obj:
            if not first_content_line:
                raise ValueError(
                    f"trace line {lineno}: {META_KEY} only allowed as the first line"
                )
            if not isinstance(obj[META_KEY], dict):
                raise ValueError(f"trace line {lineno}: {META_KEY} is not an object")
            meta = obj[META_KEY]
            first_content_line = False
            continue
        first_content_line = False
        for key in _RESERVED:
            if key not in obj:
                raise ValueError(f"trace line {lineno}: missing {key!r}")
        if not isinstance(obj["rid"], int) or obj["rid"] < 0:
            raise ValueError(f"trace line {lineno}: bad rid {obj['rid']!r}")
        if not isinstance(obj["span"], str) or not obj["span"]:
            raise ValueError(f"trace line {lineno}: bad span {obj['span']!r}")
        t0, t1 = obj["t0"], obj["t1"]
        if not isinstance(t0, (int, float)) or not isinstance(t1, (int, float)):
            raise ValueError(f"trace line {lineno}: non-numeric t0/t1")
        if t1 < t0:
            raise ValueError(f"trace line {lineno}: t1 < t0 ({t1} < {t0})")
        n += 1
    return TraceCheck(n, meta)
