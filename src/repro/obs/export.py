"""Trace exporters: JSONL (replay-harness contract) + Chrome trace events.

JSONL — one span per line, the input format for the future trace-driven
replay harness (ROADMAP item 5). The contract, which ``validate_trace_jsonl``
enforces and tests pin:

    {"rid": int >= 0, "span": str, "t0": float, "t1": float >= t0, ...meta}

``rid`` joins a request's spans into one tree; ``span`` is the stage name
(normally from ``trace.STAGES`` — consumers must ignore unknown names);
``t0``/``t1`` are seconds on the shared monotonic clock (``obs.clock.now``),
same epoch across every line of one file. Remaining keys are stage metadata
(batch id/size, cache outcome, encoding, byte counts) and are optional.

Chrome trace-event JSON — the same spans as complete ("ph": "X") events,
viewable in Perfetto / chrome://tracing. Each stage gets its own lane
(tid), ordered by pipeline position, so a coalesce wave reads top-to-bottom
as admit → coalesce → render → ... with per-request args attached.
"""
from __future__ import annotations

import json
from typing import Iterable, Sequence

from repro.obs.trace import STAGES, Span

__all__ = [
    "spans_to_jsonl",
    "spans_to_chrome",
    "write_trace",
    "validate_trace_jsonl",
]

_RESERVED = ("rid", "span", "t0", "t1")


def spans_to_jsonl(spans: Iterable[Span]) -> str:
    """Render spans as JSONL (one compact object per line, trailing newline;
    empty string for no spans)."""
    lines = []
    for s in spans:
        obj = {"rid": s.rid, "span": s.name, "t0": s.t0, "t1": s.t1}
        for k, v in s.meta.items():
            if k not in _RESERVED:
                obj[k] = v
        lines.append(json.dumps(obj, separators=(",", ":"), default=str))
    return "\n".join(lines) + ("\n" if lines else "")


def spans_to_chrome(spans: Sequence[Span]) -> dict:
    """Render spans as a Chrome trace-event JSON object (Perfetto-viewable).

    One pid, one lane (tid) per stage in pipeline order; timestamps are
    microseconds relative to the earliest span so the viewport opens on the
    data instead of hours into an arbitrary epoch."""
    spans = list(spans)
    base = min((s.t0 for s in spans), default=0.0)
    lanes = {name: i + 1 for i, name in enumerate(STAGES)}
    events = []
    for name, tid in lanes.items():
        events.append({
            "name": "thread_name", "ph": "M", "pid": 1, "tid": tid,
            "args": {"name": f"{tid:02d}.{name}"},
        })
    for s in spans:
        tid = lanes.get(s.name)
        if tid is None:  # unknown stage -> shared overflow lane
            tid = len(STAGES) + 1
        ev = {
            "name": s.name,
            "ph": "X",
            "pid": 1,
            "tid": tid,
            "ts": round((s.t0 - base) * 1e6, 3),
            "dur": round(max(s.t1 - s.t0, 0.0) * 1e6, 3),
            "args": {"rid": s.rid, **s.meta},
        }
        events.append(ev)
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_trace(path: str, spans: Sequence[Span]) -> tuple[str, str]:
    """Write ``path`` (JSONL) and ``path`` with a ``.json`` suffix swapped in
    (Chrome trace events). Returns ``(jsonl_path, chrome_path)``."""
    spans = list(spans)
    jsonl_path = str(path)
    with open(jsonl_path, "w") as f:
        f.write(spans_to_jsonl(spans))
    stem = jsonl_path[: -len(".jsonl")] if jsonl_path.endswith(".jsonl") else jsonl_path
    chrome_path = stem + ".chrome.json"
    with open(chrome_path, "w") as f:
        json.dump(spans_to_chrome(spans), f)
    return jsonl_path, chrome_path


def validate_trace_jsonl(text: str) -> int:
    """Validate JSONL trace text against the schema contract; returns the
    number of span lines. Raises ``ValueError`` naming the first bad line."""
    n = 0
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        try:
            obj = json.loads(line)
        except json.JSONDecodeError as e:
            raise ValueError(f"trace line {lineno}: not JSON ({e})") from None
        if not isinstance(obj, dict):
            raise ValueError(f"trace line {lineno}: not an object")
        for key in _RESERVED:
            if key not in obj:
                raise ValueError(f"trace line {lineno}: missing {key!r}")
        if not isinstance(obj["rid"], int) or obj["rid"] < 0:
            raise ValueError(f"trace line {lineno}: bad rid {obj['rid']!r}")
        if not isinstance(obj["span"], str) or not obj["span"]:
            raise ValueError(f"trace line {lineno}: bad span {obj['span']!r}")
        t0, t1 = obj["t0"], obj["t1"]
        if not isinstance(t0, (int, float)) or not isinstance(t1, (int, float)):
            raise ValueError(f"trace line {lineno}: non-numeric t0/t1")
        if t1 < t0:
            raise ValueError(f"trace line {lineno}: t1 < t0 ({t1} < {t0})")
        n += 1
    return n
