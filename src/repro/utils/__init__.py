from repro.utils.tree import tree_bytes, tree_count, pack_pytree, unpack_pytree

__all__ = ["tree_bytes", "tree_count", "pack_pytree", "unpack_pytree"]
