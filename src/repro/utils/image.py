"""Minimal image IO (PPM — no imaging dependencies needed)."""
from __future__ import annotations

import numpy as np


def write_ppm(path: str, img) -> None:
    """Write an (H, W, 3) float image in [0, 1] as binary PPM (P6)."""
    arr = np.clip(np.asarray(img) * 255, 0, 255).astype(np.uint8)
    with open(path, "wb") as f:
        f.write(f"P6\n{arr.shape[1]} {arr.shape[0]}\n255\n".encode())
        f.write(arr.tobytes())
