"""Pytree utilities shared across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_count(tree) -> int:
    """Total number of elements across all leaves."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    """Total bytes across all leaves (uses each leaf's dtype)."""
    total = 0
    for x in jax.tree_util.tree_leaves(tree):
        total += int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
    return total


def pack_pytree(tree):
    """Flatten a pytree of arrays into one contiguous f32 vector.

    Used for the fused all-reduce: one collective over the packed gradient
    vector instead of one per tensor (the paper's "fused all-reduce scheme").
    Returns (vector, unpack_fn).
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = [l.shape for l in leaves]
    dtypes = [l.dtype for l in leaves]
    sizes = [int(np.prod(s)) for s in shapes]
    vec = jnp.concatenate([l.astype(jnp.float32).reshape(-1) for l in leaves]) if leaves else jnp.zeros((0,), jnp.float32)

    def unpack(v):
        out = []
        off = 0
        for s, shp, dt in zip(sizes, shapes, dtypes):
            out.append(v[off : off + s].reshape(shp).astype(dt))
            off += s
        return jax.tree_util.tree_unflatten(treedef, out)

    return vec, unpack


def unpack_pytree(vec, like):
    """Unpack a packed f32 vector into the structure/shapes/dtypes of `like`."""
    _, unpack = pack_pytree(like)
    return unpack(vec)
