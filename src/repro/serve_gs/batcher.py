"""Request queue + micro-batcher: coalesce concurrent camera requests.

Concurrent clients each want one frame; rendering them one at a time leaves
the accelerator idle between tiny dispatches. The batcher groups pending
requests by LOD level (different levels have different Gaussian counts, hence
different jit shapes) and emits micro-batches padded to a fixed set of bucket
sizes, so every (level, bucket) pair compiles exactly once.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Iterable

import numpy as np

from repro.core.projection import Camera
from repro.obs import new_request_id


@dataclasses.dataclass
class RenderRequest:
    """One client's frame request (host-side; leaves are numpy)."""

    cam: Camera
    level: int = 0
    t_submit: float = 0.0
    client_id: int = -1
    cache_key: tuple | None = None
    timestep: int = 0                    # timeline position (time-scrubbing)
    future: object | None = None         # FrameFuture delivering this frame
    row_levels: tuple | None = None      # per-tile-row LOD map (foveated frames)
    # ids come from the process-wide obs mint so a request keeps one id from
    # gateway admit through batcher queueing to span export
    request_id: int = dataclasses.field(default_factory=new_request_id)


@dataclasses.dataclass(frozen=True)
class MicroBatch:
    """A coalesced render call: ``cams`` is padded to ``bucket`` cameras."""

    level: int
    requests: tuple[RenderRequest, ...]  # the len(requests) real entries
    cams: Camera                         # stacked (bucket, ...) camera pytree
    bucket: int
    timestep: int = 0


def stack_cameras(cams: Iterable[Camera]) -> Camera:
    """Stack single cameras into one batched Camera pytree (numpy leaves)."""
    cams = list(cams)
    return Camera(*[
        np.stack([np.asarray(getattr(c, f), np.float32) for c in cams])
        for f in Camera._fields
    ])


def default_buckets(max_batch: int) -> tuple[int, ...]:
    """Powers of two up to ``max_batch`` (always including max_batch)."""
    b, out = 1, []
    while b < max_batch:
        out.append(b)
        b *= 2
    out.append(max_batch)
    return tuple(out)


class MicroBatcher:
    """FIFO-fair request queue emitting fixed-bucket micro-batches.

    Requests group by (timestep, level) — both select a distinct model on the
    device, so a micro-batch must be homogeneous in them. ``next_batch``
    drains up to ``max_batch`` requests of the group whose head request is
    oldest (so no group starves), then pads the camera stack to the smallest
    bucket >= the group size by repeating the last camera; the padded lanes
    are rendered and discarded.
    """

    def __init__(self, *, max_batch: int = 8, buckets: tuple[int, ...] | None = None):
        assert max_batch >= 1
        self.max_batch = max_batch
        self.buckets = tuple(sorted(buckets or default_buckets(max_batch)))
        assert self.buckets[-1] >= max_batch
        self._queues: dict[tuple[int, int], collections.deque[RenderRequest]] = collections.defaultdict(
            collections.deque
        )

    def submit(self, req: RenderRequest) -> int:
        self._queues[(req.timestep, req.level)].append(req)
        return req.request_id

    @property
    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def clear(self) -> int:
        """Drop every queued request (server shutdown); returns the count.
        The caller owns failing the dropped requests' futures."""
        n = self.pending
        self._queues.clear()
        return n

    def bucket_for(self, n: int) -> int:
        for b in self.buckets:
            if b >= n:
                return b
        return self.buckets[-1]

    def next_batch(self) -> MicroBatch | None:
        """Pop the oldest (timestep, level) group as one padded micro-batch
        (None if idle)."""
        live = [(q[0].request_id, key) for key, q in self._queues.items() if q]
        if not live:
            return None
        _, key = min(live)  # request ids are monotonic -> oldest head wins
        ts, lvl = key
        q = self._queues[key]
        reqs = [q.popleft() for _ in range(min(len(q), self.max_batch))]
        bucket = self.bucket_for(len(reqs))
        padded = reqs + [reqs[-1]] * (bucket - len(reqs))
        return MicroBatch(
            level=lvl,
            requests=tuple(reqs),
            cams=stack_cameras(r.cam for r in padded),
            bucket=bucket,
            timestep=ts,
        )
