"""The render server: queue -> LOD select -> cache -> batched jitted render.

Turns a trained ``GaussianModel`` into a service. Requests are admitted via
``submit`` (cache hits complete immediately); ``step`` drains one micro-batch
through the vmap-ed distributed render; ``run`` drains everything pending.
All orchestration is host-side Python — the device only ever sees fixed-shape
(level, bucket) batched render calls, so steady-state serving never recompiles.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as PS

from repro.core import gaussians as G
from repro.core.config import GSConfig
from repro.core.projection import Camera, look_at_camera
from repro.core.train import make_batched_eval_render
from repro.serve_gs.batcher import (
    MicroBatch,
    MicroBatcher,
    RenderRequest,
    default_buckets,
    stack_cameras,
)
from repro.serve_gs.cache import FrameCache, frame_key
from repro.serve_gs.lod import LODPyramid, build_lod_pyramid, select_level


def _percentile(xs: list[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else 0.0


class RenderServer:
    """Batched, LOD-aware, cached render service over a trained model."""

    def __init__(
        self,
        params: G.GaussianModel,
        cfg: GSConfig,
        *,
        mesh=None,
        n_levels: int = 3,
        keep_ratio: float = 0.5,
        max_batch: int = 8,
        buckets: tuple[int, ...] | None = None,
        cache_capacity: int = 512,
        pose_quantum: float = 1e-3,
        store_frames: bool = True,
    ):
        self.cfg = cfg
        self.mesh = mesh if mesh is not None else jax.make_mesh((1, 1), ("data", "model"))
        self.pose_quantum = pose_quantum
        self.store_frames = store_frames

        # Micro-batches shard over the mesh's data axis, so every bucket must
        # be a multiple of it: a d-device data axis renders a bucket-d batch
        # one view per device — batching IS the data parallelism.
        d = self.mesh.shape["data"]
        max_batch = d * max(-(-max_batch // d), 1)  # round up to a multiple of d
        if buckets is None:
            buckets = tuple(d * b for b in default_buckets(max(max_batch // d, 1)))
        assert all(b % d == 0 for b in buckets), (buckets, d)

        self.pyramid: LODPyramid = build_lod_pyramid(
            params, n_levels=n_levels, keep_ratio=keep_ratio, pad_quantum=cfg.pad_quantum
        )
        shard = NamedSharding(self.mesh, PS("model"))
        self._level_params = tuple(
            jax.device_put(lvl, G.GaussianModel(*([shard] * 5))) for lvl in self.pyramid.levels
        )
        # A level with keep_ratio**k of the Gaussians needs proportionally
        # fewer splats per tile: compositing is O(tiles x k_per_tile) and is
        # the dominant render term, so shrinking K is what actually makes a
        # coarse level cheap (pruning alone only shrinks project/sort/bin).
        self._level_cfgs = tuple(
            dataclasses.replace(
                cfg,
                k_per_tile=max(int(cfg.k_per_tile * keep_ratio**lvl), 32),
            )
            for lvl in range(self.pyramid.n_levels)
        )
        self._level_render = tuple(
            make_batched_eval_render(self.mesh, c) for c in self._level_cfgs
        )

        self.batcher = MicroBatcher(max_batch=max_batch, buckets=buckets)
        self.cache = FrameCache(cache_capacity)
        self.frames: dict[int, np.ndarray] = {}

        # ---- metrics
        self._latencies: list[float] = []
        self._render_s = 0.0
        self._render_calls = 0
        self._level_requests = [0] * self.pyramid.n_levels
        self._batch_sizes: list[int] = []
        self._t_first: float | None = None
        self._t_last: float | None = None
        self.completed = 0

    def warmup(self, buckets: tuple[int, ...] | None = None) -> float:
        """Pre-compile every (level, bucket) render variant; returns seconds.

        Serving latency then never includes a jit trace — the cold-start cost
        is paid here, before the first client connects. Does not touch the
        serving metrics or the cache.
        """
        buckets = buckets or self.batcher.buckets
        c = self.pyramid.scene_center
        eye = c + np.float32([0.0, 0.0, 3.0 * self.pyramid.scene_extent])
        cam = look_at_camera(
            eye, c, [0.0, 1.0, 0.0],
            self.cfg.img_w, self.cfg.img_w, self.cfg.img_w / 2, self.cfg.img_h / 2,
        )
        cam = Camera(*[np.asarray(x) for x in cam])
        t0 = time.perf_counter()
        for lp, render in zip(self._level_params, self._level_render):
            for b in buckets:
                jax.block_until_ready(render(lp, stack_cameras([cam] * b)))
        return time.perf_counter() - t0

    # ------------------------------------------------------------------ admit
    def submit(self, cam: Camera, *, client_id: int = -1, t_submit: float | None = None) -> int:
        """Admit one camera request; returns its request id.

        Cache hits complete synchronously (the frame is already on the host);
        misses are queued for the next micro-batch.
        """
        t = time.perf_counter() if t_submit is None else t_submit
        if self._t_first is None:
            self._t_first = t
        level = select_level(self.pyramid, cam, img_w=self.cfg.img_w)
        key = frame_key(cam, level, pose_quantum=self.pose_quantum)
        req = RenderRequest(cam=cam, level=level, t_submit=t, client_id=client_id, cache_key=key)
        self._level_requests[level] += 1

        frame = self.cache.get(key)
        if frame is not None:
            self._complete(req, frame)
            return req.request_id
        self.batcher.submit(req)
        return req.request_id

    # ------------------------------------------------------------------ serve
    def step(self) -> int:
        """Render one micro-batch; returns the number of requests completed."""
        mb: MicroBatch | None = self.batcher.next_batch()
        if mb is None:
            return 0
        t0 = time.perf_counter()
        imgs = self._level_render[mb.level](
            self._level_params[mb.level], jax.tree_util.tree_map(np.asarray, mb.cams)
        )
        imgs = np.asarray(jax.block_until_ready(imgs))
        self._render_s += time.perf_counter() - t0
        self._render_calls += 1
        self._batch_sizes.append(len(mb.requests))
        for i, req in enumerate(mb.requests):
            frame = imgs[i].copy()  # own buffer: never pin the whole batch
            self.cache.put(req.cache_key, frame)
            self._complete(req, frame)
        return len(mb.requests)

    def run(self) -> int:
        """Drain the queue; returns total requests completed by this call."""
        done = 0
        while self.batcher.pending:
            done += self.step()
        return done

    def _complete(self, req: RenderRequest, frame: np.ndarray) -> None:
        now = time.perf_counter()
        self._t_last = now
        self._latencies.append(now - req.t_submit)
        self.completed += 1
        if self.store_frames:
            self.frames[req.request_id] = frame

    # ---------------------------------------------------------------- metrics
    def report(self) -> dict:
        wall = (self._t_last - self._t_first) if (self._t_first is not None and self._t_last) else 0.0
        lat_ms = [x * 1e3 for x in self._latencies]
        return {
            "completed": self.completed,
            "wall_s": round(wall, 4),
            "frames_per_s": round(self.completed / wall, 2) if wall > 0 else float("inf"),
            "latency_ms": {
                "p50": round(_percentile(lat_ms, 50), 3),
                "p99": round(_percentile(lat_ms, 99), 3),
                "max": round(max(lat_ms), 3) if lat_ms else 0.0,
            },
            "render": {
                "calls": self._render_calls,
                "total_s": round(self._render_s, 4),
                "mean_batch": round(float(np.mean(self._batch_sizes)), 2) if self._batch_sizes else 0.0,
            },
            "cache": self.cache.stats(),
            "lod": {
                "live_counts": list(self.pyramid.live_counts),
                "padded_counts": [lvl.n for lvl in self.pyramid.levels],
                "requests_per_level": list(self._level_requests),
            },
        }
