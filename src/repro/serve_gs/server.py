"""The render server: queue -> LOD select -> dedup -> pipelined batched render.

Turns trained ``GaussianModel``s into a service. Requests are admitted via
``submit``, which returns a :class:`FrameFuture` (cache hits come back already
resolved); ``step`` advances the dispatch pipeline by one unit; ``run`` drains
everything pending. All orchestration is host-side Python — the device only
ever sees fixed-shape (level, bucket) batched render calls, so steady-state
serving never recompiles.

**Pipelined dispatch.** The serve loop is a bounded in-flight ring of depth
``pipeline_depth`` (default 2). ``step`` first *dispatches* micro-batches —
the jitted render call returns immediately under jax's asynchronous dispatch,
leaving the batch executing on-device — until the ring is full, then *retires*
the oldest in-flight batch: block on its device buffers, copy frames out, fill
the cache, resolve futures. While the device renders batch N the host is
therefore postprocessing batch N-1 and assembling batch N+1; the host only
blocks when the ring is full or a future is awaited. ``pipeline_depth=1`` is
the old synchronous dispatch-then-block loop, preserved bit-for-bit.

**In-flight dedup.** A pending-key table maps each in-flight ``frame_key`` to
its future: submitting a pose that quantizes onto an in-flight render attaches
the new request to the existing future instead of rendering twice (the
cross-request dedup the cache alone cannot provide — the first render has not
landed yet, so the cache misses).

**Tile-granular serving.** With ``tile_cache=True`` (the default) the frame
is the unit of *assembly*, not the unit of work: retired frames are stored in
the cache as their grid of rasterizer tiles (content-deduplicated, byte
budgeted — see ``cache.py``), ``submit`` probes the tile grid, and a pose
whose tiles are only *partially* cached renders **only the missing tile
rows** (``make_tile_row_render`` strips, bit-identical to the same rows of
the full-frame render) before assembling the frame. Partial hits arise from
byte-budget eviction and — the paper's in situ story — from *partial
invalidation*: ``add_timestep(..., changed=<slot indices>)`` projects the
changed Gaussians' conservative screen bounds through every cached pose and
drops only the tile rows the update can touch (``dirty_rows=`` remains the
manual escape hatch), so revisiting a pose after a localized simulation
update re-renders a few rows instead of the frame. Requests may also opt
into **foveated per-tile LOD** (``submit(..., gaze=, budget_ms=)``): tile
rows get their own pyramid level, mixed-level frames assemble from the same
per-(tile, level) cache entries uniform frames populate.
``tile_cache=False`` is the whole-frame baseline, preserved bit-for-bit.

The server holds a *timeline*: timestep -> (LOD pyramid, device params).
Static scenes are the one-entry special case (timestep 0, the default).
Streaming reconstructions (``repro.insitu``) register one model per simulation
timestep via ``add_timestep``, and clients scrub time by submitting the same
camera with different ``timestep`` values — each (timestep, level, pose) is a
distinct cacheable frame. The jitted render fns are shared across the whole
timeline (they are shape-keyed): a fixed-capacity insitu sequence reuses one
trace per (level, bucket) for every timestep.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import NamedTuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as PS

from repro.core import gaussians as G
from repro.core.config import GSConfig
from repro.core.projection import Camera
from repro.core.train import make_batched_eval_render, make_tile_row_render
from repro.obs import DEFAULT_SIZE_BUCKETS, Obs
from repro.obs.clock import now as _now
from repro.serve_gs.batcher import (
    MicroBatch,
    MicroBatcher,
    RenderRequest,
    default_buckets,
    stack_cameras,
)
from repro.serve_gs.cache import ASSEMBLED, FrameCache, frame_key, quantize_camera, tile_key
from repro.serve_gs.footprint import changed_indices, dirty_row_map
from repro.serve_gs.lod import (
    LODPyramid,
    build_lod_pyramid,
    front_camera,
    select_level,
    select_level_map,
)


def _percentile(xs: list[float], q: float) -> float:
    """Exact percentile over a raw sample list (benchmark clients keep raw
    client-side latency samples; the serving tiers use registry histograms)."""
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else 0.0


class FrameFuture:
    """Host-side handle for one (possibly still in-flight) frame.

    Every ``submit`` returns one; requests whose ``frame_key`` matches an
    in-flight render share a single future (in-flight dedup), so ``requests``
    may hold several waiters. ``result()`` drives the server's pipeline until
    the frame lands; the returned array is **read-only** (it is shared with
    the cache and every deduped waiter) — ``.copy()`` it to mutate.
    """

    __slots__ = ("key", "requests", "_frame", "_error", "_server")

    def __init__(self, server: "RenderServer", key: tuple, req: RenderRequest):
        self.key = key
        self.requests: list[RenderRequest] = [req]
        self._frame: np.ndarray | None = None
        self._error: BaseException | None = None
        self._server = server

    @property
    def request_id(self) -> int:
        """Id of the primary (first-submitted) request."""
        return self.requests[0].request_id

    def done(self) -> bool:
        return self._frame is not None or self._error is not None

    def result(self) -> np.ndarray:
        """The frame, blocking (and driving the pipeline) until it lands.

        Raises the failure instead if the future was failed (e.g. the server
        was closed while this request was still queued)."""
        while self._frame is None:
            if self._error is not None:
                raise self._error
            if not self._server._advance():
                raise RuntimeError(
                    f"FrameFuture {self.key} cannot resolve: server pipeline is idle"
                )
        return self._frame

    # -------------------------------------------------------------- internal
    def _attach(self, req: RenderRequest) -> None:
        assert not self.done(), "cannot attach to a resolved future"
        self.requests.append(req)

    def _fail(self, err: BaseException) -> None:
        """Mark every attached request as failed; ``result()`` raises."""
        assert self._frame is None, "cannot fail a resolved future"
        self._error = err

    def _resolve(self, frame: np.ndarray) -> int:
        """Deliver ``frame`` to every attached request; returns the count."""
        self._frame = frame
        for req in self.requests:
            self._server._complete(req, frame)
        return len(self.requests)


@dataclasses.dataclass
class _InFlight:
    """One dispatched-but-not-retired micro-batch in the pipeline ring."""

    mb: MicroBatch
    imgs: jax.Array          # device buffers; not blocked on until retire
    t_dispatch: float


@dataclasses.dataclass
class _PartialJob:
    """One partially-cached frame awaiting its missing tile rows.

    ``tiles`` is the frame's full tile grid (row-major flat); ``None`` slots
    are the tiles a strip render must fill. The job pins its cached tiles, so
    later eviction cannot take them back out from under the assembly."""

    req: RenderRequest
    fut: "FrameFuture"
    tiles: list
    # foveated frames: per-tile-row LOD levels and the uniform-level frame
    # keys whose tile entries the rows share (None -> uniform at req.level)
    row_levels: tuple | None = None
    row_keys: tuple | None = None


class TimestepModels(NamedTuple):
    """One timeline entry: the pyramid and its device-resident levels."""

    pyramid: LODPyramid
    level_params: tuple[G.GaussianModel, ...]  # device arrays, model-sharded


class RenderServer:
    """Batched, LOD-aware, cached, pipelined render service over a timeline."""

    def __init__(
        self,
        params: G.GaussianModel,
        cfg: GSConfig,
        *,
        mesh=None,
        n_levels: int = 3,
        keep_ratio: float = 0.5,
        max_batch: int = 8,
        buckets: tuple[int, ...] | None = None,
        cache_capacity: int = 512,
        cache_bytes: int | None = None,
        tile_cache: bool = True,
        pose_quantum: float = 1e-3,
        store_frames: bool = True,
        frames_capacity: int = 256,
        pipeline_depth: int = 2,
        timestep: int = 0,
        pose_registry_cap: int = 512,
        obs: Obs | None = None,
    ):
        self.cfg = cfg
        # the observability bundle every tier of this stack shares: one
        # metrics registry (atomic snapshot, one reset) + the span recorder
        # (falsy NULL_RECORDER unless tracing is enabled)
        self.obs = obs if obs is not None else Obs()
        self.mesh = mesh if mesh is not None else jax.make_mesh((1, 1), ("data", "model"))
        self.pose_quantum = pose_quantum
        self.store_frames = store_frames
        self.frames_capacity = max(int(frames_capacity), 1)
        assert pipeline_depth >= 1, pipeline_depth
        self.pipeline_depth = int(pipeline_depth)
        self.n_levels = n_levels
        self.keep_ratio = keep_ratio

        # ---- tile geometry (the rasterizer's tiling, reused as cache grid)
        self.tile_cache = bool(tile_cache)
        self.tile_h, self.tile_w = int(cfg.tile_h), int(cfg.tile_w)
        if self.tile_cache:
            assert cfg.img_h % self.tile_h == 0 and cfg.img_w % self.tile_w == 0, (
                "tile-granular caching needs the image to tile evenly "
                f"({cfg.img_h}x{cfg.img_w} vs {self.tile_h}x{self.tile_w}); "
                "pass tile_cache=False for ragged configs"
            )
        self.tiles_y = cfg.img_h // self.tile_h
        self.tiles_x = cfg.img_w // self.tile_w
        self.n_tiles = self.tiles_y * self.tiles_x

        # Micro-batches shard over the mesh's data axis, so every bucket must
        # be a multiple of it: a d-device data axis renders a bucket-d batch
        # one view per device — batching IS the data parallelism.
        d = self.mesh.shape["data"]
        max_batch = d * max(-(-max_batch // d), 1)  # round up to a multiple of d
        if buckets is None:
            buckets = tuple(d * b for b in default_buckets(max(max_batch // d, 1)))
        assert all(b % d == 0 for b in buckets), (buckets, d)

        self._shard = NamedSharding(self.mesh, PS("model"))
        # A level with keep_ratio**k of the Gaussians needs proportionally
        # fewer splats per tile: compositing is O(tiles x k_per_tile) and is
        # the dominant render term, so shrinking K is what actually makes a
        # coarse level cheap (pruning alone only shrinks project/sort/bin).
        self._level_cfgs = tuple(
            dataclasses.replace(
                cfg,
                k_per_tile=max(int(cfg.k_per_tile * keep_ratio**lvl), 32),
            )
            for lvl in range(n_levels)
        )
        # one render fn per level, shared by every timeline entry — jit
        # retraces only if a timestep brings a new padded Gaussian count
        self._level_render = tuple(
            make_batched_eval_render(self.mesh, c) for c in self._level_cfgs  # analysis: allow(retrace.factory_in_loop, one factory call per LOD level at construction; cached in _level_render for the server lifetime)
        )

        # Pose registry: every pose that ever populated the tile cache, keyed
        # by its quantized-camera signature (the pose part of the cache key).
        # World-space invalidation projects changed Gaussians through these
        # cameras to find each pose's dirty tile rows. Bounded LRU: an entry
        # evicted here makes that pose's cached tiles *conservatively* dropped
        # on the next world-space invalidation (unknown pose -> assume dirty).
        self.pose_registry_cap = max(int(pose_registry_cap), 1)
        self._poses: collections.OrderedDict[tuple, Camera] = collections.OrderedDict()
        # EWMA of the wall cost of one level-0 tile row (ms), level-normalized
        # (a level-l row counts as keep_ratio**l of a row); calibrates the
        # budget_ms -> budget_rows mapping for foveated requests
        self._row_cost_ms: float | None = None

        self._timeline: dict[int, TimestepModels] = {}
        self._first_timestep = int(timestep)
        self.add_timestep(timestep, params)

        self.batcher = MicroBatcher(max_batch=max_batch, buckets=buckets)
        # Capacity is a byte budget: tile entries are far smaller and more
        # numerous than frames, so an entry count is meaningless across
        # granularities. ``cache_capacity`` (frames) preserves the historical
        # "N cached poses" meaning: a tile-cached pose costs up to TWO frame
        # equivalents (its tiles + the zero-copy stitched frame), so the
        # conversion doubles in tile mode; content dedup claws much of the
        # tile half back. ``cache_bytes`` sets the budget directly.
        # Either at 0 disables caching.
        frame_nbytes = cfg.img_h * cfg.img_w * 3 * 4  # float32 RGB
        per_pose = frame_nbytes * (2 if self.tile_cache else 1)
        self.cache = FrameCache(
            capacity=None,  # the byte budget is the bound, not entry count
            capacity_bytes=int(cache_bytes) if cache_bytes is not None
            else int(cache_capacity) * per_pose,
            # content dedup pays at tile granularity (shared background
            # tiles); whole frames essentially never collide, so the
            # baseline skips the per-put hash entirely
            dedup=self.tile_cache,
            metrics=self.obs.metrics,
        )
        # bounded retirement buffer of recently served frames (request_id ->
        # frame); a sustained-load server must not pin every frame ever served
        self.frames: collections.OrderedDict[int, np.ndarray] = collections.OrderedDict()

        # ---- pipeline state
        self._ring: collections.deque[_InFlight] = collections.deque()
        self._pending: dict[tuple, FrameFuture] = {}  # in-flight key -> future
        self._partial: collections.deque[_PartialJob] = collections.deque()
        self._strip_renders: dict[tuple[int, int], object] = {}  # (level, row)
        self._invalidation_listeners: list = []
        self._closed = False

        # ---- metrics: typed registry entries under server.* (see repro.obs).
        # Everything here is a WINDOW quantity — one registry.reset() zeroes
        # it across this tier and every other tier sharing the registry.
        m = self.obs.metrics
        self._completed = m.counter("server.completed")
        self._deduped = m.counter("server.deduped")
        self._c_render_s = m.counter("server.render_s")
        self._c_dispatch_s = m.counter("server.dispatch_s")
        self._c_block_s = m.counter("server.block_s")
        self._render_calls = m.counter("server.render_calls")
        self._latency_ms = m.histogram("server.latency_ms")
        self._batch_sizes = m.histogram("server.batch_size", DEFAULT_SIZE_BUCKETS)
        self._occupancy = m.histogram("server.occupancy", DEFAULT_SIZE_BUCKETS)
        # ---- tile-path metrics (frame-granular; the cache's own hit/miss
        # counters are per-TILE once tile_cache is on)
        self._full_hits = m.counter("server.full_hits")        # resolved at submit
        self._partial_hits = m.counter("server.partial_hits")  # missing rows render
        self._frame_misses = m.counter("server.frame_misses")  # full render
        self._rows_rendered = m.counter("server.rows_rendered_partial")
        self._render_rows = m.counter("server.render_rows")
        # ---- LOD metrics: per-level request/row tallies live in the shared
        # registry (dotted names) so level decisions show up in snapshot()
        # and traces; `level_requests` below keeps the historical list read.
        self._c_level_requests = tuple(
            m.counter(f"server.level_requests.l{lvl}") for lvl in range(n_levels)
        )
        self._c_lod_rows = tuple(
            m.counter(f"server.lod_rows.l{lvl}") for lvl in range(n_levels)
        )
        self._c_foveated = m.counter("server.foveated_requests")
        # window state the registry can't hold (distributions over dynamic
        # key sets, window timestamps) — cleared by the same reset() via hook
        self._busy_until = 0.0  # end of the last retired in-flight window
        self._timestep_requests: dict[int, int] = {}
        self._t_first: float | None = None
        self._t_last: float | None = None
        m.on_reset(self._reset_window_state)

    def _reset_window_state(self) -> None:
        """registry.reset() hook: clear the window state held outside it.
        (``_timestep_requests`` stays host-side because its key set — the
        timeline — is dynamic; the fixed-arity per-level tallies moved into
        the registry as ``server.level_requests.l*`` / ``server.lod_rows.l*``.)"""
        self._busy_until = 0.0
        self._timestep_requests = {}
        self._t_first = self._t_last = None

    # historical attribute reads, now backed by the shared registry
    @property
    def completed(self) -> int:
        return self._completed.value

    @property
    def deduped(self) -> int:
        return self._deduped.value

    @property
    def full_hits(self) -> int:
        return self._full_hits.value

    @property
    def partial_hits(self) -> int:
        return self._partial_hits.value

    @property
    def frame_misses(self) -> int:
        return self._frame_misses.value

    @property
    def rows_rendered(self) -> int:
        return self._rows_rendered.value

    @property
    def render_rows(self) -> int:
        return self._render_rows.value

    @property
    def level_requests(self) -> list[int]:
        """Per-level request tally (read-only view of the registry counters
        ``server.level_requests.l*``; the historical attribute shape)."""
        return [c.value for c in self._c_level_requests]

    # first-entry aliases — the pre-timeline (static scene) public surface;
    # properties so they track add_timestep() re-registering the first entry
    @property
    def pyramid(self) -> LODPyramid:
        return self._timeline[self._first_timestep].pyramid

    @property
    def _level_params(self) -> tuple[G.GaussianModel, ...]:
        return self._timeline[self._first_timestep].level_params

    @property
    def n_traces(self) -> int:
        """Total jit traces across the per-level render fns (the serving
        recompile counter: steady-state serving must never grow this)."""
        try:
            return sum(int(f._cache_size()) for f in self._level_render)
        except (AttributeError, TypeError):  # pragma: no cover - cache introspection API drift
            return -1

    @property
    def strip_traces(self) -> int:
        """Compiled tile-row render variants (the partial-hit path); kept
        separate from ``n_traces`` because strips are built lazily per
        (level, row) and are not part of the steady-state full-frame budget."""
        return len(self._strip_renders)

    @property
    def in_flight(self) -> int:
        """Dispatched-but-not-retired micro-batches currently on the ring."""
        return len(self._ring)

    # --------------------------------------------------------------- timeline
    def add_timestep(
        self, timestep: int, params: G.GaussianModel, *, changed=None, dirty_rows=None
    ) -> TimestepModels:
        """Register a model for one timeline position. Re-registering an
        existing timestep replaces the model AND invalidates its cached
        frames (stale frames must not outlive the model that rendered them).

        ``changed`` is the in situ fast path and needs **no caller-side row
        math**: pass the indices of the Gaussian slots the update rewrote
        (or ``True`` to have the server diff old vs new parameters itself)
        and the server projects those Gaussians' conservative screen bounds
        — under the old *and* new parameters — through **every registered
        cached pose** to compute the dirty tile rows per pose. Only those
        tiles are dropped; clean tiles survive and the next request
        partial-renders just the dirty rows. Poses missing from the bounded
        registry (evicted) and non-tile-cache servers fall back to a full
        drop of the timestep, so ``changed`` is always safe to pass.

        ``dirty_rows`` is the legacy manual escape hatch (tile-cache servers
        only): an explicit iterable of screen tile-row indices to drop for
        every pose, for callers that computed the footprint themselves. The
        two are mutually exclusive; omitting both drops the whole timestep.
        """
        if changed is not None and dirty_rows is not None:
            raise ValueError("pass either changed= or dirty_rows=, not both")
        cache = getattr(self, "cache", None)  # absent during __init__'s first entry
        if cache is not None and int(timestep) in self._timeline:
            if dirty_rows is not None:
                self.invalidate(timestep, rows=dirty_rows)
            elif changed is not None:
                self._invalidate_changed(timestep, self._timeline[int(timestep)], params, changed)
            else:
                self.invalidate(timestep)
        pyramid = build_lod_pyramid(
            params,
            n_levels=self.n_levels,
            keep_ratio=self.keep_ratio,
            pad_quantum=self.cfg.pad_quantum,
        )
        level_params = tuple(
            jax.device_put(lvl, G.GaussianModel(*([self._shard] * 5))) for lvl in pyramid.levels
        )
        entry = TimestepModels(pyramid, level_params)
        self._timeline[int(timestep)] = entry
        return entry

    def timesteps(self) -> list[int]:
        return sorted(self._timeline)

    # ----------------------------------------------------------- invalidation
    def add_invalidation_listener(self, cb) -> None:
        """Register ``cb(timestep, rows)`` to fire after any cache
        invalidation of that timeline position (model replacement or explicit
        ``invalidate``). ``rows`` is ``None`` for a whole-frame drop or the
        frozenset of dirty screen tile-rows for a partial one. The frontend
        uses this to reset per-stream delta-encode chains — row-granular
        resets re-key only the dirty tiles on the wire."""
        self._invalidation_listeners.append(cb)

    def _notify_invalidation(self, ts: int, rows: frozenset | None) -> None:
        for cb in self._invalidation_listeners:
            cb(ts, rows)

    def invalidate(self, timestep: int, *, rows=None) -> int:
        """Drop cached frames of ``timestep`` — all of them, or (tile-cache
        servers) only the tiles in screen tile-rows ``rows``. Returns the
        number of cache entries dropped. In-flight and partially-assembled
        work is drained first, so a stale render can never land after its
        invalidation. Passing ``rows`` on a ``tile_cache=False`` server
        raises: the whole-frame cache cannot honor a row-granular drop, and
        silently widening it to the full frame would hide the caller's wrong
        assumption about what stayed cached."""
        if rows is not None and not self.tile_cache:
            raise ValueError(
                "invalidate(rows=...) needs tile_cache=True — a whole-frame "
                "cache has no row-granular entries to drop; call "
                "invalidate(timestep) for the full drop"
            )
        self.flush()  # old-model batches/partials must not outlive the drop
        ts = int(timestep)
        if rows is None:
            n = self.cache.drop(lambda k: k[0] == ts)
            self._notify_invalidation(ts, None)
        else:
            # dirty tiles go, and so does every ASSEMBLED frame of the
            # timestep — a stitched frame contains its dirty rows
            rset = frozenset(int(r) for r in rows)
            n = self.cache.drop(
                lambda k: k[0] == ts
                and (k[-1] == ASSEMBLED or (k[-1] // self.tiles_x) in rset)
            )
            self._notify_invalidation(ts, rset)
        return n

    def _invalidate_changed(
        self, timestep: int, old_entry: TimestepModels, new_params: G.GaussianModel, changed
    ) -> int:
        """World-space invalidation: drop exactly the tiles the changed
        Gaussians can touch, computed per cached pose from their projected
        bounds under the old and new parameters (see ``serve_gs.footprint``).
        Falls back to a full drop whenever row math cannot be trusted: no
        tile cache, a capacity (shape) change, or no registered poses."""
        ts = int(timestep)
        old = old_entry.pyramid.levels[0]  # full model, host numpy leaves
        new = G.GaussianModel(*[np.asarray(x) for x in new_params])
        if not self.tile_cache:
            return self.invalidate(ts)
        if any(np.asarray(getattr(old, f)).shape != np.asarray(getattr(new, f)).shape
               for f in old._fields):
            return self.invalidate(ts)  # capacity change: no per-slot diff exists
        idx = changed_indices(old, new) if changed is True else np.asarray(changed).reshape(-1)
        if idx.size == 0:
            return 0  # bit-identical re-registration: nothing can differ
        if not self._poses:
            return self.invalidate(ts)
        dirty = dirty_row_map(
            old, new, idx, self._poses,
            img_h=self.cfg.img_h, img_w=self.cfg.img_w, tile_h=self.tile_h,
        )
        return self._invalidate_per_pose(ts, dirty)

    def _invalidate_per_pose(self, timestep: int, dirty_map: dict) -> int:
        """Drop each cached pose's own dirty tile rows (``dirty_map``:
        pose signature -> frozenset of rows). Entries whose pose is not in
        the map (evicted from the registry) are dropped whole — conservative,
        never stale. Listeners get the across-pose union (``None`` if any
        pose was unknown, forcing full downstream resets)."""
        self.flush()
        ts = int(timestep)
        unknown_pose = False

        def doomed(k: tuple) -> bool:
            nonlocal unknown_pose
            if k[0] != ts:
                return False
            rows = dirty_map.get(tuple(k[4:-1]))
            if rows is None:
                unknown_pose = True
                return True
            if not rows:
                return False
            return k[-1] == ASSEMBLED or (k[-1] // self.tiles_x) in rows

        n = self.cache.drop(doomed)
        union: set[int] = set()
        for rows in dirty_map.values():
            union |= rows
        self._notify_invalidation(ts, None if unknown_pose else frozenset(union))
        return n

    def _entry(self, timestep: int) -> TimestepModels:
        try:
            return self._timeline[int(timestep)]
        except KeyError:
            raise KeyError(
                f"timestep {timestep} not on the timeline (have {self.timesteps()})"
            ) from None

    def warmup(self, buckets: tuple[int, ...] | None = None, *, timesteps=None) -> float:
        """Pre-compile every (level, bucket) render variant; returns seconds.

        Serving latency then never includes a jit trace — the cold-start cost
        is paid here, before the first client connects. One timestep suffices
        when the timeline is shape-uniform (fixed-capacity insitu sequences);
        pass ``timesteps`` to force-warm entries with distinct shapes. Does
        not touch the serving metrics or the cache.
        """
        buckets = buckets or self.batcher.buckets
        t0 = _now()
        for ts in timesteps if timesteps is not None else [self.timesteps()[0]]:
            entry = self._entry(ts)
            cam = front_camera(entry.pyramid, img_h=self.cfg.img_h, img_w=self.cfg.img_w)
            for lvl, lp in enumerate(entry.level_params):
                for b in buckets:
                    jax.block_until_ready(self._level_render[lvl](lp, stack_cameras([cam] * b)))
        return _now() - t0

    # ------------------------------------------------------------------ admit
    def _note_pose(self, sig: tuple, cam: Camera) -> None:
        """Record a served pose in the bounded registry (LRU by use)."""
        if sig in self._poses:
            self._poses.move_to_end(sig)
            return
        self._poses[sig] = jax.tree_util.tree_map(np.asarray, cam)
        while len(self._poses) > self.pose_registry_cap:
            self._poses.popitem(last=False)

    def submit(
        self,
        cam: Camera,
        *,
        timestep: int = 0,
        client_id: int = -1,
        t_submit: float | None = None,
        request_id: int | None = None,
        gaze: tuple | None = None,
        budget_ms: float | None = None,
    ) -> FrameFuture:
        """Admit one camera request; returns its :class:`FrameFuture`.

        Cache hits resolve immediately (the frame is already on the host);
        requests matching an *in-flight* key attach to the existing future
        (one render serves every concurrent duplicate); everything else is
        queued for the next micro-batch.

        ``gaze`` (normalized ``(x, y)`` in [0, 1]) and/or ``budget_ms`` opt a
        request into **foveated per-tile LOD** on tile-cache servers: tile
        rows near the gaze render at the coverage level, peripheral rows one
        level coarser per row of distance, and ``budget_ms`` shrinks the
        sharp zone until the estimated render cost fits (calibrated by a
        running per-row cost estimate; best-effort, never a hard deadline).
        Mixed-level frames assemble from the same per-(tile, level) cache
        entries uniform frames use, so a foveated request reuses every
        already-rendered tile at its assigned level and strip-renders only
        the rest. On ``tile_cache=False`` servers the hints are ignored
        (whole-frame serving has a single level per frame).

        ``request_id`` carries an id minted upstream (the gateway mints at
        admit) so the span tree keeps one id end to end; in-process callers
        omit it and the request mints its own.
        """
        if self._closed:
            raise RuntimeError("RenderServer is closed")
        t = _now() if t_submit is None else t_submit
        if self._t_first is None:
            self._t_first = t
        entry = self._entry(timestep)
        n_lvl = len(entry.level_params)  # built pyramid depth (may be < n_levels)
        level = min(select_level(entry.pyramid, cam, img_w=self.cfg.img_w), n_lvl - 1)
        row_levels = row_keys = None
        if (gaze is not None or budget_ms is not None) and self.tile_cache and not self.cache.disabled:
            gaze_row = None
            if gaze is not None:
                gaze_row = min(max(int(float(gaze[1]) * self.tiles_y), 0), self.tiles_y - 1)
            budget_rows = None
            if budget_ms is not None and self._row_cost_ms:
                budget_rows = float(budget_ms) / self._row_cost_ms
            rl = select_level_map(
                entry.pyramid, cam, img_w=self.cfg.img_w, tiles_y=self.tiles_y,
                gaze_row=gaze_row, budget_rows=budget_rows,
                n_levels=n_lvl, keep_ratio=self.keep_ratio,
            )
            if len(set(rl)) == 1:
                level = rl[0]  # degenerate map: the uniform path serves it
            else:
                row_levels = rl
                level = min(rl)  # the sharpest level present (gaze rows)
        if row_levels is None:
            key = frame_key(
                cam, level, height=self.cfg.img_h, width=self.cfg.img_w,
                timestep=timestep, pose_quantum=self.pose_quantum,
            )
        else:
            # Mixed-level frame key: same layout as frame_key — (timestep,
            # <level slot>, h, w) + pose signature — with the level slot
            # holding the whole row-level map. Its ASSEMBLED entry caches the
            # stitched result; the per-tile entries live under the *uniform*
            # keys of each row's level, shared with uniform-level frames.
            sig = quantize_camera(cam, pose_quantum=self.pose_quantum)
            key = (int(timestep), ("fov",) + row_levels, self.cfg.img_h, self.cfg.img_w) + sig
            uniq = {
                lvl: frame_key(
                    cam, lvl, height=self.cfg.img_h, width=self.cfg.img_w,
                    timestep=timestep, pose_quantum=self.pose_quantum,
                )
                for lvl in set(row_levels)
            }
            row_keys = tuple(uniq[lvl] for lvl in row_levels)
        kw = {} if request_id is None else {"request_id": int(request_id)}
        req = RenderRequest(
            cam=cam, level=level, t_submit=t, client_id=client_id, cache_key=key,
            timestep=int(timestep), row_levels=row_levels, **kw,
        )
        self._c_level_requests[level].inc()
        if self.tile_cache:
            self._note_pose(tuple(key[4:]), cam)
            if row_levels is None:
                self._c_lod_rows[level].inc(self.tiles_y)
            else:
                self._c_foveated.inc()
                for lvl in row_levels:
                    self._c_lod_rows[lvl].inc()
        self._timestep_requests[int(timestep)] = self._timestep_requests.get(int(timestep), 0) + 1
        rec = self.obs.trace

        tiles = None
        if self.tile_cache and not self.cache.disabled:
            # fast path: the stitched frame itself is cached (zero-copy hit)
            frame = self.cache.get(tile_key(key, ASSEMBLED))
            if frame is not None:
                self._full_hits.inc()
                if rec:
                    rec.record(req.request_id, "submit", t, _now(),
                               outcome="full_hit", level=level, timestep=int(timestep))
                fut = FrameFuture(self, key, req)
                fut._resolve(frame)
                return fut
            tiles = [
                self.cache.get(tile_key(key if row_keys is None else row_keys[ti // self.tiles_x], ti))
                for ti in range(self.n_tiles)
            ]
            if all(t is not None for t in tiles):  # full hit: assemble once
                self._full_hits.inc()
                a0 = _now()
                frame = self._assemble(tiles)
                self.cache.put(tile_key(key, ASSEMBLED), frame, dedup=False)
                if rec:
                    a1 = _now()
                    rec.record(req.request_id, "submit", t, a0,
                               outcome="full_hit", level=level, timestep=int(timestep))
                    rec.record(req.request_id, "assemble", a0, a1, tiles=self.n_tiles)
                fut = FrameFuture(self, key, req)
                fut._resolve(frame)
                return fut
        else:
            frame = self.cache.get(key)
            if frame is not None:
                if rec:
                    rec.record(req.request_id, "submit", t, _now(),
                               outcome="cache_hit", level=level, timestep=int(timestep))
                fut = FrameFuture(self, key, req)
                fut._resolve(frame)
                return fut
        fut = self._pending.get(key)
        if fut is not None:  # identical pose already in flight: render once
            fut._attach(req)
            self._deduped.inc()
            if rec:
                rec.record(req.request_id, "submit", t, _now(),
                           outcome="dedup", primary=fut.request_id,
                           level=level, timestep=int(timestep))
            return fut
        fut = FrameFuture(self, key, req)
        req.future = fut
        self._pending[key] = fut
        if tiles is not None and (row_levels is not None or any(t is not None for t in tiles)):
            # partial hit: a dedicated job renders only the missing tile rows.
            # Mixed-level frames always take this path — the batcher's full-
            # frame renders are single-level, but the strip renderer already
            # knows how to fill each row at its own level.
            got = sum(1 for x in tiles if x is not None)
            if got:
                self._partial_hits.inc()
            else:
                self._frame_misses.inc()
            if rec:
                rec.record(req.request_id, "submit", t, _now(),
                           outcome="partial_hit" if got else "miss",
                           missing_tiles=self.n_tiles - got,
                           level=level, timestep=int(timestep),
                           foveated=row_levels is not None)
            self._partial.append(
                _PartialJob(req=req, fut=fut, tiles=tiles, row_levels=row_levels, row_keys=row_keys)
            )
        else:
            if self.tile_cache:
                self._frame_misses.inc()
            if rec:
                rec.record(req.request_id, "submit", t, _now(),
                           outcome="miss", level=level, timestep=int(timestep))
            self.batcher.submit(req)
        return fut

    # ------------------------------------------------------------- tile path
    def _assemble(self, tiles: list) -> np.ndarray:
        """Stitch the row-major tile grid back into one read-only frame.

        Pure memory movement over the very floats the render produced, so the
        assembled frame is bit-identical to the full-frame render it was
        split from (or would have been split from)."""
        th, tw = self.tile_h, self.tile_w
        # build into an owned buffer (no .base): the cache stores it as-is,
        # so the resolved frame and the ASSEMBLED cache entry are one object
        frame = np.empty((self.cfg.img_h, self.cfg.img_w, 3), dtype=tiles[0].dtype)
        frame.reshape(self.tiles_y, th, self.tiles_x, tw, 3)[:] = (
            np.stack(tiles)
            .reshape(self.tiles_y, self.tiles_x, th, tw, 3)
            .transpose(0, 2, 1, 3, 4)
        )
        frame.setflags(write=False)
        return frame

    def _cache_put_frame(self, key: tuple, frame: np.ndarray) -> None:
        """Store a retired frame: whole (baseline) or split into tiles."""
        if not self.tile_cache:
            self.cache.put(key, frame)
            return
        if self.cache.disabled:
            return
        th, tw = self.tile_h, self.tile_w
        for ti in range(self.n_tiles):
            ty, tx = divmod(ti, self.tiles_x)
            self.cache.put(
                tile_key(key, ti),
                frame[ty * th : (ty + 1) * th, tx * tw : (tx + 1) * tw],
            )
        # and the stitched frame itself: later full hits are zero-copy (no
        # extra buffer here — this IS the retired frame, shared read-only)
        self.cache.put(tile_key(key, ASSEMBLED), frame, dedup=False)

    def _strip_fn(self, level: int, row: int):
        """The jitted single-view tile-row render for (level, row), built
        lazily (a bounded set: levels x tiles_y traces)."""
        fn = self._strip_renders.get((level, row))
        if fn is None:
            fn = make_tile_row_render(self.mesh, self._level_cfgs[level], row=row)
            self._strip_renders[(level, row)] = fn
        return fn

    def warmup_tiles(self, *, levels=None, rows=None, timesteps=None) -> float:
        """Pre-compile tile-row render variants (the partial-hit path);
        returns seconds. Lazy by default because most serving never partials
        on most (level, row) pairs — benchmarks and latency-sensitive insitu
        deployments warm the rows they expect to invalidate."""
        assert self.tile_cache, "tile-row renders exist only with tile_cache"
        t0 = _now()
        for ts in timesteps if timesteps is not None else [self.timesteps()[0]]:
            entry = self._entry(ts)
            cam = front_camera(entry.pyramid, img_h=self.cfg.img_h, img_w=self.cfg.img_w)
            cam_np = jax.tree_util.tree_map(np.asarray, cam)
            for lvl in levels if levels is not None else range(len(entry.level_params)):
                for row in rows if rows is not None else range(self.tiles_y):
                    jax.block_until_ready(
                        self._strip_fn(lvl, row)(entry.level_params[lvl], cam_np)
                    )
        return _now() - t0

    def _update_row_cost(self, cost_ms: float) -> None:
        """Fold one measurement into the level-0-row cost EWMA (the
        budget_ms calibration); measurements arrive already normalized to
        level-0 row units."""
        prev = self._row_cost_ms
        self._row_cost_ms = cost_ms if prev is None else 0.8 * prev + 0.2 * cost_ms

    def _run_partial(self, job: _PartialJob) -> int:
        """Render a partial hit's missing tile rows — each at its assigned
        level for foveated jobs — then assemble and resolve."""
        req = job.req
        entry = self._entry(req.timestep)
        cam_np = jax.tree_util.tree_map(np.asarray, req.cam)
        lvl_of = (lambda r: job.row_levels[r]) if job.row_levels is not None else (lambda r: req.level)
        key_of = (lambda r: job.row_keys[r]) if job.row_keys is not None else (lambda r: req.cache_key)
        missing = sorted(
            {ti // self.tiles_x for ti, t in enumerate(job.tiles) if t is None}
        )
        t0 = _now()
        # dispatch every missing row first (jax async dispatch), then block
        launched = [
            (r, self._strip_fn(lvl_of(r), r)(entry.level_params[lvl_of(r)], cam_np))
            for r in missing
        ]
        self._c_dispatch_s.add(_now() - t0)
        for r, dev in launched:
            strip = np.asarray(jax.block_until_ready(dev))  # (tile_h, W, 3)
            for tx in range(self.tiles_x):
                ti = r * self.tiles_x + tx
                if job.tiles[ti] is None:
                    tile = np.ascontiguousarray(
                        strip[:, tx * self.tile_w : (tx + 1) * self.tile_w]
                    )
                    tile.setflags(write=False)
                    self.cache.put(tile_key(key_of(r), ti), tile)
                    job.tiles[ti] = tile
        now = _now()
        self._c_block_s.add(now - t0)
        self._c_render_s.add(now - max(t0, self._busy_until))
        self._busy_until = now
        self._rows_rendered.inc(len(missing))
        self._render_rows.inc(len(missing))
        if missing:
            units = sum(self.keep_ratio ** lvl_of(r) for r in missing)
            self._update_row_cost((now - t0) * 1e3 / units)
        rec = self.obs.trace
        if rec:
            rec.record(req.request_id, "render", t0, now,
                       partial=True, rows=len(missing), level=req.level,
                       foveated=job.row_levels is not None)
        frame = self._assemble(job.tiles)
        self.cache.put(tile_key(req.cache_key, ASSEMBLED), frame, dedup=False)
        if rec:
            rec.record(req.request_id, "assemble", now, _now(), tiles=self.n_tiles)
        fut = self._pending.pop(req.cache_key, None)
        if fut is not None:
            return fut._resolve(frame)
        self._complete(req, frame)  # pragma: no cover - defensive
        return 1

    # ------------------------------------------------------------------ serve
    def _dispatch_one(self) -> bool:
        """Launch the next micro-batch without blocking on its result."""
        mb: MicroBatch | None = self.batcher.next_batch()
        if mb is None:
            return False
        entry = self._entry(mb.timestep)
        t0 = _now()
        imgs = self._level_render[mb.level](
            entry.level_params[mb.level], jax.tree_util.tree_map(np.asarray, mb.cams)
        )
        self._c_dispatch_s.add(_now() - t0)
        self._render_calls.inc()
        self._batch_sizes.observe(len(mb.requests))
        self._ring.append(_InFlight(mb, imgs, t0))
        self._occupancy.observe(len(self._ring))
        return True

    def _retire_one(self) -> int:
        """Block on the oldest in-flight batch and deliver its frames."""
        inf = self._ring.popleft()
        t0 = _now()
        imgs = np.asarray(jax.block_until_ready(inf.imgs))
        now = _now()
        self._c_block_s.add(now - t0)
        # render.total_s is the UNION of in-flight windows (device-busy wall):
        # overlapping batches must not double-count, or depth>=2 would report
        # more render seconds than wall-clock and look slower per frame
        self._c_render_s.add(now - max(inf.t_dispatch, self._busy_until))
        self._busy_until = now
        done = 0
        self._render_rows.inc(self.tiles_y * len(inf.mb.requests))
        units = self.tiles_y * (self.keep_ratio ** inf.mb.level) * len(inf.mb.requests)
        self._update_row_cost((now - inf.t_dispatch) * 1e3 / units)
        rec = self.obs.trace
        for i, req in enumerate(inf.mb.requests):
            frame = imgs[i].copy()  # own buffer: never pin the whole batch
            frame.setflags(write=False)  # shared with cache + deduped waiters
            if rec:
                r0 = _now()
            self._cache_put_frame(req.cache_key, frame)
            fut = self._pending.pop(req.cache_key, None)
            if fut is not None:
                done += fut._resolve(frame)
            else:  # pragma: no cover - defensive: request outside the table
                self._complete(req, frame)
                done += 1
            if rec:
                rec.record(req.request_id, "render", inf.t_dispatch, now,
                           batch=len(inf.mb.requests), bucket=inf.mb.bucket,
                           level=inf.mb.level, timestep=inf.mb.timestep)
                rec.record(req.request_id, "retire", r0, _now())
        return done

    def step(self) -> int:
        """Advance the pipeline one unit; returns requests completed.

        Partial-hit jobs (cheap, row-granular) run first; then the ring fills
        up to ``pipeline_depth`` dispatches and retires the oldest batch. At
        depth 1 with no partial jobs this is exactly the synchronous
        submit->render->block loop this server used to run.
        """
        if self._partial:
            return self._run_partial(self._partial.popleft())
        while len(self._ring) < self.pipeline_depth and self._dispatch_one():
            pass
        if self._ring:
            return self._retire_one()
        return 0

    def flush(self) -> int:
        """Complete every admitted-to-render unit of work — the dispatched
        in-flight ring AND queued partial-hit jobs — without dispatching new
        micro-batches; returns requests completed. Invalidation goes through
        here so no old-model tile can land after its drop."""
        done = 0
        while self._ring:
            done += self._retire_one()
        while self._partial:
            done += self._run_partial(self._partial.popleft())
        return done

    def run(self) -> int:
        """Drain the queue, partial jobs, and the ring; returns completed."""
        done = 0
        while self.batcher.pending or self._ring or self._partial:
            done += self.step()
        return done

    # -------------------------------------------------------------- lifecycle
    def close(self) -> int:
        """Shut the server down; returns how many queued requests were failed.

        Retires (i.e. completes) every dispatched in-flight batch, then fails
        the futures of requests still waiting in the batcher queue with a
        ``RuntimeError`` (their ``result()`` raises instead of spinning on a
        dead pipeline), drops the queue, and releases the retirement buffer.
        Idempotent; ``submit`` after close raises."""
        if self._closed:
            return 0
        self._closed = True
        self.flush()  # in-flight work (ring + partials) completes with frames
        failed = 0
        err = RuntimeError("RenderServer closed before this request rendered")
        for fut in self._pending.values():  # queued-but-never-dispatched only:
            fut._fail(err)                  # retired keys left _pending above
            failed += len(fut.requests)
        self._pending.clear()
        self.batcher.clear()
        self.frames.clear()
        return failed

    @property
    def closed(self) -> bool:
        return self._closed

    def __enter__(self) -> "RenderServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _advance(self) -> bool:
        """One pipeline unit on behalf of an awaited future; False if idle."""
        if self.batcher.pending or self._ring or self._partial:
            self.step()
            return True
        return False

    def reset_metrics(self) -> None:
        """Open a fresh measurement window (e.g. after warmup laps, before a
        benchmark lap) by resetting the WHOLE shared registry: this tier, the
        cache, and — when the stack shares one ``Obs`` — sessions, encoders,
        and the gateway, in one atomic call. Leaves structural state (cache
        contents, timeline, jit traces) untouched; requires an idle pipeline."""
        assert not self._ring and not self.batcher.pending and not self._partial, (
            "pipeline not idle"
        )
        self.obs.metrics.reset()

    def _complete(self, req: RenderRequest, frame: np.ndarray) -> None:
        now = _now()
        self._t_last = now
        self._latency_ms.observe((now - req.t_submit) * 1e3)
        self._completed.inc()
        if self.store_frames:
            self.frames[req.request_id] = frame
            while len(self.frames) > self.frames_capacity:
                self.frames.popitem(last=False)  # retire the oldest frame

    # ---------------------------------------------------------------- metrics
    def _cache_report(self) -> dict:
        """Frame-granular cache stats. With the tile cache on, the raw
        FrameCache counters are per-tile; the frame-level view (what fraction
        of *requests* were served without a full render) nests them under
        ``tiles``."""
        if not self.tile_cache:
            return self.cache.stats()
        total = self.full_hits + self.partial_hits + self.frame_misses
        return {
            "hits": self.full_hits,
            "partial_hits": self.partial_hits,
            "misses": self.frame_misses,
            "hit_rate": round(self.full_hits / total, 4) if total else 0.0,
            "tiles": self.cache.stats(),
        }

    def report(self) -> dict:
        wall = (self._t_last - self._t_first) if (self._t_first is not None and self._t_last) else 0.0
        lat = self._latency_ms
        return {
            "completed": self.completed,
            "wall_s": round(wall, 4),
            "frames_per_s": round(self.completed / wall, 2) if wall > 0 else float("inf"),
            "latency_ms": {
                "p50": round(lat.percentile(50), 3),
                "p95": round(lat.percentile(95), 3),
                "p99": round(lat.percentile(99), 3),
                "max": round(lat.vmax, 3) if lat.vmax is not None else 0.0,
            },
            "render": {
                "calls": self._render_calls.value,
                "total_s": round(self._c_render_s.value, 4),
                "mean_batch": round(self._batch_sizes.mean, 2),
            },
            "pipeline": {
                "depth": self.pipeline_depth,
                "deduped": self.deduped,
                "in_flight_now": len(self._ring),
                "max_in_flight": int(self._occupancy.vmax or 0),
                "mean_in_flight": round(self._occupancy.mean, 3),
                "dispatch_s": round(self._c_dispatch_s.value, 4),
                "block_s": round(self._c_block_s.value, 4),
                "n_traces": self.n_traces,
            },
            "cache": self._cache_report(),
            "tiles": {
                "enabled": self.tile_cache,
                "grid": [self.tiles_y, self.tiles_x],
                "full_hits": self.full_hits,
                "partial_hits": self.partial_hits,
                "frame_misses": self.frame_misses,
                "rows_rendered_partial": self.rows_rendered,
                "render_rows": self.render_rows,
                # render work per served frame, in full-frame units: 1.0 =
                # every request fully rendered, 0 = pure cache. THE tile
                # economy metric — partial invalidation should pull it well
                # under the whole-frame baseline's miss rate.
                "renders_per_frame": round(
                    self.render_rows / (self.tiles_y * self.completed), 4
                )
                if self.completed
                else 0.0,
                "strip_traces": self.strip_traces,
            },
            "lod": {
                "live_counts": list(self.pyramid.live_counts),
                "padded_counts": [lvl.n for lvl in self.pyramid.levels],
                "requests_per_level": self.level_requests,
                # per-tile-row LOD assignment tallies (foveated serving):
                # rows_per_level counts every tile row a request *assigned*
                # to each level, uniform or mixed
                "rows_per_level": [c.value for c in self._c_lod_rows],
                "foveated_requests": self._c_foveated.value,
                "row_cost_ms": round(self._row_cost_ms, 4) if self._row_cost_ms else 0.0,
            },
            "timeline": {
                "timesteps": self.timesteps(),
                "live_counts": {t: list(e.pyramid.live_counts) for t, e in sorted(self._timeline.items())},
                "requests_per_timestep": {t: n for t, n in sorted(self._timestep_requests.items())},
            },
        }
