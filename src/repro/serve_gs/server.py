"""The render server: queue -> LOD select -> cache -> batched jitted render.

Turns trained ``GaussianModel``s into a service. Requests are admitted via
``submit`` (cache hits complete immediately); ``step`` drains one micro-batch
through the vmap-ed distributed render; ``run`` drains everything pending.
All orchestration is host-side Python — the device only ever sees fixed-shape
(level, bucket) batched render calls, so steady-state serving never recompiles.

The server holds a *timeline*: timestep -> (LOD pyramid, device params).
Static scenes are the one-entry special case (timestep 0, the default).
Streaming reconstructions (``repro.insitu``) register one model per simulation
timestep via ``add_timestep``, and clients scrub time by submitting the same
camera with different ``timestep`` values — each (timestep, level, pose) is a
distinct cacheable frame. The jitted render fns are shared across the whole
timeline (they are shape-keyed): a fixed-capacity insitu sequence reuses one
trace per (level, bucket) for every timestep.
"""
from __future__ import annotations

import dataclasses
import time
from typing import NamedTuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as PS

from repro.core import gaussians as G
from repro.core.config import GSConfig
from repro.core.projection import Camera
from repro.core.train import make_batched_eval_render
from repro.serve_gs.batcher import (
    MicroBatch,
    MicroBatcher,
    RenderRequest,
    default_buckets,
    stack_cameras,
)
from repro.serve_gs.cache import FrameCache, frame_key
from repro.serve_gs.lod import LODPyramid, build_lod_pyramid, front_camera, select_level


def _percentile(xs: list[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else 0.0


class TimestepModels(NamedTuple):
    """One timeline entry: the pyramid and its device-resident levels."""

    pyramid: LODPyramid
    level_params: tuple[G.GaussianModel, ...]  # device arrays, model-sharded


class RenderServer:
    """Batched, LOD-aware, cached render service over a model timeline."""

    def __init__(
        self,
        params: G.GaussianModel,
        cfg: GSConfig,
        *,
        mesh=None,
        n_levels: int = 3,
        keep_ratio: float = 0.5,
        max_batch: int = 8,
        buckets: tuple[int, ...] | None = None,
        cache_capacity: int = 512,
        pose_quantum: float = 1e-3,
        store_frames: bool = True,
        timestep: int = 0,
    ):
        self.cfg = cfg
        self.mesh = mesh if mesh is not None else jax.make_mesh((1, 1), ("data", "model"))
        self.pose_quantum = pose_quantum
        self.store_frames = store_frames
        self.n_levels = n_levels
        self.keep_ratio = keep_ratio

        # Micro-batches shard over the mesh's data axis, so every bucket must
        # be a multiple of it: a d-device data axis renders a bucket-d batch
        # one view per device — batching IS the data parallelism.
        d = self.mesh.shape["data"]
        max_batch = d * max(-(-max_batch // d), 1)  # round up to a multiple of d
        if buckets is None:
            buckets = tuple(d * b for b in default_buckets(max(max_batch // d, 1)))
        assert all(b % d == 0 for b in buckets), (buckets, d)

        self._shard = NamedSharding(self.mesh, PS("model"))
        # A level with keep_ratio**k of the Gaussians needs proportionally
        # fewer splats per tile: compositing is O(tiles x k_per_tile) and is
        # the dominant render term, so shrinking K is what actually makes a
        # coarse level cheap (pruning alone only shrinks project/sort/bin).
        self._level_cfgs = tuple(
            dataclasses.replace(
                cfg,
                k_per_tile=max(int(cfg.k_per_tile * keep_ratio**lvl), 32),
            )
            for lvl in range(n_levels)
        )
        # one render fn per level, shared by every timeline entry — jit
        # retraces only if a timestep brings a new padded Gaussian count
        self._level_render = tuple(
            make_batched_eval_render(self.mesh, c) for c in self._level_cfgs
        )

        self._timeline: dict[int, TimestepModels] = {}
        self._first_timestep = int(timestep)
        self.add_timestep(timestep, params)

        self.batcher = MicroBatcher(max_batch=max_batch, buckets=buckets)
        self.cache = FrameCache(cache_capacity)
        self.frames: dict[int, np.ndarray] = {}

        # ---- metrics
        self._latencies: list[float] = []
        self._render_s = 0.0
        self._render_calls = 0
        self._level_requests = [0] * n_levels
        self._timestep_requests: dict[int, int] = {}
        self._batch_sizes: list[int] = []
        self._t_first: float | None = None
        self._t_last: float | None = None
        self.completed = 0

    # first-entry aliases — the pre-timeline (static scene) public surface;
    # properties so they track add_timestep() re-registering the first entry
    @property
    def pyramid(self) -> LODPyramid:
        return self._timeline[self._first_timestep].pyramid

    @property
    def _level_params(self) -> tuple[G.GaussianModel, ...]:
        return self._timeline[self._first_timestep].level_params

    # --------------------------------------------------------------- timeline
    def add_timestep(self, timestep: int, params: G.GaussianModel) -> TimestepModels:
        """Register a model for one timeline position. Re-registering an
        existing timestep replaces the model AND invalidates its cached
        frames (stale frames must not outlive the model that rendered them).
        """
        cache = getattr(self, "cache", None)  # absent during __init__'s first entry
        if cache is not None and int(timestep) in self._timeline:
            cache.drop(lambda k: k[0] == int(timestep))
        pyramid = build_lod_pyramid(
            params,
            n_levels=self.n_levels,
            keep_ratio=self.keep_ratio,
            pad_quantum=self.cfg.pad_quantum,
        )
        level_params = tuple(
            jax.device_put(lvl, G.GaussianModel(*([self._shard] * 5))) for lvl in pyramid.levels
        )
        entry = TimestepModels(pyramid, level_params)
        self._timeline[int(timestep)] = entry
        return entry

    def timesteps(self) -> list[int]:
        return sorted(self._timeline)

    def _entry(self, timestep: int) -> TimestepModels:
        try:
            return self._timeline[int(timestep)]
        except KeyError:
            raise KeyError(
                f"timestep {timestep} not on the timeline (have {self.timesteps()})"
            ) from None

    def warmup(self, buckets: tuple[int, ...] | None = None, *, timesteps=None) -> float:
        """Pre-compile every (level, bucket) render variant; returns seconds.

        Serving latency then never includes a jit trace — the cold-start cost
        is paid here, before the first client connects. One timestep suffices
        when the timeline is shape-uniform (fixed-capacity insitu sequences);
        pass ``timesteps`` to force-warm entries with distinct shapes. Does
        not touch the serving metrics or the cache.
        """
        buckets = buckets or self.batcher.buckets
        t0 = time.perf_counter()
        for ts in timesteps if timesteps is not None else [self.timesteps()[0]]:
            entry = self._entry(ts)
            cam = front_camera(entry.pyramid, img_h=self.cfg.img_h, img_w=self.cfg.img_w)
            for lvl, lp in enumerate(entry.level_params):
                for b in buckets:
                    jax.block_until_ready(self._level_render[lvl](lp, stack_cameras([cam] * b)))
        return time.perf_counter() - t0

    # ------------------------------------------------------------------ admit
    def submit(
        self,
        cam: Camera,
        *,
        timestep: int = 0,
        client_id: int = -1,
        t_submit: float | None = None,
    ) -> int:
        """Admit one camera request against one timeline position.

        Cache hits complete synchronously (the frame is already on the host);
        misses are queued for the next micro-batch.
        """
        t = time.perf_counter() if t_submit is None else t_submit
        if self._t_first is None:
            self._t_first = t
        entry = self._entry(timestep)
        level = select_level(entry.pyramid, cam, img_w=self.cfg.img_w)
        key = frame_key(cam, level, timestep=timestep, pose_quantum=self.pose_quantum)
        req = RenderRequest(
            cam=cam, level=level, t_submit=t, client_id=client_id, cache_key=key,
            timestep=int(timestep),
        )
        self._level_requests[level] += 1
        self._timestep_requests[int(timestep)] = self._timestep_requests.get(int(timestep), 0) + 1

        frame = self.cache.get(key)
        if frame is not None:
            self._complete(req, frame)
            return req.request_id
        self.batcher.submit(req)
        return req.request_id

    # ------------------------------------------------------------------ serve
    def step(self) -> int:
        """Render one micro-batch; returns the number of requests completed."""
        mb: MicroBatch | None = self.batcher.next_batch()
        if mb is None:
            return 0
        entry = self._entry(mb.timestep)
        t0 = time.perf_counter()
        imgs = self._level_render[mb.level](
            entry.level_params[mb.level], jax.tree_util.tree_map(np.asarray, mb.cams)
        )
        imgs = np.asarray(jax.block_until_ready(imgs))
        self._render_s += time.perf_counter() - t0
        self._render_calls += 1
        self._batch_sizes.append(len(mb.requests))
        for i, req in enumerate(mb.requests):
            frame = imgs[i].copy()  # own buffer: never pin the whole batch
            self.cache.put(req.cache_key, frame)
            self._complete(req, frame)
        return len(mb.requests)

    def run(self) -> int:
        """Drain the queue; returns total requests completed by this call."""
        done = 0
        while self.batcher.pending:
            done += self.step()
        return done

    def _complete(self, req: RenderRequest, frame: np.ndarray) -> None:
        now = time.perf_counter()
        self._t_last = now
        self._latencies.append(now - req.t_submit)
        self.completed += 1
        if self.store_frames:
            self.frames[req.request_id] = frame

    # ---------------------------------------------------------------- metrics
    def report(self) -> dict:
        wall = (self._t_last - self._t_first) if (self._t_first is not None and self._t_last) else 0.0
        lat_ms = [x * 1e3 for x in self._latencies]
        return {
            "completed": self.completed,
            "wall_s": round(wall, 4),
            "frames_per_s": round(self.completed / wall, 2) if wall > 0 else float("inf"),
            "latency_ms": {
                "p50": round(_percentile(lat_ms, 50), 3),
                "p99": round(_percentile(lat_ms, 99), 3),
                "max": round(max(lat_ms), 3) if lat_ms else 0.0,
            },
            "render": {
                "calls": self._render_calls,
                "total_s": round(self._render_s, 4),
                "mean_batch": round(float(np.mean(self._batch_sizes)), 2) if self._batch_sizes else 0.0,
            },
            "cache": self.cache.stats(),
            "lod": {
                "live_counts": list(self.pyramid.live_counts),
                "padded_counts": [lvl.n for lvl in self.pyramid.levels],
                "requests_per_level": list(self._level_requests),
            },
            "timeline": {
                "timesteps": self.timesteps(),
                "live_counts": {t: list(e.pyramid.live_counts) for t, e in sorted(self._timeline.items())},
                "requests_per_timestep": {t: n for t, n in sorted(self._timestep_requests.items())},
            },
        }
