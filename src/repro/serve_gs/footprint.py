"""World-space change → screen-tile footprint mapping.

The serving stack caches rendered tiles per (timestep, level, pose). When a
live in-situ update rewrites a subset of Gaussian slots, only the tiles whose
screen-space footprint intersects those Gaussians' projected bounds — under
the *old or new* parameters — can change. This module computes that mapping
on the host, per cached pose, so `RenderServer.add_timestep(..., changed=...)`
can invalidate exactly the dirty tile rows itself instead of requiring
callers to hand-compute `dirty_rows`.

Conservatism contract: the bounds come from
:func:`repro.core.projection.project_bounds_np`, a padded float64 mirror of
the jitted projection, and the row test mirrors the *inclusive* tile binning
in ``core.render.build_tile_lists``. A tile row not reported dirty is
guaranteed to composite bitwise identically; a reported row merely may have
changed. We gate on radius > 0 only — not opacity — because zero-opacity
splats still occupy top-K slots in the binned tile lists and can displace
other entries.
"""
from __future__ import annotations

import numpy as np

from repro.core import gaussians as G
from repro.core.projection import Camera, project_bounds_np


def changed_indices(old: G.GaussianModel, new: G.GaussianModel, *, atol: float = 0.0) -> np.ndarray:
    """Row indices where any parameter leaf differs between two models.

    ``atol`` tolerates quantization noise (e.g. int16 checkpoint deltas);
    0.0 means exact inequality. Raises ``ValueError`` on shape mismatch —
    a capacity change invalidates everything and has no per-row diff.
    """
    dirty = None
    for name in old._fields:
        a = np.asarray(getattr(old, name))
        b = np.asarray(getattr(new, name))
        if a.shape != b.shape:
            raise ValueError(
                f"changed_indices: field {name!r} shape {a.shape} != {b.shape}; "
                "models with different capacity have no per-slot diff"
            )
        d = np.abs(a.astype(np.float64) - b.astype(np.float64)) > atol
        d = d.reshape(d.shape[0], -1).any(axis=1)
        dirty = d if dirty is None else (dirty | d)
    return np.nonzero(dirty)[0]


def dirty_rows(
    params_list,
    idx: np.ndarray,
    cam: Camera,
    *,
    img_h: int,
    img_w: int,
    tile_h: int,
    pad_px: float = 1.0,
) -> frozenset[int]:
    """Tile rows whose composite can differ when Gaussians ``idx`` change.

    ``params_list`` holds the model states whose footprints matter — for an
    update that is both old and new parameters (a tile is dirty if the
    changed Gaussians touched it *before or after* the move). Rows are
    derived from the inclusive overlap test in ``build_tile_lists``:
    a splat at (my, rad) bins into row r iff ``my + rad >= r*tile_h`` and
    ``my - rad <= r*tile_h + tile_h``, i.e. rows
    ``ceil((my-rad)/tile_h) - 1 .. floor((my+rad)/tile_h)``.
    """
    tiles_y = (img_h + tile_h - 1) // tile_h
    all_rows = frozenset(range(tiles_y))
    idx = np.asarray(idx).reshape(-1)
    if idx.size == 0:
        return frozenset()
    out: set[int] = set()
    for params in params_list:
        mx, my, rad = project_bounds_np(params, cam, idx, pad_px=pad_px)
        live = (rad > 0) & (mx + rad >= 0) & (mx - rad <= img_w)
        if not live.any():
            continue
        my, rad = my[live], rad[live]
        lo = np.ceil((my - rad) / tile_h).astype(np.int64) - 1
        hi = np.floor((my + rad) / tile_h).astype(np.int64)
        on = (hi >= 0) & (lo <= tiles_y - 1)
        for a, b in zip(np.clip(lo[on], 0, tiles_y - 1), np.clip(hi[on], 0, tiles_y - 1)):
            out.update(range(int(a), int(b) + 1))
            if len(out) == tiles_y:
                return all_rows
    return frozenset(out)


def dirty_row_map(
    old: G.GaussianModel,
    new: G.GaussianModel,
    idx: np.ndarray,
    poses: dict,
    *,
    img_h: int,
    img_w: int,
    tile_h: int,
    pad_px: float = 1.0,
) -> dict:
    """Per-pose dirty rows for an old→new update of Gaussians ``idx``.

    ``poses`` maps a pose signature (the quantized-camera tuple the cache
    keys on) to its ``Camera``; the result maps each signature to the
    frozenset of dirty tile rows under that pose.
    """
    return {
        sig: dirty_rows(
            (old, new), idx, cam, img_h=img_h, img_w=img_w, tile_h=tile_h, pad_px=pad_px
        )
        for sig, cam in poses.items()
    }
