"""Level-of-detail pyramid over a trained Gaussian model.

Serving far-away views with all 4M-18M Gaussians wastes compute: most splats
project to well under a pixel. The pyramid precomputes opacity/scale-pruned
subsets (LightGaussian-style importance = opacity x world-space area), so the
server composites a fraction of the model when the scene's screen coverage is
small. Level 0 is always the full model; each level keeps ``keep_ratio`` of
the previous level's live Gaussians, padded up to ``pad_quantum`` (with dead,
never-visible splats) so per-level jit shapes stay shard-aligned.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

from repro.core import gaussians as G
from repro.core.projection import Camera, look_at_camera

# means/opacity used by the training pipeline to mark padded (dead) Gaussians
DEAD_MEAN = 1.0e6
DEAD_LOGIT = -20.0


class LODPyramid(NamedTuple):
    """Precomputed render-serving pyramid. ``levels[0]`` is the full model."""

    levels: tuple[G.GaussianModel, ...]  # host (numpy-leaf) models, coarsening
    live_counts: tuple[int, ...]         # live (non-padding) Gaussians per level
    scene_center: np.ndarray             # (3,) live-Gaussian centroid
    scene_extent: float                  # half-extent of the live bounding box

    @property
    def n_levels(self) -> int:
        return len(self.levels)


def live_mask(g: G.GaussianModel, *, opacity_thresh: float = 1e-4) -> np.ndarray:
    """Gaussians that can ever contribute: finite, near the scene, not dead."""
    means = np.asarray(g.means)
    logit = np.asarray(g.opacity_logit)
    opac = 1.0 / (1.0 + np.exp(-np.clip(logit, -60, 60)))
    return (
        np.all(np.isfinite(means), axis=1)
        & (np.max(np.abs(means), axis=1) < DEAD_MEAN * 0.5)
        & (opac > opacity_thresh)
    )


def importance_scores(g: G.GaussianModel) -> np.ndarray:
    """Per-Gaussian serving importance: opacity x mean cross-section area.

    Large opaque splats dominate a low-coverage (far-away) view; tiny or
    near-transparent ones vanish first. This is the pruning metric from the
    compaction literature (opacity-volume product), in world units so it is
    view-independent and can be computed once at pyramid build time.
    """
    logit = np.asarray(g.opacity_logit, np.float64)
    opac = 1.0 / (1.0 + np.exp(-np.clip(logit, -60, 60)))
    mean_scale = np.exp(np.asarray(g.log_scales, np.float64)).mean(axis=1)
    return (opac * mean_scale**2).astype(np.float64)


def _pad_model(g_np: list[np.ndarray], n_target: int) -> G.GaussianModel:
    """Pad a host-side leaf list up to ``n_target`` with dead Gaussians."""
    means, log_scales, quats, opacity_logit, sh = g_np
    n = means.shape[0]
    pad = n_target - n
    if pad > 0:
        means = np.concatenate([means, np.full((pad, 3), DEAD_MEAN, np.float32)])
        log_scales = np.concatenate([log_scales, np.zeros((pad, 3), np.float32)])
        q = np.zeros((pad, 4), np.float32)
        q[:, 0] = 1.0
        quats = np.concatenate([quats, q])
        opacity_logit = np.concatenate([opacity_logit, np.full((pad,), DEAD_LOGIT, np.float32)])
        sh = np.concatenate([sh, np.zeros((pad,) + sh.shape[1:], np.float32)])
    return G.GaussianModel(means, log_scales, quats, opacity_logit, sh)


def build_lod_pyramid(
    params: G.GaussianModel,
    *,
    n_levels: int = 3,
    keep_ratio: float = 0.5,
    pad_quantum: int = 256,
    min_live: int = 32,
) -> LODPyramid:
    """Precompute the serving pyramid from a (possibly padded) trained model.

    Level k keeps the top ``keep_ratio**k`` fraction of live Gaussians by
    ``importance_scores``. Levels that would fall below ``min_live`` are not
    built (so tiny toy scenes get shallow pyramids instead of empty levels).
    """
    assert n_levels >= 1 and 0.0 < keep_ratio < 1.0
    leaves = [np.asarray(x, np.float32) for x in params]
    mask = live_mask(params)
    live_idx = np.nonzero(mask)[0]
    if live_idx.size == 0:
        raise ValueError("model has no live Gaussians to serve")
    live_means = leaves[0][live_idx]
    center = live_means.mean(axis=0)
    extent = float(np.max(np.abs(live_means - center))) or 1.0

    # rank live Gaussians once, most important first
    scores = importance_scores(params)[live_idx]
    ranked = live_idx[np.argsort(-scores, kind="stable")]

    levels: list[G.GaussianModel] = []
    counts: list[int] = []
    for k in range(n_levels):
        n_keep = max(int(round(live_idx.size * keep_ratio**k)), 1)
        if k > 0 and n_keep < min_live:
            break
        if k == 0:
            # full model verbatim (keeps training padding / sharding layout)
            levels.append(G.GaussianModel(*leaves))
            counts.append(int(live_idx.size))
            continue
        keep = np.sort(ranked[:n_keep])  # original order keeps locality
        sub = [x[keep] for x in leaves]
        n_padded = -(-n_keep // pad_quantum) * pad_quantum
        levels.append(_pad_model(sub, n_padded))
        counts.append(n_keep)
    return LODPyramid(tuple(levels), tuple(counts), center.astype(np.float32), extent)


def front_camera(pyr: LODPyramid, *, img_h: int, img_w: int, dist_factor: float = 3.0) -> Camera:
    """Canonical head-on framing of the pyramid's scene: the one default
    viewpoint shared by server warmup, smoke drivers, and examples."""
    center = pyr.scene_center
    eye = center + np.float32([0.0, 0.0, dist_factor * pyr.scene_extent])
    cam = look_at_camera(eye, center, [0.0, 1.0, 0.0], img_w, img_w, img_w / 2, img_h / 2)
    return Camera(*[np.asarray(x) for x in cam])


def screen_coverage(pyr: LODPyramid, cam: Camera, *, img_w: int) -> float:
    """Fraction of the image width the scene's bounding sphere spans."""
    campos = np.asarray(cam.campos, np.float64)
    dist = float(np.linalg.norm(campos - pyr.scene_center))
    dist = max(dist, 1e-6)
    fx = float(np.asarray(cam.fx))
    return (2.0 * pyr.scene_extent * fx / dist) / float(img_w)


def select_level(pyr: LODPyramid, cam: Camera, *, img_w: int) -> int:
    """Pick the pyramid level for a request from its screen coverage.

    Full coverage (>= 1) renders level 0; every halving of coverage drops one
    level — matching the keep_ratio=0.5 density halving, so the Gaussians per
    *covered pixel* stay roughly constant across distances.
    """
    cov = screen_coverage(pyr, cam, img_w=img_w)
    if cov >= 1.0:
        return 0
    lvl = int(np.floor(np.log2(1.0 / max(cov, 1e-9))))
    return min(max(lvl, 0), pyr.n_levels - 1)


def select_level_map(
    pyr: LODPyramid,
    cam: Camera,
    *,
    img_w: int,
    tiles_y: int,
    gaze_row: int | None = None,
    budget_rows: float | None = None,
    sharp_rows: int = 1,
    n_levels: int | None = None,
    keep_ratio: float = 0.5,
) -> tuple[int, ...]:
    """Per-tile-row LOD assignment: gaze rows sharp, peripheral rows coarse.

    Generalizes :func:`select_level` from one level per frame to one level
    per tile row. The coverage-derived level is the *floor* everywhere; rows
    farther than ``sharp_rows`` from the gaze row coarsen one level per row
    of distance (clamped to the pyramid depth ``n_levels``, which callers
    pass as the actual built depth when shallower than ``pyr.n_levels``).

    ``budget_rows`` is an approximate render budget in full-detail-row
    units: rendering a row at level l costs ~``keep_ratio**l`` of a level-0
    row (the pyramid keeps that fraction of Gaussians). When set, the
    sharp-zone half-width shrinks until the summed cost fits — gracefully
    degrading the periphery first, never the gaze row. With neither a gaze
    hint nor a budget the map is uniform at the coverage level, matching the
    legacy whole-frame behaviour bit for bit.
    """
    n = int(n_levels if n_levels is not None else pyr.n_levels)
    base = min(select_level(pyr, cam, img_w=img_w), n - 1)
    if n <= 1 or (gaze_row is None and budget_rows is None):
        return (base,) * tiles_y
    g = tiles_y // 2 if gaze_row is None else min(max(int(gaze_row), 0), tiles_y - 1)

    def profile(s: int) -> tuple[int, ...]:
        return tuple(min(base + max(abs(r - g) - s, 0), n - 1) for r in range(tiles_y))

    if budget_rows is None:
        return profile(max(int(sharp_rows), 0))
    # widest sharp zone whose estimated cost fits the budget (s = tiles_y is
    # the uniform-sharp frame, s = 0 degrades everything but the gaze row)
    cost = lambda p: sum(keep_ratio**l for l in p)
    for s in range(tiles_y, -1, -1):
        p = profile(s)
        if cost(p) <= budget_rows:
            return p
    return profile(0)
