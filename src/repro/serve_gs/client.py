"""Synthetic multi-client load harness for the render server.

Simulates N viewers exploring a trained scene: each client walks an orbit
(``repro.volume.cameras``) at its own radius/stride, submitting one request
per round at a configurable rate. Clients sharing an orbit revisit quantized
poses, exercising the frame cache; clients at large radii exercise coarse LOD
levels. Everything is deterministic (seeded phases), so throughput runs are
reproducible.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.projection import Camera
from repro.volume.cameras import camera_slice, orbit_cameras


class OrbitClient:
    """One synthetic viewer stepping along a shared or private orbit."""

    def __init__(
        self,
        client_id: int,
        *,
        n_views: int,
        img_h: int,
        img_w: int,
        radius: float = 3.0,
        phase: int = 0,
        stride: int = 1,
    ):
        self.client_id = client_id
        self.n_views = n_views
        self.stride = stride
        self._i = phase % n_views
        self._cams = orbit_cameras(n_views, img_h=img_h, img_w=img_w, radius=radius)

    def next_camera(self) -> Camera:
        cam = camera_slice(self._cams, self._i % self.n_views)
        self._i += self.stride
        return Camera(*[np.asarray(x) for x in cam])


def make_clients(
    n_clients: int,
    *,
    n_views: int,
    img_h: int,
    img_w: int,
    base_radius: float = 3.0,
    radius_spread: float = 0.0,
    shared_orbit: bool = True,
    dup_pairs: bool = False,
) -> list[OrbitClient]:
    """Build a deterministic client fleet.

    ``shared_orbit`` starts clients phase-shifted on the SAME pose set so
    later clients hit frames cached by earlier ones; ``radius_spread`` > 0
    pushes client *pairs* outward (radius grows per pair, so each radius ring
    still has two phase-shifted clients whose poses overlap and hit the
    cache) to exercise coarser LOD levels. ``dup_pairs`` makes client 2k+1 an
    exact clone of client 2k (same orbit, same phase), so every request round
    submits each pose twice *in the same wavefront* — the duplicate-heavy
    trace that exercises the server's in-flight dedup (the cache cannot catch
    these: the first render has not landed when the twin submits).
    """
    clients = []
    for c in range(n_clients):
        # dup_pairs: both members of a pair take the pair's identity
        ident = c // 2 if dup_pairs else c
        radius = base_radius * (1.0 + radius_spread) ** (ident // 2)
        if shared_orbit or dup_pairs:
            phase = (ident * 3) % n_views
        else:
            # private trajectories: spread starting phases AND nudge each
            # radius past the pose quantum so no two clients ever share a
            # cache key (measures cache-free independent-viewer load)
            phase = (c * n_views) // max(n_clients, 1)
            radius *= 1.0 + 0.003 * (c + 1)
        clients.append(
            OrbitClient(
                c, n_views=n_views, img_h=img_h, img_w=img_w, radius=radius, phase=phase
            )
        )
    return clients


def run_load(
    server,
    clients: list[OrbitClient],
    *,
    requests_per_client: int,
    rate_hz: float = 0.0,
    flush_every_round: bool = True,
) -> dict:
    """Drive the server with interleaved client rounds; returns its report.

    Each round every client submits its next camera (one concurrent wavefront
    — what the micro-batcher coalesces), then the server drains. ``rate_hz``
    > 0 paces rounds in wall-clock time; 0 runs flat out.
    """
    period = 1.0 / rate_hz if rate_hz > 0 else 0.0
    for _ in range(requests_per_client):
        t0 = time.perf_counter()
        for cl in clients:
            server.submit(cl.next_camera(), client_id=cl.client_id)
        if flush_every_round:
            server.run()
        if period:
            left = period - (time.perf_counter() - t0)
            if left > 0:
                time.sleep(left)
    server.run()
    return server.report()
