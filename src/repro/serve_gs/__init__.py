"""Batched, LOD-aware render serving for trained Gaussian models.

The inference-side counterpart of the distributed trainer in
``repro.core.train``: queue -> LOD select -> in-flight dedup -> cache -> a
pipelined ring of vmap-ed jitted renders (``submit`` returns a
``FrameFuture``; up to ``pipeline_depth`` micro-batches stay on-device while
the host postprocesses and assembles). See ``repro.launch.serve_gs`` for the
CLI driver and ``benchmarks/serve_throughput.py`` for the throughput
methodology.
"""
from repro.serve_gs.batcher import MicroBatch, MicroBatcher, RenderRequest, stack_cameras
from repro.serve_gs.cache import FrameCache, frame_key, quantize_camera, tile_key
from repro.serve_gs.client import OrbitClient, make_clients, run_load
from repro.serve_gs.footprint import changed_indices, dirty_row_map, dirty_rows
from repro.serve_gs.lod import (
    LODPyramid,
    build_lod_pyramid,
    front_camera,
    importance_scores,
    screen_coverage,
    select_level,
    select_level_map,
)
from repro.serve_gs.server import FrameFuture, RenderServer, TimestepModels

__all__ = [
    "FrameCache",
    "FrameFuture",
    "TimestepModels",
    "LODPyramid",
    "MicroBatch",
    "MicroBatcher",
    "OrbitClient",
    "RenderRequest",
    "RenderServer",
    "build_lod_pyramid",
    "changed_indices",
    "dirty_row_map",
    "dirty_rows",
    "frame_key",
    "front_camera",
    "importance_scores",
    "make_clients",
    "quantize_camera",
    "run_load",
    "screen_coverage",
    "select_level",
    "select_level_map",
    "stack_cameras",
    "tile_key",
]
