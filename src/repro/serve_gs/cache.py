"""LRU frame cache keyed by quantized camera pose.

Post hoc exploration revisits poses constantly (orbit playback, multiple
clients on the same trajectory, scrubbing back and forth). Exact float poses
never collide, so keys quantize the extrinsics/intrinsics: poses within the
quantum render identically for all practical purposes and share one entry.
The cache also keys on the LOD level — the same pose at a different level is
a different frame.

**Copy-on-write contract.** One frame buffer is shared by the cache, the
server's retirement buffer, and every (possibly deduped) waiter's
``FrameFuture`` — a second copy per reader would double serving memory for
nothing. ``put`` therefore marks the array read-only
(``arr.setflags(write=False)``) and ``get`` hands the same read-only array to
every hit: a client that wants to draw on its frame must ``.copy()`` it
first, and an accidental in-place mutation raises instead of silently
corrupting every other reader and all later cache hits.
"""
from __future__ import annotations

import collections

import numpy as np

from repro.core.projection import Camera


def quantize_camera(
    cam: Camera,
    *,
    pose_quantum: float = 1e-3,
    focal_quantum: float = 0.5,
) -> tuple:
    """Hashable key for a camera: viewmat and intrinsics rounded to quanta.

    ``pose_quantum`` applies to every viewmat entry (rotation entries live in
    [-1, 1], translation in scene units); ``focal_quantum`` to fx/fy/cx/cy in
    pixels. Two cameras closer than half a quantum in every entry share a key.
    """
    vm = np.asarray(cam.viewmat, np.float64)
    pose = tuple(int(v) for v in np.round(vm.reshape(-1) / pose_quantum))
    intr = tuple(
        int(np.round(float(np.asarray(x)) / focal_quantum))
        for x in (cam.fx, cam.fy, cam.cx, cam.cy)
    )
    return pose + intr


def frame_key(
    cam: Camera,
    level: int,
    *,
    timestep: int = 0,
    pose_quantum: float = 1e-3,
    focal_quantum: float = 0.5,
) -> tuple:
    """Cache key for a frame: the same pose at another LOD level *or another
    timeline position* is a different frame (time-scrubbing correctness)."""
    return (int(timestep), int(level)) + quantize_camera(
        cam, pose_quantum=pose_quantum, focal_quantum=focal_quantum
    )


class FrameCache:
    """Bounded LRU mapping frame keys -> rendered frames, with hit metrics."""

    def __init__(self, capacity: int = 512):
        assert capacity >= 0
        self.capacity = capacity
        self._store: collections.OrderedDict[tuple, np.ndarray] = collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._store)

    def get(self, key: tuple) -> np.ndarray | None:
        frame = self._store.get(key)
        if frame is None:
            self.misses += 1
            return None
        self._store.move_to_end(key)
        self.hits += 1
        return frame

    def put(self, key: tuple, frame: np.ndarray) -> None:
        """Insert a frame. The cache owns the buffer from here on: it is
        marked read-only (see the module docstring's copy-on-write contract),
        so callers must not hold a writable alias."""
        if self.capacity == 0:
            return
        frame.setflags(write=False)
        if key in self._store:
            self._store.move_to_end(key)
        self._store[key] = frame
        while len(self._store) > self.capacity:
            self._store.popitem(last=False)
            self.evictions += 1

    def drop(self, predicate) -> int:
        """Invalidate every entry whose key matches ``predicate``; returns the
        count dropped (e.g. all frames of a replaced timeline timestep)."""
        keys = [k for k in self._store if predicate(k)]
        for k in keys:
            del self._store[k]
        return len(keys)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        return {
            "size": len(self._store),
            "capacity": self.capacity,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "hit_rate": round(self.hit_rate, 4),
        }
