"""Tile-granular LRU frame cache keyed by quantized camera pose.

Post hoc exploration revisits poses constantly (orbit playback, multiple
clients on the same trajectory, scrubbing back and forth). Exact float poses
never collide, so keys quantize the extrinsics/intrinsics: poses within the
quantum render identically for all practical purposes and share one entry.
The cache also keys on the LOD level, the timeline position, and the render
resolution — the same pose at a different level, timestep, or output size is
a different frame.

**Tile granularity.** The serving unit stored here is a *tile* (the
rasterizer's ``tile_h x tile_w`` screen tile), not a whole frame: the server
appends a tile index to the frame key (:func:`tile_key`) and stores the
frame as its grid of tiles. Tiles are small and numerous, so capacity is a
**byte budget** rather than an entry count, and identical tile *content* is
stored once (content-addressed blobs with refcounts): the many background
tiles shared by every pose of an orbit cost one buffer, which is what lets a
tile cache hold far more poses than a whole-frame cache of the same byte
size. Whole-frame use (one entry per key, ``tile_cache=False`` servers) is
the degenerate case of the same structure.

**Copy-on-write contract.** One buffer is shared by the cache, the server's
retirement buffer, every deduplicated key, and every waiter's
``FrameFuture`` — a second copy per reader would multiply serving memory for
nothing. ``put`` therefore marks the array read-only
(``arr.setflags(write=False)``) and ``get`` hands the same read-only array to
every hit: a client that wants to draw on its frame must ``.copy()`` it
first, and an accidental in-place mutation raises instead of silently
corrupting every other reader and all later cache hits.
"""
from __future__ import annotations

import collections
import hashlib

import numpy as np

from repro.core.projection import Camera
from repro.obs import MetricsRegistry


def quantize_camera(
    cam: Camera,
    *,
    pose_quantum: float = 1e-3,
    focal_quantum: float = 0.5,
) -> tuple:
    """Hashable key for a camera: viewmat and intrinsics rounded to quanta.

    ``pose_quantum`` applies to every viewmat entry (rotation entries live in
    [-1, 1], translation in scene units); ``focal_quantum`` to fx/fy/cx/cy in
    pixels. Two cameras closer than half a quantum in every entry share a key.
    """
    vm = np.asarray(cam.viewmat, np.float64)
    pose = tuple(int(v) for v in np.round(vm.reshape(-1) / pose_quantum))
    intr = tuple(
        int(np.round(float(np.asarray(x)) / focal_quantum))
        for x in (cam.fx, cam.fy, cam.cx, cam.cy)
    )
    return pose + intr


def frame_key(
    cam: Camera,
    level: int,
    *,
    height: int,
    width: int,
    timestep: int = 0,
    pose_quantum: float = 1e-3,
    focal_quantum: float = 0.5,
) -> tuple:
    """Cache key for a frame: the same pose at another LOD level, *another
    timeline position*, or **another output resolution** is a different
    frame. Resolution is part of the key because the camera alone does not
    carry it — two requests at one quantized pose but different render sizes
    must never share an entry (a hit would return a wrong-size frame)."""
    return (int(timestep), int(level), int(height), int(width)) + quantize_camera(
        cam, pose_quantum=pose_quantum, focal_quantum=focal_quantum
    )


ASSEMBLED = -1  # sentinel tile index: the frame assembled from its tiles


def tile_key(key: tuple, tile_index: int) -> tuple:
    """Key of one screen tile of the frame ``key`` (flat row-major index).
    ``ASSEMBLED`` keys the whole stitched frame — cached alongside its tiles
    so repeated full hits are zero-copy, governed by the same byte budget,
    LRU order, and drop predicates as everything else."""
    return key + (int(tile_index),)


class _Blob:
    """One refcounted content-addressed buffer (shared across equal tiles)."""

    __slots__ = ("data", "digest", "refs")

    def __init__(self, data: np.ndarray, digest: bytes):
        self.data = data
        self.digest = digest
        self.refs = 0


def _digest(arr: np.ndarray) -> bytes:
    h = hashlib.blake2b(digest_size=16)
    h.update(repr((arr.shape, arr.dtype.str)).encode())
    h.update(arr.tobytes())
    return h.digest()


class FrameCache:
    """Bounded LRU mapping frame/tile keys -> arrays, with byte budgeting,
    content dedup, and hit/eviction/invalidation metrics.

    ``capacity`` bounds the *entry count* (legacy whole-frame semantics;
    default 512 so a bare ``FrameCache()`` stays bounded; None = unbounded),
    ``capacity_bytes`` bounds the total bytes of *unique* buffers held (the
    tile-serving budget — pass ``capacity=None`` with it, as the server
    does, since tile entries are far more numerous than frames). Either at 0
    disables the cache entirely. Eviction is LRU by key; a buffer's bytes are
    released only when its last referencing key is gone.

    Metrics live on a :class:`repro.obs.MetricsRegistry` under ``cache.*`` —
    pass the stack's shared registry via ``metrics`` (as ``RenderServer``
    does) so one ``registry.reset()`` clears the cache window together with
    every other tier; a standalone cache gets a private registry. The
    historical attribute reads (``cache.hits`` etc.) remain as properties.
    Structural state (entries, bytes held) is NOT metrics and survives reset.
    """

    def __init__(
        self,
        capacity: int | None = 512,
        *,
        capacity_bytes: int | None = None,
        dedup: bool = True,
        metrics: MetricsRegistry | None = None,
    ):
        assert capacity is None or capacity >= 0
        assert capacity_bytes is None or capacity_bytes >= 0
        self.capacity = capacity
        self.capacity_bytes = capacity_bytes
        self.dedup = dedup
        self._store: collections.OrderedDict[tuple, _Blob] = collections.OrderedDict()
        self._blobs: dict[bytes, _Blob] = {}
        self._bytes = 0
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._hits = self.metrics.counter("cache.hits")
        self._misses = self.metrics.counter("cache.misses")
        self._evictions = self.metrics.counter("cache.evictions")
        self._dropped = self.metrics.counter("cache.dropped")
        self._dedup_shared = self.metrics.counter("cache.dedup_shared")
        self._dedup_bytes_saved = self.metrics.counter("cache.dedup_bytes_saved")

    # historical attribute reads, now backed by the shared registry
    @property
    def hits(self) -> int:
        return self._hits.value

    @property
    def misses(self) -> int:
        return self._misses.value

    @property
    def evictions(self) -> int:
        return self._evictions.value

    @property
    def dropped(self) -> int:
        """Entries removed by drop() (invalidation)."""
        return self._dropped.value

    @property
    def dedup_shared(self) -> int:
        """Puts that reused an existing buffer."""
        return self._dedup_shared.value

    @property
    def dedup_bytes_saved(self) -> int:
        return self._dedup_bytes_saved.value

    def __len__(self) -> int:
        return len(self._store)

    @property
    def bytes(self) -> int:
        """Total bytes of unique buffers currently held."""
        return self._bytes

    @property
    def disabled(self) -> bool:
        return self.capacity == 0 or self.capacity_bytes == 0

    def get(self, key: tuple) -> np.ndarray | None:
        blob = self._store.get(key)
        if blob is None:
            self._misses.inc()
            return None
        self._store.move_to_end(key)
        self._hits.inc()
        return blob.data

    # ------------------------------------------------------------- refcounts
    def _incref(self, blob: _Blob) -> None:
        if blob.refs == 0:
            self._bytes += blob.data.nbytes
            if blob.digest is not None:
                self._blobs[blob.digest] = blob
        blob.refs += 1

    def _decref(self, blob: _Blob) -> None:
        blob.refs -= 1
        if blob.refs == 0:
            self._bytes -= blob.data.nbytes
            if blob.digest is not None:
                self._blobs.pop(blob.digest, None)

    def _remove(self, key: tuple) -> None:
        self._decref(self._store.pop(key))

    def put(self, key: tuple, frame: np.ndarray, *, dedup: bool | None = None) -> None:
        """Insert an array. The cache owns the buffer from here on: it is
        marked read-only (see the module docstring's copy-on-write contract),
        so callers must not hold a writable alias. Identical content (same
        shape + bytes) already in the cache is shared, not stored twice;
        ``dedup=False`` skips the content hash for entries that essentially
        never collide (whole assembled frames)."""
        if self.disabled:
            return
        if not frame.flags.c_contiguous:
            frame = np.ascontiguousarray(frame)
        elif frame.base is not None:
            # a contiguous VIEW (e.g. a full-width tile row slice) would pin
            # its whole parent buffer while the budget counts only the slice
            frame = frame.copy()
        frame.setflags(write=False)
        dedup = self.dedup if dedup is None else dedup
        digest = _digest(frame) if dedup else None
        blob = self._blobs.get(digest) if digest is not None else None
        if blob is not None:
            self._dedup_shared.inc()
            self._dedup_bytes_saved.inc(frame.nbytes)
        else:
            blob = _Blob(frame, digest)
        old = self._store.get(key)
        if old is not None:
            if old is blob:
                self._store.move_to_end(key)
                return
            self._remove(key)
        self._incref(blob)
        self._store[key] = blob
        while (self.capacity is not None and len(self._store) > self.capacity) or (
            self.capacity_bytes is not None and self._bytes > self.capacity_bytes
        ):
            victim, vblob = self._store.popitem(last=False)
            self._decref(vblob)
            self._evictions.inc()
            if victim == key:  # a single entry larger than the whole budget
                break

    def drop(self, predicate) -> int:
        """Invalidate every entry whose key matches ``predicate``; returns
        the count dropped (e.g. all tiles of a replaced timeline timestep, or
        only the tiles of its dirty rows). Unlike eviction this is an
        explicit correctness action, accounted separately (``dropped``)."""
        keys = [k for k in self._store if predicate(k)]
        for k in keys:
            self._remove(k)
        self._dropped.inc(len(keys))
        return len(keys)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        return {
            "size": len(self._store),
            "bytes": self._bytes,
            "capacity": self.capacity,
            "capacity_bytes": self.capacity_bytes,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "dropped": self.dropped,
            "hit_rate": round(self.hit_rate, 4),
            "unique_buffers": sum(1 for _ in self._iter_unique()),
            "dedup_shared": self.dedup_shared,
            "dedup_bytes_saved": self.dedup_bytes_saved,
        }

    def _iter_unique(self):
        seen = set()
        for blob in self._store.values():
            if id(blob) not in seen:
                seen.add(id(blob))
                yield blob
