"""granite-moe-3b-a800m — MoE decoder, 40 experts top-8.

Spec: 32L d_model=1536 24H (GQA kv=8) expert d_ff=512 vocab=49155, 40e top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base family, 3b-a800m dims]

Expert dim shards over "model" (expert parallelism); dispatch is the
sort-based capacity scheme in repro.models.moe.
long_500k: SKIPPED — full attention.
"""
import dataclasses

from repro.models.config import ModelConfig

SKIP_SHAPES = {"long_500k": "full global attention MoE; no sub-quadratic variant"}


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m", arch_type="moe",
        n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
        d_ff=0, vocab=49155, head_dim=64,
        n_experts=40, top_k=8, moe_d_ff=512,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
        vocab=512, head_dim=64, n_experts=4, top_k=2, moe_d_ff=128, dtype="float32",
    )
