"""granite-3-8b — dense GQA decoder.

Spec: 40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155.
[hf:ibm-granite/granite-3.0-2b-base family, 8B variant dims]

Paper-technique note: the GS distribution scheme (gaussian-shard +
pixel-shard) is point-primitive-specific; this arch gets the generic
DPxTP substrate (fused-allreduce data parallel + tensor parallel).
long_500k: SKIPPED — full attention, no sub-quadratic variant.
"""
import dataclasses

from repro.configs.common import lm_batch_specs, decode_specs, SHAPES
from repro.models.config import ModelConfig

SKIP_SHAPES = {"long_500k": "full global attention; no sliding-window/block-sparse variant"}


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-8b", arch_type="dense",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=12800, vocab=49155, head_dim=128, rope_theta=10_000.0,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
        d_ff=512, vocab=512, head_dim=64, dtype="float32",
    )
