"""GS dataset configs mirroring the paper's two benchmarks.

Paper: Kingsnake (110 MB volume, ~4M isosurface points) and Miranda (491 MB,
~18.18M points), 448 orbit views, image resolutions 512/1024/2048, trained on
1/2/4 A100s. The synthetic stand-ins reproduce the structural regime at
configurable scale; `paper_scale=True` requests the full point counts (used
by the dry-run/roofline paths, which never materialize them).
"""
from __future__ import annotations

import dataclasses

from repro.core.config import GSConfig


@dataclasses.dataclass(frozen=True)
class GSDataset:
    name: str
    volume: str              # "kingsnake_like" | "miranda_like"
    volume_res: int
    n_views: int
    max_points: int | None
    paper_points: int        # the paper's reported Gaussian count
    radius: float = 3.0


KINGSNAKE = GSDataset(
    name="kingsnake", volume="kingsnake_like", volume_res=96,
    n_views=448, max_points=None, paper_points=4_000_000,
)
MIRANDA = GSDataset(
    name="miranda", volume="miranda_like", volume_res=96,
    n_views=448, max_points=None, paper_points=18_180_000,
)

DATASETS = {"kingsnake": KINGSNAKE, "miranda": MIRANDA}


def paper_gs_config(resolution: int = 512, **overrides) -> GSConfig:
    return GSConfig(
        img_h=resolution, img_w=resolution,
        batch_size=overrides.pop("batch_size", 4),
        **overrides,
    )
