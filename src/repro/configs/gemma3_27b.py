"""gemma3-27b — dense GQA, 5:1 local:global sliding-window attention.

Spec: 62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144.
[hf:google/gemma-3-1b-pt family, 27B dims; 5:1 local:global, 128k ctx]

long_500k: RUN — local layers use a 1024-token sliding window (ring-buffer
KV cache); the 1-in-6 global layers carry the full 500k cache, sharded.
"""
import dataclasses

from repro.models.config import ModelConfig

SKIP_SHAPES = {}


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b", arch_type="dense",
        n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16,
        d_ff=21504, vocab=262144, head_dim=128, rope_theta=1_000_000.0,
        sliding_window=1024, layer_pattern="LLLLLG",
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
        d_ff=512, vocab=512, head_dim=64, sliding_window=32,
        layer_pattern="LG", dtype="float32",
    )
