"""Shared input-shape definitions and ShapeDtypeStruct builders.

``input_specs`` returns stand-ins for every model input (weak-type-correct,
shardable, no device allocation) — exactly what jit(...).lower() consumes in
the dry-run.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import api
from repro.models.config import ModelConfig


class ShapeCase(NamedTuple):
    seq_len: int
    global_batch: int
    kind: str   # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeCase] = {
    "train_4k": ShapeCase(4_096, 256, "train"),
    "prefill_32k": ShapeCase(32_768, 32, "prefill"),
    "decode_32k": ShapeCase(32_768, 128, "decode"),
    "long_500k": ShapeCase(524_288, 1, "decode"),
}


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def lm_batch_specs(cfg: ModelConfig, shape: ShapeCase) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if cfg.arch_type == "whisper":
        return {
            "audio_embeds": _sds((b, cfg.n_audio_ctx, cfg.d_model), cfg.dtype),
            "tokens": _sds((b, s), "int32"),
            "labels": _sds((b, s), "int32"),
        }
    if cfg.arch_type == "vlm":
        return {
            "embeds": _sds((b, s, cfg.d_model), cfg.dtype),
            "positions3": _sds((b, s, 3), "int32"),
            "labels": _sds((b, s), "int32"),
        }
    return {"tokens": _sds((b, s), "int32"), "labels": _sds((b, s), "int32")}


def decode_specs(cfg: ModelConfig, shape: ShapeCase) -> dict:
    """Specs for serve_step: one new token against a seq_len-deep cache."""
    b, s = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: api.init_cache(cfg, b, s))
    return {
        "cache": cache,
        "tokens": _sds((b, 1), "int32"),
        "pos": _sds((), "int32"),
    }


def params_specs(cfg: ModelConfig, seed: int = 0):
    """Parameter ShapeDtypeStructs via eval_shape (no allocation)."""
    from repro.models import lm as L

    return jax.eval_shape(lambda: L.init_params(cfg, jax.random.key(seed)))
