"""xlstm-350m — sLSTM + mLSTM blocks [arXiv:2405.04517].

Spec: 24L d_model=1024 4H (GQA kv=4) d_ff=0 vocab=50304.
Block ratio 7:1 mLSTM:sLSTM (the paper's main xLSTM[7:1] configuration).
d_ff=0: xLSTM blocks carry their own projections; no separate FFN.

long_500k: RUN — recurrent state, O(1) memory per token (this family is
exactly why the shape exists).
"""
import dataclasses

from repro.models.config import ModelConfig

SKIP_SHAPES = {}


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m", arch_type="xlstm",
        n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab=50304, xlstm_pattern="MMMMMMMS", pure_dp=True,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=128, n_heads=2, n_kv_heads=2,
        vocab=512, xlstm_pattern="MS", dtype="float32",
    )
