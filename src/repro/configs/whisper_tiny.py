"""whisper-tiny — encoder-decoder audio transformer [arXiv:2212.04356].

Spec: 4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865, enc-dec,
conv frontend STUB: input_specs supplies (B, 1500, 384) post-conv frame
embeddings (the allowed modality carve-out); the transformer backbone is
fully implemented.

Deviations (documented): RoPE decoder positions instead of learned
embeddings; SwiGLU MLP instead of GELU. decode_32k runs structurally
(RoPE extends past the 448-token learned context of the original).
long_500k: SKIPPED — enc-dec audio model, no sub-quadratic decoder.
"""
import dataclasses

from repro.models.config import ModelConfig

SKIP_SHAPES = {"long_500k": "enc-dec audio decoder; full attention, no sub-quadratic variant"}


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny", arch_type="whisper",
        n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
        d_ff=1536, vocab=51865, head_dim=64,
        n_enc_layers=4, n_audio_ctx=1500, scan_layers=False, pure_dp=True,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, n_enc_layers=2, d_model=128, n_heads=2,
        n_kv_heads=2, d_ff=256, vocab=512, n_audio_ctx=64, dtype="float32",
    )
