"""qwen3-0.6b — dense GQA with per-head q/k RMSNorm [hf:Qwen/Qwen3-8B family].

Spec: 28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936, qk_norm.
long_500k: SKIPPED — full attention.
"""
import dataclasses

from repro.models.config import ModelConfig

SKIP_SHAPES = {"long_500k": "full global attention; no sub-quadratic variant"}


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-0.6b", arch_type="dense",
        n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8,
        d_ff=3072, vocab=151936, head_dim=128, qk_norm=True,
        rope_theta=1_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
        d_ff=512, vocab=512, head_dim=64, dtype="float32",
    )
