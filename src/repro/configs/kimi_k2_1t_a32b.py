"""kimi-k2-1t-a32b — trillion-parameter MoE (paper-table scale) [arXiv:2501.kimi2].

Spec: 61L d_model=7168 64H (GQA kv=8) expert d_ff=2048 vocab=163840,
MoE 384 experts top-8 (+1 shared expert, K2's DeepSeek-style design).

Honest scale note (see EXPERIMENTS.md §Dry-run): train_4k at 256 chips
compiles, but params+Adam exceed v5e 16 GB/chip — documented, with the
multi-pod / precision remedies; this is the paper-table "exceeds
single-unit memory" case, the transformer analogue of Miranda-on-one-A100.
long_500k: SKIPPED — full attention.
"""
import dataclasses

from repro.models.config import ModelConfig

SKIP_SHAPES = {"long_500k": "full global attention MoE; no sub-quadratic variant"}


def config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b", arch_type="moe",
        n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
        d_ff=0, vocab=163840, head_dim=112,
        n_experts=384, top_k=8, moe_d_ff=2048, n_shared_experts=1,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
        vocab=512, head_dim=64, n_experts=4, top_k=2, moe_d_ff=128,
        n_shared_experts=1, dtype="float32",
    )
