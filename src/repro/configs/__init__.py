"""Assigned-architecture registry: --arch <id> resolves here."""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "granite_3_8b",
    "gemma3_27b",
    "granite_moe_3b_a800m",
    "xlstm_350m",
    "zamba2_7b",
    "kimi_k2_1t_a32b",
    "qwen3_0_6b",
    "whisper_tiny",
    "qwen2_vl_72b",
    "moonshot_v1_16b_a3b",
]

ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}
ALIASES["qwen3-0.6b"] = "qwen3_0_6b"
ALIASES["qwen3_0.6b"] = "qwen3_0_6b"


def get_arch(name: str):
    """Resolve an architecture id (dash or underscore form) to its module."""
    name = ALIASES.get(name, name)
    if name not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ALIASES)}")
    return importlib.import_module(f"repro.configs.{name}")
