"""zamba2-7b — Mamba2 backbone + shared attention blocks [arXiv:2411.15242].

Spec: 81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000 ssm_state=64.
Two shared attention blocks alternate every 6 mamba layers (Zamba2's
shared-weight design; we omit the per-invocation LoRA deltas — noted
deviation). ssm: expand 2 -> d_inner 7168, headdim 64 -> 112 ssm heads.

long_500k: RUN — SSM state is O(1); the shared attention blocks carry the
long cache.
"""
import dataclasses

from repro.models.config import ModelConfig

SKIP_SHAPES = {}


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b", arch_type="zamba",
        n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
        d_ff=14336, vocab=32000, head_dim=112,
        ssm_state=64, ssm_heads=112, ssm_expand=2, attn_every=6,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=4, d_model=256, n_heads=4, n_kv_heads=4,
        d_ff=512, vocab=512, head_dim=64,
        ssm_state=16, ssm_heads=8, attn_every=1, dtype="float32",
    )
