"""qwen2-vl-72b — VLM decoder with M-RoPE [arXiv:2409.12191].

Spec: 80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.
M-RoPE sections (16,24,24) over head_dim 128; dynamic-resolution ViT
frontend is a STUB: input_specs supplies merged (B,S,8192) embeddings and
(B,S,3) [t,h,w] position triples (the allowed modality carve-out).
long_500k: SKIPPED — full attention.
"""
import dataclasses

from repro.models.config import ModelConfig

SKIP_SHAPES = {"long_500k": "full global attention VLM; no sub-quadratic variant"}


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b", arch_type="vlm",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=29568, vocab=152064, head_dim=128,
        mrope_sections=(16, 24, 24), rope_theta=1_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
        d_ff=512, vocab=512, head_dim=64, mrope_sections=(8, 12, 12),
        dtype="float32",
    )
