"""moonshot-v1-16b-a3b — Moonlight-style MoE [hf:moonshotai/Moonlight-16B-A3B].

Spec: 48L d_model=2048 16H (GQA kv=16) expert d_ff=1408 vocab=163840,
MoE 64 experts top-6. (Pool labels it [dense] but the spec line carries the
MoE fields and the name says a3b-active -> built as MoE, noted here.)
long_500k: SKIPPED — full attention.
"""
import dataclasses

from repro.models.config import ModelConfig

SKIP_SHAPES = {"long_500k": "full global attention MoE; no sub-quadratic variant"}


def config() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b", arch_type="moe",
        n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=0, vocab=163840, head_dim=128,
        n_experts=64, top_k=6, moe_d_ff=1408, n_shared_experts=2,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
        vocab=512, head_dim=64, n_experts=4, top_k=2, moe_d_ff=128,
        n_shared_experts=1, dtype="float32",
    )
