"""jit wrapper: GQA head expansion, padding, custom_vjp (oracle backward)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as _k
from repro.kernels.flash_attention.ref import attention_ref


def flash_attention(q, k, v, *, causal=True, window=None, q_offset=0, backend="pallas"):
    """q: (B,S,H,hd); k/v: (B,Skv,Hkv,hd). Returns (B,S,H,hd).

    backend="ref" or Skv > 8192 falls back to the chunked-scan oracle.
    """
    b, s, h, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    if backend == "ref" or skv > 8192:
        return attention_ref(q, k, v, causal=causal, window=window, q_offset=q_offset)

    group = h // hkv

    @jax.custom_vjp
    def fwd(q, k, v):
        pad_q = (-s) % _k.BQ
        kf = jnp.repeat(k, group, axis=2)
        vf = jnp.repeat(v, group, axis=2)
        qq = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        # (B,S,H,hd) -> (B*H, S, hd)
        def to_bh(t):
            return t.transpose(0, 2, 1, 3).reshape(b * h, -1, hd)

        run = _k.make_flash(b * h, s + pad_q, skv, hd, causal, window, q_offset, str(q.dtype))
        o = run(to_bh(qq), to_bh(kf), to_bh(vf))
        return o.reshape(b, h, s + pad_q, hd).transpose(0, 2, 1, 3)[:, :s]

    def fwd_fwd(q, k, v):
        return fwd(q, k, v), (q, k, v)

    def fwd_bwd(res, ct):
        q, k, v = res
        _, vjp = jax.vjp(
            lambda a, b_, c: attention_ref(a, b_, c, causal=causal, window=window, q_offset=q_offset),
            q, k, v,
        )
        return vjp(ct)

    fwd.defvjp(fwd_fwd, fwd_bwd)
    return fwd(q, k, v)
