"""Pallas TPU attention kernel: row-blocked, K/V resident in VMEM.

Grid = (batch*heads, S/BQ). Each program computes one (BQ, hd) output block:
scores (BQ, Skv) live entirely in VMEM/VREGs — the (S, S) matrix never
touches HBM (the flash property). K/V for one head fit VMEM for Skv <= ~8k
at hd=128 (2 x 4 MB); longer sequences use the production chunked-scan path
(repro.models.common.chunked_attention), which is also this kernel's oracle.

MXU work per program: (BQ x hd)x(hd x Skv) + (BQ x Skv)x(Skv x hd).
Causal/sliding-window masking is positional (iota vs program offset).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BQ = 128


def _kernel(q_ref, k_ref, v_ref, o_ref, *, causal, window, q_offset, scale):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale          # (BQ, hd)
    k = k_ref[0].astype(jnp.float32)                  # (Skv, hd)
    v = v_ref[0].astype(jnp.float32)
    skv = k.shape[0]

    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (BQ, Skv)

    q_pos = q_offset + qi * BQ + jax.lax.broadcasted_iota(jnp.int32, (BQ, skv), 0)
    kv_pos = jax.lax.broadcasted_iota(jnp.int32, (BQ, skv), 1)
    mask = jnp.ones((BQ, skv), jnp.bool_)
    if causal:
        mask = mask & (kv_pos <= q_pos)
    if window is not None:
        mask = mask & (q_pos - kv_pos < window)
    scores = jnp.where(mask, scores, -1e30)

    m = jnp.max(scores, axis=1, keepdims=True)
    p = jnp.exp(scores - m)
    l = jnp.sum(p, axis=1, keepdims=True)
    o = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ) / jnp.maximum(l, 1e-30)
    o_ref[0] = o.astype(o_ref.dtype)


@functools.lru_cache(maxsize=None)
def make_flash(bh: int, sq: int, skv: int, hd: int, causal: bool, window, q_offset: int,
               dtype_name: str, interpret=None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    scale = 1.0 / (hd ** 0.5)
    kern = functools.partial(_kernel, causal=causal, window=window, q_offset=q_offset, scale=scale)
    dtype = jnp.dtype(dtype_name)

    def run(q, k, v):
        return pl.pallas_call(
            kern,
            grid=(bh, sq // BQ),
            in_specs=[
                pl.BlockSpec((1, BQ, hd), lambda b, i: (b, i, 0)),
                pl.BlockSpec((1, skv, hd), lambda b, i: (b, 0, 0)),
                pl.BlockSpec((1, skv, hd), lambda b, i: (b, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, BQ, hd), lambda b, i: (b, i, 0)),
            out_shape=jax.ShapeDtypeStruct((bh, sq, hd), dtype),
            interpret=interpret,
        )(q, k, v)

    return run
