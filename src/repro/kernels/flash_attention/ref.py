"""Oracle: the production chunked (online-softmax) attention."""
from repro.models.common import chunked_attention


def attention_ref(q, k, v, *, causal=True, window=None, q_offset=0):
    return chunked_attention(q, k, v, causal=causal, window=window, q_offset=q_offset)
