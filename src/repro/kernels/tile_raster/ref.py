"""Pure-jnp oracle for tile rasterization (differentiable).

This is the canonical definition of the compositing math. The Pallas kernel
in ``tile_raster.py`` must match this bit-for-bit (same masking rules as the
CUDA 3D-GS rasterizer: alpha clamp at 0.99, skip alpha < 1/255, stop when
transmittance would drop below 1e-4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.projection import MX, MY, CA, CB, CC, OP, CR, CG, CB_, RAD

ALPHA_MAX = 0.99
ALPHA_MIN = 1.0 / 255.0
T_EPS = 1e-4


def compose_tile(
    tile_splats: jax.Array,  # (K, 11) packed splats, front-to-back depth order
    valid: jax.Array,        # (K,) bool
    pix_x: jax.Array,        # (P,) pixel center x coords
    pix_y: jax.Array,        # (P,) pixel center y coords
    bg: jax.Array,           # (3,)
) -> tuple[jax.Array, jax.Array]:
    """Front-to-back alpha compositing of K splats over P pixels.

    Returns (rgb (P,3), transmittance (P,)).
    """
    mx = tile_splats[:, MX][:, None]
    my = tile_splats[:, MY][:, None]
    ca = tile_splats[:, CA][:, None]
    cb = tile_splats[:, CB][:, None]
    cc = tile_splats[:, CC][:, None]
    op = tile_splats[:, OP][:, None]
    rgb = tile_splats[:, CR : CB_ + 1]  # (K,3)

    dx = pix_x[None, :] - mx  # (K,P)
    dy = pix_y[None, :] - my
    power = -0.5 * (ca * dx * dx + cc * dy * dy) - cb * dx * dy
    alpha = op * jnp.exp(jnp.minimum(power, 0.0))
    alpha = jnp.minimum(alpha, ALPHA_MAX)
    live = valid[:, None] & (power <= 0.0) & (alpha >= ALPHA_MIN)
    alpha = jnp.where(live, alpha, 0.0)

    one_minus = 1.0 - alpha
    t_incl = jnp.cumprod(one_minus, axis=0)                     # T after splat k
    t_excl = jnp.concatenate([jnp.ones_like(t_incl[:1]), t_incl[:-1]], axis=0)
    # CUDA rasterizer stop rule: splat k only composited if T would stay >= eps
    alive = t_incl >= T_EPS
    w = jnp.where(alive, alpha * t_excl, 0.0)                   # (K,P)
    # transmittance after the last composited splat (1.0 if none composited;
    # t_incl is non-increasing so the min over alive entries is the last one)
    t_final = jnp.min(jnp.where(alive, t_incl, 1.0), axis=0)
    out = jnp.einsum("kp,kc->pc", w, rgb) + t_final[:, None] * bg[None, :]
    return out, t_final


def tile_pixel_coords(tile_id, tiles_x, tile_h, tile_w, row_offset=0):
    """Pixel-center coordinates for a flat row-major tile id."""
    ty = tile_id // tiles_x
    tx = tile_id % tiles_x
    ys = ty * tile_h + row_offset + jnp.arange(tile_h)
    xs = tx * tile_w + jnp.arange(tile_w)
    yy, xx = jnp.meshgrid(ys, xs, indexing="ij")
    return xx.reshape(-1) + 0.5, yy.reshape(-1) + 0.5  # (P,), (P,)


def rasterize_tiles_ref(
    packed: jax.Array,      # (N, 11) depth-sorted packed splats
    tile_idx: jax.Array,    # (T, K) int32 indices into packed (depth order)
    tile_valid: jax.Array,  # (T, K) bool
    img_h: int,
    img_w: int,
    tile_h: int,
    tile_w: int,
    bg: jax.Array,
    row_offset: int = 0,
) -> tuple[jax.Array, jax.Array]:
    """Full-image tiled rasterization. Returns (image (H,W,3), T (H,W))."""
    tiles_x = img_w // tile_w
    t_count = tile_idx.shape[0]
    tile_splats = packed[tile_idx]  # (T,K,11)

    def one(tid, splats, valid):
        px, py = tile_pixel_coords(tid, tiles_x, tile_h, tile_w, row_offset)
        return compose_tile(splats, valid, px, py, bg)

    rgb, trans = jax.vmap(one)(jnp.arange(t_count), tile_splats, tile_valid)
    # (T, P, 3) -> (H, W, 3)
    tiles_y = img_h // tile_h
    img = rgb.reshape(tiles_y, tiles_x, tile_h, tile_w, 3).transpose(0, 2, 1, 3, 4).reshape(img_h, img_w, 3)
    tmap = trans.reshape(tiles_y, tiles_x, tile_h, tile_w).transpose(0, 2, 1, 3).reshape(img_h, img_w)
    return img, tmap


def rasterize_naive(packed: jax.Array, img_h: int, img_w: int, bg: jax.Array, chunk: int = 4096):
    """Untiled golden oracle: every splat vs every pixel (front-to-back).

    Used for quality tests and to validate the tile-list builder (a tiled
    render with sufficient K must match this).
    """
    ys, xs = jnp.meshgrid(jnp.arange(img_h) + 0.5, jnp.arange(img_w) + 0.5, indexing="ij")
    px = xs.reshape(-1)
    py = ys.reshape(-1)
    n_pix = px.shape[0]
    pad = (-n_pix) % chunk
    px = jnp.pad(px, (0, pad))
    py = jnp.pad(py, (0, pad))
    valid = packed[:, RAD] > 0

    def one(args):
        cx, cy = args
        return compose_tile(packed, valid, cx, cy, bg)

    rgb, trans = jax.lax.map(one, (px.reshape(-1, chunk), py.reshape(-1, chunk)))
    rgb = rgb.reshape(-1, 3)[:n_pix].reshape(img_h, img_w, 3)
    trans = trans.reshape(-1)[:n_pix].reshape(img_h, img_w)
    return rgb, trans
