from repro.kernels.tile_raster.ops import rasterize_tiles

__all__ = ["rasterize_tiles"]
