"""jit-ready wrapper around the tile rasterizer with backend dispatch.

backend="ref"    — pure-jnp oracle (differentiable via XLA autodiff).
backend="pallas" — Pallas TPU kernel (interpret mode on CPU), custom VJP.

Both produce identical images/gradients; tests assert allclose across a
shape/dtype sweep.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.tile_raster import ref as _ref
from repro.kernels.tile_raster import tile_raster as _pallas


def rasterize_tiles(
    packed: jax.Array,      # (N, 11) depth-sorted packed splats
    tile_idx: jax.Array,    # (T, K) int32
    tile_valid: jax.Array,  # (T, K) bool
    *,
    img_h: int,
    img_w: int,
    tile_h: int,
    tile_w: int,
    bg: jax.Array,
    backend: str = "ref",
    row_offset: int = 0,
    interpret=None,
) -> tuple[jax.Array, jax.Array]:
    """Rasterize to ((H,W,3) image, (H,W) transmittance)."""
    if backend == "ref":
        return _ref.rasterize_tiles_ref(
            packed, tile_idx, tile_valid, img_h, img_w, tile_h, tile_w, bg, row_offset
        )
    if backend != "pallas":
        raise ValueError(f"unknown backend {backend!r}")

    tiles_y = img_h // tile_h
    tiles_x = img_w // tile_w
    # Gather per-tile splat slabs; XLA autodiff turns this into the
    # scatter-add that accumulates per-splat grads across tiles.
    tile_splats = packed[tile_idx]                      # (T,K,11)
    splats_t = jnp.swapaxes(tile_splats, 1, 2)          # (T,11,K)
    composite = _pallas.make_composite(tiles_x, tile_h, tile_w, row_offset, interpret)
    raw, tfin = composite(splats_t.astype(jnp.float32), tile_valid.astype(jnp.float32))
    # (T,3,P) -> (H,W,3)
    img = (
        raw.reshape(tiles_y, tiles_x, 3, tile_h, tile_w)
        .transpose(0, 3, 1, 4, 2)
        .reshape(img_h, img_w, 3)
    )
    tmap = tfin.reshape(tiles_y, tiles_x, tile_h, tile_w).transpose(0, 2, 1, 3).reshape(img_h, img_w)
    img = img + tmap[..., None] * bg[None, None, :]
    return img, tmap


rasterize_naive = _ref.rasterize_naive
