"""Pallas TPU tile rasterizer (forward + backward).

TPU adaptation of the CUDA 3D-GS tile rasterizer. The CUDA kernel walks the
depth-sorted splat list sequentially per warp with shared-memory staging and
early exit. TPUs have no warp shuffles or atomics, so we restructure:

  1. alpha matrix        A[k,p] = clamped opacity*exp(quadratic) — fully
                         vectorized over (K splats × P pixels) in VMEM.
  2. transmittance       T via a log-space Hillis-Steele inclusive scan along
                         K (log2(K) static doubling steps — no sequential
                         K-loop, no dynamic control flow).
  3. composite           out[c,p] = sum_k C[c,k] * W[k,p] — a (3,K)x(K,P)
                         MXU matmul. Early termination becomes masking
                         (W=0 once T < 1e-4), which costs nothing on a
                         systolic/vector machine.

The backward kernel recomputes A,T (flash-attention-style rematerialization:
nothing but the inputs and the output cotangents are needed) and emits
per-splat parameter gradients with two more MXU matmuls plus a reverse scan.

Block sizes: one grid step = one image tile. VMEM footprint ~ a few (K,P)
f32 temporaries: K=1024, P=256 -> 1 MB each, well inside 16 MB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tile_raster.ref import ALPHA_MAX, ALPHA_MIN, T_EPS

_NEG_BIG = -1e30


def _inclusive_cumsum_doubling(x: jax.Array) -> jax.Array:
    """Inclusive cumsum along axis 0 via static Hillis-Steele doubling.

    K static shift+add steps (log2 K) — Mosaic-friendly (static slices only).
    """
    k = x.shape[0]
    d = 1
    while d < k:
        shifted = jnp.concatenate([jnp.zeros_like(x[:d]), x[:-d]], axis=0)
        x = x + shifted
        d *= 2
    return x


def _reverse_exclusive_cumsum(x: jax.Array) -> jax.Array:
    """Reverse *exclusive* cumsum along axis 0: out[k] = sum_{j>k} x[j]."""
    total = jnp.sum(x, axis=0, keepdims=True)
    incl = _inclusive_cumsum_doubling(x)
    return total - incl


def _pixel_coords(tile_id, tiles_x: int, tile_h: int, tile_w: int, row_offset: int):
    """Pixel-center coords (1,P) f32 for a flat row-major tile id (traced)."""
    p = tile_h * tile_w
    flat = jax.lax.broadcasted_iota(jnp.int32, (1, p), 1)
    yy = flat // tile_w
    xx = flat - yy * tile_w
    ty = tile_id // tiles_x
    tx = tile_id - ty * tiles_x
    px = (tx * tile_w + xx).astype(jnp.float32) + 0.5
    py = (ty * tile_h + row_offset + yy).astype(jnp.float32) + 0.5
    return px, py


def _alpha_and_trans(splats, valid, px, py):
    """Shared forward math: splats (11,K), valid (1,K), px/py (1,P).

    Returns (alpha (K,P), t_incl (K,P), t_excl (K,P), alive (K,P), colors (3,K)).
    """
    k = splats.shape[1]
    mx = splats[0, :].reshape(k, 1)
    my = splats[1, :].reshape(k, 1)
    ca = splats[2, :].reshape(k, 1)
    cb = splats[3, :].reshape(k, 1)
    cc = splats[4, :].reshape(k, 1)
    op = splats[5, :].reshape(k, 1)
    colors = splats[6:9, :]  # (3,K)
    vmask = valid.reshape(k, 1) > 0.5

    dx = px - mx  # (K,P)
    dy = py - my
    power = -0.5 * (ca * dx * dx + cc * dy * dy) - cb * dx * dy
    alpha_raw = op * jnp.exp(jnp.minimum(power, 0.0))
    alpha = jnp.minimum(alpha_raw, ALPHA_MAX)
    live = vmask & (power <= 0.0) & (alpha >= ALPHA_MIN)
    alpha = jnp.where(live, alpha, 0.0)

    lm = jnp.log1p(-alpha)
    s_incl = _inclusive_cumsum_doubling(lm)
    t_incl = jnp.exp(s_incl)
    t_excl = jnp.exp(s_incl - lm)
    alive = t_incl >= T_EPS
    return alpha, alpha_raw, live, t_incl, t_excl, alive, colors, (dx, dy, power)


def _fwd_kernel(splats_ref, valid_ref, out_ref, tfin_ref, *, tiles_x, tile_h, tile_w, row_offset):
    t = pl.program_id(0)
    splats = splats_ref[0]  # (11,K)
    valid = valid_ref[...]  # (1,K)
    px, py = _pixel_coords(t, tiles_x, tile_h, tile_w, row_offset)
    alpha, _, _, t_incl, t_excl, alive, colors, _ = _alpha_and_trans(splats, valid, px, py)
    w = jnp.where(alive, alpha * t_excl, 0.0)  # (K,P)
    out = jax.lax.dot_general(
        colors, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (3,P)
    t_final = jnp.min(jnp.where(alive, t_incl, 1.0), axis=0, keepdims=True)  # (1,P)
    out_ref[0] = out
    tfin_ref[...] = t_final


def _bwd_kernel(
    splats_ref, valid_ref, gout_ref, gtfin_ref, dsplats_ref, *, tiles_x, tile_h, tile_w, row_offset
):
    t = pl.program_id(0)
    splats = splats_ref[0]       # (11,K)
    valid = valid_ref[...]       # (1,K)
    gout = gout_ref[0]           # (3,P)
    gtfin = gtfin_ref[...]       # (1,P)
    px, py = _pixel_coords(t, tiles_x, tile_h, tile_w, row_offset)

    alpha, alpha_raw, live, t_incl, t_excl, alive, colors, (dx, dy, power) = _alpha_and_trans(
        splats, valid, px, py
    )
    w = jnp.where(alive, alpha * t_excl, 0.0)  # (K,P)

    # d colors: out = C @ W  =>  dC = gout @ W^T   (3,P)x(P,K) -> (3,K)
    dcolors = jax.lax.dot_general(
        gout, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (3,K)
    # dW = C^T @ gout : (K,3)x(3,P) -> (K,P)
    dw = jax.lax.dot_general(
        colors, gout, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (K,P)
    dw = jnp.where(alive, dw, 0.0)

    # t_final grad: t_final = t_incl at last alive (or 1). d t_final / d alpha_k
    # = -t_final/(1-alpha_k) for alive k. Downstream-weight term:
    #   B[k,p] = sum_{j>k} dW[j,p]*W[j,p] + gtfin[p]*t_final[p]
    t_final = jnp.min(jnp.where(alive, t_incl, 1.0), axis=0, keepdims=True)  # (1,P)
    b = _reverse_exclusive_cumsum(dw * w) + gtfin * t_final  # (K,P)

    one_minus = 1.0 - alpha
    dalpha = jnp.where(alive, dw * t_excl - b / one_minus, 0.0)  # (K,P)

    # chain through masking & clamp: alpha = live ? min(op*exp(min(power,0)), 0.99) : 0
    unclamped = live & (alpha_raw < ALPHA_MAX)
    dalpha_raw = jnp.where(unclamped, dalpha, 0.0)
    e = jnp.exp(jnp.minimum(power, 0.0))
    op = splats[5, :].reshape(-1, 1)
    dop = jnp.sum(dalpha_raw * e, axis=1)  # (K,)
    dpower = jnp.where(power < 0.0, dalpha_raw * op * e, 0.0)  # (K,P)

    ca = splats[2, :].reshape(-1, 1)
    cb = splats[3, :].reshape(-1, 1)
    cc = splats[4, :].reshape(-1, 1)
    dca = jnp.sum(dpower * (-0.5 * dx * dx), axis=1)
    dcb = jnp.sum(dpower * (-dx * dy), axis=1)
    dcc = jnp.sum(dpower * (-0.5 * dy * dy), axis=1)
    ddx = dpower * (-ca * dx - cb * dy)
    ddy = dpower * (-cc * dy - cb * dx)
    dmx = -jnp.sum(ddx, axis=1)
    dmy = -jnp.sum(ddy, axis=1)

    k = splats.shape[1]
    zeros_k = jnp.zeros((k,), jnp.float32)
    dsplats = jnp.stack(
        [dmx, dmy, dca, dcb, dcc, dop, dcolors[0], dcolors[1], dcolors[2], zeros_k, zeros_k],
        axis=0,
    )  # (11,K)
    dsplats_ref[0] = dsplats


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


@functools.lru_cache(maxsize=None)
def make_composite(tiles_x: int, tile_h: int, tile_w: int, row_offset: int, interpret=None):
    """Build the custom_vjp'd tile compositor for a static tile layout.

    Returned fn: (tile_splats_t (T,11,K) f32, valid (T,K) f32) ->
                 (out (T,3,P) f32, t_final (T,P) f32)
    Differentiable w.r.t. tile_splats_t only (valid gets zero cotangent).
    """
    interpret = _auto_interpret(interpret)

    def _run_fwd(splats_t, valid):
        t_count, _, k = splats_t.shape
        p = tile_h * tile_w
        kern = functools.partial(
            _fwd_kernel, tiles_x=tiles_x, tile_h=tile_h, tile_w=tile_w, row_offset=row_offset
        )
        return pl.pallas_call(
            kern,
            grid=(t_count,),
            in_specs=[
                pl.BlockSpec((1, 11, k), lambda t: (t, 0, 0)),
                pl.BlockSpec((1, k), lambda t: (t, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, 3, p), lambda t: (t, 0, 0)),
                pl.BlockSpec((1, p), lambda t: (t, 0)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((t_count, 3, p), jnp.float32),
                jax.ShapeDtypeStruct((t_count, p), jnp.float32),
            ],
            interpret=interpret,
        )(splats_t, valid)

    def _run_bwd(splats_t, valid, gout, gtfin):
        t_count, _, k = splats_t.shape
        p = tile_h * tile_w
        kern = functools.partial(
            _bwd_kernel, tiles_x=tiles_x, tile_h=tile_h, tile_w=tile_w, row_offset=row_offset
        )
        return pl.pallas_call(
            kern,
            grid=(t_count,),
            in_specs=[
                pl.BlockSpec((1, 11, k), lambda t: (t, 0, 0)),
                pl.BlockSpec((1, k), lambda t: (t, 0)),
                pl.BlockSpec((1, 3, p), lambda t: (t, 0, 0)),
                pl.BlockSpec((1, p), lambda t: (t, 0)),
            ],
            out_specs=pl.BlockSpec((1, 11, k), lambda t: (t, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((t_count, 11, k), jnp.float32),
            interpret=interpret,
        )(splats_t, valid, gout, gtfin)

    @jax.custom_vjp
    def composite(splats_t, valid):
        return _run_fwd(splats_t, valid)

    def composite_fwd(splats_t, valid):
        out = _run_fwd(splats_t, valid)
        return out, (splats_t, valid)

    def composite_bwd(res, cts):
        splats_t, valid = res
        gout, gtfin = cts
        dsplats = _run_bwd(splats_t, valid, gout, gtfin)
        return dsplats, jnp.zeros_like(valid)

    composite.defvjp(composite_fwd, composite_bwd)
    return composite
