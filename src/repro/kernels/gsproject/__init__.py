from repro.kernels.gsproject.ops import project_packed

__all__ = ["project_packed"]
