"""Oracle for the projection kernel = the production jnp projection math."""
from __future__ import annotations

from repro.core import gaussians as G
from repro.core import projection as P


def project_ref(g: G.GaussianModel, cam: P.Camera, *, near: float = 0.01):
    """(N,11) packed splats — the exact math the Pallas kernel must match."""
    return P.project(g, cam, near=near)
