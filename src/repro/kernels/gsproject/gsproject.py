"""Pallas TPU kernel: EWA projection of a block of Gaussians (deg-0 SH).

Pure VPU work — every quantity is an elementwise formula over a lane-block
of Gaussians, laid out SoA-transposed so the Gaussian index is the 128-lane
dimension: means (3,N), scales (3,N), quats (4,N), opacity (N,), sh0 (3,N)
-> packed (11,N). Camera scalars ride in a replicated (1,32) VMEM block.

Covariance path avoids any 3x3 matrix ops: cov3d's six unique entries are
computed as sums over the three scaled rotation columns, then folded with
the two JW rows — ~90 fused vector ops per lane-block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 1024
CAM_SLOTS = 32  # viewmat(16), fx, fy, cx, cy, near, campos(3) -> padded to 32


def _kernel(means_ref, scales_ref, quats_ref, opac_ref, sh0_ref, cam_ref, out_ref, *, blur):
    cam = cam_ref[0]
    rv = [[cam[4 * i + j] for j in range(4)] for i in range(3)]  # rows of viewmat[:3]
    fx, fy, cx, cy, near = cam[16], cam[17], cam[18], cam[19], cam[20]

    mx, my_, mz = means_ref[0], means_ref[1], means_ref[2]
    sx = jnp.exp(scales_ref[0])
    sy = jnp.exp(scales_ref[1])
    sz = jnp.exp(scales_ref[2])
    qw, qx, qy, qz = quats_ref[0], quats_ref[1], quats_ref[2], quats_ref[3]
    qn = jax.lax.rsqrt(qw * qw + qx * qx + qy * qy + qz * qz + 1e-24)
    qw, qx, qy, qz = qw * qn, qx * qn, qy * qn, qz * qn

    # rotation matrix columns scaled: col_k = s_k * R[:, k]
    r = [
        [1 - 2 * (qy * qy + qz * qz), 2 * (qx * qy - qw * qz), 2 * (qx * qz + qw * qy)],
        [2 * (qx * qy + qw * qz), 1 - 2 * (qx * qx + qz * qz), 2 * (qy * qz - qw * qx)],
        [2 * (qx * qz - qw * qy), 2 * (qy * qz + qw * qx), 1 - 2 * (qx * qx + qy * qy)],
    ]
    s2 = [sx * sx, sy * sy, sz * sz]
    # cov3d_ij = sum_k s_k^2 r[i][k] r[j][k]
    cov = {}
    for i in range(3):
        for j in range(i, 3):
            cov[(i, j)] = sum(s2[k] * r[i][k] * r[j][k] for k in range(3))

    def cov3(i, j):
        return cov[(i, j)] if i <= j else cov[(j, i)]

    # camera-space position
    pc = [rv[i][0] * mx + rv[i][1] * my_ + rv[i][2] * mz + rv[i][3] for i in range(3)]
    x, y, z = pc
    valid = z > near
    zc = jnp.where(valid, z, 1.0)
    inv_z = 1.0 / zc
    inv_z2 = inv_z * inv_z

    mean_x = fx * x * inv_z + cx
    mean_y = fy * y * inv_z + cy

    # JW rows (2x3): jw[a][k] = J[a,:] @ Rv[:,k]
    jw0 = [fx * inv_z * rv[0][k] - fx * x * inv_z2 * rv[2][k] for k in range(3)]
    jw1 = [fy * inv_z * rv[1][k] - fy * y * inv_z2 * rv[2][k] for k in range(3)]
    v0 = [sum(cov3(k, l) * jw0[l] for l in range(3)) for k in range(3)]
    v1 = [sum(cov3(k, l) * jw1[l] for l in range(3)) for k in range(3)]
    a = sum(jw0[k] * v0[k] for k in range(3)) + blur
    b = sum(jw1[k] * v0[k] for k in range(3))
    c = sum(jw1[k] * v1[k] for k in range(3)) + blur

    det = jnp.maximum(a * c - b * b, 1e-12)
    inv_det = 1.0 / det
    conic_a = c * inv_det
    conic_b = -b * inv_det
    conic_c = a * inv_det
    mid = 0.5 * (a + c)
    lam1 = mid + jnp.sqrt(jnp.maximum(mid * mid - det, 0.0))
    radius = jnp.minimum(jnp.ceil(3.0 * jnp.sqrt(jnp.maximum(lam1, 0.0))), 1e4)

    opac = jax.nn.sigmoid(opac_ref[0])
    sh_c0 = 0.28209479177387814
    cr = jnp.clip(sh_c0 * sh0_ref[0] + 0.5, 0.0, 1.0)
    cg = jnp.clip(sh_c0 * sh0_ref[1] + 0.5, 0.0, 1.0)
    cb = jnp.clip(sh_c0 * sh0_ref[2] + 0.5, 0.0, 1.0)

    opac = jnp.where(valid, opac, 0.0)
    radius = jnp.where(valid, radius, 0.0)
    depth = jnp.where(valid, z, jnp.inf)

    for slot, val in enumerate(
        [mean_x, mean_y, conic_a, conic_b, conic_c, opac, cr, cg, cb, depth, radius]
    ):
        out_ref[slot] = val


@functools.lru_cache(maxsize=None)
def make_project(n_padded: int, blur: float = 0.3, interpret=None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    kern = functools.partial(_kernel, blur=blur)
    grid = (n_padded // BLOCK_N,)

    def run(means_t, scales_t, quats_t, opac, sh0_t, cam_vec):
        return pl.pallas_call(
            kern,
            grid=grid,
            in_specs=[
                pl.BlockSpec((3, BLOCK_N), lambda i: (0, i)),
                pl.BlockSpec((3, BLOCK_N), lambda i: (0, i)),
                pl.BlockSpec((4, BLOCK_N), lambda i: (0, i)),
                pl.BlockSpec((1, BLOCK_N), lambda i: (0, i)),
                pl.BlockSpec((3, BLOCK_N), lambda i: (0, i)),
                pl.BlockSpec((1, CAM_SLOTS), lambda i: (0, 0)),
            ],
            out_specs=pl.BlockSpec((11, BLOCK_N), lambda i: (0, i)),
            out_shape=jax.ShapeDtypeStruct((11, n_padded), jnp.float32),
            interpret=interpret,
        )(means_t, scales_t, quats_t, opac, sh0_t, cam_vec)

    return run
