"""jit wrapper for the projection kernel: custom_vjp with the oracle's
backward (projection is ~3% of step FLOPs; its backward fuses fine in XLA,
so only the forward gets a hand kernel — see DESIGN.md §6)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import gaussians as G
from repro.core import projection as P
from repro.kernels.gsproject import gsproject as _k
from repro.kernels.gsproject.ref import project_ref

_CAM_USED = 16 + 5 + 3  # viewmat(4x4 row-major), fx/fy/cx/cy/near, campos


def project_packed(g: G.GaussianModel, cam: P.Camera, *, backend: str = "ref", near: float = 0.01):
    """(N, 11) packed splats. backend="pallas" requires sh_degree == 0."""
    if backend == "ref" or g.sh.shape[1] != 1:
        return project_ref(g, cam, near=near)

    @jax.custom_vjp
    def fwd(gm):
        n = gm.means.shape[0]
        pad = (-n) % _k.BLOCK_N
        mt = jnp.pad(gm.means, ((0, pad), (0, 0))).T
        st = jnp.pad(gm.log_scales, ((0, pad), (0, 0))).T
        qt = jnp.pad(gm.quats, ((0, pad), (0, 0))).T        # zero quats: rsqrt guard
        ot = jnp.pad(gm.opacity_logit, (0, pad), constant_values=-20.0)[None]
        sh0 = jnp.pad(gm.sh[:, 0, :], ((0, pad), (0, 0))).T
        cam_vec = jnp.concatenate(
            [
                cam.viewmat.reshape(-1),                     # 16 (kernel reads rows 0..2)
                jnp.stack([cam.fx, cam.fy, cam.cx, cam.cy]),
                jnp.asarray([near], jnp.float32),
                cam.campos,
                jnp.zeros((_k.CAM_SLOTS - _CAM_USED,), jnp.float32),
            ]
        )[None].astype(jnp.float32)
        run = _k.make_project(n + pad)
        out_t = run(
            mt.astype(jnp.float32), st.astype(jnp.float32), qt.astype(jnp.float32),
            ot.astype(jnp.float32), sh0.astype(jnp.float32), cam_vec,
        )
        return out_t.T[:n]

    def fwd_fwd(gm):
        return fwd(gm), gm

    def fwd_bwd(gm, ct):
        _, vjp = jax.vjp(lambda m: project_ref(m, cam, near=near), gm)
        return vjp(ct)

    fwd.defvjp(fwd_fwd, fwd_bwd)
    return fwd(g)
