"""Sharded checkpointing: per-leaf .npy files + a JSON manifest.

Layout:  <dir>/step_<N>/manifest.json
         <dir>/step_<N>/<flat.key.path>.npy

Device arrays are pulled shard-by-shard via addressable_shards (no full
replication on one host), written as whole-array npy (single-host runtime);
the manifest records the logical structure for restore. Works for any pytree
(GSTrainState, transformer params, optimizer states).
"""
from __future__ import annotations

import json
import os
import re

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = ".".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path)
        key = re.sub(r"[^\w.\-]", "_", key) or "root"
        out[key] = leaf
    return out, treedef


def _leaf_to_host(leaf) -> np.ndarray:
    """Pull one (possibly sharded) leaf to host, shard by shard.

    Assembling from ``addressable_shards`` avoids materializing a second
    fully-replicated device copy the way a whole-leaf ``device_get`` on a
    sharded array can; each shard is copied into its slice of one host
    buffer. Non-jax leaves (numpy, python scalars) pass straight through.
    """
    if isinstance(leaf, jax.Array) and getattr(leaf, "is_fully_addressable", False):
        out = np.empty(leaf.shape, dtype=leaf.dtype)
        for shard in leaf.addressable_shards:
            out[shard.index] = np.asarray(shard.data)
        return out
    return np.asarray(jax.device_get(leaf))


def save_checkpoint(ckpt_dir: str, step: int, tree) -> str:
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(d, exist_ok=True)
    flat, _ = _flatten(tree)
    manifest = {}
    for key, leaf in flat.items():
        arr = _leaf_to_host(leaf)
        np.save(os.path.join(d, key + ".npy"), arr)
        manifest[key] = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump({"step": step, "leaves": manifest}, f, indent=1)
    return d


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for n in os.listdir(ckpt_dir) if (m := re.match(r"step_(\d+)$", n))]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, like):
    """Restore into the structure of `like` (shapes must match)."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like, treedef = _flatten(like)
    leaves = []
    for key in flat_like:
        if key not in manifest["leaves"]:
            raise KeyError(f"checkpoint missing leaf {key}")
        leaves.append(np.load(os.path.join(d, key + ".npy")))
    # _flatten returns dict in tree_flatten order
    return jax.tree_util.tree_unflatten(treedef, leaves)
