"""Trip-count-aware HLO cost model (FLOPs / bytes / collectives).

XLA's built-in HloCostAnalysis (what compiled.cost_analysis() reports) counts
`while` bodies ONCE — a 62-layer scanned model reports ~1/62 of its real
FLOPs. Since every production config here scans its layer stack, the roofline
would be garbage without correcting for trip counts. This module parses the
post-SPMD optimized HLO and computes:

  flops   dot: 2*prod(result)*prod(contracting)   (batch dims already in result)
          conv: 2*prod(result)*prod(kernel)/out_features
          fusion: sum of the fused computation's op flops
          elementwise/reduce/sort: ~1 flop per element (noise next to dots)
  bytes   per op: result + operands (same convention as HloCostAnalysis);
          fusions: boundary buffers only (internal traffic stays in registers)
  colls   per-chip moved bytes with ring formulas (see collective_stats)

while ops multiply their body+condition cost by the trip count recovered from
the condition's comparison constant. Validated against unrolled references in
tests/test_hlo_cost.py.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "u1": 1, "s1": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")

_SHAPE_TOK = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
# result sig is either a tuple "(t1, t2, ...)" (no nested parens in HLO types)
# or a single type token; then the op kind followed by its open-paren.
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|[^(=]*?)\s*([\w\-]+)\(")
_OPERANDS_RE = re.compile(r"%([\w.\-]+)")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^{]*)?\{\s*$")
_CALLS_RE = re.compile(r"(?:calls=|to_apply=|body=|condition=)%?([\w.\-]+)")
_CALLS_LIST_RE = re.compile(r"calls=\{([^}]*)\}")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONST_RE = re.compile(r"[su](?:32|64)\[\]\s+constant\((\d+)\)")

_ZERO_FLOP_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast", "copy",
    "reshape", "transpose", "broadcast", "slice", "dynamic-slice",
    "dynamic-update-slice", "concatenate", "iota", "pad", "reverse",
    "gather", "scatter", "rng-bit-generator", "convert", "after-all",
    "custom-call", "partition-id", "replica-id", "copy-start", "copy-done",
    "send", "recv", "send-done", "recv-done", "infeed", "outfeed", "domain",
    "opt-barrier",
}


def _parse_shape_bytes_elems(sig: str) -> tuple[int, int]:
    total_b = 0
    total_e = 0
    for dt, dims in _SHAPE_TOK.findall(sig):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total_e += n
        total_b += n * DTYPE_BYTES[dt]
    return total_b, total_e


def _shape_dims(sig: str) -> list[list[int]]:
    out = []
    for dt, dims in _SHAPE_TOK.findall(sig):
        if dt not in DTYPE_BYTES:
            continue
        out.append([int(d) for d in dims.split(",") if d])
    return out


@dataclass
class Op:
    name: str
    kind: str
    sig: str          # result type signature text
    line: str
    operands: list[str] = field(default_factory=list)
    is_root: bool = False


@dataclass
class Computation:
    name: str
    ops: dict[str, Op] = field(default_factory=dict)
    order: list[str] = field(default_factory=list)


def parse_hlo(text: str) -> dict[str, Computation]:
    """Computation headers sit at column 0 (possibly spanning multiple lines
    for tuple-typed params); body ops are indented."""
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s:
            continue
        if not line[0].isspace():
            if line.startswith("ENTRY"):
                cur = Computation("ENTRY")
                comps["ENTRY"] = cur
            elif line.startswith("%"):
                name = re.split(r"[\s(]", line[1:], maxsplit=1)[0]
                cur = Computation(name)
                comps[name] = cur
            continue  # header (or HloModule line): never an op
        if s == "}" or cur is None:
            continue
        m = _DEF_RE.match(s)
        if not m:
            continue
        name, sig, kind = m.group(1), m.group(2), m.group(3)
        # operands: %refs inside the call parens (first level is fine for cost)
        after = s[m.end():]
        operands = _OPERANDS_RE.findall(after.split(")")[0]) if ")" in after else _OPERANDS_RE.findall(after)
        op = Op(name=name, kind=kind, sig=sig, line=s, operands=operands,
                is_root=s.startswith("ROOT"))
        cur.ops[name] = op
        cur.order.append(name)
    return comps


def _group_size(line: str) -> int:
    """Participants per replica group. 1 => intra-device no-op collective
    (e.g. a psum on a 1-sized mesh axis): zero interconnect traffic."""
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    if re.search(r"replica_groups=\{\{\d+\}", line):
        return 1  # singleton groups: intra-device no-op, zero ICI traffic
    return 2  # unknown form (incl. {} = all): conservative


def _dot_flops(op: Op, comp: Computation) -> float:
    res_dims = _shape_dims(op.sig)
    res_elems = float(math.prod(res_dims[0])) if res_dims else 0.0
    m = _LHS_C_RE.search(op.line)
    contract = 1.0
    if m and op.operands:
        lhs = comp.ops.get(op.operands[0])
        if lhs is not None:
            lhs_dims = _shape_dims(lhs.sig)
            if lhs_dims:
                for ci in (int(c) for c in m.group(1).split(",") if c):
                    if ci < len(lhs_dims[0]):
                        contract *= lhs_dims[0][ci]
    return 2.0 * res_elems * contract


def _conv_flops(op: Op, comp: Computation) -> float:
    res_dims = _shape_dims(op.sig)
    res_elems = float(math.prod(res_dims[0])) if res_dims else 0.0
    kern_elems = 1.0
    out_feat = 1.0
    if len(op.operands) >= 2:
        k = comp.ops.get(op.operands[1])
        if k is not None:
            kd = _shape_dims(k.sig)
            if kd:
                kern_elems = float(math.prod(kd[0]))
    if res_dims:
        out_feat = float(res_dims[0][-1]) if res_dims[0] else 1.0
    # per output element: kernel_elems / out_features MACs (approx; exact for
    # standard and depthwise convs which are the only ones we emit)
    return 2.0 * res_elems * max(kern_elems / max(out_feat, 1.0), 1.0)


class HloCost:
    def __init__(self, text: str):
        self.comps = parse_hlo(text)
        self._memo: dict[str, dict] = {}

    def fusion_boundary_bytes(self, op: Op, comp: Computation) -> float:
        """HBM bytes at a fusion boundary, slice-aware.

        A fusion parameter consumed ONLY by (dynamic-)slice/gather inside the
        fused computation reads just the sliced region — charging the whole
        buffer would bill a 4096-step scan 4096x its real traffic. Likewise a
        fusion whose ROOT is a dynamic-update-slice writes only the update
        (the buffer aliases in place).
        """
        res_bytes, _ = _parse_shape_bytes_elems(op.sig)
        cm = re.search(r"calls=%?([\w.\-]+)", op.line)
        callee = self.comps.get(cm.group(1)) if cm else None
        if callee is None:
            operand_bytes = 0
            for o in op.operands:
                t = comp.ops.get(o)
                if t is not None:
                    ob, _ = _parse_shape_bytes_elems(t.sig)
                    operand_bytes += ob
            return res_bytes + operand_bytes

        # map parameter index -> param op
        params_by_idx: dict[int, Op] = {}
        for o in callee.ops.values():
            if o.kind == "parameter":
                m = re.search(r"parameter\((\d+)\)", o.line)
                if m:
                    params_by_idx[int(m.group(1))] = o

        total = 0.0
        for idx, operand_name in enumerate(op.operands):
            t = comp.ops.get(operand_name)
            full, _ = _parse_shape_bytes_elems(t.sig) if t is not None else (0, 0)
            pop = params_by_idx.get(idx)
            if pop is None or full == 0:
                total += full
                continue
            consumers = [o for o in callee.ops.values() if pop.name in o.operands]
            if consumers and all(
                (c.kind in ("dynamic-slice", "slice", "gather"))
                or (c.kind == "dynamic-update-slice" and c.operands and c.operands[0] == pop.name)
                for c in consumers
            ):
                sliced = 0.0
                for c in consumers:
                    if c.kind == "dynamic-update-slice":
                        if len(c.operands) >= 2 and c.operands[1] in callee.ops:
                            ub, _ = _parse_shape_bytes_elems(callee.ops[c.operands[1]].sig)
                            sliced += ub
                    else:
                        rb, _ = _parse_shape_bytes_elems(c.sig)
                        sliced += rb
                total += min(sliced, full)
            else:
                total += full

        # write side: DUS root writes only the update region
        root = next((o for o in callee.ops.values() if o.is_root), None)
        if root is not None and root.kind == "dynamic-update-slice" and len(root.operands) >= 2:
            upd = callee.ops.get(root.operands[1])
            if upd is not None:
                ub, _ = _parse_shape_bytes_elems(upd.sig)
                return total + ub
        return total + res_bytes

    def _trip_count(self, cond_name: str, depth: int = 0) -> float:
        """Trip count = max integer constant in the condition (transitively
        through called fusions — the compare often lives in a fused callee)."""
        cond = self.comps.get(cond_name)
        if not cond or depth > 3:
            return 1.0
        consts = [0]
        for op in cond.ops.values():
            consts += [int(c) for c in _CONST_RE.findall(op.line)]
            for callee in _CALLS_RE.findall(op.line):
                consts.append(self._trip_count(callee, depth + 1))
        best = max(consts)
        return float(best) if best > 0 else 1.0

    def comp_cost(self, name: str) -> dict:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        zero = {"flops": 0.0, "bytes": 0.0, "coll": {c: {"count": 0.0, "moved_bytes": 0.0} for c in COLLECTIVES}}
        if comp is None:
            return zero
        total = {"flops": 0.0, "bytes": 0.0, "coll": {c: {"count": 0.0, "moved_bytes": 0.0} for c in COLLECTIVES}}
        self._memo[name] = total  # memo first (recursive graphs are DAGs)

        def add(child: dict, w: float = 1.0):
            total["flops"] += w * child["flops"]
            total["bytes"] += w * child["bytes"]
            for c in COLLECTIVES:
                total["coll"][c]["count"] += w * child["coll"][c]["count"]
                total["coll"][c]["moved_bytes"] += w * child["coll"][c]["moved_bytes"]

        for opname in comp.order:
            op = comp.ops[opname]
            kind = op.kind
            res_bytes, res_elems = _parse_shape_bytes_elems(op.sig)
            operand_bytes = 0
            for o in op.operands:
                target = comp.ops.get(o)
                if target is not None:
                    ob, _ = _parse_shape_bytes_elems(target.sig)
                    operand_bytes += ob

            # ---- aliasing-aware byte special cases: these ops touch only the
            # slice/update region, not the (often huge) aliased buffer operand.
            if kind in ("dynamic-slice", "slice", "gather"):
                total["bytes"] += 2.0 * res_bytes
                continue
            if kind == "dynamic-update-slice":
                upd_bytes = 0
                if len(op.operands) >= 2:
                    t = comp.ops.get(op.operands[1])
                    if t is not None:
                        upd_bytes, _ = _parse_shape_bytes_elems(t.sig)
                total["bytes"] += 2.0 * upd_bytes
                continue
            if kind == "scatter":
                upd_bytes = 0
                if len(op.operands) >= 3:
                    t = comp.ops.get(op.operands[2])
                    if t is not None:
                        upd_bytes, _ = _parse_shape_bytes_elems(t.sig)
                total["flops"] += upd_bytes / 4.0  # add-combiner
                total["bytes"] += 3.0 * upd_bytes
                continue

            if kind == "while":
                m = _CALLS_RE.findall(op.line)
                body = next((x for x in m if "body" in op.line.split(x)[0][-20:]), None)
                # robust: parse body=/condition= separately
                bm = re.search(r"body=%?([\w.\-]+)", op.line)
                cm = re.search(r"condition=%?([\w.\-]+)", op.line)
                trips = self._trip_count(cm.group(1)) if cm else 1.0
                if bm:
                    add(self.comp_cost(bm.group(1)), trips)
                if cm:
                    add(self.comp_cost(cm.group(1)), trips)
                # loop-carried state is aliased in place: no per-op bytes
                continue
            if kind == "fusion":
                cm = re.search(r"calls=%?([\w.\-]+)", op.line)
                if cm:
                    inner = self.comp_cost(cm.group(1))
                    total["flops"] += inner["flops"]
                    for c in COLLECTIVES:
                        total["coll"][c]["count"] += inner["coll"][c]["count"]
                        total["coll"][c]["moved_bytes"] += inner["coll"][c]["moved_bytes"]
                total["bytes"] += self.fusion_boundary_bytes(op, comp)  # slice-aware
                continue
            if kind in ("call", "conditional", "map"):
                for callee in _CALLS_RE.findall(op.line):
                    add(self.comp_cost(callee))
                for callee_list in _CALLS_LIST_RE.findall(op.line):
                    for callee in _OPERANDS_RE.findall(callee_list):
                        add(self.comp_cost(callee))
                total["bytes"] += res_bytes + operand_bytes
                continue

            if kind.startswith(tuple(COLLECTIVES)):
                base = kind.replace("-start", "").replace("-done", "")
                if base in COLLECTIVES and not kind.endswith("-done"):
                    n = max(_group_size(op.line), 1)
                    if base == "all-gather":
                        moved = res_bytes * (n - 1) / n
                    elif base == "all-reduce":
                        moved = 2 * res_bytes * (n - 1) / n
                    elif base == "reduce-scatter":
                        moved = res_bytes * (n - 1)
                    elif base == "all-to-all":
                        moved = res_bytes * (n - 1) / n
                    else:
                        moved = res_bytes
                    total["coll"][base]["count"] += 1
                    total["coll"][base]["moved_bytes"] += moved
                total["bytes"] += res_bytes + operand_bytes
                continue

            # flops
            if kind == "dot":
                total["flops"] += _dot_flops(op, comp)
            elif kind == "convolution":
                total["flops"] += _conv_flops(op, comp)
            elif kind == "sort":
                total["flops"] += res_elems * max(math.log2(max(res_elems, 2)), 1.0)
                # include the comparator body once per comparison (approx)
            elif kind in ("reduce", "reduce-window"):
                total["flops"] += operand_bytes / 4.0  # ~1 flop per input elem
            elif kind in _ZERO_FLOP_OPS:
                pass
            else:
                total["flops"] += res_elems  # elementwise & transcendental
            if kind not in ("parameter", "constant", "tuple", "get-tuple-element"):
                total["bytes"] += res_bytes + operand_bytes
        return total

    def entry_cost(self) -> dict:
        return self.comp_cost("ENTRY")


def analyze(text: str) -> dict:
    hc = HloCost(text)
    cost = hc.entry_cost()
    cost["coll_total_moved_bytes"] = sum(cost["coll"][c]["moved_bytes"] for c in COLLECTIVES)
    cost["top_collectives"] = top_collectives(hc)
    cost["top_bytes"] = top_bytes_ops(hc)
    return cost


def top_bytes_ops(hc: "HloCost", k: int = 12) -> list[dict]:
    """The k largest HBM-traffic sites by trip-weighted (result+operand)
    bytes — evidence for memory-bound §Perf iterations."""
    mults = _comp_multipliers(hc)
    rows = []
    for name, comp in hc.comps.items():
        w = mults.get(name, 1.0)
        for op in comp.ops.values():
            if op.kind in ("parameter", "constant", "tuple", "get-tuple-element", "while"):
                continue
            res_bytes, _ = _parse_shape_bytes_elems(op.sig)
            operand_bytes = 0
            for o in op.operands:
                t = comp.ops.get(o)
                if t is not None:
                    ob, _ = _parse_shape_bytes_elems(t.sig)
                    operand_bytes += ob
            if op.kind in ("dynamic-slice", "slice", "gather"):
                b = 2.0 * res_bytes
            elif op.kind == "dynamic-update-slice":
                b = 2.0 * res_bytes  # approx for the report
            elif op.kind == "fusion":
                b = hc.fusion_boundary_bytes(op, comp)
            else:
                b = res_bytes + operand_bytes
            rows.append({"kind": op.kind, "comp": name, "trips": w, "bytes": w * b,
                         "sig": op.sig[:70]})
    rows.sort(key=lambda r: -r["bytes"])
    return rows[:k]


def sum_sig_suffix_bytes(hc: "HloCost", suffix: tuple[int, ...]) -> float:
    """Trip-weighted bytes of all ops whose result shape ends with `suffix`.

    Used by the GS dry-run to quantify the (K, tile_pixels) alpha-matrix
    class of intermediates: the ref-backend lowering spills them to HBM, the
    Pallas tile kernel keeps them in VMEM — subtracting them models the
    kernel's memory term on real hardware (method documented in
    EXPERIMENTS.md §Paper-repro)."""
    mults = _comp_multipliers(hc)
    total = 0.0
    for name, comp in hc.comps.items():
        w = mults.get(name, 1.0)
        for op in comp.ops.values():
            if op.kind in ("parameter", "constant", "tuple", "get-tuple-element", "while"):
                continue
            for dims in _shape_dims(op.sig):
                if len(dims) >= len(suffix) and tuple(dims[-len(suffix):]) == suffix:
                    total += w * math.prod(dims) * 4.0  # f32 class
    return total


def _comp_multipliers(hc: "HloCost") -> dict[str, float]:
    mults: dict[str, float] = {"ENTRY": 1.0}
    changed = True
    guard = 0
    while changed and guard < 20:
        changed = False
        guard += 1
        for name, comp in hc.comps.items():
            w = mults.get(name)
            if w is None:
                continue
            for op in comp.ops.values():
                if op.kind == "while":
                    bm = re.search(r"body=%?([\w.\-]+)", op.line)
                    cm = re.search(r"condition=%?([\w.\-]+)", op.line)
                    trips = hc._trip_count(cm.group(1)) if cm else 1.0
                    for target in filter(None, [bm and bm.group(1), cm and cm.group(1)]):
                        cand = w * trips
                        if mults.get(target, 0.0) < cand:
                            mults[target] = cand
                            changed = True
                else:
                    for callee in _CALLS_RE.findall(op.line):
                        if mults.get(callee, 0.0) < w:
                            mults[callee] = w
                            changed = True
    return mults


def top_collectives(hc: "HloCost", k: int = 12) -> list[dict]:
    """The k largest collectives by trip-weighted moved bytes (evidence for
    the §Perf hypothesis loop: *which* tensor is being moved, from *where*)."""
    mults = _comp_multipliers(hc)
    rows = []
    for name, comp in hc.comps.items():
        w = mults.get(name, 1.0)
        for op in comp.ops.values():
            base = op.kind.replace("-start", "").replace("-done", "")
            if base in COLLECTIVES and not op.kind.endswith("-done"):
                nbytes, _ = _parse_shape_bytes_elems(op.sig)
                n = max(_group_size(op.line), 1)
                factor = {"all-gather": (n - 1) / n, "all-reduce": 2 * (n - 1) / n,
                          "reduce-scatter": (n - 1), "all-to-all": (n - 1) / n,
                          "collective-permute": 1.0}[base]
                meta = re.search(r'op_name="([^"]+)"', op.line)
                rows.append({
                    "kind": base, "comp": name, "trips": w,
                    "moved_bytes": w * nbytes * factor, "result_sig": op.sig[:90],
                    "op_name": (meta.group(1)[-110:] if meta else ""),
                })
    rows.sort(key=lambda r: -r["moved_bytes"])
    return rows[:k]
