"""Production mesh definitions (TPU v5e; CPU host devices in the dry-run).

A FUNCTION, not a module constant — importing this module must never touch
jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_gs_mesh(n_data: int, n_model: int):
    """Mesh for distributed 3D-GS runs/benchmarks (paper scaling: 1/2/4 workers)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


# TPU v5e hardware constants used by the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link
