"""Network gateway driver: serve trained Gaussian models over TCP.

Registers one or more streams — a static scene (checkpoint or synthetic
isosurface) and, optionally, a `TemporalCheckpointStore` insitu sequence as a
scrubbable timeline — on one shared render-server pool, then listens for
frontend-protocol clients (``repro.frontend.FrontendClient``).

  # serve a synthetic scene + a 3-step synthetic timeline, verify with an
  # in-process client, print the gateway report, exit
  PYTHONPATH=src python -m repro.launch.frontend --smoke

  # serve a trained checkpoint and a recorded insitu run until Ctrl-C
  PYTHONPATH=src python -m repro.launch.frontend --port 7070 \
      --ckpt experiments/ckpts/run0 --insitu-store experiments/insitu/run0/seq

  # one-liner client
  python -c "from repro.frontend import FrontendClient; from repro.serve_gs \
      import front_camera; ..."
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

from repro.configs.gs_datasets import DATASETS
from repro.core.config import GSConfig
from repro.core.projection import look_at_camera
from repro.frontend import FrontendClient, Gateway, GatewayThread, SessionManager
from repro.insitu import TemporalCheckpointStore, timeline_stream
from repro.launch.serve_gs import init_params_from_volume, load_params_from_ckpt
from repro.obs import Obs, parse_slo_spec, trace_meta, validate_trace_jsonl, write_trace


def synthetic_timeline(params, n_steps: int, *, drift: float = 0.08) -> dict:
    """A tiny in-memory timeline: the static scene drifting along +x. Stands
    in for a recorded insitu sequence when none is given (smoke/self-test)."""
    means = np.asarray(params.means)
    return {
        t: params._replace(means=means + np.float32(drift * t) * np.float32([1, 0, 0]))
        for t in range(n_steps)
    }


def self_test(host: str, port: int, *, scrub_stream: str | None) -> dict:
    """Connect like a real remote viewer; one render per stream + a scrub,
    plus one foveated render (gaze hint) exercising the per-tile LOD path."""
    with FrontendClient(host, port) as cl:
        h, w = cl.hello["img_h"], cl.hello["img_w"]
        cam_by_stream = {}
        rendered = {}
        for sid, info in cl.streams.items():
            # a front camera needs scene geometry the client doesn't have;
            # a fixed orbit-ish pose works for any normalized scene
            cam = look_at_camera([0, 0, -3.0], [0, 0, 0], [0, 1, 0], w * 1.2, w * 1.2, w / 2, h / 2)
            cam_by_stream[sid] = cam
            frame = cl.render(sid, cam, timestep=info["timesteps"][0])
            rendered[sid] = list(frame.shape)
            assert frame.shape == (h, w, 3) and frame.dtype == np.uint8, frame.shape
        # foveated render: gaze at the top edge so the lower rows coarsen
        sid0, info0 = next(iter(cl.streams.items()))
        fov = cl.render(sid0, cam_by_stream[sid0], timestep=info0["timesteps"][0],
                        gaze=(0.5, 0.0))
        assert fov.shape == (h, w, 3), fov.shape
        scrubbed = 0
        if scrub_stream is not None:
            ts = cl.streams[scrub_stream]["timesteps"]
            frames = cl.scrub(scrub_stream, cam_by_stream[scrub_stream], ts)
            scrubbed = len(frames)
            assert sorted(frames) == sorted(ts)
        stats = cl.stats()
    return {"rendered": rendered, "scrubbed": scrubbed, "stats": stats}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small config + in-process client self-test, then exit")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=7070, help="0 = ephemeral")
    # static stream source
    ap.add_argument("--ckpt", default=None, help="checkpoint dir from repro.launch.train")
    ap.add_argument("--dataset", choices=list(DATASETS), default="kingsnake")
    ap.add_argument("--volume-res", type=int, default=48)
    ap.add_argument("--max-points", type=int, default=4000)
    # timeline stream source
    ap.add_argument("--insitu-store", default=None,
                    help="TemporalCheckpointStore dir -> scrubbable 'timeline' stream")
    ap.add_argument("--synthetic-timeline", type=int, default=0,
                    help="N>0: register an N-step synthetic drift timeline")
    # serving engine
    ap.add_argument("--res", type=int, default=64)
    ap.add_argument("--levels", type=int, default=3)
    ap.add_argument("--keep-ratio", type=float, default=0.5)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--cache", type=int, default=512,
                    help="cache capacity in frame-equivalents (byte budget)")
    ap.add_argument("--frame-cache", action="store_true",
                    help="whole-frame cache baseline (no tile granularity)")
    ap.add_argument("--pipeline-depth", type=int, default=2)
    # gateway
    ap.add_argument("--queue-limit", type=int, default=8,
                    help="per-session bounded queue (overflow sheds oldest)")
    ap.add_argument("--wave-per-session", type=int, default=4)
    ap.add_argument("--no-delta", action="store_true",
                    help="disable zlib delta frame encoding (always raw RGB8)")
    ap.add_argument("--serve-seconds", type=float, default=0.0,
                    help="serve for N seconds then exit (0 = until Ctrl-C)")
    # observability
    ap.add_argument("--trace-out", default=None, metavar="PATH.jsonl",
                    help="record request span traces; on exit write JSONL "
                         "here plus a Perfetto-viewable .chrome.json next to it")
    ap.add_argument("--trace-capacity", type=int, default=65536,
                    help="span ring size (oldest spans drop beyond this)")
    ap.add_argument("--slo", default=None, metavar="p99_ms=N[,window_s=S,budget=B]",
                    help="live SLO tracking on served latency; state "
                         "(ok/warn/breach + budget burn) shows up in the "
                         "stats and metrics wire messages")
    args = ap.parse_args(argv)
    slo_kw = parse_slo_spec(args.slo) if args.slo else None

    if args.smoke:
        args.res = min(args.res, 32)
        args.volume_res = min(args.volume_res, 32)
        args.max_points = min(args.max_points, 800)
        args.levels = min(args.levels, 2)
        args.port = 0  # never collide in CI
        if args.insitu_store is None and args.synthetic_timeline == 0:
            args.synthetic_timeline = 3

    if args.ckpt:
        params = load_params_from_ckpt(args.ckpt)
    else:
        params = init_params_from_volume(
            args.dataset, volume_res=args.volume_res, max_points=args.max_points
        )
    cfg = GSConfig(img_h=args.res, img_w=args.res, k_per_tile=128 if args.smoke else 256)

    obs = Obs(trace=args.trace_out is not None, trace_capacity=args.trace_capacity)
    manager = SessionManager(
        cfg,
        obs=obs,
        n_levels=args.levels,
        keep_ratio=args.keep_ratio,
        max_batch=args.max_batch,
        cache_capacity=args.cache,
        tile_cache=not args.frame_cache,
        store_frames=False,
        pipeline_depth=args.pipeline_depth,
    )
    manager.register_static("static", params)
    scrub_stream = None
    if args.insitu_store:
        with TemporalCheckpointStore(args.insitu_store) as store:
            timeline_stream(manager, "timeline", store)
        scrub_stream = "timeline"
    elif args.synthetic_timeline > 0:
        manager.register_timeline("timeline", synthetic_timeline(params, args.synthetic_timeline))
        scrub_stream = "timeline"
    warm_s = manager.warmup()

    gateway = Gateway(
        manager,
        host=args.host,
        port=args.port,
        queue_limit=args.queue_limit,
        wave_per_session=args.wave_per_session,
        delta_encoding=not args.no_delta,
        slo=slo_kw,
    )
    gt = GatewayThread(gateway).start()
    try:
        print(
            f"frontend listening on {args.host}:{gateway.port} "
            f"streams={list(manager.streams)} warmup={warm_s:.1f}s "
            f"(client: repro.frontend.FrontendClient('{args.host}', {gateway.port}))",
            flush=True,
        )
        if args.smoke:
            out = self_test(args.host, gateway.port, scrub_stream=scrub_stream)
            print(json.dumps(out, indent=1))
            gw = out["stats"]["gateway"]
            assert gw["protocol_errors"] == 0 and gw["shed"] == 0, gw
            assert gw["frames_sent"] >= len(manager.streams), gw
            # per-tile LOD accounting reached the report (foveated or uniform,
            # every request assigns each tile row a level)
            lod = out["stats"]["server"]["lod"]
            assert sum(lod["rows_per_level"]) > 0, lod
            print(f"frontend smoke ok: {gw['frames_sent']} frames over TCP, "
                  f"{gw['bytes_out']} bytes, 0 shed")
        elif args.serve_seconds > 0:
            time.sleep(args.serve_seconds)
        else:
            while True:
                time.sleep(3600)
    except KeyboardInterrupt:
        pass
    finally:
        gt.stop()
        if args.trace_out:
            spans = obs.trace.drain()
            # the knobs ride in the export header: a later launch.tune run
            # replays against the exact configuration that produced the trace
            meta = trace_meta(obs.trace, knobs={
                "coalesce_ms": gateway.coalesce_ms,
                "max_batch": args.max_batch,
                "pipeline_depth": args.pipeline_depth,
                "queue_limit": args.queue_limit,
                "wave_per_session": args.wave_per_session,
            })
            jsonl_path, chrome_path = write_trace(args.trace_out, spans, meta=meta)
            with open(jsonl_path) as f:
                n = validate_trace_jsonl(f.read())
            print(f"trace: {n} spans -> {jsonl_path} + {chrome_path}")
            if n.dropped:
                print(f"WARNING: span ring overflowed — {n.dropped} spans "
                      f"LOST (capacity {obs.trace.capacity}); raise "
                      f"--trace-capacity before trusting replay fits",
                      file=sys.stderr)


if __name__ == "__main__":
    main()
