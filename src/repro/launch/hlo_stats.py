"""Collective-byte extraction from compiled HLO text (for §Roofline).

cost_analysis() has FLOPs and HBM bytes but not collective traffic, so we
parse the post-SPMD HLO. Two subtleties handled here:

1. Collectives inside `while` bodies (layer scans) execute once per trip —
   each computation gets a trip-count multiplier recovered from the while
   condition's comparison constant (nested whiles multiply).
2. Per-chip link traffic uses the standard ring formulas:
     all-gather         result_bytes * (n-1)/n      (result is the gathered)
     all-reduce         2 * bytes * (n-1)/n
     reduce-scatter     result_bytes * (n-1)        (result is the scattered)
     all-to-all         bytes * (n-1)/n
     collective-permute bytes
"""
from __future__ import annotations

import re

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->.*\{\s*$")
_WHILE_RE = re.compile(r"while\(.*?\).*condition=%?([\w.\-]+).*body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"[su]32\[\]\s+constant\((\d+)\)")
_COLL_LINE_RE = re.compile(
    r"=\s*(.*?)\s+(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)(-start|-done)?\("
)


def _shape_bytes(segment: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(segment):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return 2


def _split_computations(text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in text.splitlines():
        m = _COMP_HDR_RE.match(line.strip())
        if m and (line.startswith("ENTRY") or line.startswith("%") or line.startswith("  ") is False):
            cur = m.group(1)
            if line.strip().startswith("ENTRY"):
                cur = "ENTRY"
            comps[cur] = []
        elif cur is not None:
            comps[cur].append(line)
    return comps


def _trip_counts(comps: dict[str, list[str]]) -> dict[str, float]:
    """Multiplier per computation (ENTRY=1; while bodies *= trip count)."""
    # trip count of a while = the max s32 constant in its condition computation
    edges: list[tuple[str, str, float]] = []  # (parent, body, trips)
    for parent, lines in comps.items():
        for line in lines:
            m = _WHILE_RE.search(line)
            if m:
                cond, body = m.group(1), m.group(2)
                consts = [int(c) for cl in comps.get(cond, []) for c in _CONST_RE.findall(cl)]
                trips = float(max(consts)) if consts else 1.0
                edges.append((parent, body, trips))
                edges.append((parent, cond, trips))
    mult = {name: (1.0 if name == "ENTRY" else 0.0) for name in comps}
    # also seed computations referenced via calls/fusions from ENTRY at 1.0:
    # conservatively, any computation never reached keeps multiplier from edges;
    # non-while computations (fusions) inherit their caller implicitly because
    # XLA inlines collectives only at computation level via calls — handle calls:
    call_re = re.compile(r"(?:calls=|to_apply=)%?([\w.\-]+)")
    for parent, lines in comps.items():
        for line in lines:
            if "while(" in line:
                continue
            for callee in call_re.findall(line):
                edges.append((parent, callee, 1.0))
    for _ in range(12):  # fixpoint over nesting depth
        changed = False
        for parent, child, trips in edges:
            if parent in mult and child in mult:
                cand = mult[parent] * trips
                if cand > mult[child]:
                    mult[child] = cand
                    changed = True
        if not changed:
            break
    return mult


def collective_stats(hlo_text: str) -> dict:
    comps = _split_computations(hlo_text)
    mult = _trip_counts(comps)
    stats = {c: {"count": 0.0, "result_bytes": 0.0, "moved_bytes": 0.0} for c in COLLECTIVES}
    for name, lines in comps.items():
        w = mult.get(name, 1.0)
        if w == 0.0:
            w = 1.0  # unreached computations: count once, conservative
        for line in lines:
            m = _COLL_LINE_RE.search(line)
            if not m or m.group(3) == "-done":
                continue
            op = m.group(2)
            nbytes = _shape_bytes(m.group(1))
            n = max(_group_size(line), 2)
            if op == "all-gather":
                moved = nbytes * (n - 1) / n
            elif op == "all-reduce":
                moved = 2 * nbytes * (n - 1) / n
            elif op == "reduce-scatter":
                moved = nbytes * (n - 1)
            elif op == "all-to-all":
                moved = nbytes * (n - 1) / n
            else:
                moved = nbytes
            s = stats[op]
            s["count"] += w
            s["result_bytes"] += w * nbytes
            s["moved_bytes"] += w * moved
    stats["total_moved_bytes"] = sum(s["moved_bytes"] for s in stats.values() if isinstance(s, dict))
    return stats
