"""CLI: run the ``repro.analysis`` passes with a baseline ratchet.

::

    PYTHONPATH=src python -m repro.launch.analyze \
        --report ANALYSIS_report.json

Runs the retrace lint, the vocabulary checker, the static lockset pass, and
the broad-except lint over ``src/`` (vocabulary additionally scans
``benchmarks/``, ``tests/``, and the docs), applies ``# analysis:
allow(...)`` pragmas, and ratchets the remaining findings against
``ANALYSIS_baseline.json``: pre-existing findings pass, new ones fail with
exit code 1. ``--update-baseline`` rewrites the baseline to the current
findings (the "accept this debt, block growth" workflow). Pure AST — never
imports jax; a full-repo run is well under a second.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from repro.analysis import common
from repro.analysis import hygiene, locks, names, retrace

PASSES = {
    "retrace": retrace.run,
    "locks": locks.run,
    "hygiene": hygiene.run,
    # "names" runs separately: it takes extra code roots + doc files
}

DOC_FILES = ("README.md", os.path.join("benchmarks", "bench_schema.py"))


def run_analysis(
    repo_root: str,
    *,
    src_root: str = "src",
    extra_code_roots: tuple[str, ...] = ("benchmarks", "tests"),
    doc_files: tuple[str, ...] = DOC_FILES,
    rules: set[str] | None = None,
) -> list[common.Finding]:
    """Run every pass; returns findings (pragma-waived ones included, with
    ``allowed_by`` set)."""
    src_files = common.load_tree(
        common.iter_python_files(os.path.join(repo_root, src_root)), repo_root
    )
    findings: list[common.Finding] = []
    for fn in PASSES.values():
        findings.extend(fn(src_files))

    # the vocabulary pass sees benchmarks + tests too (uses/reads live
    # there), and the docs for drift
    vocab_files = list(src_files)
    for root in extra_code_roots:
        p = os.path.join(repo_root, root)
        if os.path.isdir(p):
            vocab_files.extend(
                common.load_tree(common.iter_python_files(p), repo_root)
            )
    docs = {}
    for rel in doc_files:
        p = os.path.join(repo_root, rel)
        if os.path.exists(p):
            with open(p, encoding="utf-8") as f:
                docs[rel.replace(os.sep, "/")] = f.read()
    findings.extend(names.run(vocab_files, docs))

    if rules:  # selectors are exact rules or prefixes ("retrace." etc.)
        findings = [f for f in findings
                    if any(f.rule == r or f.rule.startswith(r) for r in rules)]
    findings.sort(key=lambda f: (f.path, f.line, f.rule, f.detail))
    return findings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", default=".", help="repo root (default: cwd)")
    ap.add_argument("--src", default="src", help="source tree to lint")
    ap.add_argument("--baseline", default="ANALYSIS_baseline.json")
    ap.add_argument("--report", default=None, help="write the JSON report here")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline to the current findings")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule filter (e.g. 'retrace.,names.unread')")
    ap.add_argument("-q", "--quiet", action="store_true")
    args = ap.parse_args(argv)

    t0 = time.perf_counter()
    rules = set(r.strip() for r in args.rules.split(",") if r.strip()) if args.rules else None
    try:
        findings = run_analysis(args.root, src_root=args.src, rules=rules)
    except ValueError as e:
        print(f"analyze: {e}", file=sys.stderr)
        return 2

    active = [f for f in findings if f.allowed_by is None]
    allowed = [f for f in findings if f.allowed_by is not None]
    baseline_path = os.path.join(args.root, args.baseline)
    baseline = common.load_baseline(baseline_path)
    new, fixed, counts = common.diff_against_baseline(findings, baseline)
    elapsed = time.perf_counter() - t0

    if args.update_baseline:
        common.save_baseline(baseline_path, findings)
        print(f"analyze: baseline rewritten with {len(active)} finding(s) "
              f"-> {baseline_path}")
        new, fixed = [], []

    by_rule: dict[str, int] = {}
    for f in active:
        by_rule[f.rule] = by_rule.get(f.rule, 0) + 1
    report = {
        "schema": 1,
        "elapsed_s": round(elapsed, 3),
        "findings": len(active),
        "allowed": len(allowed),
        "by_rule": dict(sorted(by_rule.items())),
        "baseline": {
            "path": args.baseline,
            "entries": sum(baseline.values()),
            "new": len(new),
            "fixed": len(fixed),
            "fixed_keys": fixed,
        },
        "new_findings": [f.to_dict() for f in new],
        "all_findings": [f.to_dict() for f in active],
        "allowed_findings": [f.to_dict() for f in allowed],
    }
    if args.report:
        with open(args.report, "w") as f:
            json.dump(report, f, indent=1)
            f.write("\n")

    if not args.quiet:
        print(f"analyze: {len(active)} finding(s) "
              f"({len(allowed)} pragma-allowed) in {elapsed*1e3:.0f} ms; "
              f"baseline covers {sum(baseline.values())}, new: {len(new)}, "
              f"fixed: {len(fixed)}")
        for f in new:
            print(f"  NEW {f.rule} {f.path}:{f.line} [{f.detail}] {f.message}")
        if fixed:
            for k in fixed:
                print(f"  fixed (re-tighten baseline): {k}")
    if new:
        print(
            f"analyze: {len(new)} new finding(s) over the baseline — fix "
            "them, pragma them with a reason, or (for accepted debt) rerun "
            "with --update-baseline",
            file=sys.stderr,
        )
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
