import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# (dev override for fast iteration; production dry-run keeps 512)
if os.environ.get("DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={os.environ['DRYRUN_DEVICES']}"

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
mesh, prove memory/sharding coherence, and extract roofline terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-27b --shape long_500k --multi-pod
"""
import argparse
import json

import jax
import numpy as np

from repro.configs import get_arch
from repro.obs.clock import now, since
from repro.configs.common import SHAPES, lm_batch_specs, decode_specs, params_specs
from repro.launch import hlo_cost
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh
from repro.models import api
from repro.models.partitioning import batch_pspecs, cache_pspecs, param_pspecs, to_named
from repro.models.sharding import use_mesh_rules


def run_dryrun(arch: str, shape_name: str, *, multi_pod: bool = False, fsdp: bool = True,
               out_dir: str | None = None, print_hlo_stats: bool = True) -> dict:
    mod = get_arch(arch)
    cfg = mod.config()
    shape = SHAPES[shape_name]
    mesh_tag = "pod2" if multi_pod else "pod1"
    result = {
        "arch": cfg.name, "shape": shape_name, "mesh": mesh_tag,
        "kind": shape.kind, "devices": 512 if multi_pod else 256,
    }

    skip = getattr(mod, "SKIP_SHAPES", {}).get(shape_name)
    if skip:
        result["skipped"] = skip
        _write(result, out_dir)
        print(f"SKIP {arch} {shape_name}: {skip}")
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    # monotonic: compile timing must not jump when NTP steps the wall clock
    t0 = now()

    rules = None
    if getattr(cfg, "pure_dp", False):
        rules = {
            "batch": ("pod", "data", "model"), "heads": None, "kv_heads": None,
            "ff": None, "experts": None, "vocab": None, "moe_d": None,
        }
    # FSDP re-gathers weights every step — amortized over thousands of tokens
    # in training/prefill, but a pure per-token tax at decode (measured 6.3 GB
    # of weight all-gathers per token on gemma3 long_500k). Decode keeps
    # weights model-sharded only; they fit (<= params/16 per chip).
    if shape.kind == "decode":
        fsdp = False
    with use_mesh_rules(mesh, rules):
        params = params_specs(cfg)
        pp = to_named(param_pspecs(cfg, params, mesh, fsdp=fsdp), mesh)
        if shape.kind == "train":
            opt = jax.eval_shape(api.adamw_init, params)
            op = to_named(param_pspecs(cfg, opt, mesh, fsdp=fsdp), mesh)
            batch = lm_batch_specs(cfg, shape)
            bp = to_named(batch_pspecs(cfg, batch, mesh), mesh)
            step = api.make_train_step(cfg)
            lowered = jax.jit(step, in_shardings=(pp, op, bp)).lower(params, opt, batch)
        elif shape.kind == "prefill":
            batch = lm_batch_specs(cfg, shape)
            bp = to_named(batch_pspecs(cfg, batch, mesh), mesh)
            step = api.make_prefill_step(cfg)
            lowered = jax.jit(step, in_shardings=(pp, bp)).lower(params, batch)
        else:  # decode
            specs = decode_specs(cfg, shape)
            cp = to_named(cache_pspecs(cfg, specs["cache"], mesh), mesh)
            tp = to_named(batch_pspecs(cfg, {"t": specs["tokens"]}, mesh)["t"], mesh)
            step = api.make_serve_step(cfg)
            lowered = jax.jit(step, in_shardings=(pp, cp, tp, None)).lower(
                params, specs["cache"], specs["tokens"], specs["pos"]
            )
        compiled = lowered.compile()
    t_compile = since(t0)

    mem = compiled.memory_analysis()
    print(mem)
    xla_cost = compiled.cost_analysis()
    print({k: xla_cost.get(k) for k in ("flops", "bytes accessed")})
    hlo = compiled.as_text()
    cost = hlo_cost.analyze(hlo)

    # ---- roofline terms (per chip, seconds)
    flops = cost["flops"]
    bytes_hbm = cost["bytes"]
    bytes_coll = cost["coll_total_moved_bytes"]
    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = bytes_hbm / HBM_BW
    collective_s = bytes_coll / ICI_BW

    # analytic model flops (global), then per chip
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind == "train" else (shape.seq_len if shape.kind == "prefill" else 1))
    if shape.kind == "train":
        model_flops = 6.0 * n_active * tokens
    else:
        model_flops = 2.0 * n_active * tokens
    model_flops_chip = model_flops / n_chips

    dominant = max(("compute", compute_s), ("memory", memory_s), ("collective", collective_s), key=lambda kv: kv[1])[0]
    result.update(
        {
            "compile_s": round(t_compile, 1),
            "memory_analysis": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "peak_estimate_bytes": mem.argument_size_in_bytes + mem.temp_size_in_bytes,
                "fits_16gb": (mem.argument_size_in_bytes + mem.temp_size_in_bytes) < 16e9,
            },
            "xla_cost_raw": {k: xla_cost.get(k) for k in ("flops", "bytes accessed", "transcendentals")},
            "hlo_flops": flops,
            "hlo_bytes": bytes_hbm,
            "collective_moved_bytes": bytes_coll,
            "collectives": cost["coll"],
            "top_collectives": cost.get("top_collectives", []),
            "top_bytes": cost.get("top_bytes", []),
            "roofline": {
                "compute_s": compute_s,
                "memory_s": memory_s,
                "collective_s": collective_s,
                "dominant": dominant,
            },
            "model_flops_per_chip": model_flops_chip,
            "useful_flop_ratio": model_flops_chip / flops if flops else None,
            "params_total": cfg.param_count(),
            "params_active": n_active,
        }
    )
    _write(result, out_dir)
    print(
        f"{arch} {shape_name} {mesh_tag}: compile {t_compile:.0f}s  "
        f"compute {compute_s*1e3:.2f}ms  memory {memory_s*1e3:.2f}ms  "
        f"collective {collective_s*1e3:.2f}ms  dominant={dominant}  "
        f"useful={result['useful_flop_ratio'] and round(result['useful_flop_ratio'],3)}"
    )
    return result


def _write(result, out_dir):
    if not out_dir:
        return
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{result['arch']}_{result['shape']}_{result['mesh']}.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    run_dryrun(args.arch, args.shape, multi_pod=args.multi_pod, fsdp=not args.no_fsdp, out_dir=args.out)


if __name__ == "__main__":
    main()
