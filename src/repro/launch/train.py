"""End-to-end distributed 3D-GS training driver (the paper pipeline).

  volume -> isosurface points -> Gaussian init -> GT orbit renders ->
  distributed Grendel-style optimization (+ densification rounds) ->
  metrics (PSNR / SSIM / LPIPS-proxy) + checkpoints.

Usage (CPU demo scale):
  PYTHONPATH=src python -m repro.launch.train --dataset kingsnake \
      --volume-res 48 --max-points 4000 --res 64 --steps 200 --views 24
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.core import gaussians as G
from repro.core.config import GSConfig
from repro.core.densify import densify_and_rebalance, reset_opacity
from repro.core.losses import lpips_proxy, psnr, ssim
from repro.core.train import (
    all_gather_bytes_per_step,
    init_state,
    make_eval_render,
    make_train_step,
    record_shard_balance,
    shard_balance,
    state_shardings,
)
from repro.configs.gs_datasets import DATASETS
from repro.data.views import ViewDataset
from repro.obs import Obs, devmem, new_request_id, trace_meta, validate_trace_jsonl, write_trace
from repro.obs.clock import now, since
from repro.volume import datasets as VD
from repro.volume.isosurface import extract_isosurface_points


class GSTrainer:
    """Owns the (re-jitted-per-densify-round) distributed train step."""

    def __init__(self, cfg: GSConfig, mesh, points, colors, *, verbose: bool = True,
                 obs: Obs | None = None):
        self.cfg = cfg
        self.mesh = mesh
        self.n_shards = mesh.shape["model"]
        self.verbose = verbose
        # training telemetry bundle: share one with a serving stack and
        # train spans/metrics land next to request spans on one clock
        self.obs = obs if obs is not None else Obs()
        n0 = points.shape[0]
        quantum = self.n_shards * cfg.pad_quantum
        pad = (-n0) % quantum
        pts = np.concatenate([np.asarray(points), np.full((pad, 3), 1e6, np.float32)])
        cols = np.concatenate([np.asarray(colors), np.zeros((pad, 3), np.float32)])
        g = G.init_from_points(jnp.asarray(pts), jnp.asarray(cols), sh_degree=cfg.sh_degree)
        g = g._replace(opacity_logit=g.opacity_logit.at[n0:].set(-20.0))
        self.state = jax.device_put(init_state(g), state_shardings(mesh))
        self._step_fn = None
        self._n_jitted = None

    @property
    def step_fn(self):
        n = self.state.params.n
        if self._step_fn is None or self._n_jitted != n:
            self._step_fn = make_train_step(self.mesh, self.cfg)
            self._n_jitted = n
        return self._step_fn

    def shard_balance(self, *, record: bool = True) -> dict:
        """Per-model-shard load stats (``train.shard_*`` gauges when
        ``record``) — the skew signal densification creates and a dynamic
        rebalancing pass will consume."""
        bal = shard_balance(self.state, opacity_thresh=self.cfg.prune_opacity_thresh)
        if record:
            record_shard_balance(self.obs.metrics, bal)
        return bal

    def fit(self, data: ViewDataset, *, steps: int, densify: bool = True, log_every: int = 50,
            scene_extent: float = 1.0):
        """Per-step telemetry rides the registry (``train.loss`` gauge,
        ``train.step_ms`` histogram, ``train.gather_bytes``); spans cover
        batch assembly -> jitted dispatch -> device compute (bounded by
        block_until_ready, traced runs only) -> densify rounds. The
        ``log_every`` print reads ONE atomic registry snapshot instead of
        loose locals, so what it prints is exactly what ``--metrics-out``
        exports."""
        m = self.obs.metrics
        loss_gauge = m.gauge("train.loss")
        step_ms = m.histogram("train.step_ms")
        device_ms = m.histogram("train.device_ms")
        gather_bytes = m.counter("train.gather_bytes")
        steps_total = m.counter("train.steps")
        rid = new_request_id()  # one span tree per fit call
        gb = all_gather_bytes_per_step(self.cfg, self.mesh, self.state.params.n)
        losses = []
        t0 = now()
        t_iter = t0
        for i, (cams, gt) in enumerate(data.batches(self.cfg.batch_size, steps=steps)):
            rec = self.obs.trace
            t_batch = now()
            if rec:
                rec.record(rid, "batch", t_iter, t_batch, step=i)
            self.state, metrics = self.step_fn(self.state, cams, gt)
            if rec:
                t_disp = now()
                rec.record(rid, "dispatch", t_batch, t_disp, step=i)
                jax.block_until_ready(self.state)
                t_dev = now()
                rec.record(rid, "device", t_disp, t_dev, step=i)
                device_ms.observe((t_dev - t_disp) * 1e3)
            losses.append(float(metrics["loss"]))  # blocks on the step
            loss_gauge.set(losses[-1])
            steps_total.inc()
            gather_bytes.inc(gb)
            step_ms.observe(since(t_batch) * 1e3)
            step = int(self.state.step)
            if densify and self.cfg.densify_from <= step <= self.cfg.densify_until and step % self.cfg.densify_interval == 0:
                t_d = now()
                self.state, report = densify_and_rebalance(
                    self.state, self.cfg, n_shards=self.n_shards, scene_extent=scene_extent
                )
                self.state = jax.device_put(self.state, state_shardings(self.mesh))
                rec = self.obs.trace
                if rec:
                    rec.record(rid, "densify", t_d, now(), step=step,
                               n=int(self.state.params.n))
                gb = all_gather_bytes_per_step(self.cfg, self.mesh, self.state.params.n)
                self.shard_balance()  # densify is where shards skew
                if self.verbose:
                    print(f"  densify @ {step}: {report}")
            if densify and step % self.cfg.opacity_reset_interval == 0 and step > 0:
                self.state = reset_opacity(self.state)
            if self.verbose and i % log_every == 0:
                snap = m.snapshot()  # ONE atomic read: loss + timing agree
                print(
                    f"step {step:6d} loss {snap['train.loss']:.5f} "
                    f"step_ms p50 {snap['train.step_ms']['p50']:.1f} "
                    f"({since(t0):.1f}s)"
                )
            t_iter = now()
        self.shard_balance()
        devmem.record(m)
        return losses

    def evaluate(self, data: ViewDataset, view_ids) -> dict:
        eval_fn = make_eval_render(self.mesh, self.cfg)
        rec = self.obs.trace
        rid = new_request_id()
        t0 = now() if rec else 0.0
        ps, ss, lp = [], [], []
        for i in view_ids:
            cam, gt = data.view(int(i))
            img, _ = eval_fn(self.state.params, cam)
            ps.append(float(psnr(img, gt)))
            ss.append(float(ssim(img, gt)))
            lp.append(float(lpips_proxy(img, gt)))
        out = {"psnr": float(np.mean(ps)), "ssim": float(np.mean(ss)), "lpips_proxy": float(np.mean(lp))}
        self.obs.metrics.gauge("train.psnr").set(round(out["psnr"], 4))
        if rec:
            rec.record(rid, "eval", t0, now(), views=len(ps), psnr=round(out["psnr"], 3))
        return out


def build_dataset(name: str, *, volume_res: int, n_views: int, img_h: int, img_w: int,
                  max_points: int | None, cache_dir: str | None = "experiments/gt_cache"):
    ds = DATASETS[name]
    vol = getattr(VD, ds.volume)(res=volume_res)
    pts, nrm, cols = extract_isosurface_points(vol, max_points=max_points)
    data = ViewDataset(vol, n_views=n_views, img_h=img_h, img_w=img_w, radius=ds.radius, cache_dir=cache_dir)
    return vol, pts, cols, data


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", choices=list(DATASETS), default="kingsnake")
    ap.add_argument("--res", type=int, default=64)
    ap.add_argument("--volume-res", type=int, default=48)
    ap.add_argument("--views", type=int, default=24)
    ap.add_argument("--max-points", type=int, default=4000)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--data-par", type=int, default=1)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--backend", choices=["ref", "pallas"], default="ref")
    ap.add_argument("--k-per-tile", type=int, default=256)
    ap.add_argument("--gather-mode", default="auto", choices=["auto", "projected", "params3d"])
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--trace-out", default=None,
                    help="write per-step span trace (JSONL; .chrome.json sibling for Perfetto)")
    ap.add_argument("--metrics-out", default=None,
                    help="write final train.* registry snapshot as JSON")
    ap.add_argument("--trace-capacity", type=int, default=65536)
    args = ap.parse_args()

    obs = Obs(trace=args.trace_out is not None, trace_capacity=args.trace_capacity)

    mesh = jax.make_mesh((args.data_par, args.model_par), ("data", "model"))
    cfg = GSConfig(
        img_h=args.res, img_w=args.res, batch_size=args.batch, backend=args.backend,
        k_per_tile=args.k_per_tile, max_steps=max(args.steps, 1),
        gather_mode=args.gather_mode,
        densify_from=100, densify_interval=150, densify_until=max(args.steps - 50, 101),
        opacity_reset_interval=10**9,
    )
    vol, pts, cols, data = build_dataset(
        args.dataset, volume_res=args.volume_res, n_views=args.views,
        img_h=args.res, img_w=args.res, max_points=args.max_points,
    )
    print(f"{args.dataset}: {pts.shape[0]} isosurface points, {args.views} views @ {args.res}^2, mesh {dict(mesh.shape)}")
    tr = GSTrainer(cfg, mesh, pts, cols, obs=obs)
    t0 = now()
    losses = tr.fit(data, steps=args.steps)
    train_time = since(t0)
    metrics = tr.evaluate(data, range(0, args.views, max(args.views // 8, 1)))
    print(f"train {train_time:.1f}s  final-loss {losses[-1]:.5f}  {metrics}")
    if args.ckpt:
        rec, rid = obs.trace, new_request_id()
        t_c = now()
        path = save_checkpoint(args.ckpt, int(tr.state.step), tr.state)
        if rec:
            rec.record(rid, "ckpt", t_c, now(), step=int(tr.state.step))
        print("checkpoint:", path)
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(obs.metrics.snapshot(), f, indent=1, sort_keys=True)
        print("metrics:", args.metrics_out)
    if args.trace_out:
        spans = obs.trace.drain()
        meta = trace_meta(obs.trace, knobs={
            "dataset": args.dataset, "steps": args.steps, "batch": args.batch,
            "data_par": args.data_par, "model_par": args.model_par,
            "backend": args.backend, "gather_mode": cfg.gather_mode,
        })
        jsonl_path, chrome_path = write_trace(args.trace_out, spans, meta=meta)
        with open(jsonl_path) as f:
            n = validate_trace_jsonl(f.read())
        print(f"trace: {n} spans -> {jsonl_path} + {chrome_path}")


if __name__ == "__main__":
    main()
