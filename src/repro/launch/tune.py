"""Trace-driven autotuning driver: fit, replay, recommend, calibrate.

Closes the observe -> model -> decide loop over a recorded span trace:

  # record a trace (benchmarks/frontend_load.py --trace-out, or either
  # serving CLI), then search the knob space via replay
  PYTHONPATH=src python -m repro.launch.tune --trace TRACE_frontend.jsonl \
      --out RECOMMEND_tune.json

  # additionally self-calibrate against the measured benchmark record and
  # fail if the replay misses the measured fps/p99 by more than the budget
  PYTHONPATH=src python -m repro.launch.tune --trace TRACE_frontend.jsonl \
      --measured BENCH_frontend.json --bench-out BENCH_replay.json

The recommendation JSON is consumed by ``benchmarks/serve_throughput.py``
and ``benchmarks/frontend_load.py`` via ``--config-from`` (see
:func:`load_recommended_knobs`). The whole pipeline is deterministic for a
fixed trace + ``--seed``: the recommendation embeds the cost-model
fingerprint so any consumer can verify which fit produced it.

Self-calibration is the honesty gate: replaying the trace under the very
knobs that produced it must predict aggregate fps and p99 close to the
*measured* numbers in the benchmark record (the traced lap's, when present).
A model that can't reproduce the world it watched has no business
recommending changes to it — CI enforces the budget via ``BENCH_replay.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def load_recommended_knobs(path: str) -> dict:
    """Read the knob dict out of a ``launch.tune`` recommendation file (or
    accept a bare ``{knob: value}`` JSON for hand-written configs) — the
    ``--config-from`` entry point for the benchmark drivers."""
    with open(path) as f:
        rec = json.load(f)
    if isinstance(rec, dict) and "recommended" in rec:
        return dict(rec["recommended"]["knobs"])
    if isinstance(rec, dict):
        return dict(rec)
    raise ValueError(f"{path}: not a recommendation file or knob dict")


def _measured_numbers(path: str) -> tuple[float, float, str]:
    """Pull measured (fps, p99_ms) from a BENCH_*.json record, preferring
    the traced lap's own numbers (``trace_frames_per_s``/``trace_p99_ms``)
    — that lap is the one the spans describe — over the best-lap
    headline metrics."""
    with open(path) as f:
        rec = json.load(f)
    metrics = rec.get("metrics", rec)
    if "trace_frames_per_s" in metrics:
        return (float(metrics["trace_frames_per_s"]),
                float(metrics.get("trace_p99_ms", metrics.get("p99_ms", 0.0))),
                "traced_lap")
    return float(metrics["frames_per_s"]), float(metrics["p99_ms"]), "best_lap"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", required=True, metavar="PATH.jsonl",
                    help="span trace exported by --trace-out")
    ap.add_argument("--seed", type=int, default=0,
                    help="replay seed (fixed trace + seed => fixed output)")
    ap.add_argument("--slo-p99-ms", type=float, default=None,
                    help="rank knob candidates under this p99 target "
                         "(infeasible ones lose to any feasible one)")
    ap.add_argument("--out", default="RECOMMEND_tune.json",
                    help="recommendation JSON (consumed via --config-from)")
    # self-calibration gate
    ap.add_argument("--measured", default=None, metavar="BENCH.json",
                    help="measured benchmark record to calibrate against")
    ap.add_argument("--bench-out", default=None, metavar="BENCH_replay.json",
                    help="write the predicted-vs-measured calibration record")
    ap.add_argument("--calibration-budget", type=float, default=0.2,
                    help="max relative error on fps AND p99 before failing")
    args = ap.parse_args(argv)

    # imported here so `--help` works without src on the path being warm
    from repro.obs.autotune import recommend
    from repro.obs.replay import fit_trace

    model = fit_trace(args.trace)
    dropped = int(model.meta.get("dropped", 0))
    if dropped:
        # fit on a lossy trace is fit on a lie — proceed (the model may
        # still be useful) but say so where nobody can miss it
        print(f"WARNING: trace dropped {dropped} spans to ring overwrite "
              f"(capacity {model.meta.get('capacity')}); the cost model is "
              f"fit on an incomplete record — re-record with a larger "
              f"--trace-capacity for trustworthy numbers", file=sys.stderr)
    print(f"model: {len(model.arrivals)} requests / {model.span_count} spans, "
          f"outcomes {model.outcome_mix()}, knobs {model.knobs or '(none recorded)'}, "
          f"fingerprint {model.fingerprint()[:12]}")

    rec = recommend(model, seed=args.seed, slo_p99_ms=args.slo_p99_ms)
    base, reco = rec["baseline"], rec["recommended"]
    print(f"baseline  {base['knobs']}\n"
          f"          -> {base['predicted']['frames_per_s']} fps, "
          f"p99 {base['predicted']['p99_ms']} ms, "
          f"shed {base['predicted']['shed']}")
    print(f"recommend {reco['knobs']}\n"
          f"          -> {reco['predicted']['frames_per_s']} fps, "
          f"p99 {reco['predicted']['p99_ms']} ms, "
          f"shed {reco['predicted']['shed']} "
          f"({rec['predicted_speedup']}x predicted, "
          f"{rec['evaluated']} candidates)")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"recommendation -> {args.out}")

    if args.measured is None:
        return

    # ---- self-calibration: predicted (recorded knobs) vs measured
    measured_fps, measured_p99, source = _measured_numbers(args.measured)
    pred = base["predicted"]
    fps_err = abs(pred["frames_per_s"] - measured_fps) / max(measured_fps, 1e-9)
    p99_err = abs(pred["p99_ms"] - measured_p99) / max(measured_p99, 1e-9)
    calibration_error = max(fps_err, p99_err)
    print(f"calibration vs {args.measured} ({source}): "
          f"fps {pred['frames_per_s']} vs {measured_fps} "
          f"(err {fps_err:.1%}), p99 {pred['p99_ms']} vs {measured_p99} ms "
          f"(err {p99_err:.1%}) -> {calibration_error:.1%} "
          f"(budget {args.calibration_budget:.0%})")
    if args.bench_out:
        # bench_schema lives in benchmarks/ (not on the package path);
        # the record shape is small enough to emit inline, same schema
        record = {
            "bench": "replay_calibration",
            "schema": 2,
            "config": {
                "trace": os.path.basename(args.trace),
                "seed": args.seed,
                "spans": model.span_count,
                "requests": len(model.arrivals),
                "dropped_spans": dropped,
                "measured_source": source,
                **{f"knob_{k}": v for k, v in sorted(base["knobs"].items())},
            },
            "metrics": {
                "predicted_frames_per_s": pred["frames_per_s"],
                "measured_frames_per_s": measured_fps,
                "fps_error": round(fps_err, 4),
                "predicted_p99_ms": pred["p99_ms"],
                "measured_p99_ms": measured_p99,
                "p99_error": round(p99_err, 4),
                "calibration_error": round(calibration_error, 4),
                "calibration_budget": args.calibration_budget,
                "predicted_speedup": rec["predicted_speedup"],
                "recommended_frames_per_s": reco["predicted"]["frames_per_s"],
            },
        }
        os.makedirs(os.path.dirname(args.bench_out) or ".", exist_ok=True)
        with open(args.bench_out, "w") as f:
            json.dump(record, f, indent=1)
        print(f"calibration record -> {args.bench_out}")
    if calibration_error > args.calibration_budget:
        raise SystemExit(
            f"replay calibration error {calibration_error:.1%} exceeds budget "
            f"{args.calibration_budget:.0%}: the cost model does not "
            f"reproduce the measured run it was fit on"
        )


if __name__ == "__main__":
    main()
