"""Streaming in-situ reconstruction driver: stream -> warm-start train ->
temporal checkpoints -> time-scrub serving smoke.

Consumes a time-varying synthetic volume stream (Kingsnake uncoiling or
Miranda mixing-layer growth), keeps one fixed-capacity Gaussian model
tracking the isosurface (cold start at t=0, warm delta-training after),
appends every timestep to a keyframe+delta temporal checkpoint store, then
reloads the sequence into a timeline RenderServer and scrubs one camera
across time. Prints a JSON report; exits nonzero if the train step traced
more than once or scrubbed frames are not per-timestep distinct.

  PYTHONPATH=src python -m repro.launch.insitu --smoke
  PYTHONPATH=src python -m repro.launch.insitu --dataset miranda \
      --timesteps 6 --res 64 --cold-steps 200 --warm-steps 40 \
      --ckpt experiments/insitu/run0
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import tempfile

import jax
import numpy as np

from repro.core.config import GSConfig
from repro.insitu import (
    InsituTrainer,
    TemporalCheckpointStore,
    build_timeline_server,
    replay_live,
    scrub,
)
from repro.obs import Obs, trace_meta, validate_trace_jsonl, write_trace
from repro.obs.clock import now, since
from repro.serve_gs import front_camera
from repro.volume.timevary import GENERATORS, synthetic_stream


def scrub_smoke(
    store: TemporalCheckpointStore, cfg: GSConfig, *, n_scrub: int = 3, pipeline_depth: int = 2
) -> dict:
    """Time-scrubbing smoke: one camera, ``n_scrub`` timesteps, frames must
    be distinct per timestep and cache-hit on replay. Runs with
    ``store_frames=False`` (the production serving configuration): frames
    arrive through each request's ``FrameFuture``, nothing is pinned."""
    ts = store.timesteps()[:n_scrub]
    with build_timeline_server(
        store, cfg, n_levels=2, max_batch=2, store_frames=False,
        pipeline_depth=pipeline_depth,
    ) as server:
        cam = front_camera(server.pyramid, img_h=cfg.img_h, img_w=cfg.img_w)

        frames = scrub(server, cam, ts)
        misses_first = server.cache.misses
        frames2 = scrub(server, cam, ts)  # replay: must be pure cache hits
        diffs = {
            f"{a}->{b}": float(np.abs(frames[a] - frames[b]).max()) for a, b in zip(ts, ts[1:])
        }
        return {
            "timesteps": ts,
            "frame_shape": list(frames[ts[0]].shape),
            "max_abs_frame_delta": diffs,
            "frames_distinct": all(d > 1e-4 for d in diffs.values()),
            "replay_identical": all(np.array_equal(frames[t], frames2[t]) for t in ts),
            "replay_cache_hits": server.cache.hits,
            "replay_new_misses": server.cache.misses - misses_first,
            "pipeline": server.report()["pipeline"],
            "timeline": server.report()["timeline"],
        }


def live_replay_smoke(store: TemporalCheckpointStore, cfg: GSConfig) -> dict:
    """Live-update smoke: replay the stored sequence through ONE serving
    slot. The store's per-timestep changed slots drive world-space
    invalidation — after the first viewer pose registers, later updates
    should drop only the tile rows the changed Gaussians can touch (partial
    invalidations), not the whole frame."""
    ts = store.timesteps()
    events: list[int | None] = []  # None = full drop, int = dirty row count
    with build_timeline_server(
        store, cfg, timesteps=ts[:1], n_levels=2, max_batch=2, store_frames=False
    ) as server:
        server.add_invalidation_listener(
            lambda t, rows: events.append(None if rows is None else len(rows))
        )
        cam = front_camera(server.pyramid, img_h=cfg.img_h, img_w=cfg.img_w)

        def view(_t=None):
            fut = server.submit(cam, timestep=ts[0])
            server.run()
            fut.result()

        view()  # registers the pose the invalidator projects through
        replay_live(store, server, timesteps=ts[1:], serve_timestep=ts[0], on_timestep=view)
        return {
            "updates": len(ts) - 1,
            "invalidations": events,
            "partial_invalidations": sum(1 for e in events if e is not None),
            "full_invalidations": sum(1 for e in events if e is None),
        }


def traced_overhead_gate(trainer: InsituTrainer, vol, *, probe_steps: int, budget: float) -> dict:
    """Bound what span tracing costs a warm train step (the training twin of
    the serving stack's traced-request gate). Three probe laps on the live
    model — warmup+untraced, untraced, traced — each through the real
    ``_fit`` loop on throwaway ``Obs`` bundles (the run's registry/ring stay
    clean). The traced lap is judged against the SLOWER untraced lap, so
    ordinary jitter doesn't fail the gate; a real regression (tracing adds
    more than ``budget`` fractional per-step overhead) does."""
    data = trainer._dataset(vol)
    saved = trainer.obs

    def lap(traced: bool) -> float:
        trainer.obs = Obs(trace=traced, trace_capacity=8 * probe_steps + 16)
        t0 = now()
        trainer._fit(data, probe_steps, psnr0=0.0)
        return since(t0)

    try:
        lap(False)  # warm caches/dispatch before anything is timed
        untraced = [lap(False), lap(False)]
        traced = lap(True)
    finally:
        trainer.obs = saved
    overhead = traced / max(max(untraced), 1e-9) - 1.0
    return {
        "probe_steps": probe_steps,
        "untraced_s": [round(t, 4) for t in untraced],
        "traced_s": round(traced, 4),
        "overhead": round(overhead, 4),
        "budget": budget,
        "ok": overhead <= budget,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="reduced CPU config (48px, 3 timesteps)")
    ap.add_argument("--dataset", choices=list(GENERATORS), default="miranda")
    ap.add_argument("--timesteps", type=int, default=4)
    ap.add_argument("--t1", type=float, default=0.3, help="simulation time of the last timestep")
    ap.add_argument("--volume-res", type=int, default=48)
    ap.add_argument("--res", type=int, default=64)
    ap.add_argument("--views", type=int, default=8)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--max-points", type=int, default=2000)
    ap.add_argument("--cold-steps", type=int, default=150)
    ap.add_argument("--warm-steps", type=int, default=30)
    ap.add_argument("--capacity-factor", type=float, default=1.5)
    ap.add_argument("--keyframe-interval", type=int, default=4)
    ap.add_argument("--raymarch-steps", type=int, default=48)
    ap.add_argument("--data-par", type=int, default=1)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument(
        "--pipeline-depth", type=int, default=2,
        help="serving smoke: in-flight micro-batches (1 = synchronous dispatch)",
    )
    ap.add_argument(
        "--sync-store", action="store_true",
        help="write temporal checkpoints inline instead of on the background writer",
    )
    ap.add_argument("--ckpt", default=None, help="temporal store dir (default: temp dir)")
    ap.add_argument("--no-scrub", action="store_true", help="skip the serving smoke")
    ap.add_argument("--report", default=None, help="write the JSON report here too")
    ap.add_argument("--trace-out", default=None, metavar="PATH.jsonl",
                    help="record per-step train spans; on exit write JSONL here "
                         "plus a Perfetto-viewable .chrome.json next to it")
    ap.add_argument("--trace-capacity", type=int, default=65536,
                    help="span ring size (oldest spans drop beyond this)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the final train.* registry snapshot as JSON")
    ap.add_argument("--overhead-gate", type=int, default=0, metavar="STEPS",
                    help="probe-lap steps for the traced-step overhead gate "
                         "(0 = off); exits nonzero when tracing costs more "
                         "than --overhead-budget per step")
    ap.add_argument("--overhead-budget", type=float, default=0.25)
    args = ap.parse_args(argv)

    if args.smoke:
        args.timesteps = min(args.timesteps, 3)
        args.volume_res = min(args.volume_res, 32)
        args.res = min(args.res, 48)
        args.views = min(args.views, 6)
        args.max_points = min(args.max_points, 800)
        args.cold_steps = min(args.cold_steps, 40)
        args.warm_steps = min(args.warm_steps, 10)
        args.t1 = min(args.t1, 0.15)

    mesh = jax.make_mesh((args.data_par, args.model_par), ("data", "model"))
    cfg = GSConfig(
        img_h=args.res, img_w=args.res, batch_size=args.batch,
        k_per_tile=128 if args.smoke else 256,
        max_steps=args.cold_steps + args.warm_steps * max(args.timesteps - 1, 0),
        densify_from=10**9, opacity_reset_interval=10**9,
    )
    stream = synthetic_stream(args.dataset, args.timesteps, res=args.volume_res, t1=args.t1)
    store_dir = args.ckpt or os.path.join(tempfile.mkdtemp(prefix="insitu_"), "seq")
    # context manager: queued background writes survive (flush + writer join)
    # even when a later stage of this driver raises
    with TemporalCheckpointStore(
        store_dir, keyframe_interval=args.keyframe_interval,
        async_writes=not args.sync_store,
    ) as store:
        if store.timesteps():
            raise SystemExit(
                f"temporal store {store_dir} already holds timesteps {store.timesteps()}; "
                "this driver records a fresh sequence from t=0 — pass a new --ckpt dir"
            )

        obs = Obs(trace=args.trace_out is not None, trace_capacity=args.trace_capacity)
        trainer = InsituTrainer(
            cfg, mesh,
            capacity_factor=args.capacity_factor,
            cold_steps=args.cold_steps, warm_steps=args.warm_steps,
            n_views=args.views, max_points=args.max_points,
            n_steps_raymarch=args.raymarch_steps, init_scale=0.06, verbose=True,
            obs=obs,
        )
        print(
            f"insitu: {args.dataset} x{args.timesteps} timesteps, vol {args.volume_res}^3, "
            f"{args.res}px, mesh {dict(mesh.shape)}, store {store_dir}"
        )
        reports = trainer.run(stream, store=store)

        out = {
            "config": {
                "dataset": args.dataset, "timesteps": args.timesteps, "res": args.res,
                "volume_res": args.volume_res, "capacity": trainer.capacity,
                "cold_steps": args.cold_steps, "warm_steps": args.warm_steps,
            },
            "timesteps": [
                {k: v for k, v in dataclasses.asdict(r).items() if k != "psnr_curve"}
                for r in reports
            ],
            "recompile_count": trainer.n_traces,
            "shard_balance": trainer.shard_balance(record=False),
            "store": store.stats(),
        }
        if not args.no_scrub:
            out["scrub"] = scrub_smoke(
                store, cfg, n_scrub=min(3, args.timesteps), pipeline_depth=args.pipeline_depth
            )
            if args.timesteps > 1:
                out["live_replay"] = live_replay_smoke(store, cfg)

    if args.overhead_gate > 0:
        probe_vol = next(iter(synthetic_stream(args.dataset, 1, res=args.volume_res, t1=0.0)))
        out["traced_overhead"] = traced_overhead_gate(
            trainer, probe_vol, probe_steps=args.overhead_gate, budget=args.overhead_budget
        )

    txt = json.dumps(out, indent=1)
    print(txt)
    if args.report:
        os.makedirs(os.path.dirname(args.report) or ".", exist_ok=True)
        with open(args.report, "w") as f:
            f.write(txt)
    if args.metrics_out:
        os.makedirs(os.path.dirname(args.metrics_out) or ".", exist_ok=True)
        with open(args.metrics_out, "w") as f:
            json.dump(obs.metrics.snapshot(), f, indent=1, sort_keys=True)
        print("metrics:", args.metrics_out)
    if args.trace_out:
        spans = obs.trace.drain()
        meta = trace_meta(obs.trace, knobs={
            "dataset": args.dataset, "timesteps": args.timesteps,
            "cold_steps": args.cold_steps, "warm_steps": args.warm_steps,
            "capacity": trainer.capacity,
            "data_par": args.data_par, "model_par": args.model_par,
        })
        jsonl_path, chrome_path = write_trace(args.trace_out, spans, meta=meta)
        with open(jsonl_path) as f:
            n = validate_trace_jsonl(f.read())
        print(f"trace: {n} spans -> {jsonl_path} + {chrome_path}")
        if n.dropped:
            print(f"WARNING: span ring overflowed — {n.dropped} spans LOST "
                  f"(capacity {obs.trace.capacity}); raise --trace-capacity "
                  f"before trusting stage breakdowns", file=sys.stderr)

    assert trainer.n_traces == 1, f"train step retraced: {trainer.n_traces} traces"
    if not args.no_scrub:
        assert out["scrub"]["frames_distinct"], "scrubbed frames are not per-timestep distinct"
        assert out["scrub"]["replay_new_misses"] == 0, "scrub replay missed the frame cache"
    if args.overhead_gate > 0:
        g = out["traced_overhead"]
        if not g["ok"]:
            raise SystemExit(
                f"traced-step overhead gate FAILED: {g['overhead']:.1%} per step "
                f"(budget {g['budget']:.0%}) over {g['probe_steps']} probe steps"
            )
        print(f"traced-step overhead {g['overhead']:+.1%} (budget {g['budget']:.0%}) ok")
    ratio = out["store"]["delta_compression"]
    print(
        f"insitu ok: {len(reports)} timesteps, 1 train-step trace, "
        f"final PSNR {reports[-1].psnr_after:.2f} dB"
        + (f", delta frames {ratio}x smaller than keyframes" if ratio else "")
    )


if __name__ == "__main__":
    main()
