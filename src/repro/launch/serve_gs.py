"""Gaussian render-serving driver: trained model -> multi-client service.

Loads a trained checkpoint (or initializes a fresh model from a synthetic
isosurface when none is given), builds the LOD pyramid, and drives the
batched render server with a synthetic client fleet, printing a JSON report.

  PYTHONPATH=src python -m repro.launch.serve_gs --smoke
  PYTHONPATH=src python -m repro.launch.serve_gs --ckpt experiments/ckpts/run0 \
      --res 128 --clients 8 --requests 16 --levels 3
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint
from repro.configs.gs_datasets import DATASETS
from repro.core import gaussians as G
from repro.core.config import GSConfig
from repro.core.train import init_state
from repro.obs import Obs, trace_meta, validate_trace_jsonl, write_trace
from repro.serve_gs import RenderServer, make_clients, run_load
from repro.volume import datasets as VD
from repro.volume.isosurface import extract_isosurface_points


def load_params_from_ckpt(ckpt_dir: str) -> G.GaussianModel:
    step = latest_step(ckpt_dir)
    if step is None:
        raise SystemExit(f"no checkpoint under {ckpt_dir}")
    man = json.load(open(os.path.join(ckpt_dir, f"step_{step:08d}", "manifest.json")))
    n = man["leaves"]["params.means"]["shape"][0]
    like = init_state(G.init_from_points(jnp.zeros((n, 3)), jnp.zeros((n, 3))))
    state = restore_checkpoint(ckpt_dir, step, jax.tree_util.tree_map(np.asarray, like))
    return G.GaussianModel(*[np.asarray(x) for x in state.params])


def init_params_from_volume(dataset: str, *, volume_res: int, max_points: int) -> G.GaussianModel:
    ds = DATASETS[dataset]
    vol = getattr(VD, ds.volume)(res=volume_res)
    pts, _, cols = extract_isosurface_points(vol, max_points=max_points)
    return G.init_from_points(jnp.asarray(pts), jnp.asarray(cols), init_scale=0.05)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="reduced CPU config (32px, 32 requests)")
    ap.add_argument("--ckpt", default=None, help="checkpoint dir from repro.launch.train")
    ap.add_argument("--dataset", choices=list(DATASETS), default="kingsnake")
    ap.add_argument("--volume-res", type=int, default=48)
    ap.add_argument("--max-points", type=int, default=4000)
    ap.add_argument("--res", type=int, default=64)
    ap.add_argument("--levels", type=int, default=3)
    ap.add_argument("--keep-ratio", type=float, default=0.5)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8, help="requests per client")
    ap.add_argument("--orbit-views", type=int, default=12)
    ap.add_argument("--radius-spread", type=float, default=1.0)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument(
        "--pipeline-depth", type=int, default=2,
        help="in-flight micro-batches kept on-device (1 = synchronous dispatch)",
    )
    ap.add_argument("--cache", type=int, default=512,
                    help="cache capacity in frame-equivalents (byte budget = "
                    "N x frame bytes; 0 disables)")
    ap.add_argument("--cache-bytes", type=int, default=None,
                    help="cache byte budget directly (overrides --cache)")
    ap.add_argument("--frame-cache", action="store_true",
                    help="whole-frame cache baseline (disables the "
                    "tile-granular cache + partial strip renders)")
    ap.add_argument("--rate", type=float, default=0.0, help="request rounds per second (0 = flat out)")
    ap.add_argument("--report", default=None, help="write the JSON report here too")
    ap.add_argument("--trace-out", default=None, metavar="PATH.jsonl",
                    help="record request span traces; on exit write JSONL "
                         "here plus a Perfetto-viewable .chrome.json next to it")
    ap.add_argument("--trace-capacity", type=int, default=65536,
                    help="span ring size (oldest spans drop beyond this)")
    args = ap.parse_args(argv)

    if args.smoke:
        args.res = min(args.res, 32)
        args.volume_res = min(args.volume_res, 32)
        args.max_points = min(args.max_points, 800)

    if args.ckpt:
        params = load_params_from_ckpt(args.ckpt)
    else:
        params = init_params_from_volume(
            args.dataset, volume_res=args.volume_res, max_points=args.max_points
        )
    cfg = GSConfig(img_h=args.res, img_w=args.res, k_per_tile=128 if args.smoke else 256)

    obs = Obs(trace=args.trace_out is not None, trace_capacity=args.trace_capacity)
    with RenderServer(
        params,
        cfg,
        obs=obs,
        n_levels=args.levels,
        keep_ratio=args.keep_ratio,
        max_batch=args.max_batch,
        cache_capacity=args.cache,
        cache_bytes=args.cache_bytes,
        tile_cache=not args.frame_cache,
        store_frames=False,
        pipeline_depth=args.pipeline_depth,
    ) as server:
        print(
            f"serve_gs: {args.dataset} n={params.n} levels={server.pyramid.live_counts} "
            f"res={args.res} clients={args.clients}x{args.requests}"
        )
        clients = make_clients(
            args.clients,
            n_views=args.orbit_views,
            img_h=args.res,
            img_w=args.res,
            radius_spread=args.radius_spread,
        )
        report = run_load(server, clients, requests_per_client=args.requests, rate_hz=args.rate)
    report["config"] = {
        "res": args.res,
        "clients": args.clients,
        "requests_per_client": args.requests,
        "levels": args.levels,
        "keep_ratio": args.keep_ratio,
        "max_batch": args.max_batch,
        "pipeline_depth": args.pipeline_depth,
    }
    out = json.dumps(report, indent=1)
    print(out)
    if args.report:
        os.makedirs(os.path.dirname(args.report) or ".", exist_ok=True)
        with open(args.report, "w") as f:
            f.write(out)
    if args.trace_out:
        spans = obs.trace.drain()
        meta = trace_meta(obs.trace, knobs={
            "max_batch": args.max_batch,
            "pipeline_depth": args.pipeline_depth,
        })
        jsonl_path, chrome_path = write_trace(args.trace_out, spans, meta=meta)
        with open(jsonl_path) as f:
            n = validate_trace_jsonl(f.read())
        print(f"trace: {n} spans -> {jsonl_path} + {chrome_path}")
        if n.dropped:
            print(f"WARNING: span ring overflowed — {n.dropped} spans LOST "
                  f"(capacity {obs.trace.capacity}); raise --trace-capacity "
                  f"before trusting replay fits", file=sys.stderr)
    assert report["completed"] == args.clients * args.requests, (
        f"pipelined path dropped requests: completed {report['completed']} of "
        f"{args.clients * args.requests}"
    )
    print(f"served {report['completed']} requests "
          f"({report['frames_per_s']} frames/s, cache hit rate {report['cache']['hit_rate']}, "
          f"depth {report['pipeline']['depth']}, deduped {report['pipeline']['deduped']})")


if __name__ == "__main__":
    main()
