"""Batched LM serving driver: prefill (chunked) + cached greedy decode.

This is the runtime counterpart of the decode_32k / long_500k dry-run
shapes. On real hardware you'd pass --data-par/--model-par to shard the
cache; on CPU it runs reduced configs.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import api, lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=0, help="default prompt+gen")
    args = ap.parse_args()

    mod = get_arch(args.arch)
    cfg = mod.smoke_config() if args.smoke else mod.config()
    cache_len = args.cache_len or (args.prompt_len + args.gen)
    print(f"{cfg.name}: {cfg.n_layers}L d={cfg.d_model} ({cfg.arch_type}); "
          f"batch={args.batch} cache={cache_len}")

    key = jax.random.key(0)
    params = lm.init_params(cfg, key)
    serve = jax.jit(api.make_serve_step(cfg))
    cache = api.init_cache(cfg, args.batch, cache_len)

    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab)

    # prefill by stepping the decode cache through the prompt (token-by-token
    # cache population; a fused prefill that bulk-writes the cache is the
    # enumerated §Perf follow-up for serving)
    t0 = time.perf_counter()
    logits = None
    for t in range(args.prompt_len):
        logits, cache = serve(params, cache, prompt[:, t : t + 1], jnp.asarray(t, jnp.int32))
    t_prefill = time.perf_counter() - t0

    toks = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [np.asarray(toks[:, 0])]
    t0 = time.perf_counter()
    for t in range(args.prompt_len, args.prompt_len + args.gen - 1):
        logits, cache = serve(params, cache, toks, jnp.asarray(t, jnp.int32))
        toks = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(np.asarray(toks[:, 0]))
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0

    gen = np.stack(out, axis=1)
    print("generated ids:\n", gen)
    print(f"prefill {t_prefill*1e3:.0f} ms ({args.prompt_len} steps), "
          f"decode {t_decode/max(args.gen-1,1)*1e3:.1f} ms/token")


if __name__ == "__main__":
    main()
