from repro.data.views import ViewDataset

__all__ = ["ViewDataset"]
