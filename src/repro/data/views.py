"""View data pipeline: GT render cache + shuffled batch iterator.

The paper trains against 448 synthetic orbit views; rendering those GT images
(ray-marched isosurface) is expensive, so they are produced once and cached
on disk, then served as shuffled batches sharded onto the mesh.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.projection import Camera
from repro.volume.cameras import camera_slice, orbit_cameras
from repro.volume.datasets import VolumeSpec
from repro.volume.raymarch import render_isosurface


class ViewDataset:
    def __init__(
        self,
        vol: VolumeSpec,
        *,
        n_views: int,
        img_h: int,
        img_w: int,
        radius: float = 3.0,
        cache_dir: str | None = None,
        n_steps_raymarch: int = 128,
        seed: int = 0,
    ):
        self.img_h, self.img_w = img_h, img_w
        self.n_views = n_views
        self.cams = orbit_cameras(n_views, img_h=img_h, img_w=img_w, radius=radius)
        self.rng = np.random.default_rng(seed)

        cache_file = None
        if cache_dir:
            os.makedirs(cache_dir, exist_ok=True)
            cache_file = os.path.join(cache_dir, f"{vol.name}_{n_views}v_{img_h}x{img_w}.npy")
        if cache_file and os.path.exists(cache_file):
            self.gt = np.load(cache_file)
        else:
            field = jnp.asarray(vol.field)
            imgs = []
            for i in range(n_views):
                img = render_isosurface(
                    field, vol.isovalue, camera_slice(self.cams, i),
                    img_h=img_h, img_w=img_w, extent=vol.extent, n_steps=n_steps_raymarch,
                )
                imgs.append(np.asarray(img))
            self.gt = np.stack(imgs).astype(np.float32)
            if cache_file:
                np.save(cache_file, self.gt)

    def batches(self, batch_size: int, *, steps: int):
        """Yield (Camera batch, gt batch) `steps` times (with replacement
        across epochs, without within an epoch — 3D-GS convention). When an
        epoch runs low the next permutation is *prepended*, so the leftover
        views are still drawn before any view repeats: every view is sampled
        exactly once per epoch. At the epoch seam a draw that would duplicate
        a view already in the batch is swapped deeper into the new
        permutation (possible whenever batch_size <= n_views)."""
        order = []
        for _ in range(steps):
            sel = []
            for _ in range(batch_size):
                if not order:
                    order = list(self.rng.permutation(self.n_views))
                if order[-1] in sel:
                    for j in range(len(order) - 1):
                        if order[j] not in sel:
                            order[-1], order[j] = order[j], order[-1]
                            break
                sel.append(order.pop())
            sel = np.asarray(sel)
            yield camera_slice(self.cams, jnp.asarray(sel)), jnp.asarray(self.gt[sel])

    def view(self, i: int):
        return camera_slice(self.cams, i), jnp.asarray(self.gt[i])
