"""Temporal checkpoint store: keyframes + quantized delta frames.

A streamed sequence multiplies checkpoint cost by T: an 18M-Gaussian model is
~1 GB of float32 per timestep, so storing every timestep verbatim is exactly
the volume-dump I/O burden in-situ reconstruction exists to avoid. But
consecutive warm-started models differ by a few optimization steps, so the
parameter *delta* is tiny and narrow — ideal for quantization.

Layout (on top of ``repro.checkpoint.store``):

  <dir>/sequence.json            ordered timestep index (kind, base, files)
  <dir>/step_<t>/...             keyframes — the standard checkpoint layout,
                                 restorable by ``restore_checkpoint`` alone
  <dir>/delta_<t>.npz            per-leaf int16-quantized (x_t - x_recon_{t-1})
                                 plus per-leaf scales and sparse exact rows

Deltas chain against the *reconstructed* previous frame (not the exact one),
so quantization error never accumulates along the chain: every frame is within
half a quantum of its true value regardless of distance from the keyframe.

Not every per-Gaussian delta is small: dead-slot reseeding moves a padding
row's mean from the 1e6 sentinel into the scene — a jump six orders of
magnitude above the training deltas, which would poison a shared
max-abs-based quantization scale for the whole leaf. Rows whose delta exceeds
``exact_jump_thresh`` are therefore stored *exactly* (sparse float32 indices
+ values) and excluded from the scale; the remaining rows quantize against a
tight scale. ``load(t)`` restores the nearest keyframe at or before t and
replays deltas (quantized part, then exact-row overwrite).

**Asynchronous writes.** Delta quantization and ``np.savez_compressed`` are
pure host work; running them inline stalls the training loop between
timesteps. With ``async_writes=True`` (the default) ``append`` only pulls the
params to host (cheap, and required before the trainer mutates them again)
and hands the encode+write to a single background writer thread, so the
stream's next timestep trains while the previous one compresses. Appends are
processed strictly in order (one thread, FIFO queue — the delta chain needs
it); every read (``load``/``timesteps``/``stats``) flushes pending writes
first, and ``flush()``/``close()`` make durability explicit. A failure in the
writer surfaces on the next ``append``/``flush``.
"""
from __future__ import annotations

import json
import os
import queue
import threading
import time

import numpy as np

from repro.analysis import tsan
from repro.checkpoint.store import _leaf_to_host, restore_checkpoint, save_checkpoint
from repro.core import gaussians as G

_QMAX = 32767  # int16 symmetric range


def _to_host(params: G.GaussianModel) -> dict[str, np.ndarray]:
    """Shard-wise host pull (same rationale as checkpoint save: no second
    fully-replicated copy of a model-sharded leaf)."""
    return {
        f: np.asarray(_leaf_to_host(getattr(params, f)), np.float32)
        for f in G.GaussianModel._fields
    }


class TemporalCheckpointStore:
    """Append-only per-timestep store of ``GaussianModel`` params."""

    def __init__(
        self,
        directory: str,
        *,
        keyframe_interval: int = 4,
        exact_jump_thresh: float = 1.0,
        async_writes: bool = True,
    ):
        assert keyframe_interval >= 1
        self.directory = directory
        self.exact_jump_thresh = float(exact_jump_thresh)
        self.async_writes = async_writes
        os.makedirs(directory, exist_ok=True)
        self._index_path = os.path.join(directory, "sequence.json")
        if os.path.exists(self._index_path):
            with open(self._index_path) as f:
                self._index = json.load(f)
            # the sequence on disk owns its parameters: reopening with
            # different constructor values must not change cadence or
            # jump-detection mid-sequence
            self.keyframe_interval = int(self._index["keyframe_interval"])
            self.exact_jump_thresh = float(self._index.get("exact_jump_thresh", exact_jump_thresh))
        else:
            self.keyframe_interval = keyframe_interval
            self._index = {
                "keyframe_interval": keyframe_interval,
                "exact_jump_thresh": self.exact_jump_thresh,
                "timesteps": [],
            }
        # submit-side view of the sequence (the writer thread lags behind):
        # monotonicity and key-vs-delta cadence are decided at append() time
        self._submitted = len(self._index["timesteps"])
        self._last_t = self._index["timesteps"][-1]["t"] if self._index["timesteps"] else None

        # background writer: created lazily on the first async append
        self._queue: queue.Queue | None = None
        self._writer: threading.Thread | None = None
        self._writer_err: BaseException | None = None
        self._closed = False

        # overlap metrics: host time spent inside append() (what the caller's
        # loop pays) vs. inside the encode+write itself (what was hidden)
        self.append_s = 0.0
        self.write_s = 0.0

        # reconstructed previous frame, kept so deltas chain without drift
        self._recon: dict[str, np.ndarray] | None = None
        if self._index["timesteps"]:
            self._recon = _to_host(self.load(self._index["timesteps"][-1]["t"]))
        # opt-in runtime race sanitizer (REPRO_TSAN=1; no-op otherwise).
        # The listed fields cross the writer-thread boundary ordered by the
        # bounded queue + flush()'s queue.join(), not by a lock — any OTHER
        # field the writer starts touching is a reported race.
        tsan.attach(self, name="TemporalCheckpointStore",
                    ordered=("_recon", "_index", "_writer_err", "write_s"))

    # ------------------------------------------------------------------ write
    def append(self, t: int, params: G.GaussianModel) -> str:
        """Store timestep ``t``; returns the path (to be) written. ``t`` must
        be strictly greater than every stored timestep. With async writes the
        encode+write happens on the writer thread; call ``flush()`` (or any
        read) to wait for durability. (If an earlier background write failed,
        the writer may promote this frame from delta to keyframe — the index
        records the actual kind; the predicted path is best-effort.)"""
        assert not self._closed, "append() after close()"
        self._raise_writer_error()
        assert self._last_t is None or t > self._last_t, (t, self._last_t)
        t0 = time.perf_counter()
        is_key = (self._submitted % self.keyframe_interval == 0) or self._submitted == 0
        self._last_t = t
        self._submitted += 1
        host = _to_host(params)  # must copy out before the caller mutates
        if is_key:
            path = os.path.join(self.directory, f"step_{t:08d}")
        else:
            path = os.path.join(self.directory, f"delta_{t:08d}.npz")
        if self.async_writes:
            if self._writer is None:
                # bounded: each entry is a full host copy of the params, so a
                # writer slower than training must backpressure append() here
                # rather than grow the queue (and host memory) without limit
                self._queue = queue.Queue(maxsize=2)  # analysis: allow(locks.thread_shared_write, written before Thread.start(); thread-start happens-before publishes it to the writer)
                self._writer = threading.Thread(
                    target=self._writer_loop, name="temporal-store-writer", daemon=True
                )
                self._writer.start()
            self._queue.put((t, host, is_key))
        else:
            self._write(t, host, is_key)
        self.append_s += time.perf_counter() - t0
        return path

    def _writer_loop(self) -> None:
        while True:
            item = self._queue.get()
            if item is None:
                self._queue.task_done()
                return
            try:
                # keep writing after a failure: _recon and the index reflect
                # only successful writes, so later frames stay self-consistent
                # (deltas chain against the last *stored* frame) — only the
                # failed timestep is lost, and flush()/append() report it
                self._write(*item)
            except BaseException as e:  # analysis: allow(hygiene.broad_except, writer must survive any failure to keep draining; first error is surfaced on the next append/flush)
                if self._writer_err is None:  # first failure wins
                    self._writer_err = (item[0], e)  # analysis: allow(locks.thread_shared_write, single-writer field; readers are ordered behind it by queue.join() in flush())
            finally:
                self._queue.task_done()

    def _write(self, t: int, host: dict[str, np.ndarray], is_key: bool) -> None:
        """Encode + persist one timestep (writer thread in async mode)."""
        t0 = time.perf_counter()
        ts = self._index["timesteps"]
        if self._recon is None:
            # no reconstruction base (e.g. the sequence's first keyframe
            # failed to write): a delta is impossible — promote to keyframe
            is_key = True
        if is_key:
            save_checkpoint(self.directory, t, G.GaussianModel(**host))
            ts.append({"t": t, "kind": "key"})
            self._recon = host
        else:
            payload, recon = {}, {}
            for name, x in host.items():
                diff = x - self._recon[name]
                # rows with a discontinuous jump (reseeded dead slots leaving
                # the 1e6 sentinel) are stored exactly and kept out of the
                # quantization scale, which stays tight for the smooth rows
                row_max = np.abs(diff.reshape(diff.shape[0], -1)).max(axis=1)
                jump = np.nonzero(row_max > self.exact_jump_thresh)[0]
                smooth_max = float(np.delete(row_max, jump).max()) if jump.size < row_max.size else 0.0
                scale = smooth_max / _QMAX or 1.0
                q = np.clip(np.round(diff / scale), -_QMAX, _QMAX).astype(np.int16)
                q[jump] = 0
                r = self._recon[name] + q.astype(np.float32) * scale
                r[jump] = x[jump]
                payload[name] = q
                payload[name + "__scale"] = np.float32(scale)
                payload[name + "__jump_idx"] = jump.astype(np.int32)
                payload[name + "__jump_val"] = x[jump].astype(np.float32)
                recon[name] = r
            np.savez_compressed(os.path.join(self.directory, f"delta_{t:08d}.npz"), **payload)
            ts.append({"t": t, "kind": "delta"})
            self._recon = recon
        with open(self._index_path, "w") as f:
            json.dump(self._index, f, indent=1)
        self.write_s += time.perf_counter() - t0  # analysis: allow(locks.thread_shared_write, written only by the writer thread (or sync path); stats() readers are ordered behind flush()'s queue.join())

    # ------------------------------------------------------------- lifecycle
    def _raise_writer_error(self) -> None:
        if self._writer_err is not None:
            (t, err), self._writer_err = self._writer_err, None
            raise RuntimeError(
                f"temporal store background write failed for timestep {t}; "
                "that timestep is NOT on disk (later appends are unaffected — "
                "deltas chain against the last successfully stored frame)"
            ) from err

    def flush(self) -> None:
        """Block until every queued append is durable on disk."""
        if self._queue is not None:
            self._queue.join()
        self._raise_writer_error()

    def close(self) -> None:
        """Flush pending writes and stop the writer thread. Idempotent."""
        if self._closed:
            return
        if self._writer is not None:
            self._queue.join()
            self._queue.put(None)  # sentinel: writer exits after draining
            self._writer.join()
            self._writer = None
        self._closed = True
        self._raise_writer_error()

    def __enter__(self) -> "TemporalCheckpointStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------- read
    def timesteps(self) -> list[int]:
        self.flush()
        return [e["t"] for e in self._index["timesteps"]]

    def _entry(self, t: int) -> int:
        for i, e in enumerate(self._index["timesteps"]):
            if e["t"] == t:
                return i
        raise KeyError(f"timestep {t} not in store (have {self.timesteps()})")

    def _load_key(self, t: int) -> dict[str, np.ndarray]:
        man = json.load(open(os.path.join(self.directory, f"step_{t:08d}", "manifest.json")))
        shapes = {f: man["leaves"][f]["shape"] for f in G.GaussianModel._fields}
        like = G.GaussianModel(**{f: np.zeros(shapes[f], np.float32) for f in G.GaussianModel._fields})
        return _to_host(restore_checkpoint(self.directory, t, like))

    def load(self, t: int) -> G.GaussianModel:
        """Reconstruct timestep ``t``: nearest keyframe <= t, then deltas."""
        self.flush()
        i = self._entry(t)
        entries = self._index["timesteps"]
        k = i
        while entries[k]["kind"] != "key":
            k -= 1
        frame = self._load_key(entries[k]["t"])
        for e in entries[k + 1 : i + 1]:
            with np.load(os.path.join(self.directory, f"delta_{e['t']:08d}.npz")) as z:
                for name in G.GaussianModel._fields:
                    x = frame[name] + z[name].astype(np.float32) * float(z[name + "__scale"])
                    jump = z[name + "__jump_idx"]
                    if jump.size:
                        x[jump] = z[name + "__jump_val"]
                    frame[name] = x
        return G.GaussianModel(**frame)

    def changed_slots(self, t: int) -> np.ndarray | None:
        """Gaussian slots timestep ``t`` changed relative to ``t-1``, straight
        from the stored delta encoding (no params diff): the union over leaves
        of rows with a nonzero quantized delta plus the sparse exact-jump rows
        (reseeded slots). Returns ``None`` for keyframes — a keyframe carries
        no delta, so the change set is unknown and callers must assume
        everything (exactly what ``RenderServer.add_timestep`` without
        ``changed=`` does). Post hoc replay uses this to drive world-space
        invalidation with zero trainer involvement.
        """
        self.flush()
        i = self._entry(int(t))
        e = self._index["timesteps"][i]
        if e["kind"] == "key":
            return None
        rows: set[int] = set()
        with np.load(os.path.join(self.directory, f"delta_{e['t']:08d}.npz")) as z:
            for name in G.GaussianModel._fields:
                q = z[name]
                nz = np.nonzero(q.reshape(q.shape[0], -1).any(axis=1))[0]
                rows.update(int(r) for r in nz)
                rows.update(int(r) for r in z[name + "__jump_idx"])
        return np.asarray(sorted(rows), np.int64)

    # ---------------------------------------------------------------- metrics
    def stats(self) -> dict:
        """On-disk footprint: delta frames vs keyframes (the compression win).
        Flushes first, so the numbers cover every append."""
        self.flush()
        key_b, delta_b, n_key, n_delta = 0, 0, 0, 0
        for e in self._index["timesteps"]:
            if e["kind"] == "key":
                d = os.path.join(self.directory, f"step_{e['t']:08d}")
                key_b += sum(os.path.getsize(os.path.join(d, f)) for f in os.listdir(d))
                n_key += 1
            else:
                delta_b += os.path.getsize(os.path.join(self.directory, f"delta_{e['t']:08d}.npz"))
                n_delta += 1
        return {
            "timesteps": len(self._index["timesteps"]),
            "keyframes": n_key,
            "delta_frames": n_delta,
            "keyframe_bytes": key_b,
            "delta_bytes": delta_b,
            "mean_key_bytes": key_b // max(n_key, 1),
            "mean_delta_bytes": delta_b // max(n_delta, 1),
            "delta_compression": (
                round((key_b / n_key) / (delta_b / n_delta), 2) if n_key and delta_b else None
            ),
            "async_writes": self.async_writes,
            "append_wall_s": round(self.append_s, 4),
            "write_s": round(self.write_s, 4),
        }
