"""Temporal checkpoint store: keyframes + quantized delta frames.

A streamed sequence multiplies checkpoint cost by T: an 18M-Gaussian model is
~1 GB of float32 per timestep, so storing every timestep verbatim is exactly
the volume-dump I/O burden in-situ reconstruction exists to avoid. But
consecutive warm-started models differ by a few optimization steps, so the
parameter *delta* is tiny and narrow — ideal for quantization.

Layout (on top of ``repro.checkpoint.store``):

  <dir>/sequence.json            ordered timestep index (kind, base, files)
  <dir>/step_<t>/...             keyframes — the standard checkpoint layout,
                                 restorable by ``restore_checkpoint`` alone
  <dir>/delta_<t>.npz            per-leaf int16-quantized (x_t - x_recon_{t-1})
                                 plus per-leaf scales and sparse exact rows

Deltas chain against the *reconstructed* previous frame (not the exact one),
so quantization error never accumulates along the chain: every frame is within
half a quantum of its true value regardless of distance from the keyframe.

Not every per-Gaussian delta is small: dead-slot reseeding moves a padding
row's mean from the 1e6 sentinel into the scene — a jump six orders of
magnitude above the training deltas, which would poison a shared
max-abs-based quantization scale for the whole leaf. Rows whose delta exceeds
``exact_jump_thresh`` are therefore stored *exactly* (sparse float32 indices
+ values) and excluded from the scale; the remaining rows quantize against a
tight scale. ``load(t)`` restores the nearest keyframe at or before t and
replays deltas (quantized part, then exact-row overwrite).
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.checkpoint.store import _leaf_to_host, restore_checkpoint, save_checkpoint
from repro.core import gaussians as G

_QMAX = 32767  # int16 symmetric range


def _to_host(params: G.GaussianModel) -> dict[str, np.ndarray]:
    """Shard-wise host pull (same rationale as checkpoint save: no second
    fully-replicated copy of a model-sharded leaf)."""
    return {
        f: np.asarray(_leaf_to_host(getattr(params, f)), np.float32)
        for f in G.GaussianModel._fields
    }


class TemporalCheckpointStore:
    """Append-only per-timestep store of ``GaussianModel`` params."""

    def __init__(self, directory: str, *, keyframe_interval: int = 4, exact_jump_thresh: float = 1.0):
        assert keyframe_interval >= 1
        self.directory = directory
        self.exact_jump_thresh = float(exact_jump_thresh)
        os.makedirs(directory, exist_ok=True)
        self._index_path = os.path.join(directory, "sequence.json")
        if os.path.exists(self._index_path):
            with open(self._index_path) as f:
                self._index = json.load(f)
            # the sequence on disk owns its parameters: reopening with
            # different constructor values must not change cadence or
            # jump-detection mid-sequence
            self.keyframe_interval = int(self._index["keyframe_interval"])
            self.exact_jump_thresh = float(self._index.get("exact_jump_thresh", exact_jump_thresh))
        else:
            self.keyframe_interval = keyframe_interval
            self._index = {
                "keyframe_interval": keyframe_interval,
                "exact_jump_thresh": self.exact_jump_thresh,
                "timesteps": [],
            }
        # reconstructed previous frame, kept so deltas chain without drift
        self._recon: dict[str, np.ndarray] | None = None
        if self._index["timesteps"]:
            self._recon = _to_host(self.load(self._index["timesteps"][-1]["t"]))

    # ------------------------------------------------------------------ write
    def append(self, t: int, params: G.GaussianModel) -> str:
        """Store timestep ``t``; returns the path written. ``t`` must be
        strictly greater than every stored timestep."""
        ts = self._index["timesteps"]
        assert not ts or t > ts[-1]["t"], (t, ts[-1]["t"] if ts else None)
        host = _to_host(params)
        is_key = (len(ts) % self.keyframe_interval == 0) or self._recon is None
        if is_key:
            path = save_checkpoint(self.directory, t, G.GaussianModel(**host))
            ts.append({"t": t, "kind": "key"})
            self._recon = host
        else:
            path = os.path.join(self.directory, f"delta_{t:08d}.npz")
            payload, recon = {}, {}
            for name, x in host.items():
                diff = x - self._recon[name]
                # rows with a discontinuous jump (reseeded dead slots leaving
                # the 1e6 sentinel) are stored exactly and kept out of the
                # quantization scale, which stays tight for the smooth rows
                row_max = np.abs(diff.reshape(diff.shape[0], -1)).max(axis=1)
                jump = np.nonzero(row_max > self.exact_jump_thresh)[0]
                smooth_max = float(np.delete(row_max, jump).max()) if jump.size < row_max.size else 0.0
                scale = smooth_max / _QMAX or 1.0
                q = np.clip(np.round(diff / scale), -_QMAX, _QMAX).astype(np.int16)
                q[jump] = 0
                r = self._recon[name] + q.astype(np.float32) * scale
                r[jump] = x[jump]
                payload[name] = q
                payload[name + "__scale"] = np.float32(scale)
                payload[name + "__jump_idx"] = jump.astype(np.int32)
                payload[name + "__jump_val"] = x[jump].astype(np.float32)
                recon[name] = r
            np.savez_compressed(path, **payload)
            ts.append({"t": t, "kind": "delta"})
            self._recon = recon
        with open(self._index_path, "w") as f:
            json.dump(self._index, f, indent=1)
        return path

    # ------------------------------------------------------------------- read
    def timesteps(self) -> list[int]:
        return [e["t"] for e in self._index["timesteps"]]

    def _entry(self, t: int) -> int:
        for i, e in enumerate(self._index["timesteps"]):
            if e["t"] == t:
                return i
        raise KeyError(f"timestep {t} not in store (have {self.timesteps()})")

    def _load_key(self, t: int) -> dict[str, np.ndarray]:
        man = json.load(open(os.path.join(self.directory, f"step_{t:08d}", "manifest.json")))
        shapes = {f: man["leaves"][f]["shape"] for f in G.GaussianModel._fields}
        like = G.GaussianModel(**{f: np.zeros(shapes[f], np.float32) for f in G.GaussianModel._fields})
        return _to_host(restore_checkpoint(self.directory, t, like))

    def load(self, t: int) -> G.GaussianModel:
        """Reconstruct timestep ``t``: nearest keyframe <= t, then deltas."""
        i = self._entry(t)
        entries = self._index["timesteps"]
        k = i
        while entries[k]["kind"] != "key":
            k -= 1
        frame = self._load_key(entries[k]["t"])
        for e in entries[k + 1 : i + 1]:
            with np.load(os.path.join(self.directory, f"delta_{e['t']:08d}.npz")) as z:
                for name in G.GaussianModel._fields:
                    x = frame[name] + z[name].astype(np.float32) * float(z[name + "__scale"])
                    jump = z[name + "__jump_idx"]
                    if jump.size:
                        x[jump] = z[name + "__jump_val"]
                    frame[name] = x
        return G.GaussianModel(**frame)

    # ---------------------------------------------------------------- metrics
    def stats(self) -> dict:
        """On-disk footprint: delta frames vs keyframes (the compression win)."""
        key_b, delta_b, n_key, n_delta = 0, 0, 0, 0
        for e in self._index["timesteps"]:
            if e["kind"] == "key":
                d = os.path.join(self.directory, f"step_{e['t']:08d}")
                key_b += sum(os.path.getsize(os.path.join(d, f)) for f in os.listdir(d))
                n_key += 1
            else:
                delta_b += os.path.getsize(os.path.join(self.directory, f"delta_{e['t']:08d}.npz"))
                n_delta += 1
        return {
            "timesteps": len(self._index["timesteps"]),
            "keyframes": n_key,
            "delta_frames": n_delta,
            "keyframe_bytes": key_b,
            "delta_bytes": delta_b,
            "mean_key_bytes": key_b // max(n_key, 1),
            "mean_delta_bytes": delta_b // max(n_delta, 1),
            "delta_compression": (
                round((key_b / n_key) / (delta_b / n_delta), 2) if n_key and delta_b else None
            ),
        }
