"""Warm-start incremental trainer for streaming time-varying volumes.

The static pipeline (``repro.launch.train``) pays two costs per volume that a
stream cannot afford: a from-scratch optimization and — via densification's
shape changes — repeated jit traces. This trainer fixes both:

  * **Fixed padded capacity.** The Gaussian count is padded once, at the
    first timestep, to ``capacity`` (a shard-aligned multiple of
    ``n_shards * cfg.pad_quantum``). Every subsequent timestep reuses the
    same shapes, so the jitted train step is traced exactly once for the
    whole sequence (``n_traces`` tracks this via the jit cache size).

  * **Warm start + dead-slot reseeding.** Params *and* Adam moments carry
    over from timestep t to t+1; only ``warm_steps`` delta-optimization
    steps run (vs ``cold_steps`` at t=0). Instead of densification, dead
    slots (padding + pruned-to-transparent Gaussians) are re-seeded from the
    new timestep's isosurface extraction — a shape-preserving stand-in for
    adaptive density control that lets the model follow surface regions that
    appear over time.
"""
from __future__ import annotations

import dataclasses
import zlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gaussians as G
from repro.core.config import GSConfig
from repro.core.densify import DEAD_LOGIT
from repro.core.losses import psnr
from repro.core.train import (
    GSTrainState,
    all_gather_bytes_per_step,
    init_state,
    make_eval_render,
    make_train_step,
    record_shard_balance,
    shard_balance,
    state_shardings,
)
from repro.obs import Obs, devmem, new_request_id
from repro.obs.clock import now, since
from repro.data.views import ViewDataset
from repro.volume.datasets import VolumeSpec
from repro.volume.isosurface import extract_isosurface_points


@dataclasses.dataclass
class TimestepReport:
    """What happened while absorbing one stream timestep."""

    t_index: int
    name: str
    mode: str                 # "cold" | "warm"
    steps: int
    n_extracted: int          # isosurface points pulled from this timestep
    n_reseeded: int           # dead slots re-seeded from them
    psnr_before: float        # eval view, before this timestep's training
    psnr_after: float
    loss_final: float
    wall_s: float             # extraction + GT render + train + eval
    train_s: float            # optimization only
    n_traces: int             # cumulative train-step jit traces (must stay 1)
    psnr_curve: list = dataclasses.field(default_factory=list)  # [(step, psnr)]
    # Gaussian slots this timestep rewrote (reseeded + optimizer-moved rows),
    # diffed host-side against the previous timestep's params. None means
    # unknown/everything (cold start) — exactly what a serving tier should
    # assume. Feeds RenderServer.add_timestep(..., changed=...) so the
    # trainer->server handoff needs no caller-side row math.
    changed_slots: list | None = None


def fixed_capacity_init(
    points: np.ndarray,
    colors: np.ndarray,
    capacity: int,
    *,
    sh_degree: int = 0,
    init_scale: float = 0.05,
) -> G.GaussianModel:
    """Init a model at exactly ``capacity`` slots; extra slots are dead."""
    n0 = points.shape[0]
    assert n0 <= capacity, (n0, capacity)
    pad = capacity - n0
    pts = np.concatenate([np.asarray(points, np.float32), np.full((pad, 3), 1e6, np.float32)])
    cols = np.concatenate([np.asarray(colors, np.float32), np.zeros((pad, 3), np.float32)])
    g = G.init_from_points(jnp.asarray(pts), jnp.asarray(cols), sh_degree=sh_degree, init_scale=init_scale)
    return g._replace(opacity_logit=g.opacity_logit.at[n0:].set(DEAD_LOGIT))


def reseed_dead_slots(
    state: GSTrainState,
    points: np.ndarray,
    colors: np.ndarray,
    *,
    init_scale: float = 0.05,
    init_opacity: float = 0.1,
    opacity_thresh: float = 0.005,
    max_fraction: float = 1.0,
    rng: np.random.Generator | None = None,
) -> tuple[GSTrainState, int, np.ndarray]:
    """Re-seed dead capacity from a fresh isosurface extraction (host-side).

    Dead = opacity below ``opacity_thresh`` (covers both padding at
    ``DEAD_LOGIT`` and Gaussians the optimizer pruned to transparency). Up to
    ``max_fraction`` of the dead slots are refilled with randomly sampled new
    surface points; their Adam moments and densify stats are zeroed so the
    optimizer treats them as newborn. Shapes are untouched — the caller's
    jitted train step keeps its trace. Returns ``(state, n_fill, slots)``
    where ``slots`` are the refilled row indices (empty when nothing was
    reseeded) — the world-space invalidation path wants them without
    re-diffing the params.
    """
    rng = rng or np.random.default_rng(0)
    p = jax.tree_util.tree_map(np.asarray, state.params)
    opac = 1.0 / (1.0 + np.exp(-np.clip(p.opacity_logit, -60, 60)))
    dead = np.nonzero(opac < opacity_thresh)[0]
    points = np.asarray(points, np.float32)
    colors = np.asarray(colors, np.float32)
    n_fill = min(int(len(dead) * max_fraction), points.shape[0])
    if n_fill == 0:
        return state, 0, np.zeros(0, np.int64)
    slots = dead[rng.choice(len(dead), n_fill, replace=False)] if n_fill < len(dead) else dead
    pick = rng.choice(points.shape[0], n_fill, replace=False)

    seed = fixed_capacity_init(points[pick], colors[pick], n_fill, sh_degree=p.sh_degree, init_scale=init_scale)
    seed = seed._replace(
        opacity_logit=jnp.full((n_fill,), float(np.log(init_opacity / (1 - init_opacity))), jnp.float32)
    )
    seed = jax.tree_util.tree_map(np.asarray, seed)

    new_params = G.GaussianModel(*[a.copy() for a in p])
    for field in G.GaussianModel._fields:
        getattr(new_params, field)[slots] = getattr(seed, field)

    def zero_rows(tree):
        out = jax.tree_util.tree_map(lambda a: np.asarray(a).copy(), tree)
        for leaf in out:
            leaf[slots] = 0.0
        return out

    m = zero_rows(state.adam.m)
    v = zero_rows(state.adam.v)
    stats = []
    for s in (state.grad2d_accum, state.vis_count, state.max_radii):
        a = np.asarray(s).copy()
        a[slots] = 0.0
        stats.append(a)

    new_state = GSTrainState(
        params=G.GaussianModel(*[jnp.asarray(a) for a in new_params]),
        adam=state.adam._replace(
            m=G.GaussianModel(*[jnp.asarray(a) for a in m]),
            v=G.GaussianModel(*[jnp.asarray(a) for a in v]),
        ),
        step=state.step,
        grad2d_accum=jnp.asarray(stats[0]),
        vis_count=jnp.asarray(stats[1]),
        max_radii=jnp.asarray(stats[2]),
    )
    return new_state, n_fill, np.sort(np.asarray(slots, np.int64))


class InsituTrainer:
    """Tracks an evolving isosurface with one fixed-shape Gaussian model.

    ``start(vol)`` cold-starts on the first timestep; ``advance(vol)``
    warm-starts every following one; ``run(stream)`` drives a whole
    ``VolumeStream`` (optionally appending params to a
    ``TemporalCheckpointStore`` after each timestep).
    """

    def __init__(
        self,
        cfg: GSConfig,
        mesh,
        *,
        capacity: int | None = None,
        capacity_factor: float = 1.5,
        cold_steps: int = 200,
        warm_steps: int = 40,
        n_views: int = 8,
        radius: float = 3.0,
        max_points: int | None = 4000,
        n_steps_raymarch: int = 64,
        init_scale: float = 0.05,
        eval_view: int = 0,
        eval_every: int = 0,
        seed: int = 0,
        verbose: bool = False,
        obs: Obs | None = None,
    ):
        self.cfg = cfg
        self.mesh = mesh
        self.n_shards = mesh.shape["model"]
        self.capacity = capacity
        self.capacity_factor = capacity_factor
        self.cold_steps = cold_steps
        self.warm_steps = warm_steps
        self.n_views = n_views
        self.radius = radius
        self.max_points = max_points
        self.n_steps_raymarch = n_steps_raymarch
        self.init_scale = init_scale
        self.eval_view = eval_view
        self.eval_every = eval_every
        self.rng = np.random.default_rng(seed)
        self.verbose = verbose
        # the observability bundle this trainer reports through: share one
        # with a serving stack (run(server=...)) and training spans land on
        # the same clock/ring as the request spans; standalone trainers get
        # a private bundle so instrumentation never needs a None check
        self.obs = obs if obs is not None else Obs()

        self.state: GSTrainState | None = None
        self.t_index = 0
        self.reports: list[TimestepReport] = []
        self._step_fn = None
        self._eval_fn = None
        self._rid = 0  # request id of the timestep currently being absorbed

    # ------------------------------------------------------------- plumbing
    @property
    def n_traces(self) -> int:
        """Jit-trace count of the train step (the recompile counter)."""
        if self._step_fn is None:
            return 0
        try:
            return int(self._step_fn._cache_size())
        except (AttributeError, TypeError):  # pragma: no cover - cache introspection API drift
            return -1

    def _dataset(self, vol: VolumeSpec) -> ViewDataset:
        # view-sampling seed derived from the timestep content, not from this
        # trainer's rng position: a warm pipeline and a cold baseline handed
        # the same timestep then draw identical batch orders (fair
        # steps-to-target comparisons in benchmarks/insitu_throughput.py)
        return ViewDataset(
            vol,
            n_views=self.n_views,
            img_h=self.cfg.img_h,
            img_w=self.cfg.img_w,
            radius=self.radius,
            cache_dir=None,
            n_steps_raymarch=self.n_steps_raymarch,
            seed=zlib.crc32(vol.name.encode()) & 0x7FFFFFFF,
        )

    def _eval_psnr(self, data: ViewDataset) -> float:
        rec = self.obs.trace
        t0 = now() if rec else 0.0
        cam, gt = data.view(self.eval_view % self.n_views)
        img, _ = self._eval_fn(self.state.params, cam)
        p = float(psnr(img, gt))
        if rec:
            rec.record(self._rid, "eval", t0, now(), psnr=round(p, 3))
        self.obs.metrics.gauge("train.psnr").set(round(p, 4))
        return p

    def _fit(self, data: ViewDataset, steps: int, *, psnr0: float) -> tuple[float, list]:
        """The optimization loop of one timestep, instrumented per step:
        ``batch`` (host view assembly) -> ``dispatch`` (jitted call returns
        under async dispatch) -> ``device`` (bounded by block_until_ready,
        traced runs only — an untraced run keeps jax's dispatch overlap and
        the step stays bitwise identical either way). Wall per step always
        lands in the ``train.step_ms`` histogram; device seconds land in
        ``train.device_ms`` when tracing bounds them."""
        m = self.obs.metrics
        step_ms = m.histogram("train.step_ms")
        device_ms = m.histogram("train.device_ms")
        loss_gauge = m.gauge("train.loss")
        steps_total = m.counter("train.steps")
        curve = []
        loss = float("nan")
        if self.eval_every > 0:
            curve.append((0, psnr0))  # already measured by the caller
        rid = self._rid
        t_iter = now()
        for i, (cams, gt) in enumerate(data.batches(self.cfg.batch_size, steps=steps)):
            rec = self.obs.trace  # re-read: tracing may toggle mid-fit
            t_batch = now()
            if rec:
                rec.record(rid, "batch", t_iter, t_batch, step=i)
            self.state, metrics = self._step_fn(self.state, cams, gt)
            if rec:
                t_disp = now()
                rec.record(rid, "dispatch", t_batch, t_disp, step=i)
                jax.block_until_ready(self.state)
                t_dev = now()
                rec.record(rid, "device", t_disp, t_dev, step=i)
                device_ms.observe((t_dev - t_disp) * 1e3)
            loss = float(metrics["loss"])  # blocks on the step either way
            loss_gauge.set(loss)
            steps_total.inc()
            step_ms.observe(since(t_batch) * 1e3)
            if self.eval_every > 0 and (i + 1) % self.eval_every == 0:
                curve.append((i + 1, self._eval_psnr(data)))
            t_iter = now()
        return loss, curve

    def reset(self) -> None:
        """Forget the model but keep the jitted fns: the next ``start()`` at
        the same capacity is compile-free. Lets warm-vs-cold baselines
        (``benchmarks/insitu_throughput.py``) cold-start many timesteps
        without re-tracing identical shapes."""
        self.state = None
        self.t_index = 0
        self.reports = []

    def shard_balance(self, *, record: bool = True) -> dict:
        """Per-model-shard load stats of the current state (see
        :func:`repro.core.train.shard_balance`); lands them on the registry
        (``train.shard_*`` gauges) unless ``record=False``."""
        assert self.state is not None, "no model yet"
        bal = shard_balance(self.state, opacity_thresh=self.cfg.prune_opacity_thresh)
        if record:
            record_shard_balance(self.obs.metrics, bal)
        return bal

    # ------------------------------------------------------------ timesteps
    def start(self, vol: VolumeSpec, *, steps: int | None = None) -> TimestepReport:
        assert self.state is None, "start() already called; use advance()"
        t0 = now()
        self._rid = new_request_id()
        rec = self.obs.trace
        pts, _, cols = extract_isosurface_points(vol, max_points=self.max_points)
        if rec:
            rec.record(self._rid, "extract", t0, now(), t_index=self.t_index,
                       points=int(pts.shape[0]), vol=vol.name)
        if self.capacity is None:
            quantum = self.n_shards * self.cfg.pad_quantum
            want = int(pts.shape[0] * self.capacity_factor)
            self.capacity = max(-(-want // quantum) * quantum, quantum)
        assert self.capacity % (self.n_shards * self.cfg.pad_quantum) == 0
        if pts.shape[0] > self.capacity:
            keep = self.rng.choice(pts.shape[0], self.capacity, replace=False)
            pts, cols = pts[keep], cols[keep]
        g = fixed_capacity_init(pts, cols, self.capacity, sh_degree=self.cfg.sh_degree, init_scale=self.init_scale)
        self.state = jax.device_put(init_state(g), state_shardings(self.mesh))
        if self._step_fn is None:
            self._step_fn = make_train_step(self.mesh, self.cfg)
            self._eval_fn = make_eval_render(self.mesh, self.cfg)
        return self._absorb(vol, pts, cols, 0, steps or self.cold_steps, "cold", t0)

    def advance(self, vol: VolumeSpec, *, steps: int | None = None) -> TimestepReport:
        assert self.state is not None, "advance() before start()"
        t0 = now()
        self._rid = new_request_id()
        rec = self.obs.trace
        pts, _, cols = extract_isosurface_points(vol, max_points=self.max_points)
        if rec:
            rec.record(self._rid, "extract", t0, now(), t_index=self.t_index,
                       points=int(pts.shape[0]), vol=vol.name)
        # params before reseed+training: the diff baseline for changed_slots
        prev_params = jax.tree_util.tree_map(np.asarray, self.state.params)
        t_rs = now() if rec else 0.0
        self.state, n_reseeded, _ = reseed_dead_slots(
            self.state,
            pts,
            cols,
            init_scale=self.init_scale,
            opacity_thresh=self.cfg.prune_opacity_thresh,
            rng=self.rng,
        )
        self.state = jax.device_put(self.state, state_shardings(self.mesh))
        if rec:
            rec.record(self._rid, "reseed", t_rs, now(), t_index=self.t_index,
                       filled=int(n_reseeded))
        self.obs.metrics.counter("train.reseeded").inc(int(n_reseeded))
        rep = self._absorb(
            vol, pts, cols, n_reseeded, steps or self.warm_steps, "warm", t0,
            prev_params=prev_params,
        )
        return rep

    def _absorb(self, vol, pts, cols, n_reseeded, steps, mode, t0, prev_params=None) -> TimestepReport:
        m = self.obs.metrics
        data = self._dataset(vol)
        p_before = self._eval_psnr(data)
        ttrain = now()
        loss, curve = self._fit(data, steps, psnr0=p_before)
        train_s = since(ttrain)
        rec = self.obs.trace
        if rec:
            rec.record(self._rid, "fit", ttrain, now(), t_index=self.t_index,
                       mode=mode, steps=steps)
        changed = None
        if prev_params is not None:
            # one host-side diff covers reseeded slots AND optimizer-moved
            # rows: everything the serving tier must treat as dirty
            from repro.serve_gs.footprint import changed_indices

            now_params = jax.tree_util.tree_map(np.asarray, self.state.params)
            changed = [int(i) for i in changed_indices(prev_params, now_params)]
        rep = TimestepReport(
            t_index=self.t_index,
            name=vol.name,
            mode=mode,
            steps=steps,
            n_extracted=int(pts.shape[0]),
            n_reseeded=int(n_reseeded),
            psnr_before=p_before,
            psnr_after=self._eval_psnr(data),
            loss_final=loss,
            wall_s=since(t0),
            train_s=train_s,
            n_traces=self.n_traces,
            psnr_curve=curve,
            changed_slots=changed,
        )
        # per-timestep telemetry: shard balance (the rebalancing trigger
        # signal), the step's analytic all-gather payload, and the device
        # memory watermark — Miranda-scale capacity limits show up here
        # timesteps before they OOM
        self.shard_balance()
        m.counter("train.gather_bytes").inc(
            all_gather_bytes_per_step(self.cfg, self.mesh, self.state.params.n) * steps
        )
        m.counter("train.timesteps").inc()
        m.histogram("train.timestep_wall_ms").observe(rep.wall_s * 1e3)
        devmem.record(m)
        self.reports.append(rep)
        self.t_index += 1
        if self.verbose:
            print(
                f"[insitu] t={rep.t_index} {rep.mode:4s} {rep.steps:4d} steps "
                f"PSNR {rep.psnr_before:5.2f}->{rep.psnr_after:5.2f} dB "
                f"reseed {rep.n_reseeded} ({rep.wall_s:.1f}s, traces={rep.n_traces})"
            )
        return rep

    def run(self, stream, *, store=None, server=None, serve_timestep=0) -> list[TimestepReport]:
        """Consume a ``VolumeStream``; optionally append each timestep's
        params to a ``TemporalCheckpointStore`` and/or push each timestep to
        a live ``RenderServer``.

        With the store's default asynchronous writer, ``append`` only pulls
        params to host and enqueues the encode+write — delta quantization and
        compression overlap with the *next* timestep's training instead of
        stalling the stream. The store is flushed before returning, so every
        appended timestep is durable when ``run`` hands back its reports.

        ``server`` wires the live-viewing loop with **no caller-side row
        math**: after each timestep the model is re-registered on the
        server's ``serve_timestep`` timeline slot with this timestep's
        ``changed_slots``, so the server computes per-pose dirty tile rows
        itself from the changed Gaussians' projected bounds (cold start
        passes no ``changed`` and drops everything, which is vacuous on the
        first registration).
        """
        out = []
        for vol in stream:
            rep = self.start(vol) if self.state is None else self.advance(vol)
            out.append(rep)
            rec = self.obs.trace
            if store is not None:
                t0 = now() if rec else 0.0
                store.append(rep.t_index, self.state.params)
                if rec:
                    rec.record(self._rid, "ckpt", t0, now(), t_index=rep.t_index)
            if server is not None:
                t0 = now() if rec else 0.0
                params = jax.tree_util.tree_map(np.asarray, self.state.params)
                if rep.changed_slots is None:
                    server.add_timestep(int(serve_timestep), params)
                else:
                    server.add_timestep(
                        int(serve_timestep), params,
                        changed=np.asarray(rep.changed_slots, np.int64),
                    )
                if rec:
                    rec.record(
                        self._rid, "serve", t0, now(), t_index=rep.t_index,
                        changed=(len(rep.changed_slots)
                                 if rep.changed_slots is not None else -1),
                    )
        if store is not None:
            store.flush()
        return out
