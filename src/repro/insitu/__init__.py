"""Streaming time-varying volume reconstruction (the paper's in-situ goal).

The static pipeline trains one volume from scratch; this subsystem consumes a
*sequence* of evolving timesteps (``repro.volume.timevary``) and keeps one
fixed-capacity Gaussian model tracking the isosurface:

  stream -> extract -> reseed dead slots -> warm-start delta-optimize
         -> temporal checkpoint (keyframe + quantized delta)
         -> time-scrub serving (timeline RenderServer)

See ``repro.launch.insitu`` for the CLI driver and
``benchmarks/insitu_throughput.py`` for the warm-vs-cold methodology.
"""
from repro.insitu.serve import build_timeline_server, replay_live, scrub, timeline_stream
from repro.insitu.store import TemporalCheckpointStore
from repro.insitu.trainer import (
    InsituTrainer,
    TimestepReport,
    fixed_capacity_init,
    reseed_dead_slots,
)

__all__ = [
    "InsituTrainer",
    "TemporalCheckpointStore",
    "TimestepReport",
    "build_timeline_server",
    "fixed_capacity_init",
    "replay_live",
    "reseed_dead_slots",
    "scrub",
    "timeline_stream",
]
