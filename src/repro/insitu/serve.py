"""Time-scrub serving: a temporal checkpoint store -> timeline RenderServer.

Post hoc exploration of a streamed reconstruction is scrubbing: the client
holds a camera and drags a time slider; every (timestep, pose) frame should
be servable at interactive rates and cacheable. This module assembles a
``RenderServer`` whose timeline is the store's timestep sequence — one LOD
pyramid per timestep, all sharing the per-level jitted render fns (a
fixed-capacity insitu run is shape-uniform, so the whole timeline compiles
once per (level, bucket)).
"""
from __future__ import annotations

import numpy as np

from repro.core.config import GSConfig
from repro.core.projection import Camera
from repro.insitu.store import TemporalCheckpointStore
from repro.serve_gs import RenderServer


def build_timeline_server(
    store: TemporalCheckpointStore,
    cfg: GSConfig,
    *,
    timesteps: list[int] | None = None,
    **server_kw,
) -> RenderServer:
    """Load (a subset of) the stored sequence into one timeline server."""
    ts = timesteps if timesteps is not None else store.timesteps()
    assert ts, "temporal store is empty"
    server = RenderServer(store.load(ts[0]), cfg, timestep=ts[0], **server_kw)
    for t in ts[1:]:
        server.add_timestep(t, store.load(t))
    return server


def replay_live(
    store: TemporalCheckpointStore,
    server: RenderServer,
    *,
    timesteps: list[int] | None = None,
    serve_timestep: int = 0,
    on_timestep=None,
):
    """Replay a stored sequence through ONE live timeline slot.

    The post hoc twin of ``InsituTrainer.run(server=...)``: each stored
    timestep re-registers ``serve_timestep`` with the slots the stored delta
    encoding says changed (``store.changed_slots``), so the server's
    world-space invalidation drops only the tiles those Gaussians can touch
    under each cached pose — no caller row math. Keyframes (unknown change
    set) fall back to a full drop. ``on_timestep(t)`` runs after each
    registration (e.g. to submit viewer requests between updates).
    """
    ts = timesteps if timesteps is not None else store.timesteps()
    assert ts, "temporal store is empty"
    for t in ts:
        params = store.load(t)
        slots = store.changed_slots(t)
        if slots is None or int(serve_timestep) not in server.timesteps():
            server.add_timestep(int(serve_timestep), params)
        else:
            server.add_timestep(int(serve_timestep), params, changed=slots)
        if on_timestep is not None:
            on_timestep(t)


def timeline_stream(manager, stream_id: str, store: TemporalCheckpointStore, *, timesteps=None):
    """Expose a stored insitu sequence as a scrubbable network stream.

    The frontend-facing twin of :func:`build_timeline_server`: instead of a
    private server, the sequence is registered on a shared
    ``repro.frontend.SessionManager`` pool under ``stream_id`` — remote
    clients then scrub it with ``scrub`` messages while other streams
    (static scenes, other runs) share the same device pool, micro-batcher,
    and frame cache. Returns the registered ``StreamInfo``."""
    return manager.register_timeline(stream_id, store, timesteps=timesteps)


def scrub(server: RenderServer, cam: Camera, timesteps: list[int]) -> dict[int, np.ndarray]:
    """Request the same camera across ``timesteps``; returns t -> frame.

    The playback primitive: a client dragging the time slider at a fixed
    viewpoint. Frames come back per-timestep distinct and individually
    cached (a second scrub over the same range is all cache hits). Frames are
    delivered through each request's ``FrameFuture`` — no reliance on the
    server's retirement buffer, so this works on servers built with
    ``store_frames=False`` (the production configuration). ``run`` drains the
    whole scrub through the pipelined dispatcher before the futures are read,
    so awaiting them never blocks.
    """
    futures = {t: server.submit(cam, timestep=t) for t in timesteps}
    server.run()
    return {t: fut.result() for t, fut in futures.items()}
