from repro.optim.adam import AdamState, adam_init, adam_update
from repro.optim.schedules import expon_lr, grendel_lr_scale

__all__ = ["AdamState", "adam_init", "adam_update", "expon_lr", "grendel_lr_scale"]
