"""LR schedules: 3D-GS exponential position-LR decay + Grendel batch scaling."""
from __future__ import annotations

import math

import jax.numpy as jnp


def expon_lr(step, *, lr_init: float, lr_final: float, max_steps: int, delay_mult: float = 1.0):
    """3D-GS exponential decay schedule for the position learning rate."""
    t = jnp.clip(step / max_steps, 0.0, 1.0)
    log_lerp = jnp.exp(jnp.log(lr_init) * (1 - t) + jnp.log(lr_final) * t)
    return delay_mult * log_lerp


def grendel_lr_scale(batch_size: int) -> float:
    """Grendel-GS "independent gradients" sqrt LR scaling for batched views.

    Zhao et al. (ECCV'24) show per-view gradients on disjoint pixels are
    near-independent, so LR scales with sqrt(batch) rather than linearly.
    """
    return math.sqrt(float(batch_size))
