"""Adam with per-leaf learning rates (3D-GS trains each field at its own LR).

State lives with the parameter shard: when params are sharded over the
"model" mesh axis, moments are too — ZeRO-style optimizer sharding for free,
which is exactly how Grendel-GS keeps its memory advantage.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    m: object   # pytree like params
    v: object   # pytree like params
    count: jax.Array  # () int32


def adam_init(params) -> AdamState:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return AdamState(zeros, jax.tree_util.tree_map(jnp.zeros_like, params), jnp.zeros((), jnp.int32))


def adam_update(
    grads,
    state: AdamState,
    params,
    lr_tree,
    *,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-15,
):
    """One Adam step. ``lr_tree`` is a pytree of scalars matching params
    (or a single scalar broadcast to all leaves)."""
    count = state.count + 1
    c = count.astype(jnp.float32)
    bc1 = 1.0 - b1**c
    bc2 = 1.0 - b2**c

    if not isinstance(lr_tree, (dict, tuple, list)) and not hasattr(lr_tree, "_fields"):
        lr_tree = jax.tree_util.tree_map(lambda _: lr_tree, params)

    def upd(g, m, v, p, lr):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        return m, v, p - lr * mhat / (jnp.sqrt(vhat) + eps)

    out = jax.tree_util.tree_map(upd, grads, state.m, state.v, params, lr_tree)
    leaves, treedef = jax.tree_util.tree_flatten(out, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3)
    m = jax.tree_util.tree_unflatten(treedef, [l[0] for l in leaves])
    v = jax.tree_util.tree_unflatten(treedef, [l[1] for l in leaves])
    new_params = jax.tree_util.tree_unflatten(treedef, [l[2] for l in leaves])
    return new_params, AdamState(m, v, count)
