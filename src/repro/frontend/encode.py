"""Off-loop frame encoding: raw RGB8 and zlib-compressed temporal deltas.

Rendered frames leave the serving engine as read-only float32 HxWx3 arrays in
[0, 1]. Shipping those over TCP would cost 12 bytes/pixel; the gateway instead
quantizes to RGB8 (4x smaller, visually lossless for display) and — because a
viewer's consecutive frames are usually near-identical (orbit playback, time
scrubbing at a fixed pose, cache hits) — optionally sends the *uint8
difference vs the last frame it sent on that stream*, zlib-compressed. The
difference wraps modulo 256, so decode is exact: ``cur = last + delta (mod
256)`` reproduces the quantized frame bit-for-bit; a static view compresses
to almost nothing.

Encoder and decoder are tiny mirrored state machines keyed by stream id:
both sides update ``last`` to the decoded frame after every ``frame``
message, and TCP ordering keeps them in lockstep. The first frame on a
stream (or any resolution change) is always a raw keyframe. All of this is
pure host work — the gateway runs it on an executor thread, never on the
event loop (that is the "off-loop" in the module name).
"""
from __future__ import annotations

import zlib

import numpy as np

RAW8 = "rgb8"       # payload = uint8 HxWx3, row-major
ZDELTA8 = "zdelta8"  # payload = zlib(uint8 wraparound diff vs last frame)


def quantize_rgb8(frame: np.ndarray) -> np.ndarray:
    """Float [0,1] HxWx3 -> contiguous uint8 (the on-wire pixel format)."""
    f = np.asarray(frame)
    if f.dtype == np.uint8:
        return np.ascontiguousarray(f)
    return np.ascontiguousarray(
        (np.clip(f, 0.0, 1.0) * 255.0 + 0.5).astype(np.uint8)
    )


class FrameEncoder:
    """Per-connection encoder; independent delta chain per stream id."""

    def __init__(self, *, delta: bool = True, zlevel: int = 1):
        self.delta = delta
        self.zlevel = zlevel
        self._last: dict[str, np.ndarray] = {}
        self.raw_frames = 0
        self.delta_frames = 0
        self.bytes_raw = 0      # what raw-only would have cost
        self.bytes_sent = 0

    def encode(self, stream: str, frame: np.ndarray) -> tuple[dict, bytes]:
        """Returns (meta fields for the frame header, payload bytes)."""
        q = quantize_rgb8(frame)
        meta = {"shape": list(q.shape)}
        last = self._last.get(stream)
        if self.delta and last is not None and last.shape == q.shape:
            diff = q - last  # uint8 arithmetic wraps mod 256: exact on decode
            payload = zlib.compress(diff.tobytes(), self.zlevel)
            meta["encoding"] = ZDELTA8
            self.delta_frames += 1
        else:
            payload = q.tobytes()
            meta["encoding"] = RAW8
            self.raw_frames += 1
        self._last[stream] = q
        self.bytes_raw += q.nbytes
        self.bytes_sent += len(payload)
        return meta, payload

    def reset(self, stream: str | None = None) -> None:
        """Drop delta state (one stream, or all): next frame is a keyframe."""
        if stream is None:
            self._last.clear()
        else:
            self._last.pop(stream, None)

    def stats(self) -> dict:
        return {
            "delta": self.delta,
            "raw_frames": self.raw_frames,
            "delta_frames": self.delta_frames,
            "bytes_sent": self.bytes_sent,
            "bytes_raw_equiv": self.bytes_raw,
            "compression": round(self.bytes_raw / self.bytes_sent, 3)
            if self.bytes_sent
            else None,
        }


class FrameDecoder:
    """Mirror of :class:`FrameEncoder`; lives in the client."""

    def __init__(self):
        self._last: dict[str, np.ndarray] = {}

    def decode(self, stream: str, meta: dict, payload: bytes) -> np.ndarray:
        """Returns the frame as a READ-ONLY uint8 array (the same contract
        as the server's copy-on-write cache frames, and uniform across the
        raw and delta paths — mutate a ``.copy()``)."""
        shape = tuple(int(s) for s in meta["shape"])
        enc = meta.get("encoding", RAW8)
        if enc == RAW8:
            # zero-copy view over the wire bytes (already non-writable)
            q = np.frombuffer(payload, np.uint8).reshape(shape)
        elif enc == ZDELTA8:
            last = self._last.get(stream)
            if last is None or last.shape != shape:
                raise ValueError(
                    f"delta frame for stream {stream!r} without a matching base"
                )
            diff = np.frombuffer(zlib.decompress(payload), np.uint8).reshape(shape)
            q = last + diff  # wraps mod 256, inverting the encoder exactly
            q.setflags(write=False)
        else:
            raise ValueError(f"unknown frame encoding {enc!r}")
        self._last[stream] = q
        return q
