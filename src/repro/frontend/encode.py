"""Off-loop frame encoding: raw RGB8, zlib temporal deltas, changed tiles.

Rendered frames leave the serving engine as read-only float32 HxWx3 arrays in
[0, 1]. Shipping those over TCP would cost 12 bytes/pixel; the gateway instead
quantizes to RGB8 (4x smaller, visually lossless for display) and — because a
viewer's consecutive frames are usually near-identical (orbit playback, time
scrubbing at a fixed pose, cache hits) — sends one of:

  ``zdelta8``  the uint8 difference vs the last frame sent on that stream,
               zlib-compressed. The difference wraps modulo 256, so decode is
               exact: ``cur = last + delta (mod 256)`` reproduces the
               quantized frame bit-for-bit.
  ``tiles8``   changed-tile streaming (protocol v2): the frame is diffed vs
               ``last`` per screen tile, and only the tiles whose content
               changed ship — their mod-256 diffs concatenated into ONE zlib
               stream, with the changed tile ids in the header. A frame whose
               motion touches three tiles costs three tiles on the wire; an
               identical frame costs a header. Exact, like zdelta8.

               On top of the diff, both ends mirror a bounded per-stream
               **tile store** of recently shipped tile contents (a ring of
               ``TILE_STORE_SLOTS``). A changed tile whose NEW content was
               already shipped on this stream — an orbit replay lap, a
               scrub revisiting a timestep, any pose the viewer returns to —
               is sent as a tiny ``[tile_id, slot]`` reference instead of
               pixels: the client already holds those bytes. The store is
               mirrored deterministically (shipped tiles enter the ring in
               header order; the header carries the frame's starting slot),
               so no round-trip or acknowledgment is needed.

Either way, if the compressed payload comes out **no smaller than raw**
(noisy first-contact frames — zlib on incompressible diffs adds overhead),
the encoder falls back to a raw keyframe and counts it (``raw_fallbacks``):
the wire never pays for compression that didn't compress.

Encoder and decoder are tiny mirrored state machines keyed by stream id:
both sides update ``last`` to the decoded frame after every ``frame``
message, and TCP ordering keeps them in lockstep. The first frame on a
stream (or any resolution change) is always a raw keyframe. Payload lengths
are validated against the header geometry before any reshape, so a
truncated or oversized frame from a misbehaving peer raises a
:class:`CodecError` naming the stream instead of a bare numpy error. All of
this is pure host work — the gateway runs it on an executor thread, never on
the event loop (that is the "off-loop" in the module name).
"""
from __future__ import annotations

import hashlib
import zlib

import numpy as np

RAW8 = "rgb8"        # payload = uint8 HxWx3, row-major
ZDELTA8 = "zdelta8"  # payload = zlib(uint8 wraparound diff vs last frame)
TILES8 = "tiles8"    # payload = zlib(concat of changed tiles' uint8 diffs)

ENCODINGS = (RAW8, ZDELTA8, TILES8)

# Mirrored per-stream tile-store ring size (slots). Memory per stream per
# connection is bounded by SLOTS x tile bytes (16x16x3 tiles -> ~1.5 MB),
# and holds a few frames' worth of recent tile content for ref-not-reship.
TILE_STORE_SLOTS = 2048


class CodecError(ValueError):
    """A frame payload is inconsistent with its header (wrong length,
    missing delta base, unknown encoding). Subclasses ValueError so legacy
    callers catching that still work; always names the stream."""


def quantize_rgb8(frame: np.ndarray) -> np.ndarray:
    """Float [0,1] HxWx3 -> contiguous uint8 (the on-wire pixel format)."""
    f = np.asarray(frame)
    if f.dtype == np.uint8:
        return np.ascontiguousarray(f)
    return np.ascontiguousarray(
        (np.clip(f, 0.0, 1.0) * 255.0 + 0.5).astype(np.uint8)
    )


def tile_grid(h: int, w: int, th: int, tw: int) -> list[tuple[slice, slice]]:
    """Row-major (y-slice, x-slice) spans of the tile grid; ragged edges get
    short tiles, so any resolution tiles exactly."""
    return [
        (slice(y, min(y + th, h)), slice(x, min(x + tw, w)))
        for y in range(0, h, th)
        for x in range(0, w, tw)
    ]


def _zdecompress(payload: bytes, expected: int, stream: str, what: str) -> bytes:
    """Bounded zlib decompress: a peer cannot zlib-bomb the receiver, and a
    wrong-size result is a protocol error naming the stream."""
    try:
        d = zlib.decompressobj()
        out = d.decompress(payload, expected + 1)
    except zlib.error as e:
        raise CodecError(f"stream {stream!r}: undecodable {what} payload: {e}") from None
    if len(out) != expected or d.unconsumed_tail or not d.eof:
        raise CodecError(
            f"stream {stream!r}: {what} payload decompresses to "
            f"{len(out)}{'+' if d.unconsumed_tail or not d.eof else ''} bytes, "
            f"header shape needs {expected}"
        )
    return out


class FrameEncoder:
    """Per-connection encoder; independent delta chain per stream id.

    ``tiles=True`` (negotiated: protocol v2 peers only) switches the delta
    path to changed-tile streaming with the ``tile`` grid shape.
    """

    def __init__(
        self,
        *,
        delta: bool = True,
        zlevel: int = 1,
        tiles: bool = False,
        tile: tuple[int, int] = (16, 16),
    ):
        self.delta = delta
        self.zlevel = zlevel
        self.tiles = tiles
        self.tile = (int(tile[0]), int(tile[1]))
        self._last: dict[str, np.ndarray] = {}
        # tile store (encoder side): digest -> slot, ring of digests, counter
        self._store: dict[str, dict[bytes, int]] = {}
        self._ring: dict[str, list[bytes]] = {}
        self._slot: dict[str, int] = {}
        # tile rows a partial reset marked dirty: their tiles ship (or ref)
        # on the next frame even when the pixel diff is zero
        self._force_rows: dict[str, set[int]] = {}
        self.raw_frames = 0
        self.delta_frames = 0
        self.tile_frames = 0
        self.raw_fallbacks = 0   # compressed >= raw, shipped raw instead
        self.tiles_total = 0     # tiles considered across tile frames
        self.tiles_shipped = 0   # tiles whose pixels went on the wire
        self.tiles_reffed = 0    # tiles sent as store references (no pixels)
        self.tiles_forced = 0    # tiles included only because a row was forced
        self.bytes_raw = 0       # what raw-only would have cost
        self.bytes_sent = 0

    def offered(self) -> list[str]:
        """Encodings this encoder may emit (for the hello_ok listing)."""
        out = [RAW8]
        if self.delta:
            out.append(TILES8 if self.tiles else ZDELTA8)
        return out

    @staticmethod
    def _digest(tile: np.ndarray) -> bytes:
        h = hashlib.blake2b(digest_size=16)
        h.update(repr(tile.shape).encode())
        h.update(np.ascontiguousarray(tile).tobytes())
        return h.digest()

    def _encode_tiles(
        self, stream: str, q: np.ndarray, last: np.ndarray
    ) -> tuple[dict, bytes, list[bytes]]:
        th, tw = self.tile
        grid = tile_grid(q.shape[0], q.shape[1], th, tw)
        tiles_x = -(-q.shape[1] // tw)
        forced = self._force_rows.get(stream) or ()
        diff = q - last  # uint8 arithmetic wraps mod 256: exact on decode
        store = self._store.get(stream, {})
        changed, refs, parts, staged = [], [], [], []
        for ti, (ys, xs) in enumerate(grid):
            d = diff[ys, xs]
            if not d.any():
                # a forced (invalidated) row's tiles ship anyway: the zero
                # diff decodes bit-exactly, and the client's copy is re-keyed
                # instead of silently assumed current
                if ti // tiles_x not in forced:
                    continue
                self.tiles_forced += 1
            digest = self._digest(q[ys, xs])
            slot = store.get(digest)
            if slot is not None:
                # the client already holds these exact pixels: ref, not reship
                refs.append([ti, slot])
            else:
                changed.append(ti)
                parts.append(d.tobytes())
                staged.append(digest)
        payload = zlib.compress(b"".join(parts), self.zlevel)
        meta = {
            "encoding": TILES8,
            "tile": [th, tw],
            "tiles": changed,
            "slot0": self._slot.get(stream, 0),
        }
        if refs:
            meta["refs"] = refs
        return meta, payload, staged

    def _commit_tiles(self, stream: str, staged: list[bytes]) -> None:
        """Enter the shipped tiles into the mirrored store ring, in header
        order (the decoder replays exactly this on receipt)."""
        store = self._store.setdefault(stream, {})
        ring = self._ring.setdefault(stream, [])
        slot = self._slot.get(stream, 0)
        for digest in staged:
            pos = slot % TILE_STORE_SLOTS
            if len(ring) <= pos:
                ring.append(digest)
            else:
                old = ring[pos]
                # evict the digest this ring position held — unless it was
                # re-inserted since and now maps to a newer slot
                if store.get(old) == slot - TILE_STORE_SLOTS:
                    del store[old]
                ring[pos] = digest
            store[digest] = slot
            slot += 1
        self._slot[stream] = slot

    def encode(self, stream: str, frame: np.ndarray) -> tuple[dict, bytes]:
        """Returns (meta fields for the frame header, payload bytes)."""
        q = quantize_rgb8(frame)
        meta = {"shape": list(q.shape)}
        last = self._last.get(stream)
        payload = None
        staged: list[bytes] = []
        if self.delta and last is not None and last.shape == q.shape:
            if self.tiles:
                tmeta, payload, staged = self._encode_tiles(stream, q, last)
            else:
                diff = q - last
                payload = zlib.compress(diff.tobytes(), self.zlevel)
                tmeta = {"encoding": ZDELTA8}
            if len(payload) >= q.nbytes and not tmeta.get("refs"):
                # compression lost (noisy first-contact frames): ship raw.
                # (Frames with store refs always stay tiles8 — the refs are
                # the savings, and a raw frame would desync nothing but
                # would re-ship pixels the client already holds.)
                self.raw_fallbacks += 1
                payload = None
            else:
                meta.update(tmeta)
                if self.tiles:
                    self._commit_tiles(stream, staged)
                    self.tile_frames += 1
                    # counted only for frames that really shipped as tiles8
                    # (a raw fallback put zero tiles on the wire)
                    th, tw = self.tile
                    self.tiles_total += len(
                        tile_grid(q.shape[0], q.shape[1], th, tw)
                    )
                    self.tiles_shipped += len(tmeta["tiles"])
                    self.tiles_reffed += len(tmeta.get("refs") or [])
                else:
                    self.delta_frames += 1
        if payload is None:
            payload = q.tobytes()
            meta["encoding"] = RAW8
            self.raw_frames += 1
        self._last[stream] = q
        # any shipped frame covers the forced rows (raw and zdelta8 carry the
        # whole frame; tiles8 included them above): the mark is consumed
        self._force_rows.pop(stream, None)
        self.bytes_raw += q.nbytes
        self.bytes_sent += len(payload)
        return meta, payload

    def reset(self, stream: str | None = None, *, rows=None) -> None:
        """Drop delta state (one stream, or all): next frame is a keyframe.
        The tile store survives — its content stays bit-exact regardless of
        why the chain was cut, and the header's ``slot0`` keeps both ends'
        rings aligned across the reset.

        ``rows`` (tiles8 chains only) is the partial reset: instead of
        cutting the chain, the given tile rows are marked dirty so the next
        frame ships (or store-refs) their tiles even where the pixel diff is
        zero — the client's copies of exactly the invalidated rows get
        re-keyed while the rest of the frame stays delta-coded. Falls back to
        the full reset when the stream has no chain to patch or the encoder
        is not in tiles mode."""
        if rows is not None and stream is not None:
            rows = {int(r) for r in rows}
            if self.tiles and stream in self._last and rows:
                self._force_rows.setdefault(stream, set()).update(rows)
                return
            if not rows:
                return  # nothing dirty: the chain is intact
        if stream is None:
            self._last.clear()
            self._force_rows.clear()
        else:
            self._last.pop(stream, None)
            self._force_rows.pop(stream, None)

    def stats(self) -> dict:
        return {
            "delta": self.delta,
            "tiles": self.tiles,
            "raw_frames": self.raw_frames,
            "delta_frames": self.delta_frames,
            "tile_frames": self.tile_frames,
            "raw_fallbacks": self.raw_fallbacks,
            "tiles_total": self.tiles_total,
            "tiles_shipped": self.tiles_shipped,
            "tiles_reffed": self.tiles_reffed,
            "tiles_forced": self.tiles_forced,
            "tiles_shipped_frac": round(self.tiles_shipped / self.tiles_total, 4)
            if self.tiles_total
            else None,
            "bytes_sent": self.bytes_sent,
            "bytes_raw_equiv": self.bytes_raw,
            "compression": round(self.bytes_raw / self.bytes_sent, 3)
            if self.bytes_sent
            else None,
        }


class FrameDecoder:
    """Mirror of :class:`FrameEncoder`; lives in the client. Speaks every
    encoding, so one decoder follows whatever the negotiation picked."""

    def __init__(self):
        self._last: dict[str, np.ndarray] = {}
        # tile store (decoder side): slot -> absolute tile pixels, per stream
        self._store: dict[str, dict[int, np.ndarray]] = {}

    def _base(self, stream: str, shape: tuple, enc: str) -> np.ndarray:
        last = self._last.get(stream)
        if last is None or last.shape != shape:
            raise CodecError(
                f"stream {stream!r}: {enc} frame without a matching base"
            )
        return last

    def decode(self, stream: str, meta: dict, payload: bytes) -> np.ndarray:
        """Returns the frame as a READ-ONLY uint8 array (the same contract
        as the server's copy-on-write cache frames, and uniform across all
        encodings — mutate a ``.copy()``). Payload length is validated
        against the header geometry before any array op; mismatches raise
        :class:`CodecError` naming the stream."""
        shape = tuple(int(s) for s in meta["shape"])
        expected = int(np.prod(shape))
        enc = meta.get("encoding", RAW8)
        if enc == RAW8:
            if len(payload) != expected:
                raise CodecError(
                    f"stream {stream!r}: raw payload is {len(payload)} bytes, "
                    f"header shape {list(shape)} needs {expected}"
                )
            # zero-copy view over the wire bytes (already non-writable)
            q = np.frombuffer(payload, np.uint8).reshape(shape)
        elif enc == ZDELTA8:
            last = self._base(stream, shape, enc)
            raw = _zdecompress(payload, expected, stream, enc)
            diff = np.frombuffer(raw, np.uint8).reshape(shape)
            q = last + diff  # wraps mod 256, inverting the encoder exactly
            q.setflags(write=False)
        elif enc == TILES8:
            last = self._base(stream, shape, enc)
            th, tw = (int(x) for x in meta.get("tile") or (16, 16))
            if th <= 0 or tw <= 0:
                raise CodecError(f"stream {stream!r}: bad tile shape {meta.get('tile')}")
            grid = tile_grid(shape[0], shape[1], th, tw)
            ids = [int(t) for t in meta.get("tiles") or []]
            refs = [(int(t), int(s)) for t, s in meta.get("refs") or []]
            if any(not 0 <= t < len(grid) for t in ids + [t for t, _ in refs]):
                raise CodecError(
                    f"stream {stream!r}: tile ids out of range for a "
                    f"{len(grid)}-tile grid"
                )
            spans = [grid[t] for t in ids]
            sizes = [
                (ys.stop - ys.start) * (xs.stop - xs.start) * shape[2]
                for ys, xs in spans
            ]
            raw = _zdecompress(payload, sum(sizes), stream, enc)
            store = self._store.setdefault(stream, {})
            q = last.copy()
            # store references first: tiles the encoder knows we already hold
            for ti, slot in refs:
                ys, xs = grid[ti]
                tile = store.get(slot)
                want = (ys.stop - ys.start, xs.stop - xs.start, shape[2])
                if tile is None or tile.shape != want:
                    raise CodecError(
                        f"stream {stream!r}: tile ref to slot {slot} "
                        f"{'missing from' if tile is None else 'mismatched in'} "
                        f"the mirrored store"
                    )
                q[ys, xs] = tile
            # then shipped diffs — and mirror the encoder's store commits
            # (shipped tiles enter the ring in header order from slot0)
            slot = int(meta.get("slot0", 0))
            off = 0
            for (ys, xs), n in zip(spans, sizes):
                d = np.frombuffer(raw, np.uint8, count=n, offset=off).reshape(
                    ys.stop - ys.start, xs.stop - xs.start, shape[2]
                )
                q[ys, xs] = last[ys, xs] + d  # mod-256 patch, tile-exact
                store[slot] = np.ascontiguousarray(q[ys, xs])
                store.pop(slot - TILE_STORE_SLOTS, None)
                slot += 1
                off += n
            if len(store) > 2 * TILE_STORE_SLOTS:  # bound across slot0 jumps
                for s in [s for s in store if not slot - TILE_STORE_SLOTS <= s < slot]:
                    del store[s]
            q.setflags(write=False)
        else:
            raise CodecError(f"stream {stream!r}: unknown frame encoding {enc!r}")
        self._last[stream] = q
        return q
