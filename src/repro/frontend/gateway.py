"""Asyncio TCP gateway: the network door onto the serving engine.

One event loop owns every connection; the blocking world (jax dispatch,
``FrameFuture.result()``, zlib) never runs on it:

  reader task (per conn)   parse messages, admission-control into the
                           session's bounded queue, answer shed/bad requests
  dispatcher task (one)    collect a *wave* — up to ``wave_per_session``
                           queued requests from every live session, round-
                           robin fair — and hand it to the render executor
  render executor (1 thr)  the only thread that touches the RenderServer:
                           submit the wave, drain the pipelined ring, return
                           frames. Single-threaded by design — the serving
                           engine is not thread-safe, and one thread is all
                           it needs (the device does the parallel work)
  encode executor (1 thr)  RGB8 quantization + zlib delta compression

A wave is the network-side analogue of the micro-batcher's wavefront: every
session contributes its oldest queued requests, so concurrent clients
coalesce into large micro-batches and identical poses dedup in flight, while
the per-session quota keeps one chatty client from monopolizing a wave.
Responses are written frame-by-frame as the wave retires; each full message
is composed before a single ``write`` call, so the reader task (shed errors)
and the dispatcher (frames) can safely share one writer.
"""
from __future__ import annotations

import asyncio
import sys
import threading
from concurrent.futures import ThreadPoolExecutor

from repro.analysis import tsan
from repro.frontend import protocol as proto
from repro.frontend.sessions import PendingRender, Session, SessionManager
from repro.obs import SLOTracker, new_request_id
from repro.obs.clock import now as _now

# error codes
SHED = "shed"                  # load-shedding dropped this queued request
BAD_REQUEST = "bad_request"    # unknown stream/timestep or malformed fields
RENDER_ERROR = "render_error"  # the serving engine failed this request


class Gateway:
    """One TCP endpoint multiplexing sessions onto a ``SessionManager``."""

    def __init__(
        self,
        manager: SessionManager,
        *,
        host: str = "127.0.0.1",
        port: int = 0,
        queue_limit: int = 8,
        wave_per_session: int = 4,
        delta_encoding: bool = True,
        coalesce_ms: float = 2.0,
        inline_encode_bytes: int = 1 << 20,
        gil_switch_interval_s: float | None = 5e-4,
        slo: dict | None = None,
    ):
        self.manager = manager
        self.host = host
        self.port = port  # 0 = ephemeral; the bound port replaces it on start
        self.queue_limit = queue_limit
        self.wave_per_session = wave_per_session
        self.delta_encoding = delta_encoding
        self.coalesce_ms = coalesce_ms
        self.inline_encode_bytes = inline_encode_bytes
        self.gil_switch_interval_s = gil_switch_interval_s
        self._prev_switch_interval: float | None = None

        self._server: asyncio.base_events.Server | None = None
        self._dispatch_task: asyncio.Task | None = None
        self._deliver_task: asyncio.Task | None = None  # tail of the chain
        self._conn_tasks: set[asyncio.Task] = set()
        self._sessions: dict[int, Session] = {}
        self._writers: dict[int, asyncio.StreamWriter] = {}
        self._render_exec = ThreadPoolExecutor(1, thread_name_prefix="gs-render")
        self._encode_exec = ThreadPoolExecutor(1, thread_name_prefix="gs-encode")
        self._work: asyncio.Event | None = None  # created on the serving loop
        self._gate: asyncio.Event | None = None
        self._closed = False

        # the stack's shared observability bundle: the manager owns it, the
        # engine + cache + sessions already meter onto it, and the gateway
        # registers its tier under gateway.* — so the stats/metrics wire
        # messages and frontend_load read ONE atomic snapshot instead of
        # mixing loop-thread counters with render-thread counters mid-update
        self.obs = manager.obs
        m = self.obs.metrics
        # wave-cycle phase accounting: where a served frame's wall-clock
        # goes — render executor vs encode vs socket
        self._c_render_wait_s = m.counter("gateway.render_wait_s")
        self._c_encode_wait_s = m.counter("gateway.encode_wait_s")
        self._c_write_s = m.counter("gateway.write_s")
        self._c_frames_sent = m.counter("gateway.frames_sent")
        self._c_shed_sent = m.counter("gateway.shed")
        self._c_protocol_errors = m.counter("gateway.protocol_errors")
        self._c_request_errors = m.counter("gateway.request_errors")
        self._c_dropped_writes = m.counter("gateway.dropped_writes")
        self._c_delivery_errors = m.counter("gateway.delivery_errors")
        self._c_engine_errors = m.counter("gateway.engine_errors")
        self._c_delta_resets = m.counter("gateway.delta_resets")
        self._c_partial_resets = m.counter("gateway.partial_resets")
        self._c_bytes_out = m.counter("gateway.bytes_out")
        self._c_waves = m.counter("gateway.waves")
        self._c_connections = m.counter("gateway.connections_total")
        # end-to-end served latency (admit -> socket write done, ms): the
        # histogram the SLO tracker windows and bench stage blocks report
        self._h_request_ms = m.histogram("gateway.request_ms")
        # live SLO monitoring (opt-in): ``slo`` is SLOTracker kwargs, e.g.
        # {"p99_ms": 250, "window_s": 30, "budget": 0.01} — the parsed form
        # of the CLI's --slo flag. Surfaced in stats + the metrics message.
        self.slo = SLOTracker(m, **slo) if slo else None

        # opt-in runtime race sanitizer (REPRO_TSAN=1; no-op otherwise).
        # The listed fields are written once by the serving loop thread
        # after construction — ordered by GatewayThread._ready, which
        # start() waits on before any caller can touch the gateway.
        tsan.attach(
            self, name="Gateway", dicts=("_sessions", "_writers"),
            ordered=("port", "_server", "_dispatch_task", "_deliver_task",
                     "_conn_tasks", "_work", "_gate", "_closed",
                     "_prev_switch_interval"),
        )

    # historical attribute reads, now backed by the shared registry
    @property
    def render_wait_s(self) -> float:
        return self._c_render_wait_s.value

    @property
    def encode_wait_s(self) -> float:
        return self._c_encode_wait_s.value

    @property
    def write_s(self) -> float:
        return self._c_write_s.value

    @property
    def frames_sent(self) -> int:
        return self._c_frames_sent.value

    @property
    def shed_sent(self) -> int:
        return self._c_shed_sent.value

    @property
    def protocol_errors(self) -> int:
        return self._c_protocol_errors.value

    @property
    def request_errors(self) -> int:
        return self._c_request_errors.value

    @property
    def dropped_writes(self) -> int:
        return self._c_dropped_writes.value

    @property
    def delivery_errors(self) -> int:
        return self._c_delivery_errors.value

    @property
    def engine_errors(self) -> int:
        return self._c_engine_errors.value

    @property
    def delta_resets(self) -> int:
        """Full-stream invalidations -> forced keyframes."""
        return self._c_delta_resets.value

    @property
    def partial_resets(self) -> int:
        """Row-granular invalidations -> forced tile rows (chain kept)."""
        return self._c_partial_resets.value

    @property
    def bytes_out(self) -> int:
        return self._c_bytes_out.value

    @property
    def waves(self) -> int:
        return self._c_waves.value

    @property
    def connections_total(self) -> int:
        return self._c_connections.value

    # -------------------------------------------------------------- lifecycle
    async def start(self) -> "Gateway":
        assert self.manager.server is not None, "register streams before start()"
        if self.gil_switch_interval_s is not None:
            # the serving hot path ping-pongs between the event loop, the
            # render thread, and the encode thread; CPython's default 5 ms
            # GIL switch interval turns every hand-off into milliseconds of
            # wakeup latency (measured 2-3x aggregate fps on a 2-core host).
            # Process-wide by nature; pass None to leave it alone; restored
            # on aclose() so embedders are not permanently rescheduled.
            self._prev_switch_interval = sys.getswitchinterval()
            sys.setswitchinterval(self.gil_switch_interval_s)
        self._work = asyncio.Event()
        self._gate = asyncio.Event()
        self._gate.set()
        self._server = await asyncio.start_server(self._handle_conn, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        self._dispatch_task = asyncio.ensure_future(self._dispatch_loop())
        return self

    async def serve_forever(self) -> None:
        assert self._server is not None, "start() first"
        await self._server.serve_forever()

    def run_on_engine(self, fn, *args):
        """Run ``fn`` on the render-executor thread; returns its Future.

        The public hook for engine maintenance from outside the loop
        (cache invalidation between benchmark laps, model hot-swaps): the
        single render executor is the only thread allowed to touch the
        serving engine, and queueing through it serializes behind any
        in-flight wave instead of racing one."""
        return self._render_exec.submit(fn, *args)

    def pause(self) -> None:
        """Hold dispatch (admission + shedding continue). Loop thread only."""
        self._gate.clear()

    def resume(self) -> None:
        self._gate.set()

    async def aclose(self) -> None:
        """Stop accepting, drop connections, close the serving engine."""
        if self._closed:
            return
        self._closed = True
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        if self._dispatch_task is not None:
            self._dispatch_task.cancel()
            await asyncio.gather(self._dispatch_task, return_exceptions=True)
        if self._deliver_task is not None:  # flush in-flight responses first
            await asyncio.gather(self._deliver_task, return_exceptions=True)
        for writer in list(self._writers.values()):
            writer.close()
        for task in list(self._conn_tasks):
            task.cancel()
        if self._conn_tasks:
            await asyncio.gather(*self._conn_tasks, return_exceptions=True)
        # the render executor serializes this behind any in-flight wave, so
        # the engine closes from the same (only) thread that ever drove it
        loop = asyncio.get_running_loop()
        await loop.run_in_executor(self._render_exec, self.manager.close)
        self._render_exec.shutdown(wait=True)
        self._encode_exec.shutdown(wait=True)
        if self._prev_switch_interval is not None:
            sys.setswitchinterval(self._prev_switch_interval)
            self._prev_switch_interval = None

    # ------------------------------------------------------------ connections
    async def _handle_conn(self, reader: asyncio.StreamReader, writer: asyncio.StreamWriter):
        cfg = self.manager.cfg
        session = Session(
            queue_limit=self.queue_limit,
            delta_encoding=self.delta_encoding,
            tile=(cfg.tile_h, cfg.tile_w),
            metrics=self.obs.metrics,
        )
        self._sessions[session.session_id] = session
        self._writers[session.session_id] = writer
        self._conn_tasks.add(asyncio.current_task())
        self._c_connections.inc()
        try:
            while True:
                try:
                    # requests carry everything in the header; a peer
                    # declaring a fat payload is hostile or confused
                    msg = await proto.read_message(reader, max_payload=1 << 16)
                except proto.ProtocolError as e:
                    # framing is gone — tell the peer once and hang up
                    self._c_protocol_errors.inc()
                    await self._send(session, {"type": proto.ERROR, "code": BAD_REQUEST,
                                               "detail": str(e)})
                    break
                if msg is None:
                    break
                header, _payload = msg
                if not await self._handle_message(session, header):
                    break
        except (asyncio.CancelledError, ConnectionError):
            pass
        finally:
            self._sessions.pop(session.session_id, None)
            self._writers.pop(session.session_id, None)
            self._conn_tasks.discard(asyncio.current_task())
            session.queue.clear()  # abandoned: the client is gone
            writer.close()

    async def _handle_message(self, session: Session, header: dict) -> bool:
        """Process one parsed message; False ends the connection."""
        mtype = header.get("type")
        seq = header.get("seq")
        if mtype == proto.HELLO:
            # application-protocol negotiation: a v1 hello (no protocol
            # field / no tiles8 offer) keeps the v1 zdelta8 wire format
            negotiated = session.negotiate(
                header.get("protocol", 1), header.get("encodings")
            )
            await self._send(session, {
                "type": proto.HELLO_OK,
                "protocol": negotiated,
                "encodings": session.encoder.offered(),
                "tile": list(session.tile),
                "streams": self.manager.describe(),
                "img_h": self.manager.cfg.img_h,
                "img_w": self.manager.cfg.img_w,
                "delta": self.delta_encoding,
                "session": session.session_id,
            })
        elif mtype == proto.RENDER:
            await self._admit_renders(session, header, [header.get("timestep", 0)])
        elif mtype == proto.SCRUB:
            ts = header.get("timesteps") or []
            if isinstance(ts, list):
                # defensive dedupe for third-party clients: one response per
                # timestep is the contract a per-seq fan-in counts against
                try:
                    ts = list(dict.fromkeys(ts))
                except TypeError:
                    pass  # unhashable entries become bad_request in _admit_renders
            if not isinstance(ts, list) or not ts:
                self._c_request_errors.inc()
                session.errors_sent += 1
                await self._send(session, {"type": proto.ERROR, "seq": seq,
                                           "code": BAD_REQUEST,
                                           "detail": "scrub needs a timesteps list"})
                return True
            await self._admit_renders(session, header, ts)
        elif mtype == proto.STATS:
            # session/gateway counters snapshot on the LOOP thread (they are
            # mutated here — reading them from another thread races dict
            # iteration); only the engine report crosses to the render
            # executor, whose single thread owns every server metric
            report = self._gateway_stats()
            loop = asyncio.get_running_loop()
            report.update(await loop.run_in_executor(
                self._render_exec, self.manager.report
            ))
            await self._send(session, {"type": proto.STATS_OK, "seq": seq,
                                       "report": report})
        elif mtype == proto.METRICS:
            # the typed-registry view: one ATOMIC flat snapshot (no executor
            # hop needed — the registry lock makes cross-thread reads safe)
            rec = self.obs.trace
            await self._send(session, {
                "type": proto.METRICS_OK, "seq": seq,
                "metrics": self.obs.metrics.snapshot(),
                "trace": {"enabled": bool(rec), "recorded": rec.recorded,
                          "dropped": rec.dropped},
                "slo": self.slo.report() if self.slo is not None else None,
            })
        elif mtype == proto.BYE:
            return False
        else:
            self._c_protocol_errors.inc()
            session.errors_sent += 1
            await self._send(session, {"type": proto.ERROR, "seq": seq,
                                       "code": BAD_REQUEST,
                                       "detail": f"unknown message type {mtype!r}"})
        return True

    async def _admit_renders(
        self, session: Session, header: dict, timesteps: list
    ) -> None:
        """Admission-control render/scrub items into the session queue."""
        seq = header.get("seq")
        stream_id = header.get("stream", "")
        try:
            cam = proto.camera_from_wire(header.get("camera") or {})
            resolved = [
                (int(t), self.manager.resolve(stream_id, t)) for t in timesteps
            ]
            # optional foveation hints (protocol v2 extras, both may be absent)
            budget_ms = header.get("budget_ms")
            if budget_ms is not None:
                budget_ms = float(budget_ms)
                if not budget_ms > 0:
                    raise ValueError("budget_ms must be > 0")
            gaze = header.get("gaze")
            if gaze is not None:
                gx, gy = (float(v) for v in gaze)
                gaze = (min(max(gx, 0.0), 1.0), min(max(gy, 0.0), 1.0))
        except (proto.ProtocolError, KeyError, TypeError, ValueError) as e:
            # malformed fields (non-int timesteps included) answer with a
            # bad_request frame instead of killing the connection handler
            self._c_request_errors.inc()
            session.errors_sent += 1
            await self._send(session, {"type": proto.ERROR, "seq": seq,
                                       "code": BAD_REQUEST, "detail": str(e)})
            return
        # a scrub is ONE admission unit: its fan-out may exceed the session
        # queue limit (it is bounded by the registered timeline length), and
        # the oldest-drop shed must never evict the scrub's own items — a
        # full-timeline scrub would otherwise deterministically shed itself
        limit = max(session.queue_limit, len(resolved))
        bulk = len(resolved) > 1
        rec = self.obs.trace
        for i, (t, global_ts) in enumerate(resolved):
            # the request id is minted HERE, at admission — the root of the
            # span tree; it rides the PendingRender into RenderServer.submit
            # so engine spans join the same tree
            pr = PendingRender(
                session=session, seq=seq, stream_id=stream_id, timestep=t,
                global_ts=global_ts, cam=cam, t_admit=_now(),
                scrub_last=i == len(resolved) - 1, bulk=bulk,
                request_id=new_request_id(),
                budget_ms=budget_ms, gaze=gaze,
            )
            if rec:
                rec.record(pr.request_id, "admit", pr.t_admit,
                           session=session.session_id, seq=seq,
                           stream=stream_id, timestep=t, bulk=bulk)
            victim = session.admit(pr, limit=limit)
            if victim is not None:
                self._c_shed_sent.inc()
                victim.session.errors_sent += 1
                if rec:
                    # a shed request's tree must END visibly, not vanish:
                    # the terminated span covers admit -> shed decision
                    rec.record(victim.request_id, "shed", victim.t_admit,
                               _now(), terminated=True, seq=victim.seq,
                               stream=victim.stream_id,
                               timestep=victim.timestep)
                await self._send(victim.session, {
                    "type": proto.ERROR, "seq": victim.seq, "code": SHED,
                    "stream": victim.stream_id, "timestep": victim.timestep,
                    "detail": "session queue full: oldest request shed",
                })
        self._work.set()

    # -------------------------------------------------------------- dispatch
    async def _coalesce(self) -> None:
        """Give a concurrent wavefront one beat to finish landing.

        N clients answering the previous wave submit near-simultaneously,
        but their reader tasks need event-loop turns to parse; cutting a
        wave on the FIRST arrival renders fragment micro-batches (measured:
        mean batch 1.7 vs 4 for the same trace in-process). Hold until
        enough requests are queued to fill a device micro-batch — or the
        window expires. Worst-case added latency is ``coalesce_ms``, an
        order below a render; batching efficiency dominates."""
        if self.coalesce_ms <= 0:
            return
        target = self.manager.server.batcher.max_batch
        loop = asyncio.get_running_loop()
        deadline = loop.time() + self.coalesce_ms / 1e3
        while loop.time() < deadline:
            if sum(len(s.queue) for s in self._sessions.values()) >= target:
                return
            await asyncio.sleep(self.coalesce_ms / 8e3)

    async def _dispatch_loop(self) -> None:
        loop = asyncio.get_running_loop()
        while True:
            await self._work.wait()
            self._work.clear()
            while True:
                await self._gate.wait()
                await self._coalesce()
                wave: list[PendingRender] = []
                for session in list(self._sessions.values()):
                    wave.extend(session.take(self.wave_per_session))
                if not wave:
                    break
                self._c_waves.inc()
                t0 = _now()
                rec = self.obs.trace
                if rec:
                    # queue residency: admit -> picked up by this wave
                    wid = self._c_waves.value
                    for pr in wave:
                        rec.record(pr.request_id, "coalesce", pr.t_admit, t0,
                                   wave=wid, wave_size=len(wave))
                try:
                    results = await loop.run_in_executor(
                        self._render_exec, self._render_wave, wave
                    )
                except Exception:  # analysis: allow(hygiene.broad_except, last-ditch dispatcher survival — the loop must outlive engine surprises; counted on gateway.engine_errors)
                    self._c_engine_errors.inc()
                    continue
                finally:
                    self._c_render_wait_s.add(_now() - t0)
                # deliver (encode + write) in a CHAINED background task and
                # immediately collect the next wave: clients that request
                # ahead (any streaming viewer) keep the render thread busy
                # while the previous wave compresses and hits the sockets —
                # the gateway-level analogue of the server's in-flight ring.
                # Chaining (each deliver awaits its predecessor) preserves
                # per-session response order and the delta-encode lockstep.
                self._deliver_task = asyncio.ensure_future(
                    self._deliver(results, self._deliver_task)
                )

    async def _deliver(self, results: list, prev: asyncio.Task | None) -> None:
        if prev is not None:
            await asyncio.gather(prev, return_exceptions=True)
        try:
            await self._deliver_inner(results)
        except Exception:  # analysis: allow(hygiene.broad_except, counted on gateway.delivery_errors — a failed wave must not vanish)
            # without this, the successor's gather(return_exceptions=True)
            # would silently eat the exception and every counter would read
            # "all fine" while a whole wave of clients hangs
            self._c_delivery_errors.inc()

    async def _deliver_inner(self, results: list) -> None:
        loop = asyncio.get_running_loop()
        # a cache invalidation (model hot-swap, dirty-row drop) marks its
        # stream dirty: patch every session's delta chain for it BEFORE this
        # wave encodes. Row-granular invalidations (world-space dirty tiles)
        # only force the affected tile rows onto the wire — the chain stays
        # intact elsewhere; a full invalidation (rows=None) still cuts the
        # chain so the first post-update frame ships as a keyframe rather
        # than extending one rooted in superseded content
        for sid, rows in self.manager.take_dirty().items():
            if rows is None:
                self._c_delta_resets.inc()
            else:
                self._c_partial_resets.inc()
            for s in list(self._sessions.values()):
                s.encoder.reset(sid, rows=rows)
        t1 = _now()
        # One executor hop encodes the WHOLE wave (per-frame hops cost a
        # thread wakeup + loop wakeup each — measurable at localhost rates).
        # Small waves skip the hop entirely: an executor round-trip costs
        # milliseconds of wakeup latency under load, while quantize+zlib on
        # a few hundred KB costs tens of microseconds — "off-loop" is for
        # production-resolution frames, not for work cheaper than the hop.
        wave_bytes = sum(
            frame.nbytes for _, frame, err in results if err is None
        )
        if wave_bytes <= self.inline_encode_bytes:
            encoded = self._encode_wave(results)
        else:
            encoded = await loop.run_in_executor(
                self._encode_exec, self._encode_wave, results
            )
        t2 = _now()
        self._c_encode_wait_s.add(t2 - t1)
        rec = self.obs.trace
        for pr, err, header, payload in encoded:
            if err is not None:
                self._c_request_errors.inc()
                pr.session.errors_sent += 1
                await self._send(pr.session, {
                    "type": proto.ERROR, "seq": pr.seq, "code": RENDER_ERROR,
                    "stream": pr.stream_id, "timestep": pr.timestep,
                    "detail": str(err),
                })
                continue
            if rec:
                w0 = _now()
            ok = await self._send(pr.session, header, payload)
            if rec:
                rec.record(pr.request_id, "write", w0, _now(),
                           bytes=len(payload), ok=ok)
            if ok:
                self._c_frames_sent.inc()
                pr.session.frames_sent += 1
                # end-to-end served latency: admit -> response on the wire
                self._h_request_ms.observe((_now() - pr.t_admit) * 1e3)
        self._c_write_s.add(_now() - t2)
        if self.slo is not None:
            self.slo.tick()  # fold this wave into the SLO window promptly

    def _encode_wave(self, results: list) -> list:
        """Encode executor only: quantize+compress one wave's frames."""
        out = []
        rec = self.obs.trace
        for pr, frame, err in results:
            if err is not None:
                out.append((pr, err, None, None))
                continue
            if rec:
                e0 = _now()
            meta, payload = pr.session.encoder.encode(pr.stream_id, frame)
            if rec:
                rec.record(pr.request_id, "encode", e0, _now(),
                           encoding=meta.get("encoding"), bytes=len(payload))
            out.append((pr, None, {
                "type": proto.FRAME, "seq": pr.seq, "stream": pr.stream_id,
                "timestep": pr.timestep, "last": pr.scrub_last, **meta,
            }, payload))
        return out

    def _render_wave(self, wave: list[PendingRender]) -> list:
        """Render executor only: the sole code path touching the engine.

        Never lets an exception escape — an engine failure mid-batch becomes
        per-request error results, so the dispatcher task survives and every
        waiting client gets an answer instead of a silent permanent hang."""
        server = self.manager.server
        out, futs = [], []
        for pr in wave:
            try:
                futs.append((pr, server.submit(
                    pr.cam, timestep=pr.global_ts, client_id=pr.session.session_id,
                    t_submit=pr.t_admit,
                    request_id=pr.request_id if pr.request_id >= 0 else None,
                    gaze=pr.gaze, budget_ms=pr.budget_ms,
                )))
            except Exception as e:  # analysis: allow(hygiene.broad_except, bad submit state (e.g. closing) becomes this request's error response; counted on gateway.request_errors at delivery)
                out.append((pr, None, e))
        try:
            server.run()  # drain the queue + the pipelined in-flight ring
            run_err = None
        except Exception as e:  # analysis: allow(hygiene.broad_except, a run() failure fails every pending future below — surfaced per request, counted on gateway.request_errors)
            run_err = e
        for pr, fut in futs:
            try:
                if run_err is not None and not fut.done():
                    out.append((pr, None, run_err))
                else:
                    out.append((pr, fut.result(), None))
            except Exception as e:  # analysis: allow(hygiene.broad_except, per-request render failure becomes that request's error response; counted on gateway.request_errors at delivery)
                out.append((pr, None, e))
        return out

    async def _send(self, session: Session, header: dict, payload: bytes = b"") -> bool:
        writer = self._writers.get(session.session_id)
        if writer is None:
            self._c_dropped_writes.inc()
            return False
        try:
            self._c_bytes_out.inc(await proto.write_message(writer, header, payload))
            return True
        except (OSError, RuntimeError):  # peer vanished / transport broke
            self._c_dropped_writes.inc()
            return False

    # --------------------------------------------------------------- metrics
    def report(self) -> dict:
        """Gateway + session + serving-engine metrics. Call from the loop
        thread (or while the gateway is quiescent); the stats message
        handler composes the same parts thread-correctly."""
        return {**self._gateway_stats(), **self.manager.report()}

    def _gateway_stats(self) -> dict:
        """Gateway-tier stats from ONE atomic registry snapshot.

        Historically this mixed loop-thread counters with engine metrics the
        render executor was mutating mid-read (torn values under load); every
        gateway counter now lives on the shared registry, so a single locked
        ``snapshot()`` yields a consistent point in time regardless of which
        thread asks. Per-session dicts stay loop-thread-only (they iterate
        ``_sessions``, which only the loop mutates)."""
        snap = self.obs.metrics.snapshot()

        def g(name, default=0):
            return snap.get("gateway." + name, default)

        return {
            "gateway": {
                "host": self.host,
                "port": self.port,
                "connections_total": g("connections_total"),
                "sessions_now": len(self._sessions),
                "frames_sent": g("frames_sent"),
                "shed": g("shed"),
                "protocol_errors": g("protocol_errors"),
                "request_errors": g("request_errors"),
                "dropped_writes": g("dropped_writes"),
                "delivery_errors": g("delivery_errors"),
                "engine_errors": g("engine_errors"),
                "delta_resets": g("delta_resets"),
                "partial_resets": g("partial_resets"),
                "bytes_out": g("bytes_out"),
                "waves": g("waves"),
                "queue_limit": self.queue_limit,
                "wave_per_session": self.wave_per_session,
                "render_wait_s": round(g("render_wait_s", 0.0), 4),
                "encode_wait_s": round(g("encode_wait_s", 0.0), 4),
                "write_s": round(g("write_s", 0.0), 4),
                "slo": self.slo.report() if self.slo is not None else None,
            },
            "sessions": {s.session_id: s.stats() for s in self._sessions.values()},
        }


# --------------------------------------------------------------------------
# thread-hosted gateway (tests, benchmarks, in-process embedding)
# --------------------------------------------------------------------------
class GatewayThread:
    """Run a gateway's event loop on a daemon thread; sync start/stop."""

    def __init__(self, gateway: Gateway):
        self.gateway = gateway
        self.loop = asyncio.new_event_loop()
        self._ready = threading.Event()
        self._startup_error: BaseException | None = None
        self._thread = threading.Thread(target=self._run, name="gs-gateway", daemon=True)
        # _startup_error is Event-ordered (_run sets it before _ready.set();
        # start() waits on _ready before reading) — same waiver as the
        # static pass's pragma at the write site
        tsan.attach(self, name="GatewayThread", ordered=("_startup_error",))

    def _run(self) -> None:
        asyncio.set_event_loop(self.loop)
        try:
            self.loop.run_until_complete(self.gateway.start())
        except BaseException as e:  # analysis: allow(hygiene.broad_except, startup failure (incl. SystemExit/KeyboardInterrupt on the loop thread) is captured and re-raised in start())
            self._startup_error = e  # analysis: allow(locks.thread_shared_write, ordered by the _ready Event: start() waits on it before reading)
            self._ready.set()
            return
        self._ready.set()
        self.loop.run_forever()
        self.loop.run_until_complete(self.loop.shutdown_asyncgens())
        self.loop.close()

    def start(self, timeout: float = 30.0) -> "GatewayThread":
        self._thread.start()
        if not self._ready.wait(timeout):
            raise TimeoutError("gateway event loop failed to come up")
        if self._startup_error is not None:
            raise self._startup_error
        return self

    @property
    def port(self) -> int:
        return self.gateway.port

    def call(self, coro, timeout: float = 60.0):
        """Run a coroutine on the gateway loop from any thread."""
        return asyncio.run_coroutine_threadsafe(coro, self.loop).result(timeout)

    def call_soon(self, fn, *args) -> None:
        self.loop.call_soon_threadsafe(fn, *args)

    def stop(self, timeout: float = 60.0) -> None:
        if self._startup_error is None and self.loop.is_running():
            asyncio.run_coroutine_threadsafe(self.gateway.aclose(), self.loop).result(timeout)
            self.loop.call_soon_threadsafe(self.loop.stop)
        self._thread.join(timeout)

    def __enter__(self) -> "GatewayThread":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
