"""Versioned length-prefixed wire protocol for the render gateway.

Every message is one frame on the TCP stream:

  +----+---+---+------------+-------------+----------------+---------------+
  | GS | v | 0 | header_len | payload_len | header (JSON)  | payload (raw) |
  +----+---+---+------------+-------------+----------------+---------------+
   2B   1B  1B   uint32 BE     uint32 BE     header_len B     payload_len B

The JSON header carries the message ``type`` plus small structured fields
(stream id, sequence number, camera, encoding metadata); bulk bytes — the
encoded frame — ride in the raw payload, never through JSON. The format is
dependency-free (``struct`` + ``json``), explicit about byte order, and
versioned: a peer speaking a different major version is rejected at the
first frame, not by a mid-stream parse explosion.

Message types (header["type"]):

  hello / hello_ok     handshake; hello_ok lists the registered streams
  render               one camera at (stream, timestep) -> one ``frame``
  scrub                one camera across many timesteps -> many ``frame``s
  frame                response payload = encoded RGB8 (see ``encode.py``)
  stats / stats_ok     gateway + serving-engine metrics snapshot
  metrics / metrics_ok atomic typed-registry snapshot (v2; flat dotted names)
  error                failure for a specific seq (code: shed/bad_request/...)
  bye                  client-initiated clean shutdown of the connection

Requests carry a client-chosen ``seq``; every response names the ``seq`` it
answers, so one connection can hold many requests in flight (the gateway
sheds overload per-session by answering queued seqs with ``error/shed``).

**Versioning.** Two numbers, two jobs. ``VERSION`` (the prefix byte) is the
*framing* version — how bytes become messages — and only changes if the
prefix layout does. ``PROTOCOL`` is the *application* version, negotiated in
``hello``: the client sends ``{"protocol": <its max>, "encodings": [...]}``,
the gateway answers ``hello_ok`` with ``min(client, server)`` and the frame
encodings it will actually use. Protocol v2 adds the ``tiles8``
changed-tile frame encoding (see ``encode.py``); a v1 peer (or a hello with
no ``protocol`` field) falls back to the v1 ``zdelta8``/``rgb8`` wire
format, so old clients keep working against new gateways and vice versa.

``render``/``scrub`` headers may additionally carry two OPTIONAL foveated-
serving hints — ``gaze`` (normalized ``[x, y]`` in [0, 1]) and
``budget_ms`` (positive float render-time budget). Absent fields mean
uniform-LOD serving, and old gateways ignore unknown header fields, so
these ride within PROTOCOL 2 rather than bumping it.
"""
from __future__ import annotations

import json
import struct
from typing import Iterator

import numpy as np

from repro.core.projection import Camera

MAGIC = b"GS"
VERSION = 1    # wire FRAMING version (prefix byte): layout of the prefix
PROTOCOL = 2   # application version, negotiated in hello (v2: tiles8 frames)

# magic(2) version(1) reserved(1) header_len(u32) payload_len(u32), big-endian
_PREFIX = struct.Struct(">2sBBII")
PREFIX_SIZE = _PREFIX.size

MAX_HEADER_BYTES = 1 << 20   # a header is small structured JSON
MAX_PAYLOAD_BYTES = 1 << 28  # one frame; 256 MB is beyond any sane config

# message type constants
HELLO, HELLO_OK = "hello", "hello_ok"
RENDER, FRAME, SCRUB = "render", "frame", "scrub"
STATS, STATS_OK = "stats", "stats_ok"
METRICS, METRICS_OK = "metrics", "metrics_ok"  # v2: typed-registry snapshot
ERROR, BYE = "error", "bye"


class ProtocolError(Exception):
    """The byte stream is not speaking this protocol (or this version)."""


def pack_message(header: dict, payload: bytes = b"") -> bytes:
    """Serialize one message to its on-wire bytes."""
    hdr = json.dumps(header, separators=(",", ":")).encode("utf-8")
    if len(hdr) > MAX_HEADER_BYTES:
        raise ProtocolError(f"header too large: {len(hdr)} bytes")
    if len(payload) > MAX_PAYLOAD_BYTES:
        raise ProtocolError(f"payload too large: {len(payload)} bytes")
    return _PREFIX.pack(MAGIC, VERSION, 0, len(hdr), len(payload)) + hdr + payload


def unpack_prefix(buf: bytes) -> tuple[int, int]:
    """Validate a 12-byte frame prefix; returns (header_len, payload_len)."""
    if len(buf) < PREFIX_SIZE:
        raise ProtocolError(f"short prefix: {len(buf)} < {PREFIX_SIZE} bytes")
    magic, version, _, hlen, plen = _PREFIX.unpack(buf[:PREFIX_SIZE])
    if magic != MAGIC:
        raise ProtocolError(f"bad magic {magic!r} (not a gateway stream?)")
    if version != VERSION:
        raise ProtocolError(f"peer speaks protocol v{version}, this side v{VERSION}")
    if hlen > MAX_HEADER_BYTES:
        raise ProtocolError(f"declared header length {hlen} exceeds cap")
    if plen > MAX_PAYLOAD_BYTES:
        raise ProtocolError(f"declared payload length {plen} exceeds cap")
    return hlen, plen


def _parse_header(raw: bytes) -> dict:
    try:
        header = json.loads(raw.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise ProtocolError(f"undecodable header: {e}") from None
    if not isinstance(header, dict) or "type" not in header:
        raise ProtocolError(f"header is not a typed object: {header!r}")
    return header


def iter_messages(data: bytes) -> Iterator[tuple[dict, bytes]]:
    """Parse a byte buffer holding zero or more complete messages (sync side;
    the async path uses ``read_message``). Raises on trailing partial bytes."""
    off = 0
    while off < len(data):
        hlen, plen = unpack_prefix(data[off : off + PREFIX_SIZE])
        end = off + PREFIX_SIZE + hlen + plen
        if end > len(data):
            raise ProtocolError(f"truncated message: need {end - len(data)} more bytes")
        header = _parse_header(data[off + PREFIX_SIZE : off + PREFIX_SIZE + hlen])
        yield header, data[off + PREFIX_SIZE + hlen : end]
        off = end


async def read_message(reader, *, max_payload: int = MAX_PAYLOAD_BYTES) -> tuple[dict, bytes] | None:
    """Read one message from an asyncio StreamReader; None on clean EOF
    (EOF at a frame boundary). EOF mid-frame raises ProtocolError.

    ``max_payload`` lets a receiver cap inbound payloads below the wire
    format's limit: the gateway reads *requests*, which carry all their
    data in the JSON header — honoring the frame-sized default there would
    let any unauthenticated peer demand 256 MB allocations per message."""
    try:
        prefix = await reader.readexactly(PREFIX_SIZE)
    except EOFError:  # asyncio.IncompleteReadError subclasses EOFError
        return None  # connection closed between frames: a clean goodbye
    except ConnectionError:
        return None
    hlen, plen = unpack_prefix(prefix)
    if plen > max_payload:
        raise ProtocolError(
            f"declared payload length {plen} exceeds this receiver's cap {max_payload}"
        )
    try:
        body = await reader.readexactly(hlen + plen)  # one read, one wakeup
    except EOFError:
        raise ProtocolError("connection closed mid-message") from None
    return _parse_header(body[:hlen]), body[hlen:]


# Only pay a real drain (a loop round-trip) once this much is buffered;
# below it, write() just appends and the coroutine never yields.
DRAIN_THRESHOLD = 1 << 16


async def write_message(writer, header: dict, payload: bytes = b"") -> int:
    """Write one message; returns bytes written. The full frame is composed
    before the single ``write`` call, so concurrent writers on one
    connection can never interleave partial frames. Draining is deferred
    until the transport buffers ``DRAIN_THRESHOLD`` bytes — per-message
    drains cost an event-loop round-trip each, which at localhost frame
    rates is most of the message's latency."""
    data = pack_message(header, payload)
    writer.write(data)
    transport = writer.transport
    if transport is None or transport.get_write_buffer_size() > DRAIN_THRESHOLD:
        await writer.drain()
    return len(data)


# ------------------------------------------------------------------ cameras
def camera_to_wire(cam: Camera) -> dict:
    """Flatten a pinhole camera for the JSON header (float lists)."""
    return {
        "viewmat": [float(v) for v in np.asarray(cam.viewmat, np.float32).reshape(-1)],
        "fx": float(np.asarray(cam.fx)),
        "fy": float(np.asarray(cam.fy)),
        "cx": float(np.asarray(cam.cx)),
        "cy": float(np.asarray(cam.cy)),
    }


def camera_from_wire(d: dict) -> Camera:
    try:
        vm = np.asarray(d["viewmat"], np.float32).reshape(4, 4)
        return Camera(
            vm,
            np.float32(d["fx"]),
            np.float32(d["fy"]),
            np.float32(d["cx"]),
            np.float32(d["cy"]),
        )
    except (KeyError, TypeError, ValueError) as e:
        raise ProtocolError(f"malformed camera: {e}") from None
