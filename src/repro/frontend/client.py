"""Frontend clients: asyncio-native, plus a sync wrapper for scripts/tests.

``AsyncFrontendClient`` keeps many requests in flight on one connection: each
request carries a client-chosen ``seq``, a background reader task routes
responses (frames, shed notices, stats) back to per-seq futures, and a
``FrameDecoder`` mirrors the gateway's per-stream delta chain. The sync
``FrontendClient`` hosts the async client on a private event-loop thread and
exposes blocking calls — the shape scripts and pytest want.
"""
from __future__ import annotations

import asyncio
import itertools
import threading

import numpy as np

from repro.core.projection import Camera
from repro.frontend import protocol as proto
from repro.frontend.encode import ENCODINGS, FrameDecoder


class ShedError(RuntimeError):
    """The gateway load-shed this request (session queue overflow)."""


class RemoteRenderError(RuntimeError):
    """The gateway answered with a non-shed error for this request."""


class AsyncFrontendClient:
    """One gateway connection; safe for many concurrent awaiting tasks."""

    def __init__(self, host: str = "127.0.0.1", port: int = 0):
        self.host = host
        self.port = port
        self.hello: dict | None = None  # hello_ok header (streams listing etc.)
        self._reader: asyncio.StreamReader | None = None
        self._writer: asyncio.StreamWriter | None = None
        self._reader_task: asyncio.Task | None = None
        self._pending: dict[int, dict] = {}
        self._seq = itertools.count()
        self._decoder = FrameDecoder()
        self.frames_received = 0
        self.shed_received = 0

    @property
    def streams(self) -> dict:
        return (self.hello or {}).get("streams", {})

    # ------------------------------------------------------------- lifecycle
    async def connect(self) -> dict:
        self._reader, self._writer = await asyncio.open_connection(self.host, self.port)
        # offer the full application protocol + every encoding the decoder
        # speaks; the gateway answers with what it will actually use (a v1
        # gateway ignores the extra fields — same fallback, from its side)
        await proto.write_message(self._writer, {
            "type": proto.HELLO,
            "protocol": proto.PROTOCOL,
            "encodings": list(ENCODINGS),
        })
        msg = await proto.read_message(self._reader)
        if msg is None or msg[0].get("type") != proto.HELLO_OK:
            raise proto.ProtocolError(f"handshake failed: {msg and msg[0]}")
        self.hello = msg[0]
        self._reader_task = asyncio.ensure_future(self._read_loop())
        return self.hello

    @property
    def protocol(self) -> int:
        """Negotiated application protocol (1 until connected)."""
        return int((self.hello or {}).get("protocol", 1))

    async def close(self) -> None:
        if self._writer is not None:
            try:
                await proto.write_message(self._writer, {"type": proto.BYE})
            except ConnectionError:
                pass
            self._writer.close()
        if self._reader_task is not None:
            await asyncio.gather(self._reader_task, return_exceptions=True)
        self._fail_pending(ConnectionError("client closed"))

    # -------------------------------------------------------------- requests
    async def submit_render(
        self, stream: str, cam: Camera, *, timestep: int = 0,
        gaze: tuple | None = None, budget_ms: float | None = None,
    ) -> asyncio.Future:
        """Fire one render; returns the future (fire-many, await-later).

        ``gaze`` (normalized (x, y) in [0, 1]) and ``budget_ms`` are the
        optional foveated-serving hints: the engine sharpens the gazed tile
        rows and coarsens the periphery to fit the render-time budget. Both
        ride as optional header fields a v1 gateway simply ignores."""
        seq = next(self._seq)
        fut = asyncio.get_running_loop().create_future()
        self._pending[seq] = {"kind": "render", "fut": fut}
        header = {
            "type": proto.RENDER, "seq": seq, "stream": stream,
            "timestep": int(timestep), "camera": proto.camera_to_wire(cam),
        }
        if gaze is not None:
            header["gaze"] = [float(gaze[0]), float(gaze[1])]
        if budget_ms is not None:
            header["budget_ms"] = float(budget_ms)
        await proto.write_message(self._writer, header)
        return fut

    async def render(
        self, stream: str, cam: Camera, *, timestep: int = 0,
        gaze: tuple | None = None, budget_ms: float | None = None,
    ) -> np.ndarray:
        """One frame (uint8 HxWx3). Raises ShedError if load-shed."""
        return await (await self.submit_render(
            stream, cam, timestep=timestep, gaze=gaze, budget_ms=budget_ms
        ))

    async def scrub(self, stream: str, cam: Camera, timesteps: list[int]) -> dict[int, np.ndarray]:
        """One camera across ``timesteps``; returns {timestep: frame}.
        Raises ShedError (naming the lost timesteps) if any were shed."""
        seq = next(self._seq)
        # dedupe (order-preserving): responses key by timestep, so duplicate
        # entries would leave the completion count unreachable forever
        ts = list(dict.fromkeys(int(t) for t in timesteps))
        fut = asyncio.get_running_loop().create_future()
        self._pending[seq] = {
            "kind": "scrub", "fut": fut, "want": len(ts), "acc": {}, "shed": [],
        }
        await proto.write_message(self._writer, {
            "type": proto.SCRUB, "seq": seq, "stream": stream,
            "timesteps": ts,
            "camera": proto.camera_to_wire(cam),
        })
        return await fut

    async def stats(self) -> dict:
        seq = next(self._seq)
        fut = asyncio.get_running_loop().create_future()
        self._pending[seq] = {"kind": "stats", "fut": fut}
        await proto.write_message(self._writer, {"type": proto.STATS, "seq": seq})
        return await fut

    async def metrics(self) -> dict:
        """The gateway's atomic typed-registry snapshot (protocol v2):
        ``{"metrics": {dotted name: value|histogram}, "trace": {...},
        "slo": {...}|None}`` — ``slo`` carries the gateway's live window
        state (p99, budget burn, ok/warn/breach) when SLO tracking is on."""
        seq = next(self._seq)
        fut = asyncio.get_running_loop().create_future()
        self._pending[seq] = {"kind": "metrics", "fut": fut}
        await proto.write_message(self._writer, {"type": proto.METRICS, "seq": seq})
        return await fut

    # ---------------------------------------------------------------- reader
    async def _read_loop(self) -> None:
        try:
            while True:
                msg = await proto.read_message(self._reader)
                if msg is None:
                    break
                self._route(*msg)
        except Exception as e:  # analysis: allow(hygiene.broad_except, ANY reader death — protocol violation, undecodable frame, version skew — must fail the in-flight futures loudly; a bare return would leave every awaiting render()/scrub()/stats() hanging forever)
            self._fail_pending(e)
            return
        self._fail_pending(ConnectionError("gateway closed the connection"))

    def _route(self, header: dict, payload: bytes) -> None:
        mtype = header.get("type")
        seq = header.get("seq")
        entry = self._pending.get(seq)
        if mtype == proto.FRAME:
            frame = self._decoder.decode(header["stream"], header, payload)
            self.frames_received += 1
            if entry is None:
                return  # response to a request we gave up on
            if entry["kind"] == "render":
                del self._pending[seq]
                if not entry["fut"].done():
                    entry["fut"].set_result(frame)
            else:  # scrub accumulates until every timestep is accounted for
                entry["acc"][int(header["timestep"])] = frame
                self._maybe_finish_scrub(seq, entry)
        elif mtype == proto.ERROR:
            code = header.get("code")
            if code == "shed":
                self.shed_received += 1
            if entry is None:
                return
            if entry["kind"] == "scrub" and code == "shed":
                entry["shed"].append(int(header.get("timestep", -1)))
                self._maybe_finish_scrub(seq, entry)
                return
            del self._pending[seq]
            err = ShedError if code == "shed" else RemoteRenderError
            if not entry["fut"].done():
                entry["fut"].set_exception(err(header.get("detail", code)))
        elif mtype == proto.STATS_OK and entry is not None:
            del self._pending[seq]
            if not entry["fut"].done():
                entry["fut"].set_result(header.get("report", {}))
        elif mtype == proto.METRICS_OK and entry is not None:
            del self._pending[seq]
            if not entry["fut"].done():
                entry["fut"].set_result(
                    {"metrics": header.get("metrics", {}),
                     "trace": header.get("trace", {}),
                     "slo": header.get("slo")}
                )

    def _maybe_finish_scrub(self, seq: int, entry: dict) -> None:
        if len(entry["acc"]) + len(entry["shed"]) < entry["want"]:
            return
        del self._pending[seq]
        if entry["fut"].done():
            return
        if entry["shed"]:
            entry["fut"].set_exception(
                ShedError(f"scrub lost timesteps {sorted(entry['shed'])} to load-shedding")
            )
        else:
            entry["fut"].set_result(entry["acc"])

    def _fail_pending(self, err: BaseException) -> None:
        pending, self._pending = self._pending, {}
        for entry in pending.values():
            if not entry["fut"].done():
                entry["fut"].set_exception(err)


class FrontendClient:
    """Blocking facade: the async client on a private event-loop thread."""

    def __init__(self, host: str = "127.0.0.1", port: int = 0, *, timeout: float = 120.0):
        self.timeout = timeout
        self._loop = asyncio.new_event_loop()
        self._thread = threading.Thread(
            target=self._loop.run_forever, name="gs-client", daemon=True
        )
        self._thread.start()
        self._cl = AsyncFrontendClient(host, port)
        self.hello = self._call(self._cl.connect())

    def _call(self, coro):
        return asyncio.run_coroutine_threadsafe(coro, self._loop).result(self.timeout)

    @property
    def streams(self) -> dict:
        return self._cl.streams

    def render(
        self, stream: str, cam: Camera, *, timestep: int = 0,
        gaze: tuple | None = None, budget_ms: float | None = None,
    ) -> np.ndarray:
        return self._call(self._cl.render(
            stream, cam, timestep=timestep, gaze=gaze, budget_ms=budget_ms
        ))

    def scrub(self, stream: str, cam: Camera, timesteps: list[int]) -> dict[int, np.ndarray]:
        return self._call(self._cl.scrub(stream, cam, timesteps))

    def stats(self) -> dict:
        return self._call(self._cl.stats())

    def metrics(self) -> dict:
        return self._call(self._cl.metrics())

    def close(self) -> None:
        if self._loop.is_running():
            try:
                self._call(self._cl.close())
            finally:
                self._loop.call_soon_threadsafe(self._loop.stop)
                self._thread.join(self.timeout)

    def __enter__(self) -> "FrontendClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
