"""Multi-stream session layer: many model timelines, one device pool.

**Streams.** A *stream* is one servable model timeline behind a string id — a
static trained scene (one timestep) or a ``TemporalCheckpointStore``-backed
insitu sequence (many). All streams share ONE :class:`RenderServer`: the
server's timeline is an integer axis, so the manager gives every stream a
disjoint block of global positions (``base + local_timestep``, stride 2^20)
and translates ids at the door. Sharing one server is the point — every
stream's requests coalesce into the same micro-batcher, share the same
in-flight ring, frame cache, and per-(shape, level, bucket) jit traces, so
adding a stream costs model memory, not a second serving stack.

**Sessions.** A *session* is one connected client: a bounded request queue,
shed accounting, and the per-connection delta-encoder state. Admission
control is oldest-drop load shedding: when a session's queue is full, the
oldest still-queued request is dropped (and answered with ``error/shed``)
rather than the newest — the viewer wants the freshest pose, and a bounded
queue keeps one firehosing client from starving every other session (the
per-session cap is the fairness mechanism; the shed counter is the metric).
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import threading
from typing import Iterable, Mapping

from repro.analysis import tsan
from repro.core import gaussians as G
from repro.core.config import GSConfig
from repro.core.projection import Camera
from repro.frontend import protocol as proto
from repro.frontend.encode import RAW8, TILES8, ZDELTA8, FrameEncoder
from repro.obs import MetricsRegistry, Obs
from repro.obs.clock import now as _now
from repro.serve_gs import RenderServer

STREAM_STRIDE = 1 << 20  # global-timeline block reserved per stream

STATIC, TIMELINE = "static", "timeline"


@dataclasses.dataclass(frozen=True)
class StreamInfo:
    """One registered timeline: wire-visible description + base offset."""

    stream_id: str
    kind: str               # STATIC | TIMELINE
    base: int               # global timeline position of local timestep 0
    timesteps: tuple[int, ...]  # local (client-visible) timesteps
    timestep_set: frozenset = frozenset()  # O(1) membership for resolve()

    def describe(self) -> dict:
        return {"kind": self.kind, "timesteps": list(self.timesteps)}


class SessionManager:
    """Registers streams on one shared ``RenderServer`` and owns its life."""

    def __init__(self, cfg: GSConfig, *, obs: Obs | None = None, **server_kw):
        self.cfg = cfg
        # one Obs bundle for the whole stack this manager fronts: the shared
        # RenderServer, its cache, every session, and the gateway all meter
        # onto this registry, so one reset()/snapshot() covers every tier
        self.obs = obs if obs is not None else Obs()
        self._server_kw = dict(server_kw)
        self.server: RenderServer | None = None
        self.streams: dict[str, StreamInfo] = {}
        self._next_base = 0
        # streams whose cached content was invalidated since the last
        # take_dirty(), mapped to the dirty tile rows (None = whole frame):
        # the gateway resets their wire delta chains — row-granular when the
        # server computed exact dirty tiles, so the next frame re-keys only
        # those tiles on the wire. Set on the render-executor thread, drained
        # on the loop thread -> locked.
        self._dirty_streams: dict[str, set[int] | None] = {}
        self._dirty_lock = threading.Lock()
        # opt-in runtime race sanitizer (REPRO_TSAN=1; no-op otherwise):
        # verifies the _dirty_lock discipline above actually holds at
        # runtime, including dict mutations the static pass can't see
        tsan.attach(self, name="SessionManager",
                    locks=("_dirty_lock",), dicts=("_dirty_streams",))

    # ------------------------------------------------------------- register
    def _register(
        self, stream_id: str, kind: str, entries: Iterable[tuple[int, G.GaussianModel]]
    ) -> StreamInfo:
        if stream_id in self.streams:
            raise ValueError(f"stream {stream_id!r} already registered")
        entries = list(entries)
        assert entries, f"stream {stream_id!r} has no timesteps"
        locals_ = [int(t) for t, _ in entries]
        assert all(0 <= t < STREAM_STRIDE for t in locals_), locals_
        base = self._next_base
        self._next_base += STREAM_STRIDE
        for t, params in entries:
            if self.server is None:
                self.server = RenderServer(
                    params, self.cfg, timestep=base + int(t), obs=self.obs,
                    **self._server_kw
                )
                self.server.add_invalidation_listener(self._on_invalidate)
            else:
                self.server.add_timestep(base + int(t), params)
        info = StreamInfo(stream_id, kind, base, tuple(locals_), frozenset(locals_))
        self.streams[stream_id] = info
        return info

    def register_static(self, stream_id: str, params: G.GaussianModel) -> StreamInfo:
        """One trained scene as a single-timestep stream."""
        return self._register(stream_id, STATIC, [(0, params)])

    def register_timeline(self, stream_id: str, source, timesteps=None) -> StreamInfo:
        """A temporal sequence as a scrubbable stream.

        ``source`` is anything with ``timesteps()`` and ``load(t)`` (a
        ``TemporalCheckpointStore``) or a ``{timestep: params}`` mapping."""
        if isinstance(source, Mapping):
            entries = sorted((int(t), p) for t, p in source.items())
        else:
            ts = timesteps if timesteps is not None else source.timesteps()
            entries = [(int(t), source.load(t)) for t in ts]
        return self._register(stream_id, TIMELINE, entries)

    # -------------------------------------------------------------- resolve
    def resolve(self, stream_id: str, timestep: int = 0) -> int:
        """(stream id, local timestep) -> global server timeline position."""
        info = self.streams.get(stream_id)
        if info is None:
            raise KeyError(f"unknown stream {stream_id!r} (have {sorted(self.streams)})")
        t = int(timestep)
        if t not in info.timestep_set:  # a full-timeline scrub resolves every
            raise KeyError(             # t on the loop thread: keep it O(1)
                f"stream {stream_id!r} has no timestep {t} (have {list(info.timesteps)})"
            )
        return info.base + t

    def describe(self) -> dict:
        """Wire-facing listing for ``hello_ok``."""
        return {sid: info.describe() for sid, info in self.streams.items()}

    # --------------------------------------------------------- invalidation
    def _on_invalidate(self, global_ts: int, rows=None) -> None:
        """Server invalidation listener: map the global timeline position
        back to its stream and mark its wire delta chains dirty. ``rows`` is
        the server's dirty tile-row set (None = whole frame); repeated
        invalidations before a drain accumulate — a None anywhere dominates
        (full reset), row sets union."""
        if rows is not None and not rows:
            return  # nothing dropped: wire chains stay valid
        for sid, info in self.streams.items():
            if info.base <= global_ts < info.base + STREAM_STRIDE:
                with self._dirty_lock:
                    if sid in self._dirty_streams:
                        prev = self._dirty_streams[sid]
                        if prev is None or rows is None:
                            self._dirty_streams[sid] = None
                        else:
                            prev.update(int(r) for r in rows)
                    else:
                        self._dirty_streams[sid] = (
                            None if rows is None else {int(r) for r in rows}
                        )
                return

    def take_dirty(self) -> dict[str, set[int] | None]:
        """Pop the streams invalidated since the last call (gateway loop):
        stream id -> dirty tile rows, or None for a whole-frame reset."""
        with self._dirty_lock:
            dirty, self._dirty_streams = self._dirty_streams, {}
        return dirty

    def invalidate(self, stream_id: str, timestep: int = 0, *, rows=None) -> int:
        """Invalidate a stream timestep's cached frames (all, or only the
        tile rows in ``rows``). The serving engine is single-threaded by
        contract — from a running gateway, route this through
        ``Gateway.run_on_engine`` like any other engine maintenance."""
        info = self.streams.get(stream_id)
        if info is None:
            raise KeyError(f"unknown stream {stream_id!r} (have {sorted(self.streams)})")
        assert self.server is not None
        return self.server.invalidate(info.base + int(timestep), rows=rows)

    # ------------------------------------------------------------ lifecycle
    def warmup(self) -> float:
        """Compile every (shape, level, bucket) variant across all streams.

        One representative timestep per stream suffices: timesteps within a
        stream are shape-uniform (fixed capacity), distinct streams may not
        be."""
        assert self.server is not None, "no streams registered"
        return self.server.warmup(
            timesteps=[info.base + info.timesteps[0] for info in self.streams.values()]
        )

    def close(self) -> int:
        """Close the shared server; returns failed queued requests."""
        if self.server is None:
            return 0
        return self.server.close()

    def __enter__(self) -> "SessionManager":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def report(self) -> dict:
        return {
            "streams": self.describe(),
            "server": self.server.report() if self.server is not None else None,
        }


# --------------------------------------------------------------------------
# per-connection sessions
# --------------------------------------------------------------------------
_session_ids = itertools.count()


@dataclasses.dataclass
class PendingRender:
    """One admitted-but-not-rendered request queued on a session."""

    session: "Session"
    seq: int
    stream_id: str
    timestep: int       # local (client-visible)
    global_ts: int      # resolved server timeline position
    cam: Camera
    t_admit: float
    scrub_last: bool = False  # final item of a scrub fan-out
    bulk: bool = False        # part of a multi-item (scrub) admission unit
    request_id: int = -1      # obs id minted at admit; joins the span tree
    # optional foveated-serving hints, passed through to the engine verbatim
    budget_ms: float | None = None
    gaze: tuple | None = None  # normalized (x, y) in [0, 1]


class Session:
    """One client connection's server-side state (queue, shed, encoder)."""

    def __init__(
        self,
        *,
        queue_limit: int,
        delta_encoding: bool = True,
        tile: tuple[int, int] = (16, 16),
        metrics: MetricsRegistry | None = None,
    ):
        assert queue_limit >= 1, queue_limit
        self.session_id = next(_session_ids)
        self.queue_limit = queue_limit
        self.queue: collections.deque[PendingRender] = collections.deque()
        self.delta_encoding = delta_encoding
        self.tile = (int(tile[0]), int(tile[1]))
        self.protocol = 1  # until the hello negotiates higher
        self.encoder = FrameEncoder(delta=delta_encoding)
        # per-connection lifetime tallies (stats() on the wire). The shared
        # registry additionally aggregates them across sessions under
        # sessions.* so one snapshot/reset covers the session tier too.
        self.shed = 0
        self.admitted = 0
        self.frames_sent = 0
        self.errors_sent = 0
        self._agg_admitted = metrics.counter("sessions.admitted") if metrics else None
        self._agg_shed = metrics.counter("sessions.shed") if metrics else None
        # queue residency (admit -> picked up by a wave, ms), aggregated
        # across sessions: the admission-side half of the served-latency
        # story the SLO window watches on gateway.request_ms
        self._h_queue_ms = metrics.histogram("sessions.queue_ms") if metrics else None
        self.t_connect = _now()

    def admit(self, pr: PendingRender, *, limit: int | None = None) -> PendingRender | None:
        """Queue one request; returns the request shed to make room (the
        OLDEST *sheddable* one), or None when nothing was evicted.

        ``limit`` stretches the cap for one admission (the gateway passes a
        scrub's fan-out size, bounded by the stream's registered timeline
        length). Shedding policy around ``bulk`` (scrub) items — an in-
        progress scrub is one unit of work and must not be nibbled apart:

        * a plain render never evicts a bulk item: if only bulk items are
          queued the queue stretches by one instead (a later render then
          sees THAT render as the oldest sheddable item, so the stretch is
          bounded at one entry past the bulk block);
        * a bulk item may evict bulk items of an OLDER scrub (a new scrub
          displaces a stale one — the oldest-drop rule applied at message
          granularity, which also bounds repeated-scrub queue growth) but
          never items of its own seq.
        """
        victim = None
        if len(self.queue) >= max(self.queue_limit, limit or 0):
            for i, cand in enumerate(self.queue):  # oldest-first scan
                if (not cand.bulk) or (pr.bulk and cand.seq != pr.seq):
                    victim = cand
                    del self.queue[i]
                    self.shed += 1
                    if self._agg_shed:
                        self._agg_shed.inc()
                    break
        self.queue.append(pr)
        self.admitted += 1
        if self._agg_admitted:
            self._agg_admitted.inc()
        return victim

    def negotiate(self, protocol, encodings: Iterable[str] | None) -> int:
        """Pick the session's application protocol + frame encoding from the
        peer's hello. A v1 hello (no ``protocol`` field, or no ``tiles8`` in
        its encodings) keeps the v1 zdelta8/rgb8 wire format; a v2 peer that
        offers ``tiles8`` gets changed-tile streaming. Replaces the encoder
        (no frame has been sent yet — hello is the first exchange)."""
        try:
            self.protocol = max(1, min(int(protocol), proto.PROTOCOL))
        except (TypeError, ValueError):
            self.protocol = 1
        offered = set(encodings) if encodings is not None else {RAW8, ZDELTA8}
        tiles = self.delta_encoding and self.protocol >= 2 and TILES8 in offered
        # never emit an encoding the peer did not offer: a raw-only decoder
        # (encodings=["rgb8"]) must get raw keyframes, not zdelta8
        delta = self.delta_encoding and (tiles or ZDELTA8 in offered)
        self.encoder = FrameEncoder(delta=delta, tiles=tiles, tile=self.tile)
        return self.protocol

    def take(self, n: int) -> list[PendingRender]:
        """Pop up to ``n`` queued requests (FIFO) for a dispatch wave."""
        out = [self.queue.popleft() for _ in range(min(n, len(self.queue)))]
        if self._h_queue_ms is not None and out:
            t = _now()
            for pr in out:
                self._h_queue_ms.observe((t - pr.t_admit) * 1e3)
        return out

    def stats(self) -> dict:
        return {
            "protocol": self.protocol,
            "admitted": self.admitted,
            "frames_sent": self.frames_sent,
            "shed": self.shed,
            "errors_sent": self.errors_sent,
            "queued_now": len(self.queue),
            "queue_limit": self.queue_limit,
            "encoder": self.encoder.stats(),
            "uptime_s": round(_now() - self.t_connect, 3),
        }
