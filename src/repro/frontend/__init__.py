"""Network frontend: asyncio gateway + multi-stream sessions over the
pipelined serving engine.

The delivery side of the paper's "real-time post hoc and in situ
visualization": remote clients speak a small versioned binary protocol
(``protocol``), an asyncio TCP gateway (``gateway``) admission-controls them
into per-session bounded queues, and a session layer (``sessions``) maps
string stream ids — static trained scenes and scrubbable insitu timelines —
onto ONE shared ``RenderServer`` so every stream's traffic coalesces into
the same micro-batches, cache, and jit traces. Frames travel as RGB8 or
zlib-compressed temporal deltas (``encode``), encoded off the event loop.

See ``repro.launch.frontend`` for the CLI and
``benchmarks/frontend_load.py`` for the localhost load methodology.
"""
from repro.frontend.client import (
    AsyncFrontendClient,
    FrontendClient,
    RemoteRenderError,
    ShedError,
)
from repro.frontend.encode import (
    CodecError,
    FrameDecoder,
    FrameEncoder,
    quantize_rgb8,
    tile_grid,
)
from repro.frontend.gateway import Gateway, GatewayThread
from repro.frontend.protocol import (
    ProtocolError,
    camera_from_wire,
    camera_to_wire,
    iter_messages,
    pack_message,
    read_message,
    write_message,
)
from repro.frontend.sessions import (
    PendingRender,
    Session,
    SessionManager,
    StreamInfo,
)

__all__ = [
    "AsyncFrontendClient",
    "CodecError",
    "FrameDecoder",
    "FrameEncoder",
    "FrontendClient",
    "Gateway",
    "GatewayThread",
    "PendingRender",
    "ProtocolError",
    "RemoteRenderError",
    "Session",
    "SessionManager",
    "ShedError",
    "StreamInfo",
    "camera_from_wire",
    "camera_to_wire",
    "iter_messages",
    "pack_message",
    "quantize_rgb8",
    "read_message",
    "tile_grid",
    "write_message",
]
