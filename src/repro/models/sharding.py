"""Logical-axis sharding rules (MaxText-style) for the transformer substrate.

Activations are annotated with logical names; a rules table maps them to mesh
axes. The GS pipeline's lesson (ship small projected state, not parameters)
shows up here as: activations move over "model", weights stay put.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import PartitionSpec as PS

_state = threading.local()


DEFAULT_RULES: dict[str, tuple[str, ...] | str | None] = {
    "batch": ("pod", "data"),     # missing mesh axes are dropped automatically
    "seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "kv_seq": None,
    "ff": "model",
    "experts": "model",
    "vocab": "model",
    "moe_d": "model",             # token-side d-shard inside the MoE block:
                                  # makes dispatch/combine gathers local and
                                  # turns the e<->d reshard into an all-to-all
    "fsdp": "data",               # weight sharding axis for large models
    "cache_seq": None,
    "state": None,
}


def current_rules():
    return getattr(_state, "rules", None)


def current_mesh():
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh_rules(mesh, rules=None):
    """Activate sharding annotations for model code built inside."""
    prev = (current_rules(), current_mesh())
    _state.rules = dict(DEFAULT_RULES, **(rules or {}))
    _state.mesh = mesh
    try:
        yield
    finally:
        _state.rules, _state.mesh = prev


def spec_for(*names: str | None) -> PS:
    """PartitionSpec for logical axis names under the active rules/mesh."""
    rules = current_rules()
    mesh = current_mesh()
    if rules is None or mesh is None:
        return PS()
    axes = []
    for nm in names:
        if nm is None:
            axes.append(None)
            continue
        ax = rules.get(nm)
        if ax is None:
            axes.append(None)
        elif isinstance(ax, str):
            axes.append(ax if ax in mesh.shape else None)
        else:
            present = tuple(a for a in ax if a in mesh.shape)
            axes.append(present if present else None)
    return PS(*axes)


def lshard(x: jax.Array, *names: str | None) -> jax.Array:
    """Annotate activation x with logical axis names (no-op without a mesh)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, jax.sharding.NamedSharding(mesh, spec_for(*names)))
