"""xLSTM blocks (Beck et al., arXiv:2405.04517): mLSTM + sLSTM.

mLSTM: matrix-memory LSTM with exponential gating. Train path uses a
chunkwise-parallel form (flash-linear-attention style) carrying the matrix
state C, normalizer n and log-scale stabilizer m across chunks — the TPU
adaptation of the paper's CUDA kernels. Decode is the plain recurrence.

sLSTM: scalar-memory LSTM with recurrent (per-head block-diagonal) weights;
inherently sequential -> lax.scan over time (the paper itself notes sLSTM is
not parallelizable).
"""
from __future__ import annotations

import jax
import numpy as np
import jax.numpy as jnp

from repro.models.common import dense_init, rmsnorm, rmsnorm_init
from repro.models.sharding import lshard

CHUNK = 64


def _dims(cfg):
    h = cfg.n_heads
    hd = cfg.d_model // h
    return h, hd


# ================================================================== mLSTM ==
def mlstm_init(key, cfg, dtype):
    d = cfg.d_model
    h, hd = _dims(cfg)
    ks = jax.random.split(key, 7)
    return {
        "wq": dense_init(ks[0], (d, d), dtype),
        "wk": dense_init(ks[1], (d, d), dtype),
        "wv": dense_init(ks[2], (d, d), dtype),
        "wi": dense_init(ks[3], (d, h), jnp.float32, scale=0.02),
        "wf": dense_init(ks[4], (d, h), jnp.float32, scale=0.02),
        "wo_gate": dense_init(ks[5], (d, d), dtype),
        "fbias": jnp.full((h,), 3.0, jnp.float32),  # open forget gates at init
        "norm": rmsnorm_init(d, dtype),
        "out_proj": dense_init(ks[6], (d, d), dtype),
    }


def _mlstm_qkvif(p, cfg, x):
    bsz, s, d = x.shape
    h, hd = _dims(cfg)
    q = (x @ p["wq"]).reshape(bsz, s, h, hd)
    k = (x @ p["wk"]).reshape(bsz, s, h, hd) / jnp.sqrt(hd).astype(x.dtype)
    v = (x @ p["wv"]).reshape(bsz, s, h, hd)
    ilog = (x.astype(jnp.float32) @ p["wi"])                  # (B,S,H) input gate logit
    flog = jax.nn.log_sigmoid(x.astype(jnp.float32) @ p["wf"] + p["fbias"])  # (B,S,H)
    return q, k, v, ilog, flog


def mlstm_train(p, cfg, x):
    bsz, s, d = x.shape
    h, hd = _dims(cfg)
    q, k, v, ilog, flog = _mlstm_qkvif(p, cfg, x)
    q = lshard(q, "batch", "seq", "heads", None)
    k = lshard(k, "batch", "seq", "heads", None)
    v = lshard(v, "batch", "seq", "heads", None)

    c = min(CHUNK, s)
    pad = (-s) % c
    if pad:
        q, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0))) for t in (q, k, v))
        ilog = jnp.pad(ilog, ((0, 0), (0, pad), (0, 0)))
        flog = jnp.pad(flog, ((0, 0), (0, pad), (0, 0)), constant_values=-1e4)
    nc = q.shape[1] // c

    def rs(t):
        return t.reshape(bsz, nc, c, *t.shape[2:]).transpose(1, 0, *range(2, t.ndim + 1))

    qs, ks_, vs = (rs(t).astype(jnp.float32) for t in (q, k, v))   # (nc,B,c,H,*)
    ils, fls = rs(ilog), rs(flog)                                   # (nc,B,c,H)

    def chunk_step(carry, inp):
        cstate, nstate, m = carry       # (B,H,hd,hd), (B,H,hd), (B,H)
        qc, kc, vc, il, fl = inp
        cf = jnp.cumsum(fl, axis=1)                                 # (B,c,H) inclusive
        total_f = cf[:, -1]                                         # (B,H)
        # intra-chunk log weights w_ij = cf_i - cf_j + il_j  (j <= i)
        wlog = cf[:, :, None, :] - cf[:, None, :, :] + il[:, None, :, :]   # (B,i,j,H)
        causal = jnp.tril(jnp.ones((wlog.shape[1], wlog.shape[1]), bool))
        wlog = jnp.where(causal[None, :, :, None], wlog, -jnp.inf)
        carry_log = cf + m[:, None]                                 # (B,i,H) carry-in scale per row
        m_row = jnp.maximum(jnp.max(wlog, axis=2), carry_log)       # (B,i,H)
        m_row = jnp.maximum(m_row, -1e30)
        wa = jnp.exp(wlog - m_row[:, :, None, :])                   # (B,i,j,H)
        cscale = jnp.exp(carry_log - m_row)                         # (B,i,H)

        scores = jnp.einsum("bihd,bjhd->bijh", qc, kc)              # (B,i,j,H)
        num_intra = jnp.einsum("bijh,bijh,bjhp->bihp", wa, scores, vc)
        num_carry = jnp.einsum("bihd,bhdp,bih->bihp", qc, cstate, cscale)
        den_intra = jnp.einsum("bijh,bijh->bih", wa, scores)
        den_carry = jnp.einsum("bihd,bhd,bih->bih", qc, nstate, cscale)
        num = num_intra + num_carry
        den = den_intra + den_carry
        denom = jnp.maximum(jnp.abs(den), jnp.exp(-m_row))          # xLSTM max(|n q|, 1) at scale m
        y = num / denom[..., None]                                  # (B,i,H,P)

        # ---- state to next chunk, restabilized at m_new
        m_new = jnp.maximum(m + total_f, jnp.max(total_f[:, None] - cf + il, axis=1))
        upd_log = total_f[:, None] - cf + il                        # (B,j,H)
        uw = jnp.exp(upd_log - m_new[:, None])                      # (B,j,H)
        c_next = cstate * jnp.exp(m + total_f - m_new)[:, :, None, None] + jnp.einsum(
            "bjh,bjhd,bjhp->bhdp", uw, kc, vc
        )
        n_next = nstate * jnp.exp(m + total_f - m_new)[:, :, None] + jnp.einsum("bjh,bjhd->bhd", uw, kc)
        return (c_next, n_next, m_new), y

    c0 = jnp.zeros((bsz, h, hd, hd), jnp.float32)
    n0 = jnp.zeros((bsz, h, hd), jnp.float32)
    m0 = jnp.full((bsz, h), -1e30, jnp.float32)
    _, ys = jax.lax.scan(chunk_step, (c0, n0, m0), (qs, ks_, vs, ils, fls))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(bsz, nc * c, h, hd)[:, :s]

    o = jax.nn.sigmoid(x @ p["wo_gate"])
    y = (y.reshape(bsz, s, d).astype(x.dtype)) * o
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    return lshard(y @ p["out_proj"], "batch", "seq", "embed")


def mlstm_cache_init(cfg, batch):
    h, hd = _dims(cfg)
    return {
        "c": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, h, hd), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
    }


def mlstm_decode(p, cfg, x, cache):
    bsz = x.shape[0]
    h, hd = _dims(cfg)
    q, k, v, ilog, flog = _mlstm_qkvif(p, cfg, x)   # seq dim = 1
    qf, kf, vf = (t[:, 0].astype(jnp.float32) for t in (q, k, v))
    il, fl = ilog[:, 0], flog[:, 0]                                 # (B,H)
    m_new = jnp.maximum(cache["m"] + fl, il)
    scale_old = jnp.exp(cache["m"] + fl - m_new)
    scale_in = jnp.exp(il - m_new)
    c_new = cache["c"] * scale_old[:, :, None, None] + jnp.einsum("bhd,bhp->bhdp", kf, vf) * scale_in[:, :, None, None]
    n_new = cache["n"] * scale_old[:, :, None] + kf * scale_in[:, :, None]
    num = jnp.einsum("bhd,bhdp->bhp", qf, c_new)
    den = jnp.einsum("bhd,bhd->bh", qf, n_new)
    denom = jnp.maximum(jnp.abs(den), jnp.exp(-m_new))
    y = (num / denom[..., None]).reshape(bsz, 1, h * hd).astype(x.dtype)
    o = jax.nn.sigmoid(x @ p["wo_gate"])
    y = y * o
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    return y @ p["out_proj"], {"c": c_new, "n": n_new, "m": m_new}


# ================================================================== sLSTM ==
def slstm_init(key, cfg, dtype):
    d = cfg.d_model
    h, hd = _dims(cfg)
    ks = jax.random.split(key, 3)
    return {
        "wx": dense_init(ks[0], (d, 4 * d), dtype),        # z,i,f,o pre-activations
        "r": (jax.random.normal(ks[1], (h, hd, 4 * hd)) * 0.02).astype(dtype),  # recurrent per head
        "fbias": jnp.full((d,), 3.0, jnp.float32),
        "norm": rmsnorm_init(d, dtype),
        "out_proj": dense_init(ks[2], (d, d), dtype),
    }


def _slstm_scan(wx, r, fbias):
    """Pure local recurrence. wx: (B,S,4,H,hd) f32. Returns ys (B,S,H,hd)."""
    bsz, s, four, h, hd = wx.shape

    def step(carry, inp):
        cs, ns, ms, ys = carry           # cell, normalizer, stabilizer, hidden
        pre = inp + jnp.einsum("bhd,hdk->bhk", ys, r).reshape(bsz, 4, h, hd)
        z = jnp.tanh(pre[:, 0])
        ilog = pre[:, 1]
        flog = jax.nn.log_sigmoid(pre[:, 2] + fbias.reshape(h, hd)[None])
        o = jax.nn.sigmoid(pre[:, 3])
        m_new = jnp.maximum(flog + ms, ilog)
        i_s = jnp.exp(ilog - m_new)
        f_s = jnp.exp(flog + ms - m_new)
        c_new = f_s * cs + i_s * z
        n_new = f_s * ns + i_s
        y = o * c_new / jnp.maximum(n_new, 1.0)
        return (c_new, n_new, m_new, y), y

    zeros = jnp.zeros((bsz, h, hd), jnp.float32)
    init = (zeros, zeros, jnp.full((bsz, h, hd), -1e30, jnp.float32), zeros)
    _, ys = jax.lax.scan(step, init, wx.transpose(1, 0, 2, 3, 4))
    return ys.transpose(1, 0, 2, 3)


def slstm_train(p, cfg, x):
    bsz, s, d = x.shape
    h, hd = _dims(cfg)
    wx = (x @ p["wx"]).reshape(bsz, s, 4, h, hd).astype(jnp.float32)
    r = p["r"].astype(jnp.float32)
    fbias = p["fbias"]

    # Recurrent-scan sharding (§Perf xlstm iteration 2): run the time scan
    # under shard_map — batch stays on "data", everything else replicated, so
    # the S sequential steps emit ZERO collectives. Left to GSPMD, the loop
    # body re-shards per step (12k+ tiny all-reduces per train step at 4k).
    from repro.models.sharding import current_mesh, current_rules
    from jax.sharding import PartitionSpec as PS

    mesh = current_mesh()
    if mesh is None:
        ys = _slstm_scan(wx, r, fbias)
    else:
        batch_rule = (current_rules() or {}).get("batch") or ("pod", "data")
        baxes = tuple(a for a in batch_rule if a in mesh.shape)
        bspec = baxes if bsz % max(
            1, int(np.prod([mesh.shape[a] for a in baxes]))
        ) == 0 else None
        from repro.core.sharding import shard_map

        ys = shard_map(  # analysis: allow(retrace.jit_outside_factory, runs under the caller's jitted train step: constructed once per outer trace, not per call)
            _slstm_scan,
            mesh=mesh,
            in_specs=(PS(bspec), PS(), PS()),
            out_specs=PS(bspec),
            check_vma=False,
        )(wx, r, fbias)
    y = ys.reshape(bsz, s, d).astype(x.dtype)
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    return lshard(y @ p["out_proj"], "batch", "seq", "embed")


def slstm_cache_init(cfg, batch):
    h, hd = _dims(cfg)
    z = jnp.zeros((batch, h, hd), jnp.float32)
    return {"c": z, "n": z, "m": jnp.full((batch, h, hd), -1e30, jnp.float32), "y": z}


def slstm_decode(p, cfg, x, cache):
    bsz = x.shape[0]
    h, hd = _dims(cfg)
    wx = (x[:, 0] @ p["wx"]).reshape(bsz, 4, h, hd).astype(jnp.float32)
    pre = wx + jnp.einsum("bhd,hdk->bhk", cache["y"], p["r"].astype(jnp.float32)).reshape(bsz, 4, h, hd)
    z = jnp.tanh(pre[:, 0])
    ilog = pre[:, 1]
    flog = jax.nn.log_sigmoid(pre[:, 2] + p["fbias"].reshape(h, hd)[None])
    o = jax.nn.sigmoid(pre[:, 3])
    m_new = jnp.maximum(flog + cache["m"], ilog)
    i_s = jnp.exp(ilog - m_new)
    f_s = jnp.exp(flog + cache["m"] - m_new)
    c_new = f_s * cache["c"] + i_s * z
    n_new = f_s * cache["n"] + i_s
    y = o * c_new / jnp.maximum(n_new, 1.0)
    d = h * hd
    out = y.reshape(bsz, 1, d).astype(x.dtype)
    out = rmsnorm(p["norm"], out, cfg.norm_eps)
    new_cache = {"c": c_new, "n": n_new, "m": m_new, "y": y}
    return out @ p["out_proj"], new_cache
