"""Parameter / batch / cache PartitionSpecs (rule-based, shape-aware).

Specs are derived from leaf names with divisibility checks against the mesh,
so the same rules serve every architecture and mesh. Stacked leading layer
dims are padded with None automatically (rules describe trailing dims).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as PS

from repro.models.config import ModelConfig


def _div(n: int, mesh, axis) -> bool:
    if axis is None:
        return True
    if isinstance(axis, tuple):
        size = int(np.prod([mesh.shape[a] for a in axis]))
    else:
        if axis not in mesh.shape:
            return False
        size = mesh.shape[axis]
    return n % size == 0


def _checked(spec_tail: tuple, shape: tuple, mesh) -> PS:
    """Pad leading Nones to rank; drop axes that don't divide."""
    rank = len(shape)
    tail = list(spec_tail[-rank:]) if len(spec_tail) > rank else list(spec_tail)
    full = [None] * (rank - len(tail)) + tail
    out = []
    for dim, ax in zip(shape, full):
        out.append(ax if (ax is not None and _div(dim, mesh, ax)) else None)
    return PS(*out)


_IN_OUT = {"wq", "wk", "wv", "wi", "wg", "wo_gate", "in_proj", "wx"}
_OUT_IN = {"wo", "out_proj"}


def param_pspecs(cfg: ModelConfig, params: Any, mesh, *, fsdp: bool = True) -> Any:
    """Pytree of PartitionSpec matching `params` (arrays or ShapeDtypeStructs)."""
    fs = "data" if fsdp else None
    if getattr(cfg, "pure_dp", False):
        # no tensor parallelism: weights replicated over "model", fsdp over data
        def rule_dp(path, leaf):
            spec = rule(path, leaf)
            return PS(*[None if a == "model" else a for a in spec])

    def rule(path, leaf):
        names = [p.key for p in path if hasattr(p, "key")]
        name = names[-1] if names else ""
        in_moe = "moe" in names or "shared" in names
        shape = leaf.shape
        if name == "embedding":
            return _checked(("model", fs), shape, mesh)
        if in_moe and name in ("wi", "wg", "wo") and len(shape) >= 3:
            # (E, d, ff) / (E, ff, d): expert-parallel only. FSDP on the
            # contraction dim forced a per-layer partial-sum all-reduce of
            # the (B,e,cap,f) activations (§Perf kimi iteration 2) — expert
            # weights are replicated across "data" instead.
            return _checked(("model", None, None), shape, mesh)
        if name == "router":
            return _checked((fs, None), shape, mesh)
        if name in _IN_OUT:
            return _checked((fs, "model"), shape, mesh)
        if name in _OUT_IN:
            return _checked(("model", fs), shape, mesh)
        if name == "conv_w":
            return _checked((None, "model"), shape, mesh)
        if name in ("a_log", "d_skip", "dt_bias", "fbias"):
            return _checked(("model",), shape, mesh)
        if name == "r":  # sLSTM recurrent (H, hd, 4hd)
            return _checked(("model", None, None), shape, mesh)
        return PS()  # norms, scalars: replicated

    if getattr(cfg, "pure_dp", False):
        return jax.tree_util.tree_map_with_path(rule_dp, params)
    return jax.tree_util.tree_map_with_path(rule, params)


def batch_pspecs(cfg: ModelConfig, batch: Any, mesh) -> Any:
    axes = ("pod", "data", "model") if getattr(cfg, "pure_dp", False) else ("pod", "data")
    baxes = tuple(a for a in axes if a in mesh.shape)

    def rule(path, leaf):
        return _checked((baxes,) + (None,) * (len(leaf.shape) - 1), leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(rule, batch)


def cache_pspecs(cfg: ModelConfig, cache: Any, mesh) -> Any:
    """Decode-cache specs: batch->data when divisible, else seq->data (long
    context, batch 1); heads->model when divisible, else head_dim->model."""
    def rule(path, leaf):
        names = [p.key for p in path if hasattr(p, "key")]
        name = names[-1] if names else ""
        shape = leaf.shape
        if name in ("k", "v", "cross_k", "cross_v") or (names and names[-2:] and name in ("k", "v")):
            # (..., B, S, Hkv, hd)
            b, s, hkv, hd = shape[-4], shape[-3], shape[-2], shape[-1]
            baxis = "data" if _div(b, mesh, "data") else None
            haxis = "model" if _div(hkv, mesh, "model") else None
            # kv_heads not divisible: shard the cache SEQ dim over "model"
            # instead of head_dim — attention then partial-sums a tiny
            # (B,H,hd) output rather than all-gathering the cache
            # (§Perf decode follow-up; measured on qwen3 decode_32k)
            saxis = None
            if haxis is None and _div(s, mesh, "model"):
                saxis = "model"
            if baxis is None and saxis is None and _div(s, mesh, "data"):
                saxis = "data"
            return _checked((baxis, saxis, haxis, None), shape, mesh)
        if name == "state":      # mamba (B,H,N,P)
            return _checked(("data", "model", None, None), shape, mesh)
        if name == "conv":       # (B, W-1, C)
            return _checked(("data", None, "model"), shape, mesh)
        if name == "c" and len(shape) == 4:   # mlstm (B,H,hd,hd)
            return _checked(("data", "model", None, None), shape, mesh)
        if name in ("c", "n", "m", "y"):
            return _checked(("data", "model", None), shape, mesh)
        return _checked(("data",) + (None,) * (len(shape) - 1), shape, mesh)

    return jax.tree_util.tree_map_with_path(rule, cache)


def to_named(tree_specs, mesh):
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), tree_specs)
