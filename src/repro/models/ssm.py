"""Mamba2 (SSD) block — chunked state-space duality algorithm, pure JAX.

Train path: intra-chunk quadratic term + inter-chunk recurrent scan (the
SSD decomposition from the Mamba2 paper), chunk size 64 to bound the
(c, c, H) decay tensor; heads shard over "model". Decode path: single-step
recurrent state update, O(1) per token — this is what makes long_500k
feasible for the SSM/hybrid architectures.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, rmsnorm, rmsnorm_init
from repro.models.sharding import lshard

CONV_WIDTH = 4
CHUNK = 64


def mamba2_dims(cfg):
    di = cfg.ssm_expand * cfg.d_model
    h = cfg.ssm_heads
    p = di // h
    n = cfg.ssm_state
    return di, h, p, n


def mamba2_init(key, cfg, dtype):
    d = cfg.d_model
    di, h, p, n = mamba2_dims(cfg)
    conv_ch = di + 2 * n
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di + 2 * n + h), dtype),
        "conv_w": (jax.random.normal(ks[1], (CONV_WIDTH, conv_ch)) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": rmsnorm_init(di, dtype),
        "out_proj": dense_init(ks[2], (di, d), dtype),
    }


def _causal_depthwise_conv(x, w, b):
    """x: (B,S,C), w: (W,C), b: (C,). Causal depthwise conv."""
    bsz, s, c = x.shape
    xw = jnp.pad(x, ((0, 0), (CONV_WIDTH - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xw.transpose(0, 2, 1)[:, :, None, :],                       # (B,C,1,S+W-1)
        w.T[:, None, None, :],                                      # (C,1,1,W)
        (1, 1),
        "VALID",
        feature_group_count=c,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )[:, :, 0, :].transpose(0, 2, 1)
    return out + b


def _split_proj(p, cfg, xproj):
    di, h, hp, n = mamba2_dims(cfg)
    z = xproj[..., :di]
    xc = xproj[..., di : 2 * di + 2 * n]   # conv channels: x, B, C
    dt = xproj[..., 2 * di + 2 * n :]      # (..., H)
    return z, xc, dt


def mamba2_train(p, cfg, x):
    """x: (B,S,d) -> (B,S,d)."""
    bsz, s, d = x.shape
    di, h, hp, n = mamba2_dims(cfg)
    proj = x @ p["in_proj"]
    z, xc, dt = _split_proj(p, cfg, proj)
    xc = jax.nn.silu(_causal_depthwise_conv(xc, p["conv_w"], p["conv_b"]))
    xh = xc[..., :di].reshape(bsz, s, h, hp)
    bmat = xc[..., di : di + n]            # (B,S,N)
    cmat = xc[..., di + n :]               # (B,S,N)
    xh = lshard(xh, "batch", "seq", "heads", None)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])     # (B,S,H)
    a = -jnp.exp(p["a_log"])                                        # (H,)
    da = dt * a                                                     # (B,S,H) negative

    c = CHUNK
    pad = (-s) % c
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        da = jnp.pad(da, ((0, 0), (0, pad), (0, 0)))
    nc = xh.shape[1] // c
    xh_ = xh.reshape(bsz, nc, c, h, hp)
    b_ = bmat.reshape(bsz, nc, c, n).astype(jnp.float32)
    c_ = cmat.reshape(bsz, nc, c, n).astype(jnp.float32)
    dt_ = dt.reshape(bsz, nc, c, h)
    da_ = da.reshape(bsz, nc, c, h)

    cums = jnp.cumsum(da_, axis=2)                                  # (B,nc,c,H) inclusive
    # ---- intra-chunk (quadratic within chunk)
    cb = jnp.einsum("bnis,bnjs->bnij", c_, b_)                      # (B,nc,c,c)
    causal = jnp.tril(jnp.ones((c, c), bool))
    # mask in LOG space before exp: the j>i upper triangle would otherwise
    # overflow exp() and poison the backward pass with inf*0 NaNs
    dlog = cums[:, :, :, None, :] - cums[:, :, None, :, :]          # (B,nc,c,c,H)
    decay = jnp.exp(jnp.where(causal[None, None, :, :, None], dlog, -1e30))
    w = cb[..., None] * decay * dt_[:, :, None]
    y_intra = jnp.einsum("bnijh,bnjhp->bnihp", w, xh_.astype(jnp.float32))

    # ---- inter-chunk recurrence
    chunk_total = cums[:, :, -1, :]                                 # (B,nc,H)
    state_in = jnp.einsum(
        "bnjh,bnjs,bnjhp->bnhsp",
        jnp.exp(chunk_total[:, :, None] - cums) * dt_,
        b_,
        xh_.astype(jnp.float32),
    )  # (B,nc,H,N,P)

    def step(s_prev, inp):
        s_chunk, tot = inp                                          # (B,H,N,P), (B,H)
        s_new = s_prev * jnp.exp(tot)[:, :, None, None] + s_chunk
        return s_new, s_prev

    s0 = jnp.zeros((bsz, h, n, hp), jnp.float32)
    _, s_prevs = jax.lax.scan(step, s0, (state_in.transpose(1, 0, 2, 3, 4), chunk_total.transpose(1, 0, 2)))
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)                      # (B,nc,H,N,P) state before chunk
    y_inter = jnp.einsum("bnis,bnih,bnhsp->bnihp", c_, jnp.exp(cums), s_prevs)

    y = (y_intra + y_inter).reshape(bsz, nc * c, h, hp)[:, :s]
    y = y + p["d_skip"][None, None, :, None] * xh[:, :s].astype(jnp.float32)
    y = y.reshape(bsz, s, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    return lshard(y @ p["out_proj"], "batch", "seq", "embed")


def mamba2_cache_init(cfg, batch, dtype):
    di, h, hp, n = mamba2_dims(cfg)
    return {
        "state": jnp.zeros((batch, h, n, hp), jnp.float32),
        "conv": jnp.zeros((batch, CONV_WIDTH - 1, di + 2 * n), dtype),
    }


def mamba2_decode(p, cfg, x, cache):
    """x: (B,1,d). Returns (y (B,1,d), new_cache)."""
    bsz = x.shape[0]
    di, h, hp, n = mamba2_dims(cfg)
    proj = x[:, 0] @ p["in_proj"]                                   # (B, ...)
    z, xc, dt = _split_proj(p, cfg, proj)
    conv_in = jnp.concatenate([cache["conv"], xc[:, None]], axis=1)  # (B,W,Cc)
    xc = jax.nn.silu(jnp.einsum("bwc,wc->bc", conv_in, p["conv_w"]) + p["conv_b"])
    new_conv = conv_in[:, 1:]

    xh = xc[:, :di].reshape(bsz, h, hp).astype(jnp.float32)
    bvec = xc[:, di : di + n].astype(jnp.float32)
    cvec = xc[:, di + n :].astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])     # (B,H)
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt * a)                                         # (B,H)
    state = cache["state"] * decay[:, :, None, None] + jnp.einsum(
        "bh,bs,bhp->bhsp", dt, bvec, xh
    )
    y = jnp.einsum("bs,bhsp->bhp", cvec, state) + p["d_skip"][None, :, None] * xh
    y = y.reshape(bsz, 1, di).astype(x.dtype)
    y = y * jax.nn.silu(z[:, None])
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    return y @ p["out_proj"], {"state": state, "conv": new_conv}
