"""Public model API: init / train_step / serve_step factories.

These are the functions the launcher jits (and the dry-run lowers) — one
code path for smoke tests (1 CPU device) and the 512-chip mesh.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import common as C
from repro.models import lm as L
from repro.models.config import ModelConfig
from repro.models.sharding import lshard


# ------------------------------------------------------------- cache init
def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype=None) -> dict:
    dtype = dtype or C.dtype_of(cfg)
    unit, n_units, rem = L.layer_plan(cfg)
    cache: dict[str, Any] = {}

    def one(kind):
        return L._layer_cache_init(cfg, kind, batch, cache_len, dtype)

    if cfg.arch_type == "zamba":
        period = max(cfg.attn_every, 1)

        # stacked mamba caches for the double-unit scan + per-invocation attn caches
        def stack_caches(n, inner):
            return jax.tree_util.tree_map(lambda x: jnp.broadcast_to(x, (n,) + x.shape), inner)

        cache["units"] = {
            "a": stack_caches(n_units, stack_caches(period, one("mamba"))),
            "b": stack_caches(n_units, stack_caches(period, one("mamba"))),
            "attn_a": stack_caches(n_units, one("attn_global")),
            "attn_b": stack_caches(n_units, one("attn_global")),
        }
        cache["rem"] = [one("mamba") for _ in rem]
        n_rem_attn = len(rem) // period
        cache["rem_attn"] = [one("attn_global") for _ in range(n_rem_attn)]
        return cache

    if "units" in _params_layout(cfg):
        cache["units"] = {
            f"slot{i}": jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x, (n_units,) + x.shape), one(kind)
            )
            for i, kind in enumerate(unit)
        }
    else:
        cache["flat"] = [one(unit[i % len(unit)]) for i in range(n_units * len(unit))]
    cache["rem"] = [one(k) for k in rem]
    if cfg.arch_type == "whisper":
        # cross-attention K/V computed once at prefill from the encoder output
        cache["cross_k"] = [
            jnp.zeros((batch, cfg.n_audio_ctx, cfg.n_kv_heads, cfg.hd), dtype) for _ in range(cfg.n_layers)
        ]
        cache["cross_v"] = [
            jnp.zeros((batch, cfg.n_audio_ctx, cfg.n_kv_heads, cfg.hd), dtype) for _ in range(cfg.n_layers)
        ]
    return cache


def _params_layout(cfg: ModelConfig) -> set[str]:
    unit, n_units, _ = L.layer_plan(cfg)
    if cfg.arch_type == "zamba" and cfg.scan_layers:
        return {"units"}
    if cfg.scan_layers and n_units > 1:
        return {"units"}
    return {"flat_layers"}


# ------------------------------------------------------------- decode stack
def backbone_decode(cfg: ModelConfig, params, cache, x, pos, mrope_positions=None):
    unit, n_units, rem = L.layer_plan(cfg)

    if cfg.arch_type == "zamba":
        return _zamba_decode(cfg, params, cache, x, pos)

    if "units" in params:
        def body(xc, inp):
            unit_params, unit_cache = inp
            new_caches = {}
            for i, kind in enumerate(unit):
                xc, nc = L._layer_decode(
                    cfg, kind, unit_params[f"slot{i}"], xc, unit_cache[f"slot{i}"], pos, mrope_positions
                )
                new_caches[f"slot{i}"] = nc
            return xc, new_caches

        x, new_unit_caches = jax.lax.scan(body, x, (params["units"], cache["units"]))
        cache = dict(cache, units=new_unit_caches)
    else:
        new_flat = []
        for i, lp in enumerate(params.get("flat_layers", [])):
            if cfg.arch_type == "whisper":
                x, nc = L._layer_decode(cfg, unit[i % len(unit)], lp, x, cache["flat"][i], pos)
                # cross attention against precomputed encoder K/V
                cp = params["cross_layers"][i]
                x = L._cross_attend(cfg, cp, x, cache["cross_k"][i], cache["cross_v"][i])
            else:
                x, nc = L._layer_decode(cfg, unit[i % len(unit)], lp, x, cache["flat"][i], pos)
            new_flat.append(nc)
        cache = dict(cache, flat=new_flat)
    new_rem = []
    for (kind, lp), rc in zip(zip(rem, params["rem_layers"]), cache["rem"]):
        x, nc = L._layer_decode(cfg, kind, lp, x, rc, pos, mrope_positions)
        new_rem.append(nc)
    cache = dict(cache, rem=new_rem)
    return C.rmsnorm(params["final_norm"], x, cfg.norm_eps), cache


def _zamba_decode(cfg, params, cache, x, pos):
    period = max(cfg.attn_every, 1)
    sa, sb = params["shared_attn"]

    def half(xc, unit_params, unit_cache, shared, attn_cache):
        def body(carry, inp):
            xc2 = carry
            lp, lc = inp
            xc2, nc = L._layer_decode(cfg, "mamba", lp, xc2, lc, pos)
            return xc2, nc

        xc, ncs = jax.lax.scan(body, xc, (unit_params, unit_cache))
        xc, na = L._layer_decode(cfg, "attn_global", shared, xc, attn_cache, pos)
        return xc, ncs, na

    def double(xc, inp):
        up, uc = inp
        xc, nca, naa = half(xc, up["a"], uc["a"], sa, uc["attn_a"])
        xc, ncb, nab = half(xc, up["b"], uc["b"], sb, uc["attn_b"])
        return xc, {"a": nca, "b": ncb, "attn_a": naa, "attn_b": nab}

    x, new_units = jax.lax.scan(double, x, (params["units"], cache["units"]))
    new_rem, new_rem_attn = [], []
    ai = 0
    for i, (lp, rc) in enumerate(zip(params["rem_layers"], cache["rem"])):
        x, nc = L._layer_decode(cfg, "mamba", lp, x, rc, pos)
        new_rem.append(nc)
        if (i + 1) % period == 0 and ai < len(cache["rem_attn"]):
            x, na = L._layer_decode(cfg, "attn_global", sa, x, cache["rem_attn"][ai], pos)
            new_rem_attn.append(na)
            ai += 1
    cache = dict(cache, units=new_units, rem=new_rem, rem_attn=new_rem_attn)
    return C.rmsnorm(params["final_norm"], x, cfg.norm_eps), cache


# ------------------------------------------------------------- optimizer
def adamw_init(params):
    z = jax.tree_util.tree_map(lambda x: jnp.zeros_like(x, dtype=jnp.float32), params)
    return {"m": z, "v": jax.tree_util.tree_map(jnp.copy, z), "count": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, opt, *, lr=3e-4, b1=0.9, b2=0.95, eps=1e-8, wd=0.1):
    count = opt["count"] + 1
    c = count.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * gf * gf
        mhat = m2 / (1 - b1**c)
        vhat = v2 / (1 - b2**c)
        step = mhat / (jnp.sqrt(vhat) + eps) + wd * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m2, v2

    out = jax.tree_util.tree_map(upd, params, grads, opt["m"], opt["v"])
    leaves, td = jax.tree_util.tree_flatten(out, is_leaf=lambda t: isinstance(t, tuple) and len(t) == 3)
    p2 = jax.tree_util.tree_unflatten(td, [l[0] for l in leaves])
    m2 = jax.tree_util.tree_unflatten(td, [l[1] for l in leaves])
    v2 = jax.tree_util.tree_unflatten(td, [l[2] for l in leaves])
    return p2, {"m": m2, "v": v2, "count": count}


# ------------------------------------------------------------- train step
def compute_loss(cfg: ModelConfig, params, batch) -> jax.Array:
    if cfg.arch_type == "whisper":
        x = L.whisper_train(cfg, params, batch["audio_embeds"], batch["tokens"])
    elif cfg.arch_type == "vlm":
        x = batch["embeds"].astype(C.dtype_of(cfg))
        x = lshard(x, "batch", "seq", "embed")
        x = L.backbone_train(cfg, params, x, None, mrope_positions=batch["positions3"])
    else:
        tokens = batch["tokens"]
        x = C.embed_lookup(params["embed"], tokens)
        positions = jnp.broadcast_to(jnp.arange(tokens.shape[1])[None], tokens.shape)
        x = L.backbone_train(cfg, params, x, positions)
    return C.chunked_ce_loss(params["embed"], x, batch["labels"])


def make_train_step(cfg: ModelConfig, *, lr: float = 3e-4):
    def train_step(params, opt, batch):
        loss, grads = jax.value_and_grad(lambda p: compute_loss(cfg, p, batch))(params)
        params, opt = adamw_update(params, grads, opt, lr=lr)
        return params, opt, {"loss": loss}

    return train_step


# ------------------------------------------------------------- prefill step
def make_prefill_step(cfg: ModelConfig):
    """Full forward over the prompt, returning last-position logits.

    (Cache population is the same compute plus pure HBM traffic — counted
    analytically in the roofline's memory term; see DESIGN.md.)
    """

    def prefill_step(params, batch):
        if cfg.arch_type == "whisper":
            x = L.whisper_train(cfg, params, batch["audio_embeds"], batch["tokens"])
        elif cfg.arch_type == "vlm":
            x = lshard(batch["embeds"].astype(C.dtype_of(cfg)), "batch", "seq", "embed")
            x = L.backbone_train(cfg, params, x, None, mrope_positions=batch["positions3"])
        else:
            tokens = batch["tokens"]
            x = C.embed_lookup(params["embed"], tokens)
            positions = jnp.broadcast_to(jnp.arange(tokens.shape[1])[None], tokens.shape)
            x = L.backbone_train(cfg, params, x, positions)
        return C.lm_logits(params["embed"], x[:, -1:])

    return prefill_step


# ------------------------------------------------------------- serve step
def make_serve_step(cfg: ModelConfig):
    """One-token decode step against a KV/state cache."""

    def serve_step(params, cache, tokens, pos):
        # tokens: (B,1) int32; pos: () int32
        x = C.embed_lookup(params["embed"], tokens)
        mrope = None
        if cfg.arch_type == "vlm":
            p3 = jnp.broadcast_to(pos, (tokens.shape[0], 1, 3)).astype(jnp.int32)
            mrope = p3
        x, cache = backbone_decode(cfg, params, cache, x, pos, mrope_positions=mrope)
        logits = C.lm_logits(params["embed"], x)
        return logits, cache

    return serve_step
