"""Architecture configuration for the assigned model pool."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str               # dense | moe | xlstm | zamba | whisper | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None         # default d_model // n_heads
    qk_norm: bool = False                  # qwen3-style per-head q/k RMSNorm
    rope_theta: float = 10_000.0
    tie_embeddings: bool = True
    norm_eps: float = 1e-6

    # sliding-window / local:global pattern (gemma3): e.g. "LLLLLG" repeats
    sliding_window: Optional[int] = None
    layer_pattern: Optional[str] = None

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    n_shared_experts: int = 0              # dense experts always on (kimi/moonshot style)

    # SSM (mamba2 / zamba hybrid)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_expand: int = 2
    attn_every: int = 0                    # zamba: shared attn block period

    # xLSTM: pattern of m/s blocks, e.g. "MMMMMMMS" repeats
    xlstm_pattern: str = "M"

    # whisper (enc-dec)
    n_enc_layers: int = 0
    n_audio_ctx: int = 0                   # encoder frames (post-conv)

    # vlm
    mrope_sections: tuple[int, int, int] = (0, 0, 0)
    n_patches: int = 0                     # image patch embeddings per sample (stub frontend)

    # numerics / compile strategy
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True

    # distribution strategy: small models waste the "model" axis on 64-wide
    # tensor shards whose TP psums dwarf their compute — run them pure-DP
    # with the batch sharded over EVERY mesh axis instead (§Perf xlstm iter 4)
    pure_dp: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def param_count(self) -> int:
        """Analytic total parameter count (embeddings + blocks)."""
        d, hd = self.d_model, self.hd
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        att = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) + (self.n_heads * hd) * d
        if self.arch_type in ("dense", "vlm", "moe"):
            if self.is_moe:
                ff = 3 * d * self.moe_d_ff * self.n_experts + d * self.n_experts  # router
                ff += 3 * d * self.moe_d_ff * self.n_shared_experts
            else:
                ff = 3 * d * self.d_ff
            per_layer = att + ff + 2 * d
            return emb + self.n_layers * per_layer
        if self.arch_type == "xlstm":
            di = self.ssm_expand * d
            per_layer = 4 * d * di + 2 * d  # qkv/gates + out proj (approx)
            return emb + self.n_layers * per_layer
        if self.arch_type == "zamba":
            di = self.ssm_expand * d
            mamba = 2 * d * di + di * d + di * (2 * self.ssm_state) + 2 * d
            n_attn = self.n_layers // max(self.attn_every, 1)
            return emb + self.n_layers * mamba + 2 * (att + 3 * d * self.d_ff) + n_attn * 0
        if self.arch_type == "whisper":
            enc = self.n_enc_layers * (att + 3 * d * self.d_ff + 2 * d)
            dec = self.n_layers * (2 * att + 3 * d * self.d_ff + 3 * d)
            return emb + enc + dec
        return emb + self.n_layers * (att + 3 * d * self.d_ff + 2 * d)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k + shared experts only)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        all_experts = 3 * d * self.moe_d_ff * self.n_experts * self.n_layers
        active = 3 * d * self.moe_d_ff * (self.top_k + self.n_shared_experts) * self.n_layers
        return full - all_experts + active
