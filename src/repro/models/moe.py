"""Mixture-of-Experts layer: top-k router + sort-based capacity dispatch.

TPU-native dispatch (no ragged ops): tokens are argsorted by expert id,
packed into a fixed (E, C, d) buffer (capacity drop beyond C), processed with
one batched einsum whose expert dim is sharded over "model" (expert
parallelism), then unsorted and combined. FLOPs stay within capacity_factor
of the ideal 6*N_active*D, which the roofline analysis relies on.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, mlp, mlp_init
from repro.models.sharding import lshard


def moe_init(key, cfg, dtype):
    d, ff, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), jnp.float32, scale=0.02),
        "wi": dense_init(ks[1], (e, d, ff), dtype),
        "wg": dense_init(ks[2], (e, d, ff), dtype),
        "wo": dense_init(ks[3], (e, ff, d), dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks[4], d, ff * cfg.n_shared_experts, dtype)
    return p


def moe_apply(p, cfg, x):
    """x: (B,S,d) -> (out (B,S,d), aux_losses dict).

    Dispatch groups are per batch row (vmapped), so every intermediate keeps
    a leading B dim sharded over "data" and an expert dim sharded over
    "model" — no global replicated token buffer ever materializes. (§Perf
    iteration 1: the flat global-dispatch formulation forced GSPMD to
    all-reduce an (E*cap, d) buffer per layer — ~287 GB/layer for kimi-k2.)
    """
    b, s, d = x.shape
    if s == 1 and b > 1:
        # decode: per-row dispatch would allocate E slots per TOKEN (a 48x
        # capacity blow-up for kimi-k2). Fold the batch into one dispatch
        # group instead (§Perf follow-up after kimi decode useful=0.033).
        out, aux = moe_apply(p, cfg, x.reshape(1, b, d))
        return out.reshape(b, 1, d), aux
    k = cfg.top_k
    e = cfg.n_experts
    cap = int((s * k / e) * cfg.capacity_factor) + 1

    logits = (x.astype(jnp.float32)) @ p["router"]           # (B,S,E)
    logits = lshard(logits, "batch", "seq", None)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)            # (B,S,k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    gate_idx = lshard(gate_idx, "batch", "seq", None)

    # token-side tensors carry d sharded over "model" (free slice on entry);
    # the expert-shard boundary then lowers to an all-to-all, not gathers
    x_d = lshard(x, "batch", "seq", "moe_d")

    def dispatch_row(xt, idx):
        """xt: (S,d), idx: (S,k) -> (buf (e,cap,d), dest (S*k,), keep)."""
        flat_e = idx.reshape(-1)                             # (S*k,)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        starts = jnp.searchsorted(sorted_e, jnp.arange(e))
        pos_in_e = jnp.arange(s * k) - starts[sorted_e]
        keep = pos_in_e < cap
        dest = jnp.where(keep, sorted_e * cap + pos_in_e, e * cap)
        tok = order // k
        buf = jnp.zeros((e * cap + 1, d), xt.dtype).at[dest].set(xt[tok])
        return buf[: e * cap].reshape(e, cap, d), dest, order, keep

    buf, dest, order, keep = jax.vmap(dispatch_row)(x_d, gate_idx)  # (B,e,cap,d)
    buf = lshard(buf, "batch", None, None, "moe_d")          # scatter stays local
    buf = lshard(buf, "batch", "experts", None, None)        # <- all-to-all (d->e)

    # ---- expert FFN (batched over experts; expert dim sharded = EP)
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, p["wg"])) * jnp.einsum(
        "becd,edf->becf", buf, p["wi"]
    )
    h = lshard(h, "batch", "experts", None, None)
    y = jnp.einsum("becf,efd->becd", h, p["wo"])
    y = lshard(y, "batch", "experts", None, None)
    y = lshard(y, "batch", None, None, "moe_d")              # <- all-to-all (e->d)

    def combine_row(yb, dest_b, order_b):
        y_flat = jnp.concatenate([yb.reshape(e * cap, d), jnp.zeros((1, d), yb.dtype)], axis=0)
        gathered = y_flat[dest_b]                            # (S*k, d); dropped -> 0
        inv = jnp.argsort(order_b, stable=True)
        return gathered[inv].reshape(s, k, d)

    y_exp = jax.vmap(combine_row)(y, dest, order)            # (B,S,k,d) d-sharded
    y_exp = lshard(y_exp, "batch", "seq", None, "moe_d")
    out = jnp.einsum("bskd,bsk->bsd", y_exp.astype(jnp.float32), gate_vals).astype(x.dtype)

    if cfg.n_shared_experts:
        out = out + mlp(p["shared"], x)

    # load-balance aux (Switch-style) + router z-loss
    me = jnp.mean(probs, axis=(0, 1))                        # (E,)
    ce = jnp.mean(jax.nn.one_hot(gate_idx, e, dtype=jnp.float32).sum(2), axis=(0, 1))
    aux = {
        "lb_loss": e * jnp.sum(me * ce),
        "router_z": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
        "drop_frac": 1.0 - jnp.mean(keep.astype(jnp.float32)),
    }
    return lshard(out, "batch", "seq", "embed"), aux
