"""Model assembly for all assigned architecture families.

A model = embedding + a sequence of *segments*. Homogeneous runs of layers
are stacked (leading L dim) and executed with lax.scan (keeps HLO small for
80-layer configs); heterogeneous patterns (gemma3 local:global, zamba2
shared-attention) scan over repeating *units* with any remainder layers
applied unstacked.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import common as C
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models import xlstm as XL
from repro.models.config import ModelConfig
from repro.models.sharding import lshard


# ------------------------------------------------------------ block defs
def _layer_init(cfg: ModelConfig, kind: str, key, dtype):
    d = cfg.d_model
    if kind in ("attn_global", "attn_local"):
        k1, k2, k3 = jax.random.split(key, 3)
        p = {"ln1": C.rmsnorm_init(d, dtype), "attn": C.attn_init(k1, cfg, dtype)}
        p["ln2"] = C.rmsnorm_init(d, dtype)
        if cfg.is_moe:
            p["moe"] = MOE.moe_init(k2, cfg, dtype)
        else:
            p["mlp"] = C.mlp_init(k3, d, cfg.d_ff, dtype)
        return p
    if kind == "mamba":
        return {"ln1": C.rmsnorm_init(d, dtype), "mamba": SSM.mamba2_init(key, cfg, dtype)}
    if kind == "mlstm":
        return {"ln1": C.rmsnorm_init(d, dtype), "mlstm": XL.mlstm_init(key, cfg, dtype)}
    if kind == "slstm":
        return {"ln1": C.rmsnorm_init(d, dtype), "slstm": XL.slstm_init(key, cfg, dtype)}
    raise ValueError(kind)


def _layer_train(cfg: ModelConfig, kind: str, p, x, positions, mrope_positions=None):
    if kind in ("attn_global", "attn_local"):
        window = cfg.sliding_window if kind == "attn_local" else None
        h = C.attention_train(
            p["attn"], cfg, C.rmsnorm(p["ln1"], x, cfg.norm_eps), positions,
            window=window, mrope_positions=mrope_positions,
        )
        x = x + h
        y = C.rmsnorm(p["ln2"], x, cfg.norm_eps)
        if cfg.is_moe:
            y, _aux = MOE.moe_apply(p["moe"], cfg, y)
        else:
            y = C.mlp(p["mlp"], y)
        return x + y
    if kind == "mamba":
        return x + SSM.mamba2_train(p["mamba"], cfg, C.rmsnorm(p["ln1"], x, cfg.norm_eps))
    if kind == "mlstm":
        return x + XL.mlstm_train(p["mlstm"], cfg, C.rmsnorm(p["ln1"], x, cfg.norm_eps))
    if kind == "slstm":
        return x + XL.slstm_train(p["slstm"], cfg, C.rmsnorm(p["ln1"], x, cfg.norm_eps))
    raise ValueError(kind)


def _layer_decode(cfg: ModelConfig, kind: str, p, x, cache, pos, mrope_positions=None):
    """cache: per-layer dict. Returns (x, new_cache)."""
    if kind in ("attn_global", "attn_local"):
        window = cfg.sliding_window if kind == "attn_local" else None
        h, ck, cv = C.attention_decode(
            p["attn"], cfg, C.rmsnorm(p["ln1"], x, cfg.norm_eps), cache["k"], cache["v"], pos,
            window=window, mrope_positions=mrope_positions,
        )
        x = x + h
        y = C.rmsnorm(p["ln2"], x, cfg.norm_eps)
        if cfg.is_moe:
            y, _ = MOE.moe_apply(p["moe"], cfg, y)
        else:
            y = C.mlp(p["mlp"], y)
        return x + y, {"k": ck, "v": cv}
    if kind == "mamba":
        h, nc = SSM.mamba2_decode(p["mamba"], cfg, C.rmsnorm(p["ln1"], x, cfg.norm_eps), cache)
        return x + h, nc
    if kind == "mlstm":
        h, nc = XL.mlstm_decode(p["mlstm"], cfg, C.rmsnorm(p["ln1"], x, cfg.norm_eps), cache)
        return x + h, nc
    if kind == "slstm":
        h, nc = XL.slstm_decode(p["slstm"], cfg, C.rmsnorm(p["ln1"], x, cfg.norm_eps), cache)
        return x + h, nc
    raise ValueError(kind)


def _layer_cache_init(cfg: ModelConfig, kind: str, batch: int, cache_len: int, dtype):
    if kind in ("attn_global", "attn_local"):
        length = min(cache_len, cfg.sliding_window) if (kind == "attn_local" and cfg.sliding_window) else cache_len
        hd = cfg.hd
        return {
            "k": jnp.zeros((batch, length, cfg.n_kv_heads, hd), dtype),
            "v": jnp.zeros((batch, length, cfg.n_kv_heads, hd), dtype),
        }
    if kind == "mamba":
        return SSM.mamba2_cache_init(cfg, batch, dtype)
    if kind == "mlstm":
        return XL.mlstm_cache_init(cfg, batch)
    if kind == "slstm":
        return XL.slstm_cache_init(cfg, batch)
    raise ValueError(kind)


# ------------------------------------------------------------ pattern plan
PATTERN_KINDS = {"L": "attn_local", "G": "attn_global", "M": "mlstm", "S": "slstm", "D": "mamba"}


def layer_plan(cfg: ModelConfig) -> tuple[list[str], int, list[str]]:
    """Returns (unit kinds, n_units, remainder kinds)."""
    if cfg.arch_type == "xlstm":
        pattern = [PATTERN_KINDS[c] for c in cfg.xlstm_pattern]
    elif cfg.arch_type == "zamba":
        # scanned double-units of 2*attn_every mamba layers (+2 shared attn)
        period = max(cfg.attn_every, 1)
        n_double = cfg.n_layers // (2 * period)
        rem = ["mamba"] * (cfg.n_layers - n_double * 2 * period)
        return ["mamba"] * (2 * period), n_double, rem
    elif cfg.layer_pattern:
        pattern = [PATTERN_KINDS[c] for c in cfg.layer_pattern]
    else:
        pattern = ["attn_global"]
    n_units = cfg.n_layers // len(pattern)
    rem = [pattern[i] for i in range(cfg.n_layers - n_units * len(pattern))]
    return pattern, n_units, rem


# ------------------------------------------------------------ init
def init_params(cfg: ModelConfig, key) -> dict:
    dtype = C.dtype_of(cfg)
    keys = jax.random.split(key, 8)
    unit, n_units, rem = layer_plan(cfg)
    params: dict[str, Any] = {
        "embed": C.embed_init(keys[0], cfg.vocab, cfg.d_model, dtype),
        "final_norm": C.rmsnorm_init(cfg.d_model, dtype),
    }

    def stack_init(kind, key, count):
        ks = jax.random.split(key, count)
        return jax.vmap(lambda k: _layer_init(cfg, kind, k, dtype))(ks)

    if cfg.arch_type == "zamba" and cfg.scan_layers:
        params["units"] = zamba_init_units(cfg, keys[1], dtype)
    elif cfg.scan_layers and n_units > 1:
        params["units"] = {
            f"slot{i}": stack_init(kind, jax.random.fold_in(keys[1], i), n_units)
            for i, kind in enumerate(unit)
        }
    else:
        params["flat_layers"] = [
            _layer_init(cfg, unit[i % len(unit)], jax.random.fold_in(keys[1], i), dtype)
            for i in range(n_units * len(unit))
        ]
    params["rem_layers"] = [
        _layer_init(cfg, k, jax.random.fold_in(keys[2], i), dtype) for i, k in enumerate(rem)
    ]

    if cfg.arch_type == "zamba":
        params["shared_attn"] = [
            _layer_init(cfg, "attn_global", jax.random.fold_in(keys[3], i), dtype) for i in range(2)
        ]
    if cfg.arch_type == "whisper":
        params["enc_layers"] = [
            _layer_init(cfg, "attn_global", jax.random.fold_in(keys[4], i), dtype)
            for i in range(cfg.n_enc_layers)
        ]
        params["enc_norm"] = C.rmsnorm_init(cfg.d_model, dtype)
        params["cross_layers"] = [
            {
                "ln": C.rmsnorm_init(cfg.d_model, dtype),
                "attn": C.attn_init(jax.random.fold_in(keys[5], i), cfg, dtype),
            }
            for i in range(cfg.n_layers)
        ]
    return params


# ------------------------------------------------------------ forward (train)
def _unit_forward(cfg, unit, unit_params, x, positions, mrope_positions):
    for i, kind in enumerate(unit):
        x = _layer_train(cfg, kind, unit_params[f"slot{i}"], x, positions, mrope_positions)
    return x


def backbone_train(cfg: ModelConfig, params, x, positions, mrope_positions=None):
    """Run the decoder stack on embeddings x (B,S,d)."""
    unit, n_units, rem = layer_plan(cfg)

    if cfg.arch_type == "zamba":
        return _zamba_train(cfg, params, x, positions)

    if "units" in params:
        def body(xc, unit_params):
            out = _unit_forward(cfg, unit, unit_params, xc, positions, mrope_positions)
            return out, None

        body = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(body, x, params["units"])
    else:
        for i, lp in enumerate(params.get("flat_layers", [])):
            x = _layer_train(cfg, unit[i % len(unit)], lp, x, positions, mrope_positions)
    for kind, lp in zip(rem, params["rem_layers"]):
        x = _layer_train(cfg, kind, lp, x, positions, mrope_positions)
    return C.rmsnorm(params["final_norm"], x, cfg.norm_eps)


def _zamba_train(cfg, params, x, positions):
    """Zamba2: mamba backbone with 2 alternating shared attention blocks.

    Double-unit scan: [6x mamba, sharedA, 6x mamba, sharedB] so the shared
    params are closure constants (no per-step selects). Remainder applied
    flat.
    """
    period = max(cfg.attn_every, 1)
    sa, sb = params["shared_attn"]

    def half(xc, unit_params, shared):
        def body(xc2, lp):
            return _layer_train(cfg, "mamba", lp, xc2, positions), None
        xc, _ = jax.lax.scan(body, xc, unit_params)
        return _layer_train(cfg, "attn_global", shared, xc, positions)

    def double_unit(xc, up):
        xc = half(xc, up["a"], sa)
        xc = half(xc, up["b"], sb)
        return xc, None

    du = jax.checkpoint(double_unit) if cfg.remat else double_unit
    if "units" in params:
        x, _ = jax.lax.scan(du, x, params["units"])
    for i, lp in enumerate(params["rem_layers"]):
        x = _layer_train(cfg, "mamba", lp, x, positions)
        if (i + 1) % period == 0:
            x = _layer_train(cfg, "attn_global", sa, x, positions)
    return C.rmsnorm(params["final_norm"], x, cfg.norm_eps)


def zamba_init_units(cfg: ModelConfig, key, dtype) -> dict:
    """Stacked params for the zamba double-unit scan."""
    period = max(cfg.attn_every, 1)
    n_double = cfg.n_layers // (2 * period)

    def stack(key, count):
        ks = jax.random.split(key, count)
        return jax.vmap(lambda k: _layer_init(cfg, "mamba", k, dtype))(ks)

    ka, kb = jax.random.split(key)
    return {
        "a": jax.vmap(lambda k: stack(k, period))(jax.random.split(ka, n_double)),
        "b": jax.vmap(lambda k: stack(k, period))(jax.random.split(kb, n_double)),
    }


# ------------------------------------------------------------ whisper
def sinusoid_pos(n: int, d: int) -> jax.Array:
    pos = np.arange(n)[:, None]
    dim = np.arange(0, d, 2)[None]
    ang = pos / (10_000 ** (dim / d))
    out = np.zeros((n, d), np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return jnp.asarray(out)


def whisper_encode(cfg: ModelConfig, params, audio_embeds):
    """audio_embeds: (B, n_audio_ctx, d) — post-conv frontend stub."""
    x = audio_embeds + sinusoid_pos(audio_embeds.shape[1], cfg.d_model).astype(audio_embeds.dtype)
    for lp in params["enc_layers"]:
        h = C.attention_train(
            lp["attn"], cfg, C.rmsnorm(lp["ln1"], x, cfg.norm_eps), None, causal=False
        )
        x = x + h
        x = x + C.mlp(lp["mlp"], C.rmsnorm(lp["ln2"], x, cfg.norm_eps))
    return C.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _cross_attend(cfg, p, x, enc_k, enc_v):
    q = (C.rmsnorm(p["ln"], x, cfg.norm_eps) @ p["attn"]["wq"]).reshape(
        x.shape[0], x.shape[1], cfg.n_heads, cfg.hd
    )
    out = C.chunked_attention(q, enc_k, enc_v, causal=False)
    return x + out.reshape(x.shape[0], x.shape[1], -1) @ p["attn"]["wo"]


def whisper_train(cfg: ModelConfig, params, audio_embeds, tokens):
    enc = whisper_encode(cfg, params, audio_embeds)
    x = C.embed_lookup(params["embed"], tokens)
    positions = jnp.broadcast_to(jnp.arange(tokens.shape[1])[None], tokens.shape)
    layers = params["flat_layers"]
    for lp, cp in zip(layers, params["cross_layers"]):
        x = _layer_train(cfg, "attn_global", lp, x, positions)
        enc_k = (enc @ cp["attn"]["wk"]).reshape(enc.shape[0], enc.shape[1], cfg.n_kv_heads, cfg.hd)
        enc_v = (enc @ cp["attn"]["wv"]).reshape(enc.shape[0], enc.shape[1], cfg.n_kv_heads, cfg.hd)
        x = _cross_attend(cfg, cp, x, enc_k, enc_v)
    return C.rmsnorm(params["final_norm"], x, cfg.norm_eps)
