"""Shared transformer building blocks (pure-function style, param pytrees).

Everything is written against logical-axis sharding annotations (lshard) so
the same code runs single-device in smoke tests and on the 512-chip mesh in
the dry-run. Attention is chunked (online-softmax, flash-style in pure JAX)
so 32k prefill never materializes an S x S score matrix.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.sharding import lshard


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------- init utils
def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    s = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape) * s).astype(dtype)


# ------------------------------------------------------------------ RMSNorm
def rmsnorm_init(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------- RoPE
def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B,S,H,hd), positions: (B,S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float, sections: tuple[int, int, int]) -> jax.Array:
    """Qwen2-VL M-RoPE. positions: (B,S,3) [t,h,w]; sections sum to hd/2."""
    hd = x.shape[-1]
    half = hd // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(hd, theta)  # (half,)
    # each rotary frequency slot takes its position stream by section
    sec_id = jnp.concatenate(
        [jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)]
    )  # (half,)
    pos = jnp.take_along_axis(
        positions.astype(jnp.float32),
        jnp.broadcast_to(sec_id[None, None], positions.shape[:2] + (half,)).astype(jnp.int32),
        axis=-1,
    )  # (B,S,half)
    ang = pos * freqs[None, None]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- attention
def attn_init(key, cfg, dtype):
    d, hd = cfg.d_model, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, cfg.n_heads * hd), dtype),
        "wk": dense_init(ks[1], (d, cfg.n_kv_heads * hd), dtype),
        "wv": dense_init(ks[2], (d, cfg.n_kv_heads * hd), dtype),
        "wo": dense_init(ks[3], (cfg.n_heads * hd, d), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dtype)
        p["k_norm"] = rmsnorm_init(hd, dtype)
    return p


def _qkv(p, cfg, x, positions, mrope_positions=None):
    b, s, _ = x.shape
    hd = cfg.hd
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, hd)
    k = (x @ p["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
    v = (x @ p["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
    q = lshard(q, "batch", "seq", "heads", None)
    k = lshard(k, "batch", "seq", "kv_heads", None)
    v = lshard(v, "batch", "seq", "kv_heads", None)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if mrope_positions is not None:
        q = apply_mrope(q, mrope_positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, mrope_positions, cfg.rope_theta, cfg.mrope_sections)
    elif positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def chunked_attention(
    q: jax.Array,   # (B,S,H,hd)
    k: jax.Array,   # (B,Skv,Hkv,hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    chunk: int = 1024,
) -> jax.Array:
    """Flash-style chunked attention (online softmax over KV chunks).

    Never materializes (S, Skv); peak live score block is (B,H,S,chunk).
    ``q_offset``: absolute position of q[0] relative to k[0] (decode: Skv-1).
    """
    b, s, h, hd = q.shape
    skv = k.shape[1]
    hkv = k.shape[2]
    group = h // hkv
    scale = 1.0 / np.sqrt(hd)

    qf = (q * scale).astype(jnp.float32).transpose(0, 2, 1, 3)  # (B,H,S,hd)
    kf = k.astype(jnp.float32).transpose(0, 2, 1, 3)            # (B,Hkv,Skv,hd)
    vf = v.astype(jnp.float32).transpose(0, 2, 1, 3)
    # expand kv heads to full heads (GQA)
    kf = jnp.repeat(kf, group, axis=1)
    vf = jnp.repeat(vf, group, axis=1)

    chunk = min(chunk, skv)
    pad = (-skv) % chunk
    if pad:
        kf = jnp.pad(kf, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nc = kf.shape[2] // chunk
    kf = kf.reshape(b, h, nc, chunk, hd)
    vf = vf.reshape(b, h, nc, chunk, hd)

    q_pos = q_offset + jnp.arange(s)

    @jax.checkpoint  # recompute per-chunk probabilities in the backward pass
    def step(carry, inputs):
        m, l, acc = carry
        kc, vc, ci = inputs
        kv_pos = ci * chunk + jnp.arange(chunk)
        scores = jnp.einsum("bhqd,bhkd->bhqk", qf, kc)  # (B,H,S,chunk)
        mask = kv_pos[None, :] < skv  # padding
        if causal:
            mask = mask & (kv_pos[None, :] <= q_pos[:, None])
        if window is not None:
            mask = mask & (q_pos[:, None] - kv_pos[None, :] < window)
        scores = jnp.where(mask[None, None], scores, -1e30)
        m_new = jnp.maximum(m, jnp.max(scores, axis=-1))
        p = jnp.exp(scores - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vc)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, s), -1e30, jnp.float32)
    l0 = jnp.zeros((b, h, s), jnp.float32)
    a0 = jnp.zeros((b, h, s, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (kf.transpose(2, 0, 1, 3, 4), vf.transpose(2, 0, 1, 3, 4), jnp.arange(nc))
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)  # (B,S,H,hd)


def attention_train(p, cfg, x, positions, *, window=None, causal=True, mrope_positions=None):
    b, s, d = x.shape
    q, k, v = _qkv(p, cfg, x, positions, mrope_positions)
    out = chunked_attention(q, k, v, causal=causal, window=window)
    out = out.reshape(b, s, cfg.n_heads * cfg.hd)
    return lshard(out @ p["wo"], "batch", "seq", "embed")


def attention_decode(p, cfg, x, cache_k, cache_v, pos, *, window=None, mrope_positions=None):
    """One-token decode. cache_k/v: (B, Scache, Hkv, hd) ring or linear buffer.

    pos: () int32 absolute position of the new token. Returns (out, new_k, new_v).
    """
    b = x.shape[0]
    hd = cfg.hd
    positions = jnp.broadcast_to(pos, (b, 1)).astype(jnp.int32)
    q, k, v = _qkv(p, cfg, x, positions, mrope_positions)
    s_cache = cache_k.shape[1]
    slot = (pos % s_cache).astype(jnp.int32) if window is not None else jnp.minimum(pos, s_cache - 1)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), slot, axis=1)

    kf = cache_k.astype(jnp.float32)
    vf = cache_v.astype(jnp.float32)
    group = cfg.n_heads // cfg.n_kv_heads
    qf = (q * (1.0 / np.sqrt(hd))).astype(jnp.float32)  # (B,1,H,hd)
    qf = qf.reshape(b, cfg.n_kv_heads, group, hd)
    scores = jnp.einsum("bkgd,bskd->bkgs", qf, kf)      # (B,Hkv,g,Scache)
    idx = jnp.arange(s_cache)
    if window is not None:
        # ring buffer: slot i holds the largest absolute position p' <= pos
        # with p' % s_cache == i; valid if within the window
        abs_pos = pos - ((pos - idx) % s_cache)
        mask = (abs_pos >= 0) & (abs_pos <= pos) & (pos - abs_pos < window)
    else:
        mask = idx <= pos
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", w, vf).reshape(b, 1, cfg.n_heads * hd).astype(x.dtype)
    return lshard(out @ p["wo"], "batch", "seq", "embed"), cache_k, cache_v


# -------------------------------------------------------------------- SwiGLU
def mlp_init(key, d, ff, dtype):
    ks = jax.random.split(key, 3)
    return {
        "wi": dense_init(ks[0], (d, ff), dtype),
        "wg": dense_init(ks[1], (d, ff), dtype),
        "wo": dense_init(ks[2], (ff, d), dtype),
    }


def mlp(p, x):
    h = jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])
    h = lshard(h, "batch", "seq", "ff")
    return lshard(h @ p["wo"], "batch", "seq", "embed")


# ----------------------------------------------------------------- LM pieces
def embed_init(key, vocab, d, dtype):
    return {"embedding": (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)}


def embed_lookup(p, tokens):
    return lshard(p["embedding"][tokens], "batch", "seq", "embed")


def lm_logits(p_embed, x):
    return lshard(x @ p_embed["embedding"].T, "batch", "seq", "vocab")


def chunked_ce_loss(p_embed, x, labels, *, chunk: int = 512, z_loss: float = 0.0):
    """Cross-entropy over seq chunks so (B,S,V) logits never fully materialize."""
    b, s, d = x.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nc = x.shape[1] // chunk
    xs = x.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, nc, chunk).transpose(1, 0, 2)

    @jax.checkpoint  # never keep (B,chunk,V) logits across chunks for backward
    def step(carry, inp):
        tot, cnt = carry
        xc, lc = inp
        logits = lm_logits(p_embed, xc).astype(jnp.float32)  # (B,chunk,V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
        valid = lc >= 0
        nll = jnp.where(valid, lse - gold, 0.0)
        if z_loss:
            nll = nll + jnp.where(valid, z_loss * lse**2, 0.0)
        return (tot + jnp.sum(nll), cnt + jnp.sum(valid)), None

    (tot, cnt), _ = jax.lax.scan(step, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)), (xs, ls))
    return tot / jnp.maximum(cnt, 1)
