"""Structured orbital camera rig (the paper's synthetic 448-view orbit)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.projection import Camera, look_at_camera


def orbit_cameras(
    n_views: int,
    *,
    img_h: int,
    img_w: int,
    radius: float = 3.0,
    fov_deg: float = 40.0,
    elev_cycles: float = 3.0,
    elev_max_deg: float = 55.0,
    target=(0.0, 0.0, 0.0),
) -> Camera:
    """Batched Camera on a spiral orbit: azimuth sweeps [0,2pi), elevation
    oscillates — the structured orbit used for isosurface capture."""
    az = np.linspace(0, 2 * np.pi, n_views, endpoint=False)
    elev = np.deg2rad(elev_max_deg) * np.sin(elev_cycles * az)
    fx = fy = 0.5 * img_w / np.tan(np.deg2rad(fov_deg) / 2)
    cams = []
    for a, e in zip(az, elev):
        eye = np.float32(target) + radius * np.float32(
            [np.cos(e) * np.cos(a), np.cos(e) * np.sin(a), np.sin(e)]
        )
        cams.append(
            look_at_camera(eye, np.float32(target), [0.0, 0.0, 1.0], fx, fy, img_w / 2, img_h / 2)
        )
    return Camera(*[jnp.stack([getattr(c, f) for c in cams]) for f in Camera._fields])


def camera_slice(cams: Camera, idx) -> Camera:
    return Camera(*[getattr(cams, f)[idx] for f in Camera._fields])
