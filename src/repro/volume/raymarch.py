"""Ground-truth isosurface renderer (ray-marched, jnp).

Stand-in for the ParaView renders the paper trains against: fixed-step ray
marching with sign-change detection, bisection refinement, central-difference
normals and Lambertian shading (identical shading constants to
``isosurface.shade`` so point-cloud color init matches the GT images).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.projection import Camera
from repro.volume.datasets import VolumeSpec
from repro.volume.isosurface import AMBIENT, BASE_COLOR, LIGHT_DIR


def _trilinear(field: jax.Array, p: jax.Array, extent: float) -> jax.Array:
    """Sample scalar field at world points p (..., 3); clamps at the border."""
    res = field.shape[0]
    g = (p + extent) / (2 * extent) * (res - 1)
    g = jnp.clip(g, 0.0, res - 1.001)
    i0 = jnp.floor(g).astype(jnp.int32)
    f = g - i0
    i1 = jnp.minimum(i0 + 1, res - 1)

    def at(ix, iy, iz):
        return field[ix, iy, iz]

    c000 = at(i0[..., 0], i0[..., 1], i0[..., 2])
    c100 = at(i1[..., 0], i0[..., 1], i0[..., 2])
    c010 = at(i0[..., 0], i1[..., 1], i0[..., 2])
    c110 = at(i1[..., 0], i1[..., 1], i0[..., 2])
    c001 = at(i0[..., 0], i0[..., 1], i1[..., 2])
    c101 = at(i1[..., 0], i0[..., 1], i1[..., 2])
    c011 = at(i0[..., 0], i1[..., 1], i1[..., 2])
    c111 = at(i1[..., 0], i1[..., 1], i1[..., 2])
    fx, fy, fz = f[..., 0], f[..., 1], f[..., 2]
    c00 = c000 * (1 - fx) + c100 * fx
    c10 = c010 * (1 - fx) + c110 * fx
    c01 = c001 * (1 - fx) + c101 * fx
    c11 = c011 * (1 - fx) + c111 * fx
    c0 = c00 * (1 - fy) + c10 * fy
    c1 = c01 * (1 - fy) + c11 * fy
    return c0 * (1 - fz) + c1 * fz


@partial(jax.jit, static_argnames=("img_h", "img_w", "n_steps", "extent"))
def render_isosurface(
    vol_field: jax.Array,
    isovalue: float,
    cam: Camera,
    *,
    img_h: int,
    img_w: int,
    extent: float = 1.0,
    n_steps: int = 192,
    bg=(0.0, 0.0, 0.0),
) -> jax.Array:
    """Render one GT view, (H, W, 3) in [0,1]."""
    field = vol_field - isovalue
    R = cam.viewmat[:3, :3]
    campos = cam.campos

    ys, xs = jnp.meshgrid(jnp.arange(img_h) + 0.5, jnp.arange(img_w) + 0.5, indexing="ij")
    dirs_cam = jnp.stack(
        [(xs - cam.cx) / cam.fx, (ys - cam.cy) / cam.fy, jnp.ones_like(xs)], -1
    )
    dirs = dirs_cam @ R  # cam->world (R rows are world axes of cam frame)
    dirs = dirs / jnp.linalg.norm(dirs, axis=-1, keepdims=True)

    # march from the camera through the volume's bounding sphere
    t0 = jnp.maximum(jnp.linalg.norm(campos) - 1.9 * extent, 0.02)
    t1 = jnp.linalg.norm(campos) + 1.9 * extent
    ts = jnp.linspace(t0, t1, n_steps)

    def sample(t):
        return _trilinear(field, campos + t * dirs[..., None, :].squeeze(-2), extent)

    vals = jax.vmap(lambda t: _trilinear(field, campos + t * dirs, extent))(ts)  # (S,H,W)
    sign_change = (vals[:-1] * vals[1:]) < 0
    first = jnp.argmax(sign_change, axis=0)  # (H,W) first crossing step
    hit = jnp.any(sign_change, axis=0)
    f0 = jnp.take_along_axis(vals, first[None], axis=0)[0]
    f1 = jnp.take_along_axis(vals, (first + 1)[None], axis=0)[0]
    tt = ts[first] + (ts[first + 1] - ts[first]) * f0 / (f0 - f1 + 1e-12)
    p_hit = campos + tt[..., None] * dirs

    # bisection refinement (4 rounds)
    lo = ts[first]
    hi = ts[first + 1]
    flo = f0
    for _ in range(4):
        mid = 0.5 * (lo + hi)
        fm = _trilinear(field, campos + mid[..., None] * dirs, extent)
        go_lo = (flo * fm) < 0
        hi = jnp.where(go_lo, mid, hi)
        lo = jnp.where(go_lo, lo, mid)
        flo = jnp.where(go_lo, flo, fm)
    tt = 0.5 * (lo + hi)
    p_hit = campos + tt[..., None] * dirs

    eps = 2 * extent / field.shape[0]
    grad = jnp.stack(
        [
            _trilinear(field, p_hit + jnp.float32([eps, 0, 0]), extent)
            - _trilinear(field, p_hit - jnp.float32([eps, 0, 0]), extent),
            _trilinear(field, p_hit + jnp.float32([0, eps, 0]), extent)
            - _trilinear(field, p_hit - jnp.float32([0, eps, 0]), extent),
            _trilinear(field, p_hit + jnp.float32([0, 0, eps]), extent)
            - _trilinear(field, p_hit - jnp.float32([0, 0, eps]), extent),
        ],
        -1,
    )
    n = grad / (jnp.linalg.norm(grad, axis=-1, keepdims=True) + 1e-12)
    l = jnp.asarray(LIGHT_DIR) / jnp.linalg.norm(jnp.asarray(LIGHT_DIR))
    lam = jnp.clip(-(n @ l), 0.0, 1.0)
    color = jnp.asarray(BASE_COLOR) * (AMBIENT + (1 - AMBIENT) * lam[..., None])
    bg_arr = jnp.broadcast_to(jnp.asarray(bg, jnp.float32), color.shape)
    return jnp.clip(jnp.where(hit[..., None], color, bg_arr), 0.0, 1.0)
