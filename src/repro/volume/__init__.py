from repro.volume.datasets import kingsnake_like, miranda_like, VolumeSpec
from repro.volume.isosurface import extract_isosurface_points
from repro.volume.cameras import orbit_cameras
from repro.volume.raymarch import render_isosurface

__all__ = [
    "kingsnake_like",
    "miranda_like",
    "VolumeSpec",
    "extract_isosurface_points",
    "orbit_cameras",
    "render_isosurface",
]
