from repro.volume.datasets import kingsnake_like, miranda_like, VolumeSpec
from repro.volume.isosurface import extract_isosurface_points
from repro.volume.cameras import orbit_cameras
from repro.volume.raymarch import render_isosurface
from repro.volume.timevary import (
    CallbackStream,
    DiskStream,
    VolumeStream,
    dump_stream,
    kingsnake_uncoil,
    miranda_growth,
    synthetic_stream,
)

__all__ = [
    "kingsnake_like",
    "miranda_like",
    "VolumeSpec",
    "extract_isosurface_points",
    "orbit_cameras",
    "render_isosurface",
    "CallbackStream",
    "DiskStream",
    "VolumeStream",
    "dump_stream",
    "kingsnake_uncoil",
    "miranda_growth",
    "synthetic_stream",
]
