"""Time-varying synthetic volumes + the ``VolumeStream`` source protocol.

The paper's conclusion targets "real-time post hoc and in situ visualization
of complex simulations": the volume is no longer a static dump but a sequence
of evolving timesteps. These generators extend ``repro.volume.datasets`` in
time — a Kingsnake coil that uncoils and a Miranda mixing layer that grows —
with fields that are *continuous in t*, so adjacent timesteps differ by a
small perturbation and a warm-started Gaussian model can track the surface.

``VolumeStream`` abstracts where timesteps come from:

  * ``CallbackStream``  — in-situ: the "simulation" is a callable t -> field,
    evaluated lazily as the trainer consumes it (nothing hits disk).
  * ``DiskStream``      — post hoc: timesteps previously written by
    ``dump_stream`` are read back from ``t_####.npz`` files.

Both yield plain ``VolumeSpec`` values, so every downstream stage (isosurface
extraction, GT raymarch, training) is source-agnostic.
"""
from __future__ import annotations

import json
import os
import re
from typing import Callable, Iterator, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.volume.datasets import VolumeSpec, _grid


@runtime_checkable
class VolumeStream(Protocol):
    """A finite, ordered sequence of evolving volume timesteps."""

    name: str

    def __len__(self) -> int: ...

    def __iter__(self) -> Iterator[VolumeSpec]: ...


# --------------------------------------------------------------- generators
def kingsnake_uncoil(
    t: float, *, res: int = 64, extent: float = 1.0, coils: float = 3.5
) -> VolumeSpec:
    """Kingsnake coil at simulation time ``t`` in [0, 1]: the helix uncoils.

    As t grows the total twist drops (fewer windings), the helix radius
    relaxes outward and the body stretches along z — a snake slowly
    straightening. The centerline moves continuously in t, and the field is
    a smooth function (distance to the centerline) of it, so
    ``|field(t+dt) - field(t)| -> 0`` with dt: exactly the regime warm-start
    incremental training assumes.
    """
    t = float(np.clip(t, 0.0, 1.0))
    x, y, z = _grid(res, extent)
    n_coils = coils * (1.0 - 0.45 * t)          # uncoiling: fewer windings
    tt = np.linspace(0, 2 * np.pi * n_coils, 400, dtype=np.float32)
    s = tt / tt[-1]                              # arclength-ish parameter in [0,1]
    r_helix = (0.55 + 0.10 * t) * (1.0 - 0.12 * s)
    hx = r_helix * np.cos(tt)
    hy = r_helix * np.sin(tt)
    hz = np.linspace(-(0.7 + 0.15 * t) * extent, (0.7 + 0.15 * t) * extent, tt.size, dtype=np.float32)
    pts = np.stack([hx, hy, hz], 1)

    vox = np.stack([x, y, z], -1).reshape(-1, 3)
    d = np.full((vox.shape[0],), np.inf, np.float32)
    for i in range(0, pts.shape[0], 50):
        seg = pts[i : i + 50]
        dd = np.linalg.norm(vox[:, None, :] - seg[None], axis=-1).min(1)
        d = np.minimum(d, dd)
    d = d.reshape(res, res, res)
    tex = 0.015 * np.sin(7.0 * x) * np.cos(6.0 * y) * np.sin(5.0 * z)
    field = d - (0.16 + tex)
    return VolumeSpec(field.astype(np.float32), 0.0, extent, f"kingsnake_uncoil_t{t:.3f}")


def miranda_growth(
    t: float, *, res: int = 64, extent: float = 1.0, modes: int = 6, seed: int = 1
) -> VolumeSpec:
    """Miranda mixing layer at time ``t`` in [0, 1]: the instability grows.

    The multi-mode displacement amplitude ramps up with t (mixing-layer
    width growth) while the mode phases drift slowly (structures translate),
    matching the qualitative evolution of a Rayleigh-Taylor interface.
    """
    t = float(np.clip(t, 0.0, 1.0))
    x, y, z = _grid(res, extent)
    rng = np.random.default_rng(seed)
    grow = 0.35 + 0.65 * t                       # amplitude ramp
    disp = np.zeros_like(x)
    for _ in range(modes):
        kx, ky = rng.uniform(2.0, 9.0, 2)
        ph1, ph2 = rng.uniform(0, 2 * np.pi, 2)
        amp = rng.uniform(0.04, 0.14)
        disp += grow * amp * np.sin(kx * x + ph1 + 0.6 * t) * np.cos(ky * y + ph2 + 0.4 * t)
    disp += grow * 0.08 * np.sin(4.0 * x) * np.sin(4.0 * y) * np.cos(3.0 * z)
    field = z - disp
    return VolumeSpec(field.astype(np.float32), 0.0, extent, f"miranda_growth_t{t:.3f}")


GENERATORS: dict[str, Callable[..., VolumeSpec]] = {
    "kingsnake": kingsnake_uncoil,
    "miranda": miranda_growth,
}


# ------------------------------------------------------------------ sources
class CallbackStream:
    """In-situ source: a callable ``fn(t, **kw) -> VolumeSpec`` sampled at
    ``times``. The simulation side of an in-situ coupling is exactly such a
    callback — nothing is materialized until the trainer pulls a timestep."""

    def __init__(self, fn: Callable[..., VolumeSpec], times: Sequence[float], *, name: str, **kw):
        self.fn = fn
        self.times = [float(t) for t in times]
        self.name = name
        self.kw = kw

    def __len__(self) -> int:
        return len(self.times)

    def __iter__(self) -> Iterator[VolumeSpec]:
        for t in self.times:
            yield self.fn(t, **self.kw)


def synthetic_stream(
    dataset: str, n_timesteps: int, *, res: int = 48, t0: float = 0.0, t1: float = 0.5, **kw
) -> CallbackStream:
    """Evenly-sampled in-situ stream of one of the named generators."""
    fn = GENERATORS[dataset]
    times = np.linspace(t0, t1, n_timesteps)
    return CallbackStream(fn, times, name=dataset, res=res, **kw)


class DiskStream:
    """Post-hoc source: timesteps read back from ``<dir>/t_####.npz`` dumps
    (written by ``dump_stream``), the on-disk layout a simulation's I/O stage
    would leave behind."""

    def __init__(self, directory: str):
        self.directory = directory
        with open(os.path.join(directory, "stream.json")) as f:
            meta = json.load(f)
        self.name = meta["name"]
        self._files = [
            os.path.join(directory, n)
            for n in sorted(
                (n for n in os.listdir(directory) if re.match(r"t_\d+\.npz$", n)),
                key=lambda n: int(n[2:-4]),  # numeric: lexicographic breaks past t_9999
            )
        ]

    def __len__(self) -> int:
        return len(self._files)

    def __iter__(self) -> Iterator[VolumeSpec]:
        for path in self._files:
            with np.load(path) as z:
                yield VolumeSpec(
                    z["field"].astype(np.float32),
                    float(z["isovalue"]),
                    float(z["extent"]),
                    str(z["name"]),
                )


def dump_stream(stream: VolumeStream, directory: str) -> list[str]:
    """Write a stream to disk in the ``DiskStream`` layout; returns paths."""
    os.makedirs(directory, exist_ok=True)
    paths = []
    for i, vol in enumerate(stream):
        path = os.path.join(directory, f"t_{i:04d}.npz")
        np.savez_compressed(
            path, field=vol.field, isovalue=vol.isovalue, extent=vol.extent, name=vol.name
        )
        paths.append(path)
    with open(os.path.join(directory, "stream.json"), "w") as f:
        json.dump({"name": stream.name, "n_timesteps": len(paths)}, f)
    return paths
