"""Synthetic scientific volumes standing in for the paper's datasets.

The paper uses Kingsnake (micro-CT of a snake egg clutch, ~4M isosurface
points) and Miranda (radiation-hydrodynamics mixing simulation, ~18M). We
cannot ship those; these procedural fields reproduce their *structural
character* (coiled tubular shells vs. turbulent mixing interface) at
configurable resolution so every pipeline stage runs end-to-end.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class VolumeSpec(NamedTuple):
    field: np.ndarray      # (R, R, R) float32 scalar field
    isovalue: float
    extent: float          # world-space half-extent (volume spans [-e, e]^3)
    name: str


def _grid(res: int, extent: float):
    lin = np.linspace(-extent, extent, res, dtype=np.float32)
    return np.meshgrid(lin, lin, lin, indexing="ij")


def kingsnake_like(res: int = 96, extent: float = 1.0, *, coils: float = 3.5, seed: int = 0) -> VolumeSpec:
    """Coiled-tube field: distance to a conical helix, with a shell texture.

    Isosurface = tube shell, structurally similar to the snake-egg CT scan
    (thin curved sheets, high curvature, self-occlusion).
    """
    x, y, z = _grid(res, extent)
    t = np.linspace(0, 2 * np.pi * coils, 400, dtype=np.float32)
    r_helix = 0.55 * (1.0 - 0.12 * t / t[-1])
    hx = r_helix * np.cos(t)
    hy = r_helix * np.sin(t)
    hz = np.linspace(-0.7 * extent, 0.7 * extent, t.size, dtype=np.float32)
    pts = np.stack([hx, hy, hz], 1)  # (T,3)

    # distance from every voxel to the helix polyline (chunked for memory)
    vox = np.stack([x, y, z], -1).reshape(-1, 3)
    d = np.full((vox.shape[0],), np.inf, np.float32)
    for i in range(0, pts.shape[0], 50):
        seg = pts[i : i + 50]
        dd = np.linalg.norm(vox[:, None, :] - seg[None], axis=-1).min(1)
        d = np.minimum(d, dd)
    d = d.reshape(res, res, res)
    rng = np.random.default_rng(seed)
    # gentle shell-thickness modulation so the surface is not a perfect tube
    tex = 0.015 * np.sin(7.0 * x) * np.cos(6.0 * y) * np.sin(5.0 * z)
    field = d - (0.16 + tex)
    return VolumeSpec(field.astype(np.float32), 0.0, extent, "kingsnake_like")


def miranda_like(res: int = 96, extent: float = 1.0, *, modes: int = 6, seed: int = 1) -> VolumeSpec:
    """Rayleigh-Taylor-style mixing interface: z minus a multi-mode wavy
    displacement field. Isosurface = the turbulent mixing layer (large,
    folded, sheet-like — the structural regime of Miranda)."""
    x, y, z = _grid(res, extent)
    rng = np.random.default_rng(seed)
    disp = np.zeros_like(x)
    for _ in range(modes):
        kx, ky = rng.uniform(2.0, 9.0, 2)
        ph1, ph2 = rng.uniform(0, 2 * np.pi, 2)
        amp = rng.uniform(0.04, 0.14)
        disp += amp * np.sin(kx * x + ph1) * np.cos(ky * y + ph2)
    # secondary fold structure (mushroom caps)
    disp += 0.08 * np.sin(4.0 * x) * np.sin(4.0 * y) * np.cos(3.0 * z)
    field = z - disp
    return VolumeSpec(field.astype(np.float32), 0.0, extent, "miranda_like")
