"""Isosurface point extraction (the pipeline's ParaView-extract stand-in).

Emits one interpolated point per sign-changing voxel edge (the vertex set of
marching cubes, without the mesh topology — 3D-GS only needs points), plus
central-difference normals and Lambertian-shaded colors matching the
ground-truth raymarcher, so Gaussian color init starts near the target.
"""
from __future__ import annotations

import numpy as np

from repro.volume.datasets import VolumeSpec

LIGHT_DIR = np.float32([0.4, 0.5, -0.75])
BASE_COLOR = np.float32([0.75, 0.72, 0.65])
AMBIENT = 0.25


def _normals(field: np.ndarray) -> np.ndarray:
    gx, gy, gz = np.gradient(field.astype(np.float32))
    n = np.stack([gx, gy, gz], -1)
    n /= np.linalg.norm(n, axis=-1, keepdims=True) + 1e-12
    return n


def shade(normals: np.ndarray) -> np.ndarray:
    """Lambertian shade — identical math to repro.volume.raymarch."""
    l = LIGHT_DIR / np.linalg.norm(LIGHT_DIR)
    lam = np.clip(-(normals @ l), 0.0, 1.0)
    return np.clip(BASE_COLOR[None] * (AMBIENT + (1 - AMBIENT) * lam[:, None]), 0.0, 1.0)


def extract_isosurface_points(
    vol: VolumeSpec, *, max_points: int | None = None, seed: int = 0
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Returns (points (M,3), normals (M,3), colors (M,3)) on the isosurface."""
    f = vol.field - vol.isovalue
    res = f.shape[0]
    spacing = 2 * vol.extent / (res - 1)
    norms = _normals(f)

    pts_all, nrm_all = [], []
    for axis in range(3):
        a = f
        b = np.roll(f, -1, axis=axis)
        sl = [slice(None)] * 3
        sl[axis] = slice(0, res - 1)
        sl = tuple(sl)
        a, b = a[sl], b[sl]
        cross = (a * b) < 0
        idx = np.argwhere(cross)
        if idx.size == 0:
            continue
        t = a[cross] / (a[cross] - b[cross])  # interpolation along the edge
        pos = idx.astype(np.float32)
        pos[:, axis] += t
        world = pos * spacing - vol.extent
        # interpolate normals between the edge endpoints
        n0 = norms[sl][cross]
        idx2 = idx.copy()
        idx2[:, axis] += 1
        n1 = norms[tuple(idx2.T)]
        n = n0 * (1 - t[:, None]) + n1 * t[:, None]
        n /= np.linalg.norm(n, axis=-1, keepdims=True) + 1e-12
        pts_all.append(world)
        nrm_all.append(n)

    pts = np.concatenate(pts_all, 0).astype(np.float32)
    nrm = np.concatenate(nrm_all, 0).astype(np.float32)
    if max_points is not None and pts.shape[0] > max_points:
        rng = np.random.default_rng(seed)
        keep = rng.choice(pts.shape[0], max_points, replace=False)
        pts, nrm = pts[keep], nrm[keep]
    return pts, nrm, shade(nrm)
