"""System tests: end-to-end GS training, densification, checkpointing."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import GSConfig
from repro.core.densify import densify_and_rebalance, reset_opacity, DEAD_LOGIT
from repro.core.train import init_state, make_train_step, make_eval_render, state_shardings
from repro.checkpoint import save_checkpoint, restore_checkpoint, latest_step
from repro.core import gaussians as G
from repro.core.losses import psnr
from repro.volume import kingsnake_like, miranda_like, extract_isosurface_points, orbit_cameras, render_isosurface
from repro.volume.cameras import camera_slice
from repro.data.views import ViewDataset


def _setup(n_points=600, H=32, views=4, res=32):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = GSConfig(img_h=H, img_w=H, tile_h=16, tile_w=16, k_per_tile=128, batch_size=2,
                   densify_from=1, densify_interval=5, densify_until=100)
    vol = kingsnake_like(res=res)
    pts, _, cols = extract_isosurface_points(vol, max_points=n_points, seed=0)
    pad = (-pts.shape[0]) % 128
    pts = np.concatenate([pts, np.full((pad, 3), 1e6, np.float32)])
    cols = np.concatenate([cols, np.zeros((pad, 3), np.float32)])
    g = G.init_from_points(jnp.asarray(pts), jnp.asarray(cols), init_scale=0.06)
    data = ViewDataset(vol, n_views=views, img_h=H, img_w=H, cache_dir=None, n_steps_raymarch=48)
    return mesh, cfg, g, data


def test_training_reduces_loss_and_improves_psnr():
    mesh, cfg, g, data = _setup()
    state = jax.device_put(init_state(g), state_shardings(mesh))
    step = make_train_step(mesh, cfg)
    eval_fn = make_eval_render(mesh, cfg)
    cam0, gt0 = data.view(0)
    img0, _ = eval_fn(state.params, cam0)
    psnr_before = float(psnr(img0, gt0))
    losses = []
    for cams, gt in data.batches(cfg.batch_size, steps=15):
        state, m = step(state, cams, gt)
        losses.append(float(m["loss"]))
    img1, _ = eval_fn(state.params, cam0)
    psnr_after = float(psnr(img1, gt0))
    assert losses[-1] < losses[0]
    assert psnr_after > psnr_before
    assert np.isfinite(losses).all()


def test_densify_grows_and_prunes():
    mesh, cfg, g, data = _setup()
    state = jax.device_put(init_state(g), state_shardings(mesh))
    step = make_train_step(mesh, cfg)
    for cams, gt in data.batches(cfg.batch_size, steps=6):
        state, _ = step(state, cams, gt)
    n_before = state.params.n
    state2, report = densify_and_rebalance(state, cfg, n_shards=1)
    assert report.n_padded == state2.params.n
    assert report.n_padded % cfg.pad_quantum == 0
    assert report.n_after <= report.n_padded
    # training continues after re-jit with the new count
    step2 = make_train_step(mesh, cfg)
    cams, gt = next(iter(data.batches(cfg.batch_size, steps=1)))
    state3, m = step2(jax.device_put(state2, state_shardings(mesh)), cams, gt)
    assert np.isfinite(float(m["loss"]))


def test_opacity_reset_keeps_dead_dead():
    mesh, cfg, g, data = _setup()
    state = init_state(g)
    state = reset_opacity(state)
    logit = np.asarray(state.params.opacity_logit)
    live_max = 1.0 / (1.0 + np.exp(-logit[logit > DEAD_LOGIT + 1e-3]))
    assert np.all(live_max <= 0.0101)


def test_checkpoint_roundtrip(tmp_path):
    mesh, cfg, g, data = _setup(n_points=200)
    state = init_state(g)
    d = save_checkpoint(str(tmp_path), 7, state)
    assert latest_step(str(tmp_path)) == 7
    restored = restore_checkpoint(str(tmp_path), 7, jax.tree_util.tree_map(np.asarray, state))
    for a, b in zip(jax.tree_util.tree_leaves(state), jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_miranda_volume_pipeline():
    vol = miranda_like(res=32)
    pts, nrm, cols = extract_isosurface_points(vol, max_points=500)
    assert pts.shape[0] > 0 and pts.shape == nrm.shape == cols.shape
    assert np.all(np.isfinite(pts)) and np.all(cols >= 0) and np.all(cols <= 1)
    cams = orbit_cameras(2, img_h=24, img_w=24)
    img = render_isosurface(jnp.asarray(vol.field), vol.isovalue, camera_slice(cams, 0),
                            img_h=24, img_w=24, n_steps=32)
    assert img.shape == (24, 24, 3) and bool(jnp.isfinite(img).all())
