"""Loss / metric stack tests incl. the distributed-SSIM exactness property."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.losses import gs_loss, l1_loss, lpips_proxy, psnr, ssim
from repro.core.sharding import ssim_l1_sums


def _img(seed, h=64, w=64):
    return jnp.asarray(np.random.default_rng(seed).uniform(0, 1, (h, w, 3)).astype(np.float32))


def test_ssim_identity():
    a = _img(0)
    assert float(ssim(a, a)) > 0.9999


def test_ssim_symmetric_and_bounded():
    a, b = _img(1), _img(2)
    s1, s2 = float(ssim(a, b)), float(ssim(b, a))
    assert abs(s1 - s2) < 1e-5
    assert -1.0 <= s1 <= 1.0


def test_psnr_known_value():
    a = jnp.zeros((8, 8, 3))
    b = jnp.full((8, 8, 3), 0.1)
    assert abs(float(psnr(a, b)) - 20.0) < 1e-3


def test_gs_loss_zero_at_identity():
    a = _img(3)
    assert float(gs_loss(a, a)) < 1e-6


def test_lpips_proxy_orders_similarity():
    a = _img(4)
    near = jnp.clip(a + 0.01, 0, 1)
    far = _img(5)
    assert float(lpips_proxy(a, near)) < float(lpips_proxy(a, far))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100))
def test_local_ssim_sums_match_global(seed):
    """ssim_l1_sums without axis (whole image) reproduces losses.ssim exactly."""
    a, b = _img(seed), _img(seed + 999)
    ss, l1s, cnt = ssim_l1_sums(a, b, None)
    global_ssim = float(ssim(a, b))
    assert abs(float(ss) / float(cnt) - global_ssim) < 1e-5
    assert abs(float(l1s) / float(cnt) - float(l1_loss(a, b))) < 1e-6
