"""Distribution-correctness: the Grendel-style sharded step must produce the
same optimization trajectory as single-device (run in a subprocess with 8
forced host devices; conftest keeps the main process at 1 device)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SCRIPT = textwrap.dedent(
    """
    import os, sys, json
    if len(sys.argv) > 1 and sys.argv[1] != "1":
        os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={sys.argv[1]}"
    gather_mode = sys.argv[2] if len(sys.argv) > 2 else "projected"
    import jax, numpy as np, jax.numpy as jnp
    from repro.core import gaussians as G
    from repro.core.config import GSConfig
    from repro.core.train import init_state, make_train_step, state_shardings
    from repro.volume import kingsnake_like, extract_isosurface_points, orbit_cameras, render_isosurface
    from repro.volume.cameras import camera_slice

    nd = len(jax.devices())
    shape = {1: (1, 1), 8: (4, 2)}[nd]
    mesh = jax.make_mesh(shape, ("data", "model"))
    H = W = 32
    cfg = GSConfig(img_h=H, img_w=W, tile_h=16, tile_w=16, k_per_tile=128, batch_size=4,
                   backend="ref", gather_mode=gather_mode)
    vol = kingsnake_like(res=32)
    pts, nrm, cols = extract_isosurface_points(vol, max_points=800, seed=0)
    cams = orbit_cameras(4, img_h=H, img_w=W)
    gts = jnp.stack([
        render_isosurface(jnp.asarray(vol.field), vol.isovalue, camera_slice(cams, i), img_h=H, img_w=W, n_steps=48)
        for i in range(4)
    ])
    m = mesh.shape["model"]
    pad = (-pts.shape[0]) % (m * 128)
    pts = np.concatenate([pts, np.full((pad, 3), 1e6, np.float32)])
    cols = np.concatenate([cols, np.zeros((pad, 3), np.float32)])
    g = G.init_from_points(jnp.asarray(pts), jnp.asarray(cols), init_scale=0.06)
    g = g._replace(opacity_logit=g.opacity_logit.at[pts.shape[0]-pad:].set(-20.0))
    state = jax.device_put(init_state(g), state_shardings(mesh))
    step = make_train_step(mesh, cfg)
    losses = []
    for i in range(6):
        state, metrics = step(state, cams, gts)
        losses.append(float(metrics["loss"]))
    print(json.dumps(losses))
    """
)


def _run(n_devices: int, gather_mode: str = "projected"):
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT, str(n_devices), gather_mode],
        capture_output=True, text=True, timeout=900, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert r.returncode == 0, r.stderr[-3000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_sharded_equals_single_device():
    l1 = _run(1)
    l8 = _run(8)
    np.testing.assert_allclose(l8, l1, atol=5e-6)
    assert l1[-1] < l1[0]  # it actually optimizes


@pytest.mark.slow
def test_params3d_gather_equals_projected():
    """The beyond-paper 3D-state gather schedule is trajectory-identical to
    the paper-faithful projected-splat schedule under real sharding."""
    l_proj = _run(8, "projected")
    l_3d = _run(8, "params3d")
    np.testing.assert_allclose(l_3d, l_proj, atol=5e-6)


# ====================================================== shard-balance gauges
BALANCE_SCRIPT = textwrap.dedent(
    """
    import os, sys, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, numpy as np, jax.numpy as jnp
    from repro.core.train import init_state, record_shard_balance, shard_balance, state_shardings
    from repro.insitu import fixed_capacity_init
    from repro.obs import MetricsRegistry

    mesh = jax.make_mesh((1, 4), ("data", "model"))
    n = 512
    rng = np.random.default_rng(0)
    pts = (rng.normal(size=(n, 3)) * 0.3).astype(np.float32)
    cols = rng.uniform(size=(n, 3)).astype(np.float32)
    g = fixed_capacity_init(pts, cols, n)  # n0 == capacity: every slot alive
    state = jax.device_put(init_state(g), state_shardings(mesh))
    b0 = shard_balance(state)
    m = MetricsRegistry()
    record_shard_balance(m, b0)
    # kill every slot of shard 0 (model-axis rows are contiguous blocks)
    dead = state.params.opacity_logit.at[: n // 4].set(-20.0)
    state = state._replace(params=state.params._replace(opacity_logit=dead))
    state = jax.device_put(state, state_shardings(mesh))
    b1 = shard_balance(state)
    print(json.dumps({"b0": b0, "b1": b1, "snap": m.snapshot()}))
    """
)


@pytest.mark.slow
def test_shard_balance_gauges_on_forced_mesh():
    """On a forced 4-device model mesh: per-shard alive gauges sum to the
    model size, a fresh exactly-at-capacity uniform init is perfectly
    balanced (imbalance == 1.0), and masking one shard's opacities skews it
    (> 1.0) — the signal a dynamic rebalancing pass will trigger on."""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-c", BALANCE_SCRIPT],
        capture_output=True, text=True, timeout=900, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    b0, b1, snap = out["b0"], out["b1"], out["snap"]

    assert b0["n_shards"] == 4
    assert sum(b0["capacity"]) == 512
    assert sum(b0["alive"]) == 512 == b0["alive_total"]
    assert b0["alive"] == [128] * 4  # uniform: every slot of every shard alive
    assert b0["imbalance"] == pytest.approx(1.0)

    # the registry mirrors the balance dict: per-shard gauges sum to the
    # model size and the imbalance gauge is what the dict computed
    gauges = [snap[f"train.shard_alive.s{i}"] for i in range(4)]
    assert sum(gauges) == 512 == snap["train.alive_total"]
    assert snap["train.shard_imbalance"] == pytest.approx(1.0)
    assert sum(snap[f"train.shard_capacity.s{i}"] for i in range(4)) == 512

    # one shard masked dead: total drops by that shard, max/mean rises
    assert b1["alive"][0] == 0 and sum(b1["alive"]) == 384
    assert b1["imbalance"] == pytest.approx(128 / (384 / 4))
    assert b1["imbalance"] > 1.0


# ==================================== traced-vs-untraced training guarantees
def _insitu_pair_vol():
    from repro.volume.timevary import synthetic_stream

    return next(iter(synthetic_stream("miranda", 1, res=24, t1=0.0)))


def _tiny_insitu(obs):
    import jax

    from repro.core.config import GSConfig
    from repro.insitu import InsituTrainer

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = GSConfig(
        img_h=24, img_w=24, tile_h=8, tile_w=8, k_per_tile=32, batch_size=2,
        max_steps=64, densify_from=10**9, opacity_reset_interval=10**9,
    )
    return InsituTrainer(
        cfg, mesh, cold_steps=4, warm_steps=2, n_views=4, max_points=200,
        n_steps_raymarch=16, seed=0, obs=obs,
    )


def test_training_trace_zero_alloc_and_bitwise_step():
    """The serving guarantees, restated for the train loop: with the
    NullRecorder, a full train step allocates NOTHING in the trace layer;
    and tracing a run (spans + block_until_ready fences) leaves the
    optimization bitwise identical to the untraced run."""
    import tracemalloc

    import jax

    from repro.obs import TRAIN_STAGES, Obs

    off = _tiny_insitu(Obs())
    on = _tiny_insitu(Obs(trace=True))
    vol = _insitu_pair_vol()
    rep_off = off.start(vol)
    rep_on = on.start(vol)
    assert rep_off.steps == rep_on.steps

    # bitwise: block_until_ready fences bound the device span but must not
    # perturb a single bit of the trajectory
    p_off = jax.tree_util.tree_map(np.asarray, off.state)
    p_on = jax.tree_util.tree_map(np.asarray, on.state)
    for a, b in zip(jax.tree_util.tree_leaves(p_off), jax.tree_util.tree_leaves(p_on)):
        np.testing.assert_array_equal(a, b)

    # the traced run produced training spans, all from the vocabulary
    spans = on.obs.trace.drain()
    names = {s.name for s in spans}
    assert {"extract", "batch", "dispatch", "device", "fit", "eval"} <= names
    assert names <= set(TRAIN_STAGES)

    # zero-alloc: more warm steps with tracing off touch the trace layer not
    # at all (registry observes are exempt — the guarantee is about spans)
    data = off._dataset(vol)
    off._fit(data, 1, psnr0=0.0)  # warm any lazy paths before measuring
    tracemalloc.start()
    s1 = tracemalloc.take_snapshot()
    off._fit(data, 2, psnr0=0.0)
    s2 = tracemalloc.take_snapshot()
    tracemalloc.stop()
    filt = [tracemalloc.Filter(True, "*obs/trace*")]
    diff = s2.filter_traces(filt).compare_to(s1.filter_traces(filt), "lineno")
    assert sum(abs(d.size_diff) for d in diff) == 0, diff
