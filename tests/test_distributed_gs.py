"""Distribution-correctness: the Grendel-style sharded step must produce the
same optimization trajectory as single-device (run in a subprocess with 8
forced host devices; conftest keeps the main process at 1 device)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SCRIPT = textwrap.dedent(
    """
    import os, sys, json
    if len(sys.argv) > 1 and sys.argv[1] != "1":
        os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={sys.argv[1]}"
    gather_mode = sys.argv[2] if len(sys.argv) > 2 else "projected"
    import jax, numpy as np, jax.numpy as jnp
    from repro.core import gaussians as G
    from repro.core.config import GSConfig
    from repro.core.train import init_state, make_train_step, state_shardings
    from repro.volume import kingsnake_like, extract_isosurface_points, orbit_cameras, render_isosurface
    from repro.volume.cameras import camera_slice

    nd = len(jax.devices())
    shape = {1: (1, 1), 8: (4, 2)}[nd]
    mesh = jax.make_mesh(shape, ("data", "model"))
    H = W = 32
    cfg = GSConfig(img_h=H, img_w=W, tile_h=16, tile_w=16, k_per_tile=128, batch_size=4,
                   backend="ref", gather_mode=gather_mode)
    vol = kingsnake_like(res=32)
    pts, nrm, cols = extract_isosurface_points(vol, max_points=800, seed=0)
    cams = orbit_cameras(4, img_h=H, img_w=W)
    gts = jnp.stack([
        render_isosurface(jnp.asarray(vol.field), vol.isovalue, camera_slice(cams, i), img_h=H, img_w=W, n_steps=48)
        for i in range(4)
    ])
    m = mesh.shape["model"]
    pad = (-pts.shape[0]) % (m * 128)
    pts = np.concatenate([pts, np.full((pad, 3), 1e6, np.float32)])
    cols = np.concatenate([cols, np.zeros((pad, 3), np.float32)])
    g = G.init_from_points(jnp.asarray(pts), jnp.asarray(cols), init_scale=0.06)
    g = g._replace(opacity_logit=g.opacity_logit.at[pts.shape[0]-pad:].set(-20.0))
    state = jax.device_put(init_state(g), state_shardings(mesh))
    step = make_train_step(mesh, cfg)
    losses = []
    for i in range(6):
        state, metrics = step(state, cams, gts)
        losses.append(float(metrics["loss"]))
    print(json.dumps(losses))
    """
)


def _run(n_devices: int, gather_mode: str = "projected"):
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT, str(n_devices), gather_mode],
        capture_output=True, text=True, timeout=900, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert r.returncode == 0, r.stderr[-3000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_sharded_equals_single_device():
    l1 = _run(1)
    l8 = _run(8)
    np.testing.assert_allclose(l8, l1, atol=5e-6)
    assert l1[-1] < l1[0]  # it actually optimizes


@pytest.mark.slow
def test_params3d_gather_equals_projected():
    """The beyond-paper 3D-state gather schedule is trajectory-identical to
    the paper-faithful projected-splat schedule under real sharding."""
    l_proj = _run(8, "projected")
    l_3d = _run(8, "params3d")
    np.testing.assert_allclose(l_3d, l_proj, atol=5e-6)
