"""repro.analysis: known-good/known-bad fixtures per rule class, pragma
handling, the baseline ratchet, the runtime sanitizer, and a live-repo
self-check (the committed tree + ANALYSIS_baseline.json must be clean).

Pure AST + threading — never imports jax, so the whole file runs in
milliseconds. Fixture sources live in tmp trees; dotted metric literals in
assertions are kept off the real vocabulary (``*.fixture_*``) so the live
``names`` pass scanning tests/ sees only waived or non-matching strings.
"""
import json
import os
import textwrap
import threading

import pytest

from repro.analysis import common, hygiene, locks, names, retrace, tsan
from repro.launch import analyze

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def load(tmp_path, source, name="mod.py"):
    p = tmp_path / name
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(source))
    return common.load_tree([str(p)], str(tmp_path))


def rules_of(findings, *, active_only=True):
    return sorted(f.rule for f in findings
                  if not (active_only and f.allowed_by is not None))


# ---------------------------------------------------------------- retrace
def test_retrace_jit_in_loop(tmp_path):
    fs = load(tmp_path, """
        import jax
        def caller(xs):
            for x in xs:
                f = jax.jit(step)
            gs = [jax.jit(g) for g in xs]
    """)
    found = retrace.run(fs)
    assert rules_of(found) == ["retrace.jit_in_loop", "retrace.jit_in_loop"]
    assert all("caller" in f.detail for f in found)


def test_retrace_factory_in_loop(tmp_path):
    fs = load(tmp_path, """
        import jax
        def make_step():
            return jax.jit(step)
        def caller(xs):
            for x in xs:
                s = make_step()
    """)
    assert rules_of(retrace.run(fs)) == ["retrace.factory_in_loop"]


def test_retrace_jit_outside_factory_and_waivers(tmp_path):
    fs = load(tmp_path, """
        import jax
        def handler(x):
            g = jax.jit(step)       # per-call retrace: flagged
            return g(x)
        def make_kernel():
            def run(x):             # closure inside a factory: fine
                return pallas_call(kern)(x)
            return jax.jit(run)
        def __init__(self):
            self.f = jax.jit(step)  # construction-time: fine
    """)
    found = retrace.run(fs)
    assert rules_of(found) == ["retrace.jit_outside_factory"]
    assert found[0].detail == "handler:jit"


def test_retrace_decorator_is_enclosing_scope(tmp_path):
    # @partial(jax.jit) on a module-level def evaluates at module scope:
    # neither an outside-factory construction nor a factory classification
    fs = load(tmp_path, """
        import jax
        from functools import partial
        @partial(jax.jit, static_argnums=(1,))
        def render(x, n):
            return x * n
        def caller(xs):
            return [render(x, 2) for x in xs]
    """)
    assert retrace.run(fs) == []


def test_retrace_generic_names_never_factories(tmp_path):
    # "run" builds a jit somewhere, but generic names stay out of the
    # factory set — obj.run() in a loop elsewhere must not flag
    fs = load(tmp_path, """
        import jax
        class K:
            def run(self):
                return jax.jit(step)
        def drive(server, xs):
            for x in xs:
                server.run()
    """)
    assert rules_of(retrace.run(fs)) == ["retrace.jit_outside_factory"]


def test_retrace_unhashable_static(tmp_path):
    fs = load(tmp_path, """
        import jax
        f = jax.jit(step, static_argnums=[1])
        g = jax.jit(step, static_argnames=("n",))
    """)
    found = retrace.run(fs)
    assert rules_of(found) == ["retrace.unhashable_static"]
    assert found[0].detail.endswith("static_argnums")


# ------------------------------------------------------------------ names
def test_names_vocabulary(tmp_path):  # analysis: allow(names., fixture metric literals in assertions)
    fs = load(tmp_path, """
        def wire(m, snap):
            m.counter("server.fixture_hits").inc()
            m.gauge("server.fixture_dead").set(1)
            return snap["server.fixture_hits"], snap["server.fixture_typo"]
    """)
    found = names.run(fs)
    assert rules_of(found) == ["names.unread", "names.unregistered_use"]
    by_rule = {f.rule: f for f in found}
    assert by_rule["names.unread"].detail == "server.fixture_dead"
    assert by_rule["names.unregistered_use"].detail == "server.fixture_typo"


def test_names_doc_evidence_and_drift(tmp_path):  # analysis: allow(names., fixture metric literals in assertions)
    fs = load(tmp_path, """
        def wire(m):
            m.gauge("server.fixture_doc").set(1)
    """)
    docs = {"README.md": "reports `server.fixture_doc` and `server.fixture_ghost`"}
    found = names.run(fs, docs)
    # doc mention reads fixture_doc (no unread); fixture_ghost drifted
    assert rules_of(found) == ["names.doc_drift"]
    assert found[0].detail == "server.fixture_ghost"
    assert found[0].path == "README.md"


def test_names_dynamic_families_and_declare(tmp_path):  # analysis: allow(names., fixture metric literals in assertions)
    fs = load(tmp_path, """
        def wire(m, prefix, snap, i):
            m.gauge(f"server.fixture_l{i}").set(1)     # family: resolvable
            m.gauge(prefix + ".depth").set(1)          # unresolvable: flagged
            m.gauge(prefix + ".width").set(1)  # analysis: declare(train.fixture_w.*)
            return snap["server.fixture_l3"], snap["train.fixture_w.depth"]
    """)
    docs = {"README.md": "see `server.fixture_l<i>` per level"}
    found = names.run(fs, docs)
    # both uses covered (family + declared family), doc token matches the
    # family; only the undeclared dynamic registration remains
    assert rules_of(found) == ["names.dynamic_unresolved"]
    assert found[0].detail == "wire"


def test_names_prefix_read_reclassification(tmp_path):  # analysis: allow(names., fixture metric literals in assertions)
    fs = load(tmp_path, """
        def wire(m, snap):
            m.counter("server.fixture_a.s0").inc()
            m.counter("server.fixture_a.s1").inc()
            return {k: v for k, v in snap.items()
                    if k.startswith("server.fixture_a.s")}
    """)
    # the startswith literal is a prefix read, not a typo'd use — and it
    # counts as read evidence for both registered names
    assert names.run(fs) == []


def test_names_spans(tmp_path):
    fs = load(tmp_path, """
        STAGES = ("alpha", "beta")
        def go(rec, rid):
            rec.record(rid, "alpha", 0.0)
            rec.record(rid, "gamma", 0.0)
    """)
    found = names.run(fs)
    assert rules_of(found) == ["names.unknown_span", "names.unrecorded_stage"]
    details = {f.rule: f.detail for f in found}
    assert details["names.unknown_span"] == "gamma"
    assert details["names.unrecorded_stage"] == "beta"


def test_names_test_files_may_record_offvocab_spans(tmp_path):
    vocab = load(tmp_path, "STAGES = ('alpha',)\ndef go(r, rid): r.record(rid, 'alpha', 0)\n", name="src/trace.py")
    test = load(tmp_path, "def go(r, rid): r.record(rid, 'mystery', 0)\n", name="tests/t_x.py")
    assert names.run(vocab + test) == []


# ------------------------------------------------------------------ locks
def test_locks_inconsistent_guard(tmp_path):
    fs = load(tmp_path, """
        import threading
        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self.items = []
            def put(self, x):
                with self._lock:
                    self.items.append(x)
            def drop(self):
                self.items = []
    """)
    found = locks.run(fs)
    assert rules_of(found) == ["locks.inconsistent_guard"]
    assert found[0].detail == "C.items"


def test_locks_consistent_guard_is_clean(tmp_path):
    fs = load(tmp_path, """
        import threading
        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self.items = []
            def put(self, x):
                with self._lock:
                    self.items.append(x)
            def drain(self):
                with self._lock:
                    out, self.items = self.items, []
                return out
    """)
    assert locks.run(fs) == []


def test_locks_thread_shared_write(tmp_path):
    fs = load(tmp_path, """
        import threading
        class W:
            def start(self):
                self._t = threading.Thread(target=self._loop)
                self._t.start()
            def _loop(self):
                self.count = 1
            def read(self):
                return self.count
    """)
    found = locks.run(fs)
    assert rules_of(found) == ["locks.thread_shared_write"]
    assert found[0].detail == "W.count"


def test_locks_thread_shared_guarded_is_clean(tmp_path):
    fs = load(tmp_path, """
        import threading
        class W:
            def start(self):
                self._lock = threading.Lock()
                threading.Thread(target=self._loop).start()
            def _loop(self):
                with self._lock:
                    self.count = 1
            def read(self):
                with self._lock:
                    return self.count
    """)
    assert locks.run(fs) == []


def test_locks_pragma_on_method_header_covers_block(tmp_path):
    fs = load(tmp_path, """
        import threading
        class W:
            def start(self):
                threading.Thread(target=self._loop).start()
            def _loop(self):  # analysis: allow(locks.thread_shared_write, ordered by queue.join)
                self.count = 1
            def read(self):
                return self.count
    """)
    found = locks.run(fs)
    assert len(found) == 1
    assert found[0].allowed_by == "ordered by queue.join"


# ---------------------------------------------------------------- hygiene
def test_hygiene_broad_except(tmp_path):
    fs = load(tmp_path, """
        def f():
            try:
                g()
            except Exception:
                pass
            try:
                g()
            except (ValueError, BaseException):
                pass
            try:
                g()
            except:
                pass
            try:
                g()
            except ValueError:
                pass
    """)
    found = hygiene.run(fs)
    assert rules_of(found) == ["hygiene.broad_except"] * 3
    assert all(f.detail == "f" for f in found)


# ---------------------------------------------------------------- pragmas
def test_pragma_placements(tmp_path):
    fs = load(tmp_path, """
        import jax
        def a(x):
            g = jax.jit(step)  # analysis: allow(retrace.jit_outside_factory, one-shot path)
            return g(x)
        def b(x):
            # analysis: allow(retrace., whole-family prefix on next line)
            g = jax.jit(step)
            return g(x)
        def c(x):  # analysis: allow(*, block scope from the def header)
            g = jax.jit(step)
            return g(x)
        def d(x):
            g = jax.jit(step)  # analysis: allow(locks.thread_shared_write, wrong rule)
            return g(x)
    """)
    found = retrace.run(fs)
    assert len(found) == 4
    by_fn = {f.detail.split(":")[0]: f for f in found}
    assert by_fn["a"].allowed_by == "one-shot path"
    assert by_fn["b"].allowed_by == "whole-family prefix on next line"
    assert by_fn["c"].allowed_by == "block scope from the def header"
    assert by_fn["d"].allowed_by is None  # rule mismatch: still active


# --------------------------------------------------------------- baseline
def test_baseline_ratchet_roundtrip(tmp_path):
    f1 = common.Finding("r.x", "a.py", 3, "A.f", "m")
    f2 = common.Finding("r.x", "a.py", 9, "A.f", "m")   # same key, 2nd hit
    f3 = common.Finding("r.y", "b.py", 1, "B.g", "m")
    path = str(tmp_path / "base.json")
    common.save_baseline(path, [f1, f2, f3])
    base = common.load_baseline(path)
    assert base == {"r.x|a.py|A.f": 2, "r.y|b.py|B.g": 1}

    # same findings: nothing new; dropping one key reports it fixed
    new, fixed, _ = common.diff_against_baseline([f1, f2, f3], base)
    assert new == [] and fixed == []
    new, fixed, _ = common.diff_against_baseline([f1, f2], base)
    assert new == [] and fixed == ["r.y|b.py|B.g"]

    # a third hit of a baselined-at-2 key IS new; so is a fresh key
    f4 = common.Finding("r.x", "a.py", 20, "A.f", "m")
    f5 = common.Finding("r.z", "c.py", 2, "C.h", "m")
    new, _, _ = common.diff_against_baseline([f1, f2, f3, f4, f5], base)
    assert sorted(f.key() for f in new) == ["r.x|a.py|A.f", "r.z|c.py|C.h"]

    # pragma-allowed findings never count against the baseline
    f5.allowed_by = "waived"
    new, _, _ = common.diff_against_baseline([f1, f2, f3, f5], base)
    assert new == []


_BAD_MODULE = """
import threading
import jax

STAGES = ("alpha", "beta")

def make_model():
    return jax.jit(model)

def handler(xs):
    out = []
    for x in xs:
        f = jax.jit(step)
        g = make_model()
        out.append(f(x))
    h = jax.jit(step, static_argnums=[0])
    try:
        return h(out)
    except Exception:
        return None

def meter(m, rec, rid, prefix, snap):
    m.counter("server.fixture_hits").inc()
    m.gauge(prefix + ".depth").set(1)
    rec.record(rid, "gamma", 0.0)
    return snap["server.fixture_typo"]

class Shared:
    def __init__(self):
        self._lock = threading.Lock()
        self.items = []
        self.count = 0
    def put(self, x):
        with self._lock:
            self.items.append(x)
    def drop(self):
        self.items = []
    def start(self):
        threading.Thread(target=self._loop).start()
    def _loop(self):
        self.count += 1
    def read(self):
        return self.count
"""

_EXPECT_SEEDED = {
    "retrace.jit_in_loop",
    "retrace.factory_in_loop",
    "retrace.jit_outside_factory",
    "retrace.unhashable_static",
    "hygiene.broad_except",
    "locks.inconsistent_guard",
    "locks.thread_shared_write",
    "names.unread",
    "names.unregistered_use",
    "names.dynamic_unresolved",
    "names.unknown_span",
    "names.unrecorded_stage",
}


def test_cli_seeded_regressions_fail_then_baseline(tmp_path, capsys):
    (tmp_path / "src").mkdir()
    (tmp_path / "src" / "seeded.py").write_text(_BAD_MODULE)
    report = tmp_path / "rep.json"

    rc = analyze.main(["--root", str(tmp_path), "--report", str(report), "-q"])
    assert rc == 1
    rep = json.loads(report.read_text())
    assert set(rep["by_rule"]) == _EXPECT_SEEDED
    assert rep["findings"] == rep["baseline"]["new"] == len(rep["new_findings"])

    # accept the debt: baseline it, rerun clean
    rc = analyze.main(["--root", str(tmp_path), "--update-baseline", "-q"])
    assert rc == 0
    assert (tmp_path / "ANALYSIS_baseline.json").exists()
    rc = analyze.main(["--root", str(tmp_path), "-q"])
    assert rc == 0

    # growth over the baseline fails again
    with open(tmp_path / "src" / "seeded.py", "a") as f:
        f.write("\ndef another(x):\n    return jax.jit(step)(x)\n")
    rc = analyze.main(["--root", str(tmp_path), "--report", str(report), "-q"])
    assert rc == 1
    rep = json.loads(report.read_text())
    assert [n["detail"] for n in rep["new_findings"]] == ["another:jit"]


def test_cli_rule_filter_and_parse_error(tmp_path):
    (tmp_path / "src").mkdir()
    (tmp_path / "src" / "seeded.py").write_text(_BAD_MODULE)
    rc = analyze.main(["--root", str(tmp_path), "--rules", "locks.", "-q",
                       "--report", str(tmp_path / "r.json")])
    assert rc == 1
    rep = json.loads((tmp_path / "r.json").read_text())
    assert set(rep["by_rule"]) == {"locks.inconsistent_guard",
                                   "locks.thread_shared_write"}

    (tmp_path / "src" / "broken.py").write_text("def f(:\n")
    assert analyze.main(["--root", str(tmp_path), "-q"]) == 2


def test_live_repo_is_clean_against_committed_baseline(tmp_path):
    """The committed tree + ANALYSIS_baseline.json must analyze clean —
    the same invocation CI gates on."""
    report = tmp_path / "rep.json"
    rc = analyze.main(["--root", REPO_ROOT, "--report", str(report), "-q"])
    assert rc == 0, report.read_text()
    rep = json.loads(report.read_text())
    assert rep["baseline"]["new"] == 0
    # the baseline is the accepted-debt list, not a dumping ground: only the
    # one-shot CLI mains live there
    assert rep["findings"] <= 6
    assert rep["elapsed_s"] < 30.0


# ------------------------------------------------------------------- tsan
class _Box:
    def __init__(self):
        self.x = 0
        self.lk = threading.Lock()
        self.d = {}


@pytest.fixture
def tsan_on(monkeypatch):
    monkeypatch.setenv("REPRO_TSAN", "1")
    tsan.reset()
    yield
    tsan.reset()


def _in_thread(fn):
    t = threading.Thread(target=fn, name="racer")
    t.start()
    t.join()


def test_tsan_detects_unlocked_write_write(tsan_on):
    o = tsan.attach(_Box(), name="Box")
    o.x = 1
    _in_thread(lambda: setattr(o, "x", 2))
    races = tsan.take_races()
    assert len(races) == 1
    assert races[0].field == "x" and races[0].obj == "Box"
    assert "racer" in races[0].threads
    # reported once per field, even on further racing writes
    _in_thread(lambda: setattr(o, "x", 3))
    assert tsan.take_races() == []


def test_tsan_lock_discipline_is_clean(tsan_on):
    o = tsan.attach(_Box(), name="Box", locks=("lk",))
    def w():
        with o.lk:
            o.x += 1
    w()
    _in_thread(w)
    assert tsan.take_races() == []


def test_tsan_catches_aliased_dict_mutation(tsan_on):
    o = tsan.attach(_Box(), name="Box", dicts=("d",))
    alias = o.d          # the aliasing the static pass cannot see
    alias["k"] = 1
    _in_thread(lambda: alias.pop("k"))
    races = tsan.take_races()
    assert [r.field for r in races] == ["d"]


def test_tsan_dict_swap_keeps_tracking(tsan_on):
    o = tsan.attach(_Box(), name="Box", dicts=("d",))
    o.d["k"] = 1
    o.d = {}             # take_dirty()-style swap: rewrapped transparently
    assert isinstance(o.d, tsan.TrackedDict)
    _in_thread(lambda: o.d.update(k=2))
    assert [r.field for r in tsan.take_races()] == ["d"]


def test_tsan_ordered_fields_exempt(tsan_on):
    o = tsan.attach(_Box(), name="Box", ordered=("x",))
    o.x = 1
    _in_thread(lambda: setattr(o, "x", 2))
    assert tsan.take_races() == []


def test_tsan_single_thread_never_races(tsan_on):
    o = tsan.attach(_Box(), name="Box", dicts=("d",))
    for i in range(10):
        o.x = i
        o.d[i] = i
    assert tsan.take_races() == []


def test_tsan_disabled_is_a_noop(monkeypatch):
    monkeypatch.delenv("REPRO_TSAN", raising=False)
    o = _Box()
    assert not tsan.enabled()
    assert tsan.attach(o, name="Box", locks=("lk",), dicts=("d",)) is o
    assert type(o) is _Box
    assert type(o.d) is dict and not isinstance(o.lk, tsan.TrackedLock)
