"""Partitioning rules: divisibility fallbacks, pure-DP mode, batch/cache specs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as PS

from repro.configs import get_arch
from repro.configs.common import SHAPES, decode_specs, lm_batch_specs, params_specs
from repro.models import api
from repro.models.partitioning import batch_pspecs, cache_pspecs, param_pspecs


@pytest.fixture(scope="module")
def mesh():
    # single device "mesh" with named axes of size 1 won't exercise
    # divisibility; build a fake 16x16 mesh via AbstractMesh
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh((16, 16), ("data", "model"))
    except TypeError:  # jax 0.4.x: AbstractMesh(((name, size), ...))
        return AbstractMesh((("data", 16), ("model", 16)))


def _leaves_with_specs(cfg, mesh):
    params = params_specs(cfg)
    specs = param_pspecs(cfg, params, mesh)
    return list(zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(specs, is_leaf=lambda x: isinstance(x, PS))))


def test_divisibility_fallback(mesh):
    cfg = get_arch("qwen3_0_6b").config()
    for leaf, spec in _leaves_with_specs(cfg, mesh):
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * (len(leaf.shape) - len(spec))):
            if ax is None:
                continue
            size = mesh.shape[ax] if isinstance(ax, str) else np.prod([mesh.shape[a] for a in ax])
            assert dim % size == 0, (leaf.shape, spec)


def test_pure_dp_has_no_model_sharding(mesh):
    cfg = get_arch("xlstm_350m").config()
    assert cfg.pure_dp
    for leaf, spec in _leaves_with_specs(cfg, mesh):
        assert "model" not in jax.tree_util.tree_leaves(tuple(spec)), spec


def test_moe_experts_ep_only(mesh):
    cfg = get_arch("kimi_k2_1t_a32b").config()
    params = params_specs(cfg)
    specs = param_pspecs(cfg, params, mesh)
    wi_spec = specs["units"]["slot0"]["moe"]["wi"]
    # stacked (L, E, d, ff): expert dim on model, nothing else sharded
    assert tuple(wi_spec)[-3:] == ("model", None, None)


def test_batch_specs_shard_batch(mesh):
    cfg = get_arch("granite_3_8b").config()
    batch = lm_batch_specs(cfg, SHAPES["train_4k"])
    specs = batch_pspecs(cfg, batch, mesh)
    first = tuple(specs["tokens"])[0]
    assert first in ("data", ("data",))


def test_cache_specs_long_context(mesh):
    cfg = get_arch("gemma3_27b").config()
    specs = decode_specs(cfg, SHAPES["long_500k"])
    cspecs = cache_pspecs(cfg, specs["cache"], mesh)
    # global layers: B=1 (unshardable) -> seq over data, kv_heads(16) over model
    gspec = cspecs["units"]["slot5"]["k"]  # pattern LLLLLG -> slot5 is global
    leaf = jax.tree_util.tree_leaves(specs["cache"]["units"]["slot5"])[0]
    tail = tuple(gspec)[-4:]
    assert tail[1] == "data" and tail[2] == "model", (leaf.shape, gspec)