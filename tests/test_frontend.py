"""Network frontend tests: wire-protocol round-trips, encoder exactness,
localhost gateway smoke, backpressure/shedding, and depth invariance
through the network path."""
import asyncio
import socket

import numpy as np
import pytest

from repro.core.config import GSConfig
from repro.frontend import (
    AsyncFrontendClient,
    FrameDecoder,
    FrameEncoder,
    FrontendClient,
    Gateway,
    GatewayThread,
    ProtocolError,
    SessionManager,
    ShedError,
    iter_messages,
    pack_message,
    quantize_rgb8,
)
from repro.frontend import protocol as proto
from repro.serve_gs import RenderServer

from conftest import make_cam, make_scene

H = W = 32


# ================================================================= protocol
def test_protocol_roundtrip_fuzz_over_sizes():
    """Messages of many header/payload sizes — including 0-byte payloads —
    survive pack -> concatenate -> parse bit-for-bit."""
    rng = np.random.default_rng(0)
    msgs = []
    for i, size in enumerate([0, 1, 2, 7, 64, 1023, 4096, 65537]):
        header = {"type": "frame", "seq": i, "meta": "x" * (i * 37), "uni": "画像☃"}
        msgs.append((header, rng.bytes(size)))
    buf = b"".join(pack_message(h, p) for h, p in msgs)
    out = list(iter_messages(buf))
    assert len(out) == len(msgs)
    for (h0, p0), (h1, p1) in zip(msgs, out):
        assert h0 == h1 and p0 == p1


def test_protocol_rejects_bad_magic_version_and_truncation():
    good = pack_message({"type": "hello"}, b"abc")
    with pytest.raises(ProtocolError, match="magic"):
        list(iter_messages(b"XX" + good[2:]))
    with pytest.raises(ProtocolError, match="protocol v9"):
        list(iter_messages(good[:2] + bytes([9]) + good[3:]))
    with pytest.raises(ProtocolError, match="truncated"):
        list(iter_messages(good[:-1]))
    with pytest.raises(ProtocolError, match="short prefix"):
        list(iter_messages(good[:5]))


def test_protocol_async_reader_reassembles_split_frames():
    """read_message must reassemble messages fed byte-dribbled into the
    stream, and report clean EOF (None) only at a frame boundary."""

    async def run():
        msgs = [({"type": "a", "seq": 0}, b""), ({"type": "b", "seq": 1}, b"\x00" * 100)]
        data = b"".join(pack_message(h, p) for h, p in msgs)
        reader = asyncio.StreamReader()
        # dribble in uneven chunks to exercise partial-read reassembly
        for i in range(0, len(data), 7):
            reader.feed_data(data[i : i + 7])
        reader.feed_eof()
        out = [await proto.read_message(reader) for _ in range(2)]
        assert [h["type"] for h, _ in out] == ["a", "b"]
        assert out[1][1] == b"\x00" * 100
        assert await proto.read_message(reader) is None  # clean EOF

        # EOF mid-frame is a protocol error, not a silent None
        reader2 = asyncio.StreamReader()
        reader2.feed_data(data[: len(data) - 3])
        reader2.feed_eof()
        await proto.read_message(reader2)
        with pytest.raises(ProtocolError, match="mid-message"):
            await proto.read_message(reader2)

    asyncio.run(run())


def test_camera_wire_roundtrip():
    cam = make_cam(H, W, dist=2.5)
    d = proto.camera_to_wire(cam)
    cam2 = proto.camera_from_wire(d)
    np.testing.assert_allclose(np.asarray(cam.viewmat), cam2.viewmat, atol=1e-6)
    assert float(cam2.fx) == pytest.approx(float(np.asarray(cam.fx)))
    with pytest.raises(ProtocolError, match="camera"):
        proto.camera_from_wire({"viewmat": [1, 2, 3]})


# =================================================================== encode
def test_delta_encoding_is_exact_and_smaller_on_similar_frames():
    rng = np.random.default_rng(1)
    enc, dec = FrameEncoder(), FrameDecoder()
    base = rng.random((24, 24, 3)).astype(np.float32)
    raw_bytes = None
    for step in range(4):
        frame = np.clip(base + 0.002 * step, 0, 1)
        meta, payload = enc.encode("s", frame)
        got = dec.decode("s", meta, payload)
        np.testing.assert_array_equal(got, quantize_rgb8(frame))  # exact
        if step == 0:
            assert meta["encoding"] == "rgb8"
            raw_bytes = len(payload)
        else:
            assert meta["encoding"] == "zdelta8"
            assert len(payload) < raw_bytes  # near-identical frames compress
    # independent per-stream chains: a new stream starts with a keyframe
    meta2, _ = enc.encode("other", base)
    assert meta2["encoding"] == "rgb8"


def test_decoder_rejects_delta_without_base():
    enc, dec = FrameEncoder(), FrameDecoder()
    f = np.zeros((4, 4, 3), np.float32)
    enc.encode("s", f)
    meta, payload = enc.encode("s", f)
    assert meta["encoding"] == "zdelta8"
    with pytest.raises(ValueError, match="without a matching base"):
        dec.decode("s", meta, payload)


def test_tiles8_roundtrip_exact_and_ships_only_changed_tiles():
    """Changed-tile streaming: exact reconstruction, and a frame whose
    motion touches one tile ships one tile (an identical frame ships none)."""
    rng = np.random.default_rng(2)
    enc = FrameEncoder(tiles=True, tile=(8, 8))
    dec = FrameDecoder()
    base = rng.random((24, 24, 3)).astype(np.float32)
    meta, payload = enc.encode("s", base)
    assert meta["encoding"] == "rgb8"  # keyframe
    dec.decode("s", meta, payload)

    # identical frame: tiles8 with zero tiles on the wire
    meta, payload = enc.encode("s", base)
    assert meta["encoding"] == "tiles8" and meta["tiles"] == []
    np.testing.assert_array_equal(dec.decode("s", meta, payload), quantize_rgb8(base))

    # poke ONE 8x8 tile (tile row 1, col 2 -> flat id 1*3+2=5)
    frame = base.copy()
    frame[10, 18] = 1.0 - frame[10, 18]
    meta, payload = enc.encode("s", frame)
    assert meta["encoding"] == "tiles8" and meta["tiles"] == [5]
    got = dec.decode("s", meta, payload)
    np.testing.assert_array_equal(got, quantize_rgb8(frame))
    assert not got.flags.writeable
    s = enc.stats()
    assert s["tile_frames"] == 2 and s["tiles_shipped"] == 1
    assert s["tiles_total"] == 18  # 9 tiles x 2 tile frames


def test_tiles8_handles_ragged_edge_tiles():
    rng = np.random.default_rng(3)
    enc, dec = FrameEncoder(tiles=True, tile=(16, 16)), FrameDecoder()
    a = rng.random((20, 28, 3)).astype(np.float32)  # ragged 16px grid
    b = np.clip(a + 0.01, 0, 1)
    dec.decode("s", *enc.encode("s", a))
    meta, payload = enc.encode("s", b)
    assert meta["encoding"] == "tiles8"
    np.testing.assert_array_equal(dec.decode("s", meta, payload), quantize_rgb8(b))


def test_decoder_validates_payload_length_against_header_shape():
    """Satellite: a truncated/oversized payload from a misbehaving peer must
    raise a protocol-level CodecError naming the stream — on the raw, delta,
    and tiles paths — not a bare numpy reshape error."""
    import zlib

    from repro.frontend import CodecError

    enc, dec = FrameEncoder(), FrameDecoder()
    f = np.full((4, 4, 3), 0.5, np.float32)
    meta, payload = enc.encode("cam0", f)
    # raw: short and long payloads
    with pytest.raises(CodecError, match="cam0.*47"):
        dec.decode("cam0", meta, payload[:-1])
    with pytest.raises(CodecError, match="cam0"):
        dec.decode("cam0", meta, payload + b"\x00")
    dec.decode("cam0", meta, payload)  # establish the delta base
    meta2, payload2 = enc.encode("cam0", f)
    assert meta2["encoding"] == "zdelta8"
    # delta: decompressed size disagrees with the header shape
    with pytest.raises(CodecError, match="cam0"):
        dec.decode("cam0", meta2, zlib.compress(b"\x00" * 10))
    # delta: truncated zlib stream
    with pytest.raises(CodecError, match="cam0"):
        dec.decode("cam0", meta2, payload2[:-2])
    # tiles: payload shorter than the listed tiles need
    tmeta = dict(meta2, encoding="tiles8", tile=[4, 4], tiles=[0])
    with pytest.raises(CodecError, match="cam0"):
        dec.decode("cam0", tmeta, zlib.compress(b"\x00" * 5))
    # tiles: out-of-range tile id
    with pytest.raises(CodecError, match="out of range"):
        dec.decode("cam0", dict(tmeta, tiles=[99]), zlib.compress(b""))
    # the decoder state survived every rejection: a good frame still decodes
    np.testing.assert_array_equal(
        dec.decode("cam0", meta2, payload2), quantize_rgb8(f)
    )


def test_encoder_falls_back_to_raw_when_compression_loses():
    """Satellite: when the compressed delta is no smaller than raw (noisy
    first-contact frames), ship raw and count the fallback."""
    rng = np.random.default_rng(4)
    for tiles in (False, True):
        enc, dec = FrameEncoder(tiles=tiles), FrameDecoder()
        a = rng.random((16, 16, 3)).astype(np.float32)
        b = rng.random((16, 16, 3)).astype(np.float32)  # uncorrelated noise
        enc.encode("s", a)
        meta, payload = enc.encode("s", b)
        assert meta["encoding"] == "rgb8", (tiles, meta)
        assert len(payload) == quantize_rgb8(b).nbytes
        assert enc.stats()["raw_fallbacks"] == 1
        # the decoder chain stays in lockstep through the fallback
        dec.decode("s", meta, payload)
        c = np.clip(b + 1e-3, 0, 1)
        meta3, payload3 = enc.encode("s", c)
        assert meta3["encoding"] in ("zdelta8", "tiles8")
        np.testing.assert_array_equal(dec.decode("s", meta3, payload3), quantize_rgb8(c))


def test_encoder_partial_reset_forces_rows_and_stays_exact():
    """A row-granular reset must not cut the tiles8 chain: the next frame
    ships exactly the forced rows' tiles (even at zero pixel diff), decodes
    bit-exactly, and consumes the mark."""
    rng = np.random.default_rng(5)
    enc, dec = FrameEncoder(tiles=True, tile=(8, 8)), FrameDecoder()
    base = rng.random((24, 24, 3)).astype(np.float32)
    dec.decode("s", *enc.encode("s", base))  # keyframe
    enc.reset("s", rows=[1])
    meta, payload = enc.encode("s", base)  # identical pixels, row 1 forced
    assert meta["encoding"] == "tiles8"  # chain intact, not a keyframe
    shipped = set(meta["tiles"]) | {t for t, _ in meta.get("refs") or []}
    assert shipped == {3, 4, 5}  # row 1 of a 3-wide tile grid
    np.testing.assert_array_equal(dec.decode("s", meta, payload), quantize_rgb8(base))
    assert enc.stats()["tiles_forced"] == 3
    # the mark is consumed: the next identical frame ships nothing
    meta2, payload2 = enc.encode("s", base)
    assert meta2["tiles"] == [] and not meta2.get("refs")
    np.testing.assert_array_equal(dec.decode("s", meta2, payload2), quantize_rgb8(base))
    # an empty row set is a no-op, not a chain cut
    enc.reset("s", rows=[])
    meta3, _ = enc.encode("s", base)
    assert meta3["encoding"] == "tiles8"
    # a non-tiles encoder cannot patch rows: it falls back to the full reset
    enc2 = FrameEncoder()
    enc2.encode("s", base)
    enc2.reset("s", rows=[0])
    meta4, _ = enc2.encode("s", base)
    assert meta4["encoding"] == "rgb8"


# ================================================================== gateway
def _manager(g=None, *, pipeline_depth=2, timeline_steps=2, **kw):
    g = g if g is not None else make_scene(n=256, scale=0.06)
    cfg = GSConfig(img_h=H, img_w=W, k_per_tile=64)
    kw.setdefault("n_levels", 1)
    kw.setdefault("max_batch", 4)
    kw.setdefault("store_frames", False)
    mgr = SessionManager(cfg, pipeline_depth=pipeline_depth, **kw)
    mgr.register_static("static", g)
    if timeline_steps:
        from repro.launch.frontend import synthetic_timeline

        mgr.register_timeline("timeline", synthetic_timeline(g, timeline_steps))
    return mgr


@pytest.fixture(scope="module")
def gateway_thread():
    mgr = _manager()
    mgr.warmup()
    with GatewayThread(Gateway(mgr, port=0, queue_limit=8)) as gt:
        yield gt


def test_gateway_smoke_multi_client_two_streams(gateway_thread):
    """N sync clients render over localhost across both streams: every
    request answered, zero shed, zero protocol errors, and the frames match
    an in-process render of the same pose bit-for-bit (after RGB8)."""
    gt = gateway_thread
    cams = [make_cam(H, W, dist=2.0 + 0.25 * i) for i in range(4)]
    clients = [FrontendClient("127.0.0.1", gt.port) for _ in range(4)]
    try:
        assert all(set(cl.streams) == {"static", "timeline"} for cl in clients)
        frames = {}
        for r in range(2):  # two rounds so delta encoding gets exercised
            for i, cl in enumerate(clients):
                frames[(r, i, "static")] = cl.render("static", cams[i])
                frames[(r, i, "timeline")] = cl.render("timeline", cams[i], timestep=1)
        stats = clients[0].stats()
    finally:
        for cl in clients:
            cl.close()
    gw = stats["gateway"]
    assert gw["frames_sent"] == 16 and gw["shed"] == 0
    assert gw["protocol_errors"] == 0 and gw["request_errors"] == 0
    assert gw["dropped_writes"] == 0
    # round 2 must be byte-identical to round 1 (same pose, cache or not)
    for i in range(4):
        np.testing.assert_array_equal(frames[(0, i, "static")], frames[(1, i, "static")])
    # network frames == in-process serving engine frames for the same pose
    ref = RenderServer(
        make_scene(n=256, scale=0.06), GSConfig(img_h=H, img_w=W, k_per_tile=64),
        n_levels=1, max_batch=4, store_frames=False,
    )
    with ref:
        for i in range(4):
            expect = quantize_rgb8(ref.submit(cams[i]).result())
            np.testing.assert_array_equal(frames[(0, i, "static")], expect)


def test_gateway_scrub_and_bad_requests(gateway_thread):
    gt = gateway_thread
    with FrontendClient("127.0.0.1", gt.port) as cl:
        cam = make_cam(H, W)
        frames = cl.scrub("timeline", cam, [0, 1])
        assert sorted(frames) == [0, 1]
        assert np.abs(frames[0].astype(int) - frames[1].astype(int)).max() > 0
        from repro.frontend import RemoteRenderError

        with pytest.raises(RemoteRenderError, match="no timestep"):
            cl.render("timeline", cam, timestep=99)
        with pytest.raises(RemoteRenderError, match="unknown stream"):
            cl.render("nope", cam)
        stats = cl.stats()
    assert stats["gateway"]["request_errors"] >= 2
    assert stats["gateway"]["protocol_errors"] == 0  # bad requests != protocol


def test_gateway_rejects_garbage_bytes(gateway_thread):
    """A peer that does not speak the protocol gets one error frame and a
    hangup — and the counter records it."""
    gt = gateway_thread
    before = gt.gateway.protocol_errors
    with socket.create_connection(("127.0.0.1", gt.port), timeout=10) as s:
        s.sendall(b"GET / HTTP/1.1\r\n\r\n")
        chunks = b""
        while True:
            b = s.recv(4096)
            if not b:
                break
            chunks += b
    (header, _), = iter_messages(chunks)
    assert header["type"] == "error" and "magic" in header["detail"]
    assert gt.gateway.protocol_errors == before + 1


def test_malformed_timestep_answers_bad_request_not_disconnect(gateway_thread):
    """A non-integer timestep is a bad_request answer, not a dead handler."""
    gt = gateway_thread

    def read_msg(sock):
        buf = b""
        while len(buf) < proto.PREFIX_SIZE:
            buf += sock.recv(proto.PREFIX_SIZE - len(buf))
        hlen, plen = proto.unpack_prefix(buf)
        body = b""
        while len(body) < hlen + plen:
            body += sock.recv(hlen + plen - len(body))
        return next(iter_messages(buf + body))

    cam_wire = proto.camera_to_wire(make_cam(H, W))
    with socket.create_connection(("127.0.0.1", gt.port), timeout=30) as s:
        s.sendall(pack_message({"type": "hello"}))
        assert read_msg(s)[0]["type"] == "hello_ok"
        s.sendall(pack_message({
            "type": "render", "seq": 5, "stream": "static",
            "timestep": "abc", "camera": cam_wire,
        }))
        h, _ = read_msg(s)
        assert h["type"] == "error" and h["code"] == "bad_request" and h["seq"] == 5
        # the connection survives: a well-formed render still serves
        s.sendall(pack_message({
            "type": "render", "seq": 6, "stream": "static",
            "timestep": 0, "camera": cam_wire,
        }))
        h, payload = read_msg(s)
        assert h["type"] == "frame" and h["seq"] == 6 and len(payload) > 0


def test_scrub_longer_than_queue_limit_never_sheds_itself():
    """A full-timeline scrub is one admission unit: its fan-out may exceed
    the per-session queue limit (bounded by the registered timeline) and
    must never shed its own items."""
    mgr = _manager(timeline_steps=6)
    mgr.warmup()
    with GatewayThread(Gateway(mgr, port=0, queue_limit=4)) as gt:
        with FrontendClient("127.0.0.1", gt.port) as cl:
            frames = cl.scrub("timeline", make_cam(H, W), list(range(6)))
            stats = cl.stats()
    assert sorted(frames) == list(range(6))
    assert stats["gateway"]["shed"] == 0 and stats["gateway"]["request_errors"] == 0


def test_interleaved_render_does_not_shed_in_progress_scrub():
    """A plain render arriving while a long scrub is still queued must not
    evict the scrub's items (it stretches the queue by one instead): the
    scrub is one unit of work, only another scrub may displace it."""
    mgr = _manager(timeline_steps=6)
    mgr.warmup()
    gw = Gateway(mgr, port=0, queue_limit=2)
    with GatewayThread(gw) as gt:

        async def run():
            cl = AsyncFrontendClient("127.0.0.1", gt.port)
            await cl.connect()
            gt.call_soon(gw.pause)  # keep everything queued while we interleave
            await asyncio.sleep(0.05)
            scrub_task = asyncio.ensure_future(
                cl.scrub("timeline", make_cam(H, W), list(range(6)))
            )
            await asyncio.sleep(0.1)  # the 6 scrub items are now admitted
            rfut = await cl.submit_render("static", make_cam(H, W))
            gt.call_soon(gw.resume)
            frames = await scrub_task          # would ShedError before the fix
            frame = await rfut                 # the render is served too
            stats = await cl.stats()
            await cl.close()
            return frames, frame, stats

        frames, frame, stats = asyncio.run(run())
    assert sorted(frames) == list(range(6))
    assert frame.shape == (H, W, 3)
    assert stats["gateway"]["shed"] == 0


# ------------------------------------------------------------- backpressure
def test_backpressure_sheds_oldest_with_accounting():
    """With dispatch held, a client firing more requests than its bounded
    queue sheds the OLDEST queued seqs (answered with error/shed), keeps the
    newest, and the shed metric accounts for every drop."""
    mgr = _manager(timeline_steps=0)
    mgr.warmup()
    gw = Gateway(mgr, port=0, queue_limit=2)
    with GatewayThread(gw) as gt:

        async def run():
            cl = AsyncFrontendClient("127.0.0.1", gt.port)
            await cl.connect()
            gt.call_soon(gw.pause)  # hold dispatch; admission keeps running
            await asyncio.sleep(0.05)
            futs = [
                await cl.submit_render("static", make_cam(H, W, dist=2.0 + 0.3 * i))
                for i in range(6)
            ]
            # wait until the 4 shed notices landed, then let the rest render
            for fut in futs[:4]:
                with pytest.raises(ShedError):
                    await fut
            gt.call_soon(gw.resume)
            survivors = [await fut for fut in futs[4:]]
            stats = await cl.stats()
            await cl.close()
            return survivors, stats

        survivors, stats = asyncio.run(run())
    assert len(survivors) == 2 and all(f.shape == (H, W, 3) for f in survivors)
    gwstats = stats["gateway"]
    assert gwstats["shed"] == 4 and gwstats["frames_sent"] == 2
    (sess,) = stats["sessions"].values()
    assert sess["shed"] == 4 and sess["admitted"] == 6
    assert sess["queued_now"] == 0  # queue fully drained after resume
    # shed + served == admitted: nothing dropped silently
    assert sess["shed"] + sess["frames_sent"] == sess["admitted"]


# --------------------------------------------------------- depth invariance
def test_depth1_and_depth2_identical_through_network():
    """The same request trace through a depth-1 (sync dispatch) gateway and
    a depth-2 (pipelined) gateway yields bitwise-identical RGB8 frames."""
    g = make_scene(n=256, scale=0.06)
    cams = [make_cam(H, W, dist=2.0 + 0.2 * i) for i in range(3)]
    results = {}
    for depth in (1, 2):
        mgr = _manager(g, pipeline_depth=depth)
        mgr.warmup()
        with GatewayThread(Gateway(mgr, port=0)) as gt:
            with FrontendClient("127.0.0.1", gt.port) as cl:
                frames = []
                for cam in cams:
                    frames.append(cl.render("static", cam))
                    frames.append(cl.render("timeline", cam, timestep=1))
                frames.append(cl.scrub("timeline", cams[0], [0, 1]))
                stats = cl.stats()
        assert stats["gateway"]["shed"] == 0
        assert stats["gateway"]["protocol_errors"] == 0
        results[depth] = frames
    for a, b in zip(results[1], results[2]):
        if isinstance(a, dict):
            for t in a:
                np.testing.assert_array_equal(a[t], b[t])
        else:
            np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------- tiles over TCP
def _read_msg(sock):
    buf = b""
    while len(buf) < proto.PREFIX_SIZE:
        buf += sock.recv(proto.PREFIX_SIZE - len(buf))
    hlen, plen = proto.unpack_prefix(buf)
    body = b""
    while len(body) < hlen + plen:
        body += sock.recv(hlen + plen - len(body))
    return next(iter_messages(buf + body))


def test_tiles8_negotiated_and_exact_over_real_tcp(gateway_thread):
    """Protocol v2 negotiation end-to-end: a v2 hello gets tiles8 frames, a
    repeated pose ships ZERO tiles, and the decoded frames are bitwise the
    in-process render. A v1 hello on the same gateway falls back to zdelta8."""
    gt = gateway_thread
    cam_wire = proto.camera_to_wire(make_cam(H, W))
    dec = FrameDecoder()
    with socket.create_connection(("127.0.0.1", gt.port), timeout=30) as s:
        s.sendall(pack_message({
            "type": "hello", "protocol": proto.PROTOCOL,
            "encodings": ["rgb8", "zdelta8", "tiles8"],
        }))
        h, _ = _read_msg(s)
        assert h["type"] == "hello_ok" and h["protocol"] == 2
        assert "tiles8" in h["encodings"] and h["tile"] == [16, 16]
        frames = []
        for seq in range(3):
            s.sendall(pack_message({
                "type": "render", "seq": seq, "stream": "static",
                "timestep": 0, "camera": cam_wire,
            }))
            fh, payload = _read_msg(s)
            assert fh["type"] == "frame"
            frames.append((fh, dec.decode("static", fh, payload)))
        s.sendall(pack_message({"type": "bye"}))
    assert frames[0][0]["encoding"] == "rgb8"          # keyframe
    for fh, _ in frames[1:]:
        assert fh["encoding"] == "tiles8"
        assert fh["tiles"] == []                       # same pose: no tiles
    ref = RenderServer(
        make_scene(n=256, scale=0.06), GSConfig(img_h=H, img_w=W, k_per_tile=64),
        n_levels=1, max_batch=4, store_frames=False,
    )
    with ref:
        expect = quantize_rgb8(ref.submit(make_cam(H, W)).result())
    for _, frame in frames:
        np.testing.assert_array_equal(frame, expect)

    # ---- a v1 peer (no protocol field) on the SAME gateway: zdelta8 path
    with socket.create_connection(("127.0.0.1", gt.port), timeout=30) as s:
        s.sendall(pack_message({"type": "hello"}))
        h, _ = _read_msg(s)
        assert h["protocol"] == 1 and h["encodings"] == ["rgb8", "zdelta8"]
        encs = []
        for seq in range(2):
            s.sendall(pack_message({
                "type": "render", "seq": seq, "stream": "static",
                "timestep": 0, "camera": cam_wire,
            }))
            fh, _ = _read_msg(s)
            encs.append(fh["encoding"])
        s.sendall(pack_message({"type": "bye"}))
    assert encs == ["rgb8", "zdelta8"]

    # ---- a raw-only decoder must never be sent an encoding it didn't offer
    with socket.create_connection(("127.0.0.1", gt.port), timeout=30) as s:
        s.sendall(pack_message({
            "type": "hello", "protocol": 2, "encodings": ["rgb8"],
        }))
        h, _ = _read_msg(s)
        assert h["encodings"] == ["rgb8"]
        encs = []
        for seq in range(2):
            s.sendall(pack_message({
                "type": "render", "seq": seq, "stream": "static",
                "timestep": 0, "camera": cam_wire,
            }))
            fh, _ = _read_msg(s)
            encs.append(fh["encoding"])
        s.sendall(pack_message({"type": "bye"}))
    assert encs == ["rgb8", "rgb8"]


def test_invalidation_resets_wire_delta_chain():
    """Satellite: dropping a timestep's cached frames (model hot-swap /
    dirty-row invalidation) must reset the frontend delta chains that
    referenced that stream — the next frame is a fresh keyframe, not a delta
    extending a chain rooted in superseded content."""
    mgr = _manager(timeline_steps=0)
    mgr.warmup()
    gw = Gateway(mgr, port=0)
    with GatewayThread(gw) as gt:
        cam_wire = proto.camera_to_wire(make_cam(H, W))
        with socket.create_connection(("127.0.0.1", gt.port), timeout=30) as s:
            s.sendall(pack_message({
                "type": "hello", "protocol": 2,
                "encodings": ["rgb8", "zdelta8", "tiles8"],
            }))
            _read_msg(s)

            def render(seq):
                s.sendall(pack_message({
                    "type": "render", "seq": seq, "stream": "static",
                    "timestep": 0, "camera": cam_wire,
                }))
                return _read_msg(s)[0]

            assert render(0)["encoding"] == "rgb8"
            assert render(1)["encoding"] == "tiles8"  # chain established
            # invalidate the stream's cached tiles on the engine thread
            gw.run_on_engine(mgr.invalidate, "static", 0).result(timeout=60)
            assert render(2)["encoding"] == "rgb8"    # chain was reset
            assert render(3)["encoding"] == "tiles8"  # and re-establishes
            s.sendall(pack_message({"type": "bye"}))
    assert gw.delta_resets >= 1


def test_row_invalidation_partial_resets_wire_chain():
    """Tentpole wire behavior: a row-granular invalidation re-keys ONLY the
    dirty rows' tiles on the wire — the tiles8 chain is never cut, the
    decoded frame stays bit-exact, and the gateway counts a partial (not
    full) reset."""
    mgr = _manager(timeline_steps=0)
    mgr.warmup()
    gw = Gateway(mgr, port=0)
    with GatewayThread(gw) as gt:
        cam_wire = proto.camera_to_wire(make_cam(H, W))
        dec = FrameDecoder()
        with socket.create_connection(("127.0.0.1", gt.port), timeout=30) as s:
            s.sendall(pack_message({
                "type": "hello", "protocol": 2,
                "encodings": ["rgb8", "zdelta8", "tiles8"],
            }))
            _read_msg(s)

            def render(seq):
                s.sendall(pack_message({
                    "type": "render", "seq": seq, "stream": "static",
                    "timestep": 0, "camera": cam_wire,
                }))
                fh, payload = _read_msg(s)
                return fh, dec.decode("static", fh, payload)

            render(0)                       # rgb8 keyframe
            fh1, f1 = render(1)             # tiles8, chain established
            assert fh1["encoding"] == "tiles8" and fh1["tiles"] == []
            gw.run_on_engine(
                lambda: mgr.invalidate("static", 0, rows=[0])
            ).result(timeout=60)
            fh2, f2 = render(2)
            # the chain survived — no keyframe — but row 0's tiles were
            # re-keyed (shipped or store-reffed) despite identical pixels
            assert fh2["encoding"] == "tiles8"
            rekeyed = set(fh2["tiles"]) | {t for t, _ in fh2.get("refs") or []}
            assert rekeyed == set(range(W // 16))  # exactly tile row 0
            np.testing.assert_array_equal(f2, f1)  # model unchanged: bit-exact
            s.sendall(pack_message({"type": "bye"}))
    assert gw.partial_resets >= 1 and gw.delta_resets == 0


def test_render_hints_ride_the_wire_and_validate(gateway_thread):
    """gaze/budget_ms are optional header fields: valid hints serve normally
    (this pool's single-level pyramid collapses them to the uniform path),
    malformed ones answer bad_request without killing the connection."""
    gt = gateway_thread
    cam = make_cam(H, W)
    with FrontendClient("127.0.0.1", gt.port) as cl:
        a = cl.render("static", cam)
        b = cl.render("static", cam, gaze=(0.5, 0.5), budget_ms=50.0)
        np.testing.assert_array_equal(a, b)
    cam_wire = proto.camera_to_wire(cam)
    with socket.create_connection(("127.0.0.1", gt.port), timeout=30) as s:
        s.sendall(pack_message({"type": "hello", "protocol": 2}))
        _read_msg(s)
        for bad in ({"budget_ms": -5}, {"gaze": "abc"}, {"gaze": [0.5]}):
            s.sendall(pack_message({
                "type": "render", "seq": 9, "stream": "static",
                "timestep": 0, "camera": cam_wire, **bad,
            }))
            h, _ = _read_msg(s)
            assert h["type"] == "error" and h["code"] == "bad_request", (bad, h)
        # the connection survives: a well-formed hinted render still serves
        s.sendall(pack_message({
            "type": "render", "seq": 10, "stream": "static", "timestep": 0,
            "camera": cam_wire, "gaze": [0.2, 0.8], "budget_ms": 100.0,
        }))
        h, payload = _read_msg(s)
        assert h["type"] == "frame" and len(payload) > 0
        s.sendall(pack_message({"type": "bye"}))


# ------------------------------------------------------------ session layer
def test_session_manager_stream_isolation_and_resolve():
    mgr = _manager(timeline_steps=3)
    assert mgr.resolve("static", 0) == 0
    base = mgr.streams["timeline"].base
    assert base > 0 and mgr.resolve("timeline", 2) == base + 2
    with pytest.raises(KeyError, match="unknown stream"):
        mgr.resolve("missing", 0)
    with pytest.raises(KeyError, match="no timestep"):
        mgr.resolve("static", 1)
    with pytest.raises(ValueError, match="already registered"):
        mgr.register_static("static", make_scene(n=64))
    # the shared pool really holds every stream's timeline entries
    assert len(mgr.server.timesteps()) == 4
    mgr.close()
    assert mgr.server.closed
