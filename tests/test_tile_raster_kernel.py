"""Pallas tile rasterizer vs pure-jnp oracle: forward + gradients, across a
shape sweep (per-kernel allclose requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import projection as P
from repro.core import render as R
from repro.core.losses import gs_loss
from repro.kernels.tile_raster.ref import rasterize_naive

from conftest import make_cam, make_scene

SWEEP = [
    # (n_gauss, H, W, tile_h, tile_w, K)
    (64, 32, 32, 16, 16, 64),
    (200, 64, 64, 16, 16, 128),
    (200, 48, 96, 16, 32, 256),
    (500, 64, 64, 8, 16, 512),
    (37, 32, 32, 16, 16, 64),   # K > N
]


def _render(g, cam, h, w, th, tw, k, backend):
    return R.render(g, cam, img_h=h, img_w=w, tile_h=th, tile_w=tw, k_per_tile=k, backend=backend)


@pytest.mark.parametrize("n,h,w,th,tw,k", SWEEP)
def test_forward_allclose(n, h, w, th, tw, k):
    g = make_scene(n, seed=n)
    cam = make_cam(h, w)
    img_ref, t_ref = _render(g, cam, h, w, th, tw, k, "ref")
    img_pal, t_pal = _render(g, cam, h, w, th, tw, k, "pallas")
    np.testing.assert_allclose(np.asarray(img_pal), np.asarray(img_ref), atol=3e-6, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(t_pal), np.asarray(t_ref), atol=3e-6, rtol=1e-5)
    assert np.all(np.isfinite(np.asarray(img_pal)))


@pytest.mark.parametrize("n,h,w,th,tw,k", SWEEP[:3])
def test_grad_allclose(n, h, w, th, tw, k):
    g = make_scene(n, seed=n + 1)
    cam = make_cam(h, w)
    target = jnp.clip(_render(g, cam, h, w, th, tw, k, "ref")[0] + 0.05, 0, 1)

    def loss(gm, backend):
        img, _ = _render(gm, cam, h, w, th, tw, k, backend)
        return gs_loss(img, target)

    gr = jax.grad(lambda gm: loss(gm, "ref"))(g)
    gp = jax.grad(lambda gm: loss(gm, "pallas"))(g)
    for name, a, b in zip(g._fields, gr, gp):
        a, b = np.asarray(a), np.asarray(b)
        scale = max(np.abs(a).max(), 1e-8)
        np.testing.assert_allclose(b, a, atol=2e-5 * scale + 1e-10, rtol=2e-4, err_msg=name)


def test_tiled_matches_naive_with_full_capacity():
    """With K >= N the tiled render must equal the all-splats-per-pixel oracle."""
    n, h, w = 150, 64, 64
    g = make_scene(n, seed=7)
    cam = make_cam(h, w)
    img_t, t_t = _render(g, cam, h, w, 16, 16, 256, "ref")
    packed = P.project(g, cam)
    packed_s, _ = P.sort_by_depth(packed)
    img_n, t_n = rasterize_naive(packed_s, h, w, jnp.zeros(3))
    np.testing.assert_allclose(np.asarray(img_t), np.asarray(img_n), atol=1e-6)


def test_fp32_inputs_dtype_stability():
    g = make_scene(64, seed=3)
    cam = make_cam(32, 32)
    img, t = _render(g, cam, 32, 32, 16, 16, 64, "pallas")
    assert img.dtype == jnp.float32 and t.dtype == jnp.float32


def test_background_blend():
    """Empty scene renders pure background through both backends."""
    g = make_scene(4, seed=9)
    g = g._replace(opacity_logit=jnp.full((4,), -20.0))
    cam = make_cam(32, 32)
    bg = jnp.asarray([0.2, 0.4, 0.6])
    for backend in ("ref", "pallas"):
        img, t = R.render(g, cam, img_h=32, img_w=32, tile_h=16, tile_w=16,
                          k_per_tile=64, bg=bg, backend=backend)
        np.testing.assert_allclose(np.asarray(img), np.broadcast_to(bg, (32, 32, 3)), atol=1e-6)
        np.testing.assert_allclose(np.asarray(t), 1.0, atol=1e-6)
