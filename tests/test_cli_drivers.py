"""End-to-end CLI driver tests (subprocess): train -> checkpoint -> render."""
import os
import subprocess
import sys

import pytest


def _run(args, timeout=900):
    r = subprocess.run(
        [sys.executable] + args, capture_output=True, text=True, timeout=timeout,
        env=dict(os.environ, PYTHONPATH="src"),
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-2500:])
    return r.stdout


@pytest.mark.slow
def test_train_then_render_novel_views(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    out = _run([
        "-m", "repro.launch.train", "--dataset", "kingsnake", "--volume-res", "32",
        "--max-points", "800", "--res", "32", "--steps", "8", "--views", "4",
        "--batch", "2", "--ckpt", ckpt,
    ])
    assert "final-loss" in out and "checkpoint:" in out
    renders = str(tmp_path / "renders")
    out2 = _run([
        "examples/render_novel_views.py", "--ckpt", ckpt, "--res", "32",
        "--views", "2", "--out", renders,
    ])
    files = os.listdir(renders)
    assert len(files) == 2 and all(f.endswith(".ppm") for f in files)
    # PPM header sanity
    with open(os.path.join(renders, sorted(files)[0]), "rb") as f:
        assert f.read(2) == b"P6"


@pytest.mark.slow
def test_serve_driver_smoke():
    out = _run([
        "-m", "repro.launch.serve", "--arch", "xlstm-350m", "--smoke",
        "--batch", "2", "--prompt-len", "4", "--gen", "4",
    ])
    assert "decode" in out and "generated ids" in out
