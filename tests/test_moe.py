"""MoE dispatch correctness: the sort-based capacity dispatch must equal a
dense (loop-over-experts) reference when capacity is not exceeded."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_arch
from repro.models.moe import moe_apply, moe_init


def _cfg(n_experts=4, top_k=2, cap=8.0):
    base = get_arch("granite_moe_3b_a800m").smoke_config()
    return dataclasses.replace(base, n_experts=n_experts, top_k=top_k, capacity_factor=cap)


def _dense_reference(p, cfg, x):
    """O(T*E) reference: every token through every selected expert, no drops."""
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    vals, idx = jax.lax.top_k(probs, cfg.top_k)
    vals = vals / vals.sum(-1, keepdims=True)
    out = jnp.zeros_like(xt)
    for e in range(cfg.n_experts):
        h = jax.nn.silu(xt @ p["wg"][e]) * (xt @ p["wi"][e])
        y = h @ p["wo"][e]
        for k in range(cfg.top_k):
            w = jnp.where(idx[:, k] == e, vals[:, k], 0.0)
            out = out + w[:, None] * y
    if cfg.n_shared_experts:
        from repro.models.common import mlp
        out = out + mlp(p["shared"], x).reshape(-1, d)
    return out.reshape(b, s, d)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000))
def test_dispatch_matches_dense_reference(seed):
    cfg = _cfg(cap=8.0)  # capacity large enough that nothing drops
    key = jax.random.key(seed)
    p = moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 16, cfg.d_model), jnp.float32)
    out, aux = moe_apply(p, cfg, x)
    ref = _dense_reference(p, cfg, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-4)
    assert float(aux["drop_frac"]) == 0.0


def test_capacity_drops_are_bounded_and_reported():
    cfg = _cfg(cap=0.5)  # force drops
    key = jax.random.key(0)
    p = moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 32, cfg.d_model), jnp.float32)
    out, aux = moe_apply(p, cfg, x)
    assert 0.0 < float(aux["drop_frac"]) < 1.0
    assert bool(jnp.isfinite(out).all())


def test_load_balance_loss_sane():
    cfg = _cfg()
    key = jax.random.key(1)
    p = moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 64, cfg.d_model), jnp.float32)
    _, aux = moe_apply(p, cfg, x)
    # Switch LB loss is ~E * sum(me*ce) with minimum ~top_k at uniform routing
    assert 0.5 * cfg.top_k < float(aux["lb_loss"]) < 4.0 * cfg.top_k


def test_moe_grads_flow_to_experts_and_router():
    cfg = _cfg()
    key = jax.random.key(2)
    p = moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(key, (1, 16, cfg.d_model), jnp.float32)

    def loss(p):
        out, _ = moe_apply(p, cfg, x)
        return jnp.sum(out**2)

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["router"]).max()) > 0
    assert float(jnp.abs(g["wi"]).max()) > 0
    assert float(jnp.abs(g["wo"]).max()) > 0
