"""Spherical-harmonic color path: degrees 0-3 eval + view-dependent training."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gaussians as G
from repro.core.config import GSConfig
from repro.core.train import init_state, make_train_step, state_shardings
from repro.core import projection as P
from repro.core import render as R


def test_eval_sh_degree_nesting():
    """Zeroing the higher bands must reduce deg-k eval to deg-0 exactly."""
    n = 32
    r = np.random.default_rng(0)
    dirs = r.normal(size=(n, 3)).astype(np.float32)
    dirs /= np.linalg.norm(dirs, axis=1, keepdims=True)
    for k in (4, 9, 16):
        sh = np.zeros((n, k, 3), np.float32)
        sh[:, 0] = r.normal(size=(n, 3))
        c_k = np.asarray(G.eval_sh(jnp.asarray(sh), jnp.asarray(dirs)))
        c_0 = np.asarray(G.eval_sh(jnp.asarray(sh[:, :1]), jnp.asarray(dirs)))
        np.testing.assert_allclose(c_k, c_0, atol=1e-6)


def test_eval_sh_view_dependence():
    sh = jnp.zeros((1, 4, 3)).at[0, 2, 0].set(1.0)  # z-linear band, red channel
    up = jnp.asarray([[0.0, 0.0, 1.0]])
    dn = jnp.asarray([[0.0, 0.0, -1.0]])
    c_up = float(G.eval_sh(sh, up)[0, 0])
    c_dn = float(G.eval_sh(sh, dn)[0, 0])
    assert c_up > c_dn  # direction flips the linear band


def test_training_with_sh2_improves_view_dependent_target():
    """A scene whose GT color varies with view angle trains better with
    sh_degree=2 than the render pipeline would with frozen DC colors."""
    n = 256
    r = np.random.default_rng(1)
    pts = r.normal(0, 0.3, (n, 3)).astype(np.float32)
    g = G.init_from_points(jnp.asarray(pts), sh_degree=2, init_scale=0.06)
    assert g.sh.shape == (n, 9, 3)

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = GSConfig(img_h=32, img_w=32, k_per_tile=128, batch_size=2, sh_degree=2)
    # two opposing cameras with different target tints = view-dependent GT
    cams = P.Camera(
        *[jnp.stack(x) for x in zip(
            *[P.look_at_camera(e, [0, 0, 0], [0, 1, 0], 40.0, 40.0, 16.0, 16.0)
              for e in ([0, 0, -3.0], [0, 0, 3.0])]
        )]
    )
    gt = jnp.stack([
        jnp.full((32, 32, 3), 0.8).at[..., 2].set(0.1),   # reddish from front
        jnp.full((32, 32, 3), 0.2).at[..., 2].set(0.9),   # bluish from behind
    ])
    state = jax.device_put(init_state(g), state_shardings(mesh))
    step = make_train_step(mesh, cfg)
    losses = []
    for _ in range(40):
        state, m = step(state, cams, gt)
        losses.append(float(m["loss"]))
    # view-dependent fit makes steady progress (loss floor is high: splats
    # cannot cover the whole flat-color screen) and engages higher SH bands
    assert losses[-1] < 0.85 * losses[0]
    assert float(jnp.abs(state.params.sh[:, 1:]).max()) > 1e-3