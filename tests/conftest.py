"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real single CPU device; only the dry-run forces 512 host devices
(inside its own process)."""
import jax
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import gaussians as G
from repro.core import projection as P


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _tsan_guard():
    """Under REPRO_TSAN=1, fail any test whose threads raced on an
    instrumented object (gateway / session manager / checkpoint store).
    Inert otherwise — attach() is a no-op without the env flag."""
    from repro.analysis import tsan

    tsan.reset()
    yield
    if tsan.enabled():
        races = tsan.take_races()
        assert not races, "tsan: " + "; ".join(str(r) for r in races)


def make_scene(n=200, seed=0, spread=0.5, scale=0.05):
    r = np.random.default_rng(seed)
    pts = r.normal(0, spread, (n, 3)).astype(np.float32)
    cols = r.uniform(0.1, 0.9, (n, 3)).astype(np.float32)
    g = G.init_from_points(jnp.asarray(pts), jnp.asarray(cols), init_scale=scale)
    # randomize shape a bit so quats/scales have gradients
    g = g._replace(
        log_scales=g.log_scales + jnp.asarray(r.normal(0, 0.3, (n, 3)), jnp.float32),
        quats=jnp.asarray(r.normal(0, 1, (n, 4)), jnp.float32),
        opacity_logit=jnp.asarray(r.normal(0.5, 0.5, (n,)), jnp.float32),
    )
    return g


def make_cam(h, w, dist=3.0, fov_px=None):
    f = fov_px or (w * 1.2)
    return P.look_at_camera([0, 0, -dist], [0, 0, 0], [0, 1, 0], f, f, w / 2, h / 2)
