"""Serving subsystem tests: LOD pyramid, micro-batcher, frame cache, and the
checkpoint -> server path."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.core import gaussians as G
from repro.core import render as R
from repro.core.config import GSConfig
from repro.core.losses import psnr
from repro.core.train import init_state, make_batched_eval_render, make_eval_render
from repro.launch.serve_gs import load_params_from_ckpt
from repro.serve_gs import (
    FrameCache,
    MicroBatcher,
    RenderRequest,
    RenderServer,
    build_lod_pyramid,
    frame_key,
    select_level,
    stack_cameras,
)
from repro.volume.cameras import camera_slice, orbit_cameras

from conftest import make_cam, make_scene

H = W = 32


def _render_model(g, cam):
    img, _ = R.render(g, cam, img_h=H, img_w=W, k_per_tile=128)
    return img


# --------------------------------------------------------------------- LOD
def test_lod_pyramid_monotone_and_close_to_full():
    g = make_scene(n=400, scale=0.08)
    pyr = build_lod_pyramid(g, n_levels=3, keep_ratio=0.5, pad_quantum=64)
    # each level has strictly fewer live Gaussians, padded to the quantum
    assert list(pyr.live_counts) == sorted(pyr.live_counts, reverse=True)
    for a, b in zip(pyr.live_counts, pyr.live_counts[1:]):
        assert b < a
    for lvl in pyr.levels[1:]:
        assert lvl.n % 64 == 0
    # level 0 is the model verbatim
    np.testing.assert_array_equal(np.asarray(pyr.levels[0].means), np.asarray(g.means))

    cam = make_cam(H, W)
    full = _render_model(g, cam)
    for k, lvl in enumerate(pyr.levels[1:], start=1):
        img = _render_model(G.GaussianModel(*[jnp.asarray(x) for x in lvl]), cam)
        p = float(psnr(img, full))
        assert np.isfinite(np.asarray(img)).all()
        # importance pruning keeps the dominant splats: each halving of the
        # Gaussian count may cost fidelity, but a 2x/4x-pruned toy scene must
        # stay recognizably the same image (bound loosens with depth)
        assert p > 20.0 - 3.0 * k, (k, p)


def test_lod_level_selection_by_distance():
    g = make_scene(n=300)
    pyr = build_lod_pyramid(g, n_levels=3, keep_ratio=0.5, pad_quantum=64)
    near = make_cam(H, W, dist=2.0)
    far = make_cam(H, W, dist=40.0)
    l_near = select_level(pyr, near, img_w=W)
    l_far = select_level(pyr, far, img_w=W)
    assert 0 <= l_near <= l_far <= pyr.n_levels - 1
    assert l_far > l_near


# ----------------------------------------------------------------- batcher
def _req(cam, level):
    return RenderRequest(cam=cam, level=level)


def test_batcher_coalesces_by_level_and_pads_to_bucket():
    cams = orbit_cameras(8, img_h=H, img_w=W)
    b = MicroBatcher(max_batch=4)
    ids0 = [b.submit(_req(camera_slice(cams, i), 0)) for i in range(3)]
    ids1 = [b.submit(_req(camera_slice(cams, i + 3), 1)) for i in range(2)]
    assert b.pending == 5

    mb = b.next_batch()  # level 0 submitted first -> drains first
    assert mb.level == 0
    assert [r.request_id for r in mb.requests] == ids0
    assert mb.bucket == 4  # 3 requests pad to the next bucket
    assert np.asarray(mb.cams.viewmat).shape == (4, 4, 4)
    # padding repeats the last real camera
    np.testing.assert_array_equal(
        np.asarray(mb.cams.viewmat)[3], np.asarray(mb.cams.viewmat)[2]
    )

    mb1 = b.next_batch()
    assert mb1.level == 1 and [r.request_id for r in mb1.requests] == ids1
    assert mb1.bucket == 2
    assert b.next_batch() is None and b.pending == 0


def test_batcher_respects_max_batch_and_fifo():
    cams = orbit_cameras(10, img_h=H, img_w=W)
    b = MicroBatcher(max_batch=4)
    for i in range(6):
        b.submit(_req(camera_slice(cams, i), 0))
    first = b.next_batch()
    assert len(first.requests) == 4 and first.bucket == 4
    second = b.next_batch()
    assert len(second.requests) == 2
    got = [r.request_id for r in first.requests + second.requests]
    assert got == sorted(got)  # FIFO order preserved


# ------------------------------------------------------------------- cache
def test_cache_key_quantization():
    cam = make_cam(H, W, dist=3.0)
    q = 1e-3
    k0 = frame_key(cam, 0, height=H, width=W, pose_quantum=q)
    # sub-quantum pose jitter shares the key
    jig = cam._replace(viewmat=cam.viewmat + 1e-5)
    assert frame_key(jig, 0, height=H, width=W, pose_quantum=q) == k0
    # super-quantum motion, another level, or other intrinsics do not
    moved = cam._replace(viewmat=cam.viewmat.at[2, 3].add(5 * q))
    assert frame_key(moved, 0, height=H, width=W, pose_quantum=q) != k0
    assert frame_key(cam, 1, height=H, width=W, pose_quantum=q) != k0
    zoomed = cam._replace(fx=cam.fx * 2)
    assert frame_key(zoomed, 0, height=H, width=W, pose_quantum=q) != k0
    # regression: the same quantized pose at another OUTPUT RESOLUTION must
    # not share a key — a hit would hand back a wrong-size frame
    assert frame_key(cam, 0, height=2 * H, width=2 * W, pose_quantum=q) != k0


def test_cache_lru_eviction_and_stats():
    c = FrameCache(capacity=2)
    f = np.zeros((2, 2, 3), np.float32)
    assert c.get(("a",)) is None  # miss
    c.put(("a",), f)
    c.put(("b",), f)
    assert c.get(("a",)) is not None  # hit; "a" becomes most-recent
    c.put(("c",), f)  # evicts "b" (least recent)
    assert c.get(("b",)) is None
    assert c.get(("c",)) is not None
    s = c.stats()
    assert s["hits"] == 2 and s["misses"] == 2 and s["evictions"] == 1
    assert s["hit_rate"] == 0.5 and len(c) == 2


# ------------------------------------------------- batched render + server
def test_batched_eval_render_matches_single():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = GSConfig(img_h=H, img_w=W, k_per_tile=128)
    g = make_scene(n=256, scale=0.06)
    cams = orbit_cameras(3, img_h=H, img_w=W)
    single = make_eval_render(mesh, cfg)
    for mode in ("map", "vmap"):
        batched = make_batched_eval_render(mesh, cfg, batch_mode=mode)
        imgs = batched(g, stack_cameras([camera_slice(cams, i) for i in range(3)]))
        for i in range(3):
            ref, _ = single(g, camera_slice(cams, i))
            np.testing.assert_allclose(np.asarray(imgs[i]), np.asarray(ref), atol=1e-5)


def test_server_serves_and_caches(tmp_path):
    g = make_scene(n=256, scale=0.06)
    cfg = GSConfig(img_h=H, img_w=W, k_per_tile=64)
    server = RenderServer(g, cfg, n_levels=2, max_batch=4, cache_capacity=64)
    cams = orbit_cameras(4, img_h=H, img_w=W)
    futs = [server.submit(camera_slice(cams, i)) for i in range(4)]
    assert server.run() == 4
    assert all(f.done() for f in futs)
    # resubmitting the same poses is served from cache without new renders
    calls_before = server.report()["render"]["calls"]
    futs2 = [server.submit(camera_slice(cams, i)) for i in range(4)]
    assert all(f.done() for f in futs2)  # cache hits resolve at submit
    server.run()
    rep = server.report()
    assert rep["render"]["calls"] == calls_before
    assert rep["cache"]["hits"] == 4 and rep["completed"] == 8
    for fut in futs + futs2:
        frame = fut.result()
        assert frame.shape == (H, W, 3) and np.isfinite(frame).all()
        # the retirement buffer also holds recently served frames by id
        np.testing.assert_array_equal(server.frames[fut.request_id], frame)
    # identical pose -> identical cached frame
    np.testing.assert_array_equal(futs[0].result(), futs2[0].result())


def test_checkpoint_roundtrip_feeds_server(tmp_path):
    g = make_scene(n=200, scale=0.06)
    state = init_state(g)
    save_checkpoint(str(tmp_path), 3, state)
    params = load_params_from_ckpt(str(tmp_path))
    for a, b in zip(params, state.params):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    server = RenderServer(params, GSConfig(img_h=H, img_w=W, k_per_tile=64), n_levels=2, max_batch=2)
    fut = server.submit(make_cam(H, W))
    frame = fut.result()  # awaiting the future drives the pipeline itself
    assert frame.shape == (H, W, 3)
    assert np.isfinite(frame).all()
