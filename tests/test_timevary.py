"""Time-varying volume generators + stream sources.

The warm-start trainer assumes adjacent timesteps are small perturbations:
these tests pin down determinism, temporal continuity (field delta shrinks
with dt), and that both stream sources (in-situ callback, post-hoc disk)
deliver identical timesteps.
"""
import numpy as np
import pytest

from repro.volume.datasets import VolumeSpec
from repro.volume.timevary import (
    CallbackStream,
    DiskStream,
    GENERATORS,
    VolumeStream,
    dump_stream,
    kingsnake_uncoil,
    miranda_growth,
    synthetic_stream,
)

RES = 20


@pytest.mark.parametrize("gen", [kingsnake_uncoil, miranda_growth])
def test_generator_deterministic_and_well_formed(gen):
    a = gen(0.3, res=RES)
    b = gen(0.3, res=RES)
    assert isinstance(a, VolumeSpec)
    assert a.field.shape == (RES, RES, RES) and a.field.dtype == np.float32
    np.testing.assert_array_equal(a.field, b.field)
    assert a.name == b.name
    # the isosurface exists: the field changes sign somewhere
    assert (a.field.min() < a.isovalue) and (a.field.max() > a.isovalue)


@pytest.mark.parametrize("gen", [kingsnake_uncoil, miranda_growth])
def test_field_continuity_between_adjacent_timesteps(gen):
    f0 = gen(0.2, res=RES).field
    d_small = np.abs(gen(0.2 + 0.05, res=RES).field - f0).mean()
    d_large = np.abs(gen(0.2 + 0.4, res=RES).field - f0).mean()
    span = f0.max() - f0.min()
    # a small dt moves the field a little; a large dt moves it more
    assert 0.0 < d_small < 0.05 * span, (d_small, span)
    assert d_small < d_large


@pytest.mark.parametrize("gen", [kingsnake_uncoil, miranda_growth])
def test_timesteps_are_distinct_and_named(gen):
    a, b = gen(0.1, res=RES), gen(0.4, res=RES)
    assert np.abs(a.field - b.field).max() > 0
    assert a.name != b.name  # distinct GT-cache keys per timestep


def test_callback_stream_protocol_and_order():
    stream = synthetic_stream("miranda", 4, res=RES, t0=0.0, t1=0.3)
    assert isinstance(stream, CallbackStream) and isinstance(stream, VolumeStream)
    assert len(stream) == 4
    vols = list(stream)
    assert [v.name for v in vols] == [f"miranda_growth_t{t:.3f}" for t in np.linspace(0, 0.3, 4)]
    # the stream can be consumed again (it is a source, not an iterator)
    assert [v.name for v in stream] == [v.name for v in vols]


def test_disk_stream_roundtrips_callback_stream(tmp_path):
    stream = synthetic_stream("kingsnake", 3, res=RES, t1=0.2)
    paths = dump_stream(stream, str(tmp_path))
    assert len(paths) == 3
    disk = DiskStream(str(tmp_path))
    assert isinstance(disk, VolumeStream)
    assert disk.name == "kingsnake" and len(disk) == 3
    for mem, post in zip(stream, disk):
        np.testing.assert_allclose(mem.field, post.field, atol=0)
        assert (mem.isovalue, mem.extent, mem.name) == (post.isovalue, post.extent, post.name)


def test_generator_registry():
    assert set(GENERATORS) == {"kingsnake", "miranda"}
