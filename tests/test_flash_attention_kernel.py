"""Flash-attention Pallas kernel vs the chunked-scan oracle (shape sweep,
GQA, causal/window variants, grads)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention

CASES = [
    # (B, S, Skv, H, Hkv, hd, causal, window)
    (2, 128, 128, 4, 4, 64, True, None),
    (1, 256, 256, 4, 2, 32, True, None),
    (2, 128, 128, 2, 2, 64, True, 32),
    (1, 64, 128, 2, 2, 32, True, None),   # q shorter than kv (q_offset)
    (1, 128, 128, 4, 1, 64, False, None), # bidirectional, MQA
    (1, 100, 100, 2, 2, 64, True, None),  # non-BQ-multiple S
]


def _mk(b, s, skv, h, hkv, hd, seed=0):
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, skv, hkv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, skv, hkv, hd), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("b,s,skv,h,hkv,hd,causal,window", CASES)
def test_forward_allclose(b, s, skv, h, hkv, hd, causal, window):
    q, k, v = _mk(b, s, skv, h, hkv, hd, seed=s)
    off = skv - s
    ref = flash_attention(q, k, v, causal=causal, window=window, q_offset=off, backend="ref")
    pal = flash_attention(q, k, v, causal=causal, window=window, q_offset=off, backend="pallas")
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref), atol=2e-5, rtol=2e-4)


def test_grads_match_oracle():
    q, k, v = _mk(1, 128, 128, 2, 2, 32, seed=7)

    def loss(q, k, v, backend):
        o = flash_attention(q, k, v, backend=backend)
        return jnp.sum(jnp.tanh(o))

    gr = jax.grad(loss, argnums=(0, 1, 2))(q, k, v, "ref")
    gp = jax.grad(loss, argnums=(0, 1, 2))(q, k, v, "pallas")
    for a, b_ in zip(gr, gp):
        np.testing.assert_allclose(np.asarray(b_), np.asarray(a), atol=2e-5, rtol=2e-4)


def test_long_skv_falls_back():
    q, k, v = _mk(1, 64, 9000, 1, 1, 32, seed=3)
    out = flash_attention(q, k, v, q_offset=9000 - 64)
    assert out.shape == (1, 64, 1, 32)
    assert bool(jnp.isfinite(out).all())
