"""SSM/xLSTM internals: chunked-parallel train forms must equal the
step-by-step recurrent decode forms (the core correctness invariant)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import ssm as SSM
from repro.models import xlstm as XL


def test_mamba2_chunked_equals_recurrent():
    cfg = dataclasses.replace(get_arch("zamba2_7b").smoke_config(), d_model=64, ssm_heads=4, ssm_state=8)
    key = jax.random.key(0)
    p = SSM.mamba2_init(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 96, 64), jnp.float32) * 0.5  # not chunk-aligned

    y_par = SSM.mamba2_train(p, cfg, x)

    cache = SSM.mamba2_cache_init(cfg, 2, jnp.float32)
    ys = []
    for t in range(96):
        y, cache = SSM.mamba2_decode(p, cfg, x[:, t : t + 1], cache)
        ys.append(y)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq), atol=2e-4, rtol=2e-3)


def test_mlstm_chunked_equals_recurrent():
    cfg = dataclasses.replace(get_arch("xlstm_350m").smoke_config(), d_model=64, n_heads=2, n_kv_heads=2)
    key = jax.random.key(1)
    p = XL.mlstm_init(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 80, 64), jnp.float32) * 0.5

    y_par = XL.mlstm_train(p, cfg, x)

    cache = XL.mlstm_cache_init(cfg, 2)
    ys = []
    for t in range(80):
        y, cache = XL.mlstm_decode(p, cfg, x[:, t : t + 1], cache)
        ys.append(y)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq), atol=2e-4, rtol=2e-3)


def test_slstm_train_equals_decode():
    cfg = dataclasses.replace(get_arch("xlstm_350m").smoke_config(), d_model=64, n_heads=2, n_kv_heads=2)
    key = jax.random.key(2)
    p = XL.slstm_init(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 24, 64), jnp.float32) * 0.5
    y_par = XL.slstm_train(p, cfg, x)
    cache = XL.slstm_cache_init(cfg, 2)
    ys = []
    for t in range(24):
        y, cache = XL.slstm_decode(p, cfg, x[:, t : t + 1], cache)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(jnp.concatenate(ys, 1)), atol=2e-4, rtol=2e-3)


def test_mamba2_state_decays():
    """Forget-gate property: with large negative dt bias the state barely
    integrates; with large positive it does."""
    cfg = dataclasses.replace(get_arch("zamba2_7b").smoke_config(), d_model=32, ssm_heads=2, ssm_state=4)
    key = jax.random.key(3)
    p = SSM.mamba2_init(key, cfg, jnp.float32)
    x = jax.random.normal(key, (1, 1, 32), jnp.float32)
    cache = SSM.mamba2_cache_init(cfg, 1, jnp.float32)
    p_lo = dict(p, dt_bias=jnp.full_like(p["dt_bias"], -12.0))
    p_hi = dict(p, dt_bias=jnp.full_like(p["dt_bias"], +4.0))
    _, c_lo = SSM.mamba2_decode(p_lo, cfg, x, cache)
    _, c_hi = SSM.mamba2_decode(p_hi, cfg, x, cache)
    assert float(jnp.abs(c_lo["state"]).max()) < float(jnp.abs(c_hi["state"]).max())


def test_chunked_attention_matches_exact():
    from repro.models.common import chunked_attention
    key = jax.random.key(4)
    b, s, h, hd = 2, 64, 4, 16
    q = jax.random.normal(key, (b, s, h, hd), jnp.float32)
    k = jax.random.normal(jax.random.key(5), (b, s, 2, hd), jnp.float32)
    v = jax.random.normal(jax.random.key(6), (b, s, 2, hd), jnp.float32)
    out_chunked = chunked_attention(q, k, v, causal=True, chunk=16)
    # exact reference
    kf = jnp.repeat(k, 2, axis=2)
    vf = jnp.repeat(v, 2, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kf) / np.sqrt(hd)
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None, None], scores, -1e30)
    ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(scores, -1), vf)
    np.testing.assert_allclose(np.asarray(out_chunked), np.asarray(ref), atol=2e-5, rtol=2e-4)


def test_chunked_attention_sliding_window():
    from repro.models.common import chunked_attention
    key = jax.random.key(7)
    b, s, h, hd = 1, 32, 2, 8
    q = jax.random.normal(key, (b, s, h, hd), jnp.float32)
    k = jax.random.normal(jax.random.key(8), (b, s, h, hd), jnp.float32)
    v = jax.random.normal(jax.random.key(9), (b, s, h, hd), jnp.float32)
    out = chunked_attention(q, k, v, causal=True, window=4, chunk=8)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = (kpos <= qpos) & (qpos - kpos < 4)
    scores = jnp.where(mask[None, None], scores, -1e30)
    ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(scores, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-4)
