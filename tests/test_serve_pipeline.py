"""Pipeline-discipline tests for the async serving engine: recompile budget,
in-flight dedup, depth-invariance, future delivery, frame immutability, and
the bounded retirement buffer."""
import numpy as np
import pytest

from repro.core.config import GSConfig
from repro.insitu import TemporalCheckpointStore, build_timeline_server, scrub
from repro.serve_gs import RenderServer, make_clients, run_load

from conftest import make_cam, make_scene

H = W = 32


def _server(g=None, **kw):
    g = g if g is not None else make_scene(n=256, scale=0.06)
    cfg = GSConfig(img_h=H, img_w=W, k_per_tile=64)
    kw.setdefault("n_levels", 1)
    kw.setdefault("max_batch", 4)
    return RenderServer(g, cfg, **kw)


# ---------------------------------------------------------------- recompiles
def test_pipelined_run_never_retraces_past_warmup():
    """A depth-2 pipelined run over warmed (level, bucket) shapes must keep
    the jit trace count exactly at the warmup count: pipelining changes
    dispatch order, never shapes."""
    server = _server(pipeline_depth=2, cache_capacity=0)
    server.warmup()  # every (level, bucket) variant
    warmed = server.n_traces
    assert warmed == len(server.batcher.buckets)  # one level, all buckets

    clients = make_clients(3, n_views=8, img_h=H, img_w=W)
    run_load(server, clients, requests_per_client=4)
    assert server.completed == 12
    assert server.n_traces == warmed  # steady-state serving never retraces


# --------------------------------------------------------------------- dedup
def test_in_flight_dedup_renders_once():
    """N concurrent submits of one quantized pose -> exactly 1 render call;
    every waiter gets the same frame through its own future."""
    server = _server(pipeline_depth=2, cache_capacity=0)  # cache OFF: dedup
    cam = make_cam(H, W)                                  # is the pending table
    futs = [server.submit(cam, client_id=c) for c in range(4)]
    assert server.batcher.pending == 1  # one queued render for 4 requests
    assert server.run() == 4
    rep = server.report()
    assert rep["render"]["calls"] == 1
    assert rep["pipeline"]["deduped"] == 3
    assert rep["completed"] == 4
    frames = [f.result() for f in futs]
    for fr in frames[1:]:
        np.testing.assert_array_equal(frames[0], fr)


def test_dedup_only_within_flight_window():
    # after the first render retires, a cache-off resubmit renders again:
    # the pending table holds only in-flight keys, not history
    server = _server(cache_capacity=0)
    cam = make_cam(H, W)
    server.submit(cam)
    server.run()
    server.submit(cam)
    server.run()
    rep = server.report()
    assert rep["render"]["calls"] == 2 and rep["pipeline"]["deduped"] == 0


# ------------------------------------------------------------ depth invariance
def test_depth1_and_depth2_serve_identical_frames():
    """The same request trace through the sync loop (depth=1) and the
    pipelined ring (depth=2) produces bitwise-identical frames."""
    g = make_scene(n=256, scale=0.06)
    results = {}
    for depth in (1, 2):
        server = _server(g, pipeline_depth=depth, cache_capacity=64)
        clients = make_clients(3, n_views=8, img_h=H, img_w=W)
        futs = []
        for _ in range(4):
            for cl in clients:
                futs.append(server.submit(cl.next_camera(), client_id=cl.client_id))
            server.run()
        results[depth] = [f.result() for f in futs]
        assert server.completed == 12
    for a, b in zip(results[1], results[2]):
        np.testing.assert_array_equal(a, b)


def test_ring_keeps_at_most_depth_in_flight():
    server = _server(pipeline_depth=2, max_batch=1, cache_capacity=0)
    clients = make_clients(1, n_views=16, img_h=H, img_w=W)
    for _ in range(6):
        server.submit(clients[0].next_camera())
    server.run()
    rep = server.report()
    assert rep["pipeline"]["max_in_flight"] == 2  # ring bounded by depth
    assert rep["pipeline"]["in_flight_now"] == 0  # run() drains fully
    assert rep["render"]["calls"] == 6


# ----------------------------------------------------------- future delivery
def test_future_result_drives_pipeline_without_run():
    server = _server(pipeline_depth=2)
    futs = [server.submit(make_cam(H, W, dist=2.0 + 0.2 * i)) for i in range(3)]
    # no explicit run()/step(): awaiting the last future drains everything
    frame = futs[-1].result()
    assert frame.shape == (H, W, 3)
    assert all(f.done() for f in futs)


def test_future_on_idle_pipeline_raises():
    server = _server()
    fut = server.submit(make_cam(H, W))
    server.run()
    assert fut.result() is not None  # resolved; result() is now a plain read
    # a hand-built unresolvable future fails loudly instead of spinning
    from repro.serve_gs.server import FrameFuture

    orphan = FrameFuture(server, ("nope",), fut.requests[0])
    with pytest.raises(RuntimeError, match="idle"):
        orphan.result()


# ----------------------------------------------- frame immutability (cache)
def test_served_frames_are_read_only_and_cache_cannot_be_poisoned():
    server = _server(cache_capacity=64)
    cam = make_cam(H, W)
    frame = server.submit(cam).result()
    assert not frame.flags.writeable
    with pytest.raises(ValueError):
        frame[0, 0, 0] = 123.0  # in-place mutation raises, never corrupts

    # the copy-on-write contract: a client edits a private copy...
    scribbled = frame.copy()
    scribbled[:] = 7.0
    # ...and a later cache hit still returns the pristine frame
    hit = server.submit(cam).result()
    np.testing.assert_array_equal(hit, frame)
    assert float(np.abs(hit).max()) != 7.0
    assert server.report()["render"]["calls"] == 1  # second submit was a hit


# ------------------------------------------------- bounded retirement buffer
def test_frames_buffer_is_bounded_under_sustained_load():
    server = _server(frames_capacity=5, cache_capacity=0)
    clients = make_clients(1, n_views=32, img_h=H, img_w=W)
    futs = [server.submit(clients[0].next_camera()) for _ in range(12)]
    server.run()
    assert server.completed == 12
    assert len(server.frames) == 5  # old frames retired, no unbounded growth
    # the newest frames are the ones retained
    kept = set(server.frames)
    assert kept == {f.request_id for f in futs[-5:]}


def test_store_frames_false_keeps_buffer_empty():
    server = _server(store_frames=False)
    fut = server.submit(make_cam(H, W))
    assert fut.result().shape == (H, W, 3)
    assert len(server.frames) == 0


# ------------------------------------- scrub on a store_frames=False server
def test_scrub_works_with_store_frames_false(tmp_path):
    """Regression: scrub used to read server.frames[rid] and KeyError on any
    server built with store_frames=False (exactly what the CLI driver and the
    throughput benchmark build). Futures deliver frames regardless."""
    import jax.numpy as jnp

    from repro.core import gaussians as G

    rng = np.random.default_rng(3)
    store = TemporalCheckpointStore(str(tmp_path / "seq"), keyframe_interval=2)
    for t in range(3):
        g = G.init_from_points(
            jnp.asarray(rng.normal(0, 0.4, (128, 3)).astype(np.float32) + 0.1 * t),
            jnp.asarray(np.full((128, 3), 0.5, np.float32)),
            init_scale=0.06,
        )
        store.append(t, g)
    cfg = GSConfig(img_h=H, img_w=W, k_per_tile=64)
    server = build_timeline_server(
        store, cfg, n_levels=2, max_batch=2, store_frames=False, pipeline_depth=2
    )
    frames = scrub(server, make_cam(H, W), [0, 1, 2])
    assert set(frames) == {0, 1, 2}
    for t in (0, 1):
        assert np.abs(frames[t] - frames[t + 1]).max() > 1e-4
    assert len(server.frames) == 0  # nothing pinned


# ----------------------------------------------------------- server close()
def test_close_fails_queued_futures_and_rejects_new_submits():
    server = _server(cache_capacity=0)
    fut = server.submit(make_cam(H, W))
    assert server.close() == 1  # queued-but-never-dispatched request failed
    with pytest.raises(RuntimeError, match="closed"):
        fut.result()
    assert fut.done()
    with pytest.raises(RuntimeError, match="closed"):
        server.submit(make_cam(H, W))
    assert server.close() == 0  # idempotent


def test_close_retires_in_flight_work_before_failing_the_queue():
    """close() drains the dispatched ring — those clients get real frames —
    and only never-dispatched requests are failed."""
    server = _server(pipeline_depth=2, max_batch=1, cache_capacity=0)
    futs = [server.submit(make_cam(H, W, dist=2.0 + 0.3 * i)) for i in range(3)]
    server.step()  # dispatches two micro-batches, retires one -> 1 in flight
    assert server.in_flight == 1 and server.batcher.pending == 1
    assert server.close() == 1
    assert futs[0].result().shape == (H, W, 3)  # retired before close
    assert futs[1].result().shape == (H, W, 3)  # in flight: close retired it
    with pytest.raises(RuntimeError, match="closed"):
        futs[2].result()  # still queued: failed loudly, no silent hang


def test_close_releases_retirement_buffer_and_context_manager():
    with _server(store_frames=True, frames_capacity=8) as server:
        fut = server.submit(make_cam(H, W))
        server.run()
        assert len(server.frames) == 1
    assert server.closed and len(server.frames) == 0
    assert fut.result() is not None  # resolved futures survive close


# ------------------------------------------------------- async store writer
def test_async_and_sync_store_roundtrip_identically(tmp_path):
    import jax.numpy as jnp

    from repro.core import gaussians as G

    rng = np.random.default_rng(11)
    base = G.init_from_points(
        jnp.asarray(rng.normal(0, 0.4, (64, 3)).astype(np.float32)),
        jnp.asarray(np.full((64, 3), 0.5, np.float32)),
        init_scale=0.06,
    )
    frames = [base._replace(means=base.means + 0.01 * t) for t in range(4)]

    stores = {
        "async": TemporalCheckpointStore(str(tmp_path / "a"), keyframe_interval=2),
        "sync": TemporalCheckpointStore(str(tmp_path / "s"), keyframe_interval=2, async_writes=False),
    }
    for st in stores.values():
        for t, f in enumerate(frames):
            st.append(t, f)
        st.close()
    assert stores["async"].timesteps() == stores["sync"].timesteps() == [0, 1, 2, 3]
    for t in range(4):
        a, s = stores["async"].load(t), stores["sync"].load(t)
        for name in G.GaussianModel._fields:
            np.testing.assert_array_equal(np.asarray(getattr(a, name)), np.asarray(getattr(s, name)))


def test_store_writer_failure_names_timestep_and_recovers(tmp_path, monkeypatch):
    """A failed background write surfaces (naming the lost timestep) on the
    next flush; later appends still land — promoted to a keyframe when the
    failure left no reconstruction base for a delta."""
    import jax.numpy as jnp

    from repro.core import gaussians as G

    g = G.init_from_points(jnp.zeros((8, 3)), jnp.full((8, 3), 0.5))
    store = TemporalCheckpointStore(str(tmp_path / "seq"), keyframe_interval=2)
    real_write = store._write
    monkeypatch.setattr(
        store, "_write",
        lambda t, host, is_key: (_ for _ in ()).throw(OSError("disk full"))
        if t == 0 else real_write(t, host, is_key),
    )
    store.append(0, g)
    with pytest.raises(RuntimeError, match="timestep 0"):
        store.flush()
    store.append(1, g._replace(means=g.means + 0.5))  # delta slot -> promoted
    store.close()
    assert store.timesteps() == [1]  # t=0 lost (reported), t=1 durable
    assert store._index["timesteps"][0]["kind"] == "key"
    np.testing.assert_allclose(np.asarray(store.load(1).means), 0.5, atol=1e-6)


def test_store_append_after_close_rejected(tmp_path):
    import jax.numpy as jnp

    from repro.core import gaussians as G

    g = G.init_from_points(jnp.zeros((8, 3)), jnp.zeros((8, 3)))
    store = TemporalCheckpointStore(str(tmp_path / "seq"))
    store.append(0, g)
    store.close()
    store.close()  # idempotent
    with pytest.raises(AssertionError):
        store.append(1, g)
