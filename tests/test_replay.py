"""Trace-driven replay tests: cost-model fitting determinism, discrete-event
simulation calibration against a trace with known ground truth, autotune
recommendation stability, the `launch.tune` CLI end to end (recommendation +
calibration record consumed via --config-from), rolling-window SLO state
transitions, and histogram merge/serde algebra."""
import json

import pytest

from repro.launch.tune import load_recommended_knobs
from repro.launch.tune import main as tune_main
from repro.obs import MetricsRegistry, SLOTracker, parse_slo_spec
from repro.obs.autotune import recommend
from repro.obs.costmodel import StackParams, simulate
from repro.obs.metrics import Histogram
from repro.obs.replay import fit, fit_trace, load_trace, train_stage_breakdown

KNOBS = {
    "coalesce_ms": 2.0, "max_batch": 8, "pipeline_depth": 2,
    "queue_limit": 8, "wave_per_session": 4,
}


def synth_trace(*, waves=6, sessions=4, per=2, batch_ms=8.0, period_ms=20.0,
                knobs=KNOBS) -> str:
    """A synthetic but structurally faithful gateway trace: every ``period``
    each session submits ``per`` poses, they coalesce into one wave, render
    as one batch of ``sessions*per``, then encode+write serially. Ground
    truth (fps, latency) is computable from the spans themselves."""
    meta = {"recorded": 0, "dropped": 0, "capacity": 65536,
            "clock": "monotonic", "knobs": dict(knobs)}
    recs = []
    rid = 0
    t = 100.0  # arbitrary monotonic epoch
    size = sessions * per
    for w in range(waves):
        cut = t + 0.002            # the 2ms coalesce window expires
        r0 = cut + 0.0003 * size   # submits run serially before dispatch
        r1 = r0 + batch_ms / 1e3
        sub_end = cut
        for s in range(sessions):
            for k in range(per):
                idx = s * per + k
                ta = t + 0.0002 * idx
                sub_end += 0.0003
                e0 = r1 + 0.0003 * idx
                recs += [
                    {"rid": rid, "span": "admit", "t0": ta, "t1": ta,
                     "session": s, "stream": "static", "timestep": 0},
                    {"rid": rid, "span": "coalesce", "t0": ta, "t1": cut,
                     "wave": w + 1, "wave_size": size},
                    {"rid": rid, "span": "submit", "t0": ta, "t1": sub_end,
                     "outcome": "miss", "level": 0, "timestep": 0},
                    {"rid": rid, "span": "render", "t0": r0, "t1": r1,
                     "batch": size},
                    {"rid": rid, "span": "retire", "t0": r1, "t1": r1 + 1e-4},
                    {"rid": rid, "span": "encode", "t0": e0, "t1": e0 + 1e-4},
                    {"rid": rid, "span": "write", "t0": e0 + 1e-4,
                     "t1": e0 + 2e-4},
                ]
                rid += 1
        t += period_ms / 1e3
    meta["recorded"] = len(recs)
    lines = [json.dumps({"trace_meta": meta})]
    lines += [json.dumps(r) for r in recs]
    return "\n".join(lines) + "\n"


def ground_truth(text: str) -> tuple[float, float]:
    """(fps, p99_ms) straight from the spans: what the traced stack served."""
    _, recs = load_trace(text)
    admits = {r["rid"]: r["t0"] for r in recs if r["span"] == "admit"}
    writes = {r["rid"]: r["t1"] for r in recs if r["span"] == "write"}
    lat = sorted((writes[r] - admits[r]) * 1e3 for r in admits)
    wall = max(writes.values()) - min(admits.values())
    p99 = lat[min(int(0.99 * len(lat)), len(lat) - 1)]
    return len(admits) / wall, p99


# ================================================================== fitting
def test_train_stage_breakdown_reads_training_spans_only():
    """A mixed train+serve trace (one shared Obs bundle) feeds both readers:
    the serving fit ignores training rids, and the training breakdown
    ignores serving spans — each per-stage distribution covers exactly the
    spans of its vocabulary."""
    text = synth_trace(waves=2)
    train = [
        {"rid": 900, "span": "extract", "t0": 50.0, "t1": 50.1, "t_index": 0},
        {"rid": 900, "span": "fit", "t0": 50.1, "t1": 51.1, "mode": "cold"},
        {"rid": 900, "span": "batch", "t0": 50.1, "t1": 50.15, "step": 0},
        {"rid": 900, "span": "device", "t0": 50.2, "t1": 50.5, "step": 0},
        {"rid": 901, "span": "extract", "t0": 51.2, "t1": 51.25, "t_index": 1},
        {"rid": 901, "span": "reseed", "t0": 51.25, "t1": 51.3, "filled": 7},
        {"rid": 901, "span": "fit", "t0": 51.3, "t1": 51.8, "mode": "warm"},
    ]
    mixed = text + "".join(json.dumps(r) + "\n" for r in train)
    meta, recs = load_trace(mixed)

    bd = train_stage_breakdown(recs)
    assert bd["timesteps"] == 2
    assert bd["extract"].count == 2 and bd["fit"].count == 2
    assert bd["reseed"].count == 1
    assert bd["device"].samples == [pytest.approx(0.3)]
    assert "render" not in bd and "admit" not in bd  # serving spans ignored

    # the serving fit still sees only its own request trees
    model = fit(meta, recs)
    assert all(a["rid"] < 900 for a in model.arrivals)


def test_fit_is_deterministic_and_order_independent():
    text = synth_trace()
    m1, m2 = fit_trace(text), fit_trace(text)
    assert m1.fingerprint() == m2.fingerprint()
    # record order must not matter: the fit sorts everything it touches
    meta, records = load_trace(text)
    m3 = fit(meta, list(reversed(records)))
    assert m3.fingerprint() == m1.fingerprint()
    assert m1.knobs == KNOBS
    assert m1.outcome_mix() == {"miss": 48}
    # one batch size observed (8): the scatter is there, the slope is not
    assert list(m1.batch_sizes) == [8]
    assert m1.batch_fit[1] == 0.0
    # submit cost is the *marginal* per-request CPU, not the admit->return
    # span (which embeds the coalesce wait the simulator models itself)
    assert m1.submit["miss"].mean < 0.001


def test_simulate_reproduces_the_trace_it_was_fit_on():
    """The self-calibration property the CI gate enforces on the real smoke
    trace, pinned here on a trace with analytic ground truth: replaying
    under the recorded knobs must land within the 20% budget."""
    text = synth_trace()
    model = fit_trace(text)
    truth_fps, truth_p99 = ground_truth(text)
    pred = simulate(model, StackParams.from_knobs(model.knobs), seed=0)
    assert pred["served"] == 48 and pred["shed"] == 0
    assert abs(pred["frames_per_s"] - truth_fps) / truth_fps < 0.2
    assert abs(pred["p99_ms"] - truth_p99) / truth_p99 < 0.2


def test_simulate_is_deterministic_and_sheds_under_tiny_queues():
    model = fit_trace(synth_trace())
    params = StackParams.from_knobs(model.knobs)
    assert simulate(model, params, seed=0) == simulate(model, params, seed=0)
    # per-session queue of 1 against 2-deep request-ahead: sheds happen,
    # and every arrival is accounted for exactly once
    tight = StackParams.from_knobs({**model.knobs, "queue_limit": 1})
    out = simulate(model, tight, seed=0)
    assert out["shed"] > 0
    assert out["served"] + out["shed"] == len(model.arrivals)
    # unknown knob keys (res, clients, ...) are ignored, not fatal
    assert StackParams.from_knobs({"max_batch": 4, "res": 64}).max_batch == 4


# ================================================================= autotune
def test_recommend_is_deterministic_and_stamps_the_model():
    m = fit_trace(synth_trace())
    r1 = recommend(m, seed=0)
    r2 = recommend(fit_trace(synth_trace()), seed=0)
    assert r1 == r2
    assert r1["model_fingerprint"] == m.fingerprint()
    assert r1["baseline"]["knobs"] == StackParams.from_knobs(m.knobs).to_dict()
    assert r1["evaluated"] > 1
    # the recommendation can't be worse than the baseline it searched from
    assert (r1["recommended"]["predicted"]["frames_per_s"]
            >= r1["baseline"]["predicted"]["frames_per_s"])


def test_tune_cli_recommends_calibrates_and_feeds_config_from(tmp_path):
    trace = tmp_path / "trace.jsonl"
    text = synth_trace()
    trace.write_text(text)
    truth_fps, truth_p99 = ground_truth(text)
    bench = tmp_path / "BENCH.json"
    bench.write_text(json.dumps({
        "bench": "frontend_load", "schema": 2,
        "metrics": {"trace_frames_per_s": round(truth_fps, 2),
                    "trace_p99_ms": round(truth_p99, 3)},
    }))
    rec_path = tmp_path / "rec.json"
    replay_path = tmp_path / "BENCH_replay.json"
    argv = ["--trace", str(trace), "--out", str(rec_path),
            "--measured", str(bench), "--bench-out", str(replay_path)]
    tune_main(argv)  # exits nonzero if calibration misses the 20% budget

    knobs = load_recommended_knobs(str(rec_path))
    assert set(knobs) >= {"coalesce_ms", "max_batch", "pipeline_depth"}
    replay = json.loads(replay_path.read_text())
    assert replay["bench"] == "replay_calibration" and replay["schema"] == 2
    assert replay["metrics"]["calibration_error"] <= 0.2
    assert replay["metrics"]["measured_frames_per_s"] == round(truth_fps, 2)

    # byte-identical on a second run: the determinism contract of the CLI
    rec2 = tmp_path / "rec2.json"
    tune_main(["--trace", str(trace), "--out", str(rec2)])
    assert rec2.read_text() == rec_path.read_text()

    # a bare {knob: value} file also feeds --config-from consumers
    bare = tmp_path / "bare.json"
    bare.write_text(json.dumps({"max_batch": 4}))
    assert load_recommended_knobs(str(bare)) == {"max_batch": 4}


# ====================================================================== SLO
def test_parse_slo_spec_grammar():
    assert parse_slo_spec("p99_ms=250") == {"p99_ms": 250.0}
    assert parse_slo_spec("p99_ms=250,window_s=10,budget=0.05") == {
        "p99_ms": 250.0, "window_s": 10.0, "budget": 0.05}
    with pytest.raises(ValueError, match="p99_ms"):
        parse_slo_spec("window_s=10")
    with pytest.raises(ValueError, match="bad --slo entry"):
        parse_slo_spec("p99_ms=250,latency=5")


def test_slo_window_transitions_ok_warn_breach_and_recover():
    m = MetricsRegistry()
    h = m.histogram("gateway.request_ms")
    tr = SLOTracker(m, p99_ms=50.0, window_s=10.0, budget=0.1)

    for _ in range(100):
        h.observe(10.0)
    rep = tr.report(t=1.0)
    assert rep["state"] == "ok" and rep["window_count"] == 100
    assert rep["burn"] == 0.0

    # 13 violations in 113: 11.5% > 10% budget -> burn 1.15 -> warn
    for _ in range(13):
        h.observe(200.0)
    rep = tr.report(t=2.0)
    assert rep["state"] == "warn" and 1.0 <= rep["burn"] < 2.0

    # pile on: 63/163 = 38.7% -> burn ~3.9 -> breach, and the windowed p99
    # now sits above the target (the bucket edges bound it)
    for _ in range(50):
        h.observe(200.0)
    rep = tr.report(t=3.0)
    assert rep["state"] == "breach" and rep["burn"] >= 2.0
    assert rep["window_p99_ms"] > 50.0

    # nothing new for > window_s: the bad minute ages out, state recovers
    rep = tr.report(t=14.0)
    assert rep["state"] == "ok" and rep["window_count"] == 0
    assert rep["samples_total"] == 163  # lifetime accounting survives

    # a benchmark-lap registry reset rebaselines instead of going negative
    for _ in range(5):
        h.observe(10.0)
    tr.tick(t=15.0)
    m.reset()
    h.observe(10.0)
    rep = tr.report(t=16.0)
    assert rep["state"] == "ok" and rep["window_count"] == 1


# ================================================================ histogram
def test_histogram_merge_is_associative_and_serde_round_trips():
    def mk(vals):
        h = Histogram("lat")
        for v in vals:
            h.observe(v)
        return h

    a, b, c = mk([1.0, 3.0, 9.0]), mk([0.2, 70.0]), mk([500.0] * 4)
    left = Histogram.from_dict(a.to_dict()).merge(b).merge(c)
    bc = Histogram.from_dict(b.to_dict()).merge(c)
    right = Histogram.from_dict(a.to_dict()).merge(bc)
    assert left.state() == right.state()
    assert left.count == 9 and left.total == pytest.approx(2083.2)

    # dict round trip preserves every percentile-bearing field
    rt = Histogram.from_dict(left.to_dict())
    assert rt.state() == left.state()
    assert rt.percentile(50) == left.percentile(50)

    # refusing to merge mismatched bucket layouts is a feature
    other = Histogram("lat", None, (1.0, 2.0, 3.0))
    with pytest.raises(ValueError, match="different bounds"):
        Histogram.from_dict(a.to_dict()).merge(other)
