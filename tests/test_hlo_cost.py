"""The trip-count-aware HLO cost model vs ground truth on known programs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_cost import analyze


def _cost(f, *args):
    co = jax.jit(f).lower(*args).compile()
    return analyze(co.as_text()), co


def test_scan_flops_match_unrolled():
    a = jnp.ones((128, 128))

    def scanned(x):
        def body(c, _):
            return c @ a, None
        y, _ = jax.lax.scan(body, x, None, length=12)
        return y.sum()

    def unrolled(x):
        for _ in range(12):
            x = x @ a
        return x.sum()

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    cs, _ = _cost(scanned, x)
    cu, _ = _cost(unrolled, x)
    # trip-weighted scan flops must match the unrolled program (XLA's own
    # cost_analysis is ~12x off here — the whole reason this module exists)
    assert abs(cs["flops"] - cu["flops"]) / cu["flops"] < 0.02
    expected = 2 * 128**3 * 12
    assert abs(cu["flops"] - expected) / expected < 0.05


def test_matmul_flops_exact():
    def f(a, b):
        return a @ b

    c, _ = _cost(f, jax.ShapeDtypeStruct((64, 32), jnp.float32), jax.ShapeDtypeStruct((32, 16), jnp.float32))
    expected = 2 * 64 * 32 * 16
    assert abs(c["flops"] - expected) / expected < 0.05


def test_nested_scan_multiplies():
    a = jnp.ones((64, 64))

    def nested(x):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ a, None
            c, _ = jax.lax.scan(inner, c, None, length=5)
            return c, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y.sum()

    c, _ = _cost(nested, jax.ShapeDtypeStruct((64, 64), jnp.float32))
    expected = 2 * 64**3 * 15
    assert abs(c["flops"] - expected) / expected < 0.1


def test_dynamic_update_slice_bytes_not_inflated():
    """DUS into a big buffer must count the update region, not the buffer."""
    def f(buf, upd):
        def body(c, i):
            return jax.lax.dynamic_update_slice_in_dim(c, upd, i * 4, axis=0), None
        out, _ = jax.lax.scan(body, buf, jnp.arange(64))
        return out

    buf = jax.ShapeDtypeStruct((4096, 1024), jnp.float32)
    upd = jnp.ones((4, 1024), jnp.float32)
    c, _ = _cost(f, buf, upd)
    # 64 trips x 2*(4*1024*4B) = 2.1MB; buffer itself is 16MB — stay well under
    # a "buffer re-read per trip" interpretation (64 * 16MB = 1GB)
    assert c["bytes"] < 3e8


def test_collectives_parsed_with_groups(tmp_path):
    import subprocess, sys, os, json, textwrap
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, json
        from jax.sharding import PartitionSpec as PS, NamedSharding
        from repro.launch.hlo_cost import analyze
        from repro.core.sharding import shard_map
        mesh = jax.make_mesh((8,), ("d",))
        def f(x):
            return shard_map(lambda a: jax.lax.psum(a, "d"), mesh=mesh,
                             in_specs=PS("d"), out_specs=PS())(x)
        x = jax.ShapeDtypeStruct((1024, 64), jnp.float32)
        co = jax.jit(f).lower(x).compile()
        c = analyze(co.as_text())
        print(json.dumps({k: v["count"] for k, v in c["coll"].items()}))
    """)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True, text=True,
                       env=dict(os.environ, PYTHONPATH="src"), timeout=300,
                       cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, r.stderr[-2000:]
    counts = json.loads(r.stdout.strip().splitlines()[-1])
    assert counts["all-reduce"] >= 1
