"""Tile-granular serving tests: byte-budgeted content-deduplicating cache,
bitwise tile-path equivalence (assembly, strips, partial renders), dirty-row
invalidation, and the cache-key resolution regression."""
import jax
import numpy as np
import pytest

from repro.core import projection as P
from repro.core.config import GSConfig
from repro.core.train import make_batched_eval_render, make_tile_row_render
from repro.serve_gs import (
    FrameCache,
    RenderServer,
    frame_key,
    make_clients,
    stack_cameras,
    tile_key,
)

from conftest import make_cam, make_scene

H = W = 32


def _server(g=None, *, size=H, **kw):
    g = g if g is not None else make_scene(n=256, scale=0.06)
    cfg = GSConfig(img_h=size, img_w=size, k_per_tile=64)
    kw.setdefault("n_levels", 1)
    kw.setdefault("max_batch", 4)
    return RenderServer(g, cfg, **kw)


# ==================================================================== cache
def test_cache_byte_budget_evicts_lru():
    tile = np.zeros((4, 4, 3), np.float32)  # 192 bytes
    c = FrameCache(capacity_bytes=2 * tile.nbytes, dedup=False)
    c.put(("a",), tile.copy())
    c.put(("b",), tile.copy())
    assert c.bytes == 2 * tile.nbytes and len(c) == 2
    assert c.get(("a",)) is not None  # "a" becomes most-recent
    c.put(("c",), tile.copy())  # budget forces "b" (least recent) out
    assert c.get(("b",)) is None and c.get(("c",)) is not None
    s = c.stats()
    assert s["evictions"] == 1 and s["bytes"] == 2 * tile.nbytes


def test_cache_content_dedup_shares_identical_tiles():
    """Identical tile CONTENT is stored once: the background tiles shared by
    every pose of an orbit cost one buffer, not one per pose."""
    bg = np.zeros((4, 4, 3), np.float32)
    c = FrameCache(capacity_bytes=10 * bg.nbytes)
    for i in range(8):
        c.put(("pose", i), bg.copy())
    s = c.stats()
    assert len(c) == 8
    assert s["unique_buffers"] == 1 and s["bytes"] == bg.nbytes
    assert s["dedup_shared"] == 7 and s["dedup_bytes_saved"] == 7 * bg.nbytes
    # deduped entries really alias one read-only buffer
    assert c.get(("pose", 0)) is c.get(("pose", 5))
    # dropping one referencing key keeps the buffer for the others
    c.drop(lambda k: k[1] == 0)
    assert c.bytes == bg.nbytes and c.get(("pose", 1)) is not None


def test_cache_drop_is_accounted_separately_from_eviction():
    """Satellite: drop() (invalidation) must keep the same accounting the
    eviction loop does — bytes released, and a ``dropped`` counter distinct
    from ``evictions``."""
    c = FrameCache(capacity_bytes=1 << 20)
    for i in range(4):
        c.put((0, i), np.full((4, 4, 3), i, np.float32))
    before = c.bytes
    assert before > 0
    n = c.drop(lambda k: k[1] < 2)
    s = c.stats()
    assert n == 2 and s["dropped"] == 2 and s["evictions"] == 0
    assert c.bytes < before and len(c) == 2


def test_cache_entry_capacity_still_enforced():
    c = FrameCache(capacity=2)
    f = np.zeros((2, 2, 3), np.float32)
    c.put(("a",), f.copy())
    c.put(("b",), f.copy())
    c.put(("c",), f.copy())
    assert len(c) == 2 and c.stats()["evictions"] == 1


def test_cache_off_at_zero_budget():
    c = FrameCache(capacity_bytes=0)
    c.put(("a",), np.zeros((2, 2, 3), np.float32))
    assert len(c) == 0 and c.get(("a",)) is None


# ============================================= frame_key resolution satellite
def test_same_pose_different_resolution_never_shares_cache(tmp_path):
    """Regression: frame_key omitted the render resolution, so two servers
    (or any two configs) at the same quantized pose but different output
    sizes shared a key — a cache hit then returned a wrong-size frame (or,
    tile-granular, stitched tiles of the wrong frame). Keys now carry
    (height, width)."""
    g = make_scene(n=256, scale=0.06)
    cam = make_cam(H, W)
    big = _server(g, size=2 * H)
    small = _server(g, size=H)
    small.cache = big.cache  # one shared cache, two resolutions
    f_big = big.submit(cam).result()
    f_small = small.submit(cam).result()
    assert f_big.shape == (2 * H, 2 * W, 3)
    assert f_small.shape == (H, W, 3)
    # the small server really rendered (no cross-resolution key collision)
    assert small.report()["render"]["calls"] == 1
    ref = _server(g, size=H)
    np.testing.assert_array_equal(f_small, ref.submit(cam).result())


# ==================================================== bitwise tile-path suite
def test_strip_render_rows_bitwise_equal_full_frame():
    cfg = GSConfig(img_h=H, img_w=W, k_per_tile=64)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    g = make_scene(n=256, scale=0.06)
    cam = make_cam(H, W)
    full = np.asarray(make_batched_eval_render(mesh, cfg)(g, stack_cameras([cam])))[0]
    cam_np = P.Camera(*[np.asarray(x) for x in cam])
    for row in range(H // cfg.tile_h):
        strip = np.asarray(make_tile_row_render(mesh, cfg, row=row)(g, cam_np))
        np.testing.assert_array_equal(strip, full[row * cfg.tile_h : (row + 1) * cfg.tile_h])


@pytest.mark.parametrize("depth", [1, 2])
def test_tile_server_bitwise_equals_whole_frame_baseline(depth):
    """THE equivalence suite: the tile-granular server serves bitwise the
    same frames as the whole-frame baseline across LOD levels, timesteps,
    pipeline depths, and cache replays (assembled-from-tiles frames
    included)."""
    g = make_scene(n=300, scale=0.06)
    g2 = g._replace(means=g.means + np.float32(0.15))
    results = {}
    for tiled in (False, True):
        server = _server(
            g, n_levels=2, pipeline_depth=depth, tile_cache=tiled, cache_capacity=64
        )
        server.add_timestep(1, g2)
        clients = make_clients(3, n_views=6, img_h=H, img_w=W, radius_spread=1.0)
        futs = []
        for r in range(3):
            for cl in clients:
                cam = cl.next_camera()
                futs.append(server.submit(cam, timestep=r % 2))
            # a far viewer exercises the coarse LOD level each round
            futs.append(server.submit(make_cam(H, W, dist=40.0 + r), timestep=0))
            server.run()
        # replay one client's orbit: tile path serves assembled cache hits
        replay = make_clients(3, n_views=6, img_h=H, img_w=W, radius_spread=1.0)
        for cl in replay:
            futs.append(server.submit(cl.next_camera(), timestep=0))
        server.run()
        results[tiled] = [f.result() for f in futs]
        rep = server.report()
        assert rep["lod"]["requests_per_level"][1] > 0  # both levels exercised
        if tiled:
            assert rep["cache"]["hits"] >= 3  # the replay hit assembled tiles
    for a, b in zip(results[False], results[True]):
        np.testing.assert_array_equal(a, b)


def test_partial_hit_renders_only_missing_rows():
    server = _server(cache_capacity=64)
    cam = make_cam(H, W)
    first = server.submit(cam).result()
    calls = server.report()["render"]["calls"]
    tiles_y = server.tiles_y
    server.invalidate(0, rows=[0])  # drop one tile row for this timestep
    fut = server.submit(cam)
    frame = fut.result()
    rep = server.report()
    assert rep["tiles"]["partial_hits"] == 1
    assert rep["tiles"]["rows_rendered_partial"] == 1  # only the dropped row
    assert rep["render"]["calls"] == calls  # no full-frame micro-batch ran
    assert rep["tiles"]["renders_per_frame"] < 1.0
    assert not frame.flags.writeable
    np.testing.assert_array_equal(frame, first)  # model unchanged: bitwise
    assert tiles_y > 1  # the test is vacuous on a single-row config


def test_repeated_full_hits_are_zero_copy():
    """The stitched frame is cached alongside its tiles: a repeated full hit
    hands back the SAME read-only buffer, not a fresh assembly."""
    server = _server(cache_capacity=64)
    cam = make_cam(H, W)
    first = server.submit(cam).result()
    assert server.submit(cam).result() is first
    assert server.report()["render"]["calls"] == 1


def test_invalidate_notifies_listeners_and_counts_drops():
    server = _server(cache_capacity=64)
    seen = []
    server.add_invalidation_listener(lambda ts, rows: seen.append((ts, rows)))
    server.submit(make_cam(H, W)).result()
    dropped = server.invalidate(0)
    assert dropped == server.n_tiles + 1  # every tile + the assembled frame
    assert seen == [(0, None)]  # whole-frame drop: rows is None
    assert server.report()["cache"]["tiles"]["dropped"] == dropped
    # a row-granular invalidation reports exactly the dropped row set
    server.submit(make_cam(H, W)).result()
    server.invalidate(0, rows=[0])
    assert seen[-1] == (0, frozenset({0}))


def test_row_invalidate_on_whole_frame_server_fails_loudly():
    """A whole-frame cache has no row-granular entries: silently widening a
    rows= invalidation to the full frame would hide the caller's wrong
    assumption about what stayed cached."""
    server = _server(tile_cache=False, cache_capacity=64)
    server.submit(make_cam(H, W)).result()
    with pytest.raises(ValueError, match="tile_cache"):
        server.invalidate(0, rows=[0])
    with pytest.raises(ValueError, match="not both"):
        server.add_timestep(0, make_scene(n=256, scale=0.06),
                            changed=[1], dirty_rows=[0])
    server.invalidate(0)  # the full drop still works


def _projected_rows(params, idx, cam, *, img_h, tile_h, pad=0.0):
    """Tile rows covered by the given Gaussians' screen footprints."""
    packed = np.asarray(P.project(params, cam))
    my, rad = packed[idx, P.MY], packed[idx, P.RAD]
    live = rad > 0
    rows = set()
    for y, r in zip(my[live], rad[live]):
        lo = int(np.floor((y - r - pad) / tile_h))
        hi = int(np.floor((y + r + pad) / tile_h))
        rows.update(range(max(lo, 0), min(hi, img_h // tile_h - 1) + 1))
    return rows


def test_add_timestep_dirty_rows_rerenders_only_the_update_region():
    """The in situ partial-invalidation path end-to-end: replacing a model
    whose update touches a bounded screen region with ``dirty_rows`` makes
    the next request a partial hit — and the served frame is bitwise the
    full re-render of the NEW model."""
    size = 48  # 3 tile rows: a one-row update leaves 2/3 of the frame cached
    rng = np.random.default_rng(7)
    g = make_scene(n=300, scale=0.05)
    cam = make_cam(size, size)
    # perturb only Gaussians whose projection sits in the upper screen band
    packed = np.asarray(P.project(g, cam))
    changed = np.nonzero((packed[:, P.MY] < 18.0) & (packed[:, P.RAD] > 0))[0]
    assert changed.size > 0
    means2 = np.asarray(g.means).copy()
    means2[changed] += rng.normal(0, 0.02, (changed.size, 3)).astype(np.float32)
    g2 = g._replace(means=means2)

    server = _server(g, size=size, cache_capacity=64)
    old = server.submit(cam).result()
    rows = _projected_rows(g, changed, cam, img_h=size, tile_h=16)
    rows |= _projected_rows(g2, changed, cam, img_h=size, tile_h=16)
    assert len(rows) < server.tiles_y, "update must not cover the whole frame"
    server.add_timestep(0, g2, dirty_rows=rows)
    frame = server.submit(cam).result()
    rep = server.report()
    assert rep["tiles"]["partial_hits"] == 1
    assert rep["tiles"]["rows_rendered_partial"] == len(rows)
    # ground truth: a fresh server fully renders the new model
    ref = _server(g2, size=size).submit(cam).result()
    np.testing.assert_array_equal(frame, ref)
    assert np.abs(frame - old).max() > 0  # the update was actually visible


def test_add_timestep_changed_autocomputes_dirty_rows():
    """The world-space path end-to-end: ``add_timestep(changed=idx)`` needs
    NO caller row math — the server projects the changed slots through the
    cached pose, drops only their rows, and the next request is a partial
    hit serving bitwise the full re-render of the new model. The computed
    rows must be no looser than a (padded) hand-computed footprint."""
    size = 48  # 3 tile rows
    rng = np.random.default_rng(7)
    g = make_scene(n=300, scale=0.05)
    cam = make_cam(size, size)
    packed = np.asarray(P.project(g, cam))
    changed = np.nonzero((packed[:, P.MY] < 18.0) & (packed[:, P.RAD] > 0))[0]
    assert changed.size > 0
    means2 = np.asarray(g.means).copy()
    means2[changed] += rng.normal(0, 0.02, (changed.size, 3)).astype(np.float32)
    g2 = g._replace(means=means2)

    server = _server(g, size=size, cache_capacity=64)
    old = server.submit(cam).result()  # registers the pose + fills the tiles
    hand = _projected_rows(g, changed, cam, img_h=size, tile_h=16, pad=2.0)
    hand |= _projected_rows(g2, changed, cam, img_h=size, tile_h=16, pad=2.0)
    assert len(hand) < server.tiles_y, "update must not cover the whole frame"
    server.add_timestep(0, g2, changed=changed)
    frame = server.submit(cam).result()
    rep = server.report()
    assert rep["tiles"]["partial_hits"] == 1
    assert 0 < rep["tiles"]["rows_rendered_partial"] <= len(hand)
    ref = _server(g2, size=size).submit(cam).result()
    np.testing.assert_array_equal(frame, ref)
    assert np.abs(frame - old).max() > 0


def test_add_timestep_changed_true_diffs_old_vs_new():
    """``changed=True`` makes the server diff the parameters itself; a
    bit-identical re-registration must then drop NOTHING."""
    size = 48
    g = make_scene(n=300, scale=0.05)
    cam = make_cam(size, size)
    server = _server(g, size=size, cache_capacity=64)
    server.submit(cam).result()
    entries = len(server.cache)
    seen = []
    server.add_invalidation_listener(lambda ts, rows: seen.append((ts, rows)))
    server.add_timestep(0, g, changed=True)  # identical params
    assert len(server.cache) == entries and seen == []
    # a real single-slot change drops a strict subset of the rows
    means2 = np.asarray(g.means).copy()
    means2[0] += np.float32(0.01)
    server.add_timestep(0, g._replace(means=means2), changed=True)
    assert len(seen) == 1 and seen[0][1] is not None


def test_changed_with_no_cached_poses_falls_back_to_full_drop():
    size = 48
    g = make_scene(n=300, scale=0.05)
    server = _server(g, size=size, cache_capacity=64)
    seen = []
    server.add_invalidation_listener(lambda ts, rows: seen.append(rows))
    server.add_timestep(0, g._replace(means=np.asarray(g.means) + 0.01),
                        changed=[0, 1])
    assert seen == [None]  # no registered pose: conservative whole drop


def test_world_space_dirty_rows_conservative_property():
    """Satellite: the conservativeness property. Random slot perturbations
    across several cached poses — every pixel that changes between old and
    new renders lies inside the computed dirty row set, and the complement
    rows are bitwise identical between old and new frames."""
    from repro.serve_gs import dirty_rows as footprint_rows

    size = 48
    th = 16
    rng = np.random.default_rng(11)
    g = make_scene(n=300, scale=0.05)
    server = _server(g, size=size, cache_capacity=256, store_frames=True)
    cams = [make_cam(size, size), make_cam(size, size, dist=6.0)]
    olds = [server.submit(c, timestep=0).result() for c in cams]
    for trial in range(3):
        idx = rng.choice(300, size=int(rng.integers(1, 8)), replace=False)
        means2 = np.asarray(g.means).copy()
        means2[idx] += rng.normal(0, 0.06, (idx.size, 3)).astype(np.float32)
        g2 = g._replace(means=means2)
        ts = 10 + trial  # fresh timeline slot: full renders of the new model
        server.add_timestep(ts, g2)
        for cam, old in zip(cams, olds):
            rows = footprint_rows(
                [g, g2], idx, cam, img_h=size, img_w=size, tile_h=th
            )
            new = server.submit(cam, timestep=ts).result()
            pixel_rows = {
                r for r in range(size // th)
                if np.abs(new[r * th:(r + 1) * th].astype(np.float32)
                          - old[r * th:(r + 1) * th]).max() > 0
            }
            assert pixel_rows <= rows, (trial, pixel_rows, rows)
            for r in set(range(size // th)) - rows:
                np.testing.assert_array_equal(
                    new[r * th:(r + 1) * th], old[r * th:(r + 1) * th]
                )


def test_tile_cache_dedup_across_orbit_poses():
    """Background tiles (empty black) recur across orbit poses and must be
    stored once — the mechanism that lets a tile cache hold more poses than
    a whole-frame cache of the same byte budget."""
    size = 64  # 4x4 tile grid: corner tiles are pure background
    server = _server(size=size, cache_capacity=64)
    # far orbit: the scene covers a fraction of the screen, the rest is
    # identical background tiles from every pose
    clients = make_clients(1, n_views=8, img_h=size, img_w=size, base_radius=10.0)
    for _ in range(8):
        server.submit(clients[0].next_camera())
    server.run()
    s = server.report()["cache"]["tiles"]
    assert s["dedup_shared"] > 0
    assert s["bytes"] + s["dedup_bytes_saved"] > s["bytes"]


# ============================================================ foveated LOD
def test_select_level_map_profiles():
    from repro.serve_gs import select_level_map

    server = _server(n_levels=3, size=48)
    pyr, cam = server.pyramid, make_cam(48, 48)
    # no hints: uniform at the coverage level
    uni = select_level_map(pyr, cam, img_w=48, tiles_y=5)
    assert len(set(uni)) == 1 and len(uni) == 5
    base = uni[0]
    n_lvl = len(pyr.levels)
    # gaze: +1 level per row beyond the sharp zone, clamped to the pyramid
    m = select_level_map(pyr, cam, img_w=48, tiles_y=5, gaze_row=0, sharp_rows=1)
    assert m == tuple(min(base + max(r - 1, 0), n_lvl - 1) for r in range(5))
    # generous budget: everything sharp
    assert select_level_map(
        pyr, cam, img_w=48, tiles_y=5, gaze_row=2, budget_rows=5.0
    ) == (base,) * 5
    # starvation budget: the steepest profile, never an error
    tight = select_level_map(
        pyr, cam, img_w=48, tiles_y=5, gaze_row=2, budget_rows=0.0
    )
    assert tight == tuple(min(base + abs(r - 2), n_lvl - 1) for r in range(5))


def test_foveated_frame_assembles_bitwise_from_per_level_tiles():
    """A mixed-level frame must be row-for-row bitwise identical to the
    uniform render of each row's assigned level — and reuse the uniform
    frames' cached tiles (only the coarse rows strip-render)."""
    size = 48  # 3 tile rows
    th = 16
    g = make_scene(n=300, scale=0.06)
    server = _server(g, size=size, n_levels=2, cache_capacity=256)
    cam = make_cam(size, size)
    uniform = server.submit(cam).result()  # level 0, fills its tiles
    calls = server.report()["render"]["calls"]

    fov = server.submit(cam, gaze=(0.5, 0.0)).result()  # gaze at the top
    rep = server.report()
    assert rep["lod"]["foveated_requests"] == 1
    # sharp zone reused the uniform level-0 tiles: only coarse rows rendered
    assert rep["render"]["calls"] == calls
    assert rep["tiles"]["partial_hits"] == 1
    assert 0 < rep["tiles"]["rows_rendered_partial"] < server.tiles_y
    # per-row ground truth from the engine's own level renders
    entry = server._timeline[0]
    from repro.serve_gs import stack_cameras as _stack
    levels = {
        lvl: np.asarray(server._level_render[lvl](entry.level_params[lvl], _stack([cam])))[0]
        for lvl in range(len(entry.level_params))
    }
    np.testing.assert_array_equal(levels[0], uniform)
    expected = (0, 0, 1)  # gaze row 0, sharp_rows=1 -> rows 0,1 sharp, row 2 coarse
    for r, lvl in enumerate(expected):
        np.testing.assert_array_equal(
            fov[r * th:(r + 1) * th], levels[lvl][r * th:(r + 1) * th]
        )
    assert np.abs(fov.astype(np.float32) - uniform).max() > 0  # really mixed
    # the stitched mixed frame is itself cached: replay is a zero-copy hit
    assert server.submit(cam, gaze=(0.5, 0.0)).result() is fov
    # per-level row accounting reached the report
    assert rep["lod"]["rows_per_level"][0] >= server.tiles_y + 2
    assert rep["lod"]["rows_per_level"][1] >= 1


def test_gaze_hint_ignored_on_whole_frame_server():
    server = _server(tile_cache=False, cache_capacity=64)
    cam = make_cam(H, W)
    a = server.submit(cam).result()
    b = server.submit(cam, gaze=(0.5, 0.0), budget_ms=1.0).result()
    np.testing.assert_array_equal(a, b)


def test_frame_key_is_prefix_of_tile_keys():
    cam = make_cam(H, W)
    k = frame_key(cam, 0, height=H, width=W)
    tk = tile_key(k, 3)
    assert tk[: len(k)] == k and tk[-1] == 3 and tk[0] == 0
