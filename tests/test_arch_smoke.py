"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned family runs one forward/train step + one decode step on CPU with
shape and finiteness assertions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch
from repro.models import api, lm

B, S = 2, 32


def _batch(cfg, key):
    if cfg.arch_type == "whisper":
        return {
            "audio_embeds": jax.random.normal(key, (B, cfg.n_audio_ctx, cfg.d_model), jnp.float32) * 0.1,
            "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
            "labels": jax.random.randint(key, (B, S), 0, cfg.vocab),
        }
    if cfg.arch_type == "vlm":
        return {
            "embeds": jax.random.normal(key, (B, S, cfg.d_model), jnp.float32),
            "positions3": jnp.broadcast_to(jnp.arange(S)[None, :, None], (B, S, 3)).astype(jnp.int32),
            "labels": jax.random.randint(key, (B, S), 0, cfg.vocab),
        }
    return {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab),
    }


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_reduced_variant(arch):
    mod = get_arch(arch)
    cfg = mod.smoke_config()
    # reduced-variant contract from the assignment
    assert cfg.n_layers <= 4 and cfg.d_model <= 512 and cfg.n_experts <= 4

    key = jax.random.key(0)
    params = lm.init_params(cfg, key)
    batch = _batch(cfg, key)

    # one train step (loss + grads + adamw update)
    opt = api.adamw_init(params)
    train = jax.jit(api.make_train_step(cfg))
    params2, opt2, metrics = train(params, opt, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # params actually changed
    deltas = [float(jnp.max(jnp.abs(a - b))) for a, b in
              zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(params2))]
    assert max(deltas) > 0

    # one decode step against a cache
    cache = api.init_cache(cfg, B, 64)
    serve = jax.jit(api.make_serve_step(cfg))
    logits, cache2 = serve(params, cache, jnp.zeros((B, 1), jnp.int32), jnp.asarray(3, jnp.int32))
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ["qwen3_0_6b", "xlstm_350m", "zamba2_7b", "gemma3_27b"])
def test_full_config_matches_spec(arch):
    cfg = get_arch(arch).config()
    spec = {
        "qwen3_0_6b": dict(n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8, d_ff=3072, vocab=151936),
        "xlstm_350m": dict(n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, vocab=50304),
        "zamba2_7b": dict(n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, d_ff=14336, vocab=32000),
        "gemma3_27b": dict(n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, d_ff=21504, vocab=262144),
    }[arch]
    for k, v in spec.items():
        assert getattr(cfg, k) == v, (k, getattr(cfg, k), v)


def test_decode_incremental_matches_prefix_forward():
    """Decoding tokens one-by-one reproduces teacher-forced logits (dense)."""
    cfg = get_arch("qwen3_0_6b").smoke_config()
    key = jax.random.key(1)
    params = lm.init_params(cfg, key)
    toks = jax.random.randint(key, (1, 8), 0, cfg.vocab)

    from repro.models import common as C
    x = C.embed_lookup(params["embed"], toks)
    positions = jnp.broadcast_to(jnp.arange(8)[None], (1, 8))
    h = lm.backbone_train(cfg, params, x, positions)
    full_logits = C.lm_logits(params["embed"], h)  # (1,8,V)

    cache = api.init_cache(cfg, 1, 8)
    serve = jax.jit(api.make_serve_step(cfg))
    outs = []
    for t in range(8):
        logits, cache = serve(params, cache, toks[:, t : t + 1], jnp.asarray(t, jnp.int32))
        outs.append(np.asarray(logits[0, 0]))
    dec = np.stack(outs)
    np.testing.assert_allclose(dec, np.asarray(full_logits[0]), atol=2e-3, rtol=2e-3)


def test_decode_matches_prefix_forward_ssm():
    """Same consistency property for the recurrent (mamba) family."""
    cfg = get_arch("zamba2_7b").smoke_config()
    key = jax.random.key(2)
    params = lm.init_params(cfg, key)
    toks = jax.random.randint(key, (1, 6), 0, cfg.vocab)

    from repro.models import common as C
    x = C.embed_lookup(params["embed"], toks)
    positions = jnp.broadcast_to(jnp.arange(6)[None], (1, 6))
    h = lm.backbone_train(cfg, params, x, positions)
    full_logits = C.lm_logits(params["embed"], h)

    cache = api.init_cache(cfg, 1, 6)
    serve = jax.jit(api.make_serve_step(cfg))
    outs = []
    for t in range(6):
        logits, cache = serve(params, cache, toks[:, t : t + 1], jnp.asarray(t, jnp.int32))
        outs.append(np.asarray(logits[0, 0]))
    np.testing.assert_allclose(np.stack(outs), np.asarray(full_logits[0]), atol=5e-3, rtol=5e-3)


def test_sliding_window_ring_cache():
    """gemma3-style local attention: ring cache gives same logits as a cache
    big enough to hold everything (when seq < window)."""
    import dataclasses
    cfg = get_arch("gemma3_27b").smoke_config()
    cfg = dataclasses.replace(cfg, sliding_window=4)
    key = jax.random.key(3)
    params = lm.init_params(cfg, key)
    toks = jax.random.randint(key, (1, 8), 0, cfg.vocab)
    serve = jax.jit(api.make_serve_step(cfg))
    cache_small = api.init_cache(cfg, 1, 8)   # local layers get ring size 4
    outs = []
    for t in range(8):
        logits, cache_small = serve(params, cache_small, toks[:, t:t+1], jnp.asarray(t, jnp.int32))
        outs.append(np.asarray(logits[0, 0]))
    # teacher-forced reference with the same window
    from repro.models import common as C
    x = C.embed_lookup(params["embed"], toks)
    positions = jnp.broadcast_to(jnp.arange(8)[None], (1, 8))
    h = lm.backbone_train(cfg, params, x, positions)
    ref = np.asarray(C.lm_logits(params["embed"], h)[0])
    np.testing.assert_allclose(np.stack(outs), ref, atol=2e-3, rtol=2e-3)
