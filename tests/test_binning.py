"""Tile-binning equivalence: hierarchical 2-level binning vs flat (and the
params3d gather mode's packed-splat equivalence)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import projection as P
from repro.core import render as R

from conftest import make_cam, make_scene


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 500), n=st.sampled_from([100, 400, 900]))
def test_hier_binning_equals_flat(seed, n):
    g = make_scene(n, seed=seed)
    cam = make_cam(128, 128)
    packed, _ = P.sort_by_depth(P.project(g, cam))
    i1, v1 = R.build_tile_lists(packed, img_h=128, img_w=128, tile_h=16, tile_w=16, k_per_tile=128)
    i2, v2 = R.build_tile_lists_hier(
        packed, img_h=128, img_w=128, tile_h=16, tile_w=16, k_per_tile=128, block=4, k_block_mult=4
    )
    assert bool(jnp.all(v1 == v2))
    assert bool(jnp.all(jnp.where(v1, i1, -1) == jnp.where(v2, i2, -1)))


def test_hier_binning_rectangular_and_offset():
    g = make_scene(300, seed=3)
    cam = make_cam(64, 128)
    packed, _ = P.sort_by_depth(P.project(g, cam))
    img1, t1 = R.render_packed(packed, img_h=64, img_w=128, k_per_tile=128, binning="flat", row_offset=0)
    img2, t2 = R.render_packed(packed, img_h=64, img_w=128, k_per_tile=128, binning="hier", row_offset=0)
    np.testing.assert_allclose(np.asarray(img1), np.asarray(img2), atol=1e-7)
    np.testing.assert_allclose(np.asarray(t1), np.asarray(t2), atol=1e-7)


def test_auto_binning_dispatch():
    g = make_scene(50, seed=4)
    cam = make_cam(32, 32)
    packed, _ = P.sort_by_depth(P.project(g, cam))
    # 4 tiles -> flat; must still render correctly
    img, t = R.render_packed(packed, img_h=32, img_w=32, k_per_tile=64, binning="auto")
    assert img.shape == (32, 32, 3) and bool(jnp.isfinite(img).all())
