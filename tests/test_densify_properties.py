"""Hypothesis property tests on densification / pruning / rebalancing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import gaussians as G
from repro.core.config import GSConfig
from repro.core.densify import DEAD_LOGIT, densify_and_rebalance
from repro.core.train import init_state


def _state(n, seed, *, hot_frac=0.3, low_opacity_frac=0.2):
    r = np.random.default_rng(seed)
    pts = r.normal(0, 0.4, (n, 3)).astype(np.float32)
    g = G.init_from_points(jnp.asarray(pts), init_scale=0.05)
    opac = r.uniform(0.05, 3.0, n).astype(np.float32)
    low = r.random(n) < low_opacity_frac
    opac[low] = -8.0  # sigmoid ~ 3e-4 < prune threshold
    g = g._replace(opacity_logit=jnp.asarray(opac))
    st_ = init_state(g)
    grad = np.zeros(n, np.float32)
    hot = r.random(n) < hot_frac
    grad[hot] = 1.0  # >> densify_grad_thresh after /vis
    st_ = st_._replace(
        grad2d_accum=jnp.asarray(grad),
        vis_count=jnp.ones((n,), jnp.float32),
        max_radii=jnp.full((n,), 3.0, jnp.float32),
    )
    return st_, hot, low


@settings(max_examples=10, deadline=None)
@given(n=st.integers(100, 600), seed=st.integers(0, 1000), shards=st.sampled_from([1, 2, 4]))
def test_densify_invariants(n, seed, shards):
    cfg = GSConfig(pad_quantum=64)
    state, hot, low = _state(n, seed)
    new_state, rep = densify_and_rebalance(state, cfg, n_shards=shards, scene_extent=1.0)

    # padded count divides the shard quantum; report is self-consistent
    assert rep.n_padded % (shards * cfg.pad_quantum) == 0
    assert rep.n_padded == new_state.params.n
    assert rep.n_after <= rep.n_padded
    assert rep.n_after == rep.n_before - rep.n_pruned - rep.n_split + rep.n_cloned + 2 * rep.n_split

    # every padding gaussian is dead (never rasterized)
    logit = np.asarray(new_state.params.opacity_logit)
    assert np.all(logit[rep.n_after:] <= DEAD_LOGIT + 1e-6)

    # adam moments for brand-new gaussians are zeroed
    m = np.asarray(new_state.adam.m.means)
    n_kept = rep.n_before - rep.n_pruned - rep.n_split
    assert np.all(m[n_kept:] == 0.0)

    # no NaNs anywhere (padding means are large-but-finite sentinels)
    for leaf in jax.tree_util.tree_leaves(new_state.params):
        assert np.isfinite(np.asarray(leaf)).all()


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 100))
def test_prune_only_removes_low_opacity(seed):
    cfg = GSConfig(pad_quantum=64, densify_grad_thresh=1e9)  # no clone/split
    state, hot, low = _state(300, seed)
    new_state, rep = densify_and_rebalance(state, cfg, n_shards=1)
    assert rep.n_cloned == 0 and rep.n_split == 0
    assert rep.n_after == rep.n_before - rep.n_pruned
    # survivors keep their (sorted) opacity multiset
    old = np.sort(np.asarray(state.params.opacity_logit))
    surv = old[old > np.log(cfg.prune_opacity_thresh / (1 - cfg.prune_opacity_thresh))]
    new = np.sort(np.asarray(new_state.params.opacity_logit)[: rep.n_after])
    np.testing.assert_allclose(new, surv, atol=1e-6)
